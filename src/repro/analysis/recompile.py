"""Recompile-hazard audit: the plan must not widen the step cache.

The zero-retrace contract (pinned by tests/test_replan.py) hangs on the
:class:`~repro.core.planexec.ExecPlan` split: ``perms``/``omega`` are
pytree CHILDREN (device data — replans swap them without retracing) and
everything else is static aux hashed into ``static_key()``.  Three
drift modes silently break it:

  * a child leaf that is a Python scalar/list becomes a weak-typed trace
    constant — every new value is a new trace;
  * an aux field left out of ``static_key()`` makes two plans that lower
    differently share a cache entry (or, via pytree aux equality, still
    retrace while the documented key says they should not);
  * an unhashable aux field (list, np.ndarray) crashes or defeats the
    jit cache outright.

This pass checks a live ExecPlan instance against those modes, and
``audit_plan_pair`` asserts the documented cache identity: two plans
that differ only in device data must share a ``static_key``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import numpy as np

from repro.core.planexec import ExecPlan

from repro.analysis.report import AuditReport

PASS = "recompile_hazard"

_CHILD_FIELDS = ("perms", "omega")


def _is_device_leaf(x) -> bool:
    return isinstance(x, (jax.Array, np.ndarray))


def audit_exec_plan(ep: ExecPlan, report: AuditReport,
                    where: str = "exec_plan") -> dict:
    """Static-key hygiene of one lowered plan."""
    report.ran(PASS)
    info: dict = {}

    # 1. the static key must be hashable (it IS the jit cache key)
    key = ep.static_key()
    try:
        hash(key)
        info["static_key_hashable"] = True
    except TypeError:
        info["static_key_hashable"] = False
        bad = []
        for i, part in enumerate(key):
            try:
                hash(part)
            except TypeError:
                bad.append(i)
        report.add(PASS, where,
                   "static_key() is unhashable — the compiled-step cache "
                   "cannot key on it",
                   details={"unhashable_positions": bad})

    # 2. children must be device data (arrays), never Python scalars or
    #    lists — those become per-value trace constants
    children = jax.tree.leaves(ep)
    n_bad_children = 0
    for leaf in children:
        if not _is_device_leaf(leaf):
            n_bad_children += 1
            report.add(PASS, where,
                       f"pytree child leaf of type {type(leaf).__name__} "
                       f"is a trace constant — every new value retraces",
                       details={"type": type(leaf).__name__,
                                "value": repr(leaf)[:80]})
    info["n_children"] = len(children)

    # 3. weak-typed children promote differently per call site: a weak
    #    omega forged from a Python float retraces against a strong one
    for name in _CHILD_FIELDS:
        val = getattr(ep, name, None)
        for leaf in jax.tree.leaves(val):
            if getattr(leaf, "weak_type", False):
                report.add(PASS, where,
                           f"child '{name}' carries a weak-typed array — "
                           f"dtype promotion differences will retrace",
                           details={"field": name,
                                    "dtype": str(leaf.dtype)})

    # 4. every aux (non-child) field must be folded into static_key():
    #    an unhashed field means two plans the cache treats as identical
    #    can lower different programs
    def _eq(a, b) -> bool:
        if isinstance(b, (jax.Array, np.ndarray)):
            return False
        try:
            return bool(a == b)
        except Exception:
            return False

    missing = []
    for f in dataclasses.fields(ep):
        if f.name in _CHILD_FIELDS:
            continue
        val = getattr(ep, f.name)
        if not any(_eq(val, part) for part in key):
            missing.append(f.name)
    if missing:
        report.add(PASS, where,
                   "plan field(s) missing from static_key() — the "
                   "compiled-step cache is wider than the documented "
                   "(bucket_sig, seg_sig) identity",
                   details={"missing_fields": missing})
    info["aux_fields_in_key"] = not missing

    # 5. aux fields must not hold device arrays (device data in a hash
    #    key pins buffers and compares by identity)
    for f in dataclasses.fields(ep):
        if f.name in _CHILD_FIELDS:
            continue
        for leaf in jax.tree.leaves(getattr(ep, f.name)):
            if isinstance(leaf, jax.Array):
                report.add(PASS, where,
                           f"aux field '{f.name}' holds a device array — "
                           f"static aux must be host data",
                           details={"field": f.name})
    return info


def audit_plan_pair(ep_a: ExecPlan, ep_b: ExecPlan, expect_same: bool,
                    report: AuditReport,
                    where: str = "exec_plan_pair") -> bool:
    """Assert the documented cache identity between two lowered plans:
    same (bucket/segment) signature -> same key (a replan that only moves
    device data must NOT retrace); different signature -> different key."""
    report.ran(PASS)
    same = ep_a.static_key() == ep_b.static_key()
    if same != expect_same:
        report.add(PASS, where,
                   ("plans that should share a compiled step have "
                    "different static keys — every replan would retrace"
                    if expect_same else
                    "plans with different schedules share a static key — "
                    "the cache would serve the wrong executable"),
                   details={"expect_same": expect_same, "same": same})
    return same == expect_same


def audit_trace_constants(fn_cache_size: int, n_distinct_plans: int,
                          report: AuditReport,
                          where: str = "step_cache") -> None:
    """Optional live check: stepping N same-signature plans through one
    jitted step must keep its trace cache at 1 entry."""
    report.ran(PASS)
    if fn_cache_size > 1:
        report.add(PASS, where,
                   f"compiled step retraced: {fn_cache_size} traces for "
                   f"{n_distinct_plans} same-signature plan(s)",
                   details={"cache_size": fn_cache_size})
