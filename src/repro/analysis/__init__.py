"""Static-analysis passes over the train step's jaxpr / compiled HLO.

The graph auditor proves the hot path's contracts instead of trusting
them: collectives match the ExecPlan's analytic schedule, donated
buffers really alias, no host syncs hide on the non-blocking loop, the
plan cannot widen the compiled-step cache, and every Pallas BlockSpec
tiles its operands exactly.

    from repro.analysis import run_audit
    report = run_audit()
    assert report.ok, report.summary()

CLI: ``scripts/audit.py`` / ``python benchmarks/run.py --audit``.
"""
from repro.analysis.report import AuditReport, Violation
from repro.analysis.hlo import (CollectiveRecord, CostReport, analyze,
                                extract_collectives, permute_direction)
from repro.analysis.collectives import audit_collectives, expected_schedule
from repro.analysis.donation import (audit_donation,
                                     parse_input_output_aliases)
from repro.analysis.host_sync import (audit_hlo_callbacks, audit_host_sync,
                                      audit_jaxpr_callbacks)
from repro.analysis.recompile import (audit_exec_plan, audit_plan_pair,
                                      audit_trace_constants)
from repro.analysis.pallas_audit import (audit_kernels, capture_pallas_calls,
                                         check_record)
from repro.analysis.lint_rules import audit_conventions
from repro.analysis.driver import (DEFAULT_STRATEGIES, STRATEGY_MESHES,
                                   audit_strategy, run_audit)

__all__ = [
    "AuditReport", "Violation",
    "CollectiveRecord", "CostReport", "analyze", "extract_collectives",
    "permute_direction",
    "audit_collectives", "expected_schedule",
    "audit_donation", "parse_input_output_aliases",
    "audit_hlo_callbacks", "audit_host_sync", "audit_jaxpr_callbacks",
    "audit_exec_plan", "audit_plan_pair", "audit_trace_constants",
    "audit_kernels", "capture_pallas_calls", "check_record",
    "audit_conventions",
    "DEFAULT_STRATEGIES", "STRATEGY_MESHES", "audit_strategy", "run_audit",
]
