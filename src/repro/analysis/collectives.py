"""Collective-schema audit: traced HLO schedule vs the ExecPlan's analytic.

Generalizes the hand-pinned assertions of ``tests/test_collectives.py`` /
``tests/test_hierarchy.py`` to ANY (strategy, codec, mesh, segments,
ring/bidir/hier) combination: the compiled step's collectives are
extracted with :func:`repro.analysis.hlo.extract_collectives` and diffed
against what :func:`repro.core.planexec.exec_wire_bytes` /
``exec_intra_bytes`` priced for the same :class:`ExecPlan`.

Invariants checked (all per device, the paper's accounting):
  * slow-tier traced bytes == analytic, up to the FULL-rung psum
    promotion slack (XLA promotes a bf16 all-reduce to f32 on CPU —
    exactly one extra copy of the FULL portion, since the analytic
    convention 2(P-1)/P * 2n already equals the bf16 wire volume);
  * fast-tier (intra-cluster) traced bytes == analytic, same slack rule
    for INTRA_FULL rungs;
  * ppermute count == sum over ringing rungs of K * (ring_width - 1);
  * every ppermute is a unit-stride ring hop (fwd/bwd half-rings only);
  * no sync-sized collective leaks onto a non-fleet mesh axis tuple that
    includes the pod axis unexpectedly.

Sub-threshold all-reduces (metric pmeans of scalar loss/gnorm/divergence)
are excluded: they are host telemetry, not the sync schedule.
"""
from __future__ import annotations

from typing import Iterator, Sequence, Tuple

from repro.core import planexec
from repro.core.compression import Level

from repro.analysis.hlo import CollectiveRecord, extract_collectives
from repro.analysis.report import AuditReport

PASS = "collective_schema"

# all-reduces below this payload are metric pmeans (f32 scalars), not sync
# traffic: the smallest real sync all-reduce is a 1-block FULL rung
# (1024 entries * 2B bf16 = 2 KiB).
METRIC_BYTES = 512.0


def _rungs(ep: planexec.ExecPlan
           ) -> Iterator[Tuple[Level, int, int, int]]:
    """Yield (level, sig_blocks, ring_chunks, hier_mode) per executed
    (segment, rung) piece — segmented plans execute seg_sig, not sig."""
    if ep.segmented:
        for ssig, sch, shier in zip(ep.seg_sig, ep.seg_chunks, ep.seg_hier):
            for r, s in enumerate(ssig):
                k = sch[r] if r < len(sch) else 0
                h = shier[r] if r < len(shier) else 0
                yield ep.levels[r], s, k, h
    else:
        for r, s in enumerate(ep.sig):
            k = ep.chunks[r] if r < len(ep.chunks) else 0
            h = ep.hier[r] if r < len(ep.hier) else 0
            yield ep.levels[r], s, k, h


def expected_schedule(ep: planexec.ExecPlan, n_pods: int,
                      n_edge: int = 1) -> dict:
    """The analytic schedule the compiled step must realise."""
    n_edge = max(int(n_edge), 1)
    n_cross = max(n_pods // n_edge, 1)
    permutes = 0
    ring_widths = set()
    full_slack = 0.0
    intra_full_slack = 0.0
    for level, s, k, h in _rungs(ep):
        if not s:
            continue
        ring_p = n_cross if h else n_pods
        if k:
            permutes += k * (ring_p - 1)
            ring_widths.add(ring_p)
        if level.is_full:
            full_slack += float(level.wire_bytes(s * ep.block, ring_p,
                                                 ep.block))
        if h == planexec.INTRA_FULL:
            from repro.codecs import build_codec
            intra_full_slack += float(build_codec("full").wire_bytes(
                s * ep.block, n_edge, ep.block))
    return {
        "slow_bytes": float(planexec.exec_wire_bytes(ep, n_pods, n_cross)),
        "intra_bytes": float(planexec.exec_intra_bytes(ep, n_edge)),
        "full_slack": full_slack,
        "intra_full_slack": intra_full_slack,
        "permutes": permutes,
        "ring_widths": sorted(ring_widths),
        "bidir": bool(ep.bidir),
        "n_pods": int(n_pods),
        "n_edge": int(n_edge),
        "n_cross": int(n_cross),
    }


def _is_metric(rec: CollectiveRecord) -> bool:
    return (rec.opcode == "all-reduce"
            and rec.payload_bytes < METRIC_BYTES)


def audit_collectives(hlo_text: str, ep: planexec.ExecPlan,
                      mesh_shape: Sequence[int],
                      axis_names: Sequence[str], n_pods: int,
                      n_edge: int, report: AuditReport,
                      where: str = "step") -> dict:
    """Diff the compiled step's collectives against ``ep``'s analytic
    schedule; append violations to ``report``.  Returns the traced
    summary (recorded into ``report.info`` by the driver)."""
    report.ran(PASS)
    want = expected_schedule(ep, n_pods, n_edge)
    records = extract_collectives(hlo_text, mesh_shape, axis_names)
    sync = [r for r in records if not _is_metric(r)]

    # tier classification: the slow tier is anything crossing the pod
    # axis — "pod" alone (cross-cluster ring / flat pod fleet) or the
    # combined "pod+edge" fleet gather of flat rungs on a hier mesh; the
    # fast tier is the intra-cluster "edge" exchange.
    slow = [r for r in sync if "pod" in r.axis.split("+")]
    fast = [r for r in sync if r.axis == "edge"]
    # pure data/model-axis collectives are legitimate auto-SPMD compute
    # (tensor-parallel psums); but the pod axis is shard_map-manual, so a
    # collective mixing it with a NON-fleet axis was never scheduled.
    mixed = [r for r in slow
             if set(r.axis.split("+")) - {"pod", "edge"}]

    traced_slow = sum(r.wire_bytes * r.trip_mult for r in slow)
    traced_fast = sum(r.wire_bytes * r.trip_mult for r in fast)

    def _within(traced: float, analytic: float, slack: float) -> bool:
        return analytic - 0.5 <= traced <= analytic + slack + 0.5

    if not _within(traced_slow, want["slow_bytes"], want["full_slack"]):
        report.add(PASS, where,
                   "slow-tier traced wire bytes diverge from the "
                   "ExecPlan analytic schedule",
                   details={"traced": traced_slow,
                            "analytic": want["slow_bytes"],
                            "full_promotion_slack": want["full_slack"]})
    if not _within(traced_fast, want["intra_bytes"],
                   want["intra_full_slack"]):
        report.add(PASS, where,
                   "fast-tier traced wire bytes diverge from the "
                   "ExecPlan analytic schedule",
                   details={"traced": traced_fast,
                            "analytic": want["intra_bytes"],
                            "full_promotion_slack":
                                want["intra_full_slack"]})

    permutes = [r for r in slow if r.opcode == "collective-permute"]
    n_permutes = int(round(sum(r.trip_mult for r in permutes)))
    if n_permutes != want["permutes"]:
        report.add(PASS, where,
                   "ppermute count diverges from the ring schedule "
                   "K * (P - 1) per ringing rung",
                   details={"traced": n_permutes,
                            "expected": want["permutes"],
                            "ring_widths": want["ring_widths"]})

    bad_dir = [r for r in permutes if r.direction == "other"]
    for r in bad_dir:
        report.add(PASS, where,
                   "collective-permute is not a unit-stride ring hop",
                   details={"source_target_pairs": r.source_target_pairs,
                            "axis": r.axis})
    directions = {r.direction for r in permutes} - {"other"}
    expect_both = (want["bidir"] and want["permutes"] > 0
                   and all(w >= 3 for w in want["ring_widths"]))
    if expect_both and directions == {"fwd"}:
        report.add(PASS, where,
                   "bidirectional ring requested but only forward-"
                   "half-ring ppermutes were traced", severity="warning",
                   details={"directions": sorted(directions)})

    for r in mixed:
        report.add(PASS, where,
                   f"sync-sized collective mixes the pod axis with a "
                   f"non-fleet axis '{r.axis}'",
                   details={"opcode": r.opcode, "axis": r.axis,
                            "wire_bytes": r.wire_bytes})

    traced = {
        "slow_bytes": traced_slow,
        "fast_bytes": traced_fast,
        "permutes": n_permutes,
        "directions": sorted(directions),
        "n_sync_collectives": len(sync),
        "n_metric_collectives": len(records) - len(sync),
    }
    return {"expected": want, "traced": traced}
