"""Host-sync lint: no implicit device->host blocking in the hot path.

The control loop's throughput contract (ROADMAP: non-blocking host loop)
is that the steady-state step path never blocks on device data — metric
fetches are lagged, replan polls are guarded by ``is_ready()``, and the
only blocking fetches are the documented ones (startup, elastic
transitions, the opt-in ``blocking_replans`` mode).

This pass parses the loop class's source (AST), builds the ``self.*``
call graph reachable from the body of the entry method's step loop, and
flags blocking patterns — ``.item()``, ``jax.device_get``,
``np.asarray``/``np.array`` on device values, ``block_until_ready`` —
unless the enclosing method is (a) on the documented allowlist, (b) the
call sits under an ``if ...blocking...`` opt-in branch, or (c) the method
guards itself with a ``_device_ready`` readiness probe.

A companion check walks a traced jaxpr for host-callback primitives
(``pure_callback`` / ``io_callback`` / debug prints) that would stall the
device inside the compiled step itself.
"""
from __future__ import annotations

import ast
import inspect
import textwrap
from typing import Dict, Iterable, List, Optional, Set

from repro.analysis.report import AuditReport

PASS = "host_sync"

# methods allowed to block, with the documented reason (the audit report
# carries the reason so the exemption stays reviewable)
DEFAULT_ALLOWLIST: Dict[str, str] = {
    "_flush_metrics":
        "lagged fetch: reads metrics from >= 1 step ago, already on host",
    "adapt_interval":
        "lagged divergence fetch; the blocking branch is the opt-in "
        "blocking_replans mode",
    "refresh_plan":
        "host-path strategies fetch importance on the replan cadence, "
        "off the step dispatch path",
    "restore_or_init": "startup only, before the step loop",
    "_transfer_state":
        "elastic membership transition: a full-fleet barrier by design",
}

_BLOCKING_ATTRS = {"device_get", "block_until_ready", "asarray", "array"}
_GUARD_NAME = "_device_ready"


def _attr_chain(node: ast.AST) -> str:
    """'jax.device_get' for Attribute(Name('jax'), 'device_get') etc."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
    return ".".join(reversed(parts))


def _is_blocking_call(call: ast.Call) -> Optional[str]:
    """The blocking pattern this call matches, or None."""
    fn = call.func
    if isinstance(fn, ast.Attribute):
        chain = _attr_chain(fn)
        if fn.attr == "item" and not call.args:
            return ".item()"
        if fn.attr in _BLOCKING_ATTRS:
            root = chain.split(".")[0]
            # np.asarray / np.array / numpy.array: a device-array operand
            # forces a synchronous transfer; jnp.asarray stays on device
            if fn.attr in ("asarray", "array") and root not in ("np",
                                                                "numpy"):
                return None
            return chain
    return None


class _MethodScan(ast.NodeVisitor):
    """Per-method facts: self.* calls, blocking calls (+ their If
    ancestors), and whether the method consults the readiness guard."""

    def __init__(self) -> None:
        self.self_calls: Set[str] = set()
        self.blocking: List[tuple] = []   # (pattern, lineno, if_tests)
        self.guarded = False
        self._if_stack: List[str] = []

    def visit_If(self, node: ast.If) -> None:
        try:
            test = ast.unparse(node.test)
        except Exception:
            test = ""
        self._if_stack.append(test)
        self.generic_visit(node)
        self._if_stack.pop()

    def visit_Call(self, node: ast.Call) -> None:
        fn = node.func
        if isinstance(fn, ast.Attribute) and \
                isinstance(fn.value, ast.Name) and fn.value.id == "self":
            self.self_calls.add(fn.attr)
        if isinstance(fn, ast.Name) and fn.id == _GUARD_NAME:
            self.guarded = True
        if isinstance(fn, ast.Attribute) and fn.attr == _GUARD_NAME:
            self.guarded = True
        pat = _is_blocking_call(node)
        if pat is not None:
            self.blocking.append((pat, node.lineno,
                                  list(self._if_stack)))
        self.generic_visit(node)


def _scan(nodes: Iterable[ast.AST]) -> _MethodScan:
    scan = _MethodScan()
    for n in nodes:
        scan.visit(n)
    return scan


def _class_methods(tree: ast.Module) -> Dict[str, ast.FunctionDef]:
    methods: Dict[str, ast.FunctionDef] = {}
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            methods.setdefault(node.name, node)
    return methods


def audit_host_sync(source, report: AuditReport, entry: str = "run_steps",
                    allowlist: Optional[Dict[str, str]] = None,
                    where: str = "TrainLoop") -> dict:
    """Lint the hot path of a loop class for blocking host syncs.

    ``source``: a class object or raw Python source.  The hot path is the
    body of the for/while loops of ``entry`` plus every ``self.*`` method
    transitively reachable from there.
    """
    report.ran(PASS)
    if not isinstance(source, str):
        source = inspect.getsource(source)
    allowlist = DEFAULT_ALLOWLIST if allowlist is None else allowlist
    tree = ast.parse(textwrap.dedent(source))
    methods = _class_methods(tree)
    entry_fn = methods.get(entry)
    info = {"entry": entry, "n_methods": len(methods), "checked": []}
    if entry_fn is None:
        report.add(PASS, where, f"entry method '{entry}' not found",
                   severity="warning")
        return info

    # the hot path starts INSIDE the entry's step loop: pre-loop code
    # (checkpoint restore, the initial step-counter fetch) may block
    loops = [n for n in ast.walk(entry_fn)
             if isinstance(n, (ast.For, ast.While))]
    seed = _scan(loops)
    frontier = sorted(seed.self_calls)
    reached: Set[str] = set()
    while frontier:
        name = frontier.pop()
        if name in reached or name not in methods:
            continue
        reached.add(name)
        sub = _scan([methods[name]])
        frontier.extend(sorted(sub.self_calls - reached))

    def _check(name: str, scan: _MethodScan, loop_body: bool) -> None:
        info["checked"].append(name)
        for pat, lineno, if_tests in scan.blocking:
            if pat == ".item()":
                report.add(PASS, f"{where}.{name}",
                           f".item() forces a device sync on the hot "
                           f"path (line {lineno})",
                           details={"pattern": pat, "lineno": lineno})
                continue
            if name in allowlist:
                continue
            if any("blocking" in t for t in if_tests):
                continue        # opt-in blocking branch (blocking_replans)
            if scan.guarded:
                continue        # polls readiness before fetching
            report.add(PASS, f"{where}.{name}",
                       f"blocking host sync '{pat}' reachable from the "
                       f"non-blocking hot path (line {lineno})",
                       details={"pattern": pat, "lineno": lineno,
                                "in_loop_body": loop_body})

    _check(f"{entry}:loop", seed, True)
    for name in sorted(reached):
        _check(name, _scan([methods[name]]), False)
    info["allowlisted"] = sorted(set(reached) & set(allowlist))
    return info


# ---------------------------------------------------------------------------
# traced-graph side: callbacks inside the compiled step
# ---------------------------------------------------------------------------

_CALLBACK_MARKERS = ("callback", "infeed", "outfeed")


def _iter_eqns(jaxpr):
    for eqn in jaxpr.eqns:
        yield eqn
        for v in eqn.params.values():
            sub = []
            if hasattr(v, "eqns"):
                sub = [v]
            elif hasattr(v, "jaxpr"):
                sub = [v.jaxpr]
            elif isinstance(v, (list, tuple)):
                sub = [x.jaxpr if hasattr(x, "jaxpr") else x
                       for x in v if hasattr(x, "eqns")
                       or hasattr(x, "jaxpr")]
            for s in sub:
                if hasattr(s, "eqns"):
                    yield from _iter_eqns(s)


def audit_jaxpr_callbacks(jaxpr, report: AuditReport,
                          where: str = "step") -> int:
    """Flag host-callback primitives inside a traced step jaxpr."""
    report.ran(PASS)
    closed = getattr(jaxpr, "jaxpr", jaxpr)
    n = 0
    for eqn in _iter_eqns(closed):
        prim = eqn.primitive.name
        if any(m in prim for m in _CALLBACK_MARKERS):
            n += 1
            report.add(PASS, where,
                       f"host-callback primitive '{prim}' inside the "
                       f"compiled step",
                       details={"primitive": prim})
    return n


def audit_hlo_callbacks(hlo_text: str, report: AuditReport,
                        where: str = "step") -> int:
    """HLO fallback for :func:`audit_jaxpr_callbacks`: host callbacks
    lower to custom-calls with a callback target."""
    report.ran(PASS)
    n = 0
    for line in hlo_text.splitlines():
        if "custom-call" in line and any(
                m in line for m in _CALLBACK_MARKERS):
            n += 1
            report.add(PASS, where,
                       "host-callback custom-call inside the compiled "
                       "step", details={"hlo": line.strip()[:200]})
    return n
