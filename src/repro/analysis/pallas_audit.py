"""Pallas kernel audit: BlockSpec tiling vs declared operand shapes.

Every registered kernel carries implicit contracts the Mosaic compiler
only partially enforces (and the interpreter not at all): each BlockSpec
tile must divide its operand exactly per dimension, and the index map
must keep every block inside the array for every grid point — an
off-by-one index map reads out of bounds on hardware while silently
clamping in interpret mode, which is exactly the class of bug a CPU CI
cannot catch dynamically.

The audit intercepts ``pl.pallas_call`` (no kernel body ever runs),
records (grid, specs, operand shapes) for each call, and statically
checks tiling and index-map bounds.  ``audit_kernels`` drives every
public kernel entry point in ``repro.kernels`` through the interceptor
on representative shapes.

Scalar-prefetch index maps (the producer-fused gather path) are
evaluated with a zero ref: the data-dependent ``perm[i]`` block index is
checked at its lower bound only — the runtime range contract for perms
(indices < NB+1) is pinned by the kernel tests, not this pass.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.analysis.report import AuditReport

PASS = "pallas_blockspec"

MAX_GRID_POINTS = 4096      # index-map evaluation cap per call


@dataclasses.dataclass
class PallasCallRecord:
    kernel_name: str
    grid: Tuple[int, ...]
    in_specs: List[Any]
    out_specs: List[Any]
    in_shapes: List[Tuple[int, ...]]
    out_shapes: List[Tuple[int, ...]]
    num_scalar_prefetch: int = 0


def _as_list(x) -> list:
    if x is None:
        return []
    return list(x) if isinstance(x, (list, tuple)) else [x]


class _ZeroRef:
    """Stands in for the scalar-prefetch ref when evaluating index maps
    statically: every lookup returns block index 0."""

    def __getitem__(self, _):
        return 0


@contextlib.contextmanager
def capture_pallas_calls():
    """Intercept ``pl.pallas_call``: record call geometry, return zeros
    of ``out_shape`` instead of executing.  Patch the module attribute —
    kernel modules resolve ``pl.pallas_call`` at call time."""
    records: List[PallasCallRecord] = []
    orig = pl.pallas_call

    def fake_pallas_call(kernel, *args, out_shape=None, grid=None,
                         in_specs=None, out_specs=None, grid_spec=None,
                         **kw):
        nsp = 0
        if grid_spec is not None:
            grid = getattr(grid_spec, "grid", grid)
            in_specs = getattr(grid_spec, "in_specs", in_specs)
            out_specs = getattr(grid_spec, "out_specs", out_specs)
            nsp = int(getattr(grid_spec, "num_scalar_prefetch", 0) or 0)

        def run(*operands):
            outs = _as_list(out_shape)
            records.append(PallasCallRecord(
                kernel_name=getattr(kernel, "__name__", repr(kernel)),
                grid=tuple(int(g) for g in _as_list(grid)),
                in_specs=_as_list(in_specs),
                out_specs=_as_list(out_specs),
                in_shapes=[tuple(x.shape) for x in operands[nsp:]],
                out_shapes=[tuple(o.shape) for o in outs],
                num_scalar_prefetch=nsp))
            zeros = [jnp.zeros(o.shape, o.dtype) for o in outs]
            if isinstance(out_shape, (list, tuple)):
                return type(out_shape)(zeros)
            return zeros[0]

        return run

    pl.pallas_call = fake_pallas_call
    try:
        yield records
    finally:
        pl.pallas_call = orig


def _spec_geometry(spec) -> Tuple[Optional[tuple], Optional[Callable]]:
    block = getattr(spec, "block_shape", None)
    index_map = getattr(spec, "index_map", None)
    if callable(block):         # defensively handle a swapped BlockSpec
        block, index_map = index_map, block
    return (tuple(block) if block is not None else None), index_map


def _grid_points(grid: Tuple[int, ...]):
    total = 1
    for g in grid:
        total *= max(int(g), 1)
    if total <= MAX_GRID_POINTS:
        idx = np.arange(total)
    else:                       # sample ends + stride (bounds live there)
        idx = np.unique(np.concatenate([
            np.arange(64), np.arange(total - 64, total),
            np.arange(0, total, max(total // MAX_GRID_POINTS, 1))]))
    for flat in idx.tolist():
        if not grid:
            yield ()
            continue
        yield tuple(int(c) for c in np.unravel_index(flat, grid))


def check_record(rec: PallasCallRecord, report: AuditReport,
                 where: Optional[str] = None) -> None:
    """Tile divisibility + index-map bounds for one captured call."""
    where = where or rec.kernel_name
    pairs = (list(zip(rec.in_specs, rec.in_shapes, ["in"] * 99))
             + list(zip(rec.out_specs, rec.out_shapes, ["out"] * 99)))
    for spec, shape, kind in pairs:
        block, index_map = _spec_geometry(spec)
        if block is None:       # whole-array spec: nothing to tile-check
            continue
        if len(block) != len(shape):
            report.add(PASS, where,
                       f"{kind} BlockSpec rank {len(block)} != operand "
                       f"rank {len(shape)}",
                       details={"block": list(block),
                                "shape": list(shape)})
            continue
        bad_dims = [d for d, (b, s) in enumerate(zip(block, shape))
                    if b is not None and int(s) % int(b) != 0]
        if bad_dims:
            report.add(PASS, where,
                       f"{kind} block {tuple(block)} does not divide "
                       f"operand shape {tuple(shape)}",
                       details={"block": list(block),
                                "shape": list(shape),
                                "bad_dims": bad_dims})
            continue
        if index_map is None:
            continue
        nblocks = [int(s) // int(b) for b, s in zip(block, shape)]
        extra = ((_ZeroRef(),) if rec.num_scalar_prefetch else ())
        for point in _grid_points(rec.grid):
            try:
                out = index_map(*point, *extra)
            except Exception as e:
                report.add(PASS, where,
                           f"index map raised at grid point {point}: "
                           f"{type(e).__name__}: {e}")
                break
            out = out if isinstance(out, tuple) else (out,)
            if len(out) != len(block):
                report.add(PASS, where,
                           f"index map returns {len(out)} block indices "
                           f"for a rank-{len(block)} block")
                break
            idxs = []
            for i in out:       # tracers/ZeroRef lookups stay unchecked
                try:
                    idxs.append(int(i))
                except Exception:
                    idxs.append(None)
            oob = [d for d, (i, n) in enumerate(zip(idxs, nblocks))
                   if i is not None and not 0 <= i < max(n, 1)]
            if oob:
                report.add(PASS, where,
                           f"index map sends grid point {tuple(int(p) for p in point)} "
                           f"out of bounds: block index {tuple(out)} vs "
                           f"{nblocks} blocks",
                           details={"grid_point": [int(p) for p in point],
                                    "block_index": [i for i in idxs
                                                    if i is not None],
                                    "n_blocks": nblocks})
                break


def audit_records(records: Sequence[PallasCallRecord],
                  report: AuditReport) -> None:
    report.ran(PASS)
    for rec in records:
        check_record(rec, report)


# ---------------------------------------------------------------------------
# registered-kernel sweep
# ---------------------------------------------------------------------------


def _kernel_cases() -> Dict[str, Callable[[], None]]:
    """One callable per public kernel entry point, on representative
    shapes.  Each calls the RAW function (``__wrapped__`` under the jit
    decorator) so the interceptor sees the eager ``pl.pallas_call``."""
    from repro.kernels import decode, quantize, sign, topk_compress

    R, L = 4 * decode.ROWS, decode.LANES
    f32, i32 = jnp.float32, jnp.int32
    g = jnp.zeros((R, L), f32)
    e = jnp.zeros((R, L), f32)
    s = jnp.zeros((R, 1), f32)
    w = jnp.zeros((1, 1), f32)
    q8 = jnp.zeros((R, L), jnp.int8)
    p4 = jnp.zeros((R, L // 2), jnp.uint8)
    p1 = jnp.zeros((R, L // 8), jnp.uint8)
    acc_i = jnp.zeros((R, L), i32)
    s_i = jnp.zeros((R, 1), i32)
    k = 103
    qk = jnp.zeros((R, k), f32)
    ik = jnp.zeros((R, k), i32)
    nb = 11
    fb = jnp.zeros((nb + 1, L), f32)
    perm = jnp.zeros((8,), i32)

    def raw(fn):
        return getattr(fn, "__wrapped__", fn)

    return {
        "quantize_int8_fused":
            lambda: raw(quantize.quantize_int8_fused)(g, interpret=True),
        "ef_int4_fused":
            lambda: raw(quantize.ef_int4_fused)(g, e, gamma=1.0,
                                                interpret=True),
        "dequantize_int8":
            lambda: raw(quantize.dequantize_int8)(q8, s, interpret=True),
        "quantize_int8_gather":
            lambda: raw(quantize.quantize_int8_gather)(
                fb, fb, perm, gamma=1.0, rows=1, interpret=True),
        "quantize_int8_gather_rows8":
            lambda: raw(quantize.quantize_int8_gather)(
                fb, fb, perm, gamma=1.0, rows=8, interpret=True),
        "ef_int4_gather":
            lambda: raw(quantize.ef_int4_gather)(
                fb, fb, perm, gamma=1.0, rows=1, interpret=True),
        "ef_sign_fused":
            lambda: raw(sign.ef_sign_fused)(g, e, gamma=1.0,
                                            interpret=True),
        "ef_sign_gather":
            lambda: raw(sign.ef_sign_gather)(
                fb, fb, perm, gamma=1.0, rows=1, interpret=True),
        "ef_topk_select":
            lambda: raw(topk_compress.ef_topk_select)(
                g, e, gamma=1.0, k=k, interpret=True),
        "ef_topk_gather":
            lambda: raw(topk_compress.ef_topk_gather)(
                fb, fb, perm, gamma=1.0, k=k, rows=1, interpret=True),
        "dequant_accum_int8_fused":
            lambda: raw(decode.dequant_accum_int8_fused)(
                g, q8, s, w, interpret=True),
        "dequant_accum_int4_fused":
            lambda: raw(decode.dequant_accum_int4_fused)(
                g, p4, s, w, interpret=True),
        "sign_vote_accum_fused":
            lambda: raw(decode.sign_vote_accum_fused)(
                g, s, p1, s, w, interpret=True),
        "topk_scatter_accum_fused":
            lambda: raw(decode.topk_scatter_accum_fused)(
                g, qk, ik, s, w, interpret=True),
        "dequant_accum_int8_fp_fused":
            lambda: raw(decode.dequant_accum_int8_fp_fused)(
                acc_i, q8, s, w, bits=16, interpret=True),
        "dequant_accum_int4_fp_fused":
            lambda: raw(decode.dequant_accum_int4_fp_fused)(
                acc_i, p4, s, w, bits=16, interpret=True),
        "sign_vote_accum_fp_fused":
            lambda: raw(decode.sign_vote_accum_fp_fused)(
                acc_i, s_i, p1, s, w, bits=16, interpret=True),
    }


def audit_kernels(report: AuditReport) -> dict:
    """Capture + check every registered kernel entry point."""
    report.ran(PASS)
    cases = _kernel_cases()
    checked, failed = [], []
    for name, case in cases.items():
        with capture_pallas_calls() as records:
            try:
                case()
            except Exception as e:
                failed.append(name)
                report.add(PASS, name,
                           f"kernel entry point failed under capture: "
                           f"{type(e).__name__}: {e}")
                continue
        if not records:
            report.add(PASS, name,
                       "no pallas_call captured — entry point bypassed "
                       "the kernel path", severity="warning")
            continue
        for rec in records:
            check_record(rec, report, where=name)
        checked.append(name)
    return {"kernels_checked": checked, "kernels_failed": failed}
