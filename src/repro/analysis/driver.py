"""Audit driver: lower the real train step per strategy, run every pass.

For each shipped strategy, the driver builds the smoke trainer on its
production-shaped simulated mesh, lowers + compiles the representative
step exactly the way :mod:`repro.launch.dryrun` and ``warm_compile`` do,
and runs the collective-schema, donation, host-sync (HLO side) and
recompile passes against the compiled module.  The source-level passes
(TrainLoop host-sync lint, Pallas BlockSpec sweep, AST convention lint)
run once, globally.

Entry point: :func:`run_audit` -> :class:`AuditReport` (serialized to
``AUDIT.json`` by ``scripts/audit.py`` / ``benchmarks/run.py --audit``).

Requires >= 8 simulated devices — set
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` before jax
imports (the CLIs do this for you).
"""
from __future__ import annotations

import os
from typing import Dict, Optional, Sequence, Tuple

from repro.analysis.report import AuditReport

# (mesh shape, axis names) per shipped strategy: the smallest meshes
# exercising every schedule feature (flat fleet ring + hier two-tier)
STRATEGY_MESHES: Dict[str, Tuple[Tuple[int, ...], Tuple[str, ...]]] = {
    "fullsync": ((2, 2, 2), ("pod", "data", "model")),
    "acesync": ((2, 2, 2), ("pod", "data", "model")),
    "acesync_hier": ((2, 2, 2), ("pod", "edge", "data")),
}

DEFAULT_STRATEGIES = tuple(STRATEGY_MESHES)

AUDIT_ARCH = "paper-350m"
AUDIT_SEQ_LEN = 64
AUDIT_BATCH = 4


def _require_devices(n: int) -> None:
    import jax
    have = len(jax.devices())
    if have < n:
        raise RuntimeError(
            f"audit needs {n} simulated devices, found {have}: set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={n} "
            f"before any jax import (scripts/audit.py does this)")


def _leaf_path(path) -> str:
    import jax
    return jax.tree_util.keystr(path)


def _build_step(strategy: str):
    """Lower + compile the representative train step for ``strategy`` on
    its audit mesh; returns (compiled_text, ep, trainer, mesh)."""
    import jax
    import numpy as np

    from repro.configs import SMOKE_ARCHS
    from repro.configs.base import RunConfig, ShapeConfig
    from repro.core.trainer import Trainer
    from repro.launch.mesh import make_mesh
    from repro.models.registry import build_model

    shape_dims, axis_names = STRATEGY_MESHES[strategy]
    _require_devices(int(np.prod(shape_dims)))
    mesh = make_mesh(shape_dims, axis_names)
    cfg = SMOKE_ARCHS[AUDIT_ARCH]
    shape = ShapeConfig("audit", AUDIT_SEQ_LEN, AUDIT_BATCH, "train")
    run = RunConfig(model=cfg, shape=shape)
    model = build_model(cfg, run)
    trainer = Trainer(model, run, mesh=mesh, strategy=strategy)

    plan = trainer.default_plan(bandwidth_mbps=50.0)
    ep = trainer.exec_plan(plan)
    kind = trainer.strategy.representative_kind
    trainer.seed_arg_specs(kind, trainer.state_specs(),
                           model.input_specs(shape))
    fn = trainer.jit_step(ep, kind)
    state_spec, batch_spec = trainer._arg_specs[kind]
    compiled = fn.lower(state_spec, batch_spec,
                        trainer.plan_arg_specs(ep)).compile()
    return compiled.as_text(), ep, trainer, mesh, state_spec


def _donated_leaves(state_spec) -> list:
    """(path, global nbytes) per donated state leaf, in jit flatten
    order — donated arg 0's leaves are entry parameters 0..N-1."""
    import jax
    import numpy as np
    leaves = jax.tree_util.tree_flatten_with_path(state_spec)[0]
    out = []
    for path, leaf in leaves:
        nbytes = int(np.prod(leaf.shape, dtype=np.int64)
                     * np.dtype(leaf.dtype).itemsize) if leaf.shape else \
            int(np.dtype(leaf.dtype).itemsize)
        out.append((_leaf_path(path), nbytes))
    return out


def audit_strategy(strategy: str, report: AuditReport) -> dict:
    """Compile one strategy's step and run the compiled-module passes."""
    from repro.analysis import collectives, donation, host_sync, recompile

    hlo_text, ep, trainer, mesh, state_spec = _build_step(strategy)
    mesh_shape = tuple(mesh.shape.values())
    axis_names = tuple(mesh.axis_names)
    n_pods = trainer.n_pods
    n_edge = int(mesh.shape.get("edge", 1))
    where = f"step[{strategy}]"

    info: dict = {"strategy": strategy,
                  "mesh": dict(zip(axis_names, mesh_shape)),
                  "n_pods": n_pods, "n_edge": n_edge}
    info["collectives"] = collectives.audit_collectives(
        hlo_text, ep, mesh_shape, axis_names, n_pods, n_edge, report,
        where=where)
    info["donation"] = donation.audit_donation(
        hlo_text, _donated_leaves(state_spec), report, where=where)
    host_sync.audit_hlo_callbacks(hlo_text, report, where=where)
    info["recompile"] = recompile.audit_exec_plan(
        ep, report, where=f"exec_plan[{strategy}]")
    # a replan that only moves device data (omega) must keep the key
    recompile.audit_plan_pair(
        ep, ep.with_omega(ep.omega * 0.5), expect_same=True,
        report=report, where=f"exec_plan[{strategy}]")
    return info


def run_audit(strategies: Optional[Sequence[str]] = None,
              skip_compile: bool = False) -> AuditReport:
    """The full audit: per-strategy compiled-module passes + the global
    source-level passes.  ``skip_compile`` limits the run to the
    source/kernel passes (no devices needed) — used by fast tests."""
    report = AuditReport()
    strategies = tuple(strategies or DEFAULT_STRATEGIES)

    if not skip_compile:
        for strategy in strategies:
            try:
                report.info[strategy] = audit_strategy(strategy, report)
            except Exception as e:   # a failed lowering IS a violation
                report.add("collective_schema", f"step[{strategy}]",
                           f"failed to lower/compile the train step: "
                           f"{type(e).__name__}: {e}")

    # global source-level passes -------------------------------------
    from repro.analysis import host_sync, lint_rules, pallas_audit

    from repro.launch.train import TrainLoop
    report.info["host_sync"] = host_sync.audit_host_sync(
        TrainLoop, report, entry="run_steps", where="TrainLoop")

    report.info["pallas"] = pallas_audit.audit_kernels(report)

    import repro
    # repro is a namespace package: no __file__, walk its path instead
    src_root = os.path.abspath(next(iter(repro.__path__)))
    report.info["lint"] = lint_rules.audit_conventions(src_root, report)
    report.info["strategies"] = list(strategies)
    return report
