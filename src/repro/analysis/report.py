"""Structured violation reporting for the graph auditor.

Every auditor pass appends :class:`Violation` records to a shared
:class:`AuditReport`; the driver serialises the report to ``AUDIT.json``
and CI gates on ``report.ok``.  A pass that runs clean still registers
itself (``report.ran(pass_name)``) so the artifact distinguishes "checked
and clean" from "never ran".
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, List, Optional

SEVERITIES = ("error", "warning")


@dataclasses.dataclass
class Violation:
    """One invariant breach found by a pass."""
    pass_name: str              # which auditor pass fired
    severity: str               # "error" gates CI; "warning" is advisory
    where: str                  # strategy / function / kernel / file
    message: str                # one-line human statement of the breach
    details: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def __post_init__(self) -> None:
        assert self.severity in SEVERITIES, self.severity

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class AuditReport:
    """Accumulates violations + per-pass info across auditor passes."""
    violations: List[Violation] = dataclasses.field(default_factory=list)
    info: Dict[str, Any] = dataclasses.field(default_factory=dict)
    passes: List[str] = dataclasses.field(default_factory=list)

    def ran(self, pass_name: str) -> None:
        if pass_name not in self.passes:
            self.passes.append(pass_name)

    def add(self, pass_name: str, where: str, message: str,
            severity: str = "error",
            details: Optional[Dict[str, Any]] = None) -> Violation:
        v = Violation(pass_name, severity, where, message, details or {})
        self.violations.append(v)
        return v

    def merge(self, other: "AuditReport") -> None:
        self.violations.extend(other.violations)
        for p in other.passes:
            self.ran(p)
        self.info.update(other.info)

    def errors(self) -> List[Violation]:
        return [v for v in self.violations if v.severity == "error"]

    @property
    def ok(self) -> bool:
        """No error-severity violations (warnings do not gate)."""
        return not self.errors()

    def by_pass(self, pass_name: str) -> List[Violation]:
        return [v for v in self.violations if v.pass_name == pass_name]

    def to_dict(self) -> Dict[str, Any]:
        return {
            "ok": self.ok,
            "passes": list(self.passes),
            "n_errors": len(self.errors()),
            "n_warnings": len(self.violations) - len(self.errors()),
            "violations": [v.to_dict() for v in self.violations],
            "info": self.info,
        }

    def to_json(self, indent: int = 1) -> str:
        return json.dumps(self.to_dict(), indent=indent, default=str)

    def summary(self) -> str:
        e, w = len(self.errors()), len(self.violations) - len(self.errors())
        head = (f"audit: {len(self.passes)} passes, "
                f"{e} errors, {w} warnings")
        lines = [head]
        for v in self.violations:
            lines.append(f"  [{v.severity}] {v.pass_name} @ {v.where}: "
                         f"{v.message}")
        return "\n".join(lines)
