"""Trip-count-aware HLO parser + cost model (library home).

Relocated from ``benchmarks/hlo_cost.py`` (which remains as a compat
shim): this is a library imported by tests, the dry-run harness and the
graph auditor, so it lives in the package.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE, so any
scan-over-layers model under-reports FLOPs/bytes/collectives by the trip
count (verified empirically on this container).  This walker parses the
post-optimisation HLO text, recurses into fusions / while bodies / calls /
conditionals, multiplies while bodies by their ``known_trip_count``, and
classifies every collective by WHICH MESH AXES vary inside its replica
groups — giving per-axis wire bytes ("pod" = the paper's cloud-edge uplink).

Cost conventions (documented in EXPERIMENTS.md):
  * dot/convolution: 2 * out_elems * contraction_size FLOPs;
  * elementwise / reduce: 1 FLOP per output (resp. input) element;
  * bytes_accessed: operand + output bytes at fusion granularity (a fusion
    is one read of its inputs + one write of its outputs — the HBM-traffic
    proxy);
  * collective wire bytes per participant: all-reduce 2(G-1)/G * n,
    all-gather / reduce-scatter / all-to-all (G-1)/G * n_full,
    collective-permute n.

On top of the aggregate :class:`CostReport`, :func:`extract_collectives`
returns the flat per-op collective schedule (opcode, mesh-axis class,
bytes, ring direction) the collective-schema auditor diffs against the
:class:`~repro.core.planexec.ExecPlan` analytic schedule.
"""
from __future__ import annotations

import dataclasses
import math
import re
from collections import defaultdict
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2,
    "f32": 4, "f64": 8, "c64": 8, "c128": 16, "token": 0, "opaque": 0,
    "s4": 1, "u4": 1,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


# ---------------------------------------------------------------------------
# shape parsing
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Shape:
    dtype: str
    dims: Tuple[int, ...]

    @property
    def elems(self) -> int:
        n = 1
        for d in self.dims:
            n *= d
        return n

    @property
    def bytes(self) -> int:
        return self.elems * _DTYPE_BYTES.get(self.dtype, 4)


_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")


def parse_shapes(type_str: str) -> List[Shape]:
    """All array shapes inside a (possibly tuple) type string."""
    out = []
    for m in _SHAPE_RE.finditer(type_str):
        dt = m.group(1)
        if dt not in _DTYPE_BYTES:
            continue
        dims = tuple(int(x) for x in m.group(2).split(",") if x)
        out.append(Shape(dt, dims))
    return out


def shapes_bytes(shapes: Sequence[Shape]) -> int:
    return sum(s.bytes for s in shapes)


# ---------------------------------------------------------------------------
# HLO text parsing
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class HloOp:
    var: str
    shapes: List[Shape]
    opcode: str
    operands: List[str]
    raw: str


@dataclasses.dataclass
class HloComputation:
    name: str
    ops: List[HloOp]
    shape_of: Dict[str, List[Shape]]


_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->")
_PARAM_RE = re.compile(r"([\w.\-]+):\s*((?:\([^)]*\)|[a-z0-9]+\[[\d,]*\](?:\{[^}]*\})?))")
_VAR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*")
_OPCODE_RE = re.compile(r"\s*([\w\-]+)\(")


def _parse_op_line(line: str):
    """-> (var, type_str, opcode, rest_after_open_paren) or None.

    Handles tuple result types with nested parens and /*index=N*/ comments.
    """
    vm = _VAR_RE.match(line)
    if not vm:
        return None
    var = vm.group(1)
    i = vm.end()
    if i < len(line) and line[i] == "(":
        depth, j = 1, i + 1
        while j < len(line) and depth:
            if line[j] == "(":
                depth += 1
            elif line[j] == ")":
                depth -= 1
            j += 1
        type_str = line[i:j]
    else:
        tm = re.match(r"[a-z0-9]+\[[\d,]*\](?:\{[^}]*\})?", line[i:])
        if not tm:
            return None
        j = i + tm.end()
        type_str = line[i:j]
    om = _OPCODE_RE.match(line[j:])
    if not om:
        return None
    return var, type_str, om.group(1), line[j + om.end():]


def parse_module(text: str) -> Tuple[Dict[str, HloComputation], Optional[str]]:
    comps: Dict[str, HloComputation] = {}
    entry: Optional[str] = None
    cur: Optional[HloComputation] = None
    for line in text.splitlines():
        stripped = line.strip()
        if not stripped or stripped.startswith("//"):
            continue
        if stripped.startswith("HloModule"):
            continue
        # computation headers start at column 0 and end with "{"
        if not line.startswith(" ") and stripped.rstrip().endswith("{"):
            m = _COMP_RE.match(stripped)
            if m:
                cur = HloComputation(m.group(1), [], {})
                comps[cur.name] = cur
                if stripped.startswith("ENTRY"):
                    entry = cur.name
                # parameters: name: type pairs (header params carry no
                # nested tuples on this backend; regex pairing suffices)
                for pm in _PARAM_RE.finditer(m.group(2)):
                    cur.shape_of[pm.group(1)] = parse_shapes(pm.group(2))
                continue
        if stripped == "}":
            cur = None
            continue
        if cur is None:
            continue
        parsed = _parse_op_line(line)
        if not parsed:
            continue
        var, type_str, opcode, rest = parsed
        # operand references up to the closing paren of the operand list
        depth, i = 1, 0
        while i < len(rest) and depth > 0:
            if rest[i] == "(":
                depth += 1
            elif rest[i] == ")":
                depth -= 1
            i += 1
        operand_str = rest[:i - 1] if depth == 0 else rest
        operands = re.findall(r"%([\w.\-]+)", operand_str)
        op = HloOp(var, parse_shapes(type_str), opcode, operands, line)
        cur.ops.append(op)
        cur.shape_of[var] = op.shapes
    return comps, entry


# ---------------------------------------------------------------------------
# replica-group -> mesh-axis classification
# ---------------------------------------------------------------------------


def _parse_source_target_pairs(raw: str) -> Optional[List[List[int]]]:
    """collective-permute carries source_target_pairs, not replica_groups;
    each {src,dst} pair is classified like a 2-element group (the mesh
    axes that vary between the endpoints are the axes the transfer
    crosses — "pod" for the ring exchange's ppermutes)."""
    m = re.search(r"source_target_pairs=\{(\{[^=]*?\})\}", raw)
    if not m:
        return None
    pairs = []
    for g in re.findall(r"\{([\d,\s]*)\}", m.group(1)):
        pairs.append([int(x) for x in g.split(",") if x.strip()])
    return pairs or None


def _parse_replica_groups(raw: str) -> Optional[List[List[int]]]:
    """Handles explicit {{0,1},{2,3}} and iota [G,N]<=[dims]T(perm) forms."""
    m = re.search(r"replica_groups=\{(\{[^=]*?\})\}", raw)
    if m:
        groups = []
        for g in re.findall(r"\{([\d,\s]*)\}", m.group(1)):
            groups.append([int(x) for x in g.split(",") if x.strip()])
        return groups
    m = re.search(
        r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?",
        raw)
    if m:
        a, b = int(m.group(1)), int(m.group(2))
        dims = [int(x) for x in m.group(3).split(",")]
        iota = np.arange(int(np.prod(dims))).reshape(dims)
        if m.group(4):
            perm = [int(x) for x in m.group(4).split(",")]
            iota = iota.transpose(perm)
        return iota.reshape(a, b).tolist()
    return None


def classify_axes(groups: Optional[List[List[int]]],
                  mesh_shape: Sequence[int],
                  axis_names: Sequence[str]) -> Tuple[str, int]:
    """-> (axis-class label like "pod" / "data" / "pod+data", group size)."""
    if not groups:
        return ("unknown", 1)
    g0 = groups[0]
    if len(g0) <= 1:
        return ("none", 1)
    coords = np.array(np.unravel_index(np.array(g0), mesh_shape)).T
    varying = [axis_names[i] for i in range(len(mesh_shape))
               if len(set(coords[:, i])) > 1]
    return ("+".join(varying) if varying else "none", len(g0))


def permute_direction(pairs: Optional[List[List[int]]],
                      mesh_shape: Sequence[int]) -> str:
    """Ring direction of a collective-permute's source-target pairs.

    Along the single varying mesh axis, a hop of +1 (mod size) is "fwd"
    and -1 is "bwd" (the two half-rings of the bidirectional exchange).
    Anything else — multi-axis hops, stride > 1, mixed deltas within one
    op — is "other" and flags a schedule the cost model never priced.
    On a 2-wide axis +1 == -1; that degenerate hop reports "fwd".
    """
    if not pairs:
        return "other"
    deltas = set()
    for pair in pairs:
        if len(pair) != 2:
            return "other"
        src, dst = pair
        sc = np.unravel_index(src, mesh_shape)
        dc = np.unravel_index(dst, mesh_shape)
        varying = [i for i in range(len(mesh_shape)) if sc[i] != dc[i]]
        if len(varying) != 1:
            return "other"
        ax = varying[0]
        size = int(mesh_shape[ax])
        d = (int(dc[ax]) - int(sc[ax])) % size
        if d == 1:
            deltas.add("fwd")
        elif d == size - 1:
            deltas.add("bwd")
        else:
            return "other"
    if len(deltas) != 1:
        return "other"
    return deltas.pop()


# ---------------------------------------------------------------------------
# cost walking
# ---------------------------------------------------------------------------


_TRIP_RE = re.compile(r'known_trip_count[\\"]*:\s*\{[\\"]*n[\\"]*:[\\"]*(\d+)')
_CALL_RE = re.compile(r"(?:calls|body|condition|to_apply|branch_computations)="
                      r"\{?%?([\w.\-]+(?:,\s*%?[\w.\-]+)*)\}?")


@dataclasses.dataclass
class CostReport:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    transcendentals: float = 0.0
    collective_bytes: Dict[str, float] = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    collective_count: Dict[str, int] = dataclasses.field(
        default_factory=lambda: defaultdict(int))
    op_flops: Dict[str, float] = dataclasses.field(
        default_factory=lambda: defaultdict(float))

    def add(self, other: "CostReport", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes_accessed += other.bytes_accessed * mult
        self.transcendentals += other.transcendentals * mult
        for k, v in other.collective_bytes.items():
            self.collective_bytes[k] += v * mult
        for k, v in other.collective_count.items():
            self.collective_count[k] += int(v * mult)
        for k, v in other.op_flops.items():
            self.op_flops[k] += v * mult


_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "sign", "compare", "select", "and", "or", "xor", "not",
    "clamp", "floor", "ceil", "round-nearest-afz", "round-nearest-even",
    "convert", "bitcast-convert", "copy", "shift-left",
    "shift-right-logical", "shift-right-arithmetic", "remainder", "atan2",
    "power", "is-finite", "stochastic-convert",
}
_TRANSCENDENTAL = {"exponential", "log", "tanh", "rsqrt", "sqrt", "logistic",
                   "sine", "cosine", "expm1", "log1p", "erf", "cbrt"}
_ZERO_COST = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "optimization-barrier", "partition-id", "replica-id",
    "domain", "iota", "rng-get-and-update-state", "custom-call",
    "get-dimension-size",
}


class CostWalker:
    def __init__(self, comps: Dict[str, HloComputation],
                 mesh_shape: Sequence[int], axis_names: Sequence[str]):
        self.comps = comps
        self.mesh_shape = tuple(mesh_shape)
        self.axis_names = tuple(axis_names)
        self._cache: Dict[str, CostReport] = {}

    # -- per-op costs ----------------------------------------------------
    def _dot_flops(self, op: HloOp, comp: HloComputation) -> float:
        out_elems = sum(s.elems for s in op.shapes)
        m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.raw)
        lhs_shapes = comp.shape_of.get(op.operands[0]) if op.operands else None
        contraction = 1
        if m and lhs_shapes:
            lhs = lhs_shapes[0]
            for d in m.group(1).split(","):
                if d:
                    contraction *= lhs.dims[int(d)]
        return 2.0 * out_elems * contraction

    def _conv_flops(self, op: HloOp, comp: HloComputation) -> float:
        out_elems = sum(s.elems for s in op.shapes)
        rhs_shapes = comp.shape_of.get(op.operands[1]) \
            if len(op.operands) > 1 else None
        if not rhs_shapes:
            return 2.0 * out_elems
        kernel = rhs_shapes[0]
        fgc = 1
        m = re.search(r"feature_group_count=(\d+)", op.raw)
        if m:
            fgc = int(m.group(1))
        # kernel elems already include in/out channel dims; per output elem
        # the contraction is kernel_elems / out_channels
        m2 = re.search(r"dim_labels=\S*?->\S*", op.raw)
        out_ch = kernel.dims[-1] if kernel.dims else 1
        contraction = max(1, kernel.elems // max(out_ch, 1))
        return 2.0 * out_elems * contraction

    def _collective(self, op: HloOp, rep: CostReport, comp: HloComputation):
        rec = collective_record(op, comp, self.mesh_shape, self.axis_names)
        rep.collective_bytes[rec.axis] += rec.wire_bytes
        rep.collective_count[rec.axis] += 1

    # -- computation walk -------------------------------------------------
    def comp_cost(self, name: str) -> CostReport:
        if name in self._cache:
            return self._cache[name]
        comp = self.comps.get(name)
        rep = CostReport()
        if comp is None:
            return rep
        self._cache[name] = rep  # break cycles
        for op in comp.ops:
            self._op_cost(op, comp, rep)
        return rep

    def _op_cost(self, op: HloOp, comp: HloComputation, rep: CostReport):
        opc = op.opcode
        out_elems = sum(s.elems for s in op.shapes)
        out_bytes = shapes_bytes(op.shapes)
        in_bytes = sum(shapes_bytes(comp.shape_of.get(v, []))
                       for v in op.operands)

        if opc in _ZERO_COST:
            return
        # sliced-access ops touch only the slice, not the whole operand
        if opc in ("dynamic-slice", "slice"):
            rep.bytes_accessed += 2 * out_bytes
            return
        if opc == "dynamic-update-slice":
            upd = (shapes_bytes(comp.shape_of.get(op.operands[1], []))
                   if len(op.operands) > 1 else out_bytes)
            rep.bytes_accessed += 2 * upd
            return
        if opc == "gather":
            idx = (shapes_bytes(comp.shape_of.get(op.operands[1], []))
                   if len(op.operands) > 1 else 0)
            rep.bytes_accessed += 2 * out_bytes + idx
            return
        if opc == "scatter":
            upd = (shapes_bytes(comp.shape_of.get(op.operands[2], []))
                   if len(op.operands) > 2 else out_bytes)
            rep.bytes_accessed += 3 * upd
            return
        if opc == "fusion":
            m = _CALL_RE.search(op.raw)
            boundary = in_bytes + out_bytes
            if m:
                sub = self.comp_cost(m.group(1).split(",")[0].strip(" %"))
                # flops from inside; bytes: min(fusion boundary, internal
                # slice-aware traffic) — a fusion that only dynamic-slices a
                # big operand reads the slice, not the operand
                rep.flops += sub.flops
                rep.transcendentals += sub.transcendentals
                for k, v in sub.collective_bytes.items():
                    rep.collective_bytes[k] += v
                rep.op_flops["fusion"] += sub.flops
                rep.bytes_accessed += min(boundary,
                                          sub.bytes_accessed + out_bytes)
            else:
                rep.bytes_accessed += boundary
            return
        if opc == "while":
            m = _TRIP_RE.search(op.raw)
            trip = int(m.group(1)) if m else 1
            calls = dict(re.findall(r"(body|condition)=%?([\w.\-]+)", op.raw))
            body = self.comp_cost(calls.get("body", ""))
            cond = self.comp_cost(calls.get("condition", ""))
            rep.add(body, trip)
            rep.add(cond, trip)
            return
        if opc in ("call", "async-start", "async-done"):
            m = _CALL_RE.search(op.raw)
            if m:
                rep.add(self.comp_cost(m.group(1).split(",")[0].strip(" %")))
            return
        if opc == "conditional":
            m = re.search(r"branch_computations=\{([^}]*)\}", op.raw)
            branches = []
            if m:
                branches = [b.strip(" %") for b in m.group(1).split(",")]
            else:
                tm = re.findall(r"(?:true|false)_computation=%?([\w.\-]+)",
                                op.raw)
                branches = tm
            if branches:
                costs = [self.comp_cost(b) for b in branches]
                worst = max(costs, key=lambda c: c.flops)
                rep.add(worst)
            rep.bytes_accessed += in_bytes + out_bytes
            return
        if any(opc.startswith(c) for c in COLLECTIVES):
            if not opc.endswith("-done"):  # async pairs: count -start only
                self._collective(op, rep, comp)
            rep.bytes_accessed += in_bytes + out_bytes
            return
        # compute ops
        if opc == "dot":
            f = self._dot_flops(op, comp)
            rep.flops += f
            rep.op_flops["dot"] += f
        elif opc == "convolution":
            f = self._conv_flops(op, comp)
            rep.flops += f
            rep.op_flops["convolution"] += f
        elif opc in ("reduce", "reduce-window"):
            in_elems = sum(s.elems for v in op.operands
                           for s in comp.shape_of.get(v, []))
            rep.flops += in_elems
            rep.op_flops["reduce"] += in_elems
        elif opc in _TRANSCENDENTAL:
            rep.flops += out_elems
            rep.transcendentals += out_elems
            rep.op_flops["transcendental"] += out_elems
        elif opc in _ELEMENTWISE or opc in (
                "broadcast", "reshape", "transpose", "slice", "pad",
                "concatenate", "dynamic-slice", "dynamic-update-slice",
                "gather", "scatter", "select-and-scatter", "reverse",
                "sort", "rng", "rng-bit-generator", "map", "reduce-precision",
                "cholesky", "triangular-solve", "exponential-minus-one"):
            if opc in _ELEMENTWISE:
                rep.flops += out_elems
                rep.op_flops["elementwise"] += out_elems
            elif opc == "sort":
                in_elems = sum(s.elems for v in op.operands
                               for s in comp.shape_of.get(v, []))
                lg = math.log2(max(op.shapes[0].dims[-1], 2)) \
                    if op.shapes and op.shapes[0].dims else 1.0
                rep.flops += in_elems * lg
                rep.op_flops["sort"] += in_elems * lg
        rep.bytes_accessed += in_bytes + out_bytes


def analyze(hlo_text: str, mesh_shape: Sequence[int],
            axis_names: Sequence[str]) -> CostReport:
    comps, entry = parse_module(hlo_text)
    walker = CostWalker(comps, mesh_shape, axis_names)
    if entry is None:
        # fall back: largest computation
        entry = max(comps, key=lambda n: len(comps[n].ops)) if comps else ""
    return walker.comp_cost(entry)


# ---------------------------------------------------------------------------
# per-collective schedule extraction (the auditor's view)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class CollectiveRecord:
    """One collective op on the executed path, with loop multiplicity."""
    opcode: str                 # normalised: "-start" stripped
    axis: str                   # mesh-axis class ("pod", "edge", "pod+edge")
    group_size: int
    payload_bytes: float        # operand (reduce-like) / output (gather-like)
    wire_bytes: float           # per-participant, CostReport conventions
    trip_mult: float            # product of enclosing while trip counts
    direction: str              # collective-permute: fwd / bwd / other; else ""
    source_target_pairs: Optional[List[List[int]]]
    computation: str
    raw: str


def collective_record(op: HloOp, comp: HloComputation,
                      mesh_shape: Sequence[int],
                      axis_names: Sequence[str],
                      trip_mult: float = 1.0) -> CollectiveRecord:
    """Classify one collective op: axis, bytes, ring direction."""
    groups = _parse_replica_groups(op.raw)
    pairs = None
    if op.opcode.startswith("collective-permute"):
        pairs = _parse_source_target_pairs(op.raw)
        if groups is None:
            groups = pairs
    axis, gsize = classify_axes(groups, mesh_shape, axis_names)
    opc = op.opcode.replace("-start", "")
    operand_bytes = shapes_bytes([s for v in op.operands
                                  for s in comp.shape_of.get(v, [])])
    out_bytes = shapes_bytes(op.shapes)
    if opc == "all-reduce":
        n = float(operand_bytes or out_bytes)
        wire = 2.0 * (gsize - 1) / max(gsize, 1) * n
    elif opc in ("all-gather", "all-to-all"):
        n = float(out_bytes)
        wire = (gsize - 1) / max(gsize, 1) * n
    elif opc == "reduce-scatter":
        n = float(operand_bytes or out_bytes)
        wire = (gsize - 1) / max(gsize, 1) * n
    else:  # collective-permute
        n = float(out_bytes)
        wire = n
    direction = ""
    if opc == "collective-permute":
        direction = permute_direction(pairs, mesh_shape)
    return CollectiveRecord(
        opcode=opc, axis=axis, group_size=gsize, payload_bytes=n,
        wire_bytes=wire, trip_mult=trip_mult, direction=direction,
        source_target_pairs=pairs, computation=comp.name, raw=op.raw)


class _CollectiveCollector:
    """Walks the call graph like :class:`CostWalker` but keeps every
    collective as a separate record (the cost walker only aggregates)."""

    def __init__(self, comps: Dict[str, HloComputation],
                 mesh_shape: Sequence[int], axis_names: Sequence[str]):
        self.comps = comps
        self.mesh_shape = tuple(mesh_shape)
        self.axis_names = tuple(axis_names)
        self.records: List[CollectiveRecord] = []

    def walk(self, name: str, mult: float = 1.0,
             stack: frozenset = frozenset()):
        comp = self.comps.get(name)
        if comp is None or name in stack:
            return
        stack = stack | {name}
        for op in comp.ops:
            opc = op.opcode
            if opc == "fusion" or opc in ("call", "async-start",
                                          "async-done"):
                m = _CALL_RE.search(op.raw)
                if m:
                    self.walk(m.group(1).split(",")[0].strip(" %"),
                              mult, stack)
            elif opc == "while":
                tm = _TRIP_RE.search(op.raw)
                trip = int(tm.group(1)) if tm else 1
                calls = dict(re.findall(r"(body|condition)=%?([\w.\-]+)",
                                        op.raw))
                self.walk(calls.get("body", ""), mult * trip, stack)
                self.walk(calls.get("condition", ""), mult * trip, stack)
            elif opc == "conditional":
                m = re.search(r"branch_computations=\{([^}]*)\}", op.raw)
                branches = ([b.strip(" %") for b in m.group(1).split(",")]
                            if m else re.findall(
                                r"(?:true|false)_computation=%?([\w.\-]+)",
                                op.raw))
                for b in branches:
                    self.walk(b, mult, stack)
            elif any(opc.startswith(c) for c in COLLECTIVES):
                if not opc.endswith("-done"):  # async: count -start only
                    self.records.append(collective_record(
                        op, comp, self.mesh_shape, self.axis_names, mult))


def extract_collectives(hlo_text: str, mesh_shape: Sequence[int],
                        axis_names: Sequence[str]) -> List[CollectiveRecord]:
    """Every collective on the executed path of the entry computation,
    with while-loop trip multiplicity — the traced schedule the
    collective-schema auditor diffs against the analytic one."""
    comps, entry = parse_module(hlo_text)
    if entry is None:
        entry = max(comps, key=lambda n: len(comps[n].ops)) if comps else ""
    collector = _CollectiveCollector(comps, mesh_shape, axis_names)
    collector.walk(entry)
    return collector.records
