"""AST lint pack: repo conventions the generic linters can't encode.

Three rules, each an AST walk over ``src/repro``:

  * **no-python-rng** — ``random`` / ``np.random`` calls inside device
    code (``core``, ``codecs``, ``kernels``, ``strategies``): Python RNG
    inside a traced function is a trace constant, so every step replays
    the value drawn at trace time.  Seeded ``np.random.default_rng`` in
    host-side planning code is fine and exempted by module.
  * **unregistered-plugin** — a concrete :class:`Codec` /
    :class:`SyncStrategy` subclass (one that sets a non-empty ``name``)
    must carry its ``@register_codec`` / ``@register_strategy``
    decorator, or ``build_codec`` / ``resolve_strategy`` will not find
    it and every string-keyed config silently falls back.
  * **no-host-sync-in-device-plan** — modules on the device control
    plane (``core/acesync.py``, anything defining ``device_replan_fn``)
    must not call blocking host transfers; the whole point of the
    device replan path is that it never leaves the accelerator.
"""
from __future__ import annotations

import ast
import os
from typing import Dict, Iterable, List, Optional, Set, Tuple

from repro.analysis.report import AuditReport

PASS = "lint_rules"

# device-code packages for the RNG rule (host-side launch/, data/,
# runtime/, analysis/ may use seeded numpy RNG freely)
_DEVICE_PKGS = ("core", "codecs", "kernels", "strategies")

# host-planning modules inside device packages that legitimately draw
# from a seeded host RNG (bucket shuffling, plan search)
_RNG_EXEMPT = {"core/scheduler.py", "core/planexec.py", "core/cluster.py"}

_BASES = {"Codec": "register_codec", "SyncStrategy": "register_strategy"}

_BLOCKING = ("device_get", "block_until_ready")


def _attr_chain(node: ast.AST) -> str:
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
    return ".".join(reversed(parts))


def iter_source_files(root: str) -> Iterable[Tuple[str, str]]:
    """Yield (relpath, source) for every .py under ``root``."""
    for dirpath, _dirnames, filenames in os.walk(root):
        for fn in sorted(filenames):
            if not fn.endswith(".py"):
                continue
            full = os.path.join(dirpath, fn)
            rel = os.path.relpath(full, root).replace(os.sep, "/")
            try:
                with open(full, "r") as fh:
                    yield rel, fh.read()
            except OSError:
                continue


# ---------------------------------------------------------------------------
# rule 1: Python RNG in device code
# ---------------------------------------------------------------------------


def check_python_rng(rel: str, tree: ast.Module,
                     report: AuditReport) -> None:
    if rel.split("/")[0] not in _DEVICE_PKGS or rel in _RNG_EXEMPT:
        return
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            chain = _attr_chain(node.func)
            root = chain.split(".")[0]
            if root == "random" or chain.startswith(("np.random.",
                                                     "numpy.random.")):
                report.add(PASS, f"{rel}:{node.lineno}",
                           f"Python RNG '{chain}' in device code — a "
                           f"trace constant, not per-step randomness; "
                           f"use jax.random with a threaded key",
                           details={"call": chain, "lineno": node.lineno})


# ---------------------------------------------------------------------------
# rule 2: Codec / SyncStrategy subclasses must be registered
# ---------------------------------------------------------------------------


def _class_name_attr(cls: ast.ClassDef) -> Optional[str]:
    """The literal value of a ``name = "..."`` class attribute."""
    for stmt in cls.body:
        targets: List[ast.expr] = []
        value: Optional[ast.expr] = None
        if isinstance(stmt, ast.Assign):
            targets, value = stmt.targets, stmt.value
        elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
            targets, value = [stmt.target], stmt.value
        for t in targets:
            if isinstance(t, ast.Name) and t.id == "name":
                if isinstance(value, ast.Constant) and \
                        isinstance(value.value, str):
                    return value.value
    return None


def check_registration(rel: str, tree: ast.Module,
                       report: AuditReport) -> None:
    # transitive base tracking within the module: FedAvg(_PeriodicStrategy)
    # is still a SyncStrategy
    kind_of: Dict[str, str] = {}      # class name -> base kind
    classes = [n for n in ast.walk(tree) if isinstance(n, ast.ClassDef)]
    changed = True
    while changed:
        changed = False
        for cls in classes:
            if cls.name in kind_of:
                continue
            for base in cls.bases:
                bname = base.id if isinstance(base, ast.Name) else \
                    getattr(base, "attr", "")
                kind = _BASES.get(bname) or kind_of.get(bname)
                if kind:
                    kind_of[cls.name] = kind
                    changed = True
                    break
    for cls in classes:
        kind = kind_of.get(cls.name)
        if not kind:
            continue
        concrete_name = _class_name_attr(cls)
        if not concrete_name:
            continue                  # abstract intermediate, no registry key
        decorators = {_attr_chain(d.func) if isinstance(d, ast.Call)
                      else _attr_chain(d) for d in cls.decorator_list}
        if not any(d.split(".")[-1] == kind for d in decorators):
            report.add(PASS, f"{rel}:{cls.lineno}",
                       f"class {cls.name} (name={concrete_name!r}) is a "
                       f"registry plugin but lacks @{kind} — string "
                       f"configs will not resolve it",
                       details={"class": cls.name, "name": concrete_name,
                                "expected_decorator": kind})


# ---------------------------------------------------------------------------
# rule 3: no blocking host syncs on the device control plane
# ---------------------------------------------------------------------------


def _device_plan_functions(tree: ast.Module) -> Set[str]:
    """Functions on the device control plane: device_replan_fn itself
    plus every function it defines or calls inside the module."""
    names: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.FunctionDef) and \
                "device" in node.name and ("replan" in node.name
                                           or "plan" in node.name):
            names.add(node.name)
    return names


def check_device_plan_sync(rel: str, tree: ast.Module,
                           report: AuditReport) -> None:
    roots = _device_plan_functions(tree)
    if not roots:
        return
    fns = {n.name: n for n in ast.walk(tree)
           if isinstance(n, ast.FunctionDef)}
    # transitive closure over module-level calls from the device roots
    frontier, reach = sorted(roots), set()
    while frontier:
        name = frontier.pop()
        if name in reach or name not in fns:
            continue
        reach.add(name)
        for node in ast.walk(fns[name]):
            if isinstance(node, ast.Call):
                chain = _attr_chain(node.func)
                if chain in fns:
                    frontier.append(chain)
    for name in sorted(reach):
        for node in ast.walk(fns[name]):
            if not isinstance(node, ast.Call):
                continue
            chain = _attr_chain(node.func)
            leaf = chain.split(".")[-1]
            blocking = (leaf in _BLOCKING
                        or (leaf == "item" and not node.args
                            and isinstance(node.func, ast.Attribute))
                        or (leaf in ("asarray", "array")
                            and chain.split(".")[0] in ("np", "numpy")))
            if blocking:
                report.add(PASS, f"{rel}:{node.lineno}",
                           f"blocking host sync '{chain}' inside device "
                           f"control-plane function '{name}' — the "
                           f"device replan path must stay on device",
                           details={"function": name, "call": chain,
                                    "lineno": node.lineno})


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

_RULES = (check_python_rng, check_registration, check_device_plan_sync)


def audit_conventions(src_root: str, report: AuditReport) -> dict:
    """Run the whole lint pack over a ``src/repro`` tree."""
    report.ran(PASS)
    n_files = 0
    skipped: List[str] = []
    for rel, source in iter_source_files(src_root):
        try:
            tree = ast.parse(source)
        except SyntaxError as e:
            skipped.append(rel)
            report.add(PASS, rel, f"unparseable: {e}", severity="warning")
            continue
        n_files += 1
        for rule in _RULES:
            rule(rel, tree, report)
    return {"n_files": n_files, "skipped": skipped}
