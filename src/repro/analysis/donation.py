"""Donation/aliasing audit: donated buffers must alias, not copy.

The train step donates the state (``donate_argnums=(0,)``) so the
optimizer update happens in place — at 350M-parameter scale a silent copy
doubles the state's HBM footprint and adds a full read+write per step.
XLA records honoured donations in the executable's
``input_output_alias`` header; a donated parameter that is missing from
it was silently copied (dtype change, layout mismatch, or a consumer
that outlives the write).

The pass parses the compiled module header and checks every donated
state leaf above a size floor is aliased.  Scalar leaves (step counter,
interval state) are exempt by the floor: their copies are free and XLA
legitimately folds some of them.
"""
from __future__ import annotations

import re
from typing import Dict, List, Sequence, Set, Tuple

from repro.analysis.report import AuditReport

PASS = "donation_alias"

# leaves under this many bytes (GLOBAL, pre-sharding) are not worth an
# alias: scalars and tiny vectors the compiler may fold
MIN_ALIAS_BYTES = 4096

_ALIAS_ENTRY_RE = re.compile(
    r"\{[\d,\s]*\}:\s*\(\s*(\d+)\s*,\s*\{[\d,\s]*\}\s*"
    r"(?:,\s*(may-alias|must-alias)\s*)?\)")


def parse_input_output_aliases(hlo_text: str) -> Set[int]:
    """Parameter numbers the executable aliases to an output.

    The header lives on the ``HloModule`` line:
    ``input_output_alias={ {0}: (0, {}, may-alias), {1}: (1, {}, ...) }``
    (output tuple index -> (param number, param index, kind)).
    """
    aliased: Set[int] = set()
    for line in hlo_text.splitlines():
        if "input_output_alias=" not in line:
            continue
        start = line.index("input_output_alias={") + len("input_output_alias=")
        # brace-match the alias map (the module line carries other {...}
        # attributes after it)
        depth, j = 0, start
        while j < len(line):
            if line[j] == "{":
                depth += 1
            elif line[j] == "}":
                depth -= 1
                if depth == 0:
                    break
            j += 1
        block = line[start:j + 1]
        for m in _ALIAS_ENTRY_RE.finditer(block):
            aliased.add(int(m.group(1)))
        break
    return aliased


def audit_donation(compiled_text: str, donated: Sequence[Tuple[str, int]],
                   report: AuditReport, where: str = "step",
                   min_bytes: int = MIN_ALIAS_BYTES) -> Dict[str, object]:
    """Check every donated leaf is aliased in the executable.

    ``donated``: (leaf_path, nbytes) per donated parameter, in the jit
    flattening order — donated argument 0's leaves are parameters
    ``0..len(donated)-1`` of the entry computation.
    """
    report.ran(PASS)
    aliased = parse_input_output_aliases(compiled_text)
    missing: List[Tuple[int, str, int]] = []
    for i, (path, nbytes) in enumerate(donated):
        if nbytes < min_bytes:
            continue
        if i not in aliased:
            missing.append((i, path, nbytes))
    for i, path, nbytes in missing:
        report.add(PASS, where,
                   f"donated buffer '{path}' ({nbytes} B) is NOT aliased "
                   f"in the executable — XLA made a silent copy",
                   details={"param_number": i, "leaf": path,
                            "nbytes": nbytes})
    if not aliased and donated:
        report.add(PASS, where,
                   "executable has no input_output_alias map at all — "
                   "donation was dropped entirely",
                   details={"n_donated": len(donated)})
    return {"n_donated": len(donated), "n_aliased_params": len(aliased),
            "n_missing": len(missing)}
