"""ACE-Sync public API: state container + the jittable gradient-sync pass
that fuses error feedback (eq 7), compression (eq 6), hierarchical
aggregation (eq 8) and the online importance-estimator update (eqs 3-4).

Usage inside a per-pod train step (see core/trainer.py):

    agg_grads, new_ace = acesync.sync_gradients(
        grads, ace_state, plan, mesh=mesh, shardings=param_shardings,
        cfg=run.acesync)

All heavy tensors (error buffers) are sharded like the parameters; the
estimator state is a few hundred scalars.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Tuple, Union

import jax
import jax.numpy as jnp

from repro.configs.base import ACESyncConfig
from repro.core import importance as imp
from repro.core import sync as S
from repro.core.planexec import ExecPlan
from repro.core.scheduler import Scheduler, SyncPlan


class ACEState(NamedTuple):
    errors: dict            # pytree like params (error-feedback residuals)
    importance: imp.ImportanceState
    struct_feat: jax.Array  # (G, N_STRUCT) static structural features
    div_ema: jax.Array      # divergence EMA scalar
    mse_ema: jax.Array      # estimator fit quality


def init_state(rng, params_like, param_specs, cfg: ACESyncConfig,
               error_dtype=jnp.float32) -> ACEState:
    metas = S.group_metas(param_specs)
    struct = imp.structural_features(
        [{"depth": m.depth, "size": m.size, "kind": m.kind} for m in metas])
    errors = jax.tree.map(
        lambda p: jnp.zeros(p.shape, error_dtype), params_like)
    return ACEState(
        errors=errors,
        importance=imp.init_state(rng, len(metas), cfg.importance_hidden),
        struct_feat=struct,
        div_ema=jnp.zeros((), jnp.float32),
        mse_ema=jnp.zeros((), jnp.float32))


def state_specs(params_specs, cfg: ACESyncConfig,
                error_dtype=jnp.float32) -> ACEState:
    """ShapeDtypeStruct version of init_state (dry-run, no allocation)."""
    metas = S.group_metas(params_specs)
    G = len(metas)
    rng = jax.random.PRNGKey(0)
    small = jax.eval_shape(
        lambda: init_state(rng, jax.tree.map(
            lambda s: jnp.zeros((), s.dtype), params_specs),
            params_specs, cfg))
    errors = jax.tree.map(
        lambda p: jax.ShapeDtypeStruct(p.shape, error_dtype), params_specs)
    return small._replace(errors=errors)


def sync_gradients(grads, state: ACEState, plan: Union[SyncPlan, ExecPlan],
                   *, mesh, shardings, cfg: ACESyncConfig,
                   apply_fn=None, apply_aux=(), apply_scalars=()
                   ) -> Tuple[dict, ACEState, Dict[str, jax.Array]]:
    """The ACE-Sync round. Returns (aggregated grads, new state, metrics).

    With ``apply_fn`` given (see :func:`repro.core.sync.sync_tree`) the
    aggregate is consumed rung by rung — the first return value is then
    the tuple of updated ``apply_aux`` trees instead of the aggregated
    gradients, and the optimizer work overlaps the later rungs'
    exchanges."""
    # --- per-group stats for the importance estimator ---
    mean_abs, var, nrm = S.grad_group_stats(grads)
    if S._pod_info(mesh) > 1:
        # one fleet collective for all three (G,) stat vectors — stacked,
        # a single pmean reduces each element exactly as three would
        axes = S.fleet_axes(mesh)
        mean_abs, var, nrm = jax.lax.pmean(
            jnp.stack([mean_abs, var, nrm]), axes)
    ist = imp.update_stats(state.importance, mean_abs, var, nrm)
    # online supervision: the observed (normalised) gradient-norm momentum is
    # the ground-truth importance signal for this window
    target = ist.norm_mom / jnp.maximum(jnp.max(ist.norm_mom), 1e-12)
    ist, mse = imp.train_step(ist, state.struct_feat, target,
                              alpha=cfg.alpha, lr=cfg.importance_lr)

    # --- error feedback + compression + pod aggregation ---
    agg, new_errors = S.sync_tree(grads, state.errors, plan, mesh=mesh,
                                  shardings=shardings, gamma=cfg.gamma,
                                  block=cfg.topk_block,
                                  bidir=cfg.ring_bidir,
                                  fixed_bits=cfg.accum_bits,
                                  apply_fn=apply_fn,
                                  apply_aux=apply_aux,
                                  apply_scalars=apply_scalars)

    new_state = state._replace(errors=new_errors, importance=ist,
                               mse_ema=0.99 * state.mse_ema + 0.01 * mse)
    metrics = {"imp_mse": mse, "grad_norm_mean": jnp.mean(nrm)}
    return agg, new_state, metrics


def current_scores(state: ACEState, cfg: ACESyncConfig) -> jax.Array:
    """Importance scores I(theta_i) (G,) — jittable; consumed by the
    device-resident replan (and, lagged, by host-side telemetry)."""
    return scores_from(state.importance, state.struct_feat, cfg)


def scores_from(importance: imp.ImportanceState, struct_feat,
                cfg: ACESyncConfig) -> jax.Array:
    """Scores from the estimator state alone.  The host replan path calls
    this with just ``ace.importance`` / ``ace.struct_feat`` sliced out, so
    a replan poll never tree-maps over the param-sized error buffers
    riding in the full :class:`ACEState` (host-side replan overhead)."""
    temp = imp.temporal_features(importance)
    return imp.scores(importance.params, temp, struct_feat, cfg.alpha)


def device_replan_fn(scheduler: Scheduler, cfg: ACESyncConfig):
    """The device-resident control plane: one jitted computation
    ``(importance_state, struct_feat, budget_bytes) -> int32[G]`` fusing
    the importance scoring (eqs. 3-4) with the vectorized greedy knapsack,
    so a replan never pulls ``grad_group_stats`` (or anything else) to the
    host — the host fetches only the tiny assignment vector,
    asynchronously.  The inputs are the estimator's few-hundred-scalar
    state, NOT the full ACEState (whose error buffers are param-sized).

    Cached per (scheduler, cfg) — the solver's static tables depend on the
    scheduler's (sizes, ladder, acct_pods) and the closure bakes in
    ``cfg.alpha``."""
    cache = getattr(scheduler, "_device_replan_fns", None)
    if cache is None:
        cache = scheduler._device_replan_fns = {}
    fn = cache.get(cfg)
    if fn is None:
        solver = scheduler.device_solver()

        @jax.jit
        def fn(imp_state, struct_feat, budget_bytes):
            temp = imp.temporal_features(imp_state)
            scores = imp.scores(imp_state.params, temp, struct_feat,
                                cfg.alpha)
            return solver(scores, jnp.asarray(budget_bytes, jnp.float32))

        cache[cfg] = fn
    return fn
