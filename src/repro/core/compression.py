"""Pure-jnp gradient compression operators (paper eqs. 6-7) and the
``Level`` ladder view.

Since the codec refactor the wire formats themselves live in
``repro/codecs``: each :class:`~repro.codecs.base.Codec` owns its
encode/decode math, its pod aggregation and its byte accounting, and
``core/sync.py`` dispatches whole same-level buckets through one codec at
a time.  What remains here:

  * the blocked reference operators (``topk_compress`` / ``int8_compress``
    and inverses) — the bit-exact oracles the seed shipped, now consumed
    by the codecs and pinned by tests/test_codecs.py;
  * :class:`Level` — a thin, hashable (name, keep_ratio, value_bits) view
    of one ladder rung.  Plans and configs keep speaking in Levels (they
    jit-cache cleanly); ``Level.codec`` resolves to the registered codec
    and ``Level.wire_bytes`` just delegates to it.

Error feedback (eq. 7): g_ef = g + gamma * e; after compression the residual
e' = g_ef - decompress(compress(g_ef)) stays in the local buffer.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

BLOCK = 1024


class Level(NamedTuple):
    """One rung of the compression ladder — a thin view over a codec."""
    name: str
    keep_ratio: float       # fraction of entries transmitted (1.0 = all)
    value_bits: int         # 16 (bf16), 8 (int8), 4, 1 (sign), 0 (skip)

    @property
    def is_full(self) -> bool:
        return self.keep_ratio >= 1.0 and self.value_bits >= 16

    @property
    def is_skip(self) -> bool:
        return self.keep_ratio <= 0.0

    @property
    def is_topk(self) -> bool:
        return 0.0 < self.keep_ratio < 1.0

    @property
    def codec(self):
        """The registered :class:`repro.codecs.base.Codec` this rung
        resolves to (cached; resolution is by semantics, not name)."""
        from repro.codecs import codec_for_level
        return codec_for_level(self)

    def block_k(self, block: int = BLOCK) -> int:
        """Static k per block — delegated to the topk codec so the lane
        rounding rule lives in exactly one place (dense rungs fall back to
        the whole block)."""
        if self.is_topk:
            return self.codec.block_k(block)
        return block

    def wire_bytes(self, n: int, n_pods: int, block: int = BLOCK) -> int:
        """Bytes this level moves over the pod axis per device per sync —
        delegated to the codec, the single source of byte accounting."""
        return self.codec.wire_bytes(n, n_pods, block)


def pad_to_blocks(flat: jax.Array, block: int = BLOCK) -> jax.Array:
    n = flat.shape[0]
    nb = (n + block - 1) // block
    pad = nb * block - n
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    return flat.reshape(nb, block)


# ---------------------------------------------------------------------------
# block-local top-k sparsification
# ---------------------------------------------------------------------------


def topk_compress(blocks: jax.Array, k: int):
    """blocks: (nb, B) f32 -> (values int8 (nb,k), idx uint16 (nb,k),
    scales f32 (nb,)). Values int8-quantised with per-block absmax scale."""
    mag = jnp.abs(blocks)
    _, idx = jax.lax.top_k(mag, k)                       # (nb, k) int32
    vals = jnp.take_along_axis(blocks, idx, axis=1)      # (nb, k) f32
    scale = jnp.max(jnp.abs(vals), axis=1) / 127.0       # (nb,)
    scale = jnp.maximum(scale, 1e-30)
    q = jnp.clip(jnp.round(vals / scale[:, None]), -127, 127).astype(jnp.int8)
    return q, idx.astype(jnp.uint16), scale.astype(jnp.float32)


def topk_decompress(q, idx, scale, block: int = BLOCK):
    """Inverse of :func:`topk_compress` -> dense (nb, B) f32."""
    nb, k = q.shape
    vals = q.astype(jnp.float32) * scale[:, None]
    out = jnp.zeros((nb, block), jnp.float32)
    return out.at[jnp.arange(nb)[:, None], idx.astype(jnp.int32)].add(vals)


# ---------------------------------------------------------------------------
# blockwise int8 quantisation (dense)
# ---------------------------------------------------------------------------


def int8_compress(blocks: jax.Array):
    scale = jnp.max(jnp.abs(blocks), axis=1) / 127.0
    scale = jnp.maximum(scale, 1e-30)
    q = jnp.clip(jnp.round(blocks / scale[:, None]), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def int8_decompress(q, scale):
    return q.astype(jnp.float32) * scale[:, None]


# ---------------------------------------------------------------------------
# single-device compress->decompress round trip (for residuals / simulation)
# ---------------------------------------------------------------------------


def roundtrip(flat: jax.Array, level: Level, block: int = BLOCK) -> jax.Array:
    """decompress(compress(flat)) — what the receiver reconstructs.
    Dispatches through the level's codec, so every registered wire format
    (including int4 / sign) round-trips here."""
    n = flat.shape[0]
    if level.is_full:
        return flat.astype(jnp.bfloat16).astype(jnp.float32)
    if level.is_skip:
        return jnp.zeros_like(flat)
    codec = level.codec
    blocks = pad_to_blocks(flat.astype(jnp.float32), block)
    out = codec.decode(codec.encode(blocks), block)
    return out.reshape(-1)[:n].astype(flat.dtype)
