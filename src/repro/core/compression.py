"""Gradient compression operators (paper eqs. 6-7).

All operators work on a device-local flat gradient block (the nested
shard_map in core/sync.py hands each device its own shard), blocked into
``block``-sized rows:

  * block-local top-k ("TOPK"): keep the k largest-|g| entries of every
    block — the TPU-native adaptation of DGC's sampled global top-k; the
    selection never needs a global sort and the indices fit in uint16.
  * blockwise int8 quantisation ("INT8"): absmax scale per block
    (generalises the paper's  Q(g) = sign(g)*||g||*q  to blocks).

Error feedback (eq. 7): g_ef = g + gamma * e; after compression the residual
e' = g_ef - decompress(compress(g_ef)) stays in the local buffer.

The pure-jnp implementations here double as the reference oracles for the
Pallas kernels in repro/kernels (which fuse EF + select + quantise into one
VMEM pass for the TPU runtime).
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

BLOCK = 1024


class Level(NamedTuple):
    """One rung of the compression ladder."""
    name: str
    keep_ratio: float       # fraction of entries transmitted (1.0 = all)
    value_bits: int         # 16 (bf16), 8 (int8), 0 (skip)

    @property
    def is_full(self) -> bool:
        return self.keep_ratio >= 1.0 and self.value_bits >= 16

    @property
    def is_skip(self) -> bool:
        return self.keep_ratio <= 0.0

    @property
    def is_topk(self) -> bool:
        return 0.0 < self.keep_ratio < 1.0

    def block_k(self, block: int = BLOCK) -> int:
        """Static k per block (multiple of 8 lanes, >= 8)."""
        k = int(round(self.keep_ratio * block))
        return max(8, ((k + 7) // 8) * 8)

    def wire_bytes(self, n: int, n_pods: int, block: int = BLOCK) -> int:
        """Bytes this level moves over the pod axis per device per sync
        (all_gather receive volume; psum for FULL counted as ring bytes)."""
        if self.is_skip or n_pods <= 1:
            return 0
        nb = (n + block - 1) // block
        if self.is_full:
            # bf16 psum (ring): 2 * (P-1)/P * 2n bytes on the wire
            return int(2 * (n_pods - 1) / n_pods * 2 * n)
        if self.keep_ratio >= 1.0:  # INT8 dense
            per = n + 4 * nb  # int8 payload + f32 scales
            return per * (n_pods - 1)
        k = self.block_k(block)
        per = nb * k * (1 + 2) + 4 * nb  # int8 vals + u16 idx + f32 scales
        return per * (n_pods - 1)


def pad_to_blocks(flat: jax.Array, block: int = BLOCK) -> jax.Array:
    n = flat.shape[0]
    nb = (n + block - 1) // block
    pad = nb * block - n
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    return flat.reshape(nb, block)


# ---------------------------------------------------------------------------
# block-local top-k sparsification
# ---------------------------------------------------------------------------


def topk_compress(blocks: jax.Array, k: int):
    """blocks: (nb, B) f32 -> (values int8 (nb,k), idx uint16 (nb,k),
    scales f32 (nb,)). Values int8-quantised with per-block absmax scale."""
    mag = jnp.abs(blocks)
    _, idx = jax.lax.top_k(mag, k)                       # (nb, k) int32
    vals = jnp.take_along_axis(blocks, idx, axis=1)      # (nb, k) f32
    scale = jnp.max(jnp.abs(vals), axis=1) / 127.0       # (nb,)
    scale = jnp.maximum(scale, 1e-30)
    q = jnp.clip(jnp.round(vals / scale[:, None]), -127, 127).astype(jnp.int8)
    return q, idx.astype(jnp.uint16), scale.astype(jnp.float32)


def topk_decompress(q, idx, scale, block: int = BLOCK):
    """Inverse of :func:`topk_compress` -> dense (nb, B) f32."""
    nb, k = q.shape
    vals = q.astype(jnp.float32) * scale[:, None]
    out = jnp.zeros((nb, block), jnp.float32)
    return out.at[jnp.arange(nb)[:, None], idx.astype(jnp.int32)].add(vals)


# ---------------------------------------------------------------------------
# blockwise int8 quantisation (dense)
# ---------------------------------------------------------------------------


def int8_compress(blocks: jax.Array):
    scale = jnp.max(jnp.abs(blocks), axis=1) / 127.0
    scale = jnp.maximum(scale, 1e-30)
    q = jnp.clip(jnp.round(blocks / scale[:, None]), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def int8_decompress(q, scale):
    return q.astype(jnp.float32) * scale[:, None]


# ---------------------------------------------------------------------------
# single-device compress->decompress round trip (for residuals / simulation)
# ---------------------------------------------------------------------------


def roundtrip(flat: jax.Array, level: Level, block: int = BLOCK) -> jax.Array:
    """decompress(compress(flat)) — what the receiver reconstructs."""
    n = flat.shape[0]
    if level.is_full:
        return flat.astype(jnp.bfloat16).astype(jnp.float32)
    if level.is_skip:
        return jnp.zeros_like(flat)
    blocks = pad_to_blocks(flat.astype(jnp.float32), block)
    if level.is_topk:
        out = topk_decompress(*topk_compress(blocks, level.block_k(block)),
                              block)
    else:
        out = int8_decompress(*int8_compress(blocks))
    return out.reshape(-1)[:n].astype(flat.dtype)
