"""Hierarchical cloud-edge synchronisation (paper eqs. 7-8) on the pod axis.

Execution context: these functions run INSIDE the outer per-pod shard_map
(manual over "pod"; "data"/"model" auto).  Compression is performed in a
NESTED shard_map that is manual over "data"/"model" as well, so every device
compresses exactly its local shard — no resharding — and exchanges payloads
only with its pod-peers over the (slow, DCN) "pod" axis:

    g_ef   = g + gamma * e                          (eq 7, error feedback)
    payload= codec.ef_encode(g_ef_local)             (codec from the plan)
    agg    = codec.pod_exchange(payloads, omega)     (eq 8, one collective)
    e'     = g_ef - decompress(own payload)

Since the codec refactor the per-leaf Python loop is gone: ``sync_tree``
BUCKETS same-level leaves into one flat buffer per codec, runs the codec's
fused Pallas path (``repro/kernels``) on the concatenated buffer, and
issues at most ONE pod collective per distinct codec in the plan — an
H-step sync costs O(#levels) collectives instead of O(#groups).  Each
codec packs its whole payload pytree (values + indices + scales) into a
single uint8 wire buffer before the all_gather, so "one collective" holds
regardless of how many components the wire format carries.

Wire formats are pluggable :class:`repro.codecs.base.Codec` objects (FULL
bf16-psum, dense INT8 / packed INT4, block top-k, 1-bit sign with majority
vote, SKIP); plans refer to them through the thin ``Level`` view.

Without a mesh (unit tests) the same math runs on the single local array
with n_pods = 1.
"""
from __future__ import annotations

import functools
import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.codecs import POD_AXIS, plan_wire_bytes
from repro.core import compression as C
from repro.core.scheduler import SyncPlan
from repro.kernels import ops
from repro.models.shardctx import norm_spec


# ---------------------------------------------------------------------------
# Parameter groups
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class GroupMeta:
    name: str
    size: int
    depth: float          # relative depth in the network, [0, 1]
    kind: str             # embed | attn | mlp | other


_KIND_PATTERNS = (
    ("embed", "embed"),
    ("attn", "attn"), ("wq", "attn"), ("wk", "attn"), ("wv", "attn"),
    ("wo", "attn"), ("mix", "attn"),
    ("ffn", "mlp"), ("w_gate", "mlp"), ("w_up", "mlp"), ("w_down", "mlp"),
    ("router", "mlp"),
)


def _kind_of(path: str) -> str:
    for pat, kind in _KIND_PATTERNS:
        if pat in path:
            return kind
    return "other"


def group_metas(param_specs) -> List[GroupMeta]:
    """Flatten the param pytree into ordered per-leaf groups."""
    leaves = jax.tree_util.tree_flatten_with_path(param_specs)[0]
    out = []
    total = max(len(leaves) - 1, 1)
    for i, (path, leaf) in enumerate(leaves):
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        size = 1
        for d in leaf.shape:
            size *= d
        out.append(GroupMeta(name=name, size=int(size), depth=i / total,
                             kind=_kind_of(name)))
    return out


def group_sizes(param_specs) -> List[int]:
    return [g.size for g in group_metas(param_specs)]


# ---------------------------------------------------------------------------
# bucketed local compress + pod exchange (one flat buffer per codec)
# ---------------------------------------------------------------------------


def _pod_info(mesh) -> int:
    if mesh is None or POD_AXIS not in mesh.axis_names:
        return 1
    return mesh.shape[POD_AXIS]


def _bucket_sync_local(gs, es, omega, omega_own, *, codec, gamma, n_pods,
                       block, use_pallas):
    """Fully local per-device sync of one same-codec bucket.

    ``gs`` / ``es``: tuples of local shard arrays that the plan assigned
    the same level.  They are flattened into ONE concatenated f32 buffer,
    pushed through the codec's fused EF + compress + exchange round (at
    most one pod collective), and split back — block boundaries may span
    leaves, which is fine for blockwise formats because the residual split
    ``own + new_e == ef`` holds elementwise.
    """
    sizes = [math.prod(g.shape) for g in gs]
    flats = [g.reshape(-1).astype(jnp.float32) for g in gs]
    e_flats = [e.reshape(-1).astype(jnp.float32) for e in es]
    flat = flats[0] if len(flats) == 1 else jnp.concatenate(flats)
    e_flat = e_flats[0] if len(e_flats) == 1 else jnp.concatenate(e_flats)
    agg, new_e = codec.ef_sync(flat, e_flat, omega, omega_own, gamma=gamma,
                               n_pods=n_pods, block=block, axis=POD_AXIS,
                               use_pallas=use_pallas)
    aggs, news, off = [], [], 0
    for g, e, n in zip(gs, es, sizes):
        aggs.append(agg[off:off + n].reshape(g.shape).astype(g.dtype))
        news.append(new_e[off:off + n].reshape(e.shape).astype(e.dtype))
        off += n
    return tuple(aggs), tuple(news)


# ---------------------------------------------------------------------------
# tree-level API
# ---------------------------------------------------------------------------


def _auto_axes(mesh):
    return tuple(a for a in mesh.axis_names if a != POD_AXIS)


def sync_tree(tree, errors, plan: SyncPlan, *, mesh, shardings,
              gamma: float, block: int = C.BLOCK,
              inside_manual: bool = None, use_pallas: bool = None):
    """Compress + hierarchically aggregate a gradient (or delta) pytree.

    Must be called inside the outer per-pod shard_map when the mesh has a
    pod axis.  ``shardings``: pytree of PartitionSpec matching ``tree`` (the
    data/model sharding of each leaf).  Returns (agg_tree, new_errors).

    Same-level leaves are bucketed into one flat buffer per codec, so the
    whole tree costs at most one pod collective per DISTINCT level in the
    plan (tests/test_collectives.py counts them in the lowered HLO).

    ``inside_manual``: whether we are already inside a shard_map (then the
    nested shard_map must infer the context mesh); default: pod axis
    present.  ``use_pallas``: route the EF + compress inner loop through
    the fused Pallas kernels; default
    :func:`repro.kernels.ops.default_use_pallas` (kernels on accelerators,
    pure-jnp oracles on CPU, ``REPRO_FORCE_INTERPRET=1`` to force the
    kernel path under the interpreter).
    """
    if inside_manual is None:
        inside_manual = mesh is not None and POD_AXIS in mesh.axis_names
    if use_pallas is None:
        use_pallas = ops.default_use_pallas()
    n_pods = _pod_info(mesh)
    omega = jnp.asarray(plan.omega, jnp.float32)
    if n_pods == 1 and len(plan.omega) == 1:
        omega = jnp.ones((1,), jnp.float32)  # single pod: identity weight
    # own pod's aggregation weight, computed at the per-pod level (axis_index
    # may not re-bind "pod" inside the nested fully-manual shard_map)
    if n_pods > 1:
        omega_own = omega[jax.lax.axis_index(POD_AXIS)]
    else:
        omega_own = omega[0]

    leaves, treedef = jax.tree_util.tree_flatten(tree)
    e_leaves = treedef.flatten_up_to(errors)
    s_leaves = treedef.flatten_up_to(shardings) if shardings is not None \
        else [None] * len(leaves)
    assert len(leaves) == len(plan.level_idx), \
        (len(leaves), len(plan.level_idx))

    # bucket leaf indices by level: one fused buffer + one collective each
    buckets: Dict[int, List[int]] = {}
    for i, li in enumerate(plan.level_idx):
        buckets.setdefault(li, []).append(i)

    agg_out = [None] * len(leaves)
    err_out = [None] * len(leaves)
    for li in sorted(buckets):
        idxs = buckets[li]
        codec = plan.levels[li].codec
        gs = tuple(leaves[i] for i in idxs)
        es = tuple(e_leaves[i] for i in idxs)
        fn = functools.partial(_bucket_sync_local, codec=codec, gamma=gamma,
                               n_pods=n_pods, block=block,
                               use_pallas=use_pallas)
        if mesh is not None and (compat.PARTIAL_MANUAL or not inside_manual):
            aspecs = []
            for i in idxs:
                spec = s_leaves[i]
                aspec = norm_spec(spec if spec is not None else P(), mesh)
                # drop the pod axis from specs (manual outside already)
                aspecs.append(P(*[None if ax == POD_AXIS else ax
                                  for ax in aspec]))
            aspecs = tuple(aspecs)
            inner = compat.shard_map(
                fn, mesh, in_specs=(aspecs, aspecs, P(None), P()),
                out_specs=(aspecs, aspecs),
                manual_axes=set(_auto_axes(mesh)),
                # surrounding per-pod shard_map (if any) provides the mesh
                infer_mesh=inside_manual)
            aggs, news = inner(gs, es, omega, omega_own)
        else:
            # no mesh, or old-jax fully-manual region (leaves replicated
            # over data/model there): device-local math, pod collectives
            # still bound by the enclosing manual region
            aggs, news = fn(gs, es, omega, omega_own)
        for j, i in enumerate(idxs):
            agg_out[i] = aggs[j]
            err_out[i] = news[j]
    return (jax.tree_util.tree_unflatten(treedef, agg_out),
            jax.tree_util.tree_unflatten(treedef, err_out))


def grad_group_stats(tree):
    """Per-group scalars feeding the importance estimator: (mean|g|, var,
    norm) each (G,)."""
    leaves = jax.tree_util.tree_leaves(tree)
    ma, var, nrm = [], [], []
    for g in leaves:
        g32 = g.astype(jnp.float32)
        m = jnp.mean(jnp.abs(g32))
        v = jnp.var(g32)
        n = jnp.sqrt(jnp.sum(g32 * g32))
        ma.append(m); var.append(v); nrm.append(n)
    return (jnp.stack(ma), jnp.stack(var), jnp.stack(nrm))


def wire_bytes_of_plan(plan: SyncPlan, sizes: Sequence[int],
                       n_pods: int, block: int = C.BLOCK) -> int:
    """Analytic on-the-wire bytes per device per sync for a plan, priced
    exactly the way :func:`sync_tree` transmits it (same-level leaves share
    one bucketed buffer and one collective) — the number Table 1 reports
    and tests/test_collectives.py pins to the traced HLO."""
    return plan_wire_bytes(plan, sizes, n_pods, block)
