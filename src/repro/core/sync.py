"""Hierarchical cloud-edge synchronisation (paper eqs. 7-8) on the pod axis.

Execution context: these functions run INSIDE the outer per-pod shard_map
(manual over "pod"; "data"/"model" auto).  Compression is performed in a
NESTED shard_map that is manual over "data"/"model" as well, so every device
compresses exactly its local shard — no resharding — and exchanges payloads
only with its pod-peers over the (slow, DCN) "pod" axis:

    g_ef   = g + gamma * e                          (eq 7, error feedback)
    payload= compress(g_ef_local)                    (level from the plan)
    agg    = sum_k omega_k * decompress(payload_k)   (eq 8, all_gather 'pod')
    e'     = g_ef - decompress(own payload)

Levels: FULL (bf16 psum), INT8 (dense int8 + scales all_gather), TOPK_*
(block-local top-k int8 + uint16 indices + scales all_gather), SKIP (buffer
locally, transmit nothing).

Without a mesh (unit tests) the same math runs on the single local array
with n_pods = 1.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core import compression as C
from repro.core.scheduler import SyncPlan
from repro.models.shardctx import norm_spec

POD_AXIS = "pod"


# ---------------------------------------------------------------------------
# Parameter groups
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class GroupMeta:
    name: str
    size: int
    depth: float          # relative depth in the network, [0, 1]
    kind: str             # embed | attn | mlp | other


_KIND_PATTERNS = (
    ("embed", "embed"),
    ("attn", "attn"), ("wq", "attn"), ("wk", "attn"), ("wv", "attn"),
    ("wo", "attn"), ("mix", "attn"),
    ("ffn", "mlp"), ("w_gate", "mlp"), ("w_up", "mlp"), ("w_down", "mlp"),
    ("router", "mlp"),
)


def _kind_of(path: str) -> str:
    for pat, kind in _KIND_PATTERNS:
        if pat in path:
            return kind
    return "other"


def group_metas(param_specs) -> List[GroupMeta]:
    """Flatten the param pytree into ordered per-leaf groups."""
    leaves = jax.tree_util.tree_flatten_with_path(param_specs)[0]
    out = []
    total = max(len(leaves) - 1, 1)
    for i, (path, leaf) in enumerate(leaves):
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        size = 1
        for d in leaf.shape:
            size *= d
        out.append(GroupMeta(name=name, size=int(size), depth=i / total,
                             kind=_kind_of(name)))
    return out


def group_sizes(param_specs) -> List[int]:
    return [g.size for g in group_metas(param_specs)]


# ---------------------------------------------------------------------------
# per-leaf local compress + pod exchange
# ---------------------------------------------------------------------------


def _pod_info(mesh) -> int:
    if mesh is None or POD_AXIS not in mesh.axis_names:
        return 1
    return mesh.shape[POD_AXIS]


def _local_topk_sync(flat, e_flat, omega, omega_own, *, k, gamma,
                     n_pods, block):
    """flat/e_flat: (n,) local. Returns (agg (n,), new_e (n,))."""
    n = flat.shape[0]
    ef = flat + gamma * e_flat
    blocks = C.pad_to_blocks(ef, block)
    q, idx, scale = C.topk_compress(blocks, k)
    own = C.topk_decompress(q, idx, scale, block).reshape(-1)[:n]
    if n_pods > 1:
        qs = jax.lax.all_gather(q, POD_AXIS)          # (P, nb, k) int8
        idxs = jax.lax.all_gather(idx, POD_AXIS)
        scales = jax.lax.all_gather(scale, POD_AXIS)
        scales = scales * omega[:, None]              # fold omega into scales
        nb = q.shape[0]
        qs2 = qs.transpose(1, 0, 2).reshape(nb, -1)
        idxs2 = idxs.transpose(1, 0, 2).reshape(nb, -1)
        sc2 = jnp.repeat(scales.transpose(1, 0), k, axis=1)  # (nb, P*k)
        vals = qs2.astype(jnp.float32) * sc2
        dense = jnp.zeros((nb, block), jnp.float32)
        dense = dense.at[jnp.arange(nb)[:, None],
                         idxs2.astype(jnp.int32)].add(vals)
        agg = dense.reshape(-1)[:n]
    else:
        agg = own * omega_own
    new_e = ef - own
    return agg, new_e


def _local_int8_sync(flat, e_flat, omega, omega_own, *, gamma, n_pods,
                     block):
    n = flat.shape[0]
    ef = flat + gamma * e_flat
    blocks = C.pad_to_blocks(ef, block)
    q, scale = C.int8_compress(blocks)
    own = C.int8_decompress(q, scale).reshape(-1)[:n]
    if n_pods > 1:
        qs = jax.lax.all_gather(q, POD_AXIS)          # (P, nb, B)
        scales = jax.lax.all_gather(scale, POD_AXIS) * omega[:, None]
        dense = jnp.einsum("pnb,pn->nb", qs.astype(jnp.float32), scales)
        agg = dense.reshape(-1)[:n]
    else:
        agg = own * omega_own
    new_e = ef - own
    return agg, new_e


def _leaf_sync_local(g, e, omega, omega_own, *, level: C.Level, gamma,
                     n_pods, block):
    """Fully local per-device leaf sync. g/e: local shard arrays."""
    shape = g.shape
    flat = g.reshape(-1).astype(jnp.float32)
    e_flat = e.reshape(-1).astype(jnp.float32)
    if level.is_skip:
        new_e = flat + gamma * e_flat
        return jnp.zeros_like(flat).reshape(shape).astype(g.dtype), \
            new_e.reshape(shape).astype(e.dtype)
    if level.is_full:
        ef = flat + gamma * e_flat
        wire = ef.astype(jnp.bfloat16).astype(jnp.float32)
        if n_pods > 1:
            agg = jax.lax.psum(wire * omega_own, POD_AXIS)
        else:
            agg = wire * omega_own
        new_e = ef - wire
        return agg.reshape(shape).astype(g.dtype), \
            new_e.reshape(shape).astype(e.dtype)
    if level.is_topk:
        agg, new_e = _local_topk_sync(flat, e_flat, omega, omega_own,
                                      k=level.block_k(block), gamma=gamma,
                                      n_pods=n_pods, block=block)
    else:
        agg, new_e = _local_int8_sync(flat, e_flat, omega, omega_own,
                                      gamma=gamma, n_pods=n_pods,
                                      block=block)
    return agg.reshape(shape).astype(g.dtype), \
        new_e.reshape(shape).astype(e.dtype)


# ---------------------------------------------------------------------------
# tree-level API
# ---------------------------------------------------------------------------


def _auto_axes(mesh):
    return tuple(a for a in mesh.axis_names if a != POD_AXIS)


def sync_tree(tree, errors, plan: SyncPlan, *, mesh, shardings,
              gamma: float, block: int = C.BLOCK,
              inside_manual: bool = None):
    """Compress + hierarchically aggregate a gradient (or delta) pytree.

    Must be called inside the outer per-pod shard_map when the mesh has a
    pod axis.  ``shardings``: pytree of PartitionSpec matching ``tree`` (the
    data/model sharding of each leaf).  Returns (agg_tree, new_errors).

    ``inside_manual``: whether we are already inside a shard_map (then the
    nested shard_map must infer the context mesh); default: pod axis
    present.
    """
    if inside_manual is None:
        inside_manual = mesh is not None and POD_AXIS in mesh.axis_names
    n_pods = _pod_info(mesh)
    omega = jnp.asarray(plan.omega, jnp.float32)
    if n_pods == 1 and len(plan.omega) == 1:
        omega = jnp.ones((1,), jnp.float32)  # single pod: identity weight
    # own pod's aggregation weight, computed at the per-pod level (axis_index
    # may not re-bind "pod" inside the nested fully-manual shard_map)
    if n_pods > 1:
        omega_own = omega[jax.lax.axis_index(POD_AXIS)]
    else:
        omega_own = omega[0]

    leaves, treedef = jax.tree_util.tree_flatten(tree)
    e_leaves = treedef.flatten_up_to(errors)
    s_leaves = treedef.flatten_up_to(shardings) if shardings is not None \
        else [None] * len(leaves)
    assert len(leaves) == len(plan.level_idx), \
        (len(leaves), len(plan.level_idx))

    agg_out, err_out = [], []
    for i, (g, e, spec) in enumerate(zip(leaves, e_leaves, s_leaves)):
        level = plan.level_of(i)
        fn = functools.partial(_leaf_sync_local, level=level, gamma=gamma,
                               n_pods=n_pods, block=block)
        if mesh is not None and (compat.PARTIAL_MANUAL or not inside_manual):
            aspec = norm_spec(spec if spec is not None else P(), mesh)
            # drop the pod axis from specs (manual outside already)
            aspec = P(*[None if ax == POD_AXIS else ax for ax in aspec])
            inner = compat.shard_map(
                fn, mesh, in_specs=(aspec, aspec, P(None), P()),
                out_specs=(aspec, aspec),
                manual_axes=set(_auto_axes(mesh)),
                # surrounding per-pod shard_map (if any) provides the mesh
                infer_mesh=inside_manual)
            agg, new_e = inner(g, e, omega, omega_own)
        else:
            # no mesh, or old-jax fully-manual region (leaves replicated
            # over data/model there): device-local math, pod collectives
            # still bound by the enclosing manual region
            agg, new_e = fn(g, e, omega, omega_own)
        agg_out.append(agg)
        err_out.append(new_e)
    return (jax.tree_util.tree_unflatten(treedef, agg_out),
            jax.tree_util.tree_unflatten(treedef, err_out))


def grad_group_stats(tree):
    """Per-group scalars feeding the importance estimator: (mean|g|, var,
    norm) each (G,)."""
    leaves = jax.tree_util.tree_leaves(tree)
    ma, var, nrm = [], [], []
    for g in leaves:
        g32 = g.astype(jnp.float32)
        m = jnp.mean(jnp.abs(g32))
        v = jnp.var(g32)
        n = jnp.sqrt(jnp.sum(g32 * g32))
        ma.append(m); var.append(v); nrm.append(n)
    return (jnp.stack(ma), jnp.stack(var), jnp.stack(nrm))


def wire_bytes_of_plan(plan: SyncPlan, sizes: Sequence[int],
                       n_pods: int) -> int:
    """Analytic on-the-wire bytes per device per sync for a plan."""
    return sum(plan.level_of(i).wire_bytes(n, n_pods)
               for i, n in enumerate(sizes))
