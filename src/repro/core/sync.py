"""Hierarchical cloud-edge synchronisation (paper eqs. 7-8) on the pod axis.

Execution context: these functions run INSIDE the outer per-pod shard_map
(manual over "pod"; "data"/"model" auto).  Compression is performed in a
NESTED shard_map that is manual over "data"/"model" as well, so every device
compresses exactly its local shard — no resharding — and exchanges payloads
only with its pod-peers over the (slow, DCN) "pod" axis:

    g_ef   = g + gamma * e                          (eq 7, error feedback)
    payload= codec.ef_encode(g_ef_local)             (codec from the plan)
    agg    = codec.pod_exchange(payloads, omega)     (eq 8, one collective)
    e'     = g_ef - decompress(own payload)

Since the plan-as-data refactor the exchange is **retrace-free**: every
leaf is laid out block-aligned in ONE static flat (NB, block) buffer, and
per ladder rung a gather permutation (``repro.core.planexec.ExecPlan`` —
ordinary device data) repacks the member leaves into one contiguous
per-rung buffer.  Each rung runs its codec's fused EF + compress +
exchange round on that buffer — ONE pod collective (all_gather/psum) for
small buckets, or the plan's K-chunk ``ppermute`` ring for DCN-bound ones
(``Codec.ef_sync_ring``: the transfer of chunk *i* hides the
decode-accumulate of chunk *i-1*; exactly the same bytes on the wire) —
and the aggregate/residual are scattered back through the same
permutation.  Only the tuple of padded per-rung block counts — the
bucket-shape signature — plus the per-rung chunk grid is static, so a
replan that keeps the signature swaps permutations without recompiling
(tests/test_replan.py pins this; tests/test_collectives.py keeps pinning
the collectives-per-rung and analytic==traced byte contracts, now with
the per-leaf block padding priced explicitly).

The trainer-level counterpart is rung-ordered apply (``apply_fn``): the
optimizer consumes each rung's aggregate the moment it lands, so the
apply of rung r overlaps the exchange of rung r+1 instead of barriering
on the whole tree.

Wire formats are pluggable :class:`repro.codecs.base.Codec` objects (FULL
bf16-psum, dense INT8 / packed INT4, block top-k, 1-bit sign with majority
vote, SKIP); plans refer to them through the thin ``Level`` view.

Without a mesh (unit tests) the same math runs on the single local array
with n_pods = 1.
"""
from __future__ import annotations

import functools
import math
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.codecs import EDGE_AXIS, POD_AXIS, plan_wire_bytes
from repro.core import compression as C
from repro.core.planexec import ExecPlan, build_exec_plan, n_blocks
from repro.kernels.decode import FIXED_POINT_BITS
from repro.core.scheduler import SyncPlan
from repro.kernels import ops
from repro.models.shardctx import norm_spec


# ---------------------------------------------------------------------------
# Parameter groups
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class GroupMeta:
    name: str
    size: int
    depth: float          # relative depth in the network, [0, 1]
    kind: str             # embed | attn | mlp | other


_KIND_PATTERNS = (
    ("embed", "embed"),
    ("attn", "attn"), ("wq", "attn"), ("wk", "attn"), ("wv", "attn"),
    ("wo", "attn"), ("mix", "attn"),
    ("ffn", "mlp"), ("w_gate", "mlp"), ("w_up", "mlp"), ("w_down", "mlp"),
    ("router", "mlp"),
)


def _kind_of(path: str) -> str:
    for pat, kind in _KIND_PATTERNS:
        if pat in path:
            return kind
    return "other"


def group_metas(param_specs) -> List[GroupMeta]:
    """Flatten the param pytree into ordered per-leaf groups."""
    leaves = jax.tree_util.tree_flatten_with_path(param_specs)[0]
    out = []
    total = max(len(leaves) - 1, 1)
    for i, (path, leaf) in enumerate(leaves):
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        size = 1
        for d in leaf.shape:
            size *= d
        out.append(GroupMeta(name=name, size=int(size), depth=i / total,
                             kind=_kind_of(name)))
    return out


def group_sizes(param_specs) -> List[int]:
    return [g.size for g in group_metas(param_specs)]


# ---------------------------------------------------------------------------
# local layout: where each leaf lands in the static flat block buffer
# ---------------------------------------------------------------------------


def _pod_info(mesh) -> int:
    """FLEET size: every device one flat exchange spans — the pod axis
    times the (optional) fast intra-cluster edge axis."""
    if mesh is None or POD_AXIS not in mesh.axis_names:
        return 1
    n = mesh.shape[POD_AXIS]
    if EDGE_AXIS in mesh.axis_names:
        n *= mesh.shape[EDGE_AXIS]
    return n


def fleet_axes(mesh) -> Tuple[str, ...]:
    """The mesh axes one flat fleet collective spans: ``("pod",)`` on a
    flat mesh, ``("pod", "edge")`` on a hierarchical one, ``()`` without
    a pod axis.  ``pmean``/``psum`` over the tuple reduce across the
    whole fleet; the tuple-axis ``all_gather`` order is pod-major,
    matching the ``pod * n_edge + edge`` fleet slot indexing."""
    if mesh is None or POD_AXIS not in mesh.axis_names:
        return ()
    if EDGE_AXIS in mesh.axis_names:
        return (POD_AXIS, EDGE_AXIS)
    return (POD_AXIS,)


def _tier_info(mesh) -> Tuple[int, int]:
    """(n_cross, n_edge) of the two-tier topology: cluster count on the
    slow pod axis x members per cluster on the fast edge axis.  A flat
    mesh is (n_pods, 1)."""
    if mesh is None or POD_AXIS not in mesh.axis_names:
        return 1, 1
    n_edge = mesh.shape[EDGE_AXIS] if EDGE_AXIS in mesh.axis_names else 1
    return mesh.shape[POD_AXIS], n_edge


def _uses_nested(mesh, inside_manual: bool) -> bool:
    """Whether sync_tree will wrap the exchange in a nested data/model
    shard_map (leaves become local shards there)."""
    return mesh is not None and (compat.PARTIAL_MANUAL or not inside_manual)


def _local_shape(shape, spec, mesh) -> Tuple[int, ...]:
    """Per-device shard shape of a leaf under the nested data/model-manual
    region (the pod axis is manual outside and does not divide here)."""
    spec = norm_spec(spec if spec is not None else P(), mesh)
    out = list(shape)
    for d, ax in enumerate(spec):
        if ax is None or d >= len(out):
            continue
        for a in ((ax,) if isinstance(ax, str) else tuple(ax)):
            if a not in (POD_AXIS, EDGE_AXIS):
                out[d] //= mesh.shape[a]
    return tuple(out)


def local_group_sizes(param_specs, shardings, mesh,
                      inside_manual: Optional[bool] = None) -> List[int]:
    """Per-group element counts of the layout the exchange actually runs
    on: the local shard sizes when a nested data/model shard_map applies,
    the global sizes otherwise.  This is what ``planexec.build_exec_plan``
    must be fed so host-built gather perms match the traced layout."""
    leaves, treedef = jax.tree_util.tree_flatten(param_specs)
    s_leaves = treedef.flatten_up_to(shardings) if shardings is not None \
        else [None] * len(leaves)
    if inside_manual is None:
        inside_manual = mesh is not None and POD_AXIS in mesh.axis_names
    if not _uses_nested(mesh, inside_manual):
        return [int(math.prod(l.shape)) for l in leaves]
    return [int(math.prod(_local_shape(l.shape, s, mesh)))
            for l, s in zip(leaves, s_leaves)]


# ---------------------------------------------------------------------------
# static-shape repack + per-rung exchange (the retrace-free hot path)
# ---------------------------------------------------------------------------


def _leaf_blocks(leaves, block: int) -> jax.Array:
    """Concatenate leaves into the static (NB, block) layout: each leaf
    flattened, zero-padded to a block multiple, block-aligned.  The layout
    depends only on (leaf shapes, block) — never on the plan."""
    parts = [C.pad_to_blocks(l.reshape(-1).astype(jnp.float32), block)
             for l in leaves]
    if not parts:
        return jnp.zeros((0, block), jnp.float32)
    return parts[0] if len(parts) == 1 else jnp.concatenate(parts)


def _rung_exchange(codec, fb, eb, perm, omega, omega_own, *, chunks,
                   bidir, gamma, n_pods, block, use_pallas, fixed_bits,
                   hier=0, n_cross=1, n_edge=1, omega_intra=None):
    """One rung's gather + EF + compress + exchange round: the two-tier
    path when the plan's tier grid says so (``hier > 0`` — intra-cluster
    aggregation over the fast edge axis feeding one payload per cluster
    over the pod axis, ``Codec.ef_sync_hier``), the chunked ring
    pipeline when the chunk grid says so (``chunks > 0``; see
    ``planexec.ring_chunk_count``), the one-shot path otherwise.

    The rung bucket is ``fb[perm]`` of the packed (NB+1, block)
    grad/error buffers.  The one-shot path hands the buffers + perm to
    ``Codec.ef_sync_gather``, so producer-fused codecs run the gather
    INSIDE the encode kernel — the encode reads each row straight out of
    the buffer the backward wrote, nothing rematerialises the bucket in
    between (the segment-streaming win: the collective's operand cone is
    exactly this range's rows).  The ring and two-tier paths chunk /
    re-encode whole-bucket payloads, so they materialise the gather up
    front as before.  All paths accumulate deterministically
    (fixed-point / integer / canonical-order — the codec's choice)
    whenever >= 3 peers exchange, so per-device aggregates are
    bit-identical on any mesh and ring <-> one-shot <-> two-tier replans
    never move the numerics."""
    if hier and n_edge > 1:
        return codec.ef_sync_hier(
            fb[perm].reshape(-1), eb[perm].reshape(-1), omega_intra,
            omega_own, gamma=gamma, n_cross=n_cross, n_edge=n_edge,
            intra_mode=hier, n_chunks=chunks, block=block,
            cross_axis=POD_AXIS, intra_axis=EDGE_AXIS,
            use_pallas=use_pallas, bidir=bidir, fixed_bits=fixed_bits)
    axis = (POD_AXIS, EDGE_AXIS) if n_edge > 1 else POD_AXIS
    if chunks and n_pods > 1:
        return codec.ef_sync_ring(
            fb[perm].reshape(-1), eb[perm].reshape(-1), omega, omega_own,
            gamma=gamma, n_pods=n_pods, n_chunks=chunks, block=block,
            axis=axis, use_pallas=use_pallas, bidir=bidir,
            fixed_bits=fixed_bits)
    return codec.ef_sync_gather(
        fb, eb, perm, omega, omega_own, gamma=gamma, n_pods=n_pods,
        block=block, axis=axis, use_pallas=use_pallas,
        fixed_bits=fixed_bits)


def _range_sync(gs, es, aux, perms, sig, chunks, hgrid, NB, *, levels,
                block, omega, omega_own, omega_intra, scalars, bidir,
                gamma, n_pods, n_cross, n_edge, use_pallas, fixed_bits,
                apply_fn):
    """One leaf range's pack + per-rung exchange + scatter + unpack.

    The whole tree is one range on the barriered path; the backward-
    streaming path calls this once per segment — crucially the packed
    buffers here are built ONLY from this range's leaves, so the rung
    collectives below carry no data dependence on any other segment's
    gradients and XLA's scheduler issues them while the rest of the
    backward still runs.  Returns ``(aggs | aux_outs, errs)`` as leaf
    tuples for the range."""
    fb = _leaf_blocks(gs, block)
    eb = _leaf_blocks(es, block)
    assert fb.shape[0] == NB, \
        f"leaf layout has {fb.shape[0]} blocks, plan was built for {NB}"
    zrow = jnp.zeros((1, block), jnp.float32)
    fb = jnp.concatenate([fb, zrow])
    eb = jnp.concatenate([eb, zrow])
    abufs = [jnp.concatenate([_leaf_blocks(a, block), zrow]) for a in aux]
    agg = None if apply_fn is not None \
        else jnp.zeros((NB + 1, block), jnp.float32)
    err = jnp.zeros((NB + 1, block), jnp.float32)
    # Encode pass: every payload-gather rung (one-shot multi-pod path)
    # stops at its packed uint8 wire buffer; the wires are concatenated
    # into ONE all_gather per range instead of one per rung — same bytes,
    # same per-rung fold (slicing a gathered concatenation is
    # bit-identical to gathering the piece), but the sync round's
    # collective latency stops scaling with the rung count, on the CPU
    # sim and the DCN alike.  Ring / two-tier / single-pod rungs keep
    # their own exchange paths.
    axis = (POD_AXIS, EDGE_AXIS) if n_edge > 1 else POD_AXIS
    staged, wire_parts, woff = [], [], 0
    pi = 0
    for r, S in enumerate(sig):
        if not S:
            continue
        perm = perms[pi]
        pi += 1
        codec = levels[r].codec
        chunks_r = chunks[r] if chunks else 0
        hier_r = hgrid[r] if hgrid else 0
        if (n_pods > 1 and codec.supports_ring
                and not (hier_r and n_edge > 1)
                and not (chunks_r and n_pods > 1)):
            wire, meta, new_e = codec.ef_encode_wire(
                fb, eb, perm, gamma=gamma, block=block,
                use_pallas=use_pallas)
            staged.append((S, perm, codec, (meta, woff, wire.shape[0],
                                            new_e)))
            wire_parts.append(wire)
            woff += wire.shape[0]
        else:
            b_out = _rung_exchange(
                codec, fb, eb, perm, omega,
                omega_own, chunks=chunks_r,
                bidir=bidir, gamma=gamma, n_pods=n_pods, block=block,
                use_pallas=use_pallas, fixed_bits=fixed_bits,
                hier=hier_r, n_cross=n_cross,
                n_edge=n_edge, omega_intra=omega_intra)
            staged.append((S, perm, None, b_out))
    gathered = None
    if wire_parts:
        coal = wire_parts[0] if len(wire_parts) == 1 \
            else jnp.concatenate(wire_parts)
        gathered = jax.lax.all_gather(coal, axis)
    # Decode + scatter pass, in rung order (the perms are disjoint).
    for S, perm, codec, payload in staged:
        if codec is None:
            b_agg, b_err = payload
        else:
            meta, o, nbytes, b_err = payload
            b_agg = codec.wire_decode_fold(
                gathered[:, o:o + nbytes], meta, omega, n=S * block,
                block=block, use_pallas=use_pallas,
                deterministic=n_pods >= 3, fixed_bits=fixed_bits)
        err = err.at[perm].set(b_err.reshape(S, block))
        if apply_fn is None:
            agg = agg.at[perm].set(b_agg.reshape(S, block))
        else:
            rows = apply_fn(b_agg.reshape(S, block),
                            tuple(ab[perm] for ab in abufs), scalars)
            abufs = [ab.at[perm].set(nr)
                     for ab, nr in zip(abufs, rows)]

    def unpack(flat_buf, like):
        outs, boff = [], 0
        for leaf in like:
            n = math.prod(leaf.shape)
            o = boff * block
            outs.append(flat_buf[o:o + n].reshape(leaf.shape)
                        .astype(leaf.dtype))
            boff += n_blocks(n, block)
        return tuple(outs)

    errs = unpack(err[:NB].reshape(-1), es)
    if apply_fn is None:
        return unpack(agg[:NB].reshape(-1), gs), errs
    outs = tuple(unpack(ab[:NB].reshape(-1), a)
                 for ab, a in zip(abufs, aux))
    return outs, errs


def _repack_sync_local(gs, es, perms, omega, omega_own, omega_intra, aux,
                       scalars, *, ep: ExecPlan, gamma, n_pods, n_cross,
                       n_edge, use_pallas, fixed_bits, apply_fn=None):
    """Fully local per-device sync of the whole tree through the plan's
    gather/scatter repacking.

    ``gs`` / ``es``: tuples of local shard arrays (grads and EF residuals)
    in leaf order.  They are packed into the static block layout, each
    rung's bucket is gathered through its permutation (device data — the
    only thing a replan changes), pushed through the codec's fused EF +
    compress + exchange round (ring-chunked where the plan says so),
    and scattered back.  Pad blocks gather the zero row at index NB and
    scatter into it, so they never touch real data.

    Rung-ordered apply: with ``apply_fn`` set, ``aux`` is a tuple of
    leaf-tuples (e.g. params / m / v) packed into the same block layout,
    and ``apply_fn(agg_rows, aux_rows, scalars)`` (all ``(S, block)``
    f32) consumes each rung's aggregate AS SOON AS that rung's exchange
    lands — the optimizer math for rung r carries no data dependence on
    rung r+1's collective, so XLA overlaps the apply with the next rung's
    DCN transfer instead of barriering on the whole tree.  Returns
    ``(aux_out_tuples, errs)`` instead of ``(aggs, errs)``.

    Backward-interleaved streaming: a segmented plan
    (``ep.segmented`` — see ``planexec.build_exec_plan(segments > 1)``)
    runs one :func:`_range_sync` per leaf segment, walked in REVERSE leaf
    order (backward produces the deep leaves' gradients first).  Each
    segment packs its OWN buffers from only its leaves, so a segment's
    encode+collective is issued by XLA's scheduler as soon as that leaf
    range's gradients materialise in the backward pass — the exchange of
    the deep half hides behind the backward (and the apply) of the
    shallow half.  Blockwise codec math makes the piece split exact:
    segmented == barriered bit-identical (tests/test_multipod.py soaks
    this on the P = 2 and P = 3 meshes)."""
    kw = dict(levels=ep.levels, block=ep.block, omega=omega,
              omega_own=omega_own, omega_intra=omega_intra,
              scalars=scalars, bidir=ep.bidir, gamma=gamma,
              n_pods=n_pods, n_cross=n_cross, n_edge=n_edge,
              use_pallas=use_pallas, fixed_bits=fixed_bits,
              apply_fn=apply_fn)
    if not ep.segmented:
        return _range_sync(gs, es, aux, perms, ep.sig, ep.chunks,
                           ep.hier, ep.total_blocks, **kw)
    S = len(ep.seg_sig)
    outs: list = [None] * S
    errs: list = [None] * S
    for s in reversed(range(S)):
        lo, hi = ep.seg_leaves[s], ep.seg_leaves[s + 1]
        outs[s], errs[s] = _range_sync(
            gs[lo:hi], es[lo:hi], tuple(a[lo:hi] for a in aux),
            perms[s], ep.seg_sig[s], ep.seg_chunks[s], ep.seg_hier[s],
            ep.seg_nb[s], **kw)
    err_leaves = tuple(e for seg in errs for e in seg)
    if apply_fn is None:
        return tuple(g for seg in outs for g in seg), err_leaves
    # per-aux leaf tuples reassembled across segments, leaf order
    n_aux = len(aux)
    aux_outs = tuple(tuple(o for seg in outs for o in seg[a])
                     for a in range(n_aux))
    return aux_outs, err_leaves


# ---------------------------------------------------------------------------
# tree-level API
# ---------------------------------------------------------------------------


def _auto_axes(mesh):
    return tuple(a for a in mesh.axis_names
                 if a not in (POD_AXIS, EDGE_AXIS))


def sync_tree(tree, errors, plan: Union[SyncPlan, ExecPlan], *, mesh,
              shardings, gamma: float, block: int = C.BLOCK,
              inside_manual: bool = None, use_pallas: bool = None,
              ring: Optional[int] = None, bidir: bool = True,
              fixed_bits: int = FIXED_POINT_BITS, apply_fn=None,
              apply_aux=(), apply_scalars=()):
    """Compress + hierarchically aggregate a gradient (or delta) pytree.

    Must be called inside the outer per-pod shard_map when the mesh has a
    pod axis.  ``shardings``: pytree of PartitionSpec matching ``tree`` (the
    data/model sharding of each leaf).  Returns (agg_tree, new_errors).

    ``plan`` may be an :class:`~repro.core.planexec.ExecPlan` — the
    retrace-free form whose gather perms and omega are traced device data
    (the trainer's hot path) — or a host :class:`SyncPlan`, which is
    lowered at trace time with exact (unpadded) bucket sizes, perms baked
    as constants.  Both run the same static-shape exchange: per rung with
    a non-empty bucket either ONE pod collective (the one-shot path) or
    the plan's K-chunk ``ppermute`` ring (big DCN-bound buckets; same
    bytes on the wire — tests/test_collectives.py counts both in the
    lowered HLO).  ``ring`` / ``bidir`` tune the chunk heuristic and the
    ring direction for the SyncPlan lowering path (None = roofline auto,
    0 = force one-shot, K = force K chunks; ExecPlans already carry
    their chunk grid and direction).  ``fixed_bits`` sets the
    deterministic fixed-point accumulation width used whenever >= 3 pods
    exchange (``ACESyncConfig.accum_bits``).

    Rung-ordered apply: with ``apply_fn`` given, ``apply_aux`` is a tuple
    of pytrees shaped like ``tree`` (e.g. params / m / v) and the sync
    consumes each rung's aggregate in place of returning it —
    ``apply_fn(agg_rows, aux_rows, apply_scalars)`` maps the rung bucket's
    ``(S, block)`` f32 rows to updated aux rows, and the return value is
    ``(tuple_of_new_aux_trees, new_errors)``.  This is how the trainer
    overlaps the optimizer with the exchange: rung r's update depends
    only on rung r's collective, not on a whole-tree barrier.

    ``inside_manual``: whether we are already inside a shard_map (then the
    nested shard_map must infer the context mesh); default: pod axis
    present.  ``use_pallas``: route the EF + compress inner loop through
    the fused Pallas kernels; default
    :func:`repro.kernels.ops.default_use_pallas` (kernels on accelerators,
    pure-jnp oracles on CPU, ``REPRO_FORCE_INTERPRET=1`` to force the
    kernel path under the interpreter).
    """
    if inside_manual is None:
        inside_manual = mesh is not None and POD_AXIS in mesh.axis_names
    if use_pallas is None:
        use_pallas = ops.default_use_pallas()
    n_pods = _pod_info(mesh)
    n_cross, n_edge = _tier_info(mesh)

    leaves, treedef = jax.tree_util.tree_flatten(tree)
    e_leaves = treedef.flatten_up_to(errors)
    s_leaves = treedef.flatten_up_to(shardings) if shardings is not None \
        else [None] * len(leaves)
    nested = _uses_nested(mesh, inside_manual)

    if isinstance(plan, SyncPlan):
        assert len(leaves) == len(plan.level_idx), \
            (len(leaves), len(plan.level_idx))
        if nested:
            lsz = [math.prod(_local_shape(l.shape, s, mesh))
                   for l, s in zip(leaves, s_leaves)]
        else:
            lsz = [math.prod(l.shape) for l in leaves]
        ep = build_exec_plan(plan, lsz, block=block, growth=None,
                             n_pods=n_pods, ring=ring, bidir=bidir,
                             n_edge=n_edge)
    else:
        ep = plan

    omega = ep.omega
    if n_pods == 1 and omega.shape[0] == 1:
        omega = jnp.ones((1,), jnp.float32)  # single pod: identity weight
    # own device's aggregation weight and its cluster's (E,) omega slice,
    # computed at the per-pod level (axis_index may not re-bind "pod"/
    # "edge" inside the nested fully-manual shard_map).  Fleet indexing is
    # pod-major — slot = pod * n_edge + edge — matching the tuple-axis
    # all_gather order flat rungs fold in.
    if n_edge > 1:
        pod_i = jax.lax.axis_index(POD_AXIS)
        fleet_i = pod_i * n_edge + jax.lax.axis_index(EDGE_AXIS)
        omega_own = omega[fleet_i]
        omega_intra = omega.reshape(n_cross, n_edge)[pod_i]
    elif n_pods > 1:
        omega_own = omega[jax.lax.axis_index(POD_AXIS)]
        omega_intra = omega[:1]          # no fast tier: unused
    else:
        omega_own = omega[0]
        omega_intra = omega[:1]

    fn = functools.partial(_repack_sync_local, ep=ep, gamma=gamma,
                           n_pods=n_pods, n_cross=n_cross, n_edge=n_edge,
                           use_pallas=use_pallas, fixed_bits=fixed_bits,
                           apply_fn=apply_fn)
    gs, es = tuple(leaves), tuple(e_leaves)
    aux = tuple(tuple(treedef.flatten_up_to(a)) for a in apply_aux)
    scalars = tuple(apply_scalars)
    if nested:
        aspecs = []
        for s in s_leaves:
            aspec = norm_spec(s if s is not None else P(), mesh)
            # drop the pod/edge axes from specs (manual outside already)
            aspecs.append(P(*[None if ax in (POD_AXIS, EDGE_AXIS) else ax
                              for ax in aspec]))
        aspecs = tuple(aspecs)
        # mirror the perm structure (flat per-rung, or nested per-segment
        # for backward-streaming plans): every perm rides replicated
        pspecs = jax.tree.map(lambda _: P(None), ep.perms)
        aux_specs = tuple(aspecs for _ in aux)
        scalar_specs = tuple(P() for _ in scalars)
        out_main = (tuple(aspecs for _ in aux) if apply_fn is not None
                    else aspecs)
        inner = compat.shard_map(
            fn, mesh,
            in_specs=(aspecs, aspecs, pspecs, P(None), P(), P(None),
                      aux_specs, scalar_specs),
            out_specs=(out_main, aspecs),
            manual_axes=set(_auto_axes(mesh)),
            # surrounding per-pod shard_map (if any) provides the mesh
            infer_mesh=inside_manual)
        aggs, news = inner(gs, es, ep.perms, omega, omega_own,
                           omega_intra, aux, scalars)
    else:
        # no mesh, or old-jax fully-manual region (leaves replicated
        # over data/model there): device-local math, pod collectives
        # still bound by the enclosing manual region
        aggs, news = fn(gs, es, ep.perms, omega, omega_own, omega_intra,
                        aux, scalars)
    news_tree = jax.tree_util.tree_unflatten(treedef, list(news))
    if apply_fn is not None:
        out_trees = tuple(jax.tree_util.tree_unflatten(treedef, list(a))
                          for a in aggs)
        return out_trees, news_tree
    return jax.tree_util.tree_unflatten(treedef, list(aggs)), news_tree


def grad_group_stats(tree):
    """Per-group scalars feeding the importance estimator: (mean|g|, var,
    norm) each (G,).

    One fused pass per leaf: the three reductions (sum|g|, sum g^2, sum g)
    share a single read of the leaf and XLA fuses them into one HBM
    traversal; the derived statistics come from the stacked (G, 3) table
    in one vectorised epilogue.  This runs every grad step — the old
    per-leaf mean/var/norm chain launched three independent reductions per
    leaf."""
    leaves = jax.tree_util.tree_leaves(tree)
    rows, ns = [], []
    for g in leaves:
        g32 = g.astype(jnp.float32).reshape(-1)
        rows.append(jnp.stack([jnp.sum(jnp.abs(g32)),
                               jnp.sum(g32 * g32),
                               jnp.sum(g32)]))
        ns.append(max(g32.shape[0], 1))
    table = jnp.stack(rows)                       # (G, 3), stacked once
    n = jnp.asarray(ns, jnp.float32)
    mean_abs = table[:, 0] / n
    mean = table[:, 2] / n
    var = jnp.maximum(table[:, 1] / n - mean * mean, 0.0)
    nrm = jnp.sqrt(table[:, 1])
    return mean_abs, var, nrm


def wire_bytes_of_plan(plan: SyncPlan, sizes: Sequence[int],
                       n_pods: int, block: int = C.BLOCK) -> int:
    """Analytic on-the-wire bytes per device per sync for a plan, priced
    exactly the way :func:`sync_tree` transmits it (block-aligned leaves
    repacked into one per-rung buffer and one collective, per-leaf block
    padding included) — the number Table 1 reports and
    tests/test_collectives.py pins to the traced HLO."""
    return plan_wire_bytes(plan, sizes, n_pods, block)
