"""Plan-as-data: the executable, retrace-free form of a SyncPlan.

The scheduler's :class:`~repro.core.scheduler.SyncPlan` is a host-side
policy object (one ladder-rung index per parameter group).  Baking it into
the jitted train step as a static argument meant every replan risked a
fresh XLA compile — up to L^G variants for G groups over an L-rung ladder.
:class:`ExecPlan` is the same plan lowered to *data*:

  * every parameter group is laid out block-aligned in one static flat
    (NB, block) buffer (``leaf_layout``), computed once per (model, mesh);
  * per rung, a gather permutation ``perm_r: int32[S_r]`` of block indices
    repacks the member groups into one contiguous per-rung buffer.  The
    perms are ordinary device arrays — replans swap them without
    retracing;
  * only the tuple of padded per-rung block counts — the **bucket-shape
    signature** — plus the per-rung **chunk grid** of the ring exchange is
    static.  Rung sizes are rounded up to a small geometric ladder of size
    classes (:func:`pad_block_class`; the growth is scheduled per rung by
    :func:`rung_growth` — big rungs take finer classes, tiny rungs coarser
    ones), so assignments that shuffle groups between rungs without
    crossing a class boundary hit the warm jit cache.  The padding is real
    zeros on the wire and is priced explicitly by
    ``repro.codecs.plan_wire_bytes``.

The jit cache is therefore keyed on ``(levels, sig, chunks, block)`` — a
handful of variants per run — instead of the full per-group assignment.

Chunk grid (the ring exchange)
------------------------------
Rungs whose bucket is big enough to be DCN-bound run a chunked,
double-buffered ring pipeline (``Codec.ef_sync_ring``): the bucket is
split into K chunks exchanged with ``jax.lax.ppermute`` so the transfer
of chunk *i* hides the decode-accumulate of chunk *i-1*.
:func:`ring_chunk_count` picks K per rung from the roofline constants in
``repro.launch.mesh`` (DCN 6.25 GB/s vs HBM 819 GB/s); K is rounded to a
power-of-two class and the padded rung size to a K multiple, so the grid
is a deterministic function of the (already class-rounded) signature —
replans that keep the signature keep the chunk grid, and the step stays
retrace-free.  ``chunks[r] == 0`` means the one-shot ``all_gather`` path
(small buckets, psum codecs, single pod).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, replace
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compression import BLOCK, Level
from repro.launch.mesh import DCN_BW, HBM_BW, ICI_BW

#: default geometric growth of the padded-size ladder.  2.0 gives pure
#: power-of-two classes (fewest signatures, up to 2x wire padding); the
#: default 1.125 bounds the padding overhead at 12.5% while still
#: absorbing replan-to-replan bucket jitter.  Smaller growth -> less
#: padding on the wire but more distinct bucket signatures (more
#: compiles); 1.0 disables padding entirely (exact sizes — right for
#: strategies whose plan never changes).  Tunable per run via
#: ``ACESyncConfig.bucket_pad_growth``; the *effective* growth is
#: scheduled per rung by :func:`rung_growth`.
PAD_GROWTH = 1.125

#: per-hop launch overhead of a pod-axis ``ppermute`` (DCN round-trip
#: setup; WAN-ish link per the paper's regime).  The ring pipeline pays
#: K*(P-1) of these to hide the decode, so rungs whose total DCN time is
#: not >> this latency stay on the one-shot path.
RING_HOP_LATENCY_S = 10e-6

#: never split a rung into more chunks than this: each extra chunk adds a
#: ppermute launch and shrinks the per-transfer payload toward the
#: latency floor.
RING_MAX_CHUNKS = 16

#: target per-chunk DCN transfer time.  Big enough to amortise
#: RING_HOP_LATENCY_S (~50x), small enough that the first chunk lands
#: quickly and the decode pipeline fills.
RING_TARGET_CHUNK_S = 500e-6


def n_blocks(n: int, block: int = BLOCK) -> int:
    return (int(n) + block - 1) // block


def pad_block_class(nb: int, growth: float = PAD_GROWTH) -> int:
    """Smallest size class >= ``nb`` blocks on a geometric ladder
    (1, 2, 4, 8, ... at the default growth of 2).  0 stays 0: an unused
    rung is absent from the trace entirely."""
    if nb <= 0:
        return 0
    if not growth or growth <= 1.0:
        return int(nb)
    c = 1
    while c < nb:
        c = max(c + 1, int(math.ceil(c * growth)))
    return c


#: floor of the scheduled pad growth: huge rungs never pad more than
#: ~3.1% — but also never get finer classes than this, so replan-to-
#: replan jitter still lands in class (collapsing to near-exact sizes
#: would reintroduce the per-replan retraces the class ladder exists to
#: prevent).
MIN_RUNG_GROWTH = 1.03125

#: rung size (blocks) where the growth schedule starts decaying from the
#: base.  Below this the padding is a few KB — not worth narrower (=
#: jitter-fragile) classes; above it the decay keeps the ABSOLUTE class
#: width at (base-1)*32 blocks (~4 at the 1.125 default) until the
#: MIN_RUNG_GROWTH floor takes over and the width grows as ~3.1% of nb.
RUNG_GROWTH_KNEE = 32


def rung_growth(nb: int, base: Optional[float]) -> Optional[float]:
    """Per-rung pad-growth schedule (ROADMAP knob).

    The flat default charged every rung the same relative padding; but
    the overhead that matters is byte-weighted, so big rungs want *finer*
    classes (12.5% of a multi-MB bucket is real DCN time) while tiny
    rungs want *coarser* ones (their absolute padding is a few KB and
    fewer classes means fewer compiled variants).  Scheduled, monotone in
    nb, and careful to keep classes wide enough in ABSOLUTE blocks that
    steady-state replan jitter never crosses a class boundary:

      * nb <= 4 blocks: power-of-two classes (at most 1-2 pad blocks);
      * nb <= RUNG_GROWTH_KNEE: the configured base growth (full flat
        absorption; padding bytes are negligible here);
      * larger: the excess over 1.0 decays as KNEE/nb — constant
        ~(base-1)*KNEE-block class width — floored at
        :data:`MIN_RUNG_GROWTH`, so a 4096-block rung pads <= ~3.1%
        while its classes stay >= ~128 blocks wide.

    ``BENCH_step_time.json`` records the resulting classes and the
    byte-weighted ``padding_overhead_frac`` per run.
    """
    if not base or base <= 1.0:
        return base
    if nb <= 4:
        return max(base, 2.0)
    if nb <= RUNG_GROWTH_KNEE:
        return base
    # floored at MIN_RUNG_GROWTH (or at base itself when the user asked
    # for something even finer than the floor)
    return max(1.0 + (base - 1.0) * (RUNG_GROWTH_KNEE / nb),
               min(base, MIN_RUNG_GROWTH))


def scheduled_block_class(nb: int, base: Optional[float]) -> int:
    """Smallest size class >= ``nb`` on the SINGLE scheduled ladder.

    Unlike evaluating :func:`pad_block_class` with a per-``nb`` growth
    (which would give every queried size its own ladder — a class "map"
    that is neither monotone nor a partition, so two replans one block
    apart could each be their own class and retrace), the ladder here is
    built once with the step growth evaluated at the LADDER VALUE:
    ``c -> max(c + 1, ceil(c * rung_growth(c, base)))``.  The resulting
    class function is a true monotone partition of the block counts —
    idempotent, with class widths that follow the schedule (coarse below
    the knee, ~(base-1)*KNEE blocks just above it, ~3.1% of the rung in
    the floor regime)."""
    if nb <= 0:
        return 0
    if not base or base <= 1.0:
        return int(nb)
    c = 1
    while c < nb:
        g = rung_growth(c, base)
        c = max(c + 1, int(math.ceil(c * g)))
    return c


def bucket_signature(level_idx: Sequence[int], sizes: Sequence[int],
                     n_levels: int, block: int = BLOCK,
                     growth: Optional[float] = None) -> Tuple[int, ...]:
    """Padded per-rung block counts — the static jit-cache key of the
    exchange.  ``growth=None`` gives exact (unpadded) bucket sizes; a
    float is the *base* growth of the scheduled class ladder
    (:func:`scheduled_block_class`)."""
    per = [0] * n_levels
    for li, n in zip(level_idx, sizes):
        per[int(li)] += n_blocks(n, block)
    if growth:
        per = [scheduled_block_class(nb, growth) for nb in per]
    return tuple(per)


def ring_hops(n_pods: int, bidir: bool = True) -> int:
    """Sequential hops on the ring's critical path: the bidirectional
    ring splits the P-1 receives over two independent half-rings (forward
    ⌈(P-1)/2⌉, backward ⌊(P-1)/2⌋), so full-duplex DCN links finish in
    ⌈(P-1)/2⌉ sequential hop times — up to 2x effective bandwidth at the
    same total ppermute count and wire bytes."""
    if n_pods <= 1:
        return 0
    return (n_pods // 2) if bidir else (n_pods - 1)


def ring_chunk_count(level: Level, nb: int, n_pods: int,
                     block: int = BLOCK,
                     ring: Optional[int] = None,
                     bidir: bool = True) -> int:
    """Chunk count K for one rung (0 = one-shot ``all_gather`` fallback).

    Roofline heuristic over the ``launch.mesh`` constants: the ring
    pipeline hides the per-chunk decode (HBM-bound, ~819 GB/s) behind the
    DCN transfer of the next chunk (6.25 GB/s — >100x slower per byte, so
    the decode always fits under the wire once the bucket is big enough),
    at the cost of K*(P-1) ppermute launches.  A rung rings when its
    per-hop DCN time dominates the hop latency; K targets
    ~RING_TARGET_CHUNK_S of wire time per chunk-hop, clamped to
    [2, RING_MAX_CHUNKS] and rounded to a power-of-two class so the grid
    — like the signature it derives from — is stable across replans.
    ``bidir`` shortens the critical path to :func:`ring_hops` sequential
    hops, which only moves the latency thresholds (per-hop wire time is
    P-independent).

    ``ring``: None = the heuristic; 0 (or negative) = force one-shot;
    K > 0 = force K chunks on every ring-capable rung (tests, benches).

    Cross-pod determinism: the ring is bit-deterministic on ANY pod
    count — P = 2 trivially (two-term sums commute), P >= 3 through the
    codecs' order-insensitive accumulation (fixed-point partial sums /
    integer vote counts, canonical-order buffering for top-k; see
    ``Codec.ef_sync_ring``), so the auto heuristic rings every mesh and
    forced rings share the same deterministic fold.
    """
    codec = level.codec
    if (n_pods <= 1 or nb <= 0
            or not getattr(codec, "supports_ring", False)):
        return 0
    if ring is not None:
        return 0 if ring <= 0 else min(int(ring), nb)
    payload = codec.payload_bytes(nb * block, block)
    hops = ring_hops(n_pods, bidir)
    hop_t = payload / DCN_BW             # per-hop wire time (full payload)
    # decode reads the payload + reads/writes the f32 accumulator per
    # received peer — all P-1 of them, whichever direction they arrive by
    decode_t = (payload + 8.0 * nb * block) * (n_pods - 1) / HBM_BW
    # not worth pipelining: the decode we could hide is smaller than the
    # launch overhead of even a 2-chunk ring
    if decode_t < 2 * hops * RING_HOP_LATENCY_S:
        return 0
    if hop_t < 8 * RING_HOP_LATENCY_S:
        return 0  # latency-bound already; chunking only adds hops
    k = int(round(hop_t / RING_TARGET_CHUNK_S))
    k = max(2, min(RING_MAX_CHUNKS, nb, k))
    k = 1 << (k - 1).bit_length()        # power-of-two chunk class
    return min(k, RING_MAX_CHUNKS, nb)


def ring_override(ring_chunks: int) -> Optional[int]:
    """Translate ``ACESyncConfig.ring_chunks`` (0 = auto, -1 = never,
    K = force K) into the ``ring`` argument of :func:`ring_chunk_count` /
    :func:`exec_grid` / ``sync_tree`` (None = auto, <= 0 = force
    one-shot, K = force K).  The ONE place the two sentinel conventions
    meet — pass config values through here, never raw."""
    return None if ring_chunks == 0 else int(ring_chunks)


# ---------------------------------------------------------------------------
# two-tier (hierarchical) exchange: per-rung tier choice
# ---------------------------------------------------------------------------

#: hier grid entries: 0 = flat (single-tier) exchange; 1 = two-tier with a
#: full-precision (bf16 psum) intra-cluster stage; 2 = two-tier with an
#: INT8 gather+fold intra-cluster stage.
INTRA_FULL = 1
INTRA_INT8 = 2


def hier_override(hier_mode_cfg: int) -> Optional[int]:
    """Translate ``ACESyncConfig.hier_mode`` (0 = roofline auto, -1 =
    never two-tier, 1/2 = force full/INT8 intra stage) into the ``hier``
    argument of :func:`hier_rung_mode` / :func:`exec_grid` (None = auto,
    <= 0 = force flat, 1/2 = force)."""
    return None if hier_mode_cfg == 0 else int(hier_mode_cfg)


def hier_rung_mode(level: Level, nb: int, n_cross: int, n_edge: int,
                   block: int = BLOCK, hier: Optional[int] = None) -> int:
    """Tier choice for one rung on a (n_cross clusters) x (n_edge members)
    fleet: 0 = flat, :data:`INTRA_FULL` / :data:`INTRA_INT8` = two-tier.

    A hier-capable rung (``codec.supports_hier`` — dense formats whose
    cluster aggregate re-encodes losslessly enough without a second error-
    feedback stage) ALWAYS goes two-tier on a hierarchical fleet: its
    cross-tier volume drops from (C*E - 1) to (C - 1) payloads per device
    regardless of rung size.  The roofline only picks the INTRA stage —
    full-precision (bf16 psum on the fast links, lossless tier-1) while
    its ICI time hides under the DCN transfer of the cross tier, INT8
    gather+fold once the edge group is wide enough that a dense bf16
    intra stage would dominate the wall clock.  Like the ring chunk grid,
    the choice is a deterministic function of (signature, mesh constants)
    — replans that keep the signature keep the tier grid, and the step
    stays retrace-free.

    ``hier``: None = the heuristic; <= 0 = force flat; 1/2 = force the
    full/INT8 intra stage on every hier-capable rung (tests, benches).
    """
    codec = level.codec
    if (n_edge <= 1 or n_cross <= 1 or nb <= 0
            or not getattr(codec, "supports_hier", False)):
        return 0
    if hier is not None:
        if hier <= 0:
            return 0
        return INTRA_INT8 if hier >= 2 else INTRA_FULL
    from repro.codecs import build_codec
    n = nb * block
    cross_t = (n_cross - 1) * codec.payload_bytes(n, block) / DCN_BW
    intra_full_t = build_codec("full").wire_bytes(n, n_edge, block) / ICI_BW
    return INTRA_FULL if intra_full_t <= cross_t else INTRA_INT8


def exec_grid(level_idx: Sequence[int], sizes: Sequence[int],
              levels: Sequence[Level], n_pods: int, block: int = BLOCK,
              growth: Optional[float] = None,
              ring: Optional[int] = None, bidir: bool = True,
              n_edge: int = 1, hier: Optional[int] = None
              ) -> Tuple[Tuple[int, ...], Tuple[int, ...],
                         Tuple[int, ...]]:
    """(sig, chunks, hier) of the executed exchange: the class-padded
    signature with each ringing rung rounded up to a chunk multiple, plus
    the per-rung tier grid (:func:`hier_rung_mode`).  The ONE place the
    executed static shape is decided — the Scheduler's plan pricing and
    ``build_exec_plan`` both call it, so analytic bytes match the traced
    collectives, chunk padding and tier split included.

    ``n_pods`` is the FLEET size (clusters x edge members); ``n_edge`` > 1
    makes it a hierarchical fleet of ``n_pods // n_edge`` clusters.  Two-
    tier rungs ring over the CROSS axis (cluster count); flat rungs on a
    hierarchical fleet gather over the combined (pod, edge) axis in one
    shot — ``ppermute`` cannot span a tuple axis, so they never ring."""
    sig = list(bucket_signature(level_idx, sizes, len(levels), block,
                                growth))
    n_edge = max(int(n_edge), 1)
    n_cross = max(n_pods // n_edge, 1)
    chunks, hgrid = [], []
    for r, nb in enumerate(sig):
        h = hier_rung_mode(levels[r], nb, n_cross, n_edge, block, hier)
        if h:
            k = ring_chunk_count(levels[r], nb, n_cross, block, ring,
                                 bidir)
        elif n_edge > 1:
            k = 0
        else:
            k = ring_chunk_count(levels[r], nb, n_pods, block, ring,
                                 bidir)
        if k > 1 and nb % k:
            sig[r] = nb = ((nb + k - 1) // k) * k
        chunks.append(k)
        hgrid.append(h)
    return tuple(sig), tuple(chunks), tuple(hgrid)


def sig_wire_bytes(sig: Sequence[int], levels: Sequence[Level],
                   n_pods: int, block: int = BLOCK,
                   hier: Optional[Sequence[int]] = None,
                   n_cross: Optional[int] = None) -> int:
    """Per-device wire bytes of an executed exchange with bucket signature
    ``sig`` over the bandwidth-constrained (cross) tier — what the slow-
    tier collectives actually move, padding included.  The ring path moves
    exactly the all_gather receive volume (K chunks x (P-1) hops x chunk
    payload), so chunking never changes the per-rung pricing — only the
    chunk-multiple rounding in :func:`exec_grid` (already folded into
    ``sig``) does.  With a ``hier`` tier grid, two-tier rungs cross the
    slow tier once per CLUSTER (``n_cross`` peers) instead of once per
    fleet member — the headline wire-byte cut of the hierarchy."""
    total = 0
    for r, S in enumerate(sig):
        if not S:
            continue
        pods = n_pods
        if hier and r < len(hier) and hier[r] and n_cross:
            pods = n_cross
        total += levels[r].wire_bytes(S * block, pods, block)
    return int(total)


def sig_intra_bytes(sig: Sequence[int], levels: Sequence[Level],
                    n_edge: int, block: int = BLOCK,
                    hier: Optional[Sequence[int]] = None) -> int:
    """Fast-tier (intra-cluster) per-device wire bytes of a hierarchical
    exchange: the tier-1 aggregation volume of each two-tier rung, priced
    by the intra codec the tier grid selected (bf16 psum or INT8 gather).
    Flat rungs move nothing on the fast tier (their single collective is
    priced by :func:`sig_wire_bytes` at the fleet count)."""
    if not hier or n_edge <= 1:
        return 0
    from repro.codecs import build_codec
    total = 0
    for r, S in enumerate(sig):
        if not S or not (r < len(hier) and hier[r]):
            continue
        name = "full" if hier[r] == INTRA_FULL else "int8"
        total += build_codec(name).wire_bytes(S * block, n_edge, block)
    return int(total)


# ---------------------------------------------------------------------------
# leaf layout: computed once per (model, mesh), threaded through replans
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class LeafLayout:
    """Where each leaf lands in the static flat (NB, block) buffer.

    Depends only on (leaf local sizes, block) — never on the plan — so the
    Trainer builds it ONCE at construction and every replan's
    :func:`build_exec_plan` reuses it instead of re-deriving block counts
    and start offsets from the full pytree (the host-side replan overhead
    the PR-4 satellite removes)."""
    sizes: Tuple[int, ...]
    block: int
    nbs: Tuple[int, ...]
    starts: Tuple[int, ...]          # block offset of each leaf
    total_blocks: int


def leaf_layout(sizes: Sequence[int], block: int = BLOCK) -> LeafLayout:
    nbs = tuple(n_blocks(n, block) for n in sizes)
    starts, off = [], 0
    for nb in nbs:
        starts.append(off)
        off += nb
    return LeafLayout(sizes=tuple(int(n) for n in sizes), block=block,
                      nbs=nbs, starts=tuple(starts), total_blocks=off)


# ---------------------------------------------------------------------------
# backward segmentation: the static per-rung layer -> segment schedule
# ---------------------------------------------------------------------------


def config_segments(cfg) -> int:
    """Backward segment count a config asks for: 1 (barriered) unless
    ``overlap_backward`` is on, else ``backward_segments`` (0 = defer to
    :func:`auto_segments` once the layout is known).  The single source of
    truth shared by the Trainer (lowering) and the Scheduler (plan
    signatures) — they must agree or replans would mispredict the
    compiled-step cache key."""
    if not getattr(cfg, "overlap_backward", False):
        return 1
    return int(getattr(cfg, "backward_segments", 0))


def auto_segments(layout: LeafLayout) -> int:
    """Default backward segment count (``backward_segments = 0``): two
    segments on any multi-leaf model.  Two is the sweet spot on the
    roofline — the deep half's encode+collective issues while the shallow
    half's backward still runs (most of the latency win of finer splits),
    while per-piece class padding and collective launch overhead stay at
    one extra piece per rung."""
    return 2 if len(layout.sizes) > 1 else 1


def segment_leaf_bounds(layout: LeafLayout, segments: int
                        ) -> Tuple[int, ...]:
    """Leaf-index boundaries splitting the layout into ``segments``
    contiguous leaf ranges balanced by block count — the static backward
    schedule.  Depends only on the layout (never the plan), so every
    replan shares the same segmentation and the per-(segment, rung) piece
    sizes stay a function of the bucket signature alone (retrace-free).

    Returns ``segments + 1`` monotonically increasing bounds with
    ``bounds[0] == 0`` and ``bounds[-1] == n_leaves`` (fewer when there
    are not enough leaves to populate every segment).  Leaf order is tree
    order: backward produces the DEEP (late) leaves' gradients first, so
    the streaming path walks segments in reverse."""
    n = len(layout.sizes)
    segments = max(1, min(int(segments), max(n, 1)))
    if segments <= 1 or n <= 1:
        return (0, n)
    total = max(layout.total_blocks, 1)
    bounds = [0]
    cum = 0
    for i, nb in enumerate(layout.nbs):
        cum += nb
        # cut after leaf i once this segment holds its block-count share
        if (len(bounds) < segments
                and cum * segments >= len(bounds) * total
                and i + 1 < n):
            bounds.append(i + 1)
    bounds.append(n)
    return tuple(bounds)


def seg_grids(level_idx: Sequence[int], layout: LeafLayout,
              levels: Sequence[Level], n_pods: int,
              growth: Optional[float], ring: Optional[int], bidir: bool,
              n_edge: int = 1, hier: Optional[int] = None,
              segments: int = 0):
    """The static per-(segment, rung) executed grids of a backward-
    segmented plan: ``(bounds, seg_nb, seg_sig, seg_chunks, seg_hier)``.

    ``bounds`` of length 2 means the plan stays flat (single segment).
    Each segment's grid is :func:`exec_grid` over its own leaf range, so
    every piece is class-padded / chunk-gridded exactly like a flat rung
    and small replan jitter lands in class.  NOTE the per-segment grids
    depend on which rung each leaf is assigned to — the segmented
    signature (``seg_sig``), not the flat ``sig``, is the compiled-step
    identity of a segmented plan, and a replan that moves leaves across a
    segment boundary is a NEW signature (handled by the background
    warm-compile path, never a foreground retrace).  Shared by
    :func:`build_exec_plan` and ``Scheduler._finalize`` so the plan the
    scheduler prices and the plan the trainer lowers agree."""
    if segments == 0:
        segments = auto_segments(layout)
    bounds = segment_leaf_bounds(layout, segments)
    if len(bounds) <= 2:
        return bounds, (), (), (), ()
    nbs, starts = layout.nbs, layout.starts
    seg_nb, seg_sig, seg_chunks, seg_hier = [], [], [], []
    for s in range(len(bounds) - 1):
        lo, hi = bounds[s], bounds[s + 1]
        base = starts[lo]
        end = starts[hi - 1] + nbs[hi - 1] if hi > lo else base
        seg_nb.append(end - base)
        ssig, sch, shg = exec_grid(
            tuple(level_idx[lo:hi]), layout.sizes[lo:hi], levels,
            n_pods, layout.block, growth, ring, bidir, n_edge=n_edge,
            hier=hier)
        seg_sig.append(ssig)
        seg_chunks.append(sch)
        seg_hier.append(shg)
    return (bounds, tuple(seg_nb), tuple(seg_sig), tuple(seg_chunks),
            tuple(seg_hier))


@dataclass(frozen=True)
class ExecPlan:
    """A SyncPlan lowered to device data + a static bucket signature.

    Registered as a pytree: ``perms`` and ``omega`` are children (traced,
    swapped per replan), everything else is aux data (hashed into the jit
    cache key).  ``total_blocks`` is the NB of the *local* leaf layout the
    perms index into (one zero pad block lives at index NB).  ``chunks``
    is the static per-rung chunk grid of the ring exchange (0 = one-shot;
    see :func:`ring_chunk_count`); ``bidir`` selects the bidirectional
    half-ring circulation for ringing rungs (static: it changes the
    lowered ppermute pattern).

    Backward-segmented plans (``build_exec_plan(segments > 1)``)
    additionally carry the static segment schedule: ``seg_leaves`` are
    the leaf-index bounds (:func:`segment_leaf_bounds`), ``seg_nb`` the
    per-segment block counts of the local layout, and ``seg_sig`` /
    ``seg_chunks`` / ``seg_hier`` the per-(segment, rung) executed grids
    — each piece class-padded exactly like a flat rung, so replan jitter
    still lands in class.  ``perms`` then nests per segment (leaf order;
    the streaming path walks them in reverse), each segment's perm
    indices LOCAL to its own (seg_nb + 1, block) buffer — the point of
    the whole scheme: a segment's gather depends only on that segment's
    leaves, so its encode+collective carries no data dependence on the
    rest of the backward pass."""
    levels: Tuple[Level, ...]
    sig: Tuple[int, ...]              # padded block count per rung
    block: int
    total_blocks: int
    perms: tuple                      # int32[S_r] per rung with sig[r] > 0
    omega: jax.Array                  # f32[n_fleet] aggregation weights
    chunks: Tuple[int, ...] = ()      # ring chunk count per rung
    bidir: bool = True                # both DCN directions at once
    hier: Tuple[int, ...] = ()        # per-rung tier grid (0/1/2)
    seg_leaves: Tuple[int, ...] = ()  # leaf-index bounds (segmented only)
    seg_nb: Tuple[int, ...] = ()      # blocks per segment (local layout)
    seg_sig: Tuple[Tuple[int, ...], ...] = ()
    seg_chunks: Tuple[Tuple[int, ...], ...] = ()
    seg_hier: Tuple[Tuple[int, ...], ...] = ()

    @property
    def segmented(self) -> bool:
        return len(self.seg_sig) > 1

    def static_key(self) -> tuple:
        return (self.levels, self.sig, self.chunks, self.bidir,
                self.hier, self.block, self.total_blocks,
                self.seg_leaves, self.seg_nb, self.seg_sig,
                self.seg_chunks, self.seg_hier)

    def with_omega(self, omega) -> "ExecPlan":
        return replace(self, omega=jnp.asarray(omega, jnp.float32))


jax.tree_util.register_pytree_node(
    ExecPlan,
    lambda ep: ((ep.perms, ep.omega),
                (ep.levels, ep.sig, ep.block, ep.total_blocks, ep.chunks,
                 ep.bidir, ep.hier, ep.seg_leaves, ep.seg_nb, ep.seg_sig,
                 ep.seg_chunks, ep.seg_hier)),
    lambda aux, ch: ExecPlan(levels=aux[0], sig=aux[1], block=aux[2],
                             total_blocks=aux[3], chunks=aux[4],
                             bidir=aux[5], hier=aux[6], seg_leaves=aux[7],
                             seg_nb=aux[8], seg_sig=aux[9],
                             seg_chunks=aux[10], seg_hier=aux[11],
                             perms=tuple(ch[0]), omega=ch[1]),
)


def _rung_perms(level_idx, nbs, starts, sig, base: int, pad: int,
                lo: int, hi: int, L: int) -> Tuple[jax.Array, ...]:
    """Gather perms for leaves [lo, hi): one int32[sig[r]] per rung with a
    non-empty bucket, indices relative to ``base`` (the range's first
    block), pad entries pointing at the zero row ``pad``."""
    member = [[] for _ in range(L)]
    for i in range(lo, hi):
        if nbs[i]:
            member[level_idx[i]].append(
                np.arange(starts[i] - base, starts[i] - base + nbs[i],
                          dtype=np.int32))
    perms = []
    for r in range(L):
        S = sig[r]
        if not S:
            continue
        idx = (np.concatenate(member[r]) if member[r]
               else np.zeros((0,), np.int32))
        # pad entries gather the zero block at index ``pad`` and scatter
        # back into it — they never touch real data
        p = np.full((S,), pad, np.int32)
        p[: idx.shape[0]] = idx
        perms.append(jnp.asarray(p))
    return tuple(perms)


def build_exec_plan(plan, sizes: Optional[Sequence[int]] = None, *,
                    block: int = BLOCK, growth: Optional[float] = None,
                    omega=None, n_pods: int = 1,
                    ring: Optional[int] = None, bidir: bool = True,
                    n_edge: int = 1, hier: Optional[int] = None,
                    layout: Optional[LeafLayout] = None,
                    segments: int = 1) -> ExecPlan:
    """Lower a :class:`SyncPlan` to an :class:`ExecPlan`.

    ``sizes`` are the per-group element counts of the layout the exchange
    actually runs on — the LOCAL shard sizes when the sync executes inside
    a data/model-manual region (see ``core.sync.local_group_sizes``) —
    or pass a prebuilt ``layout`` (:func:`leaf_layout`) to skip the
    per-replan recomputation.  ``growth``: padded-class ladder for
    adaptive plans (``None`` = exact sizes, right for plans that never
    change).  ``n_pods``/``ring`` feed the chunk-grid heuristic (a 1-pod
    build never rings).  The perms are numpy-built (O(total_blocks),
    trivial next to a train step) and uploaded once per distinct
    assignment.

    ``segments > 1`` builds the backward-interleaved plan: leaves split
    into contiguous ranges (:func:`segment_leaf_bounds`), each range
    packing its OWN block buffer with segment-local perms, so a
    segment's encode+exchange depends only on that range's gradients and
    issues while the rest of the backward still runs (``core/sync.py``
    streaming path).  Every per-(segment, rung) piece is class-padded and
    chunk/tier-gridded exactly like a flat rung (:func:`exec_grid` per
    segment), so the schedule stays a function of the bucket signature
    only — retrace-free across replans.  Blockwise codec math makes the
    piece split numerics-neutral: segmented == barriered bit-identical.
    """
    if layout is None:
        if sizes is None:
            raise ValueError("need sizes or a prebuilt layout")
        layout = leaf_layout(sizes, block)
    else:
        block = layout.block
    level_idx = tuple(int(i) for i in plan.level_idx)
    if len(level_idx) != len(layout.sizes):
        raise ValueError(f"plan has {len(level_idx)} groups, layout has "
                         f"{len(layout.sizes)}")
    L = len(plan.levels)
    nbs, starts = layout.nbs, layout.starts
    NB = layout.total_blocks
    sig, chunks, hgrid = exec_grid(level_idx, layout.sizes, plan.levels,
                                   n_pods, block, growth, ring, bidir,
                                   n_edge=n_edge, hier=hier)
    om = plan.omega if omega is None else omega
    kw = dict(levels=tuple(plan.levels), sig=sig, block=block,
              total_blocks=NB, chunks=chunks, bidir=bidir, hier=hgrid,
              omega=jnp.asarray(om, jnp.float32))
    bounds, seg_nb, seg_sig, seg_chunks, seg_hier = seg_grids(
        level_idx, layout, plan.levels, n_pods, growth, ring, bidir,
        n_edge=n_edge, hier=hier, segments=segments)
    if len(bounds) > 2:
        seg_perms = []
        for s in range(len(bounds) - 1):
            lo, hi = bounds[s], bounds[s + 1]
            base = starts[lo]
            seg_perms.append(_rung_perms(level_idx, nbs, starts,
                                         seg_sig[s], base, seg_nb[s],
                                         lo, hi, L))
        return ExecPlan(perms=tuple(seg_perms), seg_leaves=bounds,
                        seg_nb=seg_nb, seg_sig=seg_sig,
                        seg_chunks=seg_chunks, seg_hier=seg_hier, **kw)
    return ExecPlan(perms=_rung_perms(level_idx, nbs, starts, sig, 0, NB,
                                      0, len(level_idx), L), **kw)


def exec_wire_bytes(ep: ExecPlan, n_pods: int,
                    n_cross: Optional[int] = None) -> int:
    """Analytic per-device slow-tier wire bytes of the exchange ``ep``
    actually executes — per-(segment, rung) pieces for segmented plans,
    the flat rung grid otherwise.  This is what the traced collectives
    move, piece class padding included (the segmented counterpart of
    :func:`sig_wire_bytes` over ``SyncPlan.bucket_sig``)."""
    if ep.segmented:
        return sum(sig_wire_bytes(s, ep.levels, n_pods, ep.block, hier=h,
                                  n_cross=n_cross)
                   for s, h in zip(ep.seg_sig, ep.seg_hier))
    return sig_wire_bytes(ep.sig, ep.levels, n_pods, ep.block,
                          hier=ep.hier, n_cross=n_cross)


def exec_intra_bytes(ep: ExecPlan, n_edge: int) -> int:
    """Fast-tier counterpart of :func:`exec_wire_bytes` (zero for flat
    fleets)."""
    if ep.segmented:
        return sum(sig_intra_bytes(s, ep.levels, n_edge, ep.block, hier=h)
                   for s, h in zip(ep.seg_sig, ep.seg_hier))
    return sig_intra_bytes(ep.sig, ep.levels, n_edge, ep.block,
                           hier=ep.hier)
