"""Plan-as-data: the executable, retrace-free form of a SyncPlan.

The scheduler's :class:`~repro.core.scheduler.SyncPlan` is a host-side
policy object (one ladder-rung index per parameter group).  Baking it into
the jitted train step as a static argument meant every replan risked a
fresh XLA compile — up to L^G variants for G groups over an L-rung ladder.
:class:`ExecPlan` is the same plan lowered to *data*:

  * every parameter group is laid out block-aligned in one static flat
    (NB, block) buffer (``leaf_layout``), computed once per (model, mesh);
  * per rung, a gather permutation ``perm_r: int32[S_r]`` of block indices
    repacks the member groups into one contiguous per-rung buffer.  The
    perms are ordinary device arrays — replans swap them without
    retracing;
  * only the tuple of padded per-rung block counts — the **bucket-shape
    signature** — is static.  Rung sizes are rounded up to a small
    geometric ladder of size classes (:func:`pad_block_class`, power-of-
    two classes at the default growth of 2.0), so assignments that shuffle
    groups between rungs without crossing a class boundary hit the warm
    jit cache.  The padding is real zeros on the wire and is priced
    explicitly by ``repro.codecs.plan_wire_bytes``.

The jit cache is therefore keyed on ``(levels, sig, block)`` — a handful
of variants per run — instead of the full per-group assignment.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, replace
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compression import BLOCK, Level

#: default geometric growth of the padded-size ladder.  2.0 gives pure
#: power-of-two classes (fewest signatures, up to 2x wire padding); the
#: default 1.125 bounds the padding overhead at 12.5% while still
#: absorbing replan-to-replan bucket jitter.  Smaller growth -> less
#: padding on the wire but more distinct bucket signatures (more
#: compiles); 1.0 disables padding entirely (exact sizes — right for
#: strategies whose plan never changes).  Tunable per run via
#: ``ACESyncConfig.bucket_pad_growth``.
PAD_GROWTH = 1.125


def n_blocks(n: int, block: int = BLOCK) -> int:
    return (int(n) + block - 1) // block


def pad_block_class(nb: int, growth: float = PAD_GROWTH) -> int:
    """Smallest size class >= ``nb`` blocks on a geometric ladder
    (1, 2, 4, 8, ... at the default growth of 2).  0 stays 0: an unused
    rung is absent from the trace entirely."""
    if nb <= 0:
        return 0
    if not growth or growth <= 1.0:
        return int(nb)
    c = 1
    while c < nb:
        c = max(c + 1, int(math.ceil(c * growth)))
    return c


def bucket_signature(level_idx: Sequence[int], sizes: Sequence[int],
                     n_levels: int, block: int = BLOCK,
                     growth: Optional[float] = None) -> Tuple[int, ...]:
    """Padded per-rung block counts — the static jit-cache key of the
    exchange.  ``growth=None`` gives exact (unpadded) bucket sizes."""
    per = [0] * n_levels
    for li, n in zip(level_idx, sizes):
        per[int(li)] += n_blocks(n, block)
    if growth:
        per = [pad_block_class(nb, growth) for nb in per]
    return tuple(per)


def sig_wire_bytes(sig: Sequence[int], levels: Sequence[Level],
                   n_pods: int, block: int = BLOCK) -> int:
    """Per-device wire bytes of an executed exchange with bucket signature
    ``sig`` — what the collectives actually move, padding included."""
    return int(sum(levels[r].wire_bytes(S * block, n_pods, block)
                   for r, S in enumerate(sig) if S))


@dataclass(frozen=True)
class ExecPlan:
    """A SyncPlan lowered to device data + a static bucket signature.

    Registered as a pytree: ``perms`` and ``omega`` are children (traced,
    swapped per replan), everything else is aux data (hashed into the jit
    cache key).  ``total_blocks`` is the NB of the *local* leaf layout the
    perms index into (one zero pad block lives at index NB)."""
    levels: Tuple[Level, ...]
    sig: Tuple[int, ...]              # padded block count per rung
    block: int
    total_blocks: int
    perms: Tuple[jax.Array, ...]      # int32[S_r] per rung with sig[r] > 0
    omega: jax.Array                  # f32[n_pods] aggregation weights

    def static_key(self) -> tuple:
        return (self.levels, self.sig, self.block, self.total_blocks)

    def with_omega(self, omega) -> "ExecPlan":
        return replace(self, omega=jnp.asarray(omega, jnp.float32))


jax.tree_util.register_pytree_node(
    ExecPlan,
    lambda ep: ((ep.perms, ep.omega),
                (ep.levels, ep.sig, ep.block, ep.total_blocks)),
    lambda aux, ch: ExecPlan(levels=aux[0], sig=aux[1], block=aux[2],
                             total_blocks=aux[3], perms=tuple(ch[0]),
                             omega=ch[1]),
)


def build_exec_plan(plan, sizes: Sequence[int], *, block: int = BLOCK,
                    growth: Optional[float] = None,
                    omega=None) -> ExecPlan:
    """Lower a :class:`SyncPlan` to an :class:`ExecPlan`.

    ``sizes`` are the per-group element counts of the layout the exchange
    actually runs on — the LOCAL shard sizes when the sync executes inside
    a data/model-manual region (see ``core.sync.local_group_sizes``).
    ``growth``: padded-class ladder for adaptive plans (``None`` = exact
    sizes, right for plans that never change).  The perms are numpy-built
    (O(total_blocks), trivial next to a train step) and uploaded once per
    distinct assignment.
    """
    level_idx = tuple(int(i) for i in plan.level_idx)
    if len(level_idx) != len(sizes):
        raise ValueError(f"plan has {len(level_idx)} groups, layout has "
                         f"{len(sizes)}")
    L = len(plan.levels)
    nbs = [n_blocks(n, block) for n in sizes]
    starts = np.concatenate([[0], np.cumsum(nbs)]).astype(np.int64)
    NB = int(starts[-1])
    sig = bucket_signature(level_idx, sizes, L, block, growth)
    member = [[] for _ in range(L)]
    for i, li in enumerate(level_idx):
        if nbs[i]:
            member[li].append(np.arange(starts[i], starts[i] + nbs[i],
                                        dtype=np.int32))
    perms = []
    for r in range(L):
        S = sig[r]
        if not S:
            continue
        idx = (np.concatenate(member[r]) if member[r]
               else np.zeros((0,), np.int32))
        # pad entries gather the zero block at index NB and scatter back
        # into it — they never touch real data
        p = np.full((S,), NB, np.int32)
        p[: idx.shape[0]] = idx
        perms.append(jnp.asarray(p))
    om = plan.omega if omega is None else omega
    return ExecPlan(levels=tuple(plan.levels), sig=sig, block=block,
                    total_blocks=NB, perms=tuple(perms),
                    omega=jnp.asarray(om, jnp.float32))
