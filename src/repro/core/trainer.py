"""Distributed trainer: assembles model + optimizer + ACE-Sync into per-pod
train steps (shard_map manual over "pod"; "data"/"model" auto under XLA
SPMD).

Step kinds
----------
  grad_sync   loss/grad -> ACE-Sync compressed pod aggregation -> AdamW.
              The representative fused step (used by the dry-run).
  local       loss/grad -> AdamW, NO pod traffic (H>1 local steps; pods
              diverge on purpose — paper's edge-side accumulation).
  delta_sync  compress + aggregate (theta - anchor) across pods, reset the
              anchor (ACE-Sync local-update mode / FedAvg with EF).
  param_avg   plain omega-weighted parameter averaging (FedAvg baseline).

Strategies are first-class :class:`repro.strategies.SyncStrategy` objects
(paper Table 1's fullsync/topk/fedavg/acesync plus any registered
extension) — each one a (plan, step-kind schedule) policy over the same
machinery.  The trainer only executes step kinds; every strategy decision
(anchor state, plan construction, scheduling, H control) lives on the
strategy object resolved from the registry.

Plan-as-data: the compiled step takes the plan as an
:class:`~repro.core.planexec.ExecPlan` pytree argument — gather perms and
omega are device data, only the padded bucket signature is static — so it
is compiled once per (model, ladder, signature, kind) and steady-state
replans swap plan vectors through the warm jit cache with **zero**
retraces (tests/test_replan.py pins this).  Train state is donated
through every step (``donate_argnums``), so params / optimizer moments /
error-feedback buffers update in place instead of being copied each step.

State layout: every leaf carries a leading pod-replica dim (n_pods, ...)
sharded P("pod", ...), which is what lets pods hold *divergent* values
between syncs while remaining one SPMD program.
"""
from __future__ import annotations

import functools
import threading
from typing import Callable, Dict, Optional, Tuple, Union

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat
from repro.configs.base import RunConfig
from repro.core import acesync
from repro.core import planexec
from repro.core import sync as S
from repro.core import divergence as D
from repro.core.planexec import ExecPlan, build_exec_plan
from repro.core.scheduler import Scheduler, SyncPlan
from repro.models.shardctx import use_shard_ctx, sharding_for
from repro.optim import adamw
from repro.strategies import SyncStrategy, resolve_strategy

POD = S.POD_AXIS
EDGE = S.EDGE_AXIS


def _n_pods(mesh: Optional[Mesh]) -> int:
    """FLEET size: pod axis x the optional intra-cluster edge axis."""
    return S._pod_info(mesh)


def _n_edge(mesh: Optional[Mesh]) -> int:
    if mesh is None or EDGE not in mesh.axis_names:
        return 1
    return mesh.shape[EDGE]


def _pod_prefix(spec: P, rank: int, axes=POD) -> P:
    """P(axes, *spec) padded with None to the leaf rank — the fleet
    replica dim is sharded over ("pod", "edge") on hierarchical meshes
    (pod-major, matching the fleet slot indexing)."""
    rest = list(spec) + [None] * (rank - 1 - len(spec))
    return P(axes, *rest[: rank - 1])


def _array_spec(x):
    """ShapeDtypeStruct carrying the array's sharding — the ONE spec
    builder the AOT warm-up lowers against and the dry-run/plan specs
    reuse, so recorded call-time specs can never diverge from the warmed
    lowering."""
    return jax.ShapeDtypeStruct(x.shape, x.dtype,
                                sharding=getattr(x, "sharding", None))


class Trainer:
    #: max distinct assignments whose ExecPlan (device perm arrays) stays
    #: resident; beyond this the oldest is evicted and rebuilt on demand.
    _EXEC_CACHE_MAX = 8

    def __init__(self, model, run: RunConfig, mesh: Optional[Mesh] = None,
                 strategy: Union[str, SyncStrategy] = "acesync"):
        self.model = model
        self.run = run
        self.mesh = mesh
        self.strategy = resolve_strategy(strategy)
        self.strategy_name = self.strategy.name
        # n_pods is the FLEET size (pod x edge); a hierarchical mesh adds
        # the fast intra-cluster "edge" axis and hier-capable rungs sync
        # two-tier (intra aggregation + one payload per cluster crossing
        # the slow pod axis — see core/sync.py)
        self.n_pods = _n_pods(mesh)
        self.n_edge = _n_edge(mesh)
        self.fleet_axes = S.fleet_axes(mesh) or (POD,)
        self._fleet_dim = (self.fleet_axes if len(self.fleet_axes) > 1
                           else self.fleet_axes[0])
        self.param_specs = model.param_specs()
        self.param_shardings = model.param_shardings()
        self.metas = S.group_metas(self.param_specs)
        self.scheduler = Scheduler(run.acesync,
                                   [m.size for m in self.metas],
                                   self.n_pods, n_edge=self.n_edge)
        # per-group element counts of the layout the exchange runs on
        # (local shard sizes under the nested data/model-manual region),
        # and the block layout derived from them — both computed ONCE here
        # and threaded through every replan (TrainLoop / exec_plan) so a
        # replan poll never re-walks the param pytree
        self.local_sizes = S.local_group_sizes(
            self.param_specs, self.param_shardings, mesh)
        self.leaf_layout = planexec.leaf_layout(self.local_sizes,
                                                run.acesync.topk_block)
        self._step_cache: Dict = {}    # (levels, sig, block, kind) -> jit fn
        self._exec_cache: Dict = {}    # (levels, level_idx, adaptive) -> EP
        self._aot_cache: Dict = {}     # (static_key, kind) -> AOT Compiled
        self._arg_specs: Dict = {}     # kind -> (state_specs, batch_specs)
        # guards the build-and-evict sequences of the plan/AOT caches:
        # warm_compile runs them from a background thread while the
        # foreground step evicts the same dicts
        self._cache_lock = threading.Lock()
        #: AOT compilations performed by warm_compile (telemetry: the
        #: compiles the speculative replan warm-up moved off the
        #: foreground step; benchmarks record it)
        self.warm_compiles = 0

    # ------------------------------------------------------------------
    # state
    # ------------------------------------------------------------------
    def init_state(self, rng):
        params = self.model.init(rng)
        opt = adamw.init_opt_state(params)
        ace = acesync.init_state(rng, params, self.param_specs,
                                 self.run.acesync)
        state = {"params": params, "m": opt["m"], "v": opt["v"],
                 "step": jnp.zeros((), jnp.int32), "ace": ace}
        state.update(self.strategy.extra_state(params))
        # add the pod-replica leading dim
        return jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (self.n_pods,) + x.shape),
            state)

    def state_specs(self):
        """ShapeDtypeStruct pytree of the train state (dry-run)."""
        params = self.param_specs
        ace = acesync.state_specs(params, self.run.acesync)
        state = {"params": params, "m": params, "v": params,
                 "step": jax.ShapeDtypeStruct((), jnp.int32), "ace": ace}
        state.update(self.strategy.extra_state_specs(params))
        return jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((self.n_pods,) + s.shape, s.dtype),
            state)

    def state_shardings(self):
        """NamedSharding pytree matching :meth:`state_specs`."""
        mesh = self.mesh
        assert mesh is not None

        def leaf_spec(tmpl_spec, leaf):
            return sharding_for(mesh, _pod_prefix(tmpl_spec,
                                                  len(leaf.shape),
                                                  self._fleet_dim),
                                shape=leaf.shape)

        params_sh = jax.tree.map(
            lambda sp, l: leaf_spec(sp, l), self.param_shardings,
            jax.tree.map(lambda s: jax.ShapeDtypeStruct(
                (self.n_pods,) + s.shape, s.dtype), self.param_specs),
            is_leaf=lambda x: isinstance(x, P))
        specs = self.state_specs()

        def other(leaf):
            return sharding_for(mesh, _pod_prefix(P(), len(leaf.shape),
                                                  self._fleet_dim),
                                shape=leaf.shape)

        sh = {"params": params_sh, "m": params_sh, "v": params_sh,
              "step": jax.tree.map(other, specs["step"]),
              "ace": jax.tree.map(other, specs["ace"])}
        # error buffers follow the param sharding
        sh["ace"] = sh["ace"]._replace(errors=params_sh)
        # strategy extra state (e.g. the anchor) is param-like by contract
        for key in self.strategy.extra_state_specs(self.param_specs):
            sh[key] = params_sh
        return sh

    def batch_shardings(self, shape):
        mesh = self.mesh
        sp = self.model.input_shardings(shape)
        specs = self.model.input_specs(shape)
        return jax.tree.map(
            lambda s, spec: sharding_for(mesh, s, shape=spec.shape),
            sp, specs, is_leaf=lambda x: isinstance(x, P))

    # ------------------------------------------------------------------
    # the per-pod step bodies
    # ------------------------------------------------------------------
    def _split_pod(self, tree):
        return jax.tree.map(lambda x: x[0], tree)

    def _join_pod(self, tree):
        return jax.tree.map(lambda x: x[None], tree)

    def _pmean(self, x):
        return jax.lax.pmean(x, self.fleet_axes) if self.n_pods > 1 else x

    def _grad_step(self, params, batch):
        run = self.run

        def loss_fn(p):
            return self.model.loss(p, batch)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        if run.grad_clip > 0:
            grads, gnorm = adamw.clip_by_global_norm(grads, run.grad_clip)
        else:
            # grad_clip <= 0 disables clipping.  The global-norm scale
            # couples every grad leaf to the whole backward pass, which
            # serializes the backward-interleaved exchange: no segment's
            # collective can issue before the last backward op.  The norm
            # itself is still recorded (metrics only — outputs never gate
            # the rung collectives).
            gnorm = adamw.global_norm(grads)
        return loss, grads, gnorm

    def _optimize(self, params, grads, m, v, step):
        run = self.run
        lr = adamw.cosine_schedule(step, base_lr=run.lr,
                                   warmup=run.warmup_steps,
                                   total=run.total_steps)
        new_params, opt = adamw.adamw_update(
            params, grads, {"m": m, "v": v}, step, lr=lr,
            beta1=run.beta1, beta2=run.beta2, weight_decay=run.weight_decay)
        return new_params, opt

    def _body_grad_sync(self, state, batch, plan: ExecPlan):
        st = self._split_pod(state)
        loss, grads, gnorm = self._grad_step(st["params"], batch)
        run = self.run
        if run.acesync.overlap_apply:
            # rung-ordered apply: AdamW runs on each rung's bucket the
            # moment that rung's exchange lands (no data dependence on
            # the later rungs' collectives), so the optimizer FLOPs hide
            # behind the next rung's DCN transfer instead of waiting on a
            # whole-tree barrier after sync_tree.  Same elementwise math
            # as _optimize, on the exchange's (S, block) f32 rows.
            lr = adamw.cosine_schedule(st["step"], base_lr=run.lr,
                                       warmup=run.warmup_steps,
                                       total=run.total_steps)
            bc1, bc2 = adamw.bias_corrections(st["step"], run.beta1,
                                              run.beta2)

            def apply_rows(g_rows, aux_rows, scalars):
                p, m, v = aux_rows
                lr_s, bc1_s, bc2_s = scalars
                return adamw.update_rows(
                    p, g_rows, m, v, lr=lr_s, bc1=bc1_s, bc2=bc2_s,
                    beta1=run.beta1, beta2=run.beta2,
                    weight_decay=run.weight_decay)

            out, new_ace, metrics = acesync.sync_gradients(
                grads, st["ace"], plan, mesh=self.mesh,
                shardings=self.param_shardings, cfg=run.acesync,
                apply_fn=apply_rows,
                apply_aux=(st["params"], st["m"], st["v"]),
                apply_scalars=(lr, bc1, bc2))
            new_params, new_m, new_v = out
            new_st = dict(st, params=new_params, m=new_m, v=new_v,
                          step=st["step"] + 1, ace=new_ace)
        else:
            agg, new_ace, metrics = acesync.sync_gradients(
                grads, st["ace"], plan, mesh=self.mesh,
                shardings=self.param_shardings, cfg=run.acesync)
            new_params, opt = self._optimize(st["params"], agg, st["m"],
                                             st["v"], st["step"])
            new_st = dict(st, params=new_params, m=opt["m"], v=opt["v"],
                          step=st["step"] + 1, ace=new_ace)
        metrics = dict(metrics, loss=self._pmean(loss),
                       grad_norm=self._pmean(gnorm))
        return self._join_pod(new_st), metrics

    def _body_local(self, state, batch, plan: ExecPlan):
        st = self._split_pod(state)
        loss, grads, gnorm = self._grad_step(st["params"], batch)
        new_params, opt = self._optimize(st["params"], grads, st["m"],
                                         st["v"], st["step"])
        new_st = dict(st, params=new_params, m=opt["m"], v=opt["v"],
                      step=st["step"] + 1)
        metrics = {"loss": self._pmean(loss),
                   "grad_norm": self._pmean(gnorm)}
        return self._join_pod(new_st), metrics

    def _body_delta_sync(self, state, batch, plan: ExecPlan):
        """Compress/aggregate (theta - anchor); theta <- anchor + agg.

        With ``overlap_apply`` (default) the anchor update is rung-
        ordered the same way grad_sync's AdamW is: ``sync_tree``'s
        ``apply_fn`` path adds each rung's aggregated delta onto the
        anchor rows the moment that rung's exchange lands, so the anchor
        math of rung r hides behind rung r+1's DCN transfer instead of
        barriering on the whole tree."""
        st = self._split_pod(state)
        delta = jax.tree.map(lambda p, a: (p - a).astype(p.dtype),
                             st["params"], st["anchor"])
        div = D.pod_divergence(st["params"], self.mesh)
        if self.run.acesync.overlap_apply:
            def apply_anchor(d_rows, aux_rows, _scalars):
                (a_rows,) = aux_rows
                return (a_rows + d_rows,)

            out, new_ace, metrics = acesync.sync_gradients(
                delta, st["ace"], plan, mesh=self.mesh,
                shardings=self.param_shardings, cfg=self.run.acesync,
                apply_fn=apply_anchor, apply_aux=(st["anchor"],))
            (new_params,) = out
        else:
            agg, new_ace, metrics = acesync.sync_gradients(
                delta, st["ace"], plan, mesh=self.mesh,
                shardings=self.param_shardings, cfg=self.run.acesync)
            new_params = jax.tree.map(lambda a, d: (a + d).astype(a.dtype),
                                      st["anchor"], agg)
        new_ace = new_ace._replace(
            div_ema=0.9 * st["ace"].div_ema + 0.1 * self._pmean(div))
        new_st = dict(st, params=new_params,
                      anchor=jax.tree.map(jnp.copy, new_params),
                      ace=new_ace)
        metrics = dict(metrics, divergence=self._pmean(div))
        return self._join_pod(new_st), metrics

    def _body_param_avg(self, state, batch, plan: ExecPlan):
        """FedAvg baseline: omega-weighted plain parameter average."""
        st = self._split_pod(state)
        omega = plan.omega
        div = D.pod_divergence(st["params"], self.mesh)

        def avg(p):
            if self.n_pods > 1:
                idx = jax.lax.axis_index(POD)
                if self.n_edge > 1:
                    idx = idx * self.n_edge + jax.lax.axis_index(EDGE)
                return jax.lax.psum(
                    p.astype(jnp.float32) * omega[idx],
                    self.fleet_axes).astype(p.dtype)
            return p

        new_params = jax.tree.map(avg, st["params"])
        new_st = dict(st, params=new_params)
        if "anchor" in new_st:
            new_st["anchor"] = jax.tree.map(jnp.copy, new_params)
        return self._join_pod(new_st), {"divergence": self._pmean(div)}

    _BODIES = {"grad_sync": _body_grad_sync, "local": _body_local,
               "delta_sync": _body_delta_sync, "param_avg": _body_param_avg}

    # ------------------------------------------------------------------
    # plan-as-data compiled step factory
    # ------------------------------------------------------------------
    def exec_plan(self, plan: Union[SyncPlan, ExecPlan]) -> ExecPlan:
        """Lower a host SyncPlan to its executable plan-vector form.

        Cached per distinct assignment (the gather perms are a cheap
        numpy build + one tiny upload); omega is refreshed on every call —
        it is device data and never keys the cache.  Adaptive plans use
        the padded size-class ladder so successive replans keep the same
        bucket signature and therefore the same compiled step.
        """
        if isinstance(plan, ExecPlan):
            return plan
        key = (plan.levels, plan.level_idx, plan.adaptive)
        ep = self._exec_cache.get(key)
        if ep is None:
            cfg = self.run.acesync
            growth = self.scheduler.pad_growth if plan.adaptive else None
            # backward-interleaved streaming: segment the exchange so each
            # piece's encode+collective issues as soon as its leaf range's
            # grads materialise in backward (0 = planexec.auto_segments)
            segments = planexec.config_segments(cfg)
            ep = build_exec_plan(plan, layout=self.leaf_layout,
                                 growth=growth, n_pods=self.n_pods,
                                 ring=planexec.ring_override(
                                     cfg.ring_chunks),
                                 bidir=cfg.ring_bidir,
                                 n_edge=self.n_edge,
                                 hier=planexec.hier_override(
                                     getattr(cfg, "hier_mode", 0)),
                                 segments=segments)
            # bounded: adaptive runs see a fresh assignment nearly every
            # replan, and each entry holds O(total_blocks) device perms —
            # evict oldest-first, rebuilding is a cheap numpy pass.  The
            # lock keeps the evict-and-insert atomic against the
            # background warm_compile thread.
            with self._cache_lock:
                while len(self._exec_cache) >= self._EXEC_CACHE_MAX:
                    self._exec_cache.pop(next(iter(self._exec_cache)))
                self._exec_cache[key] = ep
        return ep.with_omega(plan.omega)

    def jit_step(self, plan: Union[SyncPlan, ExecPlan],
                 kind: str = "grad_sync") -> Callable:
        """The compiled step for the plan's bucket signature: a jitted
        ``fn(state, batch, exec_plan) -> (state, metrics)`` with the train
        state donated.  One cache entry per (ladder, signature, kind) —
        replans that keep the signature reuse it with zero retraces."""
        ep = self.exec_plan(plan)
        key = (ep.static_key(), kind)
        fn = self._step_cache.get(key)
        if fn is not None:
            return fn
        body = functools.partial(self._BODIES[kind], self)
        mesh = self.mesh

        if mesh is None:
            fn = jax.jit(body, donate_argnums=(0,))
        elif POD not in mesh.axis_names:
            # single-pod mesh: no pod axis to shard_map over; the body's
            # nested data/model shard_maps still apply.
            def wrapped_sp(state, batch, plan_vec):
                with use_shard_ctx(mesh):
                    return body(state, batch, plan_vec)
            fn = jax.jit(wrapped_sp, donate_argnums=(0,))
        else:
            state_specs = self.state_specs()
            fleet = self._fleet_dim
            state_in = jax.tree.map(lambda l: P(fleet), state_specs)
            # plan vectors (gather perms + omega) ride replicated into the
            # per-pod manual region
            plan_in = jax.tree.map(lambda _: P(), ep)
            # modern jax: manual over the fleet axes only, data/model auto
            # under XLA SPMD; old jax: fully manual, data/model-replicated
            # compute
            manual = compat.manual_axes_for(mesh, set(self.fleet_axes))

            def wrapped(state, batch, plan_vec):
                with use_shard_ctx(mesh, exclude=tuple(manual)):
                    return body(state, batch, plan_vec)

            smapped = compat.shard_map(
                wrapped, mesh,
                in_specs=(state_in, P(fleet), plan_in),
                out_specs=(state_in, P()),
                manual_axes=manual)
            fn = jax.jit(smapped, donate_argnums=(0,))
        # setdefault: a background warm_compile thread may race this
        # insert for the same key — both must end up sharing ONE jitted
        # fn, or compile_count() would sum whichever copy survived
        return self._step_cache.setdefault(key, fn)

    def _record_specs(self, kind: str, state, batch):
        """Remember the (state, batch) avals + shardings of this step
        kind once — what warm_compile AOT-lowers against (shapes never
        change within a run).  The batch arrives as an UNCOMMITTED host
        array the live dispatch auto-shards; recording its single-device
        placement verbatim would make every mesh AOT lowering fail on
        "incompatible devices" against the mesh-sharded state, so on a
        pod mesh the batch spec carries the fleet sharding the
        shard_mapped step actually consumes."""
        if kind in self._arg_specs:
            return
        if self.mesh is not None and POD in self.mesh.axis_names:
            # Steady-state shardings, not the live arrays': the step's
            # out_specs pin every state leaf to P(fleet), so leaves still
            # carrying their init-time data/model device_put layout (or an
            # uncommitted batch's single-device placement) would bake a
            # lowering the post-first-step state can never dispatch into.
            sh = NamedSharding(self.mesh, P(self._fleet_dim))

            def spec(x):
                return jax.ShapeDtypeStruct(x.shape, x.dtype, sharding=sh)
            self._arg_specs[kind] = (jax.tree.map(spec, state),
                                     jax.tree.map(spec, batch))
            return
        self._arg_specs[kind] = (jax.tree.map(_array_spec, state),
                                 jax.tree.map(_array_spec, batch))

    def seed_arg_specs(self, kind: str, state_like, batch_like):
        """Record the (state, batch) arg specs for ``kind`` WITHOUT a live
        step — ``state_like`` / ``batch_like`` may be ShapeDtypeStruct
        pytrees (only shape/dtype are read on a pod mesh).  The elastic
        membership path uses this to make a freshly-built new-P trainer
        :meth:`warm_compile`-able before it has ever stepped, so the whole
        P-change transition compiles in the background."""
        self._record_specs(kind, state_like, batch_like)

    def step(self, state, batch, plan: Union[SyncPlan, ExecPlan],
             kind: str = "grad_sync"):
        """Execute one step kind under ``plan``.  The plan rides as data;
        the compiled step is resolved from the signature-keyed cache —
        or from the AOT cache when :meth:`warm_compile` already built
        this signature's executable in the background."""
        ep = self.exec_plan(plan)
        self._record_specs(kind, state, batch)
        key = (ep.static_key(), kind)
        warmed = self._aot_cache.get(key)
        if warmed is not None:
            # LRU touch: re-insert so eviction (oldest-first insertion
            # order) never drops the signature currently being stepped
            with self._cache_lock:
                if key in self._aot_cache:
                    self._aot_cache[key] = self._aot_cache.pop(key)
            try:
                return warmed(state, batch, ep)
            except (TypeError, ValueError):
                # arg aval/sharding drifted from the warmed lowering —
                # raised by argument validation BEFORE dispatch, so the
                # donated state is untouched: drop the stale executable
                # and fall back.  Anything else (e.g. a runtime fault
                # after dispatch, when the donated buffers are already
                # gone) propagates — re-running would only mask it.
                self._aot_cache.pop(key, None)
        return self.jit_step(ep, kind)(state, batch, ep)

    def step_fn(self, plan: Union[SyncPlan, ExecPlan],
                kind: str = "grad_sync") -> Callable:
        """A ``fn(state, batch)`` closure over the plan's vectors — the
        legacy call shape (tests/benchmarks).  NOTE: the train state is
        donated; callers must rebind ``state`` on every call."""
        ep = self.exec_plan(plan)
        fn = self.jit_step(ep, kind)
        return lambda state, batch: fn(state, batch, ep)

    def plan_arg_specs(self, plan: Union[SyncPlan, ExecPlan]):
        """ShapeDtypeStruct pytree of the plan argument (dry-run lowering);
        plan vectors are replicated on the mesh when one is present."""
        ep = self.exec_plan(plan)

        def spec(a):
            sh = (NamedSharding(self.mesh, P())
                  if self.mesh is not None else None)
            return jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=sh)

        return jax.tree.map(spec, ep)

    @staticmethod
    def _fn_cache_size(fn) -> int:
        try:
            return fn._cache_size()
        except Exception:       # pragma: no cover - very old jax
            return 1

    def compile_count(self) -> int:
        """Total traced-and-compiled variants across the step cache — the
        number tests/test_replan.py pins flat across replans.  AOT
        executables from :meth:`warm_compile` are counted separately
        (``warm_compiles``): they never stall the foreground step, which
        is what this count gates.  The list() snapshot keeps the
        iteration safe against a background warm thread inserting via
        jit_step mid-count."""
        return sum(self._fn_cache_size(fn)
                   for fn in list(self._step_cache.values()))

    # ------------------------------------------------------------------
    # speculative signature warm-up (replan-time background compile)
    # ------------------------------------------------------------------
    def step_is_warm(self, plan: Union[SyncPlan, ExecPlan],
                     kinds: Optional[Tuple[str, ...]] = None) -> bool:
        """Whether stepping under ``plan`` would hit a compiled
        executable for every step kind seen so far (``kinds`` narrows
        the check)."""
        ep = self.exec_plan(plan)
        for kind in (kinds if kinds is not None else self._arg_specs):
            key = (ep.static_key(), kind)
            if key in self._aot_cache:
                continue
            fn = self._step_cache.get(key)
            if fn is None or self._fn_cache_size(fn) == 0:
                return False
        return True

    def warm_compile(self, plan: Union[SyncPlan, ExecPlan],
                     kinds: Optional[Tuple[str, ...]] = None) -> bool:
        """AOT-compile the step for ``plan``'s bucket signature against
        the recorded argument specs — safe to run from a background
        thread, so the host replan loop can warm an incoming signature
        BEFORE swapping the plan in and a class-ladder rung change never
        stalls the device on a foreground compile (ROADMAP follow-up).
        Returns True when every requested kind is warm afterwards."""
        ep = self.exec_plan(plan)
        ok = True
        for kind in (kinds if kinds is not None else tuple(self._arg_specs)):
            key = (ep.static_key(), kind)
            if key in self._aot_cache:
                continue
            fn = self._step_cache.get(key)
            if fn is not None and self._fn_cache_size(fn) > 0:
                continue        # the jit cache already holds it
            specs = self._arg_specs.get(kind)
            if specs is None:
                ok = False      # never stepped this kind: nothing to lower
                continue
            fn = self.jit_step(ep, kind)
            try:
                # plan vectors ride replicated on the mesh — lowering with
                # their live (single-device, committed) placements would
                # conflict with the mesh-sharded state
                compiled = fn.lower(
                    specs[0], specs[1], self.plan_arg_specs(ep)).compile()
            except Exception:   # pragma: no cover - defensive: a failed
                ok = False      # warm-up degrades to a foreground compile
                continue
            with self._cache_lock:
                while len(self._aot_cache) >= self._EXEC_CACHE_MAX:
                    self._aot_cache.pop(next(iter(self._aot_cache)))
                self._aot_cache[key] = compiled
            self.warm_compiles += 1
        return ok

    # convenience plans per strategy ------------------------------------
    def default_plan(self, importance=None, bandwidth_mbps: float = 50.0,
                     omega=None) -> SyncPlan:
        """Strategy-owned plan from a synthetic one-device telemetry
        snapshot (the host loop passes real telemetry instead)."""
        return self.strategy.make_plan(
            self.scheduler, importance=importance,
            telemetry=[{"bandwidth_mbps": bandwidth_mbps}], omega=omega)
