"""Knapsack bandwidth allocation (paper abstract / conclusion).

Maximise   sum_i I(theta_i) * value(level_i)
subject to sum_i wire_bytes(level_i, n_i) <= budget_bytes

over the static level ladder.  Value and bytes both come from the level's
codec (repro/codecs), the single source of comm accounting.  Dominated
rungs (cheaper-but-better alternatives exist) are pruned so the effective
ladder is monotone (more bytes -> more preserved value); on a monotone
ladder the classic greedy-by-density algorithm on the *incremental*
(delta_value / delta_bytes) items is optimal up to one item — the standard
fractional-knapsack bound.

Two solvers share the pruned ladder:

  * :func:`solve` — the host fallback: a single heap/pointer sweep.  Each
    group keeps one pointer to its next rung; only that upgrade item lives
    on the heap, so the sweep is O(G * L log G) with no rescans (the old
    multi-pass loop re-walked the full item list up to ``len(order)``
    times — O(G * L^2) per replan).
  * :func:`make_device_solver` — the jittable device solver the
    retrace-free control plane uses: one density sort over all incremental
    items, a cumulative-bytes budget mask, and a per-group ladder-order
    cumprod.  A replan is then a single device computation
    (importance scores -> plan vector) with no host round-trip.

Runs every ``replan_every`` steps; the result is a per-group level
assignment (host list or device ``int32[G]`` vector).
"""
from __future__ import annotations

import heapq
import math
from typing import Callable, List, Sequence, Tuple, Union

import jax.numpy as jnp
import numpy as np

from repro.core.compression import BLOCK, Level

#: accounting pod count: one count for every level, or one per level —
#: the hierarchical scheduler prices hier-capable rungs at the cluster
#: count (they cross the slow tier once per cluster) and flat rungs at
#: the fleet count (see Scheduler.level_acct).
PodCounts = Union[int, Sequence[int]]


def _per_level_pods(n_pods: PodCounts, n_levels: int) -> List[int]:
    """Broadcast an int accounting pod count to one per level."""
    if isinstance(n_pods, (int, np.integer)):
        return [int(n_pods)] * n_levels
    acct = [int(p) for p in n_pods]
    if len(acct) != n_levels:
        raise ValueError(f"per-level pod counts: expected {n_levels} "
                         f"entries, got {len(acct)}")
    return acct


def level_value(level: Level) -> float:
    """Fraction of gradient 'information' preserved by a level — delegated
    to the codec (``sqrt(keep_ratio)`` mass heuristic x a per-format
    quantisation factor).  These constants only need to ORDER the ladder."""
    return level.codec.value_fraction()


def per_element_cost(level: Level, n_pods: int, block: int = BLOCK) -> float:
    """Size-independent wire cost per element: one full block's bytes over
    the block size.  Used to order the ladder — every codec's wire bytes
    are (block-)linear in n, so this ranks rungs without picking an
    arbitrary probe size."""
    return level.wire_bytes(block, max(n_pods, 2), block) / block


def effective_ladder(levels: Sequence[Level],
                     n_pods: PodCounts) -> List[int]:
    """Rung indices ordered by per-element cost ascending (SKIP first),
    with dominated rungs pruned: the greedy's optimality argument needs a
    ladder monotone in (bytes -> value).  With the widened codec ladder
    that can fail (e.g. packed INT4 is cheaper AND higher-value than
    TOPK25), so drop any rung whose value does not strictly improve on a
    cheaper rung — upgrading to it would never be the right move.
    Per-level pod counts fold the two-tier discount into the ordering
    (a hier rung's slow-tier cost shrinks by fleet/clusters)."""
    acct = _per_level_pods(n_pods, len(levels))
    order = sorted(range(len(levels)),
                   key=lambda j: per_element_cost(levels[j], acct[j]))
    ladder = []
    for j in order:
        if not ladder or level_value(levels[j]) > \
                level_value(levels[ladder[-1]]) + 1e-12:
            ladder.append(j)
    return ladder


def _item_gain(importance: float, size: int, dv: float) -> float:
    return dv * max(importance, 1e-6) * math.log1p(size)


def solve(importance: Sequence[float], sizes: Sequence[int],
          levels: Sequence[Level], budget_bytes: float,
          n_pods: PodCounts) -> List[int]:
    """-> per-group level index. Greedy incremental knapsack, one
    heap/pointer sweep.

    Each group's candidate upgrade is always its NEXT rung on the pruned
    ladder, so exactly one item per group is live at a time; taking it
    pushes the group's next rung, and an unaffordable item freezes the
    group (spent only grows, so it can never become affordable later —
    the same fixpoint the old multi-pass rescan converged to).
    """
    G = len(importance)
    assert len(sizes) == G
    levels = list(levels)
    acct = _per_level_pods(n_pods, len(levels))
    order = effective_ladder(levels, acct)
    # NOTE: the solver prices each group's bytes independently.  Since the
    # plan-as-data exchange block-aligns every leaf, per-group pricing is
    # EXACT for unpadded buckets and a lower bound under size-class
    # padding (codecs.plan_wire_bytes prices the executed signature) — the
    # greedy can never exceed the analytic budget it was given.
    wb = [[levels[j].wire_bytes(sizes[i], acct[j]) for j in order]
          for i in range(G)]
    choice = [order[0]] * G          # start everything at the cheapest level
    spent = sum(wb[i][0] for i in range(G))
    val = [level_value(levels[j]) for j in order]

    heap: List[Tuple[float, int, int, int]] = []

    def push(i: int, pos: int):
        if pos >= len(order):
            return
        db = wb[i][pos] - wb[i][pos - 1]
        if db <= 0:
            return  # degenerate rung pair (equal bytes): freeze the group
        dv = _item_gain(importance[i], sizes[i], val[pos] - val[pos - 1])
        heapq.heappush(heap, (-dv / db, i, pos, db))

    for i in range(G):
        push(i, 1)
    while heap:
        _, i, pos, db = heapq.heappop(heap)
        if spent + db > budget_bytes:
            continue  # group frozen at pos - 1
        spent += db
        choice[i] = order[pos]
        push(i, pos + 1)
    return choice


def _group_hull(wb_row: np.ndarray, vals: np.ndarray) -> List[int]:
    """Upper convex hull of one group's (bytes, value) ladder points.

    Restricting the greedy to hull points makes the incremental densities
    strictly decreasing along each group's ladder — the property that lets
    a single global density sort + prefix budget mask respect ladder order
    without an inner loop.  Importance multiplies the whole value axis of
    a group, so the hull is importance-invariant and precomputes in numpy.
    """
    hull = [0]
    for p in range(1, len(vals)):
        if wb_row[p] <= wb_row[hull[-1]] or vals[p] <= vals[hull[-1]]:
            continue
        while len(hull) >= 2:
            a, b = hull[-2], hull[-1]
            dens_ab = (vals[b] - vals[a]) / (wb_row[b] - wb_row[a])
            dens_bp = (vals[p] - vals[b]) / (wb_row[p] - wb_row[b])
            if dens_bp >= dens_ab:      # b lies under the a->p chord
                hull.pop()
            else:
                break
        hull.append(p)
    return hull


def make_device_solver(sizes: Sequence[int], levels: Sequence[Level],
                       n_pods: PodCounts, block: int = BLOCK
                       ) -> Callable[[jnp.ndarray, jnp.ndarray], jnp.ndarray]:
    """Build the jittable device knapsack for a fixed (sizes, ladder).

    Returns ``fn(importance f32[G], budget_bytes scalar) -> int32[G]``.
    All static tables — the pruned ladder and each group's convex-hull
    upgrade items (:func:`_group_hull`) — are numpy-precomputed once; the
    traced computation is one density sort over the hull items, a
    cumulative-bytes budget mask (hull densities decrease within a group,
    so the accepted density-sorted prefix automatically respects ladder
    order), and a per-group cumprod selecting the hull point reached.

    This is the classic LP-relaxation greedy for the multiple-choice
    knapsack: rungs off a group's hull are never picked (the host sweep
    can pass through them), and bytes of items rejected by the prefix mask
    still count against the budget — both make the device plan
    conservative, never over budget.
    """
    acct = _per_level_pods(n_pods, len(levels))
    order = effective_ladder(list(levels), acct)
    G, Lp = len(sizes), len(order)
    if Lp == 1 or G == 0:
        base_choice = jnp.full((G,), order[0] if order else 0, jnp.int32)
        return lambda importance, budget_bytes: base_choice

    wb = np.asarray([[levels[j].wire_bytes(int(n), acct[j]) for j in order]
                     for n in sizes], np.float64)          # (G, Lp)
    base = float(wb[:, 0].sum())
    vals = np.asarray([level_value(levels[j]) for j in order])
    hulls = [_group_hull(wb[i], vals) for i in range(G)]
    Hm = max(len(h) for h in hulls)                        # hull positions
    # per-group hull item tables, padded with invalid items
    item_db = np.zeros((G, Hm - 1), np.float64)
    item_dv = np.zeros((G, Hm - 1), np.float64)
    valid = np.zeros((G, Hm - 1), bool)
    rung_at = np.zeros((G, Hm), np.int32)                  # ladder rung per
    log_sz = np.log1p(np.asarray(sizes, np.float64))       # hull position
    for i, h in enumerate(hulls):
        rung_at[i] = order[h[-1]]
        for k, p in enumerate(h):
            rung_at[i, k] = order[p]
        for k in range(1, len(h)):
            item_db[i, k - 1] = wb[i, h[k]] - wb[i, h[k - 1]]
            item_dv[i, k - 1] = (vals[h[k]] - vals[h[k - 1]]) * log_sz[i]
            valid[i, k - 1] = True

    db_j = jnp.asarray(item_db, jnp.float32)
    dv_j = jnp.asarray(item_dv, jnp.float32)
    valid_j = jnp.asarray(valid)
    rung_j = jnp.asarray(rung_at)

    def solve_fn(importance: jnp.ndarray,
                 budget_bytes: jnp.ndarray) -> jnp.ndarray:
        imp = jnp.maximum(importance.astype(jnp.float32), 1e-6)[:, None]
        dens = jnp.where(valid_j, dv_j * imp / jnp.maximum(db_j, 1.0),
                         -jnp.inf)
        flat_d = dens.reshape(-1)
        flat_b = jnp.where(valid_j, db_j, 0.0).reshape(-1)
        by_density = jnp.argsort(-flat_d)
        cum = jnp.cumsum(flat_b[by_density])
        afford = (base + cum <= budget_bytes) \
            & jnp.isfinite(flat_d[by_density])
        taken = jnp.zeros(flat_d.shape, bool).at[by_density].set(afford)
        taken = taken.reshape(G, Hm - 1).astype(jnp.int32)
        pos = jnp.cumprod(taken, axis=1).sum(axis=1)       # hull point hit
        return jnp.take_along_axis(rung_j, pos[:, None], axis=1)[:, 0]

    return solve_fn


def plan_bytes(choice: Sequence[int], sizes: Sequence[int],
               levels: Sequence[Level], n_pods: PodCounts) -> int:
    acct = _per_level_pods(n_pods, len(levels))
    return int(sum(levels[c].wire_bytes(n, acct[c])
                   for c, n in zip(choice, sizes)))
