"""Knapsack bandwidth allocation (paper abstract / conclusion).

Maximise   sum_i I(theta_i) * value(level_i)
subject to sum_i wire_bytes(level_i, n_i) <= budget_bytes

over the static level ladder.  Value and bytes both come from the level's
codec (repro/codecs), the single source of comm accounting.  Dominated
rungs (cheaper-but-better alternatives exist) are pruned so the effective
ladder is monotone (more bytes -> more preserved value); on a monotone
ladder the classic greedy-by-density algorithm on the *incremental*
(delta_value / delta_bytes) items is optimal up to one item — the standard
fractional-knapsack bound — and runs in O(G * L log(G * L)) on the host.
Runs every ``replan_every`` steps; the result is a static sync plan (one
level index per parameter group).
"""
from __future__ import annotations

import math
from typing import List, Sequence, Tuple

import numpy as np

from repro.core.compression import Level


def level_value(level: Level) -> float:
    """Fraction of gradient 'information' preserved by a level — delegated
    to the codec (``sqrt(keep_ratio)`` mass heuristic x a per-format
    quantisation factor).  These constants only need to ORDER the ladder."""
    return level.codec.value_fraction()


def solve(importance: Sequence[float], sizes: Sequence[int],
          levels: Sequence[Level], budget_bytes: float,
          n_pods: int) -> List[int]:
    """-> per-group level index. Greedy incremental knapsack."""
    G = len(importance)
    assert len(sizes) == G
    levels = list(levels)
    # order levels by wire bytes ascending (SKIP first)
    order = sorted(range(len(levels)),
                   key=lambda j: levels[j].wire_bytes(10 ** 6, max(n_pods, 2)))
    # dominated-rung pruning: the greedy's optimality argument needs a
    # ladder monotone in (bytes -> value).  With the widened codec ladder
    # that can fail (e.g. packed INT4 is cheaper AND higher-value than
    # TOPK25), so drop any rung whose value does not strictly improve on a
    # cheaper rung — upgrading to it would never be the right move.
    ladder = []
    for j in order:
        if not ladder or level_value(levels[j]) > \
                level_value(levels[ladder[-1]]) + 1e-12:
            ladder.append(j)
    order = ladder
    # NOTE: the solver prices each group's bytes independently (per-group
    # block padding).  The executed plan buckets same-level groups into one
    # buffer (codecs.plan_wire_bytes), which shares padding — so per-group
    # pricing is a conservative upper bound and the greedy can never
    # exceed the budget it was given; a joint bucket-aware cost would
    # depend on the assignment being built and break the incremental
    # density items.
    choice = [order[0]] * G          # start everything at the cheapest level
    spent = sum(levels[choice[i]].wire_bytes(sizes[i], n_pods)
                for i in range(G))

    # incremental upgrade items: (density, group, to_level_position)
    items = []
    for i in range(G):
        for pos in range(1, len(order)):
            j_prev, j = order[pos - 1], order[pos]
            dv = (level_value(levels[j]) - level_value(levels[j_prev])) \
                * max(importance[i], 1e-6) * math.log1p(sizes[i])
            db = (levels[j].wire_bytes(sizes[i], n_pods)
                  - levels[j_prev].wire_bytes(sizes[i], n_pods))
            if db <= 0:
                continue
            items.append((dv / db, i, pos, db))
    items.sort(key=lambda t: -t[0])

    pos_of = [0] * G
    # multiple passes: a skipped prerequisite may unlock later upgrades
    for _ in range(len(order)):
        progressed = False
        for dens, i, pos, db in items:
            if pos != pos_of[i] + 1:
                continue  # upgrades must be taken in ladder order
            if spent + db > budget_bytes:
                continue
            spent += db
            pos_of[i] = pos
            choice[i] = order[pos]
            progressed = True
        if not progressed:
            break
    return choice


def plan_bytes(choice: Sequence[int], sizes: Sequence[int],
               levels: Sequence[Level], n_pods: int) -> int:
    return int(sum(levels[c].wire_bytes(n, n_pods)
                   for c, n in zip(choice, sizes)))
