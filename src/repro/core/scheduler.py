"""Adaptive compression-expansion scheduling (paper eq. 5) + sync-plan
management.

The scheduler turns per-pod telemetry (bandwidth estimate B_k(t)) into a
byte budget and a compression-ratio envelope:

    c_k(t) = c_min + (c_max - c_min) * exp(-beta * B_k(t))        (eq 5)

(c is the compression aggressiveness: low bandwidth -> large c -> keep
fewer bytes; the byte budget is (1 - c) x FullSync volume).  The budget plus the importance scores feed the knapsack
(core/knapsack.py) to produce the per-group level plan.  Plans are
recomputed every ``replan_every`` steps, but since the plan-as-data
refactor they are *data*, not static jit arguments: the trainer lowers a
:class:`SyncPlan` to an :class:`~repro.core.planexec.ExecPlan` whose
gather perms and omega are ordinary device arrays, and only the padded
**bucket signature** (``SyncPlan.bucket_sig`` — per-rung block counts
rounded to size classes) keys the compiled step.  Adaptive strategies get
their plans built with padded classes so steady-state replans reuse the
warm jit cache; static strategies get exact sizes (no padding on the
wire).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.codecs import plan_intra_bytes as _bucketed_intra_bytes
from repro.codecs import plan_wire_bytes as _bucketed_plan_bytes
from repro.configs.base import ACESyncConfig
from repro.core import knapsack
from repro.core import planexec
from repro.core.compression import Level


def levels_from_config(cfg: ACESyncConfig) -> List[Level]:
    return [Level(*lv) for lv in cfg.levels]


def compression_level(cfg: ACESyncConfig, bandwidth_mbps: float) -> float:
    """eq (5) verbatim: c_k(t) = c_min + (c_max-c_min)*exp(-beta*B_k(t)).
    c is the compression AGGRESSIVENESS (paper: "under low bandwidth, the
    framework increases compression")."""
    return cfg.c_min + (cfg.c_max - cfg.c_min) * math.exp(
        -cfg.beta * bandwidth_mbps)


def kept_fraction(cfg: ACESyncConfig, bandwidth_mbps: float) -> float:
    """Fraction of the FullSync byte volume the budget allows: 1 - c_k(t)
    (floored so SKIP-everything never happens)."""
    return max(0.02, 1.0 - compression_level(cfg, bandwidth_mbps))


def byte_budget(cfg: ACESyncConfig, bandwidth_mbps: float,
                total_bytes_full: int) -> float:
    """Per-sync byte budget: the kept-fraction envelope applied to the
    full-sync wire volume."""
    return kept_fraction(cfg, bandwidth_mbps) * total_bytes_full


@dataclass
class SyncPlan:
    """Compression plan: one level index per parameter group.

    ``bucket_sig`` is the padded per-rung block-count signature the
    executed exchange will actually move (attached by the Scheduler);
    pricing (``codecs.plan_wire_bytes``) uses it so Table 1 and the
    dry-run byte assertions include the padding.  ``adaptive`` records
    whether the plan was built with padded size classes (adaptive
    strategies, replans hit a warm jit cache) or exact sizes (static
    strategies, no padding on the wire)."""
    level_idx: Tuple[int, ...]            # per group
    levels: Tuple[Level, ...]
    omega: Tuple[float, ...]              # per-pod aggregation weights
    sync_interval: int                    # H
    bucket_sig: Optional[Tuple[int, ...]] = None
    bucket_block: Optional[int] = None    # block size bucket_sig counts in
    adaptive: bool = False
    ring_chunks: Optional[Tuple[int, ...]] = None  # per-rung chunk grid
    hier: Optional[Tuple[int, ...]] = None         # per-rung tier grid
    # per-(segment, rung) signature of a backward-segmented plan — the
    # compiled-step identity when ``overlap_backward`` streams the
    # exchange (None/() for flat plans).  Two plans sharing bucket_sig
    # but not seg_sig still lower to DIFFERENT compiled steps.
    seg_sig: Optional[Tuple[Tuple[int, ...], ...]] = None

    def signature(self) -> tuple:
        """Hashable key of the full assignment (legacy; the compiled step
        is keyed on the much smaller ``bucket_sig`` instead)."""
        return (self.level_idx, tuple(self.levels), self.sync_interval)

    def level_of(self, gi: int) -> Level:
        return self.levels[self.level_idx[gi]]


class Scheduler:
    """Host-side policy engine: telemetry + importance -> SyncPlan."""

    def __init__(self, cfg: ACESyncConfig, group_sizes: Sequence[int],
                 n_pods: int, n_edge: int = 1):
        self.cfg = cfg
        self.sizes = list(group_sizes)
        # n_pods is the FLEET size (every device the flat exchange spans);
        # n_edge > 1 makes it a hierarchical fleet of n_pods // n_edge
        # clusters whose hier-capable rungs cross the slow tier once per
        # CLUSTER (see planexec.exec_grid)
        self.n_pods = n_pods
        self.n_edge = max(int(n_edge), 1)
        self.n_cross = max(n_pods // self.n_edge, 1)
        # knapsack/accounting always price levels as if >=2 peers exchange
        # (a 1-pod run would otherwise see zero cost everywhere and the
        # solver would degenerate to all-SKIP)
        self.acct_pods = max(n_pods, 2)
        self.acct_cross = max(self.n_cross, 2)
        self.levels = levels_from_config(cfg)
        self.full_level = next(l for l in self.levels if l.is_full)
        self.sync_interval = cfg.sync_interval_init
        self._full_bytes = sum(
            self.full_level.wire_bytes(n, self.acct_pods)
            for n in self.sizes)
        self._full_bytes_cross = sum(
            self.full_level.wire_bytes(n, self.acct_cross)
            for n in self.sizes)
        # per-level accounting pod counts: on a hierarchical fleet, hier-
        # capable rungs cross the slow tier at the cluster count, so the
        # knapsack prices them at acct_cross — compression choices track
        # the bytes the cross tier actually moves
        if self.hier_enabled:
            self.level_acct = [
                self.acct_cross if getattr(lv.codec, "supports_hier", False)
                else self.acct_pods for lv in self.levels]
        else:
            self.level_acct = [self.acct_pods] * len(self.levels)
        self._layout = planexec.leaf_layout(self.sizes, cfg.topk_block)
        self._device_solver = None

    @property
    def hier_enabled(self) -> bool:
        """Whether plans get a two-tier grid: hierarchical fleet (> 1
        member per cluster, > 1 cluster) and not forced flat by config."""
        return (self.n_edge > 1 and self.n_cross > 1
                and getattr(self.cfg, "hier_mode", 0) >= 0)

    def _finalize(self, plan: SyncPlan, adaptive: bool) -> SyncPlan:
        """Attach the bucket signature the executed exchange moves (padded
        size classes for adaptive plans, exact sizes otherwise — plus the
        ring chunk grid's chunk-multiple rounding and the two-tier grid,
        via the same ``planexec.exec_grid`` the trainer lowers with, so
        the priced bytes track the executed collectives)."""
        plan.adaptive = adaptive
        sig, chunks, hier = planexec.exec_grid(
            plan.level_idx, self.sizes, plan.levels, self.n_pods,
            block=self.cfg.topk_block,
            growth=self.pad_growth if adaptive else None,
            ring=planexec.ring_override(self.cfg.ring_chunks),
            bidir=self.cfg.ring_bidir, n_edge=self.n_edge,
            hier=planexec.hier_override(getattr(self.cfg, "hier_mode", 0)))
        plan.bucket_sig = sig
        plan.ring_chunks = chunks
        plan.hier = hier
        plan.bucket_block = self.cfg.topk_block
        segments = planexec.config_segments(self.cfg)
        if segments != 1:
            # backward-segmented lowering: attach the per-(segment, rung)
            # signature — the identity the trainer's compiled-step cache
            # actually keys on (see planexec.seg_grids)
            _, _, seg_sig, _, _ = planexec.seg_grids(
                plan.level_idx, self._layout, plan.levels, self.n_pods,
                self.pad_growth if adaptive else None,
                planexec.ring_override(self.cfg.ring_chunks),
                self.cfg.ring_bidir, n_edge=self.n_edge,
                hier=planexec.hier_override(
                    getattr(self.cfg, "hier_mode", 0)),
                segments=segments)
            plan.seg_sig = seg_sig or None
        return plan

    @property
    def pad_growth(self) -> float:
        return getattr(self.cfg, "bucket_pad_growth", planexec.PAD_GROWTH)

    def full_plan(self, omega: Optional[Sequence[float]] = None) -> SyncPlan:
        """FullSync baseline plan."""
        fi = self.levels.index(self.full_level)
        return self._finalize(
            SyncPlan(tuple([fi] * len(self.sizes)), tuple(self.levels),
                     self._omega(omega), 1), adaptive=False)

    def uniform_topk_plan(self, ratio: float = 0.1,
                          omega: Optional[Sequence[float]] = None) -> SyncPlan:
        """Top-k sparsification baseline (static ratio for every group)."""
        cand = [i for i, l in enumerate(self.levels)
                if l.is_topk and abs(l.keep_ratio - ratio) < 1e-6]
        idx = cand[0] if cand else min(
            (i for i, l in enumerate(self.levels) if l.is_topk),
            key=lambda i: abs(self.levels[i].keep_ratio - ratio))
        return self._finalize(
            SyncPlan(tuple([idx] * len(self.sizes)), tuple(self.levels),
                     self._omega(omega), 1), adaptive=False)

    def plan(self, importance: Sequence[float], bandwidth_mbps: float,
             omega: Optional[Sequence[float]] = None) -> SyncPlan:
        """ACE-Sync adaptive plan: knapsack under the eq-(5) budget."""
        budget = self.budget_for(bandwidth_mbps)
        choice = knapsack.solve(list(importance), self.sizes, self.levels,
                                budget, self.level_acct)
        return self._finalize(
            SyncPlan(tuple(choice), tuple(self.levels),
                     self._omega(omega), self.sync_interval), adaptive=True)

    def plan_from_levels(self, level_idx: Sequence[int],
                         omega: Optional[Sequence[float]] = None,
                         sync_interval: Optional[int] = None,
                         adaptive: bool = False) -> SyncPlan:
        """Build a plan from explicit per-group level indices — the public
        seam for strategies that pick levels without the knapsack, and for
        the device-resident replan path (the fetched ``int32[G]`` vector
        lands here).  ``adaptive=True`` pads the bucket signature to size
        classes so successive replans share the compiled step."""
        if len(level_idx) != len(self.sizes):
            raise ValueError(f"expected {len(self.sizes)} level indices, "
                             f"got {len(level_idx)}")
        return self._finalize(
            SyncPlan(tuple(int(i) for i in level_idx), tuple(self.levels),
                     self._omega(omega),
                     self.sync_interval if sync_interval is None
                     else sync_interval), adaptive=adaptive)

    def device_solver(self):
        """The jittable knapsack over this scheduler's (sizes, ladder):
        ``fn(importance f32[G], budget_bytes) -> int32[G]`` (cached)."""
        if self._device_solver is None:
            self._device_solver = knapsack.make_device_solver(
                self.sizes, self.levels, self.level_acct,
                block=self.cfg.topk_block)
        return self._device_solver

    def budget_for(self, bandwidth_mbps: float) -> float:
        """Eq-(5) byte budget against this scheduler's full-sync volume.

        On a hierarchical fleet the budget is priced against the CROSS-
        tier full volume: the 5-200 Mbps WAN links eq (5) models are the
        per-cluster uplinks, and hier-capable rungs are knapsack-priced
        at the cluster count — same envelope, same currency."""
        full = (self._full_bytes_cross if self.hier_enabled
                else self._full_bytes)
        return byte_budget(self.cfg, bandwidth_mbps, full)

    # ------------------------------------------------------------------
    # preemption-safe host state
    # ------------------------------------------------------------------
    def snapshot(self) -> dict:
        """The scheduler's mutable host state — what a checkpoint must
        carry for a restart to replay identically (everything else here
        is derived from the config and group sizes at construction)."""
        return {"sync_interval": int(self.sync_interval)}

    def restore_snapshot(self, snap: dict):
        self.sync_interval = int(snap.get("sync_interval",
                                          self.cfg.sync_interval_init))

    def adapt_interval(self, divergence: float, div_ref: float) -> int:
        """Paper eq (9) control: grow H when divergence is small, shrink
        when it exceeds the threshold band."""
        cfg = self.cfg
        rel = divergence / max(div_ref, 1e-12)
        if rel > cfg.div_high:
            self.sync_interval = max(1, self.sync_interval // 2)
        elif rel < cfg.div_low:
            self.sync_interval = min(cfg.sync_interval_max,
                                     self.sync_interval * 2)
        return self.sync_interval

    def _omega(self, omega) -> Tuple[float, ...]:
        if omega is None:
            return tuple([1.0 / self.n_pods] * self.n_pods)
        s = float(sum(omega))
        if not math.isfinite(s) or s <= 0.0:
            raise ValueError(
                f"reliability weights must have a positive finite sum, "
                f"got sum={s!r} over {len(tuple(omega))} weights — all "
                f"reliability scores underflowed?")
        return tuple(w / s for w in omega)

    def plan_wire_bytes(self, plan: SyncPlan,
                        n_pods: Optional[int] = None,
                        padded: bool = True) -> int:
        """Bytes a sync round under ``plan`` actually moves per device
        over the SLOW tier: bucketed codec pricing on the plan's executed
        bucket signature (same-level groups share one buffer/collective
        in core/sync.py; size-class padding included for adaptive plans),
        the same accounting Table 1 and the dry-run byte assertions use.
        Two-tier rungs are priced at the cluster count.  ``padded=False``
        prices the unpadded analytic floor; an explicit ``n_pods``
        prices every rung at that count (star/what-if accounting)."""
        return _bucketed_plan_bytes(
            plan, self.sizes, self.acct_pods if n_pods is None else n_pods,
            self.cfg.topk_block, use_sig=padded,
            n_cross=self.acct_cross if n_pods is None else None)

    def plan_intra_bytes(self, plan: SyncPlan) -> int:
        """Fast-tier (intra-cluster) bytes of the plan's two-tier rungs —
        zero for flat plans."""
        return _bucketed_intra_bytes(plan, self.sizes, self.n_edge,
                                     self.cfg.topk_block)

    def fullsync_wire_bytes(self) -> int:
        return self._full_bytes
