"""Attention-based parameter-importance estimator (paper eqs. 3-4).

Per parameter group i the estimator produces I(theta_i) in [0, 1]:

    I(theta_i) = alpha * Attn_temp(g_i) + (1 - alpha) * Attn_struct(theta_i)
    Attn_temp(g_i) = sigmoid(W1 * |g_i|_ema + W2 * Var(g_i)_ema)      (eq 4)

The structural branch is a small softmax attention OVER GROUPS (queries from
temporal statistics, keys/values from static structural features) so groups
compete — consistent with the knapsack view of bandwidth allocation.

The estimator is trained online: the target for step t is the observed
normalised update magnitude of each group over the next window (the paper's
"gradient snapshot" supervision), minimised with its own Adam.  Everything
is O(n_groups * hidden) — negligible next to the model.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

N_TEMPORAL = 4   # |g| ema, var ema, norm momentum, relative step
N_STRUCT = 6     # rel depth, log size, type one-hot (embed/attn/mlp/other)


class ImportanceState(NamedTuple):
    params: dict          # estimator weights
    opt_m: dict
    opt_v: dict
    feat_ema: jax.Array   # (G, 2) ema of mean|g| and var(g)
    norm_mom: jax.Array   # (G,) gradient-norm momentum
    step: jax.Array       # scalar int32


def init_params(rng, n_groups: int, hidden: int):
    k = jax.random.split(rng, 6)
    s = 1.0 / math.sqrt(hidden)

    return {
        # eq (4) temporal branch
        "w1": jnp.full((1,), 1.0, jnp.float32),
        "w2": jnp.full((1,), 1.0, jnp.float32),
        "b_temp": jnp.zeros((1,), jnp.float32),
        # structural attention
        "wq": jax.random.normal(k[0], (N_TEMPORAL, hidden)) * 0.3,
        "wk": jax.random.normal(k[1], (N_STRUCT, hidden)) * 0.3,
        "wv": jax.random.normal(k[2], (N_STRUCT, hidden)) * 0.3,
        "w_out": jax.random.normal(k[3], (hidden, 1)) * s,
        "b_out": jnp.zeros((1,), jnp.float32),
    }


def init_state(rng, n_groups: int, hidden: int) -> ImportanceState:
    p = init_params(rng, n_groups, hidden)
    zeros = jax.tree.map(jnp.zeros_like, p)
    return ImportanceState(
        params=p, opt_m=zeros, opt_v=jax.tree.map(jnp.zeros_like, p),
        feat_ema=jnp.zeros((n_groups, 2), jnp.float32),
        norm_mom=jnp.zeros((n_groups,), jnp.float32),
        step=jnp.zeros((), jnp.int32))


def structural_features(group_meta) -> jnp.ndarray:
    """group_meta: list of dicts {depth: float in [0,1], size: int,
    kind: str}. Static per model — computed once."""
    kinds = {"embed": 0, "attn": 1, "mlp": 2, "other": 3}
    rows = []
    for m in group_meta:
        one = [0.0] * 4
        one[kinds.get(m["kind"], 3)] = 1.0
        rows.append([m["depth"], math.log10(max(m["size"], 1)) / 12.0] + one)
    return jnp.asarray(rows, jnp.float32)


def update_stats(state: ImportanceState, grad_mean_abs, grad_var, grad_norm,
                 decay: float = 0.9) -> ImportanceState:
    """grad_*: (G,) per-group scalars from the current step."""
    feat = jnp.stack([grad_mean_abs, grad_var], axis=1)
    feat_ema = decay * state.feat_ema + (1 - decay) * feat
    norm_mom = decay * state.norm_mom + (1 - decay) * grad_norm
    return state._replace(feat_ema=feat_ema, norm_mom=norm_mom,
                          step=state.step + 1)


def temporal_features(state: ImportanceState) -> jnp.ndarray:
    g = state.feat_ema
    # normalise across groups so scales are comparable
    mu = jnp.mean(g, axis=0, keepdims=True)
    sd = jnp.std(g, axis=0, keepdims=True) + 1e-8
    gn = (g - mu) / sd
    nm = state.norm_mom
    nmn = (nm - jnp.mean(nm)) / (jnp.std(nm) + 1e-8)
    step_feat = jnp.full_like(nmn, jnp.log1p(state.step.astype(jnp.float32))
                              / 10.0)
    return jnp.stack([gn[:, 0], gn[:, 1], nmn, step_feat], axis=1)  # (G,4)


def scores(params, temp_feat, struct_feat, alpha: float) -> jnp.ndarray:
    """-> (G,) importance in [0,1]. eq (3)."""
    # temporal branch (eq 4): sigmoid(W1 |g| + W2 Var(g))
    attn_temp = jax.nn.sigmoid(params["w1"] * temp_feat[:, 0]
                               + params["w2"] * temp_feat[:, 1]
                               + params["b_temp"])
    # structural branch: attention over groups
    q = temp_feat @ params["wq"]          # (G, H)
    k = struct_feat @ params["wk"]        # (G, H)
    v = struct_feat @ params["wv"]        # (G, H)
    att = jax.nn.softmax(q @ k.T / math.sqrt(q.shape[-1]), axis=-1)
    ctx = att @ v                          # (G, H)
    attn_struct = jax.nn.sigmoid((ctx @ params["w_out"])[:, 0]
                                 + params["b_out"])
    return alpha * attn_temp + (1 - alpha) * attn_struct


def train_step(state: ImportanceState, struct_feat, target, *,
               alpha: float, lr: float) -> tuple[ImportanceState, jax.Array]:
    """One online Adam step toward the observed importance ``target`` (G,).
    Returns (new_state, mse)."""
    temp_feat = temporal_features(state)

    def loss_fn(p):
        s = scores(p, temp_feat, struct_feat, alpha)
        return jnp.mean((s - target) ** 2)

    mse, grads = jax.value_and_grad(loss_fn)(state.params)
    b1, b2, eps = 0.9, 0.999, 1e-8
    t = state.step.astype(jnp.float32) + 1.0
    new_m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g,
                         state.opt_m, grads)
    new_v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g,
                         state.opt_v, grads)
    def upd(p, m, v):
        mh = m / (1 - b1 ** t)
        vh = v / (1 - b2 ** t)
        return p - lr * mh / (jnp.sqrt(vh) + eps)
    new_p = jax.tree.map(upd, state.params, new_m, new_v)
    return state._replace(params=new_p, opt_m=new_m, opt_v=new_v), mse
