"""Device clustering (paper: "device clustering ensures long-term
convergence and cross-device personalization").

Pods/devices are clustered by telemetry profile (bandwidth mean/var,
latency, straggle factor); each cluster gets a shared compression policy
scale and reliability weight omega.  Plain k-means on the host (numpy) —
this runs once per replan, on a handful of device profiles.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np


def _sort_rank(x: np.ndarray) -> np.ndarray:
    """Lexicographic rank of each row — a permutation-invariant tiebreak.
    Two permutations of the same profile set rank every (identical) row
    the same way, so anything seeded through the ranks is stable under
    input reordering."""
    order = np.lexsort(x.T[::-1])          # sort by col 0, then 1, ...
    rank = np.empty(x.shape[0], np.int64)
    rank[order] = np.arange(x.shape[0])
    return rank


def _argbest(score: np.ndarray, rank: np.ndarray) -> int:
    """Index of the max score, ties broken by lexicographic row rank (NOT
    input position — the input order must never matter)."""
    best = score.max()
    tied = np.flatnonzero(score >= best - 1e-12)
    return int(tied[np.argmin(rank[tied])])


def kmeans(x: np.ndarray, k: int, iters: int = 50, seed: int = 0,
           init: np.ndarray = None) -> Tuple[np.ndarray, np.ndarray]:
    """x: (N, F). Returns (assignments (N,), centroids (k, F)).

    Deterministic farthest-point (kmeans++-style maxmin) init, sort-stable:
    the first centroid is the lexicographically smallest row and each next
    one the point farthest from the chosen set, so the SAME profile set in
    ANY order yields the same centroids and the same partition (``seed``
    is accepted for API compatibility but unused).  ``init`` warm-starts
    Lloyd's iterations from previous centroids (the ClusterState re-cluster
    path), skipping the init scan.  A cluster that loses all members is
    re-seeded from the point worst served by the surviving centroids
    instead of keeping its stale centroid forever."""
    x = np.asarray(x, np.float64)
    n = x.shape[0]
    k = min(k, n)
    rank = _sort_rank(x)
    if init is not None and init.shape == (k, x.shape[1]):
        cent = np.array(init, np.float64)
    else:
        # maxmin init: lexicographically-first row, then repeatedly the
        # point with the largest distance to its nearest chosen centroid
        cent = [x[_argbest(np.zeros(n), rank)]]
        for _ in range(1, k):
            d2 = np.min([np.sum((x - c) ** 2, axis=1) for c in cent],
                        axis=0)
            cent.append(x[_argbest(d2, rank)])
        cent = np.stack(cent)
    assign = np.full(n, -1, np.int64)
    for _ in range(iters):
        d = ((x[:, None, :] - cent[None]) ** 2).sum(-1)
        new_assign = d.argmin(1)
        if np.all(new_assign == assign):
            break
        assign = new_assign
        for j in range(k):
            m = assign == j
            if m.any():
                cent[j] = x[m].mean(0)
            else:
                # empty cluster: re-seed from the farthest point (the one
                # worst represented by the current centroids), then let
                # the next iteration re-assign around it
                cent[j] = x[_argbest(d.min(1), rank)]
    return assign, cent


def normalise_profiles(profiles: Sequence[dict]) -> np.ndarray:
    """profiles: dicts with bandwidth_mbps, latency_ms, jitter, straggle."""
    keys = ("bandwidth_mbps", "latency_ms", "jitter", "straggle")
    x = np.array([[float(p.get(k, 0.0)) for k in keys] for p in profiles])
    mu, sd = x.mean(0), x.std(0) + 1e-8
    return (x - mu) / sd


def cluster_devices(profiles: Sequence[dict], k: int,
                    seed: int = 0) -> List[int]:
    x = normalise_profiles(profiles)
    assign, _ = kmeans(x, k, seed=seed)
    return assign.tolist()


def reliability_weights(profiles: Sequence[dict],
                        assignments: Sequence[int]) -> List[float]:
    """omega_k (paper eq. 8): softmax over a reliability score =
    bandwidth / (latency * straggle), shared within a cluster."""
    import math
    scores = []
    for p in profiles:
        bw = float(p.get("bandwidth_mbps", 1.0))
        lat = float(p.get("latency_ms", 1.0))
        st = float(p.get("straggle", 1.0))
        scores.append(math.log(max(bw, 1e-3))
                      - 0.1 * math.log(max(lat, 1e-3))
                      - math.log(max(st, 1e-3)))
    # cluster-average the scores (personalised-but-stable weights)
    by_cluster = {}
    for s, a in zip(scores, assignments):
        by_cluster.setdefault(a, []).append(s)
    cl_mean = {a: sum(v) / len(v) for a, v in by_cluster.items()}
    sc = np.array([cl_mean[a] for a in assignments])
    e = np.exp(sc - sc.max())
    w = e / e.sum()
    return w.tolist()
