"""Device clustering (paper: "device clustering ensures long-term
convergence and cross-device personalization").

Pods/devices are clustered by telemetry profile (bandwidth mean/var,
latency, straggle factor); each cluster gets a shared compression policy
scale and reliability weight omega.  Plain k-means on the host (numpy) —
this runs once per replan, on a handful of device profiles.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np


def kmeans(x: np.ndarray, k: int, iters: int = 50,
           seed: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    """x: (N, F). Returns (assignments (N,), centroids (k, F))."""
    n = x.shape[0]
    k = min(k, n)
    rng = np.random.RandomState(seed)
    # k-means++ init
    cent = [x[rng.randint(n)]]
    for _ in range(1, k):
        d2 = np.min([np.sum((x - c) ** 2, axis=1) for c in cent], axis=0)
        p = d2 / max(d2.sum(), 1e-12)
        cent.append(x[rng.choice(n, p=p)])
    cent = np.stack(cent)
    assign = np.zeros(n, np.int64)
    for _ in range(iters):
        d = ((x[:, None, :] - cent[None]) ** 2).sum(-1)
        new_assign = d.argmin(1)
        if np.all(new_assign == assign):
            break
        assign = new_assign
        for j in range(k):
            m = assign == j
            if m.any():
                cent[j] = x[m].mean(0)
    return assign, cent


def normalise_profiles(profiles: Sequence[dict]) -> np.ndarray:
    """profiles: dicts with bandwidth_mbps, latency_ms, jitter, straggle."""
    keys = ("bandwidth_mbps", "latency_ms", "jitter", "straggle")
    x = np.array([[float(p.get(k, 0.0)) for k in keys] for p in profiles])
    mu, sd = x.mean(0), x.std(0) + 1e-8
    return (x - mu) / sd


def cluster_devices(profiles: Sequence[dict], k: int,
                    seed: int = 0) -> List[int]:
    x = normalise_profiles(profiles)
    assign, _ = kmeans(x, k, seed=seed)
    return assign.tolist()


def reliability_weights(profiles: Sequence[dict],
                        assignments: Sequence[int]) -> List[float]:
    """omega_k (paper eq. 8): softmax over a reliability score =
    bandwidth / (latency * straggle), shared within a cluster."""
    import math
    scores = []
    for p in profiles:
        bw = float(p.get("bandwidth_mbps", 1.0))
        lat = float(p.get("latency_ms", 1.0))
        st = float(p.get("straggle", 1.0))
        scores.append(math.log(max(bw, 1e-3))
                      - 0.1 * math.log(max(lat, 1e-3))
                      - math.log(max(st, 1e-3)))
    # cluster-average the scores (personalised-but-stable weights)
    by_cluster = {}
    for s, a in zip(scores, assignments):
        by_cluster.setdefault(a, []).append(s)
    cl_mean = {a: sum(v) / len(v) for a, v in by_cluster.items()}
    sc = np.array([cl_mean[a] for a in assignments])
    e = np.exp(sc - sc.max())
    w = e / e.sum()
    return w.tolist()
