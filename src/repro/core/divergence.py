"""Divergence-aware update control (paper eq. 9).

D_k(t) = ||theta_k(t) - theta_bar(t)||_2 estimated with fixed random
projections: each pod projects its parameters onto m shared random
directions (scalar dot products, streaming — no extra param-sized buffers),
the cross-pod mean of the projections is computed with a scalar psum, and
the deviation of the projections estimates the parameter divergence
(Johnson-Lindenstrauss).  The cloud-side Scheduler.adapt_interval then
shrinks H when divergence is high and relaxes it when pods agree.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.sync import _pod_info, fleet_axes

N_PROJ = 8


MAX_SAMPLE = 65536


def _leaf_projections(leaf, key, n_proj: int) -> jax.Array:
    """(n_proj,) random projections of one leaf.  Large leaves are strided-
    subsampled to MAX_SAMPLE entries first (same stride on every pod, so the
    projections stay comparable), keeping the cost O(n_proj * 64k)."""
    flat = leaf.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    if n > MAX_SAMPLE:
        stride = n // MAX_SAMPLE
        flat = flat[::stride][:MAX_SAMPLE]
        n = flat.shape[0]
    signs = jax.random.rademacher(
        key, (n_proj, n), dtype=jnp.int8).astype(jnp.float32)
    return signs @ flat / jnp.sqrt(jnp.float32(n))


def project_params(params, seed: int = 17, n_proj: int = N_PROJ) -> jax.Array:
    """-> (n_proj,) projection vector of the whole parameter pytree."""
    leaves = jax.tree_util.tree_leaves(params)
    out = jnp.zeros((n_proj,), jnp.float32)
    for i, leaf in enumerate(leaves):
        key = jax.random.PRNGKey(seed + i * 1009)
        out = out + _leaf_projections(leaf, key, n_proj)
    return out


def pod_divergence(params, mesh, seed: int = 17) -> jax.Array:
    """D_k estimate for the calling pod (inside the per-pod shard_map).
    Returns a scalar; identical-across-pods reference is the pod-mean."""
    proj = project_params(params, seed)
    if _pod_info(mesh) > 1:
        mean = jax.lax.pmean(proj, fleet_axes(mesh))
    else:
        mean = proj
    return jnp.sqrt(jnp.sum((proj - mean) ** 2))


def params_norm_estimate(params, seed: int = 17) -> jax.Array:
    """||theta|| estimate from the same projections (for the relative
    divergence threshold)."""
    proj = project_params(params, seed)
    return jnp.sqrt(jnp.sum(proj * proj))
