"""Hierarchical cloud-edge coordination (paper component 3).

Host-side control layer of the two-tier topology: live device clustering
over nonstationary telemetry (``ClusterState``), per-cluster policies,
and the fleet-slot reliability weights omega that flow into the knapsack
and ``SyncPlan``/``ExecPlan`` as device data.  The execution-side
counterpart is ``core/sync.py``'s two-tier exchange (intra-cluster
aggregation over the fast "edge" mesh axis feeding the compressed
cross-tier ring over "pod").
"""
from repro.hierarchy.cluster import ClusterPolicy, ClusterState

__all__ = ["ClusterPolicy", "ClusterState"]
