"""Live device clustering with hysteresis — the host half of the two-tier
sync topology.

``ClusterState`` is refreshed on the existing non-blocking replan cadence
(see :class:`repro.launch.train.TrainLoop`): each refresh consumes one
telemetry snapshot, warm-starts k-means from the previous centroids, and
applies a hysteresis rule so assignments do not flap under jitter — a
device only moves to a new cluster when the new centroid is a decisively
better fit than its current one.

Everything this module emits is *device data* (reliability weights, budget
bandwidths) or host-side bookkeeping (policies, churn counters): nothing
here introduces a new static jit key, so telemetry-driven re-clustering
never retraces the step function.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.clustering import (kmeans, normalise_profiles,
                                   reliability_weights)


@dataclasses.dataclass
class ClusterPolicy:
    """Per-cluster coordination policy derived from the current telemetry.

    ``omega`` is the cluster's total reliability mass (its share of the
    fleet softmax); ``kept_fraction`` is the compression aggressiveness
    the scheduler would pick for this cluster's mean bandwidth (filled in
    when a config is supplied to :meth:`ClusterState.policies`).
    """
    cluster: int
    members: List[int]
    bandwidth_mbps: float
    latency_ms: float
    straggle: float
    omega: float
    kept_fraction: Optional[float] = None


class ClusterState:
    """Warm-started k-means over telemetry with assignment hysteresis.

    Parameters
    ----------
    n_devices:
        Size of the simulated edge fleet (rows of each telemetry snapshot).
    k:
        Number of clusters.  When the mesh is hierarchical this should be
        the scheduler's ``n_cross`` so clusters map 1:1 onto cross-tier
        pods; on a flat mesh it is the config's ``n_clusters``.
    hysteresis:
        A device reassigns only if the squared distance to the proposed
        centroid is below ``(1 - hysteresis)`` times the distance to its
        current one.  0 disables the filter; 0.15 suppresses jitter-only
        flapping while still tracking genuine drift.
    """

    def __init__(self, n_devices: int, k: int, hysteresis: float = 0.15):
        self.n_devices = int(n_devices)
        self.k = max(1, min(int(k), self.n_devices))
        self.hysteresis = float(hysteresis)
        self.centroids: Optional[np.ndarray] = None
        self.assignments: Optional[List[int]] = None
        self.updates = 0      # update() calls
        self.churn = 0        # total device moves accepted past hysteresis
        self.reclusters = 0   # updates where at least one device moved

    # ------------------------------------------------------------------ #
    # clustering                                                         #
    # ------------------------------------------------------------------ #
    def update(self, telemetry: Sequence[Dict[str, float]]) -> bool:
        """Re-cluster on a fresh snapshot.  Returns True when assignments
        changed (first call always counts as a change)."""
        x = normalise_profiles(telemetry)
        init = self.centroids if (
            self.centroids is not None and len(self.centroids) == self.k
            and self.centroids.shape[1] == x.shape[1]) else None
        assign, cent = kmeans(x, self.k, init=init)
        self.updates += 1
        if self.assignments is None or len(self.assignments) != len(assign):
            self.assignments = [int(a) for a in assign]
            self.centroids = cent
            return True

        d = ((x[:, None, :] - cent[None, :, :]) ** 2).sum(-1)
        keep = 1.0 - self.hysteresis
        out = list(self.assignments)
        moved = 0
        for i, a in enumerate(assign):
            prev = out[i]
            a = int(a)
            if a != prev and d[i, a] < keep * d[i, prev]:
                out[i] = a
                moved += 1
        # Re-center on the post-hysteresis assignment so the next warm
        # start tracks the clustering the fleet actually runs with.
        for j in range(self.k):
            members = [i for i, a in enumerate(out) if a == j]
            if members:
                cent[j] = x[members].mean(axis=0)
        self.assignments = out
        self.centroids = cent
        if moved:
            self.churn += moved
            self.reclusters += 1
            return True
        return False

    # ------------------------------------------------------------------ #
    # preemption-safe host state                                         #
    # ------------------------------------------------------------------ #
    def snapshot(self) -> dict:
        """JSON-able mutable state: warm-start centroids, the hysteresis
        anchor (current assignments) and the churn counters.  Restoring
        this after a preemption keeps the clustering trajectory — and the
        omega weights it feeds — identical to an uninterrupted run."""
        return {
            "centroids": (None if self.centroids is None
                          else [[float(v) for v in row]
                                for row in self.centroids]),
            "assignments": (None if self.assignments is None
                            else list(self.assignments)),
            "updates": self.updates,
            "churn": self.churn,
            "reclusters": self.reclusters,
        }

    def restore_snapshot(self, snap: dict):
        cent = snap.get("centroids")
        self.centroids = (None if cent is None
                          else np.asarray(cent, dtype=np.float64))
        assign = snap.get("assignments")
        self.assignments = None if assign is None else [int(a)
                                                        for a in assign]
        self.updates = int(snap.get("updates", 0))
        self.churn = int(snap.get("churn", 0))
        self.reclusters = int(snap.get("reclusters", 0))

    def _require_assignments(self) -> List[int]:
        if self.assignments is None:
            raise RuntimeError("ClusterState.update() has not been called")
        return self.assignments

    # ------------------------------------------------------------------ #
    # fleet mapping                                                      #
    # ------------------------------------------------------------------ #
    def fleet_slots(self, n_cross: int, n_edge: int) -> List[int]:
        """Map each device to a fleet slot (pod-major: ``pod*n_edge + e``).

        Clusters land on cross-tier pods by cluster id modulo ``n_cross``;
        within a pod, a cluster's devices round-robin over the edge slots.
        With more devices than slots several devices share a slot (their
        reliability mass is summed in :meth:`fleet_omega`)."""
        n_cross = max(int(n_cross), 1)
        n_edge = max(int(n_edge), 1)
        counters: Dict[int, int] = {}
        slots = []
        for a in self._require_assignments():
            pod = a % n_cross
            r = counters.get(pod, 0)
            counters[pod] = r + 1
            slots.append(pod * n_edge + (r % n_edge))
        return slots

    def fleet_omega(self, telemetry: Sequence[Dict[str, float]],
                    n_cross: int, n_edge: int = 1) -> Tuple[float, ...]:
        """Reliability weights omega, one per fleet member, normalised.

        Device-level softmax weights are summed into their fleet slots.
        Slots no device mapped to (fleet wider than the simulated edge
        set) are filled with their pod's mean weight — global mean when a
        whole pod is empty — so no fleet member's contribution is zeroed
        by an accident of the slot mapping."""
        n_cross = max(int(n_cross), 1)
        n_edge = max(int(n_edge), 1)
        w = reliability_weights(telemetry, self._require_assignments())
        om = np.zeros(n_cross * n_edge, dtype=np.float64)
        for s, wi in zip(self.fleet_slots(n_cross, n_edge), w):
            om[s] += float(wi)
        if (om <= 0.0).any():
            grid = om.reshape(n_cross, n_edge)
            pos = om[om > 0.0]
            global_fill = float(pos.mean()) if pos.size else 1.0
            for c in range(n_cross):
                row = grid[c]
                rpos = row[row > 0.0]
                fill = float(rpos.mean()) if rpos.size else global_fill
                row[row <= 0.0] = fill
            om = grid.reshape(-1)
        om = om / om.sum()
        return tuple(float(v) for v in om)

    # ------------------------------------------------------------------ #
    # per-cluster policies                                               #
    # ------------------------------------------------------------------ #
    def policies(self, telemetry: Sequence[Dict[str, float]],
                 cfg=None) -> List[ClusterPolicy]:
        """Per-cluster coordination policies for the current assignment.
        With ``cfg`` (an ACESyncConfig) each policy also carries the
        compression level the scheduler would pick for the cluster's mean
        bandwidth (eq. 5)."""
        assign = self._require_assignments()
        w = reliability_weights(telemetry, assign)
        kept = None
        if cfg is not None:
            from repro.core.scheduler import kept_fraction
            kept = kept_fraction
        out = []
        for j in range(self.k):
            members = [i for i, a in enumerate(assign) if a == j]
            if not members:
                continue
            bw = float(np.mean([telemetry[i]["bandwidth_mbps"]
                                for i in members]))
            out.append(ClusterPolicy(
                cluster=j,
                members=members,
                bandwidth_mbps=bw,
                latency_ms=float(np.mean([telemetry[i]["latency_ms"]
                                          for i in members])),
                straggle=float(np.mean([telemetry[i].get("straggle", 1.0)
                                        for i in members])),
                omega=float(sum(float(w[i]) for i in members)),
                kept_fraction=(None if kept is None else kept(cfg, bw))))
        return out

    def bottleneck_bandwidth(self, telemetry: Sequence[Dict[str, float]],
                             default: float = 50.0) -> float:
        """The slowest cluster's mean bandwidth (Mbps).  The hierarchical
        strategy budgets the cross-tier ring against this: the ring is
        paced by its weakest member pod, so pricing against the fleet mean
        would overshoot the wall-clock budget whenever clusters diverge."""
        pols = self.policies(telemetry)
        if not pols:
            return default
        return min(p.bandwidth_mbps for p in pols)
