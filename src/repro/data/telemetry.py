"""Telemetry traces for the cloud-edge simulation (paper section 4.1-4.2).

Generates per-device/per-pod bandwidth and latency traces matching the
paper's testbed: bandwidth fluctuating in 5-200 Mbps, latency 10-300 ms,
plus jitter and straggle factors.  Traces are deterministic in (seed,
device, step) so simulated runs are reproducible and restart-safe.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List

import numpy as np

BW_MIN, BW_MAX = 5.0, 200.0         # Mbps, paper section 4.2
LAT_MIN, LAT_MAX = 10.0, 300.0      # ms

_MASK64 = (1 << 64) - 1
# splitmix64 multipliers (Steele et al.); also used to mix the counters in.
_MIX_A = 0x9E3779B97F4A7C15
_MIX_B = 0xBF58476D1CE4E5B9
_MIX_C = 0x94D049BB133111EB


def _hash01(seed: int, device_id: int, step: int, salt: int) -> float:
    """Deterministic uniform in [0, 1) from the (seed, device, step, salt)
    counter — a splitmix64 finalizer over the mixed counters.  Replaces
    the seed's per-call ``np.random.RandomState`` construction (~20us of
    Mersenne state init per sample) with a few integer ops, so replaying
    64-device traces for thousands of steps stays off the host control
    loop's critical path.  Bit-stable across platforms: pure 64-bit
    integer arithmetic, no RNG library state."""
    z = (seed * _MIX_A + device_id * _MIX_B + step * _MIX_C + salt) & _MASK64
    z = (z + _MIX_A) & _MASK64
    z ^= z >> 30
    z = (z * _MIX_B) & _MASK64
    z ^= z >> 27
    z = (z * _MIX_C) & _MASK64
    z ^= z >> 31
    return z / 2.0 ** 64


@dataclasses.dataclass
class DeviceProfile:
    device_id: int
    base_bandwidth: float     # Mbps
    base_latency: float       # ms
    jitter: float             # 0..1 relative fluctuation
    straggle: float           # >= 1.0 slowdown factor


def make_profiles(n_devices: int, seed: int = 0) -> List[DeviceProfile]:
    rng = np.random.RandomState(seed)
    profiles = []
    for i in range(n_devices):
        # log-uniform bandwidth within the paper's range; heterogeneous tiers
        bw = float(np.exp(rng.uniform(math.log(BW_MIN), math.log(BW_MAX))))
        lat = float(rng.uniform(LAT_MIN, LAT_MAX))
        jit = float(rng.uniform(0.05, 0.4))
        straggle = float(1.0 + rng.exponential(0.15))
        profiles.append(DeviceProfile(i, bw, lat, jit, straggle))
    return profiles


def bandwidth_at(profile: DeviceProfile, step: int, seed: int = 0) -> float:
    """Smooth + bursty bandwidth fluctuation at a given step (Mbps).

    Deterministic in (seed, device, step) — tests/test_hierarchy.py pins
    golden values so the trace contract survives refactors."""
    phase = (profile.device_id * 997 + seed * 31) % 1000
    slow = math.sin((step + phase) / 50.0) * 0.5 * profile.jitter
    u = _hash01(seed, profile.device_id, step, salt=1)
    burst = (2.0 * u - 1.0) * profile.jitter * 0.5
    bw = profile.base_bandwidth * (1.0 + slow + burst)
    return float(min(max(bw, BW_MIN), BW_MAX))


def latency_at(profile: DeviceProfile, step: int, seed: int = 0) -> float:
    u = _hash01(seed, profile.device_id, step, salt=2)
    lat = profile.base_latency * (1.0 + u * profile.jitter)
    return float(min(max(lat, LAT_MIN), LAT_MAX))


def snapshot(profiles: List[DeviceProfile], step: int,
             seed: int = 0) -> List[Dict]:
    """Telemetry dicts for clustering / scheduling at one step."""
    return [{
        "bandwidth_mbps": bandwidth_at(p, step, seed),
        "latency_ms": latency_at(p, step, seed),
        "jitter": p.jitter,
        "straggle": p.straggle,
    } for p in profiles]


def transfer_seconds(n_bytes: float, bandwidth_mbps: float,
                     latency_ms: float) -> float:
    """Wall-clock for one transfer on a WAN-ish link."""
    return latency_ms / 1e3 + n_bytes * 8 / (bandwidth_mbps * 1e6)
