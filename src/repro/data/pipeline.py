"""Deterministic synthetic data pipeline.

Emulates the paper's corpus (OpenWebText2 + C4 token streams, seq 512-1024,
~80-90M samples) with a seeded on-the-fly token generator so multi-epoch
distributed training is reproducible without any dataset on disk.  The
generator is:

  * deterministic in (seed, step, shard) — restart-safe: the checkpoint
    manifest stores only the step counter;
  * host-parallel: each host materialises only its addressable shard of the
    global batch and assembles a global jax.Array via
    ``jax.make_array_from_callback``;
  * structured enough to be learnable (a tiny LCG-driven Markov chain over
    the vocab) so convergence curves are meaningful in the examples.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ShapeConfig


@dataclasses.dataclass
class PipelineState:
    seed: int
    step: int


class TokenPipeline:
    """Markov-chain token stream -> model input batches."""

    def __init__(self, model, shape: ShapeConfig, seed: int = 0,
                 mesh=None, vocab_cap: int = 32768):
        self.model = model
        self.shape = shape
        self.seed = seed
        self.mesh = mesh
        self.vocab = min(model.cfg.vocab_size, vocab_cap)
        self.state = PipelineState(seed=seed, step=0)
        # fixed random Markov transition structure (succinct: per-token
        # affine map, not a dense table)
        rng = np.random.RandomState(seed)
        self._a = int(rng.randint(1, self.vocab // 2) * 2 + 1)
        self._c = int(rng.randint(1, self.vocab))

    # -- deterministic sample generator ---------------------------------
    def _tokens(self, step: int, row: int, n: int) -> np.ndarray:
        """One sequence, deterministic in (seed, step, row)."""
        rng = np.random.RandomState(
            (self.seed * 1_000_003 + step * 8191 + row) % (2 ** 31 - 1))
        start = rng.randint(self.vocab)
        noise = rng.randint(0, self.vocab, size=n)
        toks = np.empty(n, np.int64)
        t = start
        for i in range(n):
            # mostly-deterministic chain with 10% noise: learnable structure
            t = (self._a * t + self._c) % self.vocab
            toks[i] = t if noise[i] % 10 else noise[i]
        return toks

    def _host_batch(self, step: int) -> dict:
        specs = self.model.input_specs(self.shape)
        out = {}
        for name, spec in specs.items():
            if name == "labels":
                continue  # derived from tokens below
            if spec.dtype == jnp.int32:
                B, S = spec.shape
                arr = np.stack([self._tokens(step, b, S + 1)
                                for b in range(B)])
                out["tokens"] = arr[:, :-1].astype(np.int32)
                out["_labels_full"] = arr[:, 1:].astype(np.int32)
            else:  # frontend stub embeddings
                rng = np.random.RandomState(
                    (self.seed + step * 7919) % (2 ** 31 - 1))
                out[name] = rng.randn(*spec.shape).astype(np.float32) * 0.02
        if "labels" in specs:
            lb = specs["labels"].shape
            full = out.pop("_labels_full")
            if full.shape[1] < lb[1]:
                # frontend tokens prepended: don't score them
                pad = np.zeros((lb[0], lb[1] - full.shape[1]), np.int32)
                full = np.concatenate([pad, full], axis=1)
            out["labels"] = full[:, :lb[1]]
        else:
            out.pop("_labels_full", None)
        return out

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        batch_np = self._host_batch(self.state.step)
        self.state.step += 1
        if self.mesh is None:
            return {k: jnp.asarray(v) for k, v in batch_np.items()}
        from repro.models.shardctx import sharding_for
        shardings = {k: sharding_for(self.mesh, v,
                                     shape=batch_np[k].shape)
                     for k, v in
                     self.model.input_shardings(self.shape).items()}
        return {k: jax.make_array_from_callback(
                    v.shape, shardings[k],
                    lambda idx, vv=v: vv[idx])
                for k, v in batch_np.items()}

    # -- restart support -------------------------------------------------
    def snapshot(self) -> dict:
        return dataclasses.asdict(self.state)

    def restore(self, snap: dict):
        self.state = PipelineState(**snap)

    # -- elastic membership ----------------------------------------------
    def resized(self, batch_rows: int) -> "TokenPipeline":
        """A new pipeline with the batch re-balanced to ``batch_rows``
        (elastic pod-count change keeps rows-per-pod constant), resuming
        at this pipeline's exact stream position — sample contents stay
        deterministic in (seed, step, row)."""
        shape = dataclasses.replace(self.shape, global_batch=batch_rows)
        out = TokenPipeline(self.model, shape, seed=self.seed,
                            mesh=self.mesh)
        out.restore(self.snapshot())
        return out
