"""Fault-tolerant pytree checkpointer (no orbax dependency).

Layout:  <dir>/step_<N>/
            manifest.json        tree structure + leaf index + extras
            leaf_<k>.npy         one .npy per leaf (host-gathered)
         <dir>/LATEST            atomic pointer file

Properties needed at scale and covered here:
  * atomic publish: data written to step_<N>.tmp, fsync'd, renamed, and the
    LATEST pointer updated last — a crash never leaves a half checkpoint
    visible, and a leftover ``step_N.tmp`` from a killed writer is ignored
    by every reader and cleaned by :meth:`prune`;
  * integrity: the manifest carries a CRC-32 checksum plus shape/dtype per
    leaf and a treedef fingerprint; :meth:`restore` verifies both and a
    corrupt / truncated / partial checkpoint is skipped with fallback to
    the newest step that verifies;
  * loud async saves: serialisation runs on a background thread, but a
    failed write is captured and re-raised on the NEXT ``save()`` /
    ``wait()`` — a snapshot can never fail silently, and because the write
    lands in ``.tmp`` first the previous valid checkpoint is untouched;
  * transient-failure retries: the write sequence retries with exponential
    backoff (NFS blips, ENOSPC races with a cleaner) before giving up;
  * elastic restore: leaves are re-sharded on load via device_put with the
    *current* mesh's shardings, so a 2-pod checkpoint restarts fine on 1 pod
    (and vice versa) as long as pod-dim leaves are broadcastable;
  * data-pipeline state and host-side scheduler state ride in the manifest.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
import zlib
from typing import Any, Dict, List, Optional

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


#: chars of str(treedef) kept as the structure fingerprint (bounded so a
#: giant model's manifest stays small; mismatches virtually always differ
#: in the prefix — a changed dict key / NamedTuple field shows up early)
TREEDEF_FP_CHARS = 4096


def _treedef_fp(tree) -> str:
    return str(jax.tree_util.tree_structure(tree))[:TREEDEF_FP_CHARS]


def _leaf_crc(arr: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(arr).tobytes()) & 0xFFFFFFFF


class CheckpointCorruptError(RuntimeError):
    """A checkpoint directory failed integrity verification."""


class Checkpointer:
    #: write attempts per snapshot before the failure is surfaced
    RETRIES = 3
    #: base backoff between attempts (doubles each retry)
    BACKOFF_S = 0.05

    def __init__(self, directory: str):
        self.dir = directory
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        #: steps whose directories failed verification this process —
        #: diagnostics for soak tests / benchmarks
        self.corrupt_steps: List[int] = []

    # ------------------------------------------------------------------
    def _raise_pending(self):
        """Surface a background write failure captured since the last
        call — a failed snapshot is loud, not silent."""
        if self._error is not None:
            err, self._error = self._error, None
            raise RuntimeError(
                f"checkpoint write failed in the background: {err!r} — the "
                f"previous valid checkpoint is untouched") from err

    def save(self, step: int, state, extras: Optional[Dict[str, Any]] = None,
             blocking: bool = False):
        """Snapshot ``state`` (pytree of jax.Arrays) at ``step``."""
        self.wait()             # also re-raises a prior failed write
        leaves, treedef = _flatten(state)
        host_leaves = [np.asarray(jax.device_get(l)) for l in leaves]
        payload = {
            "step": step,
            # structure recorded as a repr fingerprint (NamedTuple nodes are
            # not proto-serialisable); restore is template-based anyway
            "treedef_repr": _treedef_fp(state),
            "n_leaves": len(host_leaves),
            "leaves": [{"shape": list(l.shape), "dtype": str(l.dtype),
                        "crc32": _leaf_crc(l)} for l in host_leaves],
            "extras": extras or {},
        }
        self._thread = threading.Thread(
            target=self._write_guarded, args=(step, host_leaves, payload),
            daemon=True)
        self._thread.start()
        if blocking:
            self.wait()

    def _write_guarded(self, step: int, host_leaves, payload):
        """Background entry point: retry transient failures with backoff,
        capture the terminal one for the next save()/wait().  All attempts
        write into ``.tmp`` first, so the previous valid checkpoint is
        never touched by a failed snapshot."""
        delay = self.BACKOFF_S
        for attempt in range(self.RETRIES):
            try:
                self._write(step, host_leaves, payload)
                return
            except BaseException as e:  # noqa: BLE001 - re-raised on wait
                if attempt == self.RETRIES - 1:
                    self._error = e
                    return
                time.sleep(delay)
                delay *= 2

    def _write(self, step: int, host_leaves, payload):
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        for i, leaf in enumerate(host_leaves):
            np.save(os.path.join(tmp, f"leaf_{i}.npy"), leaf)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(payload, f)
            f.flush()
            os.fsync(f.fileno())
        shutil.rmtree(final, ignore_errors=True)
        os.rename(tmp, final)
        latest_tmp = os.path.join(self.dir, "LATEST.tmp")
        with open(latest_tmp, "w") as f:
            f.write(os.path.basename(final))
            f.flush()
            os.fsync(f.fileno())
        os.replace(latest_tmp, os.path.join(self.dir, "LATEST"))

    def wait(self):
        if self._thread is not None and self._thread.is_alive():
            self._thread.join()
        self._raise_pending()

    # ------------------------------------------------------------------
    # integrity
    # ------------------------------------------------------------------
    def _step_dirs(self) -> List[int]:
        """Complete (non-.tmp) step directories, oldest first."""
        out = []
        for n in os.listdir(self.dir):
            if not n.startswith("step_") or n.endswith(".tmp"):
                continue
            try:
                out.append(int(n.split("_")[1]))
            except (IndexError, ValueError):
                continue
        return sorted(out)

    def _manifest(self, step: int) -> Optional[dict]:
        p = os.path.join(self.dir, f"step_{step:08d}", "manifest.json")
        try:
            with open(p) as f:
                return json.load(f)
        except (OSError, ValueError):
            return None

    def verify(self, step: int, deep: bool = False) -> bool:
        """Structural (and with ``deep`` checksum-level) validation of one
        checkpoint directory: manifest parses, every leaf file exists and —
        deep — its bytes match the recorded shape/dtype/CRC."""
        d = os.path.join(self.dir, f"step_{step:08d}")
        payload = self._manifest(step)
        if payload is None or payload.get("n_leaves") is None:
            return False
        n = int(payload["n_leaves"])
        metas = payload.get("leaves")
        for i in range(n):
            p = os.path.join(d, f"leaf_{i}.npy")
            if not os.path.isfile(p):
                return False
            if not deep:
                continue
            try:
                arr = np.load(p)
            except (OSError, ValueError):
                return False
            if metas is not None:
                m = metas[i]
                if (list(arr.shape) != list(m["shape"])
                        or str(arr.dtype) != m["dtype"]
                        or _leaf_crc(arr) != int(m["crc32"])):
                    return False
        return True

    def valid_steps(self, deep: bool = False) -> List[int]:
        """Steps whose directories pass :meth:`verify`, oldest first."""
        return [s for s in self._step_dirs() if self.verify(s, deep=deep)]

    def latest_step(self) -> Optional[int]:
        """The step LATEST points to — falling back to the newest step
        directory that verifies when the pointer is missing, unparsable,
        or points at a missing/corrupt directory (a crash can land between
        the directory rename and the pointer update)."""
        p = os.path.join(self.dir, "LATEST")
        if os.path.exists(p):
            try:
                with open(p) as f:
                    name = f.read().strip()
                step = int(name.split("_")[1])
                if self.verify(step):
                    return step
            except (OSError, IndexError, ValueError):
                pass
        valid = self.valid_steps()
        return valid[-1] if valid else None

    # ------------------------------------------------------------------
    def _load_leaves(self, step: int, n_expected: int):
        """Load + checksum-verify one checkpoint's leaves.  Raises
        :class:`CheckpointCorruptError` on any integrity failure."""
        d = os.path.join(self.dir, f"step_{step:08d}")
        payload = self._manifest(step)
        if payload is None:
            raise CheckpointCorruptError(f"{d}: unreadable manifest")
        if payload["n_leaves"] != n_expected:
            raise CheckpointCorruptError(
                f"{d}: holds {payload['n_leaves']} leaves, template has "
                f"{n_expected} — tree structure changed")
        metas = payload.get("leaves")
        arrs = []
        for i in range(n_expected):
            p = os.path.join(d, f"leaf_{i}.npy")
            try:
                arr = np.load(p)
            except (OSError, ValueError) as e:
                raise CheckpointCorruptError(
                    f"{d}: leaf_{i}.npy unreadable ({e})") from e
            if metas is not None:
                m = metas[i]
                if list(arr.shape) != list(m["shape"]) \
                        or str(arr.dtype) != m["dtype"]:
                    raise CheckpointCorruptError(
                        f"{d}: leaf_{i}.npy is {arr.dtype}{arr.shape}, "
                        f"manifest says {m['dtype']}{tuple(m['shape'])}")
                if _leaf_crc(arr) != int(m["crc32"]):
                    raise CheckpointCorruptError(
                        f"{d}: leaf_{i}.npy checksum mismatch (bit rot or "
                        f"truncated write)")
            arrs.append(arr)
        return arrs, payload

    def restore(self, template, step: Optional[int] = None,
                shardings=None):
        """Load a checkpoint into the structure of ``template``.

        With ``step=None`` the newest checkpoint is used, and a corrupt or
        partial one (bad checksum, missing/truncated leaf, unreadable
        manifest) is skipped with fallback to the next-newest step that
        verifies.  An explicit ``step`` raises on corruption instead.

        ``shardings``: optional pytree of NamedShardings for elastic
        re-sharding onto the current mesh."""
        leaves, treedef = _flatten(template)
        if step is not None:
            candidates = [step]
        else:
            candidates = list(reversed(self.valid_steps()))
            if not candidates:
                raise FileNotFoundError(f"no checkpoint in {self.dir}")
        last_err: Optional[Exception] = None
        for s in candidates:
            try:
                arrs, payload = self._load_leaves(s, len(leaves))
                break
            except CheckpointCorruptError as e:
                self.corrupt_steps.append(s)
                if step is not None:
                    raise
                print(f"WARNING: skipping corrupt checkpoint: {e}",
                      flush=True)
                last_err = e
        else:
            raise CheckpointCorruptError(
                f"no checkpoint in {self.dir} survived verification "
                f"(last failure: {last_err})")
        want_fp = _treedef_fp(template)
        have_fp = payload.get("treedef_repr")
        if have_fp is not None and have_fp != want_fp:
            raise ValueError(
                f"checkpoint step {payload['step']} was written for a "
                f"different tree structure:\n  saved:    {have_fp[:200]}..."
                f"\n  template: {want_fp[:200]}...\n(same leaf count, "
                f"different treedef — restoring would silently permute "
                f"state leaves)")
        out = []
        sh_leaves = (treedef.flatten_up_to(shardings)
                     if shardings is not None else [None] * len(leaves))
        for i, (arr, tmpl, sh) in enumerate(zip(arrs, leaves, sh_leaves)):
            tshape = tuple(getattr(tmpl, "shape", arr.shape))
            if arr.shape != tshape:
                # elastic pod-count change: leading replica dim broadcast/cut
                if arr.shape[1:] == tshape[1:]:
                    if arr.shape[0] < tshape[0]:
                        reps = [-(-tshape[0] // arr.shape[0])] + \
                            [1] * (arr.ndim - 1)
                        arr = np.tile(arr, reps)[: tshape[0]]
                    else:
                        arr = arr[: tshape[0]]
                else:
                    raise ValueError(
                        f"leaf {i}: shape {arr.shape} != {tshape}")
            if sh is not None:
                out.append(jax.device_put(arr, sh))
            else:
                out.append(jax.numpy.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, out), payload["extras"]

    def prune(self, keep: int = 3):
        """Keep only the newest ``keep`` checkpoints — but never remove
        the step LATEST points to (restore's anchor), and clean leftover
        ``.tmp`` directories from crashed writers."""
        for n in os.listdir(self.dir):
            if n.startswith("step_") and n.endswith(".tmp"):
                shutil.rmtree(os.path.join(self.dir, n), ignore_errors=True)
        protect = self.latest_step()
        steps = self._step_dirs()
        for s in steps[:-keep] if keep > 0 else steps:
            if s == protect:
                continue
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)
