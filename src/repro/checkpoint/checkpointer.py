"""Fault-tolerant pytree checkpointer (no orbax dependency).

Layout:  <dir>/step_<N>/
            manifest.json        tree structure + leaf index + extras
            leaf_<k>.npy         one .npy per leaf (host-gathered)
         <dir>/LATEST            atomic pointer file

Properties needed at scale and covered here:
  * atomic publish: data written to step_<N>.tmp, fsync'd, renamed, and the
    LATEST pointer updated last — a crash never leaves a half checkpoint
    visible;
  * async save: the device->host transfer happens on the caller thread
    (cheap), serialisation runs on a background thread;
  * elastic restore: leaves are re-sharded on load via device_put with the
    *current* mesh's shardings, so a 2-pod checkpoint restarts fine on 1 pod
    (and vice versa) as long as pod-dim leaves are broadcastable;
  * data-pipeline state and host-side scheduler state ride in the manifest.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


class Checkpointer:
    def __init__(self, directory: str):
        self.dir = directory
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------
    def save(self, step: int, state, extras: Optional[Dict[str, Any]] = None,
             blocking: bool = False):
        """Snapshot ``state`` (pytree of jax.Arrays) at ``step``."""
        leaves, treedef = _flatten(state)
        host_leaves = [np.asarray(jax.device_get(l)) for l in leaves]
        payload = {
            "step": step,
            # structure recorded as a repr fingerprint (NamedTuple nodes are
            # not proto-serialisable); restore is template-based anyway
            "treedef_repr": str(jax.tree_util.tree_structure(state))[:4096],
            "n_leaves": len(host_leaves),
            "extras": extras or {},
        }
        self.wait()
        self._thread = threading.Thread(
            target=self._write, args=(step, host_leaves, payload), daemon=True)
        self._thread.start()
        if blocking:
            self.wait()

    def _write(self, step: int, host_leaves, payload):
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        for i, leaf in enumerate(host_leaves):
            np.save(os.path.join(tmp, f"leaf_{i}.npy"), leaf)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(payload, f)
            f.flush()
            os.fsync(f.fileno())
        shutil.rmtree(final, ignore_errors=True)
        os.rename(tmp, final)
        latest_tmp = os.path.join(self.dir, "LATEST.tmp")
        with open(latest_tmp, "w") as f:
            f.write(os.path.basename(final))
            f.flush()
            os.fsync(f.fileno())
        os.replace(latest_tmp, os.path.join(self.dir, "LATEST"))

    def wait(self):
        if self._thread is not None and self._thread.is_alive():
            self._thread.join()

    # ------------------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        p = os.path.join(self.dir, "LATEST")
        if not os.path.exists(p):
            return None
        with open(p) as f:
            name = f.read().strip()
        if not os.path.isdir(os.path.join(self.dir, name)):
            return None
        return int(name.split("_")[1])

    def restore(self, template, step: Optional[int] = None,
                shardings=None):
        """Load a checkpoint into the structure of ``template``.

        ``shardings``: optional pytree of NamedShardings for elastic
        re-sharding onto the current mesh."""
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(f"no checkpoint in {self.dir}")
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            payload = json.load(f)
        leaves, treedef = _flatten(template)
        assert payload["n_leaves"] == len(leaves), "tree structure changed"
        out = []
        sh_leaves = (treedef.flatten_up_to(shardings)
                     if shardings is not None else [None] * len(leaves))
        for i, (tmpl, sh) in enumerate(zip(leaves, sh_leaves)):
            arr = np.load(os.path.join(d, f"leaf_{i}.npy"))
            tshape = tuple(getattr(tmpl, "shape", arr.shape))
            if arr.shape != tshape:
                # elastic pod-count change: leading replica dim broadcast/cut
                if arr.shape[1:] == tshape[1:]:
                    if arr.shape[0] < tshape[0]:
                        reps = [tshape[0] // arr.shape[0]] + \
                            [1] * (arr.ndim - 1)
                        arr = np.tile(arr, reps)[: tshape[0]]
                    else:
                        arr = arr[: tshape[0]]
                else:
                    raise ValueError(
                        f"leaf {i}: shape {arr.shape} != {tshape}")
            if sh is not None:
                out.append(jax.device_put(arr, sh))
            else:
                out.append(jax.numpy.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, out), payload["extras"]

    def prune(self, keep: int = 3):
        """Keep only the newest ``keep`` checkpoints."""
        steps = sorted(
            int(n.split("_")[1]) for n in os.listdir(self.dir)
            if n.startswith("step_") and not n.endswith(".tmp"))
        for s in steps[:-keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)
