"""Griffin-style hybrid LM (recurrentgemma-2b): RG-LRU recurrent blocks with
local sliding-window attention in a (rec, rec, attn) repeating pattern.

RG-LRU recurrence (per channel):
    a_t = exp(-c * softplus(Lambda) * sigmoid(W_a u_t + b_a))
    i_t = sigmoid(W_i u_t + b_i)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * u_t)
evaluated with the same chunked associative scan as mamba; the carried state
is only (B, D_rnn).  Channels are sharded over the "model" axis.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, RunConfig, ShapeConfig
from repro.models import layers as L
from repro.models.mamba import causal_depthwise_conv
from repro.models.shardctx import constrain, batch_spec, seq_spec

RGLRU_C = 8.0
SCAN_CHUNK = 256


def rglru_scan(u, a, h0, *, chunk=SCAN_CHUNK):
    """u, a: (B, S, Dr) input and decay; h0: (B, Dr). Returns (y, hT)."""
    B, S, Dr = u.shape
    chunk = min(chunk, S)
    nc = S // chunk
    assert nc * chunk == S

    def chunk_step(h, inp):
        uc, ac = inp

        def combine(e1, e2):
            a1, b1 = e1
            a2, b2 = e2
            return a1 * a2, b1 * a2 + b2

        a_cum, b_cum = jax.lax.associative_scan(combine, (ac, uc), axis=1)
        hs = a_cum * h[:, None] + b_cum
        return hs[:, -1], hs

    ur = u.reshape(B, nc, chunk, Dr).transpose(1, 0, 2, 3)
    ar = a.reshape(B, nc, chunk, Dr).transpose(1, 0, 2, 3)
    hT, ys = jax.lax.scan(chunk_step, h0.astype(jnp.float32),
                          (ur.astype(jnp.float32), ar.astype(jnp.float32)))
    return ys.transpose(1, 0, 2, 3).reshape(B, S, Dr), hT


def _rec_shapes(cfg):
    D, Dr, W = cfg.d_model, cfg.lru_width, cfg.conv1d_width
    return {
        "w_x": (D, Dr), "w_y": (D, Dr),
        "conv_w": (W, Dr), "conv_b": (Dr,),
        "w_a": (Dr, Dr), "b_a": (Dr,),
        "w_i": (Dr, Dr), "b_i": (Dr,),
        "lam": (Dr,),
        "w_out": (Dr, D),
    }


def _rec_shardings():
    return {
        "w_x": P(None, "data", "model"), "w_y": P(None, "data", "model"),
        "conv_w": P(None, None, "model"), "conv_b": P(None, "model"),
        "w_a": P(None, None, "model"), "b_a": P(None, "model"),
        "w_i": P(None, None, "model"), "b_i": P(None, "model"),
        "lam": P(None, "model"),
        "w_out": P(None, "model", "data"),
    }


def rec_mix(p, x, cfg, cache=None):
    """RG-LRU temporal mixer. x: (B, S, D) -> (y, new_cache)."""
    B, S, D = x.shape
    Dr = cfg.lru_width
    dt = x.dtype
    u = x @ p["w_x"].astype(dt)               # (B,S,Dr)
    gate = x @ p["w_y"].astype(dt)
    u = constrain(u, batch_spec(None, "model"))
    conv_carry = cache["conv"] if cache is not None else None
    u, new_conv = causal_depthwise_conv(u, p["conv_w"].astype(dt),
                                        p["conv_b"], conv_carry)
    r = jax.nn.sigmoid(u @ p["w_a"].astype(dt) + p["b_a"].astype(dt))
    i = jax.nn.sigmoid(u @ p["w_i"].astype(dt) + p["b_i"].astype(dt))
    log_a = (-RGLRU_C * jax.nn.softplus(p["lam"].astype(jnp.float32))
             * r.astype(jnp.float32))
    a = jnp.exp(log_a)
    gated_in = (jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12))
                * (i * u).astype(jnp.float32))
    h0 = (cache["h"] if cache is not None else jnp.zeros((B, Dr), jnp.float32))
    y, hT = rglru_scan(gated_in, a, h0)
    y = y.astype(dt) * jax.nn.gelu(gate)
    y = constrain(y, seq_spec(None))
    out = y @ p["w_out"].astype(dt)
    new_cache = ({"conv": new_conv, "h": hT} if cache is not None else None)
    return constrain(out, seq_spec(None)), new_cache


class GriffinLM:
    """recurrentgemma-style hybrid: groups of (rec, rec, local-attn) plus a
    (rec, rec) tail when n_layers % 3 != 0. Model API compatible."""

    def __init__(self, cfg: ModelConfig, run: Optional[RunConfig] = None):
        self.cfg = cfg
        self.run = run
        self.dtype = jnp.dtype(cfg.dtype)
        self.n_groups = cfg.n_layers // 3
        self.tail_rec = cfg.n_layers - 3 * self.n_groups  # leftover rec layers
        self.group_kinds = ("rec", "rec", "attn")
        self.q_chunk = run.q_chunk if run else 2048
        self.kv_chunk = run.kv_chunk if run else 1024

    # ---- params ----
    def _rec_block_init(self, rng, n):
        shapes = _rec_shapes(self.cfg)
        keys = jax.random.split(rng, len(shapes))
        out = {}
        for k0, (name, sh) in zip(keys, sorted(shapes.items())):
            full = (n,) + sh
            if name == "lam":
                out[name] = jnp.broadcast_to(
                    jnp.linspace(0.1, 1.5, sh[0], dtype=jnp.float32), full)
            elif name.startswith("b_") or name == "conv_b":
                out[name] = jnp.zeros(full, jnp.float32)
            else:
                out[name] = (jax.random.normal(k0, full, jnp.float32)
                             / math.sqrt(sh[0]))
        return out

    def _block_init(self, rng, kind, n):
        cfg = self.cfg
        k1, k2, k3 = jax.random.split(rng, 3)
        blk = {"ln1": jnp.zeros((n, cfg.d_model), jnp.float32),
               "ln2": jnp.zeros((n, cfg.d_model), jnp.float32),
               "ffn": L.mlp_init(k2, cfg, n)}
        if kind == "rec":
            blk["mix"] = self._rec_block_init(k1, n)
        else:
            blk["mix"] = L.attn_init(k1, cfg, n)
        return blk

    def init(self, rng):
        cfg = self.cfg
        keys = jax.random.split(rng, len(self.group_kinds) + self.tail_rec + 1)
        blocks = {f"slot{i}": self._block_init(keys[i], kind, self.n_groups)
                  for i, kind in enumerate(self.group_kinds)}
        tail = {f"slot{i}": self._block_init(
                    keys[len(self.group_kinds) + i], "rec", 1)
                for i in range(self.tail_rec)}
        return {"embed": L.embed_init(keys[-1], cfg),
                "final_norm": jnp.zeros((cfg.d_model,), jnp.float32),
                "blocks": blocks, "tail": tail}

    def _block_specs(self, kind, n):
        cfg = self.cfg
        pd = jnp.dtype(cfg.param_dtype)
        blk = {"ln1": jax.ShapeDtypeStruct((n, cfg.d_model), pd),
               "ln2": jax.ShapeDtypeStruct((n, cfg.d_model), pd),
               "ffn": {k: jax.ShapeDtypeStruct(s, pd)
                       for k, s in L.mlp_specs(cfg, n).items()}}
        if kind == "rec":
            blk["mix"] = {k: jax.ShapeDtypeStruct((n,) + s, pd)
                          for k, s in _rec_shapes(cfg).items()}
        else:
            blk["mix"] = {k: jax.ShapeDtypeStruct(s, pd)
                          for k, s in L.attn_specs(cfg, n).items()}
        return blk

    def param_specs(self):
        cfg = self.cfg
        pd = jnp.dtype(cfg.param_dtype)
        blocks = {f"slot{i}": self._block_specs(kind, self.n_groups)
                  for i, kind in enumerate(self.group_kinds)}
        tail = {f"slot{i}": self._block_specs("rec", 1)
                for i in range(self.tail_rec)}
        return {"embed": jax.ShapeDtypeStruct((cfg.padded_vocab, cfg.d_model), pd),
                "final_norm": jax.ShapeDtypeStruct((cfg.d_model,), pd),
                "blocks": blocks, "tail": tail}

    def _block_shardings(self, kind):
        blk = {"ln1": P(None, None), "ln2": P(None, None),
               "ffn": L.mlp_shardings(self.cfg)}
        blk["mix"] = (_rec_shardings() if kind == "rec"
                      else L.attn_shardings(self.cfg))
        return blk

    def param_shardings(self):
        blocks = {f"slot{i}": self._block_shardings(kind)
                  for i, kind in enumerate(self.group_kinds)}
        tail = {f"slot{i}": self._block_shardings("rec")
                for i in range(self.tail_rec)}
        return {"embed": P("model", None), "final_norm": P(None),
                "blocks": blocks, "tail": tail}

    # ---- cache ----
    def _rec_cache(self, B, n, make):
        cfg = self.cfg
        return {"conv": make((n, B, cfg.conv1d_width - 1, cfg.lru_width),
                             self.dtype),
                "h": make((n, B, cfg.lru_width), jnp.float32)}

    def _attn_cache(self, B, S, n, make):
        cfg = self.cfg
        W = min(S, cfg.sliding_window or S)
        return {"k": make((n, B, W, cfg.n_kv_heads, cfg.head_dim), self.dtype),
                "v": make((n, B, W, cfg.n_kv_heads, cfg.head_dim), self.dtype)}

    def _cache_make(self, B, S, make):
        out = {}
        for i, kind in enumerate(self.group_kinds):
            out[f"slot{i}"] = (self._rec_cache(B, self.n_groups, make)
                               if kind == "rec"
                               else self._attn_cache(B, S, self.n_groups, make))
        for i in range(self.tail_rec):
            out[f"tail{i}"] = self._rec_cache(B, 1, make)
        return out

    def init_cache(self, B, S):
        return self._cache_make(B, S, lambda s, d: jnp.zeros(s, d))

    def cache_specs(self, B, S):
        return self._cache_make(B, S, jax.ShapeDtypeStruct)

    def cache_shardings(self):
        rec = {"conv": P(None, ("pod", "data"), None, "model"),
               "h": P(None, ("pod", "data"), "model")}
        attn = {"k": P(None, ("pod", "data"), None, None, None),
                "v": P(None, ("pod", "data"), None, None, None)}
        out = {}
        for i, kind in enumerate(self.group_kinds):
            out[f"slot{i}"] = rec if kind == "rec" else attn
        for i in range(self.tail_rec):
            out[f"tail{i}"] = rec
        return out

    # ---- inputs ----
    def input_specs(self, shape: ShapeConfig):
        B, it = shape.global_batch, jnp.int32
        if shape.kind == "train":
            return {"tokens": jax.ShapeDtypeStruct((B, shape.seq_len), it),
                    "labels": jax.ShapeDtypeStruct((B, shape.seq_len), it)}
        if shape.kind == "prefill":
            return {"tokens": jax.ShapeDtypeStruct((B, shape.seq_len), it)}
        return {"tokens": jax.ShapeDtypeStruct((B, 1), it)}

    def input_shardings(self, shape: ShapeConfig):
        sp = {"tokens": batch_spec(None)}
        if shape.kind == "train":
            sp["labels"] = batch_spec(None)
        return sp

    def make_batch(self, rng, shape: ShapeConfig):
        specs = self.input_specs(shape)
        keys = jax.random.split(rng, len(specs))
        return {name: jax.random.randint(k0, s.shape, 0, self.cfg.vocab_size,
                                         s.dtype)
                for k0, (name, s) in zip(keys, sorted(specs.items()))}

    # ---- compute ----
    def _apply_block(self, kind, blk, x, *, positions, cache, cache_len):
        cfg = self.cfg
        h = L.rms_norm(x, blk["ln1"], cfg.rms_eps)
        if kind == "rec":
            y, nc = rec_mix(blk["mix"], h, cfg, cache)
        else:
            y, nc = L.attn_apply(blk["mix"], h, cfg, positions=positions,
                                 causal=True, window=cfg.sliding_window,
                                 cache=cache, cache_len=cache_len,
                                 q_chunk=self.q_chunk, kv_chunk=self.kv_chunk)
        x = x + y
        h = L.rms_norm(x, blk["ln2"], cfg.rms_eps)
        return x + L.mlp_apply(blk["ffn"], h), nc

    def _remat(self, f):
        if self.run is None or self.run.remat == "none":
            return f
        return jax.checkpoint(
            f, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)

    def _backbone(self, params, x, *, positions, caches=None, cache_len=None,
                  remat=False):
        kinds = self.group_kinds

        def body(x, sl):
            blocks, cache = sl
            ncs = {}
            for i, kind in enumerate(kinds):
                c = cache[f"slot{i}"] if cache is not None else None
                x, nc = self._apply_block(kind, blocks[f"slot{i}"], x,
                                          positions=positions, cache=c,
                                          cache_len=cache_len)
                ncs[f"slot{i}"] = nc
            return x, (ncs if cache is not None else None)

        fn = self._remat(body) if remat else body
        group_caches = (None if caches is None else
                        {k: v for k, v in caches.items()
                         if k.startswith("slot")})
        x, new_caches = jax.lax.scan(fn, x, (params["blocks"], group_caches))
        # unrolled tail (rec, rec)
        new_tail = {}
        for i in range(self.tail_rec):
            blk = jax.tree.map(lambda a: a[0], params["tail"][f"slot{i}"])
            c = (jax.tree.map(lambda a: a[0], caches[f"tail{i}"])
                 if caches is not None else None)
            x, nc = self._apply_block("rec", blk, x, positions=positions,
                                      cache=c, cache_len=cache_len)
            if caches is not None:
                new_tail[f"tail{i}"] = jax.tree.map(lambda a: a[None], nc)
        x = L.rms_norm(x, params["final_norm"], self.cfg.rms_eps)
        if caches is not None:
            new_caches = dict(new_caches)
            new_caches.update(new_tail)
        return x, new_caches

    def forward(self, params, batch):
        x = L.embed_lookup(params["embed"], batch["tokens"], self.cfg,
                           self.dtype)
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        x, _ = self._backbone(params, x, positions=positions, remat=True)
        return x

    def loss(self, params, batch):
        x = self.forward(params, batch)
        return L.xent_loss_chunked(x, params["embed"], batch["labels"],
                                   self.cfg)

    def prefill(self, params, batch, cache_len=None):
        x = L.embed_lookup(params["embed"], batch["tokens"], self.cfg,
                           self.dtype)
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        caches = self.init_cache(B, cache_len or S)
        x, caches = self._backbone(params, x, positions=positions,
                                   caches=caches)
        logits = L.lm_logits(x[:, -1:, :], params["embed"], self.cfg)
        return logits, caches

    def decode_step(self, params, caches, cache_len, tokens):
        x = L.embed_lookup(params["embed"], tokens, self.cfg, self.dtype)
        B = x.shape[0]
        positions = jnp.broadcast_to(cache_len[None, None], (B, 1))
        x, new_caches = self._backbone(params, x, positions=positions,
                                       caches=caches, cache_len=cache_len)
        logits = L.lm_logits(x, params["embed"], self.cfg)
        return logits, new_caches
