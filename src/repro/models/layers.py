"""Shared pure-JAX building blocks: norms, RoPE, flash-style chunked
attention (train/prefill), flash-decode attention, SwiGLU MLP, embeddings and
a chunked vocab-parallel cross-entropy.

No flax — parameters are plain pytrees of jnp arrays; every block is a pair
(init_fn, apply_fn) operating on explicit param dicts so layers can be
stacked along a leading L axis and driven by ``lax.scan``.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.shardctx import constrain, batch_spec, seq_spec

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rms_norm(x, w, eps: float = 1e-6):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + w.astype(jnp.float32))).astype(dt)


def softcap(x, cap: Optional[float]):
    if cap is None:
        return x
    return jnp.tanh(x / cap) * cap


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope(x, positions, theta: float = 10_000.0):
    """x: (..., S, H, Dh); positions: (..., S) int32."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]  # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# Flash-style chunked attention (training / prefill)
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def chunked_attention(q, k, v, *, causal: bool = True,
                      window: Optional[int] = None,
                      logit_softcap: Optional[float] = None,
                      q_chunk: int = 2048, kv_chunk: int = 1024,
                      q_offset: int = 0):
    """Online-softmax attention; never materialises the (Sq, Sk) matrix.

    q: (B, Sq, H, Dh);  k, v: (B, Sk, KV, Dh)  with H % KV == 0 (GQA).
    Returns (B, Sq, H, Dh).  ``q_offset`` is the absolute position of q[0]
    relative to k[0] (prefill: 0; not used for decode — see
    :func:`decode_attention`).
    """
    B, Sq, H, Dh = q.shape
    _, Sk, KV, _ = k.shape
    G = H // KV
    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Sk)
    nq, nk = Sq // q_chunk, Sk // kv_chunk
    assert nq * q_chunk == Sq and nk * kv_chunk == Sk, (Sq, Sk, q_chunk, kv_chunk)

    scale = 1.0 / math.sqrt(Dh)
    # repeat KV up to H so the head dim stays shardable over "model" even
    # when KV < mesh axis (GQA); per-device the repeat touches only the
    # local head shard
    if G > 1:
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
    k = constrain(k, batch_spec(None, "model", None))
    v = constrain(v, batch_spec(None, "model", None))
    qr = q.reshape(B, nq, q_chunk, H, Dh)
    kr = k.reshape(B, nk, kv_chunk, H, Dh)
    vr = v.reshape(B, nk, kv_chunk, H, Dh)

    # NOTE (§Perf hillclimb, refuted): a triangle pair-list scan that skips
    # fully-masked (q, kv) chunk pairs cut HLO FLOPs 45% on 32k prefill but
    # XLA SPMD turned the accumulator dynamic-slices into per-step
    # all-gathers (>100x collective bytes) — net regression; reverted. The
    # right home for causal block-skipping is a Pallas flash kernel with a
    # static grid (future work).
    def q_step(_, qi):
        qc, qidx = qi  # (B, q_chunk, H, Dh), scalar chunk index
        q_pos = q_offset + qidx * q_chunk + jnp.arange(q_chunk)

        def kv_step(carry, ki):
            m, l, acc = carry
            kc, vc, kidx = ki
            k_pos = kidx * kv_chunk + jnp.arange(kv_chunk)
            s = jnp.einsum("bqhd,bshd->bhqs", qc, kc,
                           preferred_element_type=jnp.float32) * scale
            s = softcap(s, logit_softcap)
            mask = jnp.ones((q_chunk, kv_chunk), bool)
            if causal:
                mask &= q_pos[:, None] >= k_pos[None, :]
            if window is not None:
                mask &= (q_pos[:, None] - k_pos[None, :]) < window
            s = jnp.where(mask, s, NEG_INF)
            s = constrain(s, batch_spec("model", None, None))
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bhqs,bshd->bhqd", p.astype(q.dtype), vc,
                            preferred_element_type=jnp.float32)
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, H, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, H, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, H, q_chunk, Dh), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (kr.transpose(1, 0, 2, 3, 4), vr.transpose(1, 0, 2, 3, 4),
             jnp.arange(nk)))
        l = jnp.maximum(l, 1e-20)
        o = (acc / l[..., None]).astype(q.dtype)  # (B, H, q_chunk, Dh)
        return None, o.transpose(0, 2, 1, 3)      # (B, q_chunk, H, Dh)

    _, o = jax.lax.scan(q_step, None,
                        (qr.transpose(1, 0, 2, 3, 4), jnp.arange(nq)))
    # o: (nq, B, q_chunk, H, Dh) -> (B, Sq, H, Dh)
    o = o.transpose(1, 0, 2, 3, 4).reshape(B, Sq, H, Dh)
    return o


def ring_slot_positions(t, alloc: int):
    """Absolute position held by each ring-cache slot after the token at
    position ``t`` has been written (slot j holds the latest position p <= t
    with p % alloc == j; negative => never written)."""
    j = jnp.arange(alloc)
    return t - jnp.mod(t - j, alloc)


def decode_attention(q, k_cache, v_cache, t, *,
                     window: Optional[int] = None,
                     logit_softcap: Optional[float] = None):
    """Single-token attention over a (possibly sequence-sharded) KV cache.

    q: (B, 1, H, Dh); k_cache/v_cache: (B, S_alloc, KV, Dh) ring caches;
    ``t``: scalar int32 absolute position of the current token (already
    written into the cache).  The softmax over the cache axis is written with
    global ops so XLA's SPMD partitioner inserts the flash-decode style
    max/sum combines when that axis is sharded over "model".
    """
    B, _, H, Dh = q.shape
    _, S, KV, _ = k_cache.shape
    G = H // KV
    scale = 1.0 / math.sqrt(Dh)
    qr = q.reshape(B, KV, G, Dh)
    s = jnp.einsum("bkgd,bskd->bkgs", qr, k_cache,
                   preferred_element_type=jnp.float32) * scale
    s = softcap(s, logit_softcap)
    pos = ring_slot_positions(t, S)
    mask = (pos >= 0) & (pos <= t)
    if window is not None:
        mask &= pos > (t - window)
    s = jnp.where(mask[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    o = jnp.einsum("bkgs,bskd->bkgd", p, v_cache,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, 1, H, Dh).astype(q.dtype)


def ring_write_decode(cache, kv, t):
    """Write one token (B, 1, KV, Dh) into a ring cache at slot t % alloc."""
    alloc = cache.shape[1]
    return jax.lax.dynamic_update_slice(
        cache, kv.astype(cache.dtype), (0, jnp.mod(t, alloc), 0, 0))


def ring_write_prefill(cache, kv):
    """Write a full prefill (B, S, KV, Dh) into a ring cache of alloc W.

    If S <= W this is a plain front write (slot j == position j).  Otherwise
    only the last W positions are kept, placed so position p sits in slot
    p % W (consistent with :func:`ring_slot_positions`).
    """
    B, S, KV, Dh = kv.shape
    W = cache.shape[1]
    if S <= W:
        return jax.lax.dynamic_update_slice(cache, kv.astype(cache.dtype),
                                            (0, 0, 0, 0))
    j = jnp.arange(W)
    src = (S - W) + jnp.mod(j - (S - W), W)  # position stored in slot j
    return jnp.take(kv, src, axis=1).astype(cache.dtype)


# ---------------------------------------------------------------------------
# Attention block (init + apply, train/prefill/decode)
# ---------------------------------------------------------------------------


def attn_init(rng, cfg, n_layers: int):
    D, H, KV, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    k = jax.random.split(rng, 4)
    def init(key, *sh):
        return (jax.random.normal(key, sh, jnp.float32)
                * (1.0 / math.sqrt(sh[-2])))
    p = {
        "wq": init(k[0], n_layers, D, H * Dh),
        "wk": init(k[1], n_layers, D, KV * Dh),
        "wv": init(k[2], n_layers, D, KV * Dh),
        "wo": init(k[3], n_layers, H * Dh, D),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((n_layers, Dh), jnp.float32)
        p["k_norm"] = jnp.zeros((n_layers, Dh), jnp.float32)
    return p


def attn_specs(cfg, n_layers: int):
    D, H, KV, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    shapes = {
        "wq": (n_layers, D, H * Dh), "wk": (n_layers, D, KV * Dh),
        "wv": (n_layers, D, KV * Dh), "wo": (n_layers, H * Dh, D),
    }
    if cfg.qk_norm:
        shapes["q_norm"] = (n_layers, Dh)
        shapes["k_norm"] = (n_layers, Dh)
    return shapes


def attn_shardings(cfg):
    # column-parallel in, row-parallel out; FSDP over "data" on the other dim
    sp = {
        "wq": P(None, "data", "model"), "wk": P(None, "data", "model"),
        "wv": P(None, "data", "model"), "wo": P(None, "model", "data"),
    }
    if cfg.qk_norm:
        sp["q_norm"] = P(None, None)
        sp["k_norm"] = P(None, None)
    return sp


def attn_apply(p, x, cfg, *, positions, causal=True, window=None,
               cache=None, cache_len=None, q_chunk=2048, kv_chunk=1024):
    """x: (B, S, D). cache: dict(k,v) of (B, Smax, KV, Dh) or None.
    Returns (y, new_cache)."""
    B, S, D = x.shape
    H, KV, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    dt = x.dtype
    q = (x @ p["wq"].astype(dt)).reshape(B, S, H, Dh)
    k = (x @ p["wk"].astype(dt)).reshape(B, S, KV, Dh)
    v = (x @ p["wv"].astype(dt)).reshape(B, S, KV, Dh)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.rms_eps)
        k = rms_norm(k, p["k_norm"], cfg.rms_eps)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    q = constrain(q, batch_spec(None, "model", None))
    # unrepeated K/V are replicated over "model" explicitly (KV heads rarely
    # divide the axis); the GQA repeat inside chunked_attention then slices
    # locally instead of triggering involuntary full rematerialisation
    k = constrain(k, batch_spec(None, None, None))
    v = constrain(v, batch_spec(None, None, None))

    new_cache = None
    if cache is not None and cache_len is not None and S == 1:
        # decode: append (ring write) then attend over the cache
        kc = ring_write_decode(cache["k"], k, cache_len)
        vc = ring_write_decode(cache["v"], v, cache_len)
        new_cache = {"k": kc, "v": vc}
        o = decode_attention(q, kc.astype(dt), vc.astype(dt), cache_len,
                             window=window, logit_softcap=cfg.attn_logit_softcap)
    else:
        o = chunked_attention(q, k, v, causal=causal, window=window,
                              logit_softcap=cfg.attn_logit_softcap,
                              q_chunk=q_chunk, kv_chunk=kv_chunk)
        if cache is not None:
            # prefill: write the (tail of the) sequence into the ring cache
            new_cache = {"k": ring_write_prefill(cache["k"], k),
                         "v": ring_write_prefill(cache["v"], v)}
    # a2a the attention output back to sequence-sharded BEFORE the out
    # projection: the contraction then has no model-sharded dim, so XLA
    # gathers the (small) weight instead of all-reducing the (large)
    # residual activation (hillclimb #1, see EXPERIMENTS.md §Perf)
    o = constrain(o, seq_spec(None, None))
    y = o.reshape(B, S, H * Dh) @ p["wo"].astype(dt)
    return constrain(y, seq_spec(None)), new_cache


def cross_attn_apply(p, x, mem, cfg, *, q_chunk=2048, kv_chunk=1024):
    """Encoder-decoder cross attention. mem: (B, Sm, D)."""
    B, S, D = x.shape
    H, KV, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    dt = x.dtype
    q = (x @ p["wq"].astype(dt)).reshape(B, S, H, Dh)
    k = (mem @ p["wk"].astype(dt)).reshape(B, mem.shape[1], KV, Dh)
    v = (mem @ p["wv"].astype(dt)).reshape(B, mem.shape[1], KV, Dh)
    o = chunked_attention(q, k, v, causal=False, q_chunk=q_chunk,
                          kv_chunk=kv_chunk)
    y = o.reshape(B, S, H * Dh) @ p["wo"].astype(dt)
    return y


# ---------------------------------------------------------------------------
# MLP (SwiGLU)
# ---------------------------------------------------------------------------


def mlp_init(rng, cfg, n_layers: int):
    D, F = cfg.d_model, cfg.d_ff
    k = jax.random.split(rng, 3)
    def init(key, *sh):
        return jax.random.normal(key, sh, jnp.float32) / math.sqrt(sh[-2])
    return {"w_gate": init(k[0], n_layers, D, F),
            "w_up": init(k[1], n_layers, D, F),
            "w_down": init(k[2], n_layers, F, D)}


def mlp_specs(cfg, n_layers: int):
    D, F = cfg.d_model, cfg.d_ff
    return {"w_gate": (n_layers, D, F), "w_up": (n_layers, D, F),
            "w_down": (n_layers, F, D)}


def mlp_shardings(cfg):
    return {"w_gate": P(None, "data", "model"),
            "w_up": P(None, "data", "model"),
            "w_down": P(None, "model", "data")}


def mlp_apply(p, x):
    dt = x.dtype
    h = jax.nn.silu(x @ p["w_gate"].astype(dt)) * (x @ p["w_up"].astype(dt))
    h = constrain(h, seq_spec(None))
    y = h @ p["w_down"].astype(dt)
    return constrain(y, seq_spec(None))


# ---------------------------------------------------------------------------
# Embedding + chunked vocab-parallel cross-entropy
# ---------------------------------------------------------------------------


def embed_init(rng, cfg):
    return jax.random.normal(rng, (cfg.padded_vocab, cfg.d_model),
                             jnp.float32) * 0.02


def embed_lookup(emb, tokens, cfg, dtype):
    x = jnp.take(emb, tokens, axis=0).astype(dtype)
    if cfg.emb_scale_by_dim:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), dtype)
    return constrain(x, seq_spec(None))


def lm_logits(x, emb, cfg):
    dt = x.dtype
    logits = jnp.einsum("bsd,vd->bsv", x, emb.astype(dt))
    logits = softcap(logits, cfg.final_logit_softcap)
    return constrain(logits, batch_spec(None, "model"))


def xent_loss_chunked(x, emb, labels, cfg, *, seq_chunk: int = 512,
                      mask=None):
    """Cross-entropy over a huge vocab without materialising full logits.

    x: (B, S, D) final hidden states; labels: (B, S) int32.  Scans over
    sequence chunks; within a chunk the logits are vocab-sharded over
    "model" and the log-sum-exp reduction crosses shards via XLA SPMD.
    """
    B, S, D = x.shape
    seq_chunk = min(seq_chunk, S)
    n = S // seq_chunk
    assert n * seq_chunk == S
    xr = x.reshape(B, n, seq_chunk, D).transpose(1, 0, 2, 3)
    lr = labels.reshape(B, n, seq_chunk).transpose(1, 0, 2)
    if mask is None:
        mask = jnp.ones((B, S), jnp.float32)
    mr = mask.reshape(B, n, seq_chunk).transpose(1, 0, 2)

    def chunk(carry, inp):
        tot, cnt = carry
        xc, lc, mc = inp
        logits = lm_logits(xc, emb, cfg).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * mc
        return (tot + jnp.sum(nll), cnt + jnp.sum(mc)), None

    (tot, cnt), _ = jax.lax.scan(chunk, (jnp.float32(0.0), jnp.float32(0.0)),
                                 (xr, lr, mr))
    return tot / jnp.maximum(cnt, 1.0)
