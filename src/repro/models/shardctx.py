"""Sharding-constraint context.

Model code annotates activations with *logical* PartitionSpecs built from the
canonical axis names ("pod", "data", "model").  When a mesh is installed via
:func:`use_shard_ctx`, the constraints are applied after dropping any axis
the mesh does not have (e.g. single-pod meshes have no "pod" axis, smoke
tests have no mesh at all).  This lets the same model code run on a laptop
CPU and on a 512-chip multi-pod mesh.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat

_state = threading.local()

# canonical axes
BATCH_AXES = ("pod", "data")
MODEL_AXIS = "model"


def current_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


def current_exclude() -> tuple:
    return getattr(_state, "exclude", ())


@contextlib.contextmanager
def use_shard_ctx(mesh: Optional[Mesh], exclude: tuple = ()):
    """Install the ambient mesh for :func:`constrain`.

    ``exclude``: axis names that are MANUAL in the surrounding shard_map
    (e.g. ("pod",) inside the per-pod train step) — they are stripped from
    constraint specs because the arrays there are already per-pod local.
    """
    prev = getattr(_state, "mesh", None)
    prev_ex = getattr(_state, "exclude", ())
    _state.mesh = mesh
    _state.exclude = tuple(exclude)
    try:
        yield
    finally:
        _state.mesh = prev
        _state.exclude = prev_ex


def _norm_axis(ax, names) -> Optional[Union[str, tuple]]:
    """Drop axis names that the mesh doesn't have."""
    if ax is None:
        return None
    if isinstance(ax, str):
        return ax if ax in names else None
    kept = tuple(a for a in ax if a in names)
    if not kept:
        return None
    return kept if len(kept) > 1 else kept[0]


def norm_spec(spec: P, mesh: Mesh, exclude: tuple = ()) -> P:
    names = set(mesh.axis_names) - set(exclude)
    return P(*[_norm_axis(ax, names) for ax in spec])


def fit_spec(spec: P, shape, mesh: Mesh, exclude: tuple = ()) -> P:
    """norm_spec + drop axes whose size doesn't divide the array dim
    (e.g. batch=1 decode can't shard over data=16 — it becomes replicated)."""
    spec = norm_spec(spec, mesh, exclude)
    out = []
    for d, ax in enumerate(spec):
        if ax is None or d >= len(shape):
            out.append(None if d >= len(shape) else ax)
            continue
        axes = (ax,) if isinstance(ax, str) else tuple(ax)
        kept, prod = [], 1
        for a in axes:
            sz = mesh.shape[a]
            if shape[d] % (prod * sz) == 0:
                kept.append(a)
                prod *= sz
        out.append(tuple(kept) if len(kept) > 1
                   else (kept[0] if kept else None))
    return P(*out)


def constrain(x, spec: P):
    """with_sharding_constraint against the ambient mesh (no-op without one).

    Inside a shard_map manual region (exclude set) a concrete
    NamedSharding's mesh would clash with the context AbstractMesh whose
    manual axes differ — a bare PartitionSpec resolves against the context
    mesh instead."""
    mesh = current_mesh()
    if mesh is None:
        return x
    if set(current_exclude()) >= set(mesh.axis_names):
        return x  # fully-manual region: nothing left to constrain
    fitted = fit_spec(spec, x.shape, mesh, current_exclude())
    if current_exclude():
        if compat.PARTIAL_MANUAL:
            return jax.lax.with_sharding_constraint(x, fitted)
        # old jax: bare specs only resolve under a physical-mesh context
        with mesh:
            return jax.lax.with_sharding_constraint(x, fitted)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, fitted))


def batch_spec(*rest) -> P:
    """P(("pod","data"), *rest) — batch-sharded leading dim."""
    return P(BATCH_AXES, *rest)


def seq_spec(*rest) -> P:
    """P(("pod","data"), "model", *rest) — batch + sequence-parallel
    activations (Megatron-SP / Ulysses style): residual-stream tensors are
    sharded over "model" along the sequence dim so per-layer saved
    activations scale with the full chip count."""
    return P(BATCH_AXES, MODEL_AXIS, *rest)


def token_spec(*rest) -> P:
    """P(("pod","data","model"), *rest) — fully token-sharded flat (T, ...)
    tensors (MoE dispatch source layout)."""
    return P(BATCH_AXES + (MODEL_AXIS,), *rest)


def sharding_for(mesh: Mesh, spec: P, shape=None) -> NamedSharding:
    if shape is not None:
        return NamedSharding(mesh, fit_spec(spec, shape, mesh))
    return NamedSharding(mesh, norm_spec(spec, mesh))
