"""Encoder-decoder transformer (seamless-m4t-medium backbone).

Per the assignment spec, the audio frontend is a STUB: ``input_specs``
provides precomputed frame embeddings (B, F, d_model) for the encoder; the
decoder is a standard causal transformer with cross-attention.  Frame count
F = seq_len // audio_downsample for train/prefill shapes.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, RunConfig, ShapeConfig
from repro.models import layers as L
from repro.models.shardctx import constrain, batch_spec, seq_spec


class EncDecTransformer:
    """Enc-dec model. Model-API compatible; decode uses a self-attention ring
    cache plus per-layer cached cross-attention K/V."""

    def __init__(self, cfg: ModelConfig, run: Optional[RunConfig] = None):
        self.cfg = cfg
        self.run = run
        self.dtype = jnp.dtype(cfg.dtype)
        assert cfg.n_enc_layers > 0
        self.q_chunk = run.q_chunk if run else 2048
        self.kv_chunk = run.kv_chunk if run else 1024

    def frames_len(self, shape: ShapeConfig) -> int:
        return max(64, shape.seq_len // self.cfg.audio_downsample)

    # ---- params ----
    def _enc_block_init(self, rng, n):
        cfg = self.cfg
        k1, k2 = jax.random.split(rng)
        return {"attn": L.attn_init(k1, cfg, n),
                "ffn": L.mlp_init(k2, cfg, n),
                "ln1": jnp.zeros((n, cfg.d_model), jnp.float32),
                "ln2": jnp.zeros((n, cfg.d_model), jnp.float32)}

    def _dec_block_init(self, rng, n):
        cfg = self.cfg
        k1, k2, k3 = jax.random.split(rng, 3)
        return {"self_attn": L.attn_init(k1, cfg, n),
                "cross_attn": L.attn_init(k2, cfg, n),
                "ffn": L.mlp_init(k3, cfg, n),
                "ln1": jnp.zeros((n, cfg.d_model), jnp.float32),
                "ln2": jnp.zeros((n, cfg.d_model), jnp.float32),
                "ln3": jnp.zeros((n, cfg.d_model), jnp.float32)}

    def init(self, rng):
        cfg = self.cfg
        k1, k2, k3 = jax.random.split(rng, 3)
        return {"embed": L.embed_init(k3, cfg),
                "enc_blocks": self._enc_block_init(k1, cfg.n_enc_layers),
                "dec_blocks": self._dec_block_init(k2, cfg.n_layers),
                "enc_norm": jnp.zeros((cfg.d_model,), jnp.float32),
                "final_norm": jnp.zeros((cfg.d_model,), jnp.float32)}

    def param_specs(self):
        cfg = self.cfg
        pd = jnp.dtype(cfg.param_dtype)
        sd = jax.ShapeDtypeStruct

        def attn_s(n):
            return {k: sd(s, pd) for k, s in L.attn_specs(cfg, n).items()}

        def mlp_s(n):
            return {k: sd(s, pd) for k, s in L.mlp_specs(cfg, n).items()}

        ne, nd = cfg.n_enc_layers, cfg.n_layers
        return {
            "embed": sd((cfg.padded_vocab, cfg.d_model), pd),
            "enc_blocks": {"attn": attn_s(ne), "ffn": mlp_s(ne),
                           "ln1": sd((ne, cfg.d_model), pd),
                           "ln2": sd((ne, cfg.d_model), pd)},
            "dec_blocks": {"self_attn": attn_s(nd), "cross_attn": attn_s(nd),
                           "ffn": mlp_s(nd),
                           "ln1": sd((nd, cfg.d_model), pd),
                           "ln2": sd((nd, cfg.d_model), pd),
                           "ln3": sd((nd, cfg.d_model), pd)},
            "enc_norm": sd((cfg.d_model,), pd),
            "final_norm": sd((cfg.d_model,), pd),
        }

    def param_shardings(self):
        cfg = self.cfg
        a, m = L.attn_shardings(cfg), L.mlp_shardings(cfg)
        ln = P(None, None)
        return {
            "embed": P("model", None),
            "enc_blocks": {"attn": a, "ffn": m, "ln1": ln, "ln2": ln},
            "dec_blocks": {"self_attn": a, "cross_attn": a, "ffn": m,
                           "ln1": ln, "ln2": ln, "ln3": ln},
            "enc_norm": P(None),
            "final_norm": P(None),
        }

    # ---- cache ----
    def init_cache(self, B, S, F=None):
        return self._cache(B, S, F or S // self.cfg.audio_downsample,
                           lambda s, d: jnp.zeros(s, d))

    def cache_specs(self, B, S, F=None):
        return self._cache(B, S, F or max(64, S // self.cfg.audio_downsample),
                           jax.ShapeDtypeStruct)

    def _cache(self, B, S, F, make):
        cfg = self.cfg
        nd = cfg.n_layers
        kv = (nd, B, S, cfg.n_kv_heads, cfg.head_dim)
        ckv = (nd, B, F, cfg.n_kv_heads, cfg.head_dim)
        return {"self": {"k": make(kv, self.dtype), "v": make(kv, self.dtype)},
                "cross": {"k": make(ckv, self.dtype),
                          "v": make(ckv, self.dtype)}}

    def cache_shardings(self):
        sp = P(None, ("pod", "data"), "model", None, None)
        return {"self": {"k": sp, "v": sp}, "cross": {"k": sp, "v": sp}}

    # ---- inputs ----
    def input_specs(self, shape: ShapeConfig):
        B, it = shape.global_batch, jnp.int32
        F = self.frames_len(shape)
        fr = jax.ShapeDtypeStruct((B, F, self.cfg.d_model), jnp.float32)
        if shape.kind == "train":
            return {"frames": fr,
                    "tokens": jax.ShapeDtypeStruct((B, shape.seq_len), it),
                    "labels": jax.ShapeDtypeStruct((B, shape.seq_len), it)}
        if shape.kind == "prefill":
            return {"frames": fr,
                    "tokens": jax.ShapeDtypeStruct((B, shape.seq_len), it)}
        return {"tokens": jax.ShapeDtypeStruct((B, 1), it)}

    def input_shardings(self, shape: ShapeConfig):
        sp = {"tokens": batch_spec(None)}
        if shape.kind != "decode":
            sp["frames"] = batch_spec(None, None)
        if shape.kind == "train":
            sp["labels"] = batch_spec(None)
        return sp

    def make_batch(self, rng, shape: ShapeConfig):
        specs = self.input_specs(shape)
        keys = jax.random.split(rng, len(specs))
        out = {}
        for k0, (name, s) in zip(keys, sorted(specs.items())):
            if s.dtype == jnp.int32:
                out[name] = jax.random.randint(k0, s.shape, 0,
                                               self.cfg.vocab_size, s.dtype)
            else:
                out[name] = jax.random.normal(k0, s.shape, s.dtype)
        return out

    # ---- compute ----
    def _remat(self, f):
        if self.run is None or self.run.remat == "none":
            return f
        return jax.checkpoint(
            f, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)

    def encode(self, params, frames, remat=False):
        cfg = self.cfg
        x = frames.astype(self.dtype)
        x = constrain(x, seq_spec(None))
        B, F, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(F)[None], (B, F))

        def body(x, blk):
            h = L.rms_norm(x, blk["ln1"], cfg.rms_eps)
            h, _ = L.attn_apply(blk["attn"], h, cfg, positions=positions,
                                causal=False, q_chunk=self.q_chunk,
                                kv_chunk=self.kv_chunk)
            x = x + h
            h = L.rms_norm(x, blk["ln2"], cfg.rms_eps)
            return x + L.mlp_apply(blk["ffn"], h), None

        fn = self._remat(body) if remat else body
        x, _ = jax.lax.scan(fn, x, params["enc_blocks"])
        return L.rms_norm(x, params["enc_norm"], cfg.rms_eps)

    def _decoder(self, params, x, mem, *, positions, caches=None,
                 cache_len=None, remat=False):
        """mem: encoder output (B, F, D) for train/prefill; None for decode
        (cross K/V comes from the cache)."""
        cfg = self.cfg
        decode = mem is None

        def body(x, sl):
            blk, cache = sl
            h = L.rms_norm(x, blk["ln1"], cfg.rms_eps)
            c_self = cache["self"] if cache is not None else None
            h, nc_self = L.attn_apply(blk["self_attn"], h, cfg,
                                      positions=positions, causal=True,
                                      cache=c_self, cache_len=cache_len,
                                      q_chunk=self.q_chunk,
                                      kv_chunk=self.kv_chunk)
            x = x + h
            h = L.rms_norm(x, blk["ln2"], cfg.rms_eps)
            if decode:
                # cross-attention against cached K/V
                ca = blk["cross_attn"]
                B = x.shape[0]
                q = (h @ ca["wq"].astype(h.dtype)).reshape(
                    B, 1, cfg.n_heads, cfg.head_dim)
                F = cache["cross"]["k"].shape[1]
                o = L.decode_attention(q, cache["cross"]["k"].astype(h.dtype),
                                       cache["cross"]["v"].astype(h.dtype),
                                       jnp.int32(F - 1))
                h = o.reshape(B, 1, -1) @ ca["wo"].astype(h.dtype)
                nc_cross = cache["cross"]
            else:
                h = L.cross_attn_apply(blk["cross_attn"], h, mem, cfg,
                                       q_chunk=self.q_chunk,
                                       kv_chunk=self.kv_chunk)
                if cache is not None:
                    ca = blk["cross_attn"]
                    B, F, _ = mem.shape
                    ck = (mem @ ca["wk"].astype(mem.dtype)).reshape(
                        B, F, cfg.n_kv_heads, cfg.head_dim)
                    cv = (mem @ ca["wv"].astype(mem.dtype)).reshape(
                        B, F, cfg.n_kv_heads, cfg.head_dim)
                    nc_cross = {"k": ck.astype(cache["cross"]["k"].dtype),
                                "v": cv.astype(cache["cross"]["v"].dtype)}
                else:
                    nc_cross = None
            x = x + h
            h = L.rms_norm(x, blk["ln3"], cfg.rms_eps)
            x = x + L.mlp_apply(blk["ffn"], h)
            nc = ({"self": nc_self, "cross": nc_cross}
                  if cache is not None else None)
            return x, nc

        fn = self._remat(body) if remat else body
        x, new_caches = jax.lax.scan(fn, x, (params["dec_blocks"], caches))
        return L.rms_norm(x, params["final_norm"], cfg.rms_eps), new_caches

    def forward(self, params, batch):
        mem = self.encode(params, batch["frames"], remat=True)
        x = L.embed_lookup(params["embed"], batch["tokens"], self.cfg,
                           self.dtype)
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        x, _ = self._decoder(params, x, mem, positions=positions, remat=True)
        return x

    def loss(self, params, batch):
        x = self.forward(params, batch)
        return L.xent_loss_chunked(x, params["embed"], batch["labels"],
                                   self.cfg)

    def prefill(self, params, batch, cache_len=None):
        mem = self.encode(params, batch["frames"])
        x = L.embed_lookup(params["embed"], batch["tokens"], self.cfg,
                           self.dtype)
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        caches = self.init_cache(B, cache_len or S, mem.shape[1])
        x, caches = self._decoder(params, x, mem, positions=positions,
                                  caches=caches)
        logits = L.lm_logits(x[:, -1:, :], params["embed"], self.cfg)
        return logits, caches

    def decode_step(self, params, caches, cache_len, tokens):
        x = L.embed_lookup(params["embed"], tokens, self.cfg, self.dtype)
        B = x.shape[0]
        positions = jnp.broadcast_to(cache_len[None, None], (B, 1))
        x, new_caches = self._decoder(params, x, None, positions=positions,
                                      caches=caches, cache_len=cache_len)
        logits = L.lm_logits(x, params["embed"], self.cfg)
        return logits, new_caches
