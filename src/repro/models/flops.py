"""Analytic MODEL_FLOPS per (arch, shape) — the "useful compute" yardstick
for the roofline's  MODEL_FLOPS / HLO_FLOPs  ratio.

Per the assignment spec:  MODEL_FLOPS = 6*N*D for training (N = params,
active params for MoE; D = tokens), 2*N*D for inference (forward only).
Attention's quadratic term is NOT included here (that is part of why
HLO_FLOPs > MODEL_FLOPS at long sequence lengths, alongside remat recompute
— the ratio makes both visible).
"""
from __future__ import annotations

from repro.configs.base import ModelConfig, ShapeConfig


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch
