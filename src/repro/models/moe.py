"""Mixture-of-Experts FFN (GShard/Switch-style capacity dispatch) with
expert parallelism over the "model" mesh axis and FSDP over "data".

Dispatch pipeline (all global ops; XLA SPMD inserts the all-to-alls between
the token-sharded and expert-sharded layouts):
  router logits -> top-k experts/token -> position-in-expert via one-hot
  cumsum -> scatter into (E*C, D) buffer -> batched expert FFN -> gather back
  -> gate-weighted combine.  Tokens over capacity are dropped (standard).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat


def moe_init(rng, cfg, n_layers: int):
    D, Fe, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    k = jax.random.split(rng, 4)
    def init(key, *sh):
        return jax.random.normal(key, sh, jnp.float32) / math.sqrt(sh[-2])
    return {
        "router": jax.random.normal(k[0], (n_layers, D, E), jnp.float32) * 0.02,
        "w_gate": init(k[1], n_layers, E, D, Fe),
        "w_up": init(k[2], n_layers, E, D, Fe),
        "w_down": init(k[3], n_layers, E, Fe, D),
    }


def moe_specs(cfg, n_layers: int):
    D, Fe, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    return {"router": (n_layers, D, E),
            "w_gate": (n_layers, E, D, Fe),
            "w_up": (n_layers, E, D, Fe),
            "w_down": (n_layers, E, Fe, D)}


def moe_shardings(cfg):
    # experts over "model" (EP), embed dim over "data" (FSDP)
    return {"router": P(None, None, None),
            "w_gate": P(None, "model", "data", None),
            "w_up": P(None, "model", "data", None),
            "w_down": P(None, "model", None, "data")}


def capacity(n_tokens: int, cfg) -> int:
    c = int(math.ceil(cfg.capacity_factor * n_tokens *
                      cfg.experts_per_token / cfg.n_experts))
    # round up to a lane-friendly multiple, floor of 8
    return max(8, ((c + 7) // 8) * 8)


def _dispatch_local(xf, logits, cfg, C):
    """Device-local capacity dispatch. xf: (T, D); logits: (T, E) f32.
    Returns (ebuf (E, C, D), eidx (T, K), pos_c (T, K), gate_keep (T, K))."""
    T, D = xf.shape
    E, K = cfg.n_experts, cfg.experts_per_token
    dt = xf.dtype
    gates, eidx = jax.lax.top_k(logits, K)                  # (T, K)
    gates = jax.nn.softmax(gates, axis=-1)
    flat_e = eidx.reshape(T * K)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)     # (T*K, E)
    pos = (jnp.cumsum(onehot, axis=0) * onehot).sum(-1) - 1
    keep = pos < C
    pos_c = jnp.where(keep, pos, C).reshape(T, K)           # C = drop row
    gate_keep = (gates * keep.reshape(T, K)).astype(dt)
    vals = (xf[:, None, :] * jnp.ones((1, K, 1), dt)).reshape(T * K, D)
    vals = vals * keep[:, None].astype(dt)
    ebuf = jnp.zeros((E, C, D), dt)
    ebuf = ebuf.at[flat_e, pos_c.reshape(-1)].add(vals, mode="drop")
    return ebuf, eidx, pos_c, gate_keep


def _combine_local(out_ebuf, eidx, pos_c, gate_keep):
    """Inverse of dispatch: gather (T, K, D) rows and gate-combine."""
    E, C, D = out_ebuf.shape
    picked = out_ebuf[eidx, jnp.minimum(pos_c, C - 1)]      # (T, K, D)
    return (picked * gate_keep[..., None]).sum(axis=1)      # (T, D)


def _expert_ffn(ebuf, wg, wu, wd):
    dt = ebuf.dtype
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", ebuf, wg.astype(dt))) * \
        jnp.einsum("ecd,edf->ecf", ebuf, wu.astype(dt))
    return jnp.einsum("ecf,efd->ecd", h, wd.astype(dt))


def moe_apply(p, x, cfg):
    """x: (B, S, D) -> (B, S, D).

    With a mesh: explicit expert parallelism inside a shard_map — tokens
    stay in their (data, model) shard, experts live on "model" peers, and
    the dispatch/return travel via all_to_all over "model"; expert weights
    (FSDP over "data") are all-gathered just-in-time.  Without a mesh the
    same math runs single-device.
    """
    from repro.models.shardctx import (current_mesh, current_exclude,
                                       fit_spec)
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.experts_per_token
    mesh = current_mesh()

    def local(xl, router, wg, wu, wd, *, ep_axis=None, fsdp_axis=None):
        Bl, Sl, Dl = xl.shape
        T = Bl * Sl
        xf = xl.reshape(T, Dl)
        if fsdp_axis is not None:
            wg = jax.lax.all_gather(wg, fsdp_axis, axis=1, tiled=True)
            wu = jax.lax.all_gather(wu, fsdp_axis, axis=1, tiled=True)
            wd = jax.lax.all_gather(wd, fsdp_axis, axis=2, tiled=True)
        logits = xf.astype(jnp.float32) @ router.astype(jnp.float32)
        C = capacity(T, cfg)
        ebuf, eidx, pos_c, gk = _dispatch_local(xf, logits, cfg, C)
        if ep_axis is not None:
            # (E, C, D) -> (E_loc, P*C, D): send each expert to its owner
            ebuf = jax.lax.all_to_all(ebuf, ep_axis, split_axis=0,
                                      concat_axis=1, tiled=True)
        out = _expert_ffn(ebuf, wg, wu, wd)
        if ep_axis is not None:
            out = jax.lax.all_to_all(out, ep_axis, split_axis=1,
                                     concat_axis=0, tiled=True)
        y = _combine_local(out, eidx, pos_c, gk)
        return y.reshape(Bl, Sl, Dl)

    if mesh is None:
        return local(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])

    excl = current_exclude()
    names = set(mesh.axis_names) - set(excl)
    if not names:
        # fully-manual enclosing region (old-jax compat): tokens/weights
        # are device-local replicas — run the single-device math
        return local(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])
    ep_axis = "model" if ("model" in names and E % mesh.shape["model"] == 0) \
        else None
    fsdp_axis = "data" if "data" in names else None
    x_spec = fit_spec(P(("pod", "data"), "model", None), x.shape, mesh, excl)
    if ep_axis is None or "model" not in str(x_spec):
        # tokens not seq-sharded (decode) — still fine, compute replicated
        pass
    w_specs = {k: fit_spec(v, p[k].shape, mesh, excl)
               for k, v in (("router", P(None, None)),
                            ("w_gate", P("model", "data", None)),
                            ("w_up", P("model", "data", None)),
                            ("w_down", P("model", None, "data")))}
    if ep_axis is None:
        w_specs = {k: fit_spec(P(*([None] * len(p[k].shape))), p[k].shape,
                               mesh, excl) for k in w_specs}
        fsdp = None
    else:
        fsdp = fsdp_axis
    out_spec = x_spec

    fn = functools.partial(local, ep_axis=ep_axis, fsdp_axis=fsdp)
    smapped = compat.shard_map(
        fn, mesh,
        in_specs=(x_spec, w_specs["router"], w_specs["w_gate"],
                  w_specs["w_up"], w_specs["w_down"]),
        out_specs=out_spec, manual_axes=names,
        # enclosing manual region (excl) provides the context mesh
        infer_mesh=bool(excl))
    return smapped(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])


def load_balance_loss(logits_f32, eidx, cfg):
    """Switch-style auxiliary load-balance loss (optional)."""
    E = cfg.n_experts
    me = jnp.mean(jax.nn.softmax(logits_f32, -1), axis=0)       # router prob mass
    ce = jnp.mean(jax.nn.one_hot(eidx[:, 0], E, dtype=jnp.float32), axis=0)
    return E * jnp.sum(me * ce)
