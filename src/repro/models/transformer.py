"""Decoder-only transformer LM (dense + gemma2-style local/global + VLM
frontend stub) built on repro.models.layers.

Layer stacking: layers are grouped into repeating *groups* so that scan can
drive heterogeneous patterns with static per-slot flavours:
  - "global"        -> group = (global,)           x L
  - "local_global"  -> group = (local, global)     x L/2   (gemma2)
Params for each slot are stacked along a leading n_groups axis and the whole
stack is driven by one ``lax.scan`` (small HLO, remat-friendly).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, RunConfig, ShapeConfig
from repro.models import layers as L
from repro.models.shardctx import batch_spec


def _norm_shapes(cfg, n, post):
    d = {"ln1": (n, cfg.d_model), "ln2": (n, cfg.d_model)}
    if post:
        d["ln1_post"] = (n, cfg.d_model)
        d["ln2_post"] = (n, cfg.d_model)
    return d


class DenseTransformer:
    """Dense decoder-only LM. Also the base for the MoE variant."""

    def __init__(self, cfg: ModelConfig, run: Optional[RunConfig] = None):
        self.cfg = cfg
        self.run = run
        self.dtype = jnp.dtype(cfg.dtype)
        if cfg.layer_pattern == "local_global":
            assert cfg.n_layers % 2 == 0
            self.group_kinds = ("local", "global")
            self.n_groups = cfg.n_layers // 2
        else:
            self.group_kinds = ("global",)
            self.n_groups = cfg.n_layers
        self.q_chunk = run.q_chunk if run else 2048
        self.kv_chunk = run.kv_chunk if run else 1024

    # ---------------- params ----------------
    def _ffn_init(self, rng, n):
        return L.mlp_init(rng, self.cfg, n)

    def _ffn_specs(self, n):
        return L.mlp_specs(self.cfg, n)

    def _ffn_shardings(self):
        return L.mlp_shardings(self.cfg)

    def _ffn_apply(self, p, x):
        return L.mlp_apply(p, x)

    def init(self, rng):
        cfg, n = self.cfg, self.n_groups
        keys = jax.random.split(rng, 2 * len(self.group_kinds) + 1)
        blocks = {}
        for i, kind in enumerate(self.group_kinds):
            blk = {
                "attn": L.attn_init(keys[2 * i], cfg, n),
                "ffn": self._ffn_init(keys[2 * i + 1], n),
            }
            for k, sh in _norm_shapes(cfg, n, cfg.post_norms).items():
                blk[k] = jnp.zeros(sh, jnp.float32)
            blocks[f"slot{i}"] = blk
        params = {
            "embed": L.embed_init(keys[-1], cfg),
            "final_norm": jnp.zeros((cfg.d_model,), jnp.float32),
            "blocks": blocks,
        }
        return params

    def param_specs(self):
        cfg, n = self.cfg, self.n_groups
        pd = jnp.dtype(cfg.param_dtype)
        blocks = {}
        for i, kind in enumerate(self.group_kinds):
            blk = {"attn": {k: jax.ShapeDtypeStruct(s, pd)
                            for k, s in L.attn_specs(cfg, n).items()},
                   "ffn": {k: jax.ShapeDtypeStruct(s, pd)
                           for k, s in self._ffn_specs(n).items()}}
            for k, sh in _norm_shapes(cfg, n, cfg.post_norms).items():
                blk[k] = jax.ShapeDtypeStruct(sh, pd)
            blocks[f"slot{i}"] = blk
        return {
            "embed": jax.ShapeDtypeStruct((cfg.padded_vocab, cfg.d_model), pd),
            "final_norm": jax.ShapeDtypeStruct((cfg.d_model,), pd),
            "blocks": blocks,
        }

    def param_shardings(self):
        cfg = self.cfg
        blocks = {}
        for i, kind in enumerate(self.group_kinds):
            blk = {"attn": L.attn_shardings(cfg),
                   "ffn": self._ffn_shardings()}
            for k in _norm_shapes(cfg, 1, cfg.post_norms):
                blk[k] = P(None, None)
            blocks[f"slot{i}"] = blk
        return {
            "embed": P("model", None),
            "final_norm": P(None),
            "blocks": blocks,
        }

    # ---------------- cache ----------------
    def _slot_cache_shape(self, kind, B, S):
        cfg = self.cfg
        if kind == "local" and cfg.sliding_window:
            S = min(S, cfg.sliding_window)
        return (self.n_groups, B, S, cfg.n_kv_heads, cfg.head_dim)

    def init_cache(self, B, S):
        return {f"slot{i}": {"k": jnp.zeros(self._slot_cache_shape(k, B, S),
                                            self.dtype),
                             "v": jnp.zeros(self._slot_cache_shape(k, B, S),
                                            self.dtype)}
                for i, k in enumerate(self.group_kinds)}

    def cache_specs(self, B, S):
        return {f"slot{i}": {"k": jax.ShapeDtypeStruct(
                                 self._slot_cache_shape(k, B, S), self.dtype),
                             "v": jax.ShapeDtypeStruct(
                                 self._slot_cache_shape(k, B, S), self.dtype)}
                for i, k in enumerate(self.group_kinds)}

    def cache_shardings(self):
        # sequence dim sharded over "model" (flash-decode combine), batch over
        # ("pod","data")
        sp = P(None, ("pod", "data"), "model", None, None)
        return {f"slot{i}": {"k": sp, "v": sp}
                for i in range(len(self.group_kinds))}

    # ---------------- inputs ----------------
    def text_len(self, shape: ShapeConfig) -> int:
        if self.cfg.frontend == "vision_stub" and shape.kind != "decode":
            return shape.seq_len - self.cfg.n_patches
        return shape.seq_len

    def input_specs(self, shape: ShapeConfig):
        B = shape.global_batch
        it = jnp.int32
        if shape.kind == "train":
            S = self.text_len(shape)
            batch = {"tokens": jax.ShapeDtypeStruct((B, S), it),
                     "labels": jax.ShapeDtypeStruct((B, shape.seq_len), it)}
        elif shape.kind == "prefill":
            S = self.text_len(shape)
            batch = {"tokens": jax.ShapeDtypeStruct((B, S), it)}
        else:  # decode: one token
            batch = {"tokens": jax.ShapeDtypeStruct((B, 1), it)}
        if self.cfg.frontend == "vision_stub" and shape.kind != "decode":
            batch["patch_embs"] = jax.ShapeDtypeStruct(
                (B, self.cfg.n_patches, self.cfg.d_model), jnp.float32)
        return batch

    def input_shardings(self, shape: ShapeConfig):
        sp = {"tokens": batch_spec(None)}
        if shape.kind == "train":
            sp["labels"] = batch_spec(None)
        if self.cfg.frontend == "vision_stub" and shape.kind != "decode":
            sp["patch_embs"] = batch_spec(None, None)
        return sp

    def make_batch(self, rng, shape: ShapeConfig):
        """Concrete random batch (for smoke tests / examples)."""
        specs = self.input_specs(shape)
        keys = jax.random.split(rng, len(specs))
        out = {}
        for k0, (name, s) in zip(keys, sorted(specs.items())):
            if s.dtype == jnp.int32:
                out[name] = jax.random.randint(k0, s.shape, 0,
                                               self.cfg.vocab_size, s.dtype)
            else:
                out[name] = jax.random.normal(k0, s.shape, s.dtype)
        return out

    # ---------------- forward ----------------
    def _embed_inputs(self, params, batch):
        cfg = self.cfg
        x = L.embed_lookup(params["embed"], batch["tokens"], cfg, self.dtype)
        if cfg.frontend == "vision_stub" and "patch_embs" in batch:
            pe = batch["patch_embs"].astype(self.dtype)
            x = jnp.concatenate([pe, x], axis=1)  # image tokens first
        return x

    def _apply_slot(self, kind, p, x, *, positions, cache=None,
                    cache_len=None, decode=False):
        cfg = self.cfg
        window = cfg.sliding_window if kind == "local" else None
        h = L.rms_norm(x, p["ln1"], cfg.rms_eps)
        h, new_cache = L.attn_apply(
            p["attn"], h, cfg, positions=positions, causal=True,
            window=window, cache=cache, cache_len=cache_len,
            q_chunk=self.q_chunk, kv_chunk=self.kv_chunk)
        if cfg.post_norms:
            h = L.rms_norm(h, p["ln1_post"], cfg.rms_eps)
        x = x + h
        h = L.rms_norm(x, p["ln2"], cfg.rms_eps)
        h = self._ffn_apply(p["ffn"], h)
        if cfg.post_norms:
            h = L.rms_norm(h, p["ln2_post"], cfg.rms_eps)
        return x + h, new_cache

    def _remat(self, f):
        if self.run is None or self.run.remat == "none":
            return f
        policy = (jax.checkpoint_policies.nothing_saveable
                  if self.run.remat == "full"
                  else jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
        return jax.checkpoint(f, policy=policy)

    def _backbone(self, params, x, *, positions, caches=None, cache_len=None,
                  decode=False, remat=False):
        kinds = self.group_kinds

        def body(x, sl):
            blocks, cache = sl
            new_caches = {}
            for i, kind in enumerate(kinds):
                c = cache[f"slot{i}"] if cache is not None else None
                x, nc = self._apply_slot(kind, blocks[f"slot{i}"], x,
                                         positions=positions, cache=c,
                                         cache_len=cache_len, decode=decode)
                new_caches[f"slot{i}"] = nc
            return x, (new_caches if cache is not None else None)

        fn = self._remat(body) if remat else body
        xs = (params["blocks"], caches)
        x, new_caches = jax.lax.scan(fn, x, xs)
        x = L.rms_norm(x, params["final_norm"], self.cfg.rms_eps)
        return x, new_caches

    # -- public compute endpoints ------------------------------------------
    def forward(self, params, batch):
        x = self._embed_inputs(params, batch)
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        x, _ = self._backbone(params, x, positions=positions, remat=True)
        return x

    def loss(self, params, batch):
        x = self.forward(params, batch)
        labels = batch["labels"]
        return L.xent_loss_chunked(x, params["embed"], labels, self.cfg)

    def prefill(self, params, batch, cache_len=None):
        x = self._embed_inputs(params, batch)
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        caches = self.init_cache(B, cache_len or S)
        x, caches = self._backbone(params, x, positions=positions,
                                   caches=caches, remat=False)
        logits = L.lm_logits(x[:, -1:, :], params["embed"], self.cfg)
        return logits, caches

    def decode_step(self, params, caches, cache_len, tokens):
        """tokens: (B, 1); cache_len: scalar count of valid positions."""
        cfg = self.cfg
        x = L.embed_lookup(params["embed"], tokens, cfg, self.dtype)
        B = x.shape[0]
        positions = jnp.broadcast_to(cache_len[None, None], (B, 1))
        x, new_caches = self._backbone(params, x, positions=positions,
                                       caches=caches, cache_len=cache_len,
                                       decode=True, remat=False)
        logits = L.lm_logits(x, params["embed"], cfg)
        return logits, new_caches


class MoETransformer(DenseTransformer):
    """Dense transformer with the FFN replaced by a capacity-dispatch MoE."""

    def _ffn_init(self, rng, n):
        from repro.models import moe
        return moe.moe_init(rng, self.cfg, n)

    def _ffn_specs(self, n):
        from repro.models import moe
        return moe.moe_specs(self.cfg, n)

    def _ffn_shardings(self):
        from repro.models import moe
        return moe.moe_shardings(self.cfg)

    def _ffn_apply(self, p, x):
        from repro.models import moe
        return moe.moe_apply(p, x, self.cfg)
