"""Architecture registry: ``--arch <id>`` -> (ModelConfig, Model class)."""
from __future__ import annotations

from typing import Optional

from repro.configs.base import ModelConfig, RunConfig
from repro.models.transformer import DenseTransformer, MoETransformer
from repro.models.mamba import MambaLM
from repro.models.rglru import GriffinLM
from repro.models.encdec import EncDecTransformer

_FAMILY_CLS = {
    "dense": DenseTransformer,
    "moe": MoETransformer,
    "ssm": MambaLM,
    "hybrid": GriffinLM,
    "encdec": EncDecTransformer,
}


def build_model(cfg: ModelConfig, run: Optional[RunConfig] = None):
    return _FAMILY_CLS[cfg.family](cfg, run)


def get_config(arch: str) -> ModelConfig:
    from repro import configs
    return configs.ARCHS[arch]


def list_archs():
    from repro import configs
    return sorted(configs.ARCHS)
