"""Mamba-1 selective-state-space LM (falcon-mamba-7b architecture).

The selective scan is computed with a chunked associative scan: the sequence
is processed in chunks of ``scan_chunk``; within a chunk the recurrence
    h_t = exp(dt_t * A) * h_{t-1} + dt_t * B_t * x_t
is evaluated by ``jax.lax.associative_scan`` (log-depth, TPU friendly), and
only the (B, D_inner, N) state is carried between chunks, so the
(B, S, D_inner, N) discretised tensor is never materialised for the full
sequence.  Channels (D_inner) are sharded over the "model" axis.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, RunConfig, ShapeConfig
from repro.models import layers as L
from repro.models.shardctx import constrain, batch_spec, seq_spec

SCAN_CHUNK = 256


def _ssm_layer_shapes(cfg):
    D, Di, N, R, W = (cfg.d_model, cfg.d_inner, cfg.ssm_state,
                      cfg.ssm_dt_rank, cfg.ssm_conv)
    return {
        "in_proj": (D, 2 * Di),
        "conv_w": (W, Di), "conv_b": (Di,),
        "x_proj": (Di, R + 2 * N),
        "dt_proj": (R, Di), "dt_bias": (Di,),
        "A_log": (Di, N), "D": (Di,),
        "out_proj": (Di, D),
        "norm": (D,),
    }


def _ssm_layer_shardings():
    return {
        "in_proj": P(None, "data", "model"),
        "conv_w": P(None, None, "model"), "conv_b": P(None, "model"),
        "x_proj": P(None, "model", None),
        "dt_proj": P(None, None, "model"), "dt_bias": P(None, "model"),
        "A_log": P(None, "model", None), "D": P(None, "model"),
        "out_proj": P(None, "model", "data"),
        "norm": P(None, None),
    }


def causal_depthwise_conv(x, w, b, carry: Optional[jax.Array] = None):
    """x: (B, S, C); w: (W, C); b: (C,). Left-padded causal depthwise conv.
    ``carry``: (B, W-1, C) previous context (decode); returns (y, new_carry).
    """
    B, S, C = x.shape
    W = w.shape[0]
    if carry is None:
        carry = jnp.zeros((B, W - 1, C), x.dtype)
    xp = jnp.concatenate([carry, x], axis=1)  # (B, S+W-1, C)
    y = jnp.zeros((B, S, C), x.dtype)
    for i in range(W):
        y = y + xp[:, i:i + S, :] * w[i].astype(x.dtype)
    y = y + b.astype(x.dtype)
    new_carry = xp[:, -(W - 1):, :] if W > 1 else carry
    return y, new_carry


def selective_scan_chunked(u, dt, A, Bc, Cc, h0, *, chunk=SCAN_CHUNK):
    """u, dt: (B, S, Di); A: (Di, N); Bc, Cc: (B, S, N); h0: (B, Di, N).
    Returns (y: (B, S, Di), hT)."""
    B, S, Di = u.shape
    N = A.shape[-1]
    chunk = min(chunk, S)
    nc = S // chunk
    assert nc * chunk == S

    def chunk_step(h, inp):
        uc, dtc, bc, cc = inp  # (B, Q, Di), (B, Q, Di), (B, Q, N), (B, Q, N)
        dA = jnp.exp(dtc[..., None] * A)                       # (B,Q,Di,N)
        dBu = (dtc * uc)[..., None] * bc[:, :, None, :]        # (B,Q,Di,N)

        def combine(e1, e2):
            a1, b1 = e1
            a2, b2 = e2
            return a1 * a2, b1 * a2 + b2

        a_cum, b_cum = jax.lax.associative_scan(combine, (dA, dBu), axis=1)
        hs = a_cum * h[:, None] + b_cum                        # (B,Q,Di,N)
        y = jnp.einsum("bqdn,bqn->bqd", hs, cc)
        return hs[:, -1], y

    ur = u.reshape(B, nc, chunk, Di).transpose(1, 0, 2, 3)
    dtr = dt.reshape(B, nc, chunk, Di).transpose(1, 0, 2, 3)
    br = Bc.reshape(B, nc, chunk, N).transpose(1, 0, 2, 3)
    cr = Cc.reshape(B, nc, chunk, N).transpose(1, 0, 2, 3)
    hT, ys = jax.lax.scan(chunk_step, h0.astype(jnp.float32),
                          (ur.astype(jnp.float32), dtr.astype(jnp.float32),
                           br.astype(jnp.float32), cr.astype(jnp.float32)))
    y = ys.transpose(1, 0, 2, 3).reshape(B, S, Di)
    return y, hT


def mamba_mix(p, x, cfg, cache=None):
    """One mamba mixer. x: (B, S, D). cache: {"conv": (B,W-1,Di),
    "h": (B,Di,N)} or None. Returns (y, new_cache)."""
    B, S, D = x.shape
    Di, N, R = cfg.d_inner, cfg.ssm_state, cfg.ssm_dt_rank
    dt_ = x.dtype
    xz = x @ p["in_proj"].astype(dt_)                     # (B,S,2Di)
    xz = constrain(xz, batch_spec(None, "model"))
    u, z = jnp.split(xz, 2, axis=-1)
    conv_carry = cache["conv"] if cache is not None else None
    u, new_conv = causal_depthwise_conv(u, p["conv_w"].astype(dt_),
                                        p["conv_b"], conv_carry)
    u = jax.nn.silu(u)
    proj = u @ p["x_proj"].astype(dt_)                    # (B,S,R+2N)
    dtr, Bc, Cc = jnp.split(proj, [R, R + N], axis=-1)
    dt = jax.nn.softplus(dtr @ p["dt_proj"].astype(dt_)
                         + p["dt_bias"].astype(dt_))      # (B,S,Di)
    dt = constrain(dt, batch_spec(None, "model"))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))          # (Di,N)
    h0 = (cache["h"] if cache is not None
          else jnp.zeros((B, Di, N), jnp.float32))
    y, hT = selective_scan_chunked(u, dt, A, Bc, Cc, h0)
    y = (y + u.astype(jnp.float32) * p["D"].astype(jnp.float32)).astype(dt_)
    y = y * jax.nn.silu(z)
    # sequence-shard before out_proj: gather the (Di, D) weight, not the
    # (B, S, D) residual (hillclimb #1)
    y = constrain(y, seq_spec(None))
    out = y @ p["out_proj"].astype(dt_)
    out = constrain(out, seq_spec(None))
    new_cache = ({"conv": new_conv, "h": hT}
                 if cache is not None else None)
    return out, new_cache


class MambaLM:
    """Attention-free mamba1 LM. Implements the same Model API as
    DenseTransformer."""

    def __init__(self, cfg: ModelConfig, run: Optional[RunConfig] = None):
        self.cfg = cfg
        self.run = run
        self.dtype = jnp.dtype(cfg.dtype)
        self.n_groups = cfg.n_layers
        self.group_kinds = ("mamba",)

    def init(self, rng):
        cfg, n = self.cfg, self.n_groups
        shapes = _ssm_layer_shapes(cfg)
        keys = jax.random.split(rng, len(shapes) + 1)
        blk = {}
        for k0, (name, sh) in zip(keys, sorted(shapes.items())):
            full = (n,) + sh
            if name == "A_log":
                a = jnp.broadcast_to(
                    jnp.log(jnp.arange(1, cfg.ssm_state + 1, dtype=jnp.float32)),
                    full)
                blk[name] = a
            elif name in ("conv_b", "dt_bias", "D", "norm"):
                blk[name] = jnp.zeros(full, jnp.float32) if name != "D" \
                    else jnp.ones(full, jnp.float32)
            else:
                blk[name] = (jax.random.normal(k0, full, jnp.float32)
                             / math.sqrt(sh[0] if len(sh) > 1 else 1.0))
        return {"embed": L.embed_init(keys[-1], cfg),
                "final_norm": jnp.zeros((cfg.d_model,), jnp.float32),
                "blocks": {"slot0": blk}}

    def param_specs(self):
        cfg, n = self.cfg, self.n_groups
        pd = jnp.dtype(cfg.param_dtype)
        blk = {name: jax.ShapeDtypeStruct((n,) + sh, pd)
               for name, sh in _ssm_layer_shapes(cfg).items()}
        return {"embed": jax.ShapeDtypeStruct((cfg.padded_vocab, cfg.d_model), pd),
                "final_norm": jax.ShapeDtypeStruct((cfg.d_model,), pd),
                "blocks": {"slot0": blk}}

    def param_shardings(self):
        return {"embed": P("model", None), "final_norm": P(None),
                "blocks": {"slot0": _ssm_layer_shardings()}}

    # ---- cache ----
    def init_cache(self, B, S):
        cfg, n = self.cfg, self.n_groups
        return {"slot0": {
            "conv": jnp.zeros((n, B, cfg.ssm_conv - 1, cfg.d_inner), self.dtype),
            "h": jnp.zeros((n, B, cfg.d_inner, cfg.ssm_state), jnp.float32)}}

    def cache_specs(self, B, S):
        cfg, n = self.cfg, self.n_groups
        return {"slot0": {
            "conv": jax.ShapeDtypeStruct(
                (n, B, cfg.ssm_conv - 1, cfg.d_inner), self.dtype),
            "h": jax.ShapeDtypeStruct(
                (n, B, cfg.d_inner, cfg.ssm_state), jnp.float32)}}

    def cache_shardings(self):
        return {"slot0": {"conv": P(None, ("pod", "data"), None, "model"),
                          "h": P(None, ("pod", "data"), "model", None)}}

    # ---- inputs (same protocol as DenseTransformer) ----
    def text_len(self, shape):
        return shape.seq_len

    def input_specs(self, shape: ShapeConfig):
        B, it = shape.global_batch, jnp.int32
        if shape.kind == "train":
            return {"tokens": jax.ShapeDtypeStruct((B, shape.seq_len), it),
                    "labels": jax.ShapeDtypeStruct((B, shape.seq_len), it)}
        if shape.kind == "prefill":
            return {"tokens": jax.ShapeDtypeStruct((B, shape.seq_len), it)}
        return {"tokens": jax.ShapeDtypeStruct((B, 1), it)}

    def input_shardings(self, shape: ShapeConfig):
        sp = {"tokens": batch_spec(None)}
        if shape.kind == "train":
            sp["labels"] = batch_spec(None)
        return sp

    def make_batch(self, rng, shape: ShapeConfig):
        specs = self.input_specs(shape)
        keys = jax.random.split(rng, len(specs))
        return {name: jax.random.randint(k0, s.shape, 0, self.cfg.vocab_size,
                                         s.dtype)
                for k0, (name, s) in zip(keys, sorted(specs.items()))}

    # ---- compute ----
    def _remat(self, f):
        if self.run is None or self.run.remat == "none":
            return f
        return jax.checkpoint(
            f, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)

    def _backbone(self, params, x, caches=None, remat=False):
        cfg = self.cfg

        def body(x, sl):
            blk, cache = sl
            h = L.rms_norm(x, blk["norm"], cfg.rms_eps)
            y, nc = mamba_mix(blk, h, cfg,
                              cache["slot0"] if cache is not None else None)
            return x + y, ({"slot0": nc} if cache is not None else None)

        fn = self._remat(body) if remat else body
        x, new_caches = jax.lax.scan(fn, x,
                                     (params["blocks"]["slot0"], caches))
        x = L.rms_norm(x, params["final_norm"], cfg.rms_eps)
        return x, new_caches

    def forward(self, params, batch):
        x = L.embed_lookup(params["embed"], batch["tokens"], self.cfg,
                           self.dtype)
        x, _ = self._backbone(params, x, remat=True)
        return x

    def loss(self, params, batch):
        x = self.forward(params, batch)
        return L.xent_loss_chunked(x, params["embed"], batch["labels"],
                                   self.cfg)

    def prefill(self, params, batch, cache_len=None):
        x = L.embed_lookup(params["embed"], batch["tokens"], self.cfg,
                           self.dtype)
        caches = self.init_cache(x.shape[0],
                                 cache_len or batch["tokens"].shape[1])
        x, caches = self._backbone(params, x, caches=caches)
        logits = L.lm_logits(x[:, -1:, :], params["embed"], self.cfg)
        return logits, caches

    def decode_step(self, params, caches, cache_len, tokens):
        x = L.embed_lookup(params["embed"], tokens, self.cfg, self.dtype)
        x, new_caches = self._backbone(params, x, caches=caches)
        logits = L.lm_logits(x, params["embed"], self.cfg)
        return logits, new_caches
