"""Config system for the repro framework.

Every assigned architecture is described by a :class:`ModelConfig`; the
parallel/runtime knobs live in :class:`RunConfig`.  Configs are frozen
dataclasses so they are hashable (usable as jit static args / cache keys).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    """Architecture description. One instance per assigned arch."""

    name: str
    family: str  # dense | moe | ssm | hybrid | encdec
    n_layers: int
    d_model: int
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0
    vocab_size: int = 32000

    # --- MoE ---
    n_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25

    # --- attention flavour ---
    rope_theta: float = 10_000.0
    qk_norm: bool = False
    attn_logit_softcap: Optional[float] = None
    final_logit_softcap: Optional[float] = None
    sliding_window: Optional[int] = None      # local-attention window size
    # layer pattern: "global" (all global attn), "local_global" (alternating,
    # gemma2-style), "griffin" (rec,rec,local-attn groups), "mamba" (all ssm)
    layer_pattern: str = "global"
    post_norms: bool = False                  # gemma2 post-layer norms

    # --- SSM (mamba1) ---
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_dt_rank: int = 0                      # 0 -> d_model // 16

    # --- RG-LRU (griffin / recurrentgemma) ---
    lru_width: int = 0                        # 0 -> d_model
    conv1d_width: int = 4

    # --- encoder-decoder ---
    n_enc_layers: int = 0                     # >0 => enc-dec model

    # --- modality frontend stubs (per spec: precomputed embeddings) ---
    frontend: Optional[str] = None            # None | "vision_stub" | "audio_stub"
    n_patches: int = 576                      # vision stub: patch tokens per image
    audio_downsample: int = 8                 # audio stub: frames = seq // ds

    # --- embeddings ---
    tie_embeddings: bool = True
    emb_scale_by_dim: bool = False            # gemma-style sqrt(d) embed scaling

    # --- numerics ---
    dtype: str = "bfloat16"                   # compute dtype
    param_dtype: str = "float32"              # master params
    rms_eps: float = 1e-6

    def __post_init__(self):
        if self.head_dim == 0 and self.n_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.n_kv_heads == 0 and self.n_heads:
            object.__setattr__(self, "n_kv_heads", self.n_heads)
        if self.ssm_dt_rank == 0 and self.family == "ssm":
            object.__setattr__(self, "ssm_dt_rank", max(1, self.d_model // 16))
        if self.lru_width == 0 and self.family == "hybrid":
            object.__setattr__(self, "lru_width", self.d_model)

    # -- derived quantities ------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        """Embedding rows padded to a multiple of 256 so the vocab dim is
        shardable over any mesh axis (standard practice; ids >= vocab_size
        are never emitted by the pipeline)."""
        return ((self.vocab_size + 255) // 256) * 256

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def gqa_groups(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    def param_count(self) -> int:
        """Analytic parameter count (embedding included once if tied)."""
        D, F, V, L = self.d_model, self.d_ff, self.vocab_size, self.n_layers
        H, KV, Dh = self.n_heads, self.n_kv_heads, self.head_dim
        n = V * D  # embedding
        if not self.tie_embeddings:
            n += V * D
        attn = D * H * Dh + 2 * D * KV * Dh + H * Dh * D
        mlp = 3 * D * F
        if self.family == "moe":
            mlp = self.n_experts * 3 * D * F + D * self.n_experts
        if self.family == "ssm":
            Di, N, R = self.d_inner, self.ssm_state, self.ssm_dt_rank
            per = (D * 2 * Di + self.ssm_conv * Di + Di          # in_proj, conv
                   + Di * (R + 2 * N) + R * Di + Di              # x_proj, dt_proj
                   + Di * N + Di                                 # A_log, D
                   + Di * D + D)                                 # out_proj, norm
            return n + L * per + D
        if self.family == "hybrid":
            Dr = self.lru_width
            rec = (2 * D * Dr + self.conv1d_width * Dr + Dr      # in projs + conv
                   + 2 * Dr + Dr * Dr // 8 * 0                   # lru params (a, gates)
                   + 2 * (Dr * Dr) // max(1, Dr // Dr)           # gates (approx)
                   + Dr * D)
            # griffin pattern: 1/3 layers are local attention
            n_attn = L // 3
            n_rec = L - n_attn
            return (n + n_rec * (rec + mlp + 2 * D)
                    + n_attn * (attn + mlp + 2 * D) + D)
        per_layer = attn + mlp + 2 * D * (2 if self.post_norms else 1)
        total_layers = L + self.n_enc_layers
        if self.n_enc_layers:
            per_dec = per_layer + attn + D  # + cross attention
            n += self.n_enc_layers * per_layer + L * per_dec
            return n + 2 * D
        return n + L * per_layer + D

    def active_param_count(self) -> int:
        """Params touched per token (MoE uses experts_per_token)."""
        if self.family != "moe":
            return self.param_count()
        D, F, L = self.d_model, self.d_ff, self.n_layers
        dense = self.param_count() - L * self.n_experts * 3 * D * F
        return dense + L * self.experts_per_token * 3 * D * F


# ---------------------------------------------------------------------------
# Input shapes (the 4 assigned shape cells)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"

    @property
    def cache_len(self) -> int:
        return self.seq_len


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Run / parallelism configuration
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ACESyncConfig:
    """Paper hyper-parameters (eqs. 3-9) + level ladder."""
    enabled: bool = True
    alpha: float = 0.5                 # eq (3) temporal/structural mix
    gamma: float = 1.0                 # eq (7) error-feedback strength
    beta: float = 0.02                 # eq (5) bandwidth->compression slope
    c_min: float = 0.01                # eq (5) min compression ratio kept
    c_max: float = 1.0                 # eq (5) max ratio kept (1.0 = full)
    topk_block: int = 1024             # kernel block for blockwise top-k
    replan_every: int = 100            # host-side knapsack cadence (steps)
    sync_interval_init: int = 4        # H: local steps per cross-pod sync
    sync_interval_max: int = 64
    div_low: float = 0.05              # eq (9) thresholds (relative)
    div_high: float = 0.25
    importance_hidden: int = 32        # attention estimator width
    importance_lr: float = 1e-3
    n_clusters: int = 4                # device clustering
    # two-tier exchange on hierarchical meshes (core/planexec.py):
    # 0 = roofline auto-picks the intra stage per rung, -1 = force flat,
    # 1/2 = force full-precision / INT8 intra aggregation (tests, benches)
    hier_mode: int = 0
    # ClusterState hysteresis: a device only migrates clusters when the
    # new centroid is at least this fraction closer than its current one
    # (repro/hierarchy — keeps assignments from flapping under jitter)
    cluster_hysteresis: float = 0.15
    # padded-size ladder of the retrace-free exchange (core/planexec.py):
    # adaptive plans round per-rung bucket sizes up to geometric classes so
    # steady-state replans reuse the compiled step.  Growth 2.0 = power-of-
    # two classes (fewest recompiles, up to 2x wire padding); 1.125 bounds
    # padding at 12.5%; 1.0 = exact sizes (every bucket-size change
    # recompiles).
    # base growth of the per-rung pad schedule (planexec.rung_growth):
    # big rungs take finer classes than this, tiny rungs coarser ones.
    bucket_pad_growth: float = 1.125
    # chunked ring exchange (planexec.ring_chunk_count): 0 = roofline
    # auto (ring DCN-bound rungs, one-shot all_gather otherwise),
    # -1 = force the one-shot path everywhere, K > 0 = force K chunks on
    # every ring-capable rung (benches/tests).
    ring_chunks: int = 0
    # bidirectional ring: circulate both DCN directions at once (two
    # half-rings of ceil((P-1)/2) hops — same ppermute count and wire
    # bytes, ~2x effective link bandwidth on full-duplex links).  False =
    # the single forward ring (benches compare the two).
    ring_bidir: bool = True
    # fractional bits of the deterministic fixed-point accumulation used
    # whenever >= 3 pods exchange (ring or one-shot): terms quantise to
    # round(x * 2^accum_bits) int32 and fold in exact integer arithmetic,
    # so per-pod aggregates are bit-identical in any fold order.  16 bits
    # = 2^-16 ABSOLUTE resolution over a +-2^15 aggregate range —
    # negligible next to the wire formats' own quantisation at unit
    # gradient scale, but terms below ~2^-17 round to zero: raise this
    # (e.g. 24 -> 6e-8 resolution, +-2^7 range) for regimes whose
    # gradients shrink far below unit scale.
    accum_bits: int = 16
    # rung-ordered optimizer apply: grad_sync applies AdamW to each
    # rung's bucket as soon as that rung's exchange lands instead of
    # barriering on the whole tree (core/sync.py apply_fn path).
    overlap_apply: bool = True
    # backward-interleaved sync: split the exchange into per-segment
    # pieces whose packs depend only on that segment's leaves, so each
    # piece's encode+collective issues as soon as the backward pass has
    # produced that leaf range's gradients instead of barriering on the
    # full grad tree (core/planexec.py segment schedule + core/sync.py
    # streaming path).  Bit-identical to the barriered exchange — every
    # codec is blockwise, so piece splitting never moves the numerics.
    overlap_backward: bool = True
    # number of backward segments: 0 = auto (planexec.auto_segments —
    # 2 on multi-leaf models), 1 = barriered (the pre-segmentation
    # exchange), K > 1 = force K segments.
    backward_segments: int = 0
    # level ladder: (name, keep_ratio, value_bits) - SKIP transmits nothing.
    # Each rung resolves to a registered repro/codecs wire format by
    # semantics: dense 8/4/1-bit -> int8 / packed int4 / sign-majority-vote.
    levels: Tuple[Tuple[str, float, int], ...] = (
        ("FULL", 1.0, 16),
        ("INT8", 1.0, 8),
        ("INT4", 1.0, 4),
        ("TOPK25_INT8", 0.25, 8),
        ("TOPK10_INT8", 0.10, 8),
        ("SIGN1", 1.0, 1),
        ("TOPK1_INT8", 0.01, 8),
        ("SKIP", 0.0, 0),
    )


@dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    shape: ShapeConfig
    multi_pod: bool = False
    # optimizer
    lr: float = 3e-4
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 1000
    # memory policy
    remat: str = "minimal"             # none | minimal | full
    # attention chunking
    q_chunk: int = 2048
    kv_chunk: int = 1024
    # ACE-Sync
    acesync: ACESyncConfig = field(default_factory=ACESyncConfig)
    # checkpointing
    ckpt_every: int = 200
    ckpt_dir: str = "/tmp/repro_ckpt"
    seed: int = 0

    def replace(self, **kw) -> "RunConfig":
        return dataclasses.replace(self, **kw)
