"""starcoder2-3b: 30L d_model=3072 24H (GQA kv=2) d_ff=12288 vocab=49152,
GQA + RoPE. [arXiv:2402.19173; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b", family="dense",
    n_layers=30, d_model=3072, n_heads=24, n_kv_heads=2, head_dim=128,
    d_ff=12288, vocab_size=49152,
    rope_theta=100_000.0,
)

SMOKE = ModelConfig(
    name="starcoder2-3b-smoke", family="dense",
    n_layers=2, d_model=48, n_heads=4, n_kv_heads=2, head_dim=12,
    d_ff=96, vocab_size=256,
)
