"""The paper's own workload: a 350M-parameter transformer LM (section 4.2),
batch 64 per edge node, AdamW, seq 512-1024."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="paper-350m", family="dense",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, head_dim=64,
    d_ff=4096, vocab_size=50304,
)

SMOKE = ModelConfig(
    name="paper-350m-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=256,
)
