"""Assigned architecture configs (exact dims from the assignment spec) plus
the paper's own 350M transformer.  Each arch also provides a reduced *smoke*
variant for CPU tests.
"""
from __future__ import annotations

from repro.configs.base import ModelConfig, RunConfig, ShapeConfig, SHAPES, ACESyncConfig

from repro.configs.dbrx_132b import CONFIG as dbrx_132b, SMOKE as dbrx_132b_smoke
from repro.configs.qwen3_moe_30b_a3b import CONFIG as qwen3_moe_30b_a3b, SMOKE as qwen3_moe_30b_a3b_smoke
from repro.configs.minitron_8b import CONFIG as minitron_8b, SMOKE as minitron_8b_smoke
from repro.configs.qwen3_8b import CONFIG as qwen3_8b, SMOKE as qwen3_8b_smoke
from repro.configs.starcoder2_3b import CONFIG as starcoder2_3b, SMOKE as starcoder2_3b_smoke
from repro.configs.gemma2_9b import CONFIG as gemma2_9b, SMOKE as gemma2_9b_smoke
from repro.configs.falcon_mamba_7b import CONFIG as falcon_mamba_7b, SMOKE as falcon_mamba_7b_smoke
from repro.configs.llava_next_mistral_7b import CONFIG as llava_next_mistral_7b, SMOKE as llava_next_mistral_7b_smoke
from repro.configs.recurrentgemma_2b import CONFIG as recurrentgemma_2b, SMOKE as recurrentgemma_2b_smoke
from repro.configs.seamless_m4t_medium import CONFIG as seamless_m4t_medium, SMOKE as seamless_m4t_medium_smoke
from repro.configs.paper_350m import CONFIG as paper_350m, SMOKE as paper_350m_smoke

ARCHS = {
    "dbrx-132b": dbrx_132b,
    "qwen3-moe-30b-a3b": qwen3_moe_30b_a3b,
    "minitron-8b": minitron_8b,
    "qwen3-8b": qwen3_8b,
    "starcoder2-3b": starcoder2_3b,
    "gemma2-9b": gemma2_9b,
    "falcon-mamba-7b": falcon_mamba_7b,
    "llava-next-mistral-7b": llava_next_mistral_7b,
    "recurrentgemma-2b": recurrentgemma_2b,
    "seamless-m4t-medium": seamless_m4t_medium,
    "paper-350m": paper_350m,
}

SMOKE_ARCHS = {
    "dbrx-132b": dbrx_132b_smoke,
    "qwen3-moe-30b-a3b": qwen3_moe_30b_a3b_smoke,
    "minitron-8b": minitron_8b_smoke,
    "qwen3-8b": qwen3_8b_smoke,
    "starcoder2-3b": starcoder2_3b_smoke,
    "gemma2-9b": gemma2_9b_smoke,
    "falcon-mamba-7b": falcon_mamba_7b_smoke,
    "llava-next-mistral-7b": llava_next_mistral_7b_smoke,
    "recurrentgemma-2b": recurrentgemma_2b_smoke,
    "seamless-m4t-medium": seamless_m4t_medium_smoke,
    "paper-350m": paper_350m_smoke,
}

# archs whose long_500k cell is skipped (pure full-attention; see DESIGN.md)
LONG_CONTEXT_ARCHS = {"falcon-mamba-7b", "recurrentgemma-2b"}


def cells(include_long_skips: bool = False):
    """All (arch, shape) dry-run cells honouring the long_500k skip rule."""
    out = []
    for arch in ARCHS:
        if arch == "paper-350m":
            continue
        for shape in SHAPES.values():
            if (shape.name == "long_500k" and not include_long_skips
                    and arch not in LONG_CONTEXT_ARCHS):
                continue
            out.append((arch, shape.name))
    return out
