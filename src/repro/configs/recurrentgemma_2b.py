"""recurrentgemma-2b: 26L d_model=2560 10H (GQA kv=1) d_ff=7680 vocab=256000,
RG-LRU + local attention (1 attn : 2 rec). [arXiv:2402.19427; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1, head_dim=256,
    d_ff=7680, vocab_size=256000,
    sliding_window=2048, lru_width=2560, conv1d_width=4,
    emb_scale_by_dim=True,
)

SMOKE = ModelConfig(
    name="recurrentgemma-2b-smoke", family="hybrid",
    n_layers=5, d_model=64, n_heads=4, n_kv_heads=1, head_dim=16,
    d_ff=128, vocab_size=256,
    sliding_window=32, lru_width=64, conv1d_width=4,
    emb_scale_by_dim=True,
)
