"""gemma2-9b: 42L d_model=3584 16H (GQA kv=8) d_ff=14336 vocab=256000,
local+global alternating, logit softcaps, post-norms. [arXiv:2408.00118; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b", family="dense",
    n_layers=42, d_model=3584, n_heads=16, n_kv_heads=8, head_dim=256,
    d_ff=14336, vocab_size=256000,
    layer_pattern="local_global", sliding_window=4096,
    attn_logit_softcap=50.0, final_logit_softcap=30.0,
    post_norms=True, emb_scale_by_dim=True,
)

SMOKE = ModelConfig(
    name="gemma2-9b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256,
    layer_pattern="local_global", sliding_window=32,
    attn_logit_softcap=50.0, final_logit_softcap=30.0,
    post_norms=True, emb_scale_by_dim=True,
)
