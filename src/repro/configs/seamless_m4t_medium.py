"""seamless-m4t-medium: enc-dec 12L(+12L enc) d_model=1024 16H (kv=16)
d_ff=4096 vocab=256206; audio frontend STUBBED (precomputed frame
embeddings per assignment spec). [arXiv:2308.11596; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium", family="encdec",
    n_layers=12, n_enc_layers=12, d_model=1024, n_heads=16, n_kv_heads=16,
    head_dim=64, d_ff=4096, vocab_size=256206,
    audio_downsample=8,
)

SMOKE = ModelConfig(
    name="seamless-m4t-medium-smoke", family="encdec",
    n_layers=2, n_enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    head_dim=16, d_ff=128, vocab_size=256,
    audio_downsample=8,
)
