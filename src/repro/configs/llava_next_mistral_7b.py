"""llava-next-mistral-7b: mistral-7b backbone 32L d_model=4096 32H (GQA kv=8)
d_ff=14336 vocab=32000; anyres vision frontend STUBBED (precomputed patch
embeddings per assignment spec). [hf:llava-hf/llava-v1.6-mistral-7b-hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=32000,
    rope_theta=1_000_000.0,
    frontend="vision_stub", n_patches=576,
)

SMOKE = ModelConfig(
    name="llava-next-mistral-7b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256,
    frontend="vision_stub", n_patches=8,
)
