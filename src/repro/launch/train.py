"""End-to-end training driver: the host-side ACE-Sync control loop.

Wires together every subsystem:
  telemetry -> clustering -> omega weights (eq 8)
  bandwidth -> eq (5) budget -> importance scores -> knapsack -> SyncPlan
  divergence (eq 9) -> sync-interval H adaptation
  H local steps per pod + 1 ACE-Sync round, checkpoints, heartbeats,
  straggler detection, elastic restart on simulated pod failure.

Runs on any mesh (including none) with any registered arch; reduced configs
train end-to-end on CPU (see examples/train_lm.py).
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Optional, Union

import jax
import numpy as np

from repro.configs import ARCHS, SMOKE_ARCHS, SHAPES
from repro.configs.base import RunConfig, ShapeConfig
from repro.checkpoint.checkpointer import Checkpointer
from repro.core import acesync
from repro.core.clustering import cluster_devices, reliability_weights
from repro.core.trainer import Trainer
from repro.data.pipeline import TokenPipeline
from repro.data.telemetry import make_profiles, snapshot, bandwidth_at
from repro.models.registry import build_model
from repro.runtime.fault_tolerance import (HeartbeatMonitor,
                                           StragglerDetector)
from repro.strategies import SYNC_KINDS, SyncStrategy, list_strategies, \
    resolve_strategy


class TrainLoop:
    """Host control loop around the jitted per-pod steps."""

    def __init__(self, model, run: RunConfig, mesh=None,
                 strategy: Union[str, SyncStrategy] = "acesync",
                 n_edge_devices: int = 8, seed: int = 0):
        self.model = model
        self.run = run
        self.mesh = mesh
        self.trainer = Trainer(model, run, mesh=mesh, strategy=strategy)
        self.strategy = self.trainer.strategy
        self.ckpt = Checkpointer(run.ckpt_dir)
        self.profiles = make_profiles(n_edge_devices, seed)
        self.monitor = HeartbeatMonitor(max(self.trainer.n_pods, 1))
        self.straggler = StragglerDetector()
        self.history = []
        self.comm_bytes = 0.0
        self._plan = None
        self._steps_since_sync = 0

    @property
    def plan(self):
        """The SyncPlan currently being executed (None before the first
        refresh)."""
        return self._plan

    # ---- policy refresh (host side, every replan_every steps) ----------
    def refresh_plan(self, state, step: int):
        cfg = self.run.acesync
        telem = snapshot(self.profiles, step)
        assign = cluster_devices(telem, cfg.n_clusters)
        sf = self.straggler.straggle_factors(self.monitor)
        for t, pod in zip(telem, range(len(telem))):
            t["straggle"] *= sf.get(pod % max(len(sf), 1), 1.0)
        omega_dev = reliability_weights(telem, assign)
        # collapse device weights to pod weights
        n_pods = self.trainer.n_pods
        omega = [0.0] * n_pods
        for i, w in enumerate(omega_dev):
            omega[i % n_pods] += w
        tot = sum(omega)
        omega = tuple(w / tot for w in omega)

        imp = None
        if self.strategy.uses_importance:
            imp = np.asarray(jax.device_get(acesync.current_scores(
                jax.tree.map(lambda x: x[0], state["ace"]),
                cfg))).tolist()
        self._plan = self.strategy.make_plan(
            self.trainer.scheduler, importance=imp, telemetry=telem,
            omega=omega)
        return self._plan

    def adapt_interval(self, state):
        """Sync-interval control (eq 9); a fixed H for static strategies."""
        ace = jax.tree.map(lambda x: x[0], state["ace"])
        div = float(jax.device_get(ace.div_ema))
        return self.strategy.adapt(self.trainer.scheduler, div)

    # ---- main loop ------------------------------------------------------
    def run_steps(self, state, pipeline, n_steps: int,
                  log_every: int = 10):
        run = self.run
        cfg = run.acesync
        H = self.strategy.initial_interval(cfg)
        if self._plan is None:
            self.refresh_plan(state, 0)
        for i in range(n_steps):
            step = int(jax.device_get(jax.tree.leaves(state["step"])[0]
                                      .reshape(-1)[0]))
            if step and step % cfg.replan_every == 0:
                self.refresh_plan(state, step)
                H = self.adapt_interval(state)
            batch = next(pipeline)
            t0 = time.time()
            kinds = self.strategy.step_schedule(self._steps_since_sync, H)
            metrics = {}
            for kind in kinds:
                fn = self.trainer.step_fn(self._plan, kind)
                state, m = fn(state, batch)
                metrics.update(m)
                self.comm_bytes += self.strategy.wire_bytes(
                    self.trainer.scheduler, self._plan, kind)
            if SYNC_KINDS & set(kinds):
                self._steps_since_sync = 0
            else:
                self._steps_since_sync += 1
            dt = time.time() - t0
            for pod in range(self.trainer.n_pods):
                self.monitor.beat(pod, dt)
            rec = {k: float(jax.device_get(v)) for k, v in metrics.items()}
            rec.update(step=step, dt=dt, H=H)
            self.history.append(rec)
            if log_every and i % log_every == 0:
                print(f"step {step:5d} loss={rec.get('loss', float('nan')):.4f} "
                      f"H={H} dt={dt:.2f}s", flush=True)
            done = step + 1  # state now holds the post-step counter
            if run.ckpt_every and done % run.ckpt_every == 0:
                self.ckpt.save(done, state,
                               extras={"pipeline": pipeline.snapshot()})
        return state

    def restore_or_init(self, rng, pipeline):
        if self.ckpt.latest_step() is not None:
            tmpl = self.trainer.state_specs()
            sh = (self.trainer.state_shardings() if self.mesh is not None
                  else None)
            state, extras = self.ckpt.restore(tmpl, shardings=sh)
            if "pipeline" in extras:
                pipeline.restore(extras["pipeline"])
            print(f"restored checkpoint @ step {self.ckpt.latest_step()}")
            return state
        state = self.trainer.init_state(rng)
        if self.mesh is not None:
            state = jax.device_put(state, self.trainer.state_shardings())
        return state


def main():
    from repro.launch.session import TrainSession

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="paper-350m")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--strategy", default="acesync",
                    choices=list_strategies())
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    args = ap.parse_args()

    sess = TrainSession.from_config(
        args.arch, strategy=args.strategy, smoke=args.smoke,
        seq_len=args.seq_len, batch=args.batch, steps=args.steps,
        warmup_steps=10, ckpt_dir=args.ckpt_dir)
    sess.run(args.steps)
    sess.finish()
    losses = sess.losses
    print(json.dumps({"first_loss": losses[0], "last_loss": losses[-1],
                      "steps": len(losses),
                      "comm_bytes": sess.comm_bytes}))


if __name__ == "__main__":
    main()
