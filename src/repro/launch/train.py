"""End-to-end training driver: the host-side ACE-Sync control loop.

Wires together every subsystem:
  telemetry -> clustering -> omega weights (eq 8)
  bandwidth -> eq (5) budget -> importance scores -> knapsack -> SyncPlan
  divergence (eq 9) -> sync-interval H adaptation
  H local steps per pod + 1 ACE-Sync round, checkpoints, heartbeats,
  straggler detection, elastic membership on pod failure/rejoin.

The loop is **non-blocking**: since the plan-as-data refactor the host
never stalls the device to replan.

  * The step counter is mirrored on the host (one device fetch at loop
    start) instead of a blocking ``device_get`` per iteration.
  * Replanning for device-capable strategies (ACE-Sync) launches ONE
    device computation (importance scoring + vectorized knapsack, see
    ``core/acesync.device_replan_fn``) and fetches only the tiny
    ``int32[G]`` assignment vector asynchronously; the loop keeps stepping
    on the old plan and swaps once the fetch lands (the replan-to-apply
    latency is recorded in ``replan_latencies``).
  * Per-step metrics and the divergence EMA are fetched LAGGED — the
    record for step t is materialised while step t+1 is already running
    on device, so the host read overlaps device compute.
  * A replan whose bucket signature crosses a size-class boundary is
    warmed SPECULATIVELY: the new signature's step is AOT-compiled in a
    background thread (``Trainer.warm_compile``) before the plan swap
    lands, so a class-ladder rung change never stalls the device on a
    foreground compile.

Surviving the fleet (see README "How the system survives preemption"):

  * Checkpoints carry the FULL training state: params/opt moments/EF error
    buffers/importance state ride in the state pytree, and the manifest
    extras carry the active SyncPlan, the scheduler's sync interval, the
    ClusterState centroids/assignments, the loop counters and the data-
    pipeline position — restore + continue replays bit-identically on the
    same mesh (``blocking_replans`` pins the replan application steps).
  * Elastic membership: a pod marked dead (heartbeat timeout or injected
    fault) triggers a transition to a P-1 mesh — a per-pod-count Trainer
    is built over the surviving devices, its ring hops / bucket signature
    re-derived through ``planexec``, its step AOT-warmed in a BACKGROUND
    thread (``Trainer.warm_compile``) while the loop keeps draining steps
    on the old fleet, and the swap (state transfer included) lands only
    once the new-P executable is ready: zero foreground recompiles across
    the transition.  A rejoin replays the same path back through the
    cached P-trainer.
  * Deterministic fault injection: a seeded
    :class:`~repro.runtime.faults.FaultSchedule` drives kill/rejoin/
    corruption/heartbeat-delay events at fixed host steps.

Runs on any mesh (including none) with any registered arch; reduced configs
train end-to-end on CPU (see examples/train_lm.py).
"""
from __future__ import annotations

import argparse
import inspect
import json
import threading
import time
from typing import Dict, List, Optional, Union

import jax
import numpy as np

from repro.configs.base import RunConfig
from repro.checkpoint.checkpointer import Checkpointer
from repro.core import acesync
from repro.core.trainer import Trainer
from repro.data.telemetry import make_profiles, snapshot
from repro.hierarchy import ClusterState
from repro.runtime import faults as F
from repro.runtime.fault_tolerance import (ElasticPlanner, HeartbeatMonitor,
                                           MeshPlan, StragglerDetector)
from repro.strategies import (STEP_ADVANCING, SYNC_KINDS, SyncStrategy,
                              list_strategies)


def _device_ready(x) -> bool:
    """True when an async host fetch of ``x`` would not block."""
    ready = getattr(x, "is_ready", None)
    if ready is None:
        return True  # old jax: accept a (cheap, already-lagged) sync get
    try:
        return bool(ready())
    except Exception:  # pragma: no cover - defensive
        return True


def _to_host_async(x):
    try:
        x.copy_to_host_async()
    except Exception:  # pragma: no cover - old jax / committed host array
        pass
    return x


class TrainLoop:
    """Host control loop around the jitted per-pod steps."""

    def __init__(self, model, run: RunConfig, mesh=None,
                 strategy: Union[str, SyncStrategy] = "acesync",
                 n_edge_devices: int = 8, seed: int = 0,
                 fault_schedule: Optional[F.FaultSchedule] = None,
                 elastic: bool = True, blocking_replans: bool = False):
        self.model = model
        self.run = run
        self.mesh = mesh
        self.trainer = Trainer(model, run, mesh=mesh, strategy=strategy)
        self.strategy = self.trainer.strategy
        self.ckpt = Checkpointer(run.ckpt_dir)
        self.profiles = make_profiles(n_edge_devices, seed)
        sched = self.trainer.scheduler
        # live clustering: 1:1 clusters<->cross-tier pods on a hierarchical
        # mesh, the config's n_clusters otherwise
        self.clusters = ClusterState(
            n_edge_devices,
            sched.n_cross if sched.hier_enabled else run.acesync.n_clusters,
            hysteresis=getattr(run.acesync, "cluster_hysteresis", 0.15))
        self._plan_takes_clusters = "clusters" in inspect.signature(
            self.strategy.make_plan).parameters
        self.monitor = HeartbeatMonitor(max(self.trainer.n_pods, 1))
        self.straggler = StragglerDetector()
        # elastic membership: only flat pod meshes re-derive their shape
        # (a hierarchical mesh's edge axis is cluster topology, not
        # membership — ROADMAP follow-up)
        self.elastic = bool(
            elastic and mesh is not None
            and set(mesh.axis_names) == {"pod", "data", "model"})
        self.planner = (ElasticPlanner(MeshPlan(
            n_pods=mesh.shape["pod"], data=mesh.shape["data"],
            model=mesh.shape["model"])) if self.elastic else None)
        self.faults = fault_schedule
        #: deterministic mode: replan fetches, AOT warm-ups and elastic
        #: swaps are applied synchronously at their launch step, so two
        #: runs of the same config replay the same plan/H/membership
        #: trajectory step for step (the restart-replay soak pins this)
        self.blocking_replans = bool(blocking_replans)
        self.history = []
        self.comm_bytes = 0.0
        self._plan = None
        self._steps_since_sync = 0
        self._H: Optional[int] = None   # persisted sync interval
        self._host_step = None          # host mirror of the device counter
        self._pending_replan = None     # (assign_dev, omega, launched_step)
        self._warming = None            # (plan, thread, launched_step)
        self._div_fetch = None          # lagged divergence EMA fetch
        self.replan_latencies = []      # steps from replan launch to apply
        self._pipeline = None           # the stream run_steps is draining
        # ---- elastic state ----
        self._trainers: Dict[int, Trainer] = {self.trainer.n_pods:
                                              self.trainer}
        self._elastic_pending = None    # (trainer, plan, pipe, th, step, P)
        self._hb_delay: Dict[int, int] = {}
        #: membership transitions applied: dicts with from/to pod counts,
        #: the swap step and whether the new-P step came from the warm
        #: AOT cache (benchmarks/soaks record this)
        self.membership_events: List[dict] = []

    @property
    def plan(self):
        """The SyncPlan currently being executed (None before the first
        refresh)."""
        return self._plan

    # ---- aggregated compile telemetry ----------------------------------
    def compile_count(self) -> int:
        """Foreground traced-and-compiled step variants across EVERY
        trainer this loop has built (elastic transitions build one per
        pod count) — the number the fault soaks pin flat across a
        membership change."""
        return sum(tr.compile_count() for tr in self._trainers.values())

    def warm_compile_count(self) -> int:
        """Background AOT compiles across every trainer."""
        return sum(tr.warm_compiles for tr in self._trainers.values())

    # ---- policy refresh (host side, every replan_every steps) ----------
    def _policy_inputs(self, step: int):
        """Telemetry snapshot -> (telemetry, fleet omega weights).

        The live :class:`~repro.hierarchy.ClusterState` re-clusters on
        each refresh (warm-started k-means + hysteresis, so jitter-only
        telemetry never flaps the assignment), and the per-device
        reliability weights come back already summed into fleet slots —
        cluster-major on a hierarchical mesh, pod-major on a flat one.
        Straggle factors from the heartbeat monitor multiply into the
        telemetry straggle before clustering, so persistently slow pods
        are down-weighted in omega instead of stalling the ring.
        Everything returned is device data; a re-cluster never adds a
        static jit key."""
        telem = snapshot(self.profiles, step)
        sf = self.straggler.straggle_factors(self.monitor)
        alive = sorted(sf) or [0]
        for i, t in enumerate(telem):
            # device i reports through the alive pod it is homed on —
            # dead pods drop out of the straggle feed entirely
            t["straggle"] *= sf.get(alive[i % len(alive)], 1.0)
        self.clusters.update(telem)
        sched = self.trainer.scheduler
        return telem, self.clusters.fleet_omega(
            telem, sched.n_cross, sched.n_edge)

    def refresh_plan(self, state, step: int):
        cfg = self.run.acesync
        telem, omega = self._policy_inputs(step)

        dev_fn = (self.strategy.device_plan_fn(self.trainer.scheduler, cfg)
                  if state is not None else None)
        if dev_fn is not None and self._plan is not None:
            # Non-blocking device replan: one jitted computation produces
            # the new plan vector; only the tiny int32[G] assignment is
            # pulled to the host, asynchronously.  The loop keeps stepping
            # on the current plan until the fetch lands (poll_replan).
            # Only the estimator's scalar state enters the computation —
            # never the param-sized error buffers riding in ACEState.
            budget = self.trainer.scheduler.budget_for(
                self.strategy.budget_bandwidth(telem, self.clusters))
            ace = state["ace"]
            imp0 = jax.tree.map(lambda x: x[0], ace.importance)
            assign = _to_host_async(
                dev_fn(imp0, ace.struct_feat[0], budget))
            self._pending_replan = (assign, omega, self._host_step or step)
            return self._plan
        # host path: the first plan, and strategies without a device solver.
        # Only the estimator's few-hundred-scalar state is sliced and
        # fetched — never the param-sized error buffers in ACEState (the
        # group metas / local sizes / leaf layout are likewise computed
        # once at Trainer construction, not re-derived per replan poll).
        imp = None
        if self.strategy.uses_importance and state is not None:
            ace = state["ace"]
            imp0 = jax.tree.map(lambda x: x[0], ace.importance)
            imp = np.asarray(jax.device_get(acesync.scores_from(
                imp0, ace.struct_feat[0], cfg))).tolist()
        kw = dict(importance=imp, telemetry=telem, omega=omega)
        if self._plan_takes_clusters:
            kw["clusters"] = self.clusters
        self._plan = self.strategy.make_plan(self.trainer.scheduler, **kw)
        return self._plan

    def _swap_plan(self, plan, launched) -> bool:
        self._plan = plan
        if self._host_step is not None:
            self.replan_latencies.append(self._host_step - launched)
        return True

    def poll_replan(self, block: bool = False) -> bool:
        """Apply a pending device replan if its async fetch has landed.
        Returns True when the plan was swapped.

        Signature warm-up: when the fetched assignment crosses a
        size-class boundary (a bucket signature the step cache has not
        compiled), the swap is DEFERRED — the new signature's step is
        AOT-compiled in a background thread (``Trainer.warm_compile``)
        while the loop keeps stepping on the current plan, and the swap
        lands on a later poll once the executable is ready.  A rung/class
        change therefore never stalls the device on a foreground
        compile."""
        if self._warming is not None:
            plan, th, launched = self._warming
            if self._pending_replan is not None \
                    and _device_ready(self._pending_replan[0]):
                # a newer assignment landed while this one was warming:
                # abandon the stale swap (the thread still finishes into
                # the AOT cache) and process the fresh fetch below
                self._warming = None
            else:
                if block:
                    th.join()
                if th.is_alive():
                    return False
                self._warming = None
                return self._swap_plan(plan, launched)
        if self._pending_replan is None:
            return False
        assign, omega, launched = self._pending_replan
        if not block and not _device_ready(assign):
            return False
        idx = np.asarray(jax.device_get(assign)).tolist()
        self._pending_replan = None
        plan = self.trainer.scheduler.plan_from_levels(
            idx, omega, adaptive=True)
        if self.trainer.step_is_warm(plan):
            return self._swap_plan(plan, launched)
        th = threading.Thread(target=self.trainer.warm_compile,
                              args=(plan,), daemon=True)
        th.start()
        self._warming = (plan, th, launched)
        if block:
            th.join()
            self._warming = None
            return self._swap_plan(plan, launched)
        return False

    def adapt_interval(self, state):
        """Sync-interval control (eq 9); a fixed H for static strategies.
        The divergence EMA is fetched lagged (the previous replan's launch
        satisfies this one) so the controller never blocks on the step in
        flight.  ``blocking_replans`` mode reads it synchronously instead
        — the H trajectory is then a pure function of the trajectory of
        states, which is what makes restart-replay bit-identical."""
        div_now = state["ace"].div_ema[0]
        if self.blocking_replans:
            return self.strategy.adapt(self.trainer.scheduler,
                                       float(jax.device_get(div_now)))
        prev = self._div_fetch
        self._div_fetch = _to_host_async(div_now)
        if prev is None:
            # no lagged sample yet: leave H untouched rather than feeding
            # the controller a fabricated zero divergence
            return (self.trainer.scheduler.sync_interval
                    if self.strategy.adapts_interval
                    else self.strategy.initial_interval(self.run.acesync))
        return self.strategy.adapt(self.trainer.scheduler,
                                   float(jax.device_get(prev)))

    # ---- preemption-safe checkpoint state -------------------------------
    def _plan_snapshot(self) -> Optional[dict]:
        p = self._plan
        if p is None:
            return None
        return {"level_idx": list(p.level_idx),
                "omega": [float(w) for w in p.omega],
                "sync_interval": int(p.sync_interval),
                "adaptive": bool(p.adaptive)}

    def ckpt_extras(self) -> dict:
        """Everything outside the state pytree a restart needs: the data-
        pipeline position, the active plan, the scheduler's adapted sync
        interval, the cluster controller's warm state and the loop
        counters.  All JSON-able — it rides in the checkpoint manifest."""
        return {
            "pipeline": (self._pipeline.snapshot()
                         if self._pipeline is not None else None),
            "plan": self._plan_snapshot(),
            "scheduler": self.trainer.scheduler.snapshot(),
            "clusters": self.clusters.snapshot(),
            "loop": {"steps_since_sync": int(self._steps_since_sync),
                     "H": None if self._H is None else int(self._H),
                     "n_pods": int(self.trainer.n_pods),
                     "comm_bytes": float(self.comm_bytes)},
        }

    def _restore_extras(self, extras: dict, pipeline):
        if extras.get("pipeline"):
            pipeline.restore(extras["pipeline"])
        if extras.get("scheduler"):
            self.trainer.scheduler.restore_snapshot(extras["scheduler"])
        if extras.get("clusters"):
            self.clusters.restore_snapshot(extras["clusters"])
        lp = extras.get("loop") or {}
        self._steps_since_sync = int(lp.get("steps_since_sync", 0))
        h = lp.get("H")
        self._H = None if h is None else int(h)
        self.comm_bytes = float(lp.get("comm_bytes", 0.0))
        ps = extras.get("plan")
        if ps:
            # rebuilt through the scheduler so bucket signature / ring
            # chunks / segment grids re-derive exactly as they would have
            # mid-run (the scheduler's sync_interval was restored above)
            self._plan = self.trainer.scheduler.plan_from_levels(
                ps["level_idx"], omega=ps["omega"],
                sync_interval=ps.get("sync_interval"),
                adaptive=bool(ps.get("adaptive", False)))

    # ---- fault injection & elastic membership ---------------------------
    def _apply_faults(self, step: int):
        if self.faults is None:
            return
        for ev in self.faults.due(step):
            if ev.kind == F.KILL_POD:
                self._on_pods_dead([ev.target])
            elif ev.kind == F.REJOIN_POD:
                self._on_pod_rejoin(ev.target)
            elif ev.kind == F.CORRUPT_CKPT:
                self.ckpt.wait()
                path = F.corrupt_checkpoint_leaf(
                    self.ckpt.dir, ev.target, seed=ev.step)
                if path:
                    print(f"FAULT step {step}: corrupted {path}",
                          flush=True)
            elif ev.kind == F.DELAY_HEARTBEAT:
                self._hb_delay[ev.target] = max(
                    self._hb_delay.get(ev.target, 0), ev.duration)

    def _on_pods_dead(self, pods):
        for p in pods:
            self.monitor.mark_dead(p)
        if not self.elastic:
            return
        plan = self.planner.on_pod_failure(pods)
        print(f"ELASTIC: pods {sorted(pods)} dead -> fleet P="
              f"{plan.n_pods}", flush=True)
        self._begin_transition(plan.n_pods)

    def _on_pod_rejoin(self, pod: int):
        self.monitor.register(pod)
        if not self.elastic:
            return
        plan = self.planner.on_pod_join(1)
        print(f"ELASTIC: pod {pod} rejoined -> fleet P={plan.n_pods}",
              flush=True)
        self._begin_transition(plan.n_pods)

    def _trainer_for(self, n_pods: int) -> Trainer:
        """The per-pod-count trainer (cached — a rejoin back to a pod
        count the loop has already run reuses the warm jit/AOT caches)."""
        tr = self._trainers.get(n_pods)
        if tr is not None:
            return tr
        mp = self.planner.plan
        shape = (n_pods, mp.data, mp.model)
        need = n_pods * mp.data * mp.model
        from repro.launch.mesh import make_mesh
        mesh = make_mesh(shape, ("pod", "data", "model"),
                         devices=jax.devices()[:need])
        tr = Trainer(self.model, self.run, mesh=mesh,
                     strategy=self.strategy)
        self._trainers[n_pods] = tr
        return tr

    def _begin_transition(self, n_new: int):
        """Stage a membership change: build (or fetch) the new-P trainer,
        re-derive its plan through planexec (ring hops, bucket signature,
        omega at the new fleet size), re-balance the batch, and AOT-warm
        the new signature in a BACKGROUND thread.  The loop keeps
        stepping on the current fleet; the swap lands in
        :meth:`_poll_elastic` once the executable is ready — zero
        foreground recompiles across the transition."""
        if n_new == self.trainer.n_pods or not self.elastic:
            return
        old = self.trainer
        tr = self._trainer_for(n_new)
        # host state rides across: the adapted sync interval prices the
        # new plan exactly where the old fleet left off
        tr.scheduler.restore_snapshot(old.scheduler.snapshot())
        telem, omega = self._policy_inputs(self._host_step or 0)
        kw = dict(importance=None, telemetry=telem, omega=omega)
        if self._plan_takes_clusters:
            kw["clusters"] = self.clusters
        plan = self.strategy.make_plan(tr.scheduler, **kw)
        pipe = self._pipeline
        if pipe is not None:
            rows = self.planner.rebalanced_rows(
                pipe.shape.global_batch, old.n_pods)
            if rows != pipe.shape.global_batch:
                pipe = pipe.resized(rows)
        # make the fresh trainer warmable before it has ever stepped:
        # seed the arg specs the AOT lowering needs from spec pytrees
        kinds = tuple(old._arg_specs) or ("grad_sync",)
        state_specs = tr.state_specs()
        batch_specs = (self.model.input_specs(pipe.shape)
                       if pipe is not None else None)
        if batch_specs is not None:
            for kind in kinds:
                tr.seed_arg_specs(kind, state_specs, batch_specs)
        th = threading.Thread(target=tr.warm_compile, args=(plan,),
                              kwargs={"kinds": kinds}, daemon=True)
        th.start()
        self._elastic_pending = (tr, plan, pipe, th,
                                 self._host_step or 0, n_new)

    def _steady_sharding(self, tr: Trainer):
        from jax.sharding import NamedSharding, PartitionSpec as P
        return NamedSharding(tr.mesh, P(tr._fleet_dim))

    def _transfer_state(self, state, tr: Trainer):
        """Move the train state onto the new fleet: host round-trip with
        the leading pod-replica dim cut (pod loss — the dead pod's EF
        residual leaves with it) or tiled (rejoin — the new pod adopts an
        existing pod's residuals/moments), then device_put with the
        steady-state P(fleet) sharding the compiled step consumes, so the
        warmed AOT executable dispatches without a reshard or retrace."""
        n_new = tr.n_pods
        sh = self._steady_sharding(tr)

        def move(x):
            a = np.asarray(jax.device_get(x))
            if a.ndim and a.shape[0] != n_new:
                if a.shape[0] < n_new:
                    reps = [-(-n_new // a.shape[0])] + [1] * (a.ndim - 1)
                    a = np.tile(a, reps)[:n_new]
                else:
                    a = a[:n_new]
            return jax.device_put(a, sh)

        return jax.tree.map(move, state)

    def _poll_elastic(self, state, block: bool = False):
        """Finish a staged membership transition once its background
        AOT warm-up completes.  Returns the (possibly transferred)
        state."""
        if self._elastic_pending is None:
            return state
        tr, plan, pipe, th, launched, n_new = self._elastic_pending
        if block:
            th.join()
        if th.is_alive():
            return state
        self._elastic_pending = None
        state = self._transfer_state(state, tr)
        # pending replans were priced for the OLD fleet (omega length,
        # scheduler identity): drop them; the next refresh replans at P
        self._pending_replan = None
        self._warming = None
        self.trainer = tr
        self.mesh = tr.mesh
        if pipe is not None:
            self._pipeline = pipe
        self._plan = plan
        self.membership_events.append({
            "step": self._host_step, "launched_step": launched,
            "n_pods": n_new, "warm_steps": (self._host_step or 0) - launched,
            "served_from_warm_cache": tr.step_is_warm(plan)})
        print(f"ELASTIC: swapped to P={n_new} at step {self._host_step} "
              f"(warmed in background over "
              f"{(self._host_step or 0) - launched} steps)", flush=True)
        return state

    def _beat_pods(self) -> List[int]:
        out = []
        for pod in self.monitor.alive_pods():
            d = self._hb_delay.get(pod, 0)
            if d > 0:
                self._hb_delay[pod] = d - 1
                continue
            out.append(pod)
        return out

    # ---- main loop ------------------------------------------------------
    def _flush_metrics(self, inflight, log_every):
        metrics, rec, idx = inflight
        rec.update({k: float(jax.device_get(v)) for k, v in metrics.items()})
        self.history.append(rec)
        if log_every and idx % log_every == 0:
            print(f"step {rec['step']:5d} "
                  f"loss={rec.get('loss', float('nan')):.4f} "
                  f"H={rec['H']} dt={rec['dt']:.2f}s", flush=True)

    def run_steps(self, state, pipeline, n_steps: int,
                  log_every: int = 10):
        run = self.run
        cfg = run.acesync
        self._pipeline = pipeline
        H = (self._H if self._H is not None
             else self.strategy.initial_interval(cfg))
        # one synchronous fetch to seed the host step mirror
        self._host_step = int(jax.device_get(
            jax.tree.leaves(state["step"])[0].reshape(-1)[0]))
        if self._plan is None:
            self.refresh_plan(state, self._host_step)
            if self.blocking_replans:
                self.poll_replan(block=True)
        inflight = None
        for i in range(n_steps):
            step = self._host_step
            self._apply_faults(step)
            state = self._poll_elastic(state,
                                       block=self.blocking_replans)
            self.poll_replan()
            if step and step % cfg.replan_every == 0:
                self.refresh_plan(state, step)
                if self.blocking_replans:
                    self.poll_replan(block=True)
                H = self.adapt_interval(state)
                self._H = H
            batch = next(self._pipeline)
            t0 = time.time()
            kinds = self.strategy.step_schedule(self._steps_since_sync, H)
            metrics = {}
            for kind in kinds:
                state, m = self.trainer.step(state, batch, self._plan, kind)
                metrics.update(m)
                self.comm_bytes += self.strategy.wire_bytes(
                    self.trainer.scheduler, self._plan, kind)
                if kind in STEP_ADVANCING:
                    self._host_step += 1
            if SYNC_KINDS & set(kinds):
                self._steps_since_sync = 0
            else:
                self._steps_since_sync += 1
            # lagged metrics: materialise step t's record while step t+1
            # is already dispatched — the host never waits on the step in
            # flight
            jax.tree.map(_to_host_async, metrics)
            if inflight is not None:
                self._flush_metrics(inflight, log_every)
            dt = time.time() - t0
            for pod in self._beat_pods():
                self.monitor.beat(pod, dt)
            newly_dead = self.monitor.check()
            if newly_dead:
                self._on_pods_dead(newly_dead)
            inflight = (metrics, dict(step=step, dt=dt, H=H), i)
            done = self._host_step  # state now holds the post-step counter
            if run.ckpt_every and done % run.ckpt_every == 0:
                self.ckpt.save(done, state, extras=self.ckpt_extras())
        if inflight is not None:
            self._flush_metrics(inflight, log_every)
        return state

    def restore_or_init(self, rng, pipeline):
        if self.ckpt.latest_step() is not None:
            tmpl = self.trainer.state_specs()
            sh = (self.trainer.state_shardings() if self.mesh is not None
                  else None)
            state, extras = self.ckpt.restore(tmpl, shardings=sh)
            self._restore_extras(extras, pipeline)
            restored_step = int(jax.device_get(
                jax.tree.leaves(state["step"])[0].reshape(-1)[0]))
            print(f"restored checkpoint @ step {restored_step}")
            return state
        state = self.trainer.init_state(rng)
        if self.mesh is not None:
            state = jax.device_put(state, self.trainer.state_shardings())
        return state


def main():
    from repro.launch.session import TrainSession

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="paper-350m")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--strategy", default="acesync",
                    choices=list_strategies())
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=None,
                    help="checkpoint cadence in steps (default: RunConfig)")
    args = ap.parse_args()

    run_kw = {}
    if args.ckpt_every is not None:
        run_kw["ckpt_every"] = args.ckpt_every
    sess = TrainSession.from_config(
        args.arch, strategy=args.strategy, smoke=args.smoke,
        seq_len=args.seq_len, batch=args.batch, steps=args.steps,
        warmup_steps=10, ckpt_dir=args.ckpt_dir, **run_kw)
    sess.run(args.steps)
    sess.finish()
    losses = sess.losses
    print(json.dumps({"first_loss": losses[0], "last_loss": losses[-1],
                      "steps": len(losses),
                      "comm_bytes": sess.comm_bytes}))


if __name__ == "__main__":
    main()
