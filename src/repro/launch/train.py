"""End-to-end training driver: the host-side ACE-Sync control loop.

Wires together every subsystem:
  telemetry -> clustering -> omega weights (eq 8)
  bandwidth -> eq (5) budget -> importance scores -> knapsack -> SyncPlan
  divergence (eq 9) -> sync-interval H adaptation
  H local steps per pod + 1 ACE-Sync round, checkpoints, heartbeats,
  straggler detection, elastic restart on simulated pod failure.

The loop is **non-blocking**: since the plan-as-data refactor the host
never stalls the device to replan.

  * The step counter is mirrored on the host (one device fetch at loop
    start) instead of a blocking ``device_get`` per iteration.
  * Replanning for device-capable strategies (ACE-Sync) launches ONE
    device computation (importance scoring + vectorized knapsack, see
    ``core/acesync.device_replan_fn``) and fetches only the tiny
    ``int32[G]`` assignment vector asynchronously; the loop keeps stepping
    on the old plan and swaps once the fetch lands (the replan-to-apply
    latency is recorded in ``replan_latencies``).
  * Per-step metrics and the divergence EMA are fetched LAGGED — the
    record for step t is materialised while step t+1 is already running
    on device, so the host read overlaps device compute.
  * A replan whose bucket signature crosses a size-class boundary is
    warmed SPECULATIVELY: the new signature's step is AOT-compiled in a
    background thread (``Trainer.warm_compile``) before the plan swap
    lands, so a class-ladder rung change never stalls the device on a
    foreground compile.

Runs on any mesh (including none) with any registered arch; reduced configs
train end-to-end on CPU (see examples/train_lm.py).
"""
from __future__ import annotations

import argparse
import inspect
import json
import threading
import time
from typing import Optional, Union

import jax
import numpy as np

from repro.configs import ARCHS, SMOKE_ARCHS, SHAPES
from repro.configs.base import RunConfig, ShapeConfig
from repro.checkpoint.checkpointer import Checkpointer
from repro.core import acesync
from repro.core.trainer import Trainer
from repro.data.pipeline import TokenPipeline
from repro.data.telemetry import make_profiles, snapshot, bandwidth_at
from repro.hierarchy import ClusterState
from repro.models.registry import build_model
from repro.runtime.fault_tolerance import (HeartbeatMonitor,
                                           StragglerDetector)
from repro.strategies import STEP_ADVANCING, SYNC_KINDS, SyncStrategy, \
    list_strategies, resolve_strategy


def _device_ready(x) -> bool:
    """True when an async host fetch of ``x`` would not block."""
    ready = getattr(x, "is_ready", None)
    if ready is None:
        return True  # old jax: accept a (cheap, already-lagged) sync get
    try:
        return bool(ready())
    except Exception:  # pragma: no cover - defensive
        return True


def _to_host_async(x):
    try:
        x.copy_to_host_async()
    except Exception:  # pragma: no cover - old jax / committed host array
        pass
    return x


class TrainLoop:
    """Host control loop around the jitted per-pod steps."""

    def __init__(self, model, run: RunConfig, mesh=None,
                 strategy: Union[str, SyncStrategy] = "acesync",
                 n_edge_devices: int = 8, seed: int = 0):
        self.model = model
        self.run = run
        self.mesh = mesh
        self.trainer = Trainer(model, run, mesh=mesh, strategy=strategy)
        self.strategy = self.trainer.strategy
        self.ckpt = Checkpointer(run.ckpt_dir)
        self.profiles = make_profiles(n_edge_devices, seed)
        sched = self.trainer.scheduler
        # live clustering: 1:1 clusters<->cross-tier pods on a hierarchical
        # mesh, the config's n_clusters otherwise
        self.clusters = ClusterState(
            n_edge_devices,
            sched.n_cross if sched.hier_enabled else run.acesync.n_clusters,
            hysteresis=getattr(run.acesync, "cluster_hysteresis", 0.15))
        self._plan_takes_clusters = "clusters" in inspect.signature(
            self.strategy.make_plan).parameters
        self.monitor = HeartbeatMonitor(max(self.trainer.n_pods, 1))
        self.straggler = StragglerDetector()
        self.history = []
        self.comm_bytes = 0.0
        self._plan = None
        self._steps_since_sync = 0
        self._host_step = None          # host mirror of the device counter
        self._pending_replan = None     # (assign_dev, omega, launched_step)
        self._warming = None            # (plan, thread, launched_step)
        self._div_fetch = None          # lagged divergence EMA fetch
        self.replan_latencies = []      # steps from replan launch to apply

    @property
    def plan(self):
        """The SyncPlan currently being executed (None before the first
        refresh)."""
        return self._plan

    # ---- policy refresh (host side, every replan_every steps) ----------
    def _policy_inputs(self, step: int):
        """Telemetry snapshot -> (telemetry, fleet omega weights).

        The live :class:`~repro.hierarchy.ClusterState` re-clusters on
        each refresh (warm-started k-means + hysteresis, so jitter-only
        telemetry never flaps the assignment), and the per-device
        reliability weights come back already summed into fleet slots —
        cluster-major on a hierarchical mesh, pod-major on a flat one.
        Everything returned is device data; a re-cluster never adds a
        static jit key."""
        telem = snapshot(self.profiles, step)
        sf = self.straggler.straggle_factors(self.monitor)
        for t, pod in zip(telem, range(len(telem))):
            t["straggle"] *= sf.get(pod % max(len(sf), 1), 1.0)
        self.clusters.update(telem)
        sched = self.trainer.scheduler
        return telem, self.clusters.fleet_omega(
            telem, sched.n_cross, sched.n_edge)

    def refresh_plan(self, state, step: int):
        cfg = self.run.acesync
        telem, omega = self._policy_inputs(step)

        dev_fn = (self.strategy.device_plan_fn(self.trainer.scheduler, cfg)
                  if state is not None else None)
        if dev_fn is not None and self._plan is not None:
            # Non-blocking device replan: one jitted computation produces
            # the new plan vector; only the tiny int32[G] assignment is
            # pulled to the host, asynchronously.  The loop keeps stepping
            # on the current plan until the fetch lands (poll_replan).
            # Only the estimator's scalar state enters the computation —
            # never the param-sized error buffers riding in ACEState.
            budget = self.trainer.scheduler.budget_for(
                self.strategy.budget_bandwidth(telem, self.clusters))
            ace = state["ace"]
            imp0 = jax.tree.map(lambda x: x[0], ace.importance)
            assign = _to_host_async(
                dev_fn(imp0, ace.struct_feat[0], budget))
            self._pending_replan = (assign, omega, self._host_step or step)
            return self._plan
        # host path: the first plan, and strategies without a device solver.
        # Only the estimator's few-hundred-scalar state is sliced and
        # fetched — never the param-sized error buffers in ACEState (the
        # group metas / local sizes / leaf layout are likewise computed
        # once at Trainer construction, not re-derived per replan poll).
        imp = None
        if self.strategy.uses_importance and state is not None:
            ace = state["ace"]
            imp0 = jax.tree.map(lambda x: x[0], ace.importance)
            imp = np.asarray(jax.device_get(acesync.scores_from(
                imp0, ace.struct_feat[0], cfg))).tolist()
        kw = dict(importance=imp, telemetry=telem, omega=omega)
        if self._plan_takes_clusters:
            kw["clusters"] = self.clusters
        self._plan = self.strategy.make_plan(self.trainer.scheduler, **kw)
        return self._plan

    def _swap_plan(self, plan, launched) -> bool:
        self._plan = plan
        if self._host_step is not None:
            self.replan_latencies.append(self._host_step - launched)
        return True

    def poll_replan(self, block: bool = False) -> bool:
        """Apply a pending device replan if its async fetch has landed.
        Returns True when the plan was swapped.

        Signature warm-up: when the fetched assignment crosses a
        size-class boundary (a bucket signature the step cache has not
        compiled), the swap is DEFERRED — the new signature's step is
        AOT-compiled in a background thread (``Trainer.warm_compile``)
        while the loop keeps stepping on the current plan, and the swap
        lands on a later poll once the executable is ready.  A rung/class
        change therefore never stalls the device on a foreground
        compile."""
        if self._warming is not None:
            plan, th, launched = self._warming
            if self._pending_replan is not None \
                    and _device_ready(self._pending_replan[0]):
                # a newer assignment landed while this one was warming:
                # abandon the stale swap (the thread still finishes into
                # the AOT cache) and process the fresh fetch below
                self._warming = None
            else:
                if block:
                    th.join()
                if th.is_alive():
                    return False
                self._warming = None
                return self._swap_plan(plan, launched)
        if self._pending_replan is None:
            return False
        assign, omega, launched = self._pending_replan
        if not block and not _device_ready(assign):
            return False
        idx = np.asarray(jax.device_get(assign)).tolist()
        self._pending_replan = None
        plan = self.trainer.scheduler.plan_from_levels(
            idx, omega, adaptive=True)
        if self.trainer.step_is_warm(plan):
            return self._swap_plan(plan, launched)
        th = threading.Thread(target=self.trainer.warm_compile,
                              args=(plan,), daemon=True)
        th.start()
        self._warming = (plan, th, launched)
        if block:
            th.join()
            self._warming = None
            return self._swap_plan(plan, launched)
        return False

    def adapt_interval(self, state):
        """Sync-interval control (eq 9); a fixed H for static strategies.
        The divergence EMA is fetched lagged (the previous replan's launch
        satisfies this one) so the controller never blocks on the step in
        flight."""
        div_now = state["ace"].div_ema[0]
        prev = self._div_fetch
        self._div_fetch = _to_host_async(div_now)
        if prev is None:
            # no lagged sample yet: leave H untouched rather than feeding
            # the controller a fabricated zero divergence
            return (self.trainer.scheduler.sync_interval
                    if self.strategy.adapts_interval
                    else self.strategy.initial_interval(self.run.acesync))
        return self.strategy.adapt(self.trainer.scheduler,
                                   float(jax.device_get(prev)))

    # ---- main loop ------------------------------------------------------
    def _flush_metrics(self, inflight, log_every):
        metrics, rec, idx = inflight
        rec.update({k: float(jax.device_get(v)) for k, v in metrics.items()})
        self.history.append(rec)
        if log_every and idx % log_every == 0:
            print(f"step {rec['step']:5d} "
                  f"loss={rec.get('loss', float('nan')):.4f} "
                  f"H={rec['H']} dt={rec['dt']:.2f}s", flush=True)

    def run_steps(self, state, pipeline, n_steps: int,
                  log_every: int = 10):
        run = self.run
        cfg = run.acesync
        H = self.strategy.initial_interval(cfg)
        # one synchronous fetch to seed the host step mirror
        self._host_step = int(jax.device_get(
            jax.tree.leaves(state["step"])[0].reshape(-1)[0]))
        if self._plan is None:
            self.refresh_plan(state, self._host_step)
        inflight = None
        for i in range(n_steps):
            step = self._host_step
            self.poll_replan()
            if step and step % cfg.replan_every == 0:
                self.refresh_plan(state, step)
                H = self.adapt_interval(state)
            batch = next(pipeline)
            t0 = time.time()
            kinds = self.strategy.step_schedule(self._steps_since_sync, H)
            metrics = {}
            for kind in kinds:
                state, m = self.trainer.step(state, batch, self._plan, kind)
                metrics.update(m)
                self.comm_bytes += self.strategy.wire_bytes(
                    self.trainer.scheduler, self._plan, kind)
                if kind in STEP_ADVANCING:
                    self._host_step += 1
            if SYNC_KINDS & set(kinds):
                self._steps_since_sync = 0
            else:
                self._steps_since_sync += 1
            # lagged metrics: materialise step t's record while step t+1
            # is already dispatched — the host never waits on the step in
            # flight
            jax.tree.map(_to_host_async, metrics)
            if inflight is not None:
                self._flush_metrics(inflight, log_every)
            dt = time.time() - t0
            for pod in range(self.trainer.n_pods):
                self.monitor.beat(pod, dt)
            inflight = (metrics, dict(step=step, dt=dt, H=H), i)
            done = self._host_step  # state now holds the post-step counter
            if run.ckpt_every and done % run.ckpt_every == 0:
                self.ckpt.save(done, state,
                               extras={"pipeline": pipeline.snapshot()})
        if inflight is not None:
            self._flush_metrics(inflight, log_every)
        return state

    def restore_or_init(self, rng, pipeline):
        if self.ckpt.latest_step() is not None:
            tmpl = self.trainer.state_specs()
            sh = (self.trainer.state_shardings() if self.mesh is not None
                  else None)
            state, extras = self.ckpt.restore(tmpl, shardings=sh)
            if "pipeline" in extras:
                pipeline.restore(extras["pipeline"])
            print(f"restored checkpoint @ step {self.ckpt.latest_step()}")
            return state
        state = self.trainer.init_state(rng)
        if self.mesh is not None:
            state = jax.device_put(state, self.trainer.state_shardings())
        return state


def main():
    from repro.launch.session import TrainSession

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="paper-350m")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--strategy", default="acesync",
                    choices=list_strategies())
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    args = ap.parse_args()

    sess = TrainSession.from_config(
        args.arch, strategy=args.strategy, smoke=args.smoke,
        seq_len=args.seq_len, batch=args.batch, steps=args.steps,
        warmup_steps=10, ckpt_dir=args.ckpt_dir)
    sess.run(args.steps)
    sess.finish()
    losses = sess.losses
    print(json.dumps({"first_loss": losses[0], "last_loss": losses[-1],
                      "steps": len(losses),
                      "comm_bytes": sess.comm_bytes}))


if __name__ == "__main__":
    main()
