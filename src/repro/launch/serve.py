"""Serving driver: batched prefill + decode with the model zoo.

Implements a minimal production-shaped serving loop: a request queue,
batched prefill, iterative decode with ring KV caches, and per-request
completion — runnable on CPU with the reduced configs (see
examples/serve_lm.py) and lowerable at full scale via launch/dryrun.py.
"""
from __future__ import annotations

import argparse
import json
import time
from dataclasses import dataclass, field
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, SMOKE_ARCHS
from repro.models.registry import build_model
from repro.models.shardctx import use_shard_ctx


@dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (S,) int32
    max_new_tokens: int = 16
    out_tokens: List[int] = field(default_factory=list)
    t_submit: float = 0.0
    t_done: Optional[float] = None


class Server:
    def __init__(self, model, cache_len: int, batch: int, mesh=None):
        self.model = model
        self.cache_len = cache_len
        self.batch = batch
        self.mesh = mesh
        self._prefill = jax.jit(self._prefill_fn, static_argnums=(2,))
        self._decode = jax.jit(self._decode_fn, donate_argnums=(1,))

    def _prefill_fn(self, params, batch, cache_len=None):
        with use_shard_ctx(self.mesh):
            return self.model.prefill(params, batch, cache_len)

    def _decode_fn(self, params, caches, cache_len, tokens):
        with use_shard_ctx(self.mesh):
            return self.model.decode_step(params, caches, cache_len, tokens)

    def serve(self, params, requests: List[Request]) -> List[Request]:
        """Static batching: pad requests to the server batch, prefill, then
        decode until every request hit its token budget."""
        out = []
        for i in range(0, len(requests), self.batch):
            out.extend(self._serve_batch(params, requests[i:i + self.batch]))
        return out

    def _serve_batch(self, params, reqs: List[Request]) -> List[Request]:
        B = self.batch
        S = max(len(r.prompt) for r in reqs)
        toks = np.zeros((B, S), np.int32)
        for j, r in enumerate(reqs):
            toks[j, S - len(r.prompt):] = r.prompt  # left-pad
            r.t_submit = time.time()
        batch = {"tokens": jnp.asarray(toks)}
        if self.model.cfg.frontend == "vision_stub":
            batch["patch_embs"] = jnp.zeros(
                (B, self.model.cfg.n_patches, self.model.cfg.d_model),
                jnp.float32)
        if self.model.cfg.family == "encdec":
            F = max(64, S // self.model.cfg.audio_downsample)
            batch["frames"] = jnp.zeros((B, F, self.model.cfg.d_model),
                                        jnp.float32)
        logits, caches = self._prefill(params, batch, self.cache_len)
        # grow caches to cache_len if the model allocated prefill-sized ones
        cache_len = jnp.int32(S)
        tokens = jnp.argmax(logits[:, -1, :self.model.cfg.vocab_size],
                            axis=-1).astype(jnp.int32)[:, None]
        max_new = max(r.max_new_tokens for r in reqs)
        for step in range(max_new):
            for j, r in enumerate(reqs):
                if step < r.max_new_tokens:
                    r.out_tokens.append(int(tokens[j, 0]))
            logits, caches = self._decode(params, caches, cache_len, tokens)
            tokens = jnp.argmax(logits[:, -1, :self.model.cfg.vocab_size],
                                axis=-1).astype(jnp.int32)[:, None]
            cache_len = cache_len + 1
        for r in reqs:
            r.t_done = time.time()
        return reqs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="paper-350m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--requests", type=int, default=8)
    args = ap.parse_args()

    cfg = (SMOKE_ARCHS if args.smoke else ARCHS)[args.arch]
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    params = jax.tree.map(lambda x: x.astype(jnp.bfloat16), params)
    server = Server(model, cache_len=args.prompt_len + args.new_tokens,
                    batch=args.batch)
    rng = np.random.RandomState(0)
    reqs = [Request(i, rng.randint(0, cfg.vocab_size,
                                   size=args.prompt_len).astype(np.int32),
                    args.new_tokens)
            for i in range(args.requests)]
    t0 = time.time()
    done = server.serve(params, reqs)
    dt = time.time() - t0
    n_tok = sum(len(r.out_tokens) for r in done)
    print(json.dumps({"requests": len(done), "tokens": n_tok,
                      "wall_s": round(dt, 2),
                      "tok_per_s": round(n_tok / dt, 1)}))


if __name__ == "__main__":
    main()
