"""Production mesh construction.

Single pod : (16, 16)    axes ("data", "model")   = 256 chips (v5e pod)
Multi-pod  : (2, 16, 16) axes ("pod", "data", "model") = 512 chips.

``make_production_mesh`` is a FUNCTION so importing this module never
touches jax device state (the dry-run sets XLA_FLAGS before any jax call).
"""
from __future__ import annotations

import jax


def _axis_type_kwargs(n_axes: int) -> dict:
    """jax >= 0.5 wants explicit axis_types; older jax (no
    jax.sharding.AxisType) defaults every axis to Auto already."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


def make_mesh(shape, axes, devices=None):
    """Arbitrary mesh (tests / small simulations).

    ``devices``: explicit device list — the elastic-membership path builds
    a smaller mesh over the surviving subset of ``jax.devices()`` after a
    pod drops out (jax.make_mesh always spans the full inventory)."""
    shape, axes = tuple(shape), tuple(axes)
    if devices is None:
        return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))
    import numpy as np
    need = int(np.prod(shape))
    if len(devices) < need:
        raise ValueError(f"mesh {shape} needs {need} devices, "
                         f"got {len(devices)}")
    grid = np.asarray(devices[:need], dtype=object).reshape(shape)
    return jax.sharding.Mesh(grid, axes, **_axis_type_kwargs(len(axes)))


# Hardware constants for the roofline analysis (TPU v5e)
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW = 50e9                     # bytes/s per link (intra-pod)
DCN_BW = 6.25e9                   # bytes/s per pod-pair link (inter-pod,
                                  # 50 Gbit/s WAN-ish — the paper's regime)
