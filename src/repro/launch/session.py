"""TrainSession: the one-call facade over model build + trainer + host loop.

Examples, benchmarks and the train CLI go through this instead of reaching
into trainer internals::

    from repro.launch.session import TrainSession

    sess = TrainSession.from_config("paper-350m", strategy="acesync")
    sess.run(100)
    print(sess.losses[-1], sess.comm_bytes)

Any registered strategy name (see ``repro.strategies.list_strategies()``)
or a :class:`~repro.strategies.SyncStrategy` instance works.
"""
from __future__ import annotations

from typing import Optional, Union

import jax

from repro.configs import ARCHS, SMOKE_ARCHS
from repro.configs.base import RunConfig, ShapeConfig
from repro.data.pipeline import TokenPipeline
from repro.launch.train import TrainLoop
from repro.models.registry import build_model
from repro.strategies import SyncStrategy


class TrainSession:
    """Owns (model, run, loop, pipeline, state) for one training run."""

    def __init__(self, model, run: RunConfig, mesh=None,
                 strategy: Union[str, SyncStrategy] = "acesync",
                 n_edge_devices: int = 8, seed: int = 0,
                 fault_schedule=None, elastic: bool = True,
                 blocking_replans: bool = False):
        self.model = model
        self.run_config = run
        self.mesh = mesh
        self.loop = TrainLoop(model, run, mesh=mesh, strategy=strategy,
                              n_edge_devices=n_edge_devices, seed=seed,
                              fault_schedule=fault_schedule,
                              elastic=elastic,
                              blocking_replans=blocking_replans)
        self.pipeline = TokenPipeline(model, run.shape, seed=seed)
        self._rng = jax.random.PRNGKey(run.seed)
        self.state = None

    @classmethod
    def from_config(cls, arch: str,
                    strategy: Union[str, SyncStrategy] = "acesync",
                    mesh=None, *, smoke: bool = True, seq_len: int = 256,
                    batch: int = 8, steps: int = 100,
                    n_edge_devices: int = 8, seed: int = 0,
                    fault_schedule=None, elastic: bool = True,
                    blocking_replans: bool = False,
                    **run_kw) -> "TrainSession":
        """Build a session from an architecture name + strategy spec."""
        cfg = (SMOKE_ARCHS if smoke else ARCHS)[arch]
        shape = ShapeConfig("session", seq_len, batch, "train")
        run_kw.setdefault("warmup_steps", max(2, steps // 10))
        run = RunConfig(model=cfg, shape=shape, total_steps=steps, **run_kw)
        model = build_model(cfg, run)
        return cls(model, run, mesh=mesh, strategy=strategy,
                   n_edge_devices=n_edge_devices, seed=seed,
                   fault_schedule=fault_schedule, elastic=elastic,
                   blocking_replans=blocking_replans)

    # ---- lifecycle ------------------------------------------------------
    @property
    def trainer(self):
        return self.loop.trainer

    @property
    def strategy(self) -> SyncStrategy:
        return self.loop.strategy

    def init(self):
        """Restore the latest checkpoint or initialize fresh state."""
        if self.state is None:
            self.state = self.loop.restore_or_init(self._rng, self.pipeline)
        return self.state

    def run(self, n_steps: Optional[int] = None,
            log_every: int = 10) -> "TrainSession":
        """Run n_steps (default: the RunConfig total) of the control loop."""
        self.init()
        self.state = self.loop.run_steps(
            self.state, self.pipeline,
            n_steps if n_steps is not None else self.run_config.total_steps,
            log_every=log_every)
        return self

    def finish(self):
        """Flush pending checkpoint writes (re-raises a failed write)."""
        self.loop.ckpt.wait()

    def save_now(self):
        """Force a full-state checkpoint at the current step (blocking)."""
        import jax as _jax
        step = int(_jax.device_get(
            _jax.tree.leaves(self.state["step"])[0].reshape(-1)[0]))
        if self.loop._pipeline is None:
            self.loop._pipeline = self.pipeline
        self.loop.ckpt.save(step, self.state,
                            extras=self.loop.ckpt_extras(), blocking=True)
        return step

    # ---- results --------------------------------------------------------
    @property
    def history(self):
        return self.loop.history

    @property
    def losses(self):
        return [h["loss"] for h in self.loop.history if "loss" in h]

    @property
    def comm_bytes(self) -> float:
        """Cumulative pod-tier wire bytes (strategy-priced, per device)."""
        return self.loop.comm_bytes
