"""Multi-pod dry-run: lower + compile every (architecture x input-shape)
cell on the production meshes, collect memory / cost / collective evidence.

MUST set the host-device override before ANY other import touches jax."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, SHAPES, cells
from repro.configs.base import RunConfig
from repro.core.trainer import Trainer
from repro.launch import mesh as mesh_lib
from repro.models.registry import build_model
from repro.models.flops import model_flops
from repro.models.shardctx import use_shard_ctx, sharding_for
from repro.strategies import list_strategies


def _with_sharding(specs, shardings_tree, mesh):
    """Attach NamedShardings to a ShapeDtypeStruct pytree."""
    def attach(s, sh):
        return jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh)
    return jax.tree.map(attach, specs, shardings_tree)


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool,
               strategy: str = "acesync", run_overrides: dict = None):
    """Returns (lowered, meta) for one cell."""
    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    cfg = ARCHS[arch]
    shape = SHAPES[shape_name]
    run = RunConfig(model=cfg, shape=shape, multi_pod=multi_pod,
                    **(run_overrides or {}))
    model = build_model(cfg, run)

    if shape.kind == "train":
        trainer = Trainer(model, run, mesh=mesh, strategy=strategy)
        plan = trainer.default_plan(bandwidth_mbps=50.0)
        # plan-as-data: lower the signature-keyed step with the plan
        # vectors (gather perms + omega) as replicated array arguments
        fn = trainer.jit_step(plan, trainer.strategy.representative_kind)
        state = _with_sharding(trainer.state_specs(),
                               trainer.state_shardings(), mesh)
        batch = _with_sharding(model.input_specs(shape),
                               trainer.batch_shardings(shape), mesh)
        lowered = fn.lower(state, batch, trainer.plan_arg_specs(plan))
        extra = {"plan": [plan.levels[i].name for i in plan.level_idx],
                 "bucket_sig": list(plan.bucket_sig or ()),
                 "strategy": trainer.strategy_name}
    else:
        # serving: bf16 params, no pod-replica dim
        isP = lambda x: isinstance(x, jax.sharding.PartitionSpec)  # noqa
        pspecs = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, jnp.bfloat16),
            model.param_specs())
        pshard = jax.tree.map(
            lambda sp, s: sharding_for(mesh, sp, shape=s.shape),
            model.param_shardings(), pspecs, is_leaf=isP)
        params = _with_sharding(pspecs, pshard, mesh)
        bspecs = model.input_specs(shape)
        bshard = jax.tree.map(
            lambda sp, s: sharding_for(mesh, sp, shape=s.shape),
            model.input_shardings(shape), bspecs, is_leaf=isP)
        batch = _with_sharding(bspecs, bshard, mesh)

        with use_shard_ctx(mesh):
            if shape.kind == "prefill":
                lowered = jax.jit(model.prefill).lower(params, batch)
            else:  # decode
                B = shape.global_batch
                cspecs = model.cache_specs(B, shape.cache_len)
                cshard = jax.tree.map(
                    lambda sp, s: sharding_for(mesh, sp, shape=s.shape),
                    model.cache_shardings(), cspecs, is_leaf=isP)
                caches = _with_sharding(cspecs, cshard, mesh)
                clen = jax.ShapeDtypeStruct(
                    (), jnp.int32, sharding=sharding_for(
                        mesh, jax.sharding.PartitionSpec()))
                lowered = jax.jit(model.decode_step,
                                  donate_argnums=(1,)).lower(
                    params, caches, clen, batch["tokens"])
        extra = {"mode": shape.kind}
    return lowered, mesh, model, run, extra


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             strategy: str = "acesync", out_dir: str = None,
             run_overrides: dict = None) -> dict:
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__),
                                    "..", "..", ".."))
    from benchmarks import hlo_cost

    t0 = time.time()
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "strategy": strategy, "ok": False}
    try:
        lowered, mesh, model, run, extra = lower_cell(
            arch, shape_name, multi_pod=multi_pod, strategy=strategy,
            run_overrides=run_overrides)
        rec.update(extra)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()

        ma = compiled.memory_analysis()
        mem = {}
        if ma is not None:
            mem = {k: int(getattr(ma, k, 0)) for k in (
                "argument_size_in_bytes", "output_size_in_bytes",
                "temp_size_in_bytes", "alias_size_in_bytes",
                "generated_code_size_in_bytes")}
        ca = compiled.cost_analysis()
        if isinstance(ca, list):
            ca = ca[0] if ca else {}
        raw_cost = {"flops": float(ca.get("flops", 0.0)),
                    "bytes": float(ca.get("bytes accessed", 0.0))} if ca \
            else {}

        txt = compiled.as_text()
        mesh_shape = tuple(mesh.shape.values())
        axis_names = tuple(mesh.axis_names)
        rep = hlo_cost.analyze(txt, mesh_shape, axis_names)
        n_chips = 1
        for d in mesh_shape:
            n_chips *= d

        shape_cfg = SHAPES[shape_name]
        mf = model_flops(ARCHS[arch], shape_cfg)
        rec.update({
            "ok": True,
            "lower_s": round(t1 - t0, 2),
            "compile_s": round(t2 - t1, 2),
            "n_chips": n_chips,
            "memory": mem,
            "bytes_per_device": int(sum(mem.get(k, 0) for k in (
                "argument_size_in_bytes", "output_size_in_bytes",
                "temp_size_in_bytes"))),
            "raw_cost_analysis": raw_cost,
            "walker": {
                "flops_per_device": rep.flops,
                "bytes_per_device": rep.bytes_accessed,
                "collective_bytes_per_device": dict(rep.collective_bytes),
                "collective_counts": dict(rep.collective_count),
                "op_flops": dict(rep.op_flops),
            },
            "model_flops_global": mf,
            "hlo_flops_global": rep.flops * n_chips,
            "useful_ratio": (mf / (rep.flops * n_chips)
                             if rep.flops else None),
        })
    except Exception as e:  # noqa
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        fname = f"{rec['mesh']}_{arch}_{shape_name}_{strategy}.json"
        with open(os.path.join(out_dir, fname), "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--strategy", default="acesync",
                    choices=list_strategies())
    ap.add_argument("--out", default="benchmarks/results")
    ap.add_argument("--remat", default=None)
    ap.add_argument("--q-chunk", type=int, default=None)
    ap.add_argument("--kv-chunk", type=int, default=None)
    args = ap.parse_args()

    overrides = {}
    if args.remat:
        overrides["remat"] = args.remat
    if args.q_chunk:
        overrides["q_chunk"] = args.q_chunk
    if args.kv_chunk:
        overrides["kv_chunk"] = args.kv_chunk

    todo = []
    if args.arch and args.shape:
        todo = [(args.arch, args.shape)]
    else:
        todo = cells()
        if args.arch:
            todo = [(a, s) for a, s in todo if a == args.arch]

    for arch, shape in todo:
        rec = run_cell(arch, shape, multi_pod=args.multi_pod,
                       strategy=args.strategy, out_dir=args.out,
                       run_overrides=overrides or None)
        status = "OK" if rec.get("ok") else f"FAIL {rec.get('error')}"
        print(f"[{rec['mesh']}] {arch} x {shape} ({args.strategy}): {status}"
              f"  compile={rec.get('compile_s')}s"
              f"  mem/dev={rec.get('bytes_per_device', 0)/1e9:.2f}GB",
              flush=True)
        if rec.get("ok"):
            cb = rec["walker"]["collective_bytes_per_device"]
            print(f"    flops/dev={rec['walker']['flops_per_device']:.3e}"
                  f"  bytes/dev={rec['walker']['bytes_per_device']:.3e}"
                  f"  collectives={ {k: f'{v:.2e}' for k, v in cb.items()} }",
                  flush=True)


if __name__ == "__main__":
    main()
