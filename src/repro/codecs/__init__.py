"""Pluggable wire-format codecs (see codecs/base.py for the contract)."""
from repro.codecs.base import (EDGE_AXIS, POD_AXIS, Codec, build_codec,
                               codec_for_level, get_codec, list_codecs,
                               n_blocks, pack_bits, pack_payload,
                               plan_intra_bytes, plan_wire_bytes,
                               register_codec, unpack_bits, unpack_payload)
from repro.codecs import builtin as _builtin  # noqa: F401 - registers codecs

__all__ = [
    "EDGE_AXIS", "POD_AXIS", "Codec", "build_codec", "codec_for_level",
    "get_codec", "list_codecs", "n_blocks", "pack_bits", "pack_payload",
    "plan_intra_bytes", "plan_wire_bytes", "register_codec", "unpack_bits",
    "unpack_payload",
]
