"""First-class compression codecs for the sync wire.

A :class:`Codec` owns everything one rung of the compression ladder used to
smear across four layers (``core/compression.py``, ``core/sync.py``,
``core/knapsack.py``, ``Scheduler``):

  * ``encode`` / ``decode``       — the wire format on blocked gradients
    (the pure-jnp oracle path, bit-exact to the seed operators);
  * ``ef_encode``                 — the fused device-local hot path:
    error feedback + compression through the Pallas kernels in
    ``repro/kernels`` when ``use_pallas`` is on;
  * ``pod_exchange``              — the codec's aggregation math over the
    slow "pod" axis.  The default packs the whole payload pytree into ONE
    flat uint8 buffer and issues ONE ``all_gather``, so a sync round costs
    one collective per codec no matter how many payload components the
    wire format carries;
  * ``ef_sync_ring`` / ``decode_accumulate`` — the chunked ring pipeline:
    the payload is split into K chunks circulated with ``ppermute`` over
    the pod axis, and while chunk *i* is on the DCN its predecessor is
    decoded and accumulated in place (fused Pallas decode-accumulate
    kernels on accelerators), hiding the decode behind the wire.  The
    gathered ``(n_pods, payload)`` buffer is never materialised: the live
    wire state is the held + in-flight chunk per lane — at most ~2x the
    bucket payload, vs ``n_pods x`` for the one-shot gather.  Which rungs
    ring (and with how many chunks) is a static plan decision — see
    ``repro.core.planexec.ring_chunk_count``;
  * ``wire_bytes``                — analytic per-device on-the-wire bytes
    for the collective the codec actually issues (all_gather receive
    volume for gather codecs, ring all-reduce bytes for psum codecs).
    This is the ONE place comm volume is priced: the scheduler, the
    knapsack, Table 1 and the dry-run byte assertions all read it, and
    tests/test_collectives.py pins it to the traced HLO collective bytes.

Codecs register by name with :func:`register_codec` (mirroring
``repro/strategies``); ``Level`` (core/compression.py) is now a thin view
that resolves to a registered codec via :func:`codec_for_level`.
"""
from __future__ import annotations

import math
from typing import Dict, List, Sequence, Tuple, Type

import jax
import jax.numpy as jnp

from repro.core.compression import BLOCK, pad_to_blocks

#: the bandwidth-constrained mesh axis payloads cross (see core/sync.py).
POD_AXIS = "pod"


# ---------------------------------------------------------------------------
# payload packing: one uint8 wire buffer per codec
# ---------------------------------------------------------------------------


def pack_payload(payload: Dict[str, jax.Array]
                 ) -> Tuple[jax.Array, tuple]:
    """Bitcast + concatenate a payload pytree into one flat uint8 buffer.

    Keys are packed in sorted order so the layout is deterministic; the
    returned ``meta`` (static) is what :func:`unpack_payload` needs to
    invert the packing on the receiving side.
    """
    parts, meta = [], []
    for key in sorted(payload):
        a = payload[key]
        u8 = jax.lax.bitcast_convert_type(a, jnp.uint8)
        parts.append(u8.reshape(-1))
        meta.append((key, tuple(a.shape), jnp.dtype(a.dtype)))
    if not parts:
        return jnp.zeros((0,), jnp.uint8), tuple(meta)
    wire = parts[0] if len(parts) == 1 else jnp.concatenate(parts)
    return wire, tuple(meta)


def unpack_payload(wire: jax.Array, meta: tuple) -> Dict[str, jax.Array]:
    """Inverse of :func:`pack_payload` (static offsets from ``meta``)."""
    out, off = {}, 0
    for key, shape, dtype in meta:
        elems = math.prod(shape) if shape else 1
        nbytes = elems * dtype.itemsize
        seg = wire[off:off + nbytes]
        if dtype.itemsize == 1:
            arr = jax.lax.bitcast_convert_type(seg.reshape(shape), dtype)
        else:
            arr = jax.lax.bitcast_convert_type(
                seg.reshape(shape + (dtype.itemsize,)), dtype)
        out[key] = arr
        off += nbytes
    return out


def pack_bits(bools: jax.Array) -> jax.Array:
    """(rows, C) boolean -> (rows, C // 8) uint8, bit i = column 8r+i."""
    rows, c = bools.shape
    b = bools.reshape(rows, c // 8, 8).astype(jnp.uint8)
    weights = (jnp.uint8(1) << jnp.arange(8, dtype=jnp.uint8))
    return jnp.sum(b * weights, axis=-1).astype(jnp.uint8)


def unpack_bits(packed: jax.Array, c: int) -> jax.Array:
    """Inverse of :func:`pack_bits` -> (rows, c) {0, 1} uint8."""
    bits = (packed[..., None] >> jnp.arange(8, dtype=jnp.uint8)) & 1
    return bits.reshape(packed.shape[0], c)


def n_blocks(n: int, block: int = BLOCK) -> int:
    return (n + block - 1) // block


# ---------------------------------------------------------------------------
# the Codec contract
# ---------------------------------------------------------------------------


class Codec:
    """One wire format: compression math + pod aggregation + accounting."""

    #: registry key; subclasses must override.
    name: str = ""
    #: bits per transmitted value (accounting/ladder ordering only).
    value_bits: int = 16
    #: fraction of entries transmitted (1.0 = dense).
    keep_ratio: float = 1.0
    #: whether the chunked ring pipeline applies: True for gather codecs
    #: (payload circulated + decode-accumulated per peer).  Codecs whose
    #: exchange is not a per-peer payload gather (FULL's psum, SKIP's
    #: nothing) have no decode to hide and stay on their one-shot path.
    supports_ring: bool = True

    # ---- accounting -----------------------------------------------------
    def payload_bytes(self, n: int, block: int = BLOCK) -> int:
        """Per-device payload size actually put on the wire (== the packed
        uint8 buffer size from :func:`pack_payload`)."""
        raise NotImplementedError

    def wire_bytes(self, n: int, n_pods: int, block: int = BLOCK) -> int:
        """Per-device per-sync bytes over the pod axis.  Default: ring
        all_gather receive volume — each device receives every peer's
        payload once."""
        if n_pods <= 1 or n <= 0:
            return 0
        return self.payload_bytes(n, block) * (n_pods - 1)

    def value_fraction(self) -> float:
        """Knapsack value heuristic: fraction of gradient 'information'
        preserved.  Only needs to ORDER the ladder (see core/knapsack.py)."""
        return 1.0

    # ---- wire format (oracle path, bit-exact to the seed operators) ----
    def encode(self, blocks: jax.Array) -> Dict[str, jax.Array]:
        """(nb, block) f32 -> payload pytree of arrays."""
        raise NotImplementedError

    def decode(self, payload: Dict[str, jax.Array],
               block: int = BLOCK) -> jax.Array:
        """payload -> dense (nb, block) f32 (receiver reconstruction)."""
        raise NotImplementedError

    # ---- fused device-local hot path -----------------------------------
    def ef_encode(self, flat: jax.Array, e_flat: jax.Array, *, gamma: float,
                  block: int = BLOCK, use_pallas: bool = False
                  ) -> Tuple[Dict[str, jax.Array], jax.Array, jax.Array]:
        """Error feedback + compress one flat (n,) f32 buffer.

        Returns ``(payload, own, new_e)``: ``own = decode(payload)[:n]`` is
        exactly what every receiver reconstructs from this device's
        payload, and ``new_e = (flat + gamma*e_flat) - own`` is the next
        error-feedback residual.  Subclasses with a Pallas kernel override
        this to fuse the EF accumulate + compression into one HBM pass
        when ``use_pallas`` is set (the kernels emit the residual
        directly).  ``own`` is only consumed on the single-pod path, so
        multi-pod jit dead-code-eliminates its computation.
        """
        n = flat.shape[0]
        ef = flat + gamma * e_flat
        payload = self.encode(pad_to_blocks(ef, block))
        own = self.decode(payload, block).reshape(-1)[:n]
        return payload, own, ef - own

    # ---- pod aggregation ------------------------------------------------
    def pod_exchange(self, payload: Dict[str, jax.Array],
                     omega: jax.Array, *, n: int, block: int = BLOCK,
                     axis: str = POD_AXIS) -> jax.Array:
        """Aggregate payloads across the pod axis -> (n,) f32.

        Default: pack the payload into one uint8 buffer, ONE ``all_gather``
        over ``axis``, then the omega-weighted sum of per-peer decodes
        (paper eq. 8), accumulated one peer at a time so the dense
        transient stays at one (n,) buffer instead of (P, n) — with
        bucketing n can be the whole model, and a stacked decode would
        multiply peak sync memory by the pod count.  Codecs whose
        aggregation is not a weighted sum of decodes (FULL's psum, SIGN's
        majority vote) override this.
        """
        wire, meta = pack_payload(payload)
        gathered = jax.lax.all_gather(wire, axis)       # (P, payload_bytes)
        n_peers = gathered.shape[0]
        agg = jnp.zeros((n,), jnp.float32)
        for p in range(n_peers):
            dense = self.decode(unpack_payload(gathered[p], meta),
                                block).reshape(-1)[:n]
            agg = agg + omega[p] * dense
        return agg

    # ---- chunked ring pipeline ------------------------------------------
    def accum_init(self, nb: int, block: int = BLOCK):
        """Fresh accumulator for ``nb`` blocks of ring aggregation.
        Default: the dense f32 partial sum.  Codecs that aggregate in the
        compressed domain (SIGN's majority vote) override with their own
        partial state."""
        return jnp.zeros((nb, block), jnp.float32)

    def decode_accumulate(self, acc, payload: Dict[str, jax.Array],
                          weight: jax.Array, *, block: int = BLOCK,
                          use_pallas: bool = False):
        """``acc (+)= weight * decode(payload)`` — ONE peer's chunk folded
        into the running aggregate.  The oracle default materialises the
        dense decode; subclasses fuse dequant + FMA into one HBM pass with
        the Pallas kernels in ``repro/kernels/decode.py`` when
        ``use_pallas`` is set."""
        return acc + weight * self.decode(payload, block)

    def accum_finalize(self, acc, n: int, block: int = BLOCK) -> jax.Array:
        """Running aggregate -> dense (n,) f32 (identity for the default
        dense partial sum)."""
        return acc.reshape(-1)[:n]

    def _chunk_payload(self, payload: Dict[str, jax.Array], i: int,
                       cb: int) -> Dict[str, jax.Array]:
        """Rows ``[i*cb, (i+1)*cb)`` of every payload component.  Valid
        for any blockwise wire format (every component's leading dim is
        the block row)."""
        return {k: a[i * cb:(i + 1) * cb] for k, a in payload.items()}

    def ef_sync_ring(self, flat: jax.Array, e_flat: jax.Array,
                     omega: jax.Array, omega_own: jax.Array, *,
                     gamma: float, n_pods: int, n_chunks: int,
                     block: int = BLOCK, axis: str = POD_AXIS,
                     use_pallas: bool = False
                     ) -> Tuple[jax.Array, jax.Array]:
        """EF + compress + CHUNKED RING exchange of one flat buffer.

        The payload is split into ``n_chunks`` equal chunks (the caller —
        ``planexec.exec_grid`` — pads the bucket to a chunk multiple) and
        circulated around the pod ring with K*(P-1) ``ppermute``s, exactly
        the all_gather receive volume on the wire.  The decode-accumulate
        of chunk *i-1* is issued between the ppermute of chunk *i* and any
        use of its result, so it carries no data dependence on the
        in-flight transfer and XLA's latency-hiding scheduler overlaps the
        DCN hop with the decode; the (P, payload) gathered buffer is never
        materialised — the live wire state is each lane's held +
        in-flight chunk, at most ~2x the bucket payload regardless of the
        pod count.

        Bit-parity with :meth:`ef_sync`: on a 2-pod ring the aggregate is
        the same two-term omega-weighted sum (addition commutes), pinned
        by tests/test_codecs.py and the subprocess exchange parity test.
        For P >= 3 each pod folds peers in ring-arrival order, so per-pod
        aggregates can differ at ulp level (fp non-associativity) — the
        auto chunk heuristic therefore only rings 2-pod meshes (see
        ``planexec.ring_chunk_count``).
        """
        if n_pods <= 1 or not self.supports_ring:
            return self.ef_sync(flat, e_flat, omega, omega_own,
                                gamma=gamma, n_pods=n_pods, block=block,
                                axis=axis, use_pallas=use_pallas)
        n = flat.shape[0]
        payload, _own, new_e = self.ef_encode(flat, e_flat, gamma=gamma,
                                              block=block,
                                              use_pallas=use_pallas)
        nb = n_blocks(n, block)
        K = max(1, min(int(n_chunks), nb))
        assert nb % K == 0, (nb, K)
        cb = nb // K
        chunks = [self._chunk_payload(payload, i, cb) for i in range(K)]
        # hop 0: own contribution (same first term as the one-shot path)
        accs = [self.decode_accumulate(self.accum_init(cb, block),
                                       chunks[i], omega_own, block=block,
                                       use_pallas=use_pallas)
                for i in range(K)]
        wires = [pack_payload(c) for c in chunks]
        meta = wires[0][1]
        cur = [w for w, _ in wires]
        my = jax.lax.axis_index(axis)
        fwd = [(p, (p + 1) % n_pods) for p in range(n_pods)]
        for h in range(1, n_pods):
            w_src = omega[(my - h) % n_pods]
            nxt, prev, pi = [], None, -1
            for i in range(K):
                r = jax.lax.ppermute(cur[i], axis, fwd)
                if prev is not None:
                    # decode chunk i-1 while chunk i is on the DCN
                    accs[pi] = self.decode_accumulate(
                        accs[pi], unpack_payload(prev, meta), w_src,
                        block=block, use_pallas=use_pallas)
                nxt.append(r)
                prev, pi = r, i
            accs[pi] = self.decode_accumulate(
                accs[pi], unpack_payload(prev, meta), w_src, block=block,
                use_pallas=use_pallas)
            cur = nxt
        parts = [self.accum_finalize(a, cb * block, block) for a in accs]
        agg = parts[0] if K == 1 else jnp.concatenate(parts)
        return agg[:n], new_e

    # ---- one sync round -------------------------------------------------
    def ef_sync(self, flat: jax.Array, e_flat: jax.Array, omega: jax.Array,
                omega_own: jax.Array, *, gamma: float, n_pods: int,
                block: int = BLOCK, axis: str = POD_AXIS,
                use_pallas: bool = False
                ) -> Tuple[jax.Array, jax.Array]:
        """EF + compress + exchange one flat buffer.  Returns
        ``(agg, new_e)`` with the invariant ``own + new_e == ef`` (the
        lossless transmit/residual split error feedback relies on)."""
        n = flat.shape[0]
        payload, own, new_e = self.ef_encode(flat, e_flat, gamma=gamma,
                                             block=block,
                                             use_pallas=use_pallas)
        if n_pods > 1:
            agg = self.pod_exchange(payload, omega, n=n, block=block,
                                    axis=axis)
        else:
            agg = own * omega_own
        return agg, new_e

    def __repr__(self) -> str:  # pragma: no cover - debugging nicety
        return f"<{type(self).__name__} {self.name!r}>"


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Type[Codec]] = {}


def register_codec(cls: Type[Codec]) -> Type[Codec]:
    """Class decorator: make ``cls`` resolvable by its ``name``."""
    if not cls.name:
        raise ValueError(f"{cls.__name__} must set a non-empty .name")
    if _REGISTRY.get(cls.name) not in (None, cls):
        raise ValueError(f"codec {cls.name!r} already registered by "
                         f"{_REGISTRY[cls.name].__name__}")
    _REGISTRY[cls.name] = cls
    return cls


def list_codecs() -> List[str]:
    """Registered codec names (sorted, stable for CLIs/benchmarks)."""
    return sorted(_REGISTRY)


def get_codec(name: str) -> Type[Codec]:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown codec {name!r}; registered: "
                       f"{list_codecs()}") from None


def build_codec(name: str, **kwargs) -> Codec:
    """Instantiate a registered codec by name."""
    return get_codec(name)(**kwargs)


# ---------------------------------------------------------------------------
# Level -> Codec resolution (core/compression.Level is a thin view)
# ---------------------------------------------------------------------------

_CODEC_CACHE: Dict[Tuple[float, int], Codec] = {}


def codec_for_level(level) -> Codec:
    """Resolve a ``Level(name, keep_ratio, value_bits)`` view to its codec
    instance (cached — codecs are stateless)."""
    key = (float(level.keep_ratio), int(level.value_bits))
    codec = _CODEC_CACHE.get(key)
    if codec is None:
        ratio, bits = key
        if ratio <= 0.0:
            codec = build_codec("skip")
        elif ratio < 1.0:
            codec = build_codec("topk", ratio=ratio)
        elif bits >= 16:
            codec = build_codec("full")
        elif bits >= 8:
            codec = build_codec("int8")
        elif bits >= 4:
            codec = build_codec("int4")
        else:
            codec = build_codec("sign")
        _CODEC_CACHE[key] = codec
    return codec


# ---------------------------------------------------------------------------
# plan pricing (bucketed: what the wire actually carries)
# ---------------------------------------------------------------------------


def plan_wire_bytes(plan, sizes: Sequence[int], n_pods: int,
                    block: int = BLOCK, use_sig: bool = True) -> int:
    """Analytic per-device wire bytes for a plan, priced the way
    ``core/sync.sync_tree`` actually transmits it: block-aligned leaves
    repacked into one per-rung buffer and one collective, per-leaf block
    padding included.  When the plan carries its padded bucket signature
    (``SyncPlan.bucket_sig``, attached by the Scheduler for plans the
    retrace-free exchange pads to size classes), that signature is priced
    — the exact bytes the executed exchange moves.  ``use_sig=False``
    forces the unpadded (exact-bucket) total, the analytic floor the
    padding overhead is measured against."""
    from repro.core.planexec import bucket_signature, sig_wire_bytes
    sig = getattr(plan, "bucket_sig", None) if use_sig else None
    if sig is not None and getattr(plan, "bucket_block", block) != block:
        sig = None  # signature counted in a different block size: rebuild
    if sig is None:
        sig = bucket_signature(plan.level_idx, sizes, len(plan.levels),
                               block)
    return sig_wire_bytes(sig, plan.levels, n_pods, block)
