"""First-class compression codecs for the sync wire.

A :class:`Codec` owns everything one rung of the compression ladder used to
smear across four layers (``core/compression.py``, ``core/sync.py``,
``core/knapsack.py``, ``Scheduler``):

  * ``encode`` / ``decode``       — the wire format on blocked gradients
    (the pure-jnp oracle path, bit-exact to the seed operators);
  * ``ef_encode``                 — the fused device-local hot path:
    error feedback + compression through the Pallas kernels in
    ``repro/kernels`` when ``use_pallas`` is on;
  * ``pod_exchange``              — the codec's aggregation math over the
    slow "pod" axis.  The default packs the whole payload pytree into ONE
    flat uint8 buffer and issues ONE ``all_gather``, so a sync round costs
    one collective per codec no matter how many payload components the
    wire format carries;
  * ``ef_sync_ring`` / ``decode_accumulate`` — the chunked ring pipeline:
    the payload is split into K chunks circulated with ``ppermute`` over
    the pod axis (both DCN directions at once by default — two
    half-rings of ⌈(P-1)/2⌉ hops, same wire bytes, ~2x full-duplex
    bandwidth), and while chunk *i* is on the DCN its predecessor is
    decoded and accumulated in place (fused Pallas decode-accumulate
    kernels on accelerators), hiding the decode behind the wire.  The
    gathered ``(n_pods, payload)`` buffer is never materialised: the live
    wire state is the held + in-flight chunk per lane — at most ~2x the
    bucket payload, vs ``n_pods x`` for the one-shot gather.  Which rungs
    ring (and with how many chunks) is a static plan decision — see
    ``repro.core.planexec.ring_chunk_count``.  Whenever >= 3 pods
    exchange, BOTH the ring and the one-shot fold switch to the codec's
    deterministic accumulation (int32 fixed-point partial sums /
    integer vote counts, or canonical-order buffering for
    ``canonical_fold`` codecs), so per-pod aggregates are bit-identical
    in any fold order and the two exchange paths never disagree;
  * ``wire_bytes``                — analytic per-device on-the-wire bytes
    for the collective the codec actually issues (all_gather receive
    volume for gather codecs, ring all-reduce bytes for psum codecs).
    This is the ONE place comm volume is priced: the scheduler, the
    knapsack, Table 1 and the dry-run byte assertions all read it, and
    tests/test_collectives.py pins it to the traced HLO collective bytes.

Codecs register by name with :func:`register_codec` (mirroring
``repro/strategies``); ``Level`` (core/compression.py) is now a thin view
that resolves to a registered codec via :func:`codec_for_level`.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Sequence, Tuple, Type

import jax
import jax.numpy as jnp

from repro.core.compression import BLOCK, pad_to_blocks
from repro.kernels.decode import (FIXED_POINT_BITS, fixed_point,
                                  from_fixed_point)

#: the bandwidth-constrained mesh axis payloads cross (see core/sync.py).
POD_AXIS = "pod"
#: the fast intra-cluster mesh axis of the two-tier topology (optional —
#: only present on hierarchical meshes; see core/sync.py).
EDGE_AXIS = "edge"


# ---------------------------------------------------------------------------
# payload packing: one uint8 wire buffer per codec
# ---------------------------------------------------------------------------


def pack_payload(payload: Dict[str, jax.Array]
                 ) -> Tuple[jax.Array, tuple]:
    """Bitcast + concatenate a payload pytree into one flat uint8 buffer.

    Keys are packed in sorted order so the layout is deterministic; the
    returned ``meta`` (static) is what :func:`unpack_payload` needs to
    invert the packing on the receiving side.
    """
    parts, meta = [], []
    for key in sorted(payload):
        a = payload[key]
        u8 = jax.lax.bitcast_convert_type(a, jnp.uint8)
        parts.append(u8.reshape(-1))
        meta.append((key, tuple(a.shape), jnp.dtype(a.dtype)))
    if not parts:
        return jnp.zeros((0,), jnp.uint8), tuple(meta)
    wire = parts[0] if len(parts) == 1 else jnp.concatenate(parts)
    return wire, tuple(meta)


def unpack_payload(wire: jax.Array, meta: tuple) -> Dict[str, jax.Array]:
    """Inverse of :func:`pack_payload` (static offsets from ``meta``)."""
    out, off = {}, 0
    for key, shape, dtype in meta:
        elems = math.prod(shape) if shape else 1
        nbytes = elems * dtype.itemsize
        seg = wire[off:off + nbytes]
        if dtype.itemsize == 1:
            arr = jax.lax.bitcast_convert_type(seg.reshape(shape), dtype)
        else:
            arr = jax.lax.bitcast_convert_type(
                seg.reshape(shape + (dtype.itemsize,)), dtype)
        out[key] = arr
        off += nbytes
    return out


def pack_bits(bools: jax.Array) -> jax.Array:
    """(rows, C) boolean -> (rows, C // 8) uint8, bit i = column 8r+i."""
    rows, c = bools.shape
    b = bools.reshape(rows, c // 8, 8).astype(jnp.uint8)
    weights = (jnp.uint8(1) << jnp.arange(8, dtype=jnp.uint8))
    return jnp.sum(b * weights, axis=-1).astype(jnp.uint8)


def unpack_bits(packed: jax.Array, c: int) -> jax.Array:
    """Inverse of :func:`pack_bits` -> (rows, c) {0, 1} uint8."""
    bits = (packed[..., None] >> jnp.arange(8, dtype=jnp.uint8)) & 1
    return bits.reshape(packed.shape[0], c)


def n_blocks(n: int, block: int = BLOCK) -> int:
    return (n + block - 1) // block


# ---------------------------------------------------------------------------
# the Codec contract
# ---------------------------------------------------------------------------


class Codec:
    """One wire format: compression math + pod aggregation + accounting."""

    #: registry key; subclasses must override.
    name: str = ""
    #: bits per transmitted value (accounting/ladder ordering only).
    value_bits: int = 16
    #: fraction of entries transmitted (1.0 = dense).
    keep_ratio: float = 1.0
    #: whether the chunked ring pipeline applies: True for gather codecs
    #: (payload circulated + decode-accumulated per peer).  Codecs whose
    #: exchange is not a per-peer payload gather (FULL's psum, SKIP's
    #: nothing) have no decode to hide and stay on their one-shot path.
    #: Doubles as the coalesced-wire capability: ``core/sync.py`` batches
    #: the one-shot payloads of every ``supports_ring`` rung in a segment
    #: into one ``all_gather`` (``ef_encode_wire`` + ``wire_decode_fold``).
    supports_ring: bool = True
    #: deterministic-mode strategy: False (default) means the codec's
    #: ``decode_accumulate`` with ``deterministic=True`` is ORDER-
    #: INSENSITIVE (exact integer partial sums — fixed-point dequant-add,
    #: integer vote counts), so the ring folds peers in arrival order.
    #: True means the accumulate is inherently order-sensitive (top-k's
    #: float scatter-add) and the ring must instead BUFFER each chunk's
    #: peer payloads and fold them in canonical pod order 0..P-1 — the
    #: exact float association of the one-shot all_gather fold.
    canonical_fold: bool = False
    #: whether the two-tier exchange applies (``ef_sync_hier``): the rung
    #: payload is re-encoded from the intra-cluster aggregate and shipped
    #: once per CLUSTER over the slow tier instead of once per device.
    #: True only for dense quantisers (int8/int4) whose re-encode of an
    #: aggregate is as faithful as of a single contribution.  Sparse /
    #: sign codecs would sparsify the cluster aggregate UNCOMPENSATED on
    #: tier 2 (the residual must stay device-local for EF correctness),
    #: and FULL's psum already spans the whole fleet in one collective —
    #: all keep ``False`` (README: codec-author note).
    supports_hier: bool = False
    #: whether ``ef_encode_gather`` fuses the rung's bucket gather into
    #: the encode kernel (the backward-streaming one-shot path then feeds
    #: the packed grad/error buffers + perm straight to
    #: ``ef_sync_gather`` instead of materialising ``fb[perm]`` first).
    #: CODEC-AUTHOR NOTE: ``ef_sync_gather`` reproduces the BASE
    #: ``ef_sync`` (encode -> pod_exchange / own*omega_own) on gathered
    #: rows — a codec that overrides ``ef_sync`` itself (FULL's psum,
    #: SKIP's no-op) must either keep ``producer_fused = False`` (the
    #: default: the gather is materialised and delegated to the codec's
    #: own ``ef_sync``, always correct) or override ``ef_sync_gather``
    #: too (README: "How encode hides behind backward").
    producer_fused: bool = False

    # ---- accounting -----------------------------------------------------
    def payload_bytes(self, n: int, block: int = BLOCK) -> int:
        """Per-device payload size actually put on the wire (== the packed
        uint8 buffer size from :func:`pack_payload`)."""
        raise NotImplementedError

    def wire_bytes(self, n: int, n_pods: int, block: int = BLOCK) -> int:
        """Per-device per-sync bytes over the pod axis.  Default: ring
        all_gather receive volume — each device receives every peer's
        payload once."""
        if n_pods <= 1 or n <= 0:
            return 0
        return self.payload_bytes(n, block) * (n_pods - 1)

    def value_fraction(self) -> float:
        """Knapsack value heuristic: fraction of gradient 'information'
        preserved.  Only needs to ORDER the ladder (see core/knapsack.py)."""
        return 1.0

    # ---- wire format (oracle path, bit-exact to the seed operators) ----
    def encode(self, blocks: jax.Array) -> Dict[str, jax.Array]:
        """(nb, block) f32 -> payload pytree of arrays."""
        raise NotImplementedError

    def decode(self, payload: Dict[str, jax.Array],
               block: int = BLOCK) -> jax.Array:
        """payload -> dense (nb, block) f32 (receiver reconstruction)."""
        raise NotImplementedError

    # ---- fused device-local hot path -----------------------------------
    def ef_encode(self, flat: jax.Array, e_flat: jax.Array, *, gamma: float,
                  block: int = BLOCK, use_pallas: bool = False
                  ) -> Tuple[Dict[str, jax.Array], jax.Array, jax.Array]:
        """Error feedback + compress one flat (n,) f32 buffer.

        Returns ``(payload, own, new_e)``: ``own = decode(payload)[:n]`` is
        exactly what every receiver reconstructs from this device's
        payload, and ``new_e = (flat + gamma*e_flat) - own`` is the next
        error-feedback residual.  Subclasses with a Pallas kernel override
        this to fuse the EF accumulate + compression into one HBM pass
        when ``use_pallas`` is set (the kernels emit the residual
        directly).  ``own`` is only consumed on the single-pod path, so
        multi-pod jit dead-code-eliminates its computation.
        """
        n = flat.shape[0]
        ef = flat + gamma * e_flat
        payload = self.encode(pad_to_blocks(ef, block))
        own = self.decode(payload, block).reshape(-1)[:n]
        return payload, own, ef - own

    def ef_encode_gather(self, fb: jax.Array, eb: jax.Array,
                         perm: jax.Array, *, gamma: float,
                         block: int = BLOCK, use_pallas: bool = False
                         ) -> Tuple[Dict[str, jax.Array], jax.Array,
                                    jax.Array]:
        """:meth:`ef_encode` of the rung bucket ``fb[perm]`` WITHOUT the
        caller materialising the gather.

        ``fb`` / ``eb``: the packed (NB+1, block) grad / error-feedback
        buffers (zero row last, see core/sync.py); ``perm``: (S,) block
        indices.  The default materialises the gather and delegates —
        bit-identical to the flat path by construction.  Producer-fused
        codecs (``producer_fused = True``) override it to read the rows
        straight out of ``fb``/``eb`` inside the encode kernel
        (repro/kernels ``*_gather``), so the encode's HBM traffic starts
        the moment the backward writes the rows — nothing re-reads the
        bucket in between.  Same per-row math either way: the two paths
        are bit-identical (tests/test_kernels.py)."""
        return self.ef_encode(fb[perm].reshape(-1),
                              eb[perm].reshape(-1), gamma=gamma,
                              block=block, use_pallas=use_pallas)

    # ---- pod aggregation ------------------------------------------------
    def pod_exchange(self, payload: Dict[str, jax.Array],
                     omega: jax.Array, *, n: int, block: int = BLOCK,
                     axis: str = POD_AXIS, use_pallas: bool = False,
                     deterministic: bool = False,
                     fixed_bits: int = FIXED_POINT_BITS) -> jax.Array:
        """Aggregate payloads across the pod axis -> (n,) f32.

        Default: pack the payload into one uint8 buffer, ONE ``all_gather``
        over ``axis``, then fold peer decodes through the codec's
        accumulation trio in canonical pod order 0..P-1 (paper eq. 8),
        one peer at a time so the dense transient stays at one (nb, block)
        buffer instead of (P, n) — with bucketing n can be the whole
        model, and a stacked decode would multiply peak sync memory by the
        pod count.  ``deterministic`` switches the trio to its exact
        (fixed-point / integer) accumulation so this one-shot fold is
        bit-identical to the P >= 3 ring's arrival-order fold.  Codecs
        whose aggregation is not a fold of per-peer payloads (FULL's
        psum, SKIP's nothing) override this.
        """
        wire, meta = pack_payload(payload)
        gathered = jax.lax.all_gather(wire, axis)       # (P, payload_bytes)
        return self.wire_decode_fold(gathered, meta, omega, n=n,
                                     block=block, use_pallas=use_pallas,
                                     deterministic=deterministic,
                                     fixed_bits=fixed_bits)

    # ---- coalesced wire exchange ---------------------------------------
    def ef_encode_wire(self, fb: jax.Array, eb: jax.Array,
                       perm: jax.Array, *, gamma: float,
                       block: int = BLOCK, use_pallas: bool = False
                       ) -> Tuple[jax.Array, tuple, jax.Array]:
        """Encode half of :meth:`ef_sync_gather`, stopped at the wire:
        returns ``(wire, meta, new_e)`` with ``wire`` the packed uint8
        payload buffer.  ``core/sync.py`` concatenates the wires of every
        payload rung in a segment and issues ONE ``all_gather`` for all
        of them — same bytes, same per-rung fold (the gathered slice of a
        concatenation is bit-identical to gathering the piece alone), but
        one DCN message per segment instead of one per rung.  Only
        meaningful for payload-gather codecs (``supports_ring``); FULL's
        psum and SKIP's no-op have no wire buffer to coalesce."""
        payload, _own, new_e = self.ef_encode_gather(
            fb, eb, perm, gamma=gamma, block=block, use_pallas=use_pallas)
        wire, meta = pack_payload(payload)
        return wire, meta, new_e

    def wire_decode_fold(self, gathered: jax.Array, meta: tuple,
                         omega: jax.Array, *, n: int, block: int = BLOCK,
                         use_pallas: bool = False,
                         deterministic: bool = False,
                         fixed_bits: int = FIXED_POINT_BITS) -> jax.Array:
        """Decode half of the one-shot exchange: fold the gathered
        ``(P, payload_bytes)`` wire rows through the accumulation trio in
        canonical pod order (paper eq. 8) -> dense (n,) f32.  The peer
        fold runs one at a time so the dense transient stays at one
        (nb, block) buffer (see :meth:`pod_exchange`)."""
        # canonical-fold codecs (top-k) are already order-deterministic
        # here — the gather order IS the canonical order, float math kept
        det = deterministic and not self.canonical_fold
        init_kw, fold_kw = self._det_kwargs(det, fixed_bits)
        nb = n_blocks(n, block)
        acc = self.accum_init(nb, block, **init_kw)
        for p in range(gathered.shape[0]):
            acc = self.decode_accumulate(
                acc, unpack_payload(gathered[p], meta), omega[p],
                block=block, use_pallas=use_pallas, **fold_kw)
        return self.accum_finalize(acc, n, block, **fold_kw)

    # ---- chunked ring pipeline ------------------------------------------
    def accum_init(self, nb: int, block: int = BLOCK, *,
                   deterministic: bool = False):
        """Fresh accumulator for ``nb`` blocks of aggregation.  Default:
        the dense f32 partial sum; ``deterministic`` selects the int32
        fixed-point partial sum whose integer adds are exact and
        commutative (the P >= 3 mode).  Codecs that aggregate in the
        compressed domain (SIGN's majority vote) override with their own
        partial state."""
        if deterministic:
            return jnp.zeros((nb, block), jnp.int32)
        return jnp.zeros((nb, block), jnp.float32)

    def decode_accumulate(self, acc, payload: Dict[str, jax.Array],
                          weight: jax.Array, *, block: int = BLOCK,
                          use_pallas: bool = False,
                          deterministic: bool = False,
                          fixed_bits: int = FIXED_POINT_BITS):
        """``acc (+)= weight * decode(payload)`` — ONE peer's chunk folded
        into the running aggregate.  The oracle default materialises the
        dense decode; subclasses fuse dequant + FMA into one HBM pass with
        the Pallas kernels in ``repro/kernels/decode.py`` when
        ``use_pallas`` is set.  ``deterministic`` quantises the weighted
        term to ``fixed_bits`` fractional bits and accumulates in int32 —
        bit-identical in ANY fold order (kernels/decode.py)."""
        if deterministic:
            return acc + fixed_point(weight * self.decode(payload, block),
                                     fixed_bits)
        return acc + weight * self.decode(payload, block)

    def accum_finalize(self, acc, n: int, block: int = BLOCK, *,
                       deterministic: bool = False,
                       fixed_bits: int = FIXED_POINT_BITS) -> jax.Array:
        """Running aggregate -> dense (n,) f32 (a fixed-point rescale for
        the deterministic int32 partial sum, identity otherwise)."""
        if deterministic:
            acc = from_fixed_point(acc, fixed_bits)
        return acc.reshape(-1)[:n]

    @staticmethod
    def _det_kwargs(deterministic: bool,
                    fixed_bits: int) -> Tuple[dict, dict]:
        """(accum_init kwargs, decode_accumulate/accum_finalize kwargs)
        for the accumulation trio.  The new ``deterministic`` /
        ``fixed_bits`` kwargs are forwarded ONLY when the deterministic
        mode is engaged, so a codec subclassed against the
        pre-deterministic trio signature keeps working on every float
        path — and can opt into P >= 3 rings via ``canonical_fold``
        (whose buffered fold never passes them) without signature
        changes."""
        if not deterministic:
            return {}, {}
        return ({"deterministic": True},
                {"deterministic": True, "fixed_bits": fixed_bits})

    def _chunk_payload(self, payload: Dict[str, jax.Array], i: int,
                       cb: int) -> Dict[str, jax.Array]:
        """Rows ``[i*cb, (i+1)*cb)`` of every payload component.  Valid
        for any blockwise wire format (every component's leading dim is
        the block row)."""
        return {k: a[i * cb:(i + 1) * cb] for k, a in payload.items()}

    def ef_sync_ring(self, flat: jax.Array, e_flat: jax.Array,
                     omega: jax.Array, omega_own: jax.Array, *,
                     gamma: float, n_pods: int, n_chunks: int,
                     block: int = BLOCK, axis: str = POD_AXIS,
                     use_pallas: bool = False, bidir: bool = True,
                     deterministic: Optional[bool] = None,
                     fixed_bits: int = FIXED_POINT_BITS
                     ) -> Tuple[jax.Array, jax.Array]:
        """EF + compress + CHUNKED RING exchange of one flat buffer.

        The payload is split into ``n_chunks`` equal chunks (the caller —
        ``planexec.exec_grid`` — pads the bucket to a chunk multiple) and
        circulated around the pod ring with K*(P-1) ``ppermute``s, exactly
        the all_gather receive volume on the wire.  The decode-accumulate
        of chunk *i-1* is issued between the ppermute of chunk *i* and any
        use of its result, so it carries no data dependence on the
        in-flight transfer and XLA's latency-hiding scheduler overlaps the
        DCN hop with the decode; the (P, payload) gathered buffer is never
        materialised — the live wire state is each lane's held +
        in-flight chunk, at most ~2x the bucket payload regardless of the
        pod count.

        ``bidir``: circulate BOTH DCN directions at once — two half-rings
        of ⌈(P-1)/2⌉ forward and ⌊(P-1)/2⌋ backward hops.  The total
        ppermute count and wire bytes are unchanged (each peer's payload
        still crosses the link once per receiving pod), but the two
        directions carry no data dependence on each other, so on
        full-duplex DCN links the critical path halves — up to 2x
        effective bandwidth.  For P = 2 it degenerates to the single
        forward hop.

        Determinism: on a 2-pod ring the aggregate is the same two-term
        omega-weighted sum as :meth:`ef_sync` (addition commutes), pinned
        by tests/test_codecs.py and the subprocess exchange parity test.
        For P >= 3 each pod receives peers in its OWN ring order, so a
        float fold would drift across pods at ulp level (fp addition is
        not associative).  ``deterministic`` (default: auto, on for
        P >= 3) therefore switches the fold to the codec's exact
        accumulation: order-insensitive int32 fixed-point / integer-vote
        partial sums folded in arrival order, or — for
        ``canonical_fold`` codecs (top-k's float scatter-add) — a
        chunk-major pipeline that buffers each chunk's P-1 peer payloads
        and folds them in canonical pod order 0..P-1, the exact float
        association of the one-shot all_gather fold.  Either way every
        pod produces bit-identical aggregates, equal to the one-shot
        path's (tests/test_collectives.py soaks this on P = 3 and 4).
        The legacy order-sensitive float fold is a loud error on P >= 3.
        """
        if n_pods <= 1 or not self.supports_ring:
            return self.ef_sync(flat, e_flat, omega, omega_own,
                                gamma=gamma, n_pods=n_pods, block=block,
                                axis=axis, use_pallas=use_pallas,
                                deterministic=deterministic,
                                fixed_bits=fixed_bits)
        if deterministic is None:
            deterministic = n_pods >= 3
        if n_pods >= 3 and not deterministic:
            raise ValueError(
                f"the order-sensitive float ring fold drifts across pods "
                f"for n_pods={n_pods} >= 3; deterministic accumulation is "
                f"mandatory there (pass deterministic=None or True)")
        n = flat.shape[0]
        payload, _own, new_e = self.ef_encode(flat, e_flat, gamma=gamma,
                                              block=block,
                                              use_pallas=use_pallas)
        nb = n_blocks(n, block)
        K = max(1, min(int(n_chunks), nb))
        assert nb % K == 0, (nb, K)
        cb = nb // K
        chunks = [self._chunk_payload(payload, i, cb) for i in range(K)]
        wires = [pack_payload(c) for c in chunks]
        meta = wires[0][1]
        cur = [w for w, _ in wires]
        my = jax.lax.axis_index(axis)
        P = n_pods
        fwd = [(p, (p + 1) % P) for p in range(P)]   # hop h: recv my-h
        bwd = [(p, (p - 1) % P) for p in range(P)]   # hop h: recv my+h
        hops_f = (P - 1 + 1) // 2 if bidir else P - 1
        hops_b = (P - 1) - hops_f
        if deterministic and self.canonical_fold:
            parts = self._ring_canonical_fold(
                cur, meta, omega, my, axis, fwd, bwd, hops_f, hops_b,
                P, cb, block, use_pallas)
        else:
            init_kw, fold_kw = self._det_kwargs(deterministic, fixed_bits)
            # hop 0: own contribution (same first term as one-shot)
            accs = [self.decode_accumulate(
                        self.accum_init(cb, block, **init_kw),
                        chunks[i], omega_own, block=block,
                        use_pallas=use_pallas, **fold_kw)
                    for i in range(K)]
            cur_f = cur
            cur_b = list(cur) if hops_b else []
            for h in range(1, max(hops_f, hops_b) + 1):
                w_f = omega[(my - h) % P]
                w_b = omega[(my + h) % P]
                nxt_f, nxt_b, pending = [], [], []
                for i in range(K):
                    # issue this chunk's transfers first, then fold the
                    # previous chunk's receives: the fold has no data
                    # dependence on the in-flight ppermutes, so XLA
                    # hides the decode behind the wire (both directions)
                    if h <= hops_f:
                        nxt_f.append(jax.lax.ppermute(cur_f[i], axis,
                                                      fwd))
                    if h <= hops_b:
                        nxt_b.append(jax.lax.ppermute(cur_b[i], axis,
                                                      bwd))
                    for pi, wire, w_src in pending:
                        accs[pi] = self.decode_accumulate(
                            accs[pi], unpack_payload(wire, meta), w_src,
                            block=block, use_pallas=use_pallas,
                            **fold_kw)
                    pending = []
                    if h <= hops_f:
                        pending.append((i, nxt_f[-1], w_f))
                    if h <= hops_b:
                        pending.append((i, nxt_b[-1], w_b))
                for pi, wire, w_src in pending:
                    accs[pi] = self.decode_accumulate(
                        accs[pi], unpack_payload(wire, meta), w_src,
                        block=block, use_pallas=use_pallas, **fold_kw)
                cur_f, cur_b = nxt_f, nxt_b
            parts = [self.accum_finalize(a, cb * block, block, **fold_kw)
                     for a in accs]
        agg = parts[0] if K == 1 else jnp.concatenate(parts)
        return agg[:n], new_e

    def _ring_canonical_fold(self, cur, meta, omega, my, axis, fwd, bwd,
                             hops_f, hops_b, P, cb, block, use_pallas):
        """Chunk-major ring with canonical-order buffering — the
        deterministic mode of ``canonical_fold`` codecs (top-k).

        Each chunk runs its full hop chain (both directions), stacking
        the received wires; the fold then walks pods 0..P-1 selecting
        each pod's wire from the stack (slot 0 = own, slots 1..hops_f =
        forward arrivals, the rest = backward), reproducing the one-shot
        all_gather fold's float association exactly — so every pod folds
        the same values in the same order and the aggregate is
        bit-identical across pods AND to the one-shot path.  Chunk i+1's
        hops carry no dependence on chunk i's fold, so the decode still
        hides behind the wire; the buffering cost is ~2 in-flight chunks
        x P chunk-payloads (≈ 2P/K of the bucket payload) instead of the
        streaming path's ~2 chunks — the price of an order-sensitive
        accumulate (README: canonical buffering cost)."""
        parts = []
        for wire in cur:
            stack = [wire]                       # slot 0: own payload
            f = b = wire
            for _ in range(hops_f):              # slot h: pod (my - h)
                f = jax.lax.ppermute(f, axis, fwd)
                stack.append(f)
            for _ in range(hops_b):              # slot hops_f+h: (my + h)
                b = jax.lax.ppermute(b, axis, bwd)
                stack.append(b)
            buf = jnp.stack(stack)               # (P, chunk_bytes) uint8
            acc = self.accum_init(cb, block)
            for j in range(P):                   # canonical pod order
                d_f = (my - j) % P               # 0 = own, <=hops_f = fwd
                d_b = (j - my) % P
                slot = jnp.where(d_f <= hops_f, d_f, hops_f + d_b)
                wire_j = jax.lax.dynamic_index_in_dim(buf, slot, axis=0,
                                                      keepdims=False)
                acc = self.decode_accumulate(
                    acc, unpack_payload(wire_j, meta), omega[j],
                    block=block, use_pallas=use_pallas)
            parts.append(self.accum_finalize(acc, cb * block, block))
        return parts

    # ---- one sync round -------------------------------------------------
    def ef_sync(self, flat: jax.Array, e_flat: jax.Array, omega: jax.Array,
                omega_own: jax.Array, *, gamma: float, n_pods: int,
                block: int = BLOCK, axis: str = POD_AXIS,
                use_pallas: bool = False,
                deterministic: Optional[bool] = None,
                fixed_bits: int = FIXED_POINT_BITS
                ) -> Tuple[jax.Array, jax.Array]:
        """EF + compress + exchange one flat buffer.  Returns
        ``(agg, new_e)`` with the invariant ``own + new_e == ef`` (the
        lossless transmit/residual split error feedback relies on).
        ``deterministic`` (auto: on for P >= 3) folds the gathered
        payloads with the same exact accumulation the ring uses, keeping
        the two exchange paths bit-identical on any pod count."""
        n = flat.shape[0]
        payload, own, new_e = self.ef_encode(flat, e_flat, gamma=gamma,
                                             block=block,
                                             use_pallas=use_pallas)
        if n_pods > 1:
            if deterministic is None:
                deterministic = n_pods >= 3
            agg = self.pod_exchange(payload, omega, n=n, block=block,
                                    axis=axis, use_pallas=use_pallas,
                                    deterministic=deterministic,
                                    fixed_bits=fixed_bits)
        else:
            agg = own * omega_own
        return agg, new_e

    def ef_sync_gather(self, fb: jax.Array, eb: jax.Array,
                       perm: jax.Array, omega: jax.Array,
                       omega_own: jax.Array, *, gamma: float, n_pods: int,
                       block: int = BLOCK, axis: str = POD_AXIS,
                       use_pallas: bool = False,
                       deterministic: Optional[bool] = None,
                       fixed_bits: int = FIXED_POINT_BITS
                       ) -> Tuple[jax.Array, jax.Array]:
        """:meth:`ef_sync` of the rung bucket ``fb[perm]`` — the
        backward-streaming one-shot entry point (core/sync.py hands the
        packed buffers + perm here instead of gathering first).

        For codecs that keep the base ``ef_sync`` (``producer_fused``),
        this runs :meth:`ef_encode_gather` + the same exchange/fold, so
        the gather fuses into the encode kernel and the collective's
        operand cone reaches only this rung's rows — what lets XLA issue
        the exchange while later backward segments still run
        (tests/test_collectives.py pins the cone in HLO).  Codecs that
        override ``ef_sync`` itself (FULL, SKIP) default to
        materialise-and-delegate, which is always bit-identical."""
        if not self.producer_fused:
            return self.ef_sync(fb[perm].reshape(-1),
                                eb[perm].reshape(-1), omega, omega_own,
                                gamma=gamma, n_pods=n_pods, block=block,
                                axis=axis, use_pallas=use_pallas,
                                deterministic=deterministic,
                                fixed_bits=fixed_bits)
        n = perm.shape[0] * block
        payload, own, new_e = self.ef_encode_gather(
            fb, eb, perm, gamma=gamma, block=block, use_pallas=use_pallas)
        if n_pods > 1:
            if deterministic is None:
                deterministic = n_pods >= 3
            agg = self.pod_exchange(payload, omega, n=n, block=block,
                                    axis=axis, use_pallas=use_pallas,
                                    deterministic=deterministic,
                                    fixed_bits=fixed_bits)
        else:
            agg = own * omega_own
        return agg, new_e

    # ---- two-tier sync round (hierarchical meshes) ----------------------
    def ef_sync_hier(self, flat: jax.Array, e_flat: jax.Array,
                     omega_intra: jax.Array, omega_own: jax.Array, *,
                     gamma: float, n_cross: int, n_edge: int,
                     intra_mode: int, n_chunks: int = 0,
                     block: int = BLOCK, cross_axis: str = POD_AXIS,
                     intra_axis: str = EDGE_AXIS,
                     use_pallas: bool = False, bidir: bool = True,
                     deterministic: Optional[bool] = None,
                     fixed_bits: int = FIXED_POINT_BITS
                     ) -> Tuple[jax.Array, jax.Array]:
        """Two-tier EF sync: cheap intra-cluster aggregation over the fast
        ``intra_axis`` feeding ONE rung payload per cluster over the slow
        ``cross_axis`` — cross-tier bytes shrink from ``(C*E-1) x payload``
        to ``(C-1) x payload`` for an ``(n_cross, n_edge)`` fleet.

        Tier 1 runs the INTRA codec's ``ef_sync`` over ``intra_axis``
        (FULL bf16-psum or INT8 gather+fold, picked statically by the
        roofline — ``planexec.hier_rung_mode``) with the per-member omega
        weights; its device-local residual carries the EF compensation.
        The cluster aggregate ``A_c`` is bit-identical across members
        (deterministic fold), so tier 2's input is pod-uniform: ``A_c``
        is re-encoded with the rung codec (``gamma=0`` — NO cluster-level
        error feedback, which would break pod-uniformity when devices are
        re-clustered mid-run) and circulated with the existing ring /
        one-shot machinery over ``cross_axis`` with UNIT weights, since
        omega was already applied at tier 1.  The fleet aggregate
        ``sum_c sum_m own_m * omega_m`` matches the flat path's weighting
        exactly; only dense quantisers set ``supports_hier`` because
        tier 2's (small, bounded) re-quantisation error is uncompensated.
        """
        from repro.core.planexec import INTRA_INT8
        intra = build_codec("int8" if intra_mode == INTRA_INT8 else "full")
        agg_c, new_e = intra.ef_sync(
            flat, e_flat, omega_intra, omega_own, gamma=gamma,
            n_pods=n_edge, block=block, axis=intra_axis,
            use_pallas=use_pallas, deterministic=deterministic,
            fixed_bits=fixed_bits)
        zeros = jnp.zeros_like(agg_c)
        unit = jnp.ones((n_cross,), agg_c.dtype)
        if n_chunks and self.supports_ring and n_cross > 1:
            agg, _ = self.ef_sync_ring(
                agg_c, zeros, unit, 1.0, gamma=0.0, n_pods=n_cross,
                n_chunks=n_chunks, block=block, axis=cross_axis,
                use_pallas=use_pallas, bidir=bidir,
                deterministic=deterministic, fixed_bits=fixed_bits)
        else:
            agg, _ = self.ef_sync(
                agg_c, zeros, unit, 1.0, gamma=0.0, n_pods=n_cross,
                block=block, axis=cross_axis, use_pallas=use_pallas,
                deterministic=deterministic, fixed_bits=fixed_bits)
        return agg, new_e

    def __repr__(self) -> str:  # pragma: no cover - debugging nicety
        return f"<{type(self).__name__} {self.name!r}>"


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Type[Codec]] = {}


def register_codec(cls: Type[Codec]) -> Type[Codec]:
    """Class decorator: make ``cls`` resolvable by its ``name``."""
    if not cls.name:
        raise ValueError(f"{cls.__name__} must set a non-empty .name")
    if _REGISTRY.get(cls.name) not in (None, cls):
        raise ValueError(f"codec {cls.name!r} already registered by "
                         f"{_REGISTRY[cls.name].__name__}")
    _REGISTRY[cls.name] = cls
    return cls


def list_codecs() -> List[str]:
    """Registered codec names (sorted, stable for CLIs/benchmarks)."""
    return sorted(_REGISTRY)


def get_codec(name: str) -> Type[Codec]:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown codec {name!r}; registered: "
                       f"{list_codecs()}") from None


def build_codec(name: str, **kwargs) -> Codec:
    """Instantiate a registered codec by name."""
    return get_codec(name)(**kwargs)


# ---------------------------------------------------------------------------
# Level -> Codec resolution (core/compression.Level is a thin view)
# ---------------------------------------------------------------------------

_CODEC_CACHE: Dict[Tuple[float, int], Codec] = {}


def codec_for_level(level) -> Codec:
    """Resolve a ``Level(name, keep_ratio, value_bits)`` view to its codec
    instance (cached — codecs are stateless)."""
    key = (float(level.keep_ratio), int(level.value_bits))
    codec = _CODEC_CACHE.get(key)
    if codec is None:
        ratio, bits = key
        if ratio <= 0.0:
            codec = build_codec("skip")
        elif ratio < 1.0:
            codec = build_codec("topk", ratio=ratio)
        elif bits >= 16:
            codec = build_codec("full")
        elif bits >= 8:
            codec = build_codec("int8")
        elif bits >= 4:
            codec = build_codec("int4")
        else:
            codec = build_codec("sign")
        _CODEC_CACHE[key] = codec
    return codec


# ---------------------------------------------------------------------------
# plan pricing (bucketed: what the wire actually carries)
# ---------------------------------------------------------------------------


def plan_wire_bytes(plan, sizes: Sequence[int], n_pods: int,
                    block: int = BLOCK, use_sig: bool = True,
                    n_cross: Optional[int] = None) -> int:
    """Analytic per-device wire bytes for a plan over the SLOW tier,
    priced the way ``core/sync.sync_tree`` actually transmits it:
    block-aligned leaves repacked into one per-rung buffer and one
    collective, per-leaf block padding included.  When the plan carries
    its padded bucket signature (``SyncPlan.bucket_sig``, attached by the
    Scheduler for plans the retrace-free exchange pads to size classes),
    that signature is priced — the exact bytes the executed exchange
    moves.  ``use_sig=False`` forces the unpadded (exact-bucket) total,
    the analytic floor the padding overhead is measured against.

    When the plan carries a two-tier grid (``SyncPlan.hier``), hier rungs
    cross the slow tier once per CLUSTER: they are priced at ``n_cross``
    participants instead of ``n_pods`` (the fast intra-cluster tier is
    priced separately by :func:`plan_intra_bytes`)."""
    from repro.core.planexec import bucket_signature, sig_wire_bytes
    sig = getattr(plan, "bucket_sig", None) if use_sig else None
    if sig is not None and getattr(plan, "bucket_block", block) != block:
        sig = None  # signature counted in a different block size: rebuild
    if sig is None:
        sig = bucket_signature(plan.level_idx, sizes, len(plan.levels),
                               block)
    hier = getattr(plan, "hier", None)
    return sig_wire_bytes(sig, plan.levels, n_pods, block,
                          hier=hier, n_cross=n_cross)


def plan_intra_bytes(plan, sizes: Sequence[int], n_edge: int,
                     block: int = BLOCK) -> int:
    """Analytic per-device FAST-tier (intra-cluster) wire bytes for a
    plan's hier rungs — zero for flat plans or single-member clusters."""
    from repro.core.planexec import bucket_signature, sig_intra_bytes
    hier = getattr(plan, "hier", None)
    if not hier or n_edge <= 1:
        return 0
    sig = getattr(plan, "bucket_sig", None)
    if sig is not None and getattr(plan, "bucket_block", block) != block:
        sig = None
    if sig is None:
        sig = bucket_signature(plan.level_idx, sizes, len(plan.levels),
                               block)
    return sig_intra_bytes(sig, plan.levels, n_edge, block, hier=hier)
