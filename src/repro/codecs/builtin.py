"""The built-in wire-format codecs.

The first four migrate the seed's closed compression ladder (FULL / INT8 /
TOPK / SKIP) payload-identically: ``encode`` / ``decode`` are the exact
seed operators from ``core/compression.py`` (tests/test_codecs.py pins
them bit-exact on fixed seeds).  ``int4`` and ``sign`` widen the ladder —
rungs the old four-layer hard-coding could not host without touching
compression, sync, knapsack and the scheduler at once:

  * ``int4``: packed two-nibbles-per-byte with blockwise absmax scale —
    dense like INT8 at half the wire bytes;
  * ``sign``: 1-bit sign with per-block mean-magnitude scale and
    majority-vote pod aggregation (signSGD with majority vote; "When Less
    is More" shows such formats can converge faster with fewer bits).

Each codec's Pallas path lives in ``repro/kernels`` and is selected by
``use_pallas`` (see ``repro.kernels.ops.default_use_pallas``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.codecs.base import (POD_AXIS, Codec, n_blocks, pack_bits,
                               register_codec, unpack_bits)
from repro.core.compression import (BLOCK, int8_compress, int8_decompress,
                                    pad_to_blocks, topk_compress,
                                    topk_decompress)
from repro.kernels import ops
from repro.kernels.decode import (FIXED_POINT_BITS, fixed_point,
                                  from_fixed_point)
from repro.kernels.quantize import _int4_body, pack_nibbles, unpack_nibbles


@register_codec
class FullCodec(Codec):
    """Dense bf16 — the psum rung.  Wire bytes are the bf16 ring
    all-reduce volume, and the exchange really is a bf16 psum (the seed
    psum'd in f32 while pricing bf16 — the analytic/traced drift this
    refactor removes).  Note: backends without native bf16 reduction (the
    CPU container) promote the all-reduce to f32 in HLO; on TPU it stays
    bf16 (tests/test_collectives.py accepts both byte totals)."""
    name = "full"
    value_bits = 16
    #: the exchange is a psum, not a payload gather: there is no per-peer
    #: decode for the ring to hide (XLA already pipelines the all-reduce),
    #: so FULL stays on its one-shot path.
    supports_ring = False

    def wire_bytes(self, n: int, n_pods: int, block: int = BLOCK) -> int:
        if n_pods <= 1 or n <= 0:
            return 0
        # bf16 ring all-reduce: 2 * (P-1)/P * 2n bytes on the wire
        return int(2 * (n_pods - 1) / n_pods * 2 * n)

    def payload_bytes(self, n: int, block: int = BLOCK) -> int:
        return 2 * n  # bf16 (informational; the exchange is a psum)

    def encode(self, blocks):
        return {"wire": blocks.astype(jnp.bfloat16)}

    def decode(self, payload, block: int = BLOCK):
        return payload["wire"].astype(jnp.float32)

    def ef_encode(self, flat, e_flat, *, gamma, block=BLOCK,
                  use_pallas=False):
        ef = flat + gamma * e_flat
        wire = ef.astype(jnp.bfloat16)
        own = wire.astype(jnp.float32)
        return {"wire": wire}, own, ef - own

    def pod_exchange(self, payload, omega, *, n, block=BLOCK,
                     axis=POD_AXIS, **_kw):
        raise NotImplementedError("FULL aggregates inside ef_sync (psum)")

    def ef_sync(self, flat, e_flat, omega, omega_own, *, gamma, n_pods,
                block=BLOCK, axis=POD_AXIS, use_pallas=False,
                deterministic=None, fixed_bits=None):
        """The psum exchange is already cross-pod deterministic on any
        pod count: XLA's all-reduce hands every participant the SAME
        reduced bits (whatever internal order it reduces in), so pods
        cannot drift apart — ``deterministic`` needs no special mode
        here.  (The inherited accumulation trio still supports the
        fixed-point mode, so a gather-style fold of FULL payloads — e.g.
        a future ring variant — is order-insensitive for free.)"""
        payload, own, new_e = self.ef_encode(flat, e_flat, gamma=gamma,
                                             block=block)
        if n_pods > 1:
            # omega folded in before the psum so the collective itself
            # moves bf16 — exactly what wire_bytes prices.
            contrib = (own * omega_own).astype(jnp.bfloat16)
            agg = jax.lax.psum(contrib, axis).astype(jnp.float32)
        else:
            agg = own * omega_own
        return agg, new_e


@register_codec
class Int8Codec(Codec):
    """Dense blockwise-absmax int8 (+ f32 scale per 1024-block)."""
    name = "int8"
    value_bits = 8
    supports_hier = True  # dense quantiser: tier-2 re-encode is faithful
    producer_fused = True  # gather fuses into the encode kernel

    def payload_bytes(self, n: int, block: int = BLOCK) -> int:
        nb = n_blocks(n, block)
        return nb * block + 4 * nb  # int8 payload (block-padded) + scales

    def value_fraction(self) -> float:
        return 0.97

    def encode(self, blocks):
        q, scale = int8_compress(blocks)
        return {"q": q, "scale": scale}

    def decode(self, payload, block: int = BLOCK):
        return int8_decompress(payload["q"], payload["scale"])

    def ef_encode(self, flat, e_flat, *, gamma, block=BLOCK,
                  use_pallas=False):
        if not use_pallas or block != ops.LANES:
            return super().ef_encode(flat, e_flat, gamma=gamma, block=block)
        n = flat.shape[0]
        ef = flat + gamma * e_flat
        q, s, r, _ = ops.quantize_int8(ef, use_pallas=True)
        nb = n_blocks(n, block)
        # kernel tiles pad to 8-row multiples; only the nb real blocks
        # ever reach the wire (analytic bytes == traced bytes).  r IS the
        # next residual; own (dead on the multi-pod path) is one fused
        # elementwise pass.
        payload = {"q": q[:nb], "scale": s[:nb, 0]}
        return payload, ef - r, r

    def ef_encode_gather(self, fb, eb, perm, *, gamma, block=BLOCK,
                         use_pallas=False):
        if not use_pallas or block != ops.LANES:
            return super().ef_encode_gather(fb, eb, perm, gamma=gamma,
                                            block=block,
                                            use_pallas=use_pallas)
        q, s, r = ops.gather_ef_int8(fb, eb, perm, gamma=gamma,
                                     use_pallas=True)
        # own (dead-code on the multi-pod path) re-derives ef lazily
        own = (fb[perm] + gamma * eb[perm]).reshape(-1) - r
        return {"q": q, "scale": s[:, 0]}, own, r

    def decode_accumulate(self, acc, payload, weight, *, block=BLOCK,
                          use_pallas=False, deterministic=False,
                          fixed_bits=FIXED_POINT_BITS):
        if not use_pallas or block != ops.LANES:
            return super().decode_accumulate(
                acc, payload, weight, block=block,
                deterministic=deterministic, fixed_bits=fixed_bits)
        return ops.decode_accum_int8(
            acc, payload["q"], payload["scale"], weight, use_pallas=True,
            fixed_bits=fixed_bits if deterministic else None)


@register_codec
class TopKCodec(Codec):
    """Block-local top-k, int8-quantised values + uint16 indices.

    The ring decode-accumulate is a float scatter-add — inherently
    fold-order sensitive — so the deterministic P >= 3 mode uses the
    canonical-order buffering path (``canonical_fold``): each chunk's
    peer payloads are buffered over the hop chain and folded in pod
    order 0..P-1, the exact association of the one-shot fold."""
    name = "topk"
    value_bits = 8
    canonical_fold = True
    producer_fused = True  # gather fuses into the selection kernel

    def __init__(self, ratio: float = 0.1):
        if not 0.0 < ratio < 1.0:
            raise ValueError(f"topk ratio must be in (0, 1), got {ratio}")
        self.keep_ratio = float(ratio)

    def block_k(self, block: int = BLOCK) -> int:
        """Static k per block (multiple of 8 lanes, >= 8)."""
        k = int(round(self.keep_ratio * block))
        return max(8, ((k + 7) // 8) * 8)

    def payload_bytes(self, n: int, block: int = BLOCK) -> int:
        nb = n_blocks(n, block)
        k = self.block_k(block)
        return nb * k * (1 + 2) + 4 * nb  # int8 vals + u16 idx + f32 scales

    def value_fraction(self) -> float:
        return self.keep_ratio ** 0.5 * 0.97

    def encode(self, blocks):
        q, idx, scale = topk_compress(blocks, self.block_k(blocks.shape[1]))
        return {"q": q, "idx": idx, "scale": scale}

    def decode(self, payload, block: int = BLOCK):
        return topk_decompress(payload["q"], payload["idx"],
                               payload["scale"], block)

    def ef_encode(self, flat, e_flat, *, gamma, block=BLOCK,
                  use_pallas=False):
        if not use_pallas or block != ops.LANES:
            return super().ef_encode(flat, e_flat, gamma=gamma, block=block)
        n = flat.shape[0]
        k = self.block_k(block)
        # one fused HBM pass: EF accumulate + bisection top-k selection
        sel, res = ops.ef_topk(flat, e_flat, gamma=gamma, k=k,
                               use_pallas=True)
        # pack the (≈k-sparse) selected tile into the wire format; the
        # residual picks up both the dropped entries (res) and the int8
        # quantisation error of the kept ones (sel - own).
        payload = self.encode(pad_to_blocks(sel, block))
        own = self.decode(payload, block).reshape(-1)[:n]
        return payload, own, (sel - own) + res

    def ef_encode_gather(self, fb, eb, perm, *, gamma, block=BLOCK,
                         use_pallas=False):
        if not use_pallas or block != ops.LANES:
            return super().ef_encode_gather(fb, eb, perm, gamma=gamma,
                                            block=block,
                                            use_pallas=use_pallas)
        n = perm.shape[0] * block
        k = self.block_k(block)
        sel, res = ops.gather_ef_topk(fb, eb, perm, gamma=gamma, k=k,
                                      use_pallas=True)
        payload = self.encode(sel)          # sel is already (S, block)
        own = self.decode(payload, block).reshape(-1)[:n]
        return payload, own, (sel.reshape(-1) - own) + res

    def decode_accumulate(self, acc, payload, weight, *, block=BLOCK,
                          use_pallas=False, deterministic=False,
                          fixed_bits=FIXED_POINT_BITS):
        # never called with deterministic=True: canonical_fold routes the
        # P >= 3 ring through the buffered canonical-order float fold
        assert not deterministic, "topk folds canonically, not fixed-point"
        if not use_pallas or block != ops.LANES:
            return super().decode_accumulate(acc, payload, weight,
                                             block=block)
        return ops.topk_scatter_accum(acc, payload["q"], payload["idx"],
                                      payload["scale"], weight,
                                      use_pallas=True)


@register_codec
class SkipCodec(Codec):
    """Transmit nothing; the whole EF accumulator becomes the residual."""
    name = "skip"
    value_bits = 0
    keep_ratio = 0.0
    supports_ring = False           # nothing on the wire, nothing to ring

    def payload_bytes(self, n: int, block: int = BLOCK) -> int:
        return 0

    def wire_bytes(self, n: int, n_pods: int, block: int = BLOCK) -> int:
        return 0

    def value_fraction(self) -> float:
        return 0.0

    def encode(self, blocks):
        return {}

    def decode(self, payload, block: int = BLOCK):
        raise NotImplementedError("SKIP has no payload to decode")

    def ef_sync(self, flat, e_flat, omega, omega_own, *, gamma, n_pods,
                block=BLOCK, axis=POD_AXIS, use_pallas=False,
                deterministic=None, fixed_bits=None):
        ef = flat + gamma * e_flat
        return jnp.zeros_like(flat), ef


@register_codec
class Int4Codec(Codec):
    """Dense packed int4: two nibbles per byte + blockwise absmax scale."""
    name = "int4"
    value_bits = 4
    supports_hier = True  # dense quantiser: tier-2 re-encode is faithful
    producer_fused = True  # gather fuses into the encode kernel

    def payload_bytes(self, n: int, block: int = BLOCK) -> int:
        nb = n_blocks(n, block)
        return nb * (block // 2) + 4 * nb

    def value_fraction(self) -> float:
        return 0.90

    def encode(self, blocks):
        q, scale = _int4_body(blocks)
        return {"q": pack_nibbles(q), "scale": scale[:, 0]}

    def decode(self, payload, block: int = BLOCK):
        q = unpack_nibbles(payload["q"])
        return q * payload["scale"][:, None]

    def ef_encode(self, flat, e_flat, *, gamma, block=BLOCK,
                  use_pallas=False):
        if not use_pallas or block != ops.LANES:
            return super().ef_encode(flat, e_flat, gamma=gamma, block=block)
        n = flat.shape[0]
        p, s, r, _ = ops.ef_int4(flat, e_flat, gamma=gamma, use_pallas=True)
        nb = n_blocks(n, block)
        payload = {"q": p[:nb], "scale": s[:nb, 0]}
        own = (flat + gamma * e_flat) - r  # dead-code on the multi-pod path
        return payload, own, r

    def ef_encode_gather(self, fb, eb, perm, *, gamma, block=BLOCK,
                         use_pallas=False):
        if not use_pallas or block != ops.LANES:
            return super().ef_encode_gather(fb, eb, perm, gamma=gamma,
                                            block=block,
                                            use_pallas=use_pallas)
        p, s, r = ops.gather_ef_int4(fb, eb, perm, gamma=gamma,
                                     use_pallas=True)
        own = (fb[perm] + gamma * eb[perm]).reshape(-1) - r
        return {"q": p, "scale": s[:, 0]}, own, r

    def decode_accumulate(self, acc, payload, weight, *, block=BLOCK,
                          use_pallas=False, deterministic=False,
                          fixed_bits=FIXED_POINT_BITS):
        if not use_pallas or block != ops.LANES:
            return super().decode_accumulate(
                acc, payload, weight, block=block,
                deterministic=deterministic, fixed_bits=fixed_bits)
        return ops.decode_accum_int4(
            acc, payload["q"], payload["scale"], weight, use_pallas=True,
            fixed_bits=fixed_bits if deterministic else None)


@register_codec
class SignCodec(Codec):
    """1-bit sign + per-block mean-|ef| scale, majority-vote aggregation."""
    name = "sign"
    value_bits = 1
    producer_fused = True  # gather fuses into the encode kernel

    def payload_bytes(self, n: int, block: int = BLOCK) -> int:
        nb = n_blocks(n, block)
        return nb * (block // 8) + 4 * nb

    def value_fraction(self) -> float:
        # 1 bit per entry keeps direction only; rank it between the
        # topk1 and topk10 rungs (signSGD-style convergence).
        return 0.25

    def encode(self, blocks):
        scale = jnp.mean(jnp.abs(blocks), axis=1).astype(jnp.float32)
        return {"q": pack_bits(blocks >= 0), "scale": scale}

    def decode(self, payload, block: int = BLOCK):
        signs = unpack_bits(payload["q"], block).astype(jnp.float32) * 2 - 1
        return signs * payload["scale"][:, None]

    def ef_encode(self, flat, e_flat, *, gamma, block=BLOCK,
                  use_pallas=False):
        if not use_pallas or block != ops.LANES:
            return super().ef_encode(flat, e_flat, gamma=gamma, block=block)
        n = flat.shape[0]
        sg, s, r, _ = ops.ef_sign(flat, e_flat, gamma=gamma,
                                  use_pallas=True)
        nb = n_blocks(n, block)
        payload = {"q": pack_bits(sg[:nb] > 0), "scale": s[:nb, 0]}
        own = (flat + gamma * e_flat) - r  # dead-code on the multi-pod path
        return payload, own, r

    def ef_encode_gather(self, fb, eb, perm, *, gamma, block=BLOCK,
                         use_pallas=False):
        if not use_pallas or block != ops.LANES:
            return super().ef_encode_gather(fb, eb, perm, gamma=gamma,
                                            block=block,
                                            use_pallas=use_pallas)
        sg, s, r = ops.gather_ef_sign(fb, eb, perm, gamma=gamma,
                                      use_pallas=True)
        payload = {"q": pack_bits(sg > 0), "scale": s[:, 0]}
        own = (fb[perm] + gamma * eb[perm]).reshape(-1) - r
        return payload, own, r

    # ---- ring pipeline: majority vote in the compressed domain ---------
    # The pod exchange itself is the BASE all_gather + trio fold (the
    # majority vote of Bernstein et al.'s signSGD expressed as partial
    # counts): agg = sign(sum_k omega_k * sign_k) scaled by the
    # omega-weighted mean magnitude.
    def accum_init(self, nb, block=BLOCK, *, deterministic=False):
        """Partial vote counts + partial magnitude — the compressed-domain
        state the ring circulates instead of a dense decode.  The
        deterministic mode keeps INTEGER vote counts (fixed-point omega x
        exact ±1 signs) and a fixed-point magnitude — both commutative,
        so any fold order reaches the same bits."""
        dt = jnp.int32 if deterministic else jnp.float32
        return {"vote": jnp.zeros((nb, block), dt),
                "mag": jnp.zeros((nb,), dt)}

    def decode_accumulate(self, acc, payload, weight, *, block=BLOCK,
                          use_pallas=False, deterministic=False,
                          fixed_bits=FIXED_POINT_BITS):
        if use_pallas and block == ops.LANES:
            vote, mag = ops.sign_vote_accum(
                acc["vote"], acc["mag"], payload["q"], payload["scale"],
                weight, use_pallas=True,
                fixed_bits=fixed_bits if deterministic else None)
            return {"vote": vote, "mag": mag}
        signs = unpack_bits(payload["q"], block).astype(jnp.float32) * 2 - 1
        if deterministic:
            wq = fixed_point(weight, fixed_bits)
            return {"vote": acc["vote"] + wq * signs.astype(jnp.int32),
                    "mag": acc["mag"] + fixed_point(
                        weight * payload["scale"], fixed_bits)}
        return {"vote": acc["vote"] + weight * signs,
                "mag": acc["mag"] + weight * payload["scale"]}

    def accum_finalize(self, acc, n, block=BLOCK, *, deterministic=False,
                       fixed_bits=FIXED_POINT_BITS):
        vote, mag = acc["vote"], acc["mag"]
        if deterministic:
            # votes only feed sign(); int32 -> f32 is exact here (the
            # count magnitude is far below 2^24)
            vote = vote.astype(jnp.float32)
            mag = from_fixed_point(mag, fixed_bits)
        agg = jnp.sign(vote) * mag[:, None]
        return agg.reshape(-1)[:n]
