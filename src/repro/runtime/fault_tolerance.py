"""Fault tolerance & elasticity runtime.

On a real multi-pod deployment these hooks wire into the cluster manager;
here every decision path is implemented and unit-tested against simulated
telemetry, and the launcher (launch/train.py) consumes them:

  * HeartbeatMonitor  — per-pod liveness from step-completion timestamps;
    marks a pod dead after ``timeout_s`` silence, and carries an explicit
    register/rejoin path so a preempted pod coming back (or a pod id the
    monitor has never seen) re-enters cleanly instead of KeyError-ing.
  * StragglerDetector — robust (median + MAD) step-time outlier detection;
    feeds the reliability weights omega (paper eq. 8) so persistent
    stragglers are down-weighted instead of stalling the ring.
  * ElasticPlanner    — maps a membership event (failure OR rejoin) to a
    new mesh plan: drop/re-add the pod, re-balance the batch; the
    launcher re-derives ring hops and re-keys the compiled step through
    the bucket-signature path (checkpointer re-shards pod-dim leaves).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence

import numpy as np


@dataclasses.dataclass
class PodStatus:
    pod_id: int
    last_seen: float
    step_times: List[float] = dataclasses.field(default_factory=list)
    alive: bool = True


class HeartbeatMonitor:
    def __init__(self, n_pods: int, timeout_s: float = 300.0):
        now = time.time()
        self.timeout_s = timeout_s
        self.pods = {i: PodStatus(i, now) for i in range(n_pods)}

    def register(self, pod_id: int, now: Optional[float] = None):
        """Explicit (re)join: a brand-new pod id gets a status record; a
        known-dead pod is resurrected with its stale step times cleared —
        pre-preemption timings would poison the straggler stats of the
        restarted pod (fresh host, cold caches, different neighbours)."""
        now = now if now is not None else time.time()
        st = self.pods.get(pod_id)
        if st is None:
            self.pods[pod_id] = PodStatus(pod_id, now)
            return
        if not st.alive:
            st.alive = True
            st.step_times = []
        st.last_seen = now

    def drop(self, pod_id: int):
        """Forget a pod entirely (it left the fleet for good)."""
        self.pods.pop(pod_id, None)

    def mark_dead(self, pod_id: int):
        """Force-mark a pod dead (fault injection / external signal)."""
        st = self.pods.get(pod_id)
        if st is not None:
            st.alive = False

    def beat(self, pod_id: int, step_time_s: float,
             now: Optional[float] = None):
        """Record a step completion.  Unknown or previously-dead pods are
        routed through :meth:`register` first — a rejoined pod's beat must
        never raise, and must not resurrect stale timing state."""
        st = self.pods.get(pod_id)
        if st is None or not st.alive:
            self.register(pod_id, now)
            st = self.pods[pod_id]
        st.last_seen = now if now is not None else time.time()
        st.step_times.append(step_time_s)
        if len(st.step_times) > 256:
            st.step_times = st.step_times[-128:]

    def check(self, now: Optional[float] = None) -> List[int]:
        """-> list of pods newly marked dead."""
        now = now if now is not None else time.time()
        dead = []
        for st in self.pods.values():
            if st.alive and now - st.last_seen > self.timeout_s:
                st.alive = False
                dead.append(st.pod_id)
        return dead

    def alive_pods(self) -> List[int]:
        return [i for i, st in self.pods.items() if st.alive]


class StragglerDetector:
    """Median/MAD outlier detection over recent step times.

    ``mad_floor_frac`` guards the near-zero-MAD regime: when every pod
    steps in statistically identical time the raw MAD collapses toward 0
    and any ulp of jitter would divide into a huge z-score, spuriously
    flagging healthy pods.  The deviation scale is floored at this
    fraction of the median step time, so only pods slower by a meaningful
    margin can be flagged at all.
    """

    def __init__(self, threshold: float = 3.0,
                 mad_floor_frac: float = 0.01):
        self.threshold = threshold
        self.mad_floor_frac = mad_floor_frac

    def straggle_factors(self, monitor: HeartbeatMonitor) -> Dict[int, float]:
        pods = monitor.alive_pods()
        med_times = {}
        for i in pods:
            ts = monitor.pods[i].step_times[-32:]
            med_times[i] = float(np.median(ts)) if ts else 0.0
        vals = np.array([v for v in med_times.values() if v > 0])
        if len(vals) == 0:
            return {i: 1.0 for i in pods}
        med = float(np.median(vals))
        return {i: (med_times[i] / med if med > 0 and med_times[i] > 0
                    else 1.0) for i in pods}

    def stragglers(self, monitor: HeartbeatMonitor) -> List[int]:
        f = self.straggle_factors(monitor)
        if not f:
            return []
        vals = np.array(list(f.values()))
        med = float(np.median(vals))
        mad = float(np.median(np.abs(vals - med)))
        scale = max(mad, self.mad_floor_frac * max(med, 1e-12), 1e-12)
        return [i for i, v in f.items()
                if (v - med) / scale > self.threshold]


@dataclasses.dataclass
class MeshPlan:
    n_pods: int
    data: int
    model: int

    @property
    def shape(self):
        if self.n_pods > 1:
            return (self.n_pods, self.data, self.model)
        return (self.data, self.model)

    @property
    def axis_names(self):
        if self.n_pods > 1:
            return ("pod", "data", "model")
        return ("data", "model")


class ElasticPlanner:
    """Membership event -> new mesh plan + restart decision."""

    def __init__(self, initial: MeshPlan):
        self.plan = initial
        self.max_pods = initial.n_pods

    def on_pod_failure(self, dead_pods: Sequence[int]) -> MeshPlan:
        remaining = self.plan.n_pods - len(set(dead_pods))
        if remaining < 1:
            raise RuntimeError("all pods dead")
        self.plan = MeshPlan(n_pods=remaining, data=self.plan.data,
                             model=self.plan.model)
        return self.plan

    def on_pod_join(self, n_joining: int = 1) -> MeshPlan:
        """A preempted pod rejoined (or capacity was added): grow the pod
        axis again, capped at the largest fleet this planner has seen —
        the device inventory the launcher actually holds."""
        grown = min(self.plan.n_pods + int(n_joining), self.max_pods)
        self.plan = MeshPlan(n_pods=grown, data=self.plan.data,
                             model=self.plan.model)
        return self.plan

    def rebalanced_batch(self, global_batch: int) -> int:
        """Keep per-chip batch constant: shrink the global batch with the
        pod count (deterministic grad-noise scale is preserved by LR scale
        on the host side)."""
        chips = self.plan.n_pods * self.plan.data * self.plan.model
        per = max(1, global_batch // max(chips, 1))
        return per * chips

    def rebalanced_rows(self, global_rows: int, old_n_pods: int) -> int:
        """Re-balance the batch ROW count across a pod-count change,
        keeping rows-per-pod constant (batch rows shard over the pod and
        data axes; the model axis replicates them)."""
        slices_old = max(old_n_pods * self.plan.data, 1)
        per = max(1, global_rows // slices_old)
        return per * self.plan.n_pods * self.plan.data
