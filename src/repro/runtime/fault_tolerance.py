"""Fault tolerance & elasticity runtime.

On a real multi-pod deployment these hooks wire into the cluster manager;
here every decision path is implemented and unit-tested against simulated
telemetry, and the launcher (launch/train.py) consumes them:

  * HeartbeatMonitor  — per-pod liveness from step-completion timestamps;
    marks a pod dead after ``timeout_s`` silence.
  * StragglerDetector — robust (median + MAD) step-time outlier detection;
    feeds the reliability weights omega (paper eq. 8) so persistent
    stragglers are down-weighted instead of stalling the ring.
  * ElasticPlanner    — maps a failure event to a new mesh plan: drop the
    dead pod, re-balance the batch, restart from the latest checkpoint
    (the checkpointer re-shards pod-dim leaves automatically).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence

import numpy as np


@dataclasses.dataclass
class PodStatus:
    pod_id: int
    last_seen: float
    step_times: List[float] = dataclasses.field(default_factory=list)
    alive: bool = True


class HeartbeatMonitor:
    def __init__(self, n_pods: int, timeout_s: float = 300.0):
        now = time.time()
        self.timeout_s = timeout_s
        self.pods = {i: PodStatus(i, now) for i in range(n_pods)}

    def beat(self, pod_id: int, step_time_s: float,
             now: Optional[float] = None):
        st = self.pods[pod_id]
        st.last_seen = now if now is not None else time.time()
        st.step_times.append(step_time_s)
        if len(st.step_times) > 256:
            st.step_times = st.step_times[-128:]

    def check(self, now: Optional[float] = None) -> List[int]:
        """-> list of pods newly marked dead."""
        now = now if now is not None else time.time()
        dead = []
        for st in self.pods.values():
            if st.alive and now - st.last_seen > self.timeout_s:
                st.alive = False
                dead.append(st.pod_id)
        return dead

    def alive_pods(self) -> List[int]:
        return [i for i, st in self.pods.items() if st.alive]


class StragglerDetector:
    """Median/MAD outlier detection over recent step times."""

    def __init__(self, threshold: float = 3.0):
        self.threshold = threshold

    def straggle_factors(self, monitor: HeartbeatMonitor) -> Dict[int, float]:
        pods = monitor.alive_pods()
        med_times = {}
        for i in pods:
            ts = monitor.pods[i].step_times[-32:]
            med_times[i] = float(np.median(ts)) if ts else 0.0
        vals = np.array([v for v in med_times.values() if v > 0])
        if len(vals) == 0:
            return {i: 1.0 for i in pods}
        med = float(np.median(vals))
        return {i: (med_times[i] / med if med > 0 and med_times[i] > 0
                    else 1.0) for i in pods}

    def stragglers(self, monitor: HeartbeatMonitor) -> List[int]:
        f = self.straggle_factors(monitor)
        vals = np.array(list(f.values()))
        mad = float(np.median(np.abs(vals - np.median(vals)))) + 1e-9
        return [i for i, v in f.items()
                if (v - np.median(vals)) / mad > self.threshold]


@dataclasses.dataclass
class MeshPlan:
    n_pods: int
    data: int
    model: int

    @property
    def shape(self):
        if self.n_pods > 1:
            return (self.n_pods, self.data, self.model)
        return (self.data, self.model)

    @property
    def axis_names(self):
        if self.n_pods > 1:
            return ("pod", "data", "model")
        return ("data", "model")


class ElasticPlanner:
    """Failure event -> new mesh plan + restart decision."""

    def __init__(self, initial: MeshPlan):
        self.plan = initial

    def on_pod_failure(self, dead_pods: Sequence[int]) -> MeshPlan:
        remaining = self.plan.n_pods - len(set(dead_pods))
        if remaining < 1:
            raise RuntimeError("all pods dead")
        self.plan = MeshPlan(n_pods=remaining, data=self.plan.data,
                             model=self.plan.model)
        return self.plan

    def rebalanced_batch(self, global_batch: int) -> int:
        """Keep per-chip batch constant: shrink the global batch with the
        pod count (deterministic grad-noise scale is preserved by LR scale
        on the host side)."""
        chips = self.plan.n_pods * self.plan.data * self.plan.model
        per = max(1, global_batch // max(chips, 1))
        return per * chips
