"""Deterministic fault injection for soak tests and benchmarks.

A :class:`FaultSchedule` is a seeded, step-indexed list of fleet events —
kill a pod at step k, rejoin it at step m, corrupt a checkpoint leaf on
disk, delay a pod's heartbeats — that the host loop
(:class:`repro.launch.train.TrainLoop`) drains at the top of every
iteration.  Schedules are pure data: deterministic in their constructor
arguments (or in ``seed`` for :meth:`FaultSchedule.random`), so a
fault-injected soak is exactly reproducible and CI failures replay.

The checkpoint corruptor flips bytes INSIDE a leaf payload (past the .npy
header) so the corruption is exactly what the checkpointer's CRC pass is
for: a file that still parses but whose contents changed.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Iterator, List, Optional, Sequence, Tuple

import numpy as np

#: event kinds the TrainLoop understands
KILL_POD = "kill_pod"
REJOIN_POD = "rejoin_pod"
CORRUPT_CKPT = "corrupt_checkpoint"
DELAY_HEARTBEAT = "delay_heartbeat"

KINDS = (KILL_POD, REJOIN_POD, CORRUPT_CKPT, DELAY_HEARTBEAT)


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    step: int           # host step at which the event fires
    kind: str           # one of KINDS
    target: int = 0     # pod id (kill/rejoin/delay) or leaf index (corrupt)
    duration: int = 0   # delay_heartbeat: steps of silence

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"expected one of {KINDS}")


class FaultSchedule:
    """An ordered, consumable schedule of :class:`FaultEvent`.

    ``due(step)`` pops and returns every event whose step has arrived
    (events are delivered at most once).  ``peek()`` exposes what remains
    so tests can assert the schedule drained.
    """

    def __init__(self, events: Sequence[FaultEvent] = ()):
        self._events: List[FaultEvent] = sorted(events,
                                                key=lambda e: e.step)
        self.fired: List[FaultEvent] = []

    def __len__(self) -> int:
        return len(self._events)

    def __iter__(self) -> Iterator[FaultEvent]:
        return iter(self._events)

    def peek(self) -> Tuple[FaultEvent, ...]:
        return tuple(self._events)

    def due(self, step: int) -> List[FaultEvent]:
        out = []
        while self._events and self._events[0].step <= step:
            out.append(self._events.pop(0))
        self.fired.extend(out)
        return out

    # ------------------------------------------------------------------
    @classmethod
    def preempt_and_rejoin(cls, pod: int, kill_step: int,
                           rejoin_step: int) -> "FaultSchedule":
        """The canonical elastic soak: pod preempted at k, back at m."""
        if rejoin_step <= kill_step:
            raise ValueError("rejoin must come after the kill")
        return cls([FaultEvent(kill_step, KILL_POD, pod),
                    FaultEvent(rejoin_step, REJOIN_POD, pod)])

    @classmethod
    def random(cls, seed: int, n_steps: int, n_pods: int,
               n_kills: int = 1, n_corruptions: int = 0,
               n_delays: int = 0) -> "FaultSchedule":
        """A seeded random schedule: each kill is paired with a later
        rejoin (membership returns to full strength by the end), plus
        optional checkpoint corruptions and heartbeat delays.  Pod 0 is
        never killed (the coordinator slot)."""
        rng = np.random.RandomState(seed)
        events: List[FaultEvent] = []
        lo, hi = max(2, n_steps // 8), max(3, n_steps - 2)
        for _ in range(n_kills):
            if n_pods < 2 or hi - lo < 2:
                break
            k = int(rng.randint(lo, hi - 1))
            m = int(rng.randint(k + 1, hi))
            pod = int(rng.randint(1, n_pods))
            events.append(FaultEvent(k, KILL_POD, pod))
            events.append(FaultEvent(m, REJOIN_POD, pod))
        for _ in range(n_corruptions):
            events.append(FaultEvent(int(rng.randint(lo, hi)),
                                     CORRUPT_CKPT, int(rng.randint(0, 8))))
        for _ in range(n_delays):
            events.append(FaultEvent(
                int(rng.randint(lo, hi)), DELAY_HEARTBEAT,
                int(rng.randint(0, n_pods)),
                duration=int(rng.randint(1, 4))))
        return cls(events)


def corrupt_checkpoint_leaf(ckpt_dir: str, leaf: int,
                            step: Optional[int] = None, seed: int = 0,
                            n_bytes: int = 64) -> Optional[str]:
    """Flip ``n_bytes`` random payload bytes of one leaf file in the
    newest (or given) checkpoint — deterministic in ``seed``.  Returns the
    corrupted path, or None when there is nothing to corrupt.  Bytes past
    the 128-byte .npy header are targeted so the file still loads and
    only the CRC (not the parser) can catch it."""
    if step is None:
        steps = sorted(int(n.split("_")[1]) for n in os.listdir(ckpt_dir)
                       if n.startswith("step_") and not n.endswith(".tmp"))
        if not steps:
            return None
        step = steps[-1]
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    path = os.path.join(d, f"leaf_{leaf}.npy")
    if not os.path.isfile(path):
        names = sorted(n for n in os.listdir(d)
                       if n.startswith("leaf_") and n.endswith(".npy"))
        if not names:
            return None
        path = os.path.join(d, names[leaf % len(names)])
    size = os.path.getsize(path)
    header = min(128, size)
    if size <= header:
        return None
    rng = np.random.RandomState(seed)
    with open(path, "r+b") as f:
        for _ in range(max(1, n_bytes)):
            off = header + int(rng.randint(0, size - header))
            f.seek(off)
            b = f.read(1)
            f.seek(off)
            f.write(bytes([b[0] ^ 0xFF]) if b else b"\xff")
    return path


def truncate_checkpoint_leaf(ckpt_dir: str, leaf: int,
                             step: Optional[int] = None) -> Optional[str]:
    """Truncate a leaf file to half its length — the torn-write shape of
    corruption (a crash mid-copy).  Returns the truncated path."""
    if step is None:
        steps = sorted(int(n.split("_")[1]) for n in os.listdir(ckpt_dir)
                       if n.startswith("step_") and not n.endswith(".tmp"))
        if not steps:
            return None
        step = steps[-1]
    path = os.path.join(ckpt_dir, f"step_{step:08d}", f"leaf_{leaf}.npy")
    if not os.path.isfile(path):
        return None
    size = os.path.getsize(path)
    with open(path, "r+b") as f:
        f.truncate(size // 2)
    return path
