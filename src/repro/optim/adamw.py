"""AdamW + gradient clipping + LR schedules in pure JAX (no optax)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def init_opt_state(params):
    return {"m": jax.tree.map(jnp.zeros_like, params),
            "v": jax.tree.map(jnp.zeros_like, params)}


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(x.astype(jnp.float32) ** 2)
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), tree), norm


def bias_corrections(step, beta1: float, beta2: float):
    """(bc1, bc2) for the Adam moment bias correction at ``step``."""
    t = step.astype(jnp.float32) + 1.0
    return 1.0 - beta1 ** t, 1.0 - beta2 ** t


def update_rows(p, g, m, v, *, lr, bc1, bc2, beta1=0.9, beta2=0.95,
                eps=1e-8, weight_decay=0.1):
    """The elementwise AdamW update on arbitrary same-shape f32 buffers —
    layout-free, so the trainer's rung-ordered apply can run it on a
    rung's ``(S, block)`` bucket rows the moment that rung's exchange
    lands.  Identical math (same association, same dtypes) to the
    whole-tree :func:`adamw_update` path.  Returns (p', m', v') in f32;
    the caller casts back to storage dtypes."""
    g32 = g.astype(jnp.float32)
    p32 = p.astype(jnp.float32)
    m_new = beta1 * m + (1 - beta1) * g32
    v_new = beta2 * v + (1 - beta2) * g32 * g32
    mh = m_new / bc1
    vh = v_new / bc2
    p_new = p32 - lr * (mh / (jnp.sqrt(vh) + eps) + weight_decay * p32)
    return p_new, m_new, v_new


def adamw_update(params, grads, opt_state, step, *, lr, beta1=0.9,
                 beta2=0.95, eps=1e-8, weight_decay=0.1):
    """One AdamW step. ``lr`` may be a traced scalar. Returns
    (new_params, new_opt_state)."""
    bc1, bc2 = bias_corrections(step, beta1, beta2)

    def upd(p, g, m, v):
        p_new, m_new, v_new = update_rows(
            p, g, m, v, lr=lr, bc1=bc1, bc2=bc2, beta1=beta1, beta2=beta2,
            eps=eps, weight_decay=weight_decay)
        return p_new.astype(p.dtype), m_new, v_new

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(opt_state["m"])
    flat_v = tdef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v)
           for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(tdef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v}


def cosine_schedule(step, *, base_lr: float, warmup: int, total: int,
                    min_frac: float = 0.1):
    s = step.astype(jnp.float32)
    warm = jnp.minimum(s / max(warmup, 1), 1.0)
    prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(math.pi * prog))
    return base_lr * warm * cos
