"""First-class synchronization strategies.

A :class:`SyncStrategy` owns every decision the seed smeared across
``Trainer`` (``_needs_anchor``, ``default_plan``), ``TrainLoop``
(``refresh_plan``, ``adapt_interval``, the step-kind schedule in
``run_steps``) and the CLIs (hard-coded ``choices=[...]`` lists):

  * ``needs_anchor`` / ``extra_state``  — what extra train state the
    strategy requires (e.g. the FedAvg/ACE-Sync anchor copy of params);
  * ``make_plan``                       — telemetry + importance + omega
    -> :class:`~repro.core.scheduler.SyncPlan`;
  * ``step_schedule``                   — which step kinds
    (``grad_sync`` / ``local`` / ``delta_sync`` / ``param_avg``) run at a
    given point of the H-step local window;
  * ``adapt``                           — divergence-driven sync-interval
    control (paper eq. 9), a no-op for fixed-interval strategies;
  * ``wire_bytes``                      — what a given step kind moves over
    the bandwidth-constrained tier (comm accounting for Table 1).

Strategies register themselves by name with :func:`register_strategy`;
``Trainer``, ``TrainLoop``, the launch CLIs, ``scripts/sweep.py`` and the
benchmarks resolve them via :func:`build_strategy` / :func:`list_strategies`,
so adding a new regime is a one-file change::

    from repro.strategies import SyncStrategy, register_strategy

    @register_strategy
    class MyStrategy(SyncStrategy):
        name = "mystrategy"
        def make_plan(self, scheduler, *, importance=None, telemetry=None,
                      omega=None):
            return scheduler.full_plan(omega)
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple, Type, Union

import jax
import jax.numpy as jnp

from repro.configs.base import ACESyncConfig
from repro.core.scheduler import Scheduler, SyncPlan

# The step kinds the trainer knows how to execute (see Trainer._BODIES).
STEP_KINDS = ("grad_sync", "local", "delta_sync", "param_avg")
# Kinds that move bytes across pods and therefore end a local window.
SYNC_KINDS = frozenset({"grad_sync", "delta_sync", "param_avg"})
# Kinds that advance the optimizer step counter (the host loop mirrors the
# device counter with these instead of a blocking device_get per step).
STEP_ADVANCING = frozenset({"grad_sync", "local"})


def mean_bandwidth(telemetry: Optional[Sequence[dict]],
                   default: float = 50.0) -> float:
    """Mean bandwidth (Mbps) over a telemetry snapshot (list of per-device
    dicts with a ``bandwidth_mbps`` key), or ``default`` when absent."""
    if not telemetry:
        return default
    vals = [t["bandwidth_mbps"] for t in telemetry
            if "bandwidth_mbps" in t]
    return sum(vals) / len(vals) if vals else default


class SyncStrategy:
    """Base class: FullSync semantics (dense sync every step, H == 1)."""

    #: registry key; subclasses must override.
    name: str = ""
    #: keep an ``anchor`` copy of params in the train state (delta_sync /
    #: param-averaging strategies reset params against it).
    needs_anchor: bool = False
    #: run the divergence-driven H controller (paper eq. 9) on replan.
    adapts_interval: bool = False
    #: feed importance scores from the online estimator into make_plan.
    uses_importance: bool = False
    #: step kind lowered by the dry-run as "the" fused step of this strategy.
    representative_kind: str = "grad_sync"

    # ---- state ----------------------------------------------------------
    def initial_interval(self, cfg: ACESyncConfig) -> int:
        """Initial H (local steps per cross-pod sync)."""
        return cfg.sync_interval_init if self.adapts_interval else 1

    def extra_state(self, params) -> Dict[str, object]:
        """Extra (param-like) train-state entries the strategy needs."""
        if self.needs_anchor:
            return {"anchor": jax.tree.map(jnp.copy, params)}
        return {}

    def extra_state_specs(self, param_specs) -> Dict[str, object]:
        """ShapeDtypeStruct version of :meth:`extra_state` (dry-run)."""
        if self.needs_anchor:
            return {"anchor": param_specs}
        return {}

    # ---- planning -------------------------------------------------------
    def make_plan(self, scheduler: Scheduler, *,
                  importance: Optional[Sequence[float]] = None,
                  telemetry: Optional[Sequence[dict]] = None,
                  omega: Optional[Sequence[float]] = None,
                  clusters=None) -> SyncPlan:
        """Turn (importance, telemetry, omega) into a compression plan.
        ``clusters`` is the loop's live :class:`~repro.hierarchy.ClusterState`
        (None outside a TrainLoop); the loop only forwards it to strategies
        whose ``make_plan`` declares the keyword, so overrides without it
        keep working."""
        return scheduler.full_plan(omega)

    def budget_bandwidth(self, telemetry: Optional[Sequence[dict]] = None,
                         clusters=None, default: float = 50.0) -> float:
        """Bandwidth (Mbps) the byte budget is priced against.  The flat
        strategies budget against the fleet mean; the hierarchical strategy
        overrides this to the bottleneck cluster's mean (the cross-tier
        ring is paced by its weakest pod).  ``clusters`` is the loop's
        :class:`~repro.hierarchy.ClusterState`, when one is live."""
        return mean_bandwidth(telemetry, default)

    def device_plan_fn(self, scheduler: Scheduler, cfg: ACESyncConfig):
        """Device-resident replan, if the strategy supports one: a jitted
        ``fn(importance_state, struct_feat, budget_bytes) -> int32[G]``
        level assignment that runs entirely on device (the host fetches
        the tiny vector asynchronously and rebuilds the plan off the
        critical path).  ``None`` (the default) means plans only come from
        the host-side :meth:`make_plan`."""
        return None

    def step_schedule(self, steps_since_sync: int, H: int
                      ) -> Tuple[str, ...]:
        """Step kinds to execute at this point of the H-step window.

        The host loop runs the kinds in order and resets its
        ``steps_since_sync`` counter whenever the sequence ends in a kind
        from :data:`SYNC_KINDS`.
        """
        return ("grad_sync",)

    def adapt(self, scheduler: Scheduler, divergence: float) -> int:
        """Divergence-driven sync-interval control; returns the new H."""
        if not self.adapts_interval:
            return self.initial_interval(scheduler.cfg)
        # reference scale: the EMA trend itself (relative control)
        return scheduler.adapt_interval(divergence,
                                        max(divergence, 1e-8) * 10.0)

    # ---- accounting -----------------------------------------------------
    def wire_bytes(self, scheduler: Scheduler, plan: SyncPlan, kind: str,
                   n_pods: Optional[int] = None) -> int:
        """Bytes the given step kind moves over the pod tier per device."""
        if kind == "local":
            return 0
        if kind == "param_avg":
            # plain parameter averaging moves the dense tensors
            return scheduler.plan_wire_bytes(scheduler.full_plan(), n_pods)
        return scheduler.plan_wire_bytes(plan, n_pods)

    def __repr__(self) -> str:  # pragma: no cover - debugging nicety
        return f"<{type(self).__name__} {self.name!r}>"


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Type[SyncStrategy]] = {}


def register_strategy(cls: Type[SyncStrategy]) -> Type[SyncStrategy]:
    """Class decorator: make ``cls`` resolvable by its ``name``."""
    if not cls.name:
        raise ValueError(f"{cls.__name__} must set a non-empty .name")
    if _REGISTRY.get(cls.name) not in (None, cls):
        raise ValueError(f"strategy {cls.name!r} already registered by "
                         f"{_REGISTRY[cls.name].__name__}")
    _REGISTRY[cls.name] = cls
    return cls


def list_strategies() -> List[str]:
    """Registered strategy names (sorted, stable for CLI choices)."""
    return sorted(_REGISTRY)


def get_strategy(name: str) -> Type[SyncStrategy]:
    """Look up a strategy class by registry name."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown strategy {name!r}; registered: "
                       f"{list_strategies()}") from None


def build_strategy(name: str, **kwargs) -> SyncStrategy:
    """Instantiate a registered strategy by name."""
    return get_strategy(name)(**kwargs)


def resolve_strategy(spec: Union[str, SyncStrategy, Type[SyncStrategy]]
                     ) -> SyncStrategy:
    """Accept a name, an instance, or a class; return an instance."""
    if isinstance(spec, SyncStrategy):
        return spec
    if isinstance(spec, type) and issubclass(spec, SyncStrategy):
        return spec()
    if isinstance(spec, str):
        return build_strategy(spec)
    raise TypeError(f"cannot resolve strategy from {spec!r}")
