"""Pluggable synchronization strategies (see base.py for the contract)."""
from repro.strategies.base import (STEP_ADVANCING, STEP_KINDS, SYNC_KINDS,
                                   SyncStrategy, build_strategy,
                                   get_strategy, list_strategies,
                                   mean_bandwidth, register_strategy,
                                   resolve_strategy)
# importing the module runs the @register_strategy decorators
from repro.strategies import builtin  # noqa: F401
from repro.strategies.builtin import (ACESync, BandwidthTiered, FedAvg,
                                      FullSync, LocalSGD, TopK)

__all__ = [
    "STEP_ADVANCING", "STEP_KINDS", "SYNC_KINDS", "SyncStrategy",
    "build_strategy",
    "get_strategy", "list_strategies", "mean_bandwidth",
    "register_strategy", "resolve_strategy",
    "ACESync", "BandwidthTiered", "FedAvg", "FullSync", "LocalSGD", "TopK",
]
