"""The built-in synchronization strategies.

The first four are the paper's Table 1 regimes, migrated from the seed's
string dispatch with plan-identical behavior (tests/test_strategies.py
asserts byte-identical ``SyncPlan``s).  ``localsgd`` and
``bandwidth_tiered`` are new regimes the old design could not host without
another round of cross-cutting ``if strategy == ...`` edits.
"""
from __future__ import annotations

from typing import Tuple

from repro.core.scheduler import Scheduler, SyncPlan, kept_fraction
from repro.strategies.base import (SyncStrategy, mean_bandwidth,
                                   register_strategy)


@register_strategy
class FullSync(SyncStrategy):
    """Dense bf16 gradient all-reduce every step (Table 1 baseline)."""
    name = "fullsync"

    def make_plan(self, scheduler: Scheduler, *, importance=None,
                  telemetry=None, omega=None) -> SyncPlan:
        return scheduler.full_plan(omega)


@register_strategy
class TopK(SyncStrategy):
    """Static top-k sparsification, same ratio for every group."""
    name = "topk"

    def __init__(self, ratio: float = 0.1):
        self.ratio = ratio

    def make_plan(self, scheduler: Scheduler, *, importance=None,
                  telemetry=None, omega=None) -> SyncPlan:
        return scheduler.uniform_topk_plan(self.ratio, omega)


class _PeriodicStrategy(SyncStrategy):
    """Shared H-window schedule: H-1 local steps, then one sync step."""
    #: kind executed at the end of each H-step local window.
    sync_kind: str = "param_avg"

    def step_schedule(self, steps_since_sync: int, H: int
                      ) -> Tuple[str, ...]:
        if H <= 1:
            return ("grad_sync",)
        if (steps_since_sync + 1) % H:
            return ("local",)
        return ("local", self.sync_kind)


@register_strategy
class FedAvg(_PeriodicStrategy):
    """Periodic omega-weighted parameter averaging (FedAvg baseline)."""
    name = "fedavg"
    needs_anchor = True
    adapts_interval = True
    sync_kind = "param_avg"

    def make_plan(self, scheduler: Scheduler, *, importance=None,
                  telemetry=None, omega=None) -> SyncPlan:
        return scheduler.full_plan(omega)


@register_strategy
class ACESync(_PeriodicStrategy):
    """The paper's adaptive strategy: importance + eq-(5) bandwidth budget
    -> knapsack plan; compressed delta sync with error feedback; eq-(9)
    divergence-controlled H."""
    name = "acesync"
    needs_anchor = True
    adapts_interval = True
    uses_importance = True
    sync_kind = "delta_sync"

    def make_plan(self, scheduler: Scheduler, *, importance=None,
                  telemetry=None, omega=None) -> SyncPlan:
        imp = (list(importance) if importance is not None
               else [1.0] * len(scheduler.sizes))
        bw = mean_bandwidth(telemetry)
        return scheduler.plan(imp, bw, omega)

    def device_plan_fn(self, scheduler: Scheduler, cfg):
        """Importance scoring + knapsack fused into one device computation
        (core/acesync.device_replan_fn) — the retrace-free control plane."""
        from repro.core import acesync
        return acesync.device_replan_fn(scheduler, cfg)


@register_strategy
class ACESyncHier(ACESync):
    """ACE-Sync on the two-tier topology (paper eq. 8 made live).

    Identical control plane to :class:`ACESync` — importance + knapsack +
    divergence-controlled H — but coordinated per cluster: the TrainLoop's
    :class:`~repro.hierarchy.ClusterState` maps devices onto the
    ``("pod","edge")`` fleet, omega arrives already slot-summed, and the
    byte budget is priced against the *bottleneck* cluster's bandwidth
    instead of the fleet mean, because the cross-tier ring moves at the
    pace of its weakest pod.  The two-tier execution itself (cheap
    intra-cluster aggregation feeding the compressed cross-tier ring) is
    picked rung-by-rung in ``planexec.exec_grid`` whenever the mesh has an
    "edge" axis, so this strategy also runs unchanged — as plain acesync —
    on a flat mesh."""
    name = "acesync_hier"

    def budget_bandwidth(self, telemetry=None, clusters=None,
                         default: float = 50.0) -> float:
        if clusters is not None and getattr(clusters, "assignments", None):
            return clusters.bottleneck_bandwidth(telemetry, default)
        return mean_bandwidth(telemetry, default)

    def make_plan(self, scheduler: Scheduler, *, importance=None,
                  telemetry=None, omega=None, clusters=None) -> SyncPlan:
        imp = (list(importance) if importance is not None
               else [1.0] * len(scheduler.sizes))
        bw = self.budget_bandwidth(telemetry, clusters)
        return scheduler.plan(imp, bw, omega)


@register_strategy
class LocalSGD(SyncStrategy):
    """Periodic parameter averaging with a FIXED sync interval.

    The classic LocalSGD regime ("When Less is More"): H-1 optimizer-only
    local steps, then a plain omega-weighted parameter average — no anchor,
    no error feedback, no divergence controller.  The seed's string
    dispatch could not express this: fixed-H scheduling was hard-wired to
    the fedavg/acesync anchor+adaptation path.
    """
    name = "localsgd"

    def __init__(self, interval: int = 8):
        if interval < 1:
            raise ValueError("localsgd interval must be >= 1")
        self.interval = interval

    def initial_interval(self, cfg) -> int:
        return self.interval

    def adapt(self, scheduler: Scheduler, divergence: float) -> int:
        return self.interval  # fixed by construction

    def make_plan(self, scheduler: Scheduler, *, importance=None,
                  telemetry=None, omega=None) -> SyncPlan:
        fi = scheduler.levels.index(scheduler.full_level)
        return scheduler.plan_from_levels([fi] * len(scheduler.sizes),
                                          omega, sync_interval=self.interval)

    def step_schedule(self, steps_since_sync: int, H: int
                      ) -> Tuple[str, ...]:
        H = max(H, 1)
        if (steps_since_sync + 1) % H:
            return ("local",)
        return ("local", "param_avg")


@register_strategy
class BandwidthTiered(SyncStrategy):
    """Knapsack-free adaptive compression from live telemetry.

    Each replan reads the bandwidth snapshot and picks, per parameter
    group, a codec BY NAME from the scheduler's ladder: when the link is
    fat (kept fraction above ``dense_fraction``) everything goes to the
    ``dense_codec`` (default ``int8``); under a thin link the large groups
    (>= median size) drop to the ``topk`` rung closest to the eq-(5)
    affordable fraction while small groups — cheap in absolute bytes but
    disproportionately important (norms, embeddings' biases) — stay dense.
    A DynaComm-style tiering rule that needs no importance estimator and
    no solver.  Because selection is by registered codec name, widening
    the ladder (int4, sign, ...) is a config change, not a strategy edit:
    ``BandwidthTiered(dense_codec="int4")`` halves the fat-link bytes.
    """
    name = "bandwidth_tiered"

    def __init__(self, dense_fraction: float = 0.45,
                 floor_ratio: float = 0.01, dense_codec: str = "int8"):
        self.dense_fraction = dense_fraction
        self.floor_ratio = floor_ratio
        self.dense_codec = dense_codec

    def _ladder_by_codec(self, scheduler: Scheduler):
        """Map codec name -> level indices of the scheduler's ladder."""
        by_name = {}
        for i, l in enumerate(scheduler.levels):
            by_name.setdefault(l.codec.name, []).append(i)
        return by_name

    def make_plan(self, scheduler: Scheduler, *, importance=None,
                  telemetry=None, omega=None) -> SyncPlan:
        bw = mean_bandwidth(telemetry)
        frac = kept_fraction(scheduler.cfg, bw)
        levels = scheduler.levels
        by_name = self._ladder_by_codec(scheduler)
        dense_cand = by_name.get(self.dense_codec) or by_name.get("int8")
        dense_i = (dense_cand[0] if dense_cand
                   else levels.index(scheduler.full_level))
        topks = [(i, levels[i].keep_ratio) for i in by_name.get("topk", [])]
        sizes = scheduler.sizes
        median = sorted(sizes)[len(sizes) // 2] if sizes else 0
        target = max(frac, self.floor_ratio)
        choice = []
        for n in sizes:
            if frac >= self.dense_fraction or n < median or not topks:
                choice.append(dense_i)
            else:
                choice.append(min(topks,
                                  key=lambda t: abs(t[1] - target))[0])
        # adaptive: replans change with telemetry, so pad bucket classes to
        # keep the compiled step's signature stable across them
        return scheduler.plan_from_levels(choice, omega, sync_interval=1,
                                          adaptive=True)
