"""Pallas TPU kernel: fused error-feedback + block-local top-k selection.

The gradient-compression hot loop (paper eqs. 6-7) touches every gradient
byte several times when written naively:

    read g, read e  -> ef = g + gamma*e          (1 pass)
    top-k select over ef                          (1-2 passes)
    write masked ef, write residual               (1 pass each)

This kernel fuses all of it into ONE HBM pass per block: each grid step
loads a (rows, 1024) tile into VMEM, computes the error-feedback
accumulator, finds the per-row top-k threshold with a fixed 16-step
bisection on |ef| (VPU-friendly: no sort, no data-dependent control flow),
and writes the selected-dense tile and the residual tile.

Selection contract (shared with ref.py, bit-exact): keep entries with
|ef| >= t where t is the bisection threshold for "approximately k per row";
ties around the threshold may admit slightly more/fewer than k — the wire
format carries a count, so correctness does not depend on exact k (DGC
makes the same trade).

Block geometry: tiles are (ROWS, LANES) = (8, 1024) f32 = 32 KiB in VMEM —
8 sublanes x 128-lane multiples, MXU/VPU aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROWS = 8          # sublane tile height (rows of independent 1024-blocks)
LANES = 1024      # block width (multiple of 128 lanes)
BISECT_ITERS = 16


def _select_body(ef, k):
    """Shared selection math (kernel + oracle): per-row bisection threshold.

    ef: (rows, LANES) f32. Returns (mask f32, threshold (rows, 1))."""
    mag = jnp.abs(ef)
    hi = jnp.max(mag, axis=-1, keepdims=True)
    lo = jnp.zeros_like(hi)
    kf = jnp.float32(k)
    for _ in range(BISECT_ITERS):
        mid = 0.5 * (lo + hi)
        cnt = jnp.sum((mag >= mid).astype(jnp.float32), axis=-1,
                      keepdims=True)
        # too many selected -> raise threshold; too few -> lower it
        take_hi = cnt > kf
        lo = jnp.where(take_hi, mid, lo)
        hi = jnp.where(take_hi, hi, mid)
    thr = 0.5 * (lo + hi)
    mask = (mag >= thr).astype(ef.dtype)
    return mask, thr


def _kernel(g_ref, e_ref, sel_ref, res_ref, *, gamma: float, k: int):
    g = g_ref[...].astype(jnp.float32)
    e = e_ref[...].astype(jnp.float32)
    ef = g + gamma * e
    mask, _ = _select_body(ef, k)
    sel = ef * mask
    sel_ref[...] = sel.astype(sel_ref.dtype)
    res_ref[...] = (ef - sel).astype(res_ref.dtype)


@functools.partial(jax.jit, static_argnames=("gamma", "k", "interpret"))
def ef_topk_select(g, e, *, gamma: float, k: int, interpret: bool = False):
    """g, e: (n_rows, LANES) f32 — n_rows % ROWS == 0.
    Returns (selected_dense, residual), both (n_rows, LANES) f32."""
    n_rows, lanes = g.shape
    assert lanes == LANES and n_rows % ROWS == 0, (g.shape,)
    grid = (n_rows // ROWS,)
    spec = pl.BlockSpec((ROWS, LANES), lambda i: (i, 0))
    out = pl.pallas_call(
        functools.partial(_kernel, gamma=gamma, k=k),
        grid=grid,
        in_specs=[spec, spec],
        out_specs=[spec, spec],
        out_shape=[jax.ShapeDtypeStruct((n_rows, LANES), jnp.float32)] * 2,
        interpret=interpret,
    )(g, e)
    return out[0], out[1]
