"""Pallas TPU kernel: fused error-feedback + block-local top-k selection.

The gradient-compression hot loop (paper eqs. 6-7) touches every gradient
byte several times when written naively:

    read g, read e  -> ef = g + gamma*e          (1 pass)
    top-k select over ef                          (1-2 passes)
    write masked ef, write residual               (1 pass each)

This kernel fuses all of it into ONE HBM pass per block: each grid step
loads a (rows, 1024) tile into VMEM, computes the error-feedback
accumulator, finds the per-row top-k threshold with a fixed 16-step
bisection on |ef| (VPU-friendly: no sort, no data-dependent control flow),
and writes the selected-dense tile and the residual tile.

Selection contract (shared with ref.py, bit-exact): keep entries with
|ef| >= t where t is the bisection threshold for "approximately k per row";
ties around the threshold may admit slightly more/fewer than k — the wire
format carries a count, so correctness does not depend on exact k (DGC
makes the same trade).

Block geometry: tiles are (ROWS, LANES) = (8, 1024) f32 = 32 KiB in VMEM —
8 sublanes x 128-lane multiples, MXU/VPU aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

ROWS = 8          # sublane tile height (rows of independent 1024-blocks)
LANES = 1024      # block width (multiple of 128 lanes)
BISECT_ITERS = 16


# ---------------------------------------------------------------------------
# producer-fused gather plumbing (shared by every gather+encode kernel)
# ---------------------------------------------------------------------------


def gather_ef_call(body, fb, eb, perm, out_defs, *, rows: int,
                   interpret: bool = False):
    """Run a per-row encode ``body`` directly on gathered bucket rows.

    ``fb`` / ``eb``: the packed (NB+1, LANES) grad / error-feedback
    buffers (zero row last); ``perm``: (S,) int32 block indices, S a
    multiple of ``rows``.  ``body(g, e) -> tuple`` maps (r, LANES) f32
    row tiles to the per-row encode outputs; ``out_defs`` lists each
    output's ``(width, dtype)`` (outputs are (S, width)).

    The gather never materialises in HBM.  Two lowerings, picked by the
    autotuner (``repro.kernels.autotune.block_rows``):

      * ``rows == 1``: the perm rides in scalar-prefetch memory and the
        input index map reads block ``perm[i]`` per grid step — Pallas's
        pipeline does the gather while fetching the tile;
      * ``rows > 1``: the whole buffer is the block and the kernel
        dynamic-slices ``rows`` indexed rows per step — fewer grid
        steps, more work (and VMEM) per step.

    Both produce bit-identical outputs (same per-row math, same f32
    order); only wall time differs.
    """
    S = perm.shape[0]
    assert S % rows == 0, (S, rows)
    nbp1, lanes = fb.shape

    def kernel_r1(p_ref, g_ref, e_ref, *out_refs):
        outs = body(g_ref[...], e_ref[...])
        for ref, o in zip(out_refs, outs):
            ref[...] = o.astype(ref.dtype)

    def kernel_rn(p_ref, g_ref, e_ref, *out_refs):
        i = pl.program_id(0)
        for r in range(rows):
            idx = p_ref[i * rows + r]
            g = pl.load(g_ref, (pl.dslice(idx, 1), slice(None)))
            e = pl.load(e_ref, (pl.dslice(idx, 1), slice(None)))
            outs = body(g, e)
            for ref, o in zip(out_refs, outs):
                pl.store(ref, (pl.dslice(r, 1), slice(None)),
                         o.astype(ref.dtype))

    if rows == 1:
        in_specs = [pl.BlockSpec((1, lanes), lambda i, p: (p[i], 0))] * 2
        out_specs = [pl.BlockSpec((1, w), lambda i, p: (i, 0))
                     for w, _ in out_defs]
        grid, kernel = (S,), kernel_r1
    else:
        in_specs = [pl.BlockSpec((nbp1, lanes), lambda i, p: (0, 0))] * 2
        out_specs = [pl.BlockSpec((rows, w), lambda i, p: (i, 0))
                     for w, _ in out_defs]
        grid, kernel = (S // rows,), kernel_rn
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1, grid=grid, in_specs=in_specs,
        out_specs=out_specs)
    return pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((S, w), dt) for w, dt in out_defs],
        interpret=interpret,
    )(perm.astype(jnp.int32), fb, eb)


def _select_body(ef, k):
    """Shared selection math (kernel + oracle): per-row bisection threshold.

    ef: (rows, LANES) f32. Returns (mask f32, threshold (rows, 1))."""
    mag = jnp.abs(ef)
    hi = jnp.max(mag, axis=-1, keepdims=True)
    lo = jnp.zeros_like(hi)
    kf = jnp.float32(k)
    for _ in range(BISECT_ITERS):
        mid = 0.5 * (lo + hi)
        cnt = jnp.sum((mag >= mid).astype(jnp.float32), axis=-1,
                      keepdims=True)
        # too many selected -> raise threshold; too few -> lower it
        take_hi = cnt > kf
        lo = jnp.where(take_hi, mid, lo)
        hi = jnp.where(take_hi, hi, mid)
    thr = 0.5 * (lo + hi)
    mask = (mag >= thr).astype(ef.dtype)
    return mask, thr


def _kernel(g_ref, e_ref, sel_ref, res_ref, *, gamma: float, k: int):
    g = g_ref[...].astype(jnp.float32)
    e = e_ref[...].astype(jnp.float32)
    ef = g + gamma * e
    mask, _ = _select_body(ef, k)
    sel = ef * mask
    sel_ref[...] = sel.astype(sel_ref.dtype)
    res_ref[...] = (ef - sel).astype(res_ref.dtype)


@functools.partial(jax.jit, static_argnames=("gamma", "k", "interpret"))
def ef_topk_select(g, e, *, gamma: float, k: int, interpret: bool = False):
    """g, e: (n_rows, LANES) f32 — n_rows % ROWS == 0.
    Returns (selected_dense, residual), both (n_rows, LANES) f32."""
    n_rows, lanes = g.shape
    assert lanes == LANES and n_rows % ROWS == 0, (g.shape,)
    grid = (n_rows // ROWS,)
    spec = pl.BlockSpec((ROWS, LANES), lambda i: (i, 0))
    out = pl.pallas_call(
        functools.partial(_kernel, gamma=gamma, k=k),
        grid=grid,
        in_specs=[spec, spec],
        out_specs=[spec, spec],
        out_shape=[jax.ShapeDtypeStruct((n_rows, LANES), jnp.float32)] * 2,
        interpret=interpret,
    )(g, e)
    return out[0], out[1]


@functools.partial(jax.jit,
                   static_argnames=("gamma", "k", "rows", "interpret"))
def ef_topk_gather(fb, eb, perm, *, gamma: float, k: int, rows: int = 1,
                   interpret: bool = False):
    """Producer-fused gather + EF + top-k selection: reads the rung's
    rows straight out of the (NB+1, LANES) buffers through ``perm``.
    Returns (selected_dense, residual), both (S, LANES) f32 — bit-exact
    to :func:`ef_topk_select` on the gathered rows."""

    def body(g, e):
        ef = g.astype(jnp.float32) + gamma * e.astype(jnp.float32)
        mask, _ = _select_body(ef, k)
        sel = ef * mask
        return sel, ef - sel

    out_defs = [(LANES, jnp.float32), (LANES, jnp.float32)]
    return gather_ef_call(body, fb, eb, perm, out_defs, rows=rows,
                          interpret=interpret)
