"""Pure-jnp oracles for the Pallas kernels (bit-exact same math, no
pallas_call) — the ground truth for the per-kernel allclose sweeps."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.topk_compress import _select_body
from repro.kernels.quantize import _quant_body, _int4_body, pack_nibbles
from repro.kernels.sign import _sign_body


def ef_topk_select_ref(g, e, *, gamma: float, k: int):
    ef = g.astype(jnp.float32) + gamma * e.astype(jnp.float32)
    mask, _ = _select_body(ef, k)
    sel = ef * mask
    return sel, ef - sel


def quantize_int8_ref(x):
    x = x.astype(jnp.float32)
    q, scale = _quant_body(x)
    return q.astype(jnp.int8), scale, x - q * scale


def dequantize_int8_ref(q, scales):
    return q.astype(jnp.float32) * scales


def ef_int4_ref(g, e, *, gamma: float):
    ef = g.astype(jnp.float32) + gamma * e.astype(jnp.float32)
    q, scale = _int4_body(ef)
    return pack_nibbles(q), scale, ef - q * scale


def ef_sign_ref(g, e, *, gamma: float):
    ef = g.astype(jnp.float32) + gamma * e.astype(jnp.float32)
    sign, scale = _sign_body(ef)
    return sign.astype(jnp.int8), scale, ef - sign * scale


# ---- producer-fused gather + encode oracles -------------------------------
# Ground truth for the `*_gather` kernels: the gather is materialised
# (fb[perm]) and the flat encode body applied — the SAME f32 per-row math
# the fused kernels run on un-materialised rows, so kernel vs oracle is a
# bit-parity assertion, not an allclose (tests/test_kernels.py).


def _gather_ef(fb, eb, perm, gamma: float):
    return (fb[perm].astype(jnp.float32) +
            gamma * eb[perm].astype(jnp.float32))


def quantize_int8_gather_ref(fb, eb, perm, *, gamma: float):
    ef = _gather_ef(fb, eb, perm, gamma)
    q, scale = _quant_body(ef)
    return q.astype(jnp.int8), scale, ef - q * scale


def ef_int4_gather_ref(fb, eb, perm, *, gamma: float):
    ef = _gather_ef(fb, eb, perm, gamma)
    q, scale = _int4_body(ef)
    return pack_nibbles(q), scale, ef - q * scale


def ef_sign_gather_ref(fb, eb, perm, *, gamma: float):
    ef = _gather_ef(fb, eb, perm, gamma)
    sign, scale = _sign_body(ef)
    return sign.astype(jnp.int8), scale, ef - sign * scale


def ef_topk_gather_ref(fb, eb, perm, *, gamma: float, k: int):
    ef = _gather_ef(fb, eb, perm, gamma)
    mask, _ = _select_body(ef, k)
    sel = ef * mask
    return sel, ef - sel


def dequant_accum_int8_ref(acc, q, s, w):
    return acc + w * (q.astype(jnp.float32) * s)


def dequant_accum_int4_ref(acc, p, s, w):
    from repro.kernels.quantize import unpack_nibbles
    return acc + w * (unpack_nibbles(p) * s)


def sign_vote_accum_ref(vote, mag, p, s, w):
    from repro.kernels.decode import unpack_signs
    return vote + w * unpack_signs(p), mag + w * s


def dequant_accum_int8_fp_ref(acc, q, s, w, bits):
    from repro.kernels.decode import fixed_point
    return acc + fixed_point(w * (q.astype(jnp.float32) * s), bits)


def dequant_accum_int4_fp_ref(acc, p, s, w, bits):
    from repro.kernels.decode import fixed_point
    from repro.kernels.quantize import unpack_nibbles
    return acc + fixed_point(w * (unpack_nibbles(p) * s), bits)


def sign_vote_accum_fp_ref(vote, mag, p, s, w, bits):
    from repro.kernels.decode import fixed_point, unpack_signs
    wq = fixed_point(w, bits)
    return (vote + wq * unpack_signs(p).astype(jnp.int32),
            mag + fixed_point(w * s, bits))


def topk_scatter_accum_ref(acc, q, idx, s, w):
    vals = q.astype(jnp.float32) * s
    rows = jnp.arange(acc.shape[0])[:, None]
    return acc.at[rows, idx.astype(jnp.int32)].add(w * vals)


def exact_topk_mask(x, k):
    """Exact per-row top-k mask (what sync.py's lax.top_k path selects) —
    used to bound the bisection kernel's approximation in property tests."""
    mag = jnp.abs(x)
    vals, _ = jax.lax.top_k(mag, k)
    thr = vals[..., -1:]
    return (mag >= thr).astype(x.dtype)
