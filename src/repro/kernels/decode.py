"""Pallas TPU kernels: fused decode-accumulate for the ring exchange.

The chunked ring pipeline (``Codec.ef_sync_ring``) folds ONE peer's
payload chunk into the running aggregate per hop:

    acc += weight * decode(payload_chunk)

Done naively that is two HBM passes (materialise the dense decode, then
FMA).  These kernels fuse dequantisation + weighted accumulate into one
VMEM pass per (8, 1024) tile — the decode compute the ring hides behind
the DCN transfer of the next chunk:

  * int8:  acc += w * (q * scale)          (dequant-add)
  * int4:  unpack two nibbles per byte, then dequant-add
  * sign:  majority-vote partial counts: vote += w * (+-1 signs unpacked
           from the bit-packed wire), mag += w * scale
  * topk:  scatter-add the k (value, index) pairs per block into the
           dense accumulator (one-hot lane compare per kept entry)

``weight`` is a TRACED scalar (the omega entry of the sending pod — plan
data, swapped per replan), so it rides as a (1, 1) operand instead of a
baked constant.  The arithmetic association matches the jnp oracle path
(``acc + w * (q * scale)``) bit for bit on identical inputs.

Deterministic (fixed-point) variants
------------------------------------
For P >= 3 pods the ring folds peers in per-pod arrival order, so the
float accumulate above would let per-pod aggregates differ at ulp level
(fp addition is not associative).  The ``*_fp`` kernels instead quantise
each weighted term to int32 fixed point and accumulate in INTEGER
arithmetic — exact, commutative and associative, so every pod reaches
bit-identical sums in any fold order:

    acc_i32 += round(w * decode(chunk) * 2^bits)       (int32 add)

``fixed_point`` / ``FIXED_POINT_BITS`` define the shared quantiser (used
by the kernels, the oracle refs AND the codecs' one-shot fold, so ring
and all_gather paths stay bit-identical).  With the default 16
fractional bits the representable aggregate range is ±2^15 at 2^-16
absolute resolution; per-term saturation (and, past it, int32 wraparound)
is itself deterministic — accuracy degrades, determinism never does.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.quantize import unpack_nibbles
from repro.kernels.topk_compress import LANES, ROWS

#: fractional bits of the deterministic fixed-point accumulator
#: (``ACESyncConfig.accum_bits`` overrides per run).
FIXED_POINT_BITS = 16

#: largest f32 magnitude that casts to int32 without overflow (2^31 - 128,
#: the nearest representable float below 2^31).
_INT32_SAT = 2147483520.0


def fixed_point(x, bits: int = FIXED_POINT_BITS):
    """f32 -> int32 fixed point: round-to-nearest-even at ``bits``
    fractional bits, saturating at the int32 range.  Pure jnp, so it runs
    inside kernel bodies, the oracle refs and the codec fold alike —
    every path quantises a term to exactly the same integer."""
    s = jnp.round(x * jnp.float32(2.0 ** bits))
    return jnp.clip(s, -_INT32_SAT, _INT32_SAT).astype(jnp.int32)


def from_fixed_point(acc, bits: int = FIXED_POINT_BITS):
    """int32 fixed point -> f32 (exact: int32 -> f64-free scale by a
    power of two)."""
    return acc.astype(jnp.float32) * jnp.float32(2.0 ** -bits)


_spec = pl.BlockSpec((ROWS, LANES), lambda i: (i, 0))
_sspec = pl.BlockSpec((ROWS, 1), lambda i: (i, 0))
_wspec = pl.BlockSpec((1, 1), lambda i: (0, 0))


def unpack_signs(packed):
    """(rows, C // 8) uint8 bit-packed -> (rows, C) f32 {-1, +1} signs.
    Same bit layout as ``repro.codecs.base.unpack_bits`` (bit i of byte b
    = column 8b+i); plain jnp, so it runs inside the kernel body and in
    the oracle ref alike."""
    bits = ((packed[:, :, None] >>
             jnp.arange(8, dtype=jnp.uint8)) & 1).astype(jnp.float32)
    return bits.reshape(packed.shape[0], packed.shape[1] * 8) * 2.0 - 1.0


def _int8_kernel(acc_ref, q_ref, s_ref, w_ref, out_ref):
    w = w_ref[0, 0]
    q = q_ref[...].astype(jnp.float32)
    out_ref[...] = acc_ref[...] + w * (q * s_ref[...])


@functools.partial(jax.jit, static_argnames=("interpret",))
def dequant_accum_int8_fused(acc, q, s, w, *, interpret: bool = False):
    """acc (rows, LANES) f32, q int8, s (rows, 1) f32, w (1, 1) f32
    -> acc + w * (q * s) in one pass."""
    n_rows, lanes = acc.shape
    assert lanes == LANES and n_rows % ROWS == 0, (acc.shape,)
    return pl.pallas_call(
        _int8_kernel,
        grid=(n_rows // ROWS,),
        in_specs=[_spec, _spec, _sspec, _wspec],
        out_specs=_spec,
        out_shape=jax.ShapeDtypeStruct((n_rows, LANES), jnp.float32),
        interpret=interpret,
    )(acc, q, s, w)


def _int4_kernel(acc_ref, p_ref, s_ref, w_ref, out_ref):
    w = w_ref[0, 0]
    q = unpack_nibbles(p_ref[...])
    out_ref[...] = acc_ref[...] + w * (q * s_ref[...])


@functools.partial(jax.jit, static_argnames=("interpret",))
def dequant_accum_int4_fused(acc, p, s, w, *, interpret: bool = False):
    """acc (rows, LANES) f32, p (rows, LANES // 2) uint8 packed nibbles,
    s (rows, 1) f32, w (1, 1) f32 -> acc + w * dequant(p, s)."""
    n_rows, lanes = acc.shape
    assert lanes == LANES and n_rows % ROWS == 0, (acc.shape,)
    pspec = pl.BlockSpec((ROWS, LANES // 2), lambda i: (i, 0))
    return pl.pallas_call(
        _int4_kernel,
        grid=(n_rows // ROWS,),
        in_specs=[_spec, pspec, _sspec, _wspec],
        out_specs=_spec,
        out_shape=jax.ShapeDtypeStruct((n_rows, LANES), jnp.float32),
        interpret=interpret,
    )(acc, p, s, w)


def _sign_kernel(vote_ref, mag_ref, p_ref, s_ref, w_ref, vout_ref,
                 mout_ref):
    w = w_ref[0, 0]
    vout_ref[...] = vote_ref[...] + w * unpack_signs(p_ref[...])
    mout_ref[...] = mag_ref[...] + w * s_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def sign_vote_accum_fused(vote, mag, p, s, w, *, interpret: bool = False):
    """Majority-vote partials: vote (rows, LANES) f32 += w * signs
    (unpacked from p (rows, LANES // 8) uint8), mag (rows, 1) f32
    += w * s."""
    n_rows, lanes = vote.shape
    assert lanes == LANES and n_rows % ROWS == 0, (vote.shape,)
    pspec = pl.BlockSpec((ROWS, LANES // 8), lambda i: (i, 0))
    return pl.pallas_call(
        _sign_kernel,
        grid=(n_rows // ROWS,),
        in_specs=[_spec, _sspec, pspec, _sspec, _wspec],
        out_specs=[_spec, _sspec],
        out_shape=[jax.ShapeDtypeStruct((n_rows, LANES), jnp.float32),
                   jax.ShapeDtypeStruct((n_rows, 1), jnp.float32)],
        interpret=interpret,
    )(vote, mag, p, s, w)


def _topk_kernel(acc_ref, q_ref, i_ref, s_ref, w_ref, out_ref, *, k: int):
    w = w_ref[0, 0]
    vals = q_ref[...].astype(jnp.float32) * s_ref[...]   # (ROWS, k) dense
    idx = i_ref[...].astype(jnp.int32)
    lanes = jax.lax.broadcasted_iota(jnp.int32, (ROWS, LANES), 1)
    acc = acc_ref[...]

    def body(j, acc):
        hot = (lanes == idx[:, j][:, None]).astype(jnp.float32)
        return acc + hot * (w * vals[:, j][:, None])

    out_ref[...] = jax.lax.fori_loop(0, k, body, acc)


def _int8_fp_kernel(acc_ref, q_ref, s_ref, w_ref, out_ref, *, bits: int):
    w = w_ref[0, 0]
    q = q_ref[...].astype(jnp.float32)
    out_ref[...] = acc_ref[...] + fixed_point(w * (q * s_ref[...]), bits)


@functools.partial(jax.jit, static_argnames=("bits", "interpret"))
def dequant_accum_int8_fp_fused(acc, q, s, w, *, bits: int,
                                interpret: bool = False):
    """Deterministic int8 decode-accumulate: acc (rows, LANES) int32
    += fixed_point(w * (q * s)) — exact integer partial sums, fold-order
    insensitive."""
    n_rows, lanes = acc.shape
    assert lanes == LANES and n_rows % ROWS == 0, (acc.shape,)
    return pl.pallas_call(
        functools.partial(_int8_fp_kernel, bits=bits),
        grid=(n_rows // ROWS,),
        in_specs=[_spec, _spec, _sspec, _wspec],
        out_specs=_spec,
        out_shape=jax.ShapeDtypeStruct((n_rows, LANES), jnp.int32),
        interpret=interpret,
    )(acc, q, s, w)


def _int4_fp_kernel(acc_ref, p_ref, s_ref, w_ref, out_ref, *, bits: int):
    w = w_ref[0, 0]
    q = unpack_nibbles(p_ref[...])
    out_ref[...] = acc_ref[...] + fixed_point(w * (q * s_ref[...]), bits)


@functools.partial(jax.jit, static_argnames=("bits", "interpret"))
def dequant_accum_int4_fp_fused(acc, p, s, w, *, bits: int,
                                interpret: bool = False):
    """Deterministic int4 decode-accumulate on the int32 fixed-point
    accumulator (packed-nibble unpack fused in)."""
    n_rows, lanes = acc.shape
    assert lanes == LANES and n_rows % ROWS == 0, (acc.shape,)
    pspec = pl.BlockSpec((ROWS, LANES // 2), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_int4_fp_kernel, bits=bits),
        grid=(n_rows // ROWS,),
        in_specs=[_spec, pspec, _sspec, _wspec],
        out_specs=_spec,
        out_shape=jax.ShapeDtypeStruct((n_rows, LANES), jnp.int32),
        interpret=interpret,
    )(acc, p, s, w)


def _sign_fp_kernel(vote_ref, mag_ref, p_ref, s_ref, w_ref, vout_ref,
                    mout_ref, *, bits: int):
    w = w_ref[0, 0]
    wq = fixed_point(w, bits)               # omega quantised once per hop
    signs = unpack_signs(p_ref[...]).astype(jnp.int32)    # exact ±1
    vout_ref[...] = vote_ref[...] + wq * signs
    mout_ref[...] = mag_ref[...] + fixed_point(w * s_ref[...], bits)


@functools.partial(jax.jit, static_argnames=("bits", "interpret"))
def sign_vote_accum_fp_fused(vote, mag, p, s, w, *, bits: int,
                             interpret: bool = False):
    """Deterministic majority-vote partials: integer vote counts
    (vote int32 += fixed_point(w) * ±1) and fixed-point magnitude
    (mag int32 += fixed_point(w * s)) — both exact and commutative."""
    n_rows, lanes = vote.shape
    assert lanes == LANES and n_rows % ROWS == 0, (vote.shape,)
    pspec = pl.BlockSpec((ROWS, LANES // 8), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_sign_fp_kernel, bits=bits),
        grid=(n_rows // ROWS,),
        in_specs=[_spec, _sspec, pspec, _sspec, _wspec],
        out_specs=[_spec, _sspec],
        out_shape=[jax.ShapeDtypeStruct((n_rows, LANES), jnp.int32),
                   jax.ShapeDtypeStruct((n_rows, 1), jnp.int32)],
        interpret=interpret,
    )(vote, mag, p, s, w)


@functools.partial(jax.jit, static_argnames=("interpret",))
def topk_scatter_accum_fused(acc, q, idx, s, w, *, interpret: bool = False):
    """acc (rows, LANES) f32 += w * scatter(q * s at idx): the top-k
    rung's decode-accumulate.  Indices are distinct per block (top_k), so
    the one-hot accumulation never double-counts a lane."""
    n_rows, lanes = acc.shape
    k = q.shape[1]
    assert lanes == LANES and n_rows % ROWS == 0, (acc.shape,)
    kspec = pl.BlockSpec((ROWS, k), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_topk_kernel, k=k),
        grid=(n_rows // ROWS,),
        in_specs=[_spec, kspec, kspec, _sspec, _wspec],
        out_specs=_spec,
        out_shape=jax.ShapeDtypeStruct((n_rows, LANES), jnp.float32),
        interpret=interpret,
    )(acc, q, idx, s, w)
