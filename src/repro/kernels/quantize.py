"""Pallas TPU kernel: fused blockwise int8 quantisation + dequant residual.

One VMEM pass per (8, 1024) tile: absmax scale per 1024-row-block, int8
cast, and the quantisation residual (for error feedback) — versus three
separate HBM passes in the naive formulation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROWS = 8
LANES = 1024


def _quant_body(x):
    """Shared math (kernel + oracle). x: (rows, LANES) f32."""
    scale = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-30)
    q = jnp.clip(jnp.round(x / scale), -127.0, 127.0)
    return q, scale


def _kernel(x_ref, q_ref, s_ref, r_ref):
    x = x_ref[...].astype(jnp.float32)
    q, scale = _quant_body(x)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale.astype(jnp.float32)
    r_ref[...] = (x - q * scale).astype(r_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def quantize_int8_fused(x, *, interpret: bool = False):
    """x: (n_rows, LANES) f32 -> (q int8, scales (n_rows, 1) f32,
    residual f32)."""
    n_rows, lanes = x.shape
    assert lanes == LANES and n_rows % ROWS == 0, (x.shape,)
    grid = (n_rows // ROWS,)
    spec = pl.BlockSpec((ROWS, LANES), lambda i: (i, 0))
    sspec = pl.BlockSpec((ROWS, 1), lambda i: (i, 0))
    q, s, r = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[spec],
        out_specs=[spec, sspec, spec],
        out_shape=[
            jax.ShapeDtypeStruct((n_rows, LANES), jnp.int8),
            jax.ShapeDtypeStruct((n_rows, 1), jnp.float32),
            jax.ShapeDtypeStruct((n_rows, LANES), jnp.float32),
        ],
        interpret=interpret,
    )(x)
    return q, s, r


def _dequant_kernel(q_ref, s_ref, out_ref):
    out_ref[...] = (q_ref[...].astype(jnp.float32) *
                    s_ref[...].astype(jnp.float32))


@functools.partial(jax.jit, static_argnames=("interpret",))
def dequantize_int8(q, scales, *, interpret: bool = False):
    n_rows, lanes = q.shape
    assert lanes == LANES and n_rows % ROWS == 0
    grid = (n_rows // ROWS,)
    return pl.pallas_call(
        _dequant_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((ROWS, LANES), lambda i: (i, 0)),
                  pl.BlockSpec((ROWS, 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((ROWS, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_rows, LANES), jnp.float32),
        interpret=interpret,
    )(q, scales)
