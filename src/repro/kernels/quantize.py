"""Pallas TPU kernels: fused blockwise quantisation + dequant residual.

One VMEM pass per (8, 1024) tile: absmax scale per 1024-row-block, the
quantised values, and the quantisation residual (for error feedback) —
versus three separate HBM passes in the naive formulation.  Two rungs live
here:

  * int8: absmax/127 scale, one byte per value;
  * int4: absmax/7 scale, two values packed per byte (low nibble first,
    offset-binary q+8), fused with the error-feedback accumulate
    ``ef = g + gamma*e`` so the INT4 sync rung is one HBM pass end-to-end.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.topk_compress import gather_ef_call

ROWS = 8
LANES = 1024


def _quant_body(x):
    """Shared math (kernel + oracle). x: (rows, LANES) f32."""
    scale = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-30)
    q = jnp.clip(jnp.round(x / scale), -127.0, 127.0)
    return q, scale


def _kernel(x_ref, q_ref, s_ref, r_ref):
    x = x_ref[...].astype(jnp.float32)
    q, scale = _quant_body(x)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale.astype(jnp.float32)
    r_ref[...] = (x - q * scale).astype(r_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def quantize_int8_fused(x, *, interpret: bool = False):
    """x: (n_rows, LANES) f32 -> (q int8, scales (n_rows, 1) f32,
    residual f32)."""
    n_rows, lanes = x.shape
    assert lanes == LANES and n_rows % ROWS == 0, (x.shape,)
    grid = (n_rows // ROWS,)
    spec = pl.BlockSpec((ROWS, LANES), lambda i: (i, 0))
    sspec = pl.BlockSpec((ROWS, 1), lambda i: (i, 0))
    q, s, r = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[spec],
        out_specs=[spec, sspec, spec],
        out_shape=[
            jax.ShapeDtypeStruct((n_rows, LANES), jnp.int8),
            jax.ShapeDtypeStruct((n_rows, 1), jnp.float32),
            jax.ShapeDtypeStruct((n_rows, LANES), jnp.float32),
        ],
        interpret=interpret,
    )(x)
    return q, s, r


@functools.partial(jax.jit, static_argnames=("gamma", "rows", "interpret"))
def quantize_int8_gather(fb, eb, perm, *, gamma: float, rows: int = 1,
                         interpret: bool = False):
    """Producer-fused gather + EF + int8 quantise: the rung's rows are
    read straight out of the (NB+1, LANES) grad / error buffers through
    ``perm`` — the gathered bucket never materialises in HBM.  Returns
    (q (S, LANES) int8, scales (S, 1) f32, residual (S, LANES) f32),
    per-row bit-exact to :func:`quantize_int8_fused` on ``ef``."""

    def body(g, e):
        ef = g.astype(jnp.float32) + gamma * e.astype(jnp.float32)
        q, scale = _quant_body(ef)
        return q, scale, ef - q * scale

    out_defs = [(LANES, jnp.int8), (1, jnp.float32), (LANES, jnp.float32)]
    return gather_ef_call(body, fb, eb, perm, out_defs, rows=rows,
                          interpret=interpret)


# ---------------------------------------------------------------------------
# int4: two nibbles per byte, blockwise absmax scale, fused error feedback
# ---------------------------------------------------------------------------


def _int4_body(x):
    """Shared math (kernel + oracle). x: (rows, LANES) f32."""
    scale = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 7.0
    scale = jnp.maximum(scale, 1e-30)
    q = jnp.clip(jnp.round(x / scale), -7.0, 7.0)
    return q, scale


def pack_nibbles(q):
    """(rows, C) f32 in [-7, 7] -> (rows, C // 2) uint8 (offset binary
    q+8; even column in the low nibble)."""
    u = (q + 8.0).astype(jnp.uint8)
    u3 = u.reshape(q.shape[0], q.shape[1] // 2, 2)
    return u3[..., 0] | (u3[..., 1] << 4)


def unpack_nibbles(packed):
    """Inverse of :func:`pack_nibbles` -> (rows, 2 * C') f32."""
    lo = (packed & 0xF).astype(jnp.float32) - 8.0
    hi = (packed >> 4).astype(jnp.float32) - 8.0
    return jnp.stack([lo, hi], axis=-1).reshape(packed.shape[0],
                                                packed.shape[1] * 2)


def _int4_kernel(g_ref, e_ref, p_ref, s_ref, r_ref, *, gamma: float):
    g = g_ref[...].astype(jnp.float32)
    e = e_ref[...].astype(jnp.float32)
    ef = g + gamma * e
    q, scale = _int4_body(ef)
    p_ref[...] = pack_nibbles(q)
    s_ref[...] = scale.astype(jnp.float32)
    r_ref[...] = (ef - q * scale).astype(r_ref.dtype)


@functools.partial(jax.jit, static_argnames=("gamma", "interpret"))
def ef_int4_fused(g, e, *, gamma: float, interpret: bool = False):
    """g, e: (n_rows, LANES) f32 -> (packed uint8 (n_rows, LANES//2),
    scales (n_rows, 1) f32, residual f32) with ef = g + gamma*e fused in."""
    n_rows, lanes = g.shape
    assert lanes == LANES and n_rows % ROWS == 0, (g.shape,)
    grid = (n_rows // ROWS,)
    spec = pl.BlockSpec((ROWS, LANES), lambda i: (i, 0))
    pspec = pl.BlockSpec((ROWS, LANES // 2), lambda i: (i, 0))
    sspec = pl.BlockSpec((ROWS, 1), lambda i: (i, 0))
    p, s, r = pl.pallas_call(
        functools.partial(_int4_kernel, gamma=gamma),
        grid=grid,
        in_specs=[spec, spec],
        out_specs=[pspec, sspec, spec],
        out_shape=[
            jax.ShapeDtypeStruct((n_rows, LANES // 2), jnp.uint8),
            jax.ShapeDtypeStruct((n_rows, 1), jnp.float32),
            jax.ShapeDtypeStruct((n_rows, LANES), jnp.float32),
        ],
        interpret=interpret,
    )(g, e)
    return p, s, r


@functools.partial(jax.jit, static_argnames=("gamma", "rows", "interpret"))
def ef_int4_gather(fb, eb, perm, *, gamma: float, rows: int = 1,
                   interpret: bool = False):
    """Producer-fused gather + EF + packed-int4 quantise through ``perm``.
    Returns (packed (S, LANES//2) uint8, scales (S, 1) f32, residual
    (S, LANES) f32), per-row bit-exact to :func:`ef_int4_fused`."""

    def body(g, e):
        ef = g.astype(jnp.float32) + gamma * e.astype(jnp.float32)
        q, scale = _int4_body(ef)
        return pack_nibbles(q), scale, ef - q * scale

    out_defs = [(LANES // 2, jnp.uint8), (1, jnp.float32),
                (LANES, jnp.float32)]
    return gather_ef_call(body, fb, eb, perm, out_defs, rows=rows,
                          interpret=interpret)


def _dequant_kernel(q_ref, s_ref, out_ref):
    out_ref[...] = (q_ref[...].astype(jnp.float32) *
                    s_ref[...].astype(jnp.float32))


@functools.partial(jax.jit, static_argnames=("interpret",))
def dequantize_int8(q, scales, *, interpret: bool = False):
    n_rows, lanes = q.shape
    assert lanes == LANES and n_rows % ROWS == 0
    grid = (n_rows // ROWS,)
    return pl.pallas_call(
        _dequant_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((ROWS, LANES), lambda i: (i, 0)),
                  pl.BlockSpec((ROWS, 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((ROWS, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_rows, LANES), jnp.float32),
        interpret=interpret,
    )(q, scales)
