"""Disk-cached block-size autotuning for the fused gather+encode kernels.

The gather kernels (``quantize_int8_gather`` / ``ef_int4_gather`` /
``ef_sign_gather`` / ``ef_topk_gather``) tile the gathered bucket
rows-per-grid-step.  The best tile height trades scalar-prefetch index-map
gathers (``rows == 1``: Pallas pipelines one (1, LANES) row per step
straight out of HBM) against in-kernel dynamic-slice gathers
(``rows > 1``: fewer grid steps, more work per step) — which side wins
depends on the codec's arithmetic intensity, the bucket's row count and
the backend generation, so it is MEASURED once per
``(codec, size-class, backend)`` and remembered:

  * in-process: a plain dict memo (the sync path asks per rung per trace);
  * across processes: a JSON file at ``$REPRO_AUTOTUNE_CACHE`` (default
    ``~/.cache/repro/autotune.json``) keyed
    ``codec|size-class|backend|jax-version``.  A backend or jax upgrade
    changes the key, so stale tunings are simply never read again — no
    explicit invalidation pass.  Buckets within 2x of each other share a
    power-of-two size class (:func:`sig_class`), so a replan that grows a
    rung re-uses the neighbouring tuning instead of re-benchmarking.

Interpret mode (CPU backend, or ``REPRO_FORCE_INTERPRET=1``) ALWAYS
returns :data:`DEFAULT_ROWS` and never reads or writes the cache file:
interpreted timings are meaningless, and CI runs must stay
byte-deterministic with no filesystem side effects
(tests/test_kernels.py pins the no-write contract).
"""
from __future__ import annotations

import json
import os

import jax

#: deterministic fallback tile height (also the interpret-mode choice).
DEFAULT_ROWS = 1
#: tile heights the measurement sweeps (divisors of the kernel ROWS=8).
ROW_CANDIDATES = (1, 2, 4, 8)
CACHE_ENV = "REPRO_AUTOTUNE_CACHE"

_MEM: dict = {}


def cache_path() -> str:
    """Where the cross-process tuning cache lives."""
    p = os.environ.get(CACHE_ENV)
    if p:
        return p
    return os.path.join(os.path.expanduser("~"), ".cache", "repro",
                        "autotune.json")


def sig_class(n_rows: int) -> int:
    """Power-of-two size class: buckets within 2x share one tuning."""
    c = 1
    while c < n_rows:
        c *= 2
    return c


def _key(codec: str, n_rows: int, backend: str) -> str:
    return f"{codec}|{sig_class(n_rows)}|{backend}|{jax.__version__}"


def _load() -> dict:
    try:
        with open(cache_path()) as f:
            d = json.load(f)
        return d if isinstance(d, dict) else {}
    except (OSError, ValueError):
        return {}


def _store(key: str, rows: int) -> None:
    path = cache_path()
    try:
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        disk = _load()
        disk[key] = rows
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(disk, f, indent=0, sort_keys=True)
        os.replace(tmp, path)
    except OSError:
        pass  # the cache is best-effort; the tuning still holds in-process


def clear_memo() -> None:
    """Drop the in-process memo (tests)."""
    _MEM.clear()


def _measure(bench, n_rows: int) -> int:
    best, best_t = DEFAULT_ROWS, None
    for rows in ROW_CANDIDATES:
        if rows > max(1, n_rows):
            break
        try:
            t = bench(rows)
        except Exception:
            continue  # a candidate that fails to lower just loses
        if best_t is None or t < best_t:
            best, best_t = rows, t
    return best


def block_rows(codec: str, n_rows: int, bench=None) -> int:
    """Rows-per-grid-step for ``codec``'s gather kernel on an
    ``n_rows``-row bucket.

    ``bench(rows) -> seconds`` wall-times one candidate on the live
    backend (the caller builds it against representative shapes; see
    ``repro.kernels.ops._gather_bench``).  ``bench=None`` resolves from
    the caches only, falling back to :data:`DEFAULT_ROWS` — measured
    results are only ever written to disk when a measurement actually
    ran, so a cache-miss lookup never pollutes the file with defaults.
    """
    from repro.kernels import ops
    if ops.interpret_mode():
        return DEFAULT_ROWS
    backend = jax.default_backend()
    key = _key(codec, n_rows, backend)
    rows = _MEM.get(key)
    if rows is not None:
        return rows
    disk = _load().get(key)
    if disk is not None:
        try:
            rows = int(disk)
        except (TypeError, ValueError):
            rows = None
        if rows in ROW_CANDIDATES:
            _MEM[key] = rows
            return rows
    rows = DEFAULT_ROWS if bench is None else _measure(bench, n_rows)
    _MEM[key] = rows
    if bench is not None:
        _store(key, rows)
    return rows
