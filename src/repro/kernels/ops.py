"""Public jit'd wrappers for the compression kernels.

On TPU these dispatch to the compiled Pallas kernels; on CPU (this
container, and any unit-test environment) they run the same kernel bodies
under ``interpret=True``.  ``use_pallas=False`` falls back to the pure-jnp
oracle — the path the CPU dry-run lowers, keeping kernel code out of the
roofline HLO while the math stays identical.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.topk_compress import ef_topk_select, LANES, ROWS
from repro.kernels.quantize import quantize_int8_fused, dequantize_int8


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def pad_rows(flat: jax.Array):
    """(n,) -> (rows, LANES) padded to a ROWS multiple."""
    n = flat.shape[0]
    per = ROWS * LANES
    nb = (n + per - 1) // per
    pad = nb * per - n
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    return flat.reshape(nb * ROWS, LANES), n


def ef_topk(g_flat, e_flat, *, gamma: float, k: int, use_pallas: bool = True):
    """Fused error-feedback + block top-k on flat arrays.
    Returns (selected_dense (n,), residual (n,))."""
    g2, n = pad_rows(g_flat.astype(jnp.float32))
    e2, _ = pad_rows(e_flat.astype(jnp.float32))
    if use_pallas:
        sel, res = ef_topk_select(g2, e2, gamma=gamma, k=k,
                                  interpret=_on_cpu())
    else:
        sel, res = ref.ef_topk_select_ref(g2, e2, gamma=gamma, k=k)
    return sel.reshape(-1)[:n], res.reshape(-1)[:n]


def quantize_int8(x_flat, *, use_pallas: bool = True):
    """Returns (q (rows, LANES) int8, scales (rows,1) f32, residual (n,),
    n)."""
    x2, n = pad_rows(x_flat.astype(jnp.float32))
    if use_pallas:
        q, s, r = quantize_int8_fused(x2, interpret=_on_cpu())
    else:
        q, s, r = ref.quantize_int8_ref(x2)
    return q, s, r.reshape(-1)[:n], n


def dequant_int8(q, scales, n, *, use_pallas: bool = True):
    if use_pallas:
        out = dequantize_int8(q, scales, interpret=_on_cpu())
    else:
        out = ref.dequantize_int8_ref(q, scales)
    return out.reshape(-1)[:n]
