"""Public jit'd wrappers for the compression kernels.

On TPU these dispatch to the compiled Pallas kernels; on CPU (this
container, and any unit-test environment) they run the same kernel bodies
under ``interpret=True``.  ``use_pallas=False`` falls back to the pure-jnp
oracle — the path the CPU dry-run lowers, keeping kernel code out of the
roofline HLO while the math stays identical.

Backend dispatch is decided ONCE per process (the sync hot loop calls
these per bucket per step; re-querying ``jax.default_backend()`` on every
call was measurable on the host-side trace).  Two cached predicates:

  * :func:`interpret_mode` — should ``pallas_call`` interpret?  True on
    CPU, False on accelerators; ``REPRO_FORCE_INTERPRET=1`` forces True
    (CI runs the kernel bodies even on CPU runners), ``=0`` forces False.
  * :func:`default_use_pallas` — should the sync path route through the
    kernels at all?  True on accelerators (the fused path is the one
    ``grad_sync`` / ``delta_sync`` exercise there); False on CPU where the
    interpreted kernels would only slow the oracle math down — unless
    ``REPRO_FORCE_INTERPRET=1`` opts CI into the kernel path.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels import autotune, ref
from repro.kernels.topk_compress import (ef_topk_gather, ef_topk_select,
                                         LANES, ROWS)
from repro.kernels.decode import (dequant_accum_int4_fp_fused,
                                  dequant_accum_int4_fused,
                                  dequant_accum_int8_fp_fused,
                                  dequant_accum_int8_fused,
                                  sign_vote_accum_fp_fused,
                                  sign_vote_accum_fused,
                                  topk_scatter_accum_fused)
from repro.kernels.quantize import (quantize_int8_fused, dequantize_int8,
                                    ef_int4_fused, ef_int4_gather,
                                    quantize_int8_gather)
from repro.kernels.sign import ef_sign_fused, ef_sign_gather

FORCE_INTERPRET_ENV = "REPRO_FORCE_INTERPRET"


def _env_force():
    v = os.environ.get(FORCE_INTERPRET_ENV)
    if v is None:
        return None
    return v.strip().lower() not in ("", "0", "false", "no")


@functools.lru_cache(maxsize=None)
def interpret_mode() -> bool:
    """Whether pallas_call should run interpreted (cached per process)."""
    forced = _env_force()
    if forced is not None:
        return forced
    return jax.default_backend() == "cpu"


@functools.lru_cache(maxsize=None)
def default_use_pallas() -> bool:
    """Default ``use_pallas`` for the sync hot path (cached per process):
    compiled kernels on accelerators, oracle math on CPU.
    ``REPRO_FORCE_INTERPRET=1`` additionally opts CPU/CI into the
    (interpreted) kernel path; ``=0`` only disables interpretation and
    never turns the compiled kernels off on accelerators."""
    if _env_force():
        return True
    return jax.default_backend() != "cpu"


def _on_cpu() -> bool:  # kept for external callers; now cached
    return interpret_mode()


def pad_rows(flat: jax.Array):
    """(n,) -> (rows, LANES) padded to a ROWS multiple."""
    n = flat.shape[0]
    per = ROWS * LANES
    nb = (n + per - 1) // per
    pad = nb * per - n
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    return flat.reshape(nb * ROWS, LANES), n


def ef_topk(g_flat, e_flat, *, gamma: float, k: int, use_pallas: bool = True):
    """Fused error-feedback + block top-k on flat arrays.
    Returns (selected_dense (n,), residual (n,))."""
    g2, n = pad_rows(g_flat.astype(jnp.float32))
    e2, _ = pad_rows(e_flat.astype(jnp.float32))
    if use_pallas:
        sel, res = ef_topk_select(g2, e2, gamma=gamma, k=k,
                                  interpret=interpret_mode())
    else:
        sel, res = ref.ef_topk_select_ref(g2, e2, gamma=gamma, k=k)
    return sel.reshape(-1)[:n], res.reshape(-1)[:n]


def quantize_int8(x_flat, *, use_pallas: bool = True):
    """Returns (q (rows, LANES) int8, scales (rows,1) f32, residual (n,),
    n)."""
    x2, n = pad_rows(x_flat.astype(jnp.float32))
    if use_pallas:
        q, s, r = quantize_int8_fused(x2, interpret=interpret_mode())
    else:
        q, s, r = ref.quantize_int8_ref(x2)
    return q, s, r.reshape(-1)[:n], n


def dequant_int8(q, scales, n, *, use_pallas: bool = True):
    if use_pallas:
        out = dequantize_int8(q, scales, interpret=interpret_mode())
    else:
        out = ref.dequantize_int8_ref(q, scales)
    return out.reshape(-1)[:n]


def ef_int4(g_flat, e_flat, *, gamma: float, use_pallas: bool = True):
    """Fused error-feedback + packed-int4 quantisation on flat arrays.
    Returns (packed uint8 (rows, LANES//2), scales (rows, 1) f32,
    residual (n,), n)."""
    g2, n = pad_rows(g_flat.astype(jnp.float32))
    e2, _ = pad_rows(e_flat.astype(jnp.float32))
    if use_pallas:
        p, s, r = ef_int4_fused(g2, e2, gamma=gamma,
                                interpret=interpret_mode())
    else:
        p, s, r = ref.ef_int4_ref(g2, e2, gamma=gamma)
    return p, s, r.reshape(-1)[:n], n


def _pad_rows2(a, rows, fill=0):
    """Pad dim 0 of ``a`` up to ``rows`` (kernel tiles want ROWS
    multiples; the pad rows carry zero payload and are sliced off)."""
    if a.shape[0] == rows:
        return a
    pad = [(0, rows - a.shape[0])] + [(0, 0)] * (a.ndim - 1)
    return jnp.pad(a, pad, constant_values=fill)


def _w2(w):
    return jnp.asarray(w, jnp.float32).reshape(1, 1)


def decode_accum_int8(acc, q, s, w, *, use_pallas: bool = True,
                      fixed_bits=None):
    """acc (nb, LANES) f32 += w * (q * s) fused — the int8 rung's ring
    decode-accumulate.  ``s``: (nb,) f32 per-block scales.
    ``fixed_bits`` set -> the deterministic variant on the int32
    fixed-point accumulator (see kernels/decode.py)."""
    nb = acc.shape[0]
    rows = ((nb + ROWS - 1) // ROWS) * ROWS
    args = (_pad_rows2(acc, rows), _pad_rows2(q, rows),
            _pad_rows2(s.reshape(-1, 1), rows), _w2(w))
    if fixed_bits is not None:
        if use_pallas:
            out = dequant_accum_int8_fp_fused(*args, bits=int(fixed_bits),
                                              interpret=interpret_mode())
        else:
            out = ref.dequant_accum_int8_fp_ref(*args, int(fixed_bits))
    elif use_pallas:
        out = dequant_accum_int8_fused(*args, interpret=interpret_mode())
    else:
        out = ref.dequant_accum_int8_ref(*args)
    return out[:nb]


def decode_accum_int4(acc, p, s, w, *, use_pallas: bool = True,
                      fixed_bits=None):
    """acc (nb, LANES) f32 += w * dequant(p packed nibbles, s) fused.
    ``fixed_bits`` set -> deterministic int32 fixed-point accumulate."""
    nb = acc.shape[0]
    rows = ((nb + ROWS - 1) // ROWS) * ROWS
    args = (_pad_rows2(acc, rows), _pad_rows2(p, rows),
            _pad_rows2(s.reshape(-1, 1), rows), _w2(w))
    if fixed_bits is not None:
        if use_pallas:
            out = dequant_accum_int4_fp_fused(*args, bits=int(fixed_bits),
                                              interpret=interpret_mode())
        else:
            out = ref.dequant_accum_int4_fp_ref(*args, int(fixed_bits))
    elif use_pallas:
        out = dequant_accum_int4_fused(*args, interpret=interpret_mode())
    else:
        out = ref.dequant_accum_int4_ref(*args)
    return out[:nb]


def sign_vote_accum(vote, mag, p, s, w, *, use_pallas: bool = True,
                    fixed_bits=None):
    """Majority-vote partials: vote (nb, LANES) += w * unpacked signs,
    mag (nb,) += w * s, fused.  ``fixed_bits`` set -> integer vote counts
    + fixed-point magnitude (deterministic, fold-order insensitive)."""
    nb = vote.shape[0]
    rows = ((nb + ROWS - 1) // ROWS) * ROWS
    args = (_pad_rows2(vote, rows), _pad_rows2(mag.reshape(-1, 1), rows),
            _pad_rows2(p, rows), _pad_rows2(s.reshape(-1, 1), rows),
            _w2(w))
    if fixed_bits is not None:
        if use_pallas:
            v, m = sign_vote_accum_fp_fused(*args, bits=int(fixed_bits),
                                            interpret=interpret_mode())
        else:
            v, m = ref.sign_vote_accum_fp_ref(*args, int(fixed_bits))
    elif use_pallas:
        v, m = sign_vote_accum_fused(*args, interpret=interpret_mode())
    else:
        v, m = ref.sign_vote_accum_ref(*args)
    return v[:nb], m[:nb].reshape(-1)


def topk_scatter_accum(acc, q, idx, s, w, *, use_pallas: bool = True):
    """acc (nb, LANES) += w * scatter(q * s at idx) fused — the top-k
    rung's ring decode-accumulate."""
    nb = acc.shape[0]
    rows = ((nb + ROWS - 1) // ROWS) * ROWS
    args = (_pad_rows2(acc, rows), _pad_rows2(q, rows),
            _pad_rows2(idx, rows), _pad_rows2(s.reshape(-1, 1), rows),
            _w2(w))
    if use_pallas:
        out = topk_scatter_accum_fused(*args, interpret=interpret_mode())
    else:
        out = ref.topk_scatter_accum_ref(args[0], args[1], args[2],
                                         args[3], args[4])
    return out[:nb]


def ef_sign(g_flat, e_flat, *, gamma: float, use_pallas: bool = True):
    """Fused error-feedback + 1-bit sign compression on flat arrays.
    Returns (sign int8 (rows, LANES), scales (rows, 1) f32, residual (n,),
    n)."""
    g2, n = pad_rows(g_flat.astype(jnp.float32))
    e2, _ = pad_rows(e_flat.astype(jnp.float32))
    if use_pallas:
        sg, s, r = ef_sign_fused(g2, e2, gamma=gamma,
                                 interpret=interpret_mode())
    else:
        sg, s, r = ref.ef_sign_ref(g2, e2, gamma=gamma)
    return sg, s, r.reshape(-1)[:n], n


# ---------------------------------------------------------------------------
# producer-fused gather + encode (the backward-streaming sync hot path)
# ---------------------------------------------------------------------------
# These read a rung's rows straight out of the packed (NB+1, LANES)
# grad / error buffers through the plan's gather perm — the gathered
# bucket never materialises between the backward pass and the encode.
# The rows-per-grid-step tile height comes from the autotune cache
# (repro/kernels/autotune.py), measured once per (codec, size-class,
# backend); interpret mode always takes the deterministic default and
# never touches the cache file.


def _pad_perm(perm, rows: int, zero_idx: int):
    """Pad the gather perm to a ``rows`` multiple with the zero-row
    index (padded tail rows encode zeros and are sliced off)."""
    S = perm.shape[0]
    pad = (-S) % rows
    if pad:
        perm = jnp.concatenate(
            [perm, jnp.full((pad,), zero_idx, perm.dtype)])
    return perm, S


def _gather_bench(kern, nbp1: int, S: int, **kw):
    """Autotune measurement closure: wall-time ``kern`` at a candidate
    tile height on representative synthetic shapes.  Runs EAGERLY on
    the live backend (only ever invoked outside interpret mode — on
    accelerators, where the compiled kernels are real)."""
    import time

    def bench(rows: int) -> float:
        fb = jax.random.normal(jax.random.PRNGKey(0), (nbp1, LANES),
                               jnp.float32)
        eb = fb * 0.5
        sp = ((S + rows - 1) // rows) * rows
        perm = (jnp.arange(sp, dtype=jnp.int32) % max(1, nbp1 - 1))
        out = kern(fb, eb, perm, rows=rows, **kw)   # compile + warm
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        for _ in range(3):
            out = kern(fb, eb, perm, rows=rows, **kw)
        jax.block_until_ready(out)
        return (time.perf_counter() - t0) / 3

    return bench


def _gather_rows(codec: str, kern, fb, perm, **kw) -> int:
    bench = None
    if not interpret_mode():
        bench = _gather_bench(kern, int(fb.shape[0]), int(perm.shape[0]),
                              **kw)
    return autotune.block_rows(codec, int(perm.shape[0]), bench=bench)


def gather_ef_int8(fb, eb, perm, *, gamma: float, use_pallas: bool = True):
    """Fused gather + EF + int8 encode of one rung's rows.
    Returns (q (S, LANES) int8, scales (S, 1) f32, residual (S*LANES,))."""
    if not use_pallas:
        q, s, r = ref.quantize_int8_gather_ref(fb, eb, perm, gamma=gamma)
        return q, s, r.reshape(-1)
    rows = _gather_rows("int8", quantize_int8_gather, fb, perm,
                        gamma=gamma, interpret=False)
    p2, S = _pad_perm(perm, rows, fb.shape[0] - 1)
    q, s, r = quantize_int8_gather(fb, eb, p2, gamma=gamma, rows=rows,
                                   interpret=interpret_mode())
    return q[:S], s[:S], r[:S].reshape(-1)


def gather_ef_int4(fb, eb, perm, *, gamma: float, use_pallas: bool = True):
    """Fused gather + EF + packed-int4 encode of one rung's rows.
    Returns (packed (S, LANES//2) uint8, scales (S, 1) f32,
    residual (S*LANES,))."""
    if not use_pallas:
        p, s, r = ref.ef_int4_gather_ref(fb, eb, perm, gamma=gamma)
        return p, s, r.reshape(-1)
    rows = _gather_rows("int4", ef_int4_gather, fb, perm,
                        gamma=gamma, interpret=False)
    p2, S = _pad_perm(perm, rows, fb.shape[0] - 1)
    p, s, r = ef_int4_gather(fb, eb, p2, gamma=gamma, rows=rows,
                             interpret=interpret_mode())
    return p[:S], s[:S], r[:S].reshape(-1)


def gather_ef_sign(fb, eb, perm, *, gamma: float, use_pallas: bool = True):
    """Fused gather + EF + 1-bit sign encode of one rung's rows.
    Returns (sign (S, LANES) int8, scales (S, 1) f32,
    residual (S*LANES,))."""
    if not use_pallas:
        sg, s, r = ref.ef_sign_gather_ref(fb, eb, perm, gamma=gamma)
        return sg, s, r.reshape(-1)
    rows = _gather_rows("sign", ef_sign_gather, fb, perm,
                        gamma=gamma, interpret=False)
    p2, S = _pad_perm(perm, rows, fb.shape[0] - 1)
    sg, s, r = ef_sign_gather(fb, eb, p2, gamma=gamma, rows=rows,
                              interpret=interpret_mode())
    return sg[:S], s[:S], r[:S].reshape(-1)


def gather_ef_topk(fb, eb, perm, *, gamma: float, k: int,
                   use_pallas: bool = True):
    """Fused gather + EF + block top-k selection of one rung's rows.
    Returns (selected_dense (S, LANES) f32, residual (S*LANES,))."""
    if not use_pallas:
        sel, res = ref.ef_topk_gather_ref(fb, eb, perm, gamma=gamma, k=k)
        return sel, res.reshape(-1)
    rows = _gather_rows("topk", ef_topk_gather, fb, perm,
                        gamma=gamma, k=k, interpret=False)
    p2, S = _pad_perm(perm, rows, fb.shape[0] - 1)
    sel, res = ef_topk_gather(fb, eb, p2, gamma=gamma, k=k, rows=rows,
                              interpret=interpret_mode())
    return sel[:S], res[:S].reshape(-1)
