"""Pallas TPU kernel: fused error-feedback + 1-bit sign compression.

The sign-with-majority-vote rung (signSGD / "When Less is More") transmits
one bit per entry plus a per-1024-block magnitude ``scale = mean(|ef|)``.
This kernel fuses the HBM-heavy part into one VMEM pass per (8, 1024)
tile:

    ef       = g + gamma * e
    sign     = +1 where ef >= 0 else -1      (int8, one per entry)
    scale    = mean(|ef|) per 1024-block
    residual = ef - sign * scale             (next error-feedback buffer)

The 8-entries-per-byte bit packing happens OUTSIDE the kernel (jnp, in
repro/codecs/builtin.py): it runs on the 8x-smaller int8 sign tensor, so
it is not HBM-bound, and keeping sub-byte shuffles out of Mosaic keeps the
kernel portable across TPU generations.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.topk_compress import LANES, ROWS, gather_ef_call


def _sign_body(x):
    """Shared math (kernel + oracle). x: (rows, LANES) f32."""
    scale = jnp.mean(jnp.abs(x), axis=-1, keepdims=True)
    sign = jnp.where(x >= 0, 1.0, -1.0)
    return sign, scale


def _kernel(g_ref, e_ref, sign_ref, s_ref, r_ref, *, gamma: float):
    g = g_ref[...].astype(jnp.float32)
    e = e_ref[...].astype(jnp.float32)
    ef = g + gamma * e
    sign, scale = _sign_body(ef)
    sign_ref[...] = sign.astype(jnp.int8)
    s_ref[...] = scale.astype(jnp.float32)
    r_ref[...] = (ef - sign * scale).astype(r_ref.dtype)


@functools.partial(jax.jit, static_argnames=("gamma", "interpret"))
def ef_sign_fused(g, e, *, gamma: float, interpret: bool = False):
    """g, e: (n_rows, LANES) f32 — n_rows % ROWS == 0.
    Returns (sign int8 (n_rows, LANES), scales (n_rows, 1) f32,
    residual f32)."""
    n_rows, lanes = g.shape
    assert lanes == LANES and n_rows % ROWS == 0, (g.shape,)
    grid = (n_rows // ROWS,)
    spec = pl.BlockSpec((ROWS, LANES), lambda i: (i, 0))
    sspec = pl.BlockSpec((ROWS, 1), lambda i: (i, 0))
    sign, s, r = pl.pallas_call(
        functools.partial(_kernel, gamma=gamma),
        grid=grid,
        in_specs=[spec, spec],
        out_specs=[spec, sspec, spec],
        out_shape=[
            jax.ShapeDtypeStruct((n_rows, LANES), jnp.int8),
            jax.ShapeDtypeStruct((n_rows, 1), jnp.float32),
            jax.ShapeDtypeStruct((n_rows, LANES), jnp.float32),
        ],
        interpret=interpret,
    )(g, e)
    return sign, s, r


@functools.partial(jax.jit, static_argnames=("gamma", "rows", "interpret"))
def ef_sign_gather(fb, eb, perm, *, gamma: float, rows: int = 1,
                   interpret: bool = False):
    """Producer-fused gather + EF + 1-bit sign compression through
    ``perm``.  Returns (sign (S, LANES) int8, scales (S, 1) f32,
    residual (S, LANES) f32), per-row bit-exact to
    :func:`ef_sign_fused`."""

    def body(g, e):
        ef = g.astype(jnp.float32) + gamma * e.astype(jnp.float32)
        sign, scale = _sign_body(ef)
        return sign, scale, ef - sign * scale

    out_defs = [(LANES, jnp.int8), (1, jnp.float32), (LANES, jnp.float32)]
    return gather_ef_call(body, fb, eb, perm, out_defs, rows=rows,
                          interpret=interpret)
