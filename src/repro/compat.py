"""Version-compatibility shims over the moving jax API surface.

The repo targets the modern ``jax.shard_map(..., axis_names=, check_vma=)``
entry point; older installs (jax < 0.5) only ship
``jax.experimental.shard_map.shard_map(..., auto=, check_rep=)`` and have
no ``jax.sharding.AxisType``.  Everything feature-detects — no version
string parsing.
"""
from __future__ import annotations

import jax

#: modern jax supports partial-manual shard_map (auto axes) under which
#: lax.scan / remat lower fine; the old experimental shard_map hits XLA
#: CHECK failures (hlo_sharding_util manual-subgroup) for scan bodies in
#: mixed manual/auto regions — there we fall back to fully-manual regions
#: with replicated compute over the would-be-auto axes.
PARTIAL_MANUAL = hasattr(jax, "shard_map")


def manual_axes_for(mesh, requested):
    """The axis set to mark manual: ``requested`` on modern jax, every
    mesh axis on old jax (see PARTIAL_MANUAL)."""
    return set(requested) if PARTIAL_MANUAL else set(mesh.axis_names)


def shard_map(fn, mesh, *, in_specs, out_specs, manual_axes,
              infer_mesh: bool = False):
    """Partial-manual shard_map over ``manual_axes`` of ``mesh``.

    ``infer_mesh``: the call site sits inside an enclosing manual region
    and (on modern jax) should pick up the context mesh instead of binding
    ``mesh`` explicitly.  Old jax cannot infer — there the physical mesh is
    always passed and the already-manual axes land in ``auto``.
    """
    manual = set(manual_axes)
    if hasattr(jax, "shard_map"):
        kw = dict(in_specs=in_specs, out_specs=out_specs,
                  axis_names=manual, check_vma=False)
        if not infer_mesh:
            kw["mesh"] = mesh
        return jax.shard_map(fn, **kw)
    from jax.experimental.shard_map import shard_map as _shard_map
    auto = frozenset(mesh.axis_names) - manual
    return _shard_map(fn, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_rep=False, auto=auto)
