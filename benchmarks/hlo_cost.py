"""Compat shim: the HLO walker moved into the library at
``repro.analysis.hlo`` (it now also feeds the graph auditor — see
``repro.analysis``).  All public names — and the underscore parsers the
tests exercise — keep importing from here."""
from repro.analysis.hlo import *  # noqa: F401,F403
from repro.analysis.hlo import (  # noqa: F401
    _parse_op_line,
    _parse_replica_groups,
    _parse_source_target_pairs,
)
