"""Paper Table 1 reproduction: Final performance comparison across methods.

Runs the paper's experiment at container scale: the paper-350m architecture
(reduced width on CPU) trained with the four strategies — FullSync, Top-k
Sparsification, FedAvg-Periodic Sync, ACE-Sync — under the paper's
cloud-edge telemetry model (64 edge devices, 5-200 Mbps), tracking

  * communication cost (GB transmitted over the bandwidth-constrained tier,
    from the exact wire format of each sync round),
  * final loss / perplexity on a held-out split,
  * convergence step (first step within 1% of final loss).

The paper's own numbers are printed alongside for reference.
"""
from __future__ import annotations

import json
import math
import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "..", "src"))

import jax  # noqa: E402

from repro.configs import SMOKE_ARCHS  # noqa: E402
from repro.configs.base import ACESyncConfig, RunConfig, ShapeConfig  # noqa
from repro.core.trainer import Trainer  # noqa: E402
from repro.data.pipeline import TokenPipeline  # noqa: E402
from repro.data.telemetry import make_profiles, bandwidth_at  # noqa: E402
from repro.models.registry import build_model  # noqa: E402
from repro.strategies import SYNC_KINDS, build_strategy  # noqa: E402

PAPER_TABLE1 = {
    "FullSync": dict(top1=82.4, ppl=18.7, comm_gb=112.5, epochs=41),
    "Top-k Sparsification": dict(top1=80.1, ppl=20.3, comm_gb=68.4,
                                 epochs=45),
    "FedAvg-Periodic Sync": dict(top1=78.9, ppl=21.6, comm_gb=52.1,
                                 epochs=47),
    "ACE-Sync (Proposed)": dict(top1=82.1, ppl=18.9, comm_gb=44.7,
                                epochs=39),
}

STRATS = [("fullsync", "FullSync"),
          ("topk", "Top-k Sparsification"),
          ("fedavg", "FedAvg-Periodic Sync"),
          ("acesync", "ACE-Sync (Proposed)")]


def run_strategy(strategy: str, steps: int, seed: int = 0,
                 eval_batches: int = 4):
    cfg = SMOKE_ARCHS["paper-350m"]
    shape = ShapeConfig("t1", 128, 8, "train")
    run = RunConfig(model=cfg, shape=shape, total_steps=steps,
                    warmup_steps=max(2, steps // 20), lr=2e-3,
                    acesync=ACESyncConfig(replan_every=20,
                                          sync_interval_init=4,
                                          beta=0.015))
    model = build_model(cfg, run)
    strat = build_strategy(strategy)
    trainer = Trainer(model, run, mesh=None, strategy=strat)
    state = trainer.init_state(jax.random.PRNGKey(seed))
    pipe = TokenPipeline(model, shape, seed=seed)
    eval_pipe = TokenPipeline(model, shape, seed=seed + 777)
    eval_set = [next(eval_pipe) for _ in range(eval_batches)]
    profiles = make_profiles(64, seed)
    sched = trainer.scheduler
    # Comm accounting follows the paper's STAR topology: each edge device
    # uploads its compressed payload to the cloud and downloads the
    # aggregated update — per-device volume == peer-pair (n=2) pricing;
    # aggregate GB = per-device x 64 edge devices.
    N_EDGE_AGG = 64

    losses, comm_bytes = [], 0.0
    # benchmark harness choice (matches the seed experiment): H-windowed
    # scheduling only for the periodic-averaging regime; the grad-sync
    # strategies (incl. ACE-Sync) are measured in their per-step sync mode
    H = (strat.initial_interval(run.acesync)
         if getattr(strat, "sync_kind", None) == "param_avg" else 1)
    eval_fn = jax.jit(model.loss)
    local_since = 0
    for t in range(steps):
        bw = float(np.median([bandwidth_at(p, t, seed)
                              for p in profiles]))
        imp = None
        if strat.uses_importance:
            from repro.core import acesync as A
            imp = np.asarray(jax.device_get(A.current_scores(
                jax.tree.map(lambda x: x[0], state["ace"]),
                run.acesync))).tolist()
        plan = strat.make_plan(sched, importance=imp,
                               telemetry=[{"bandwidth_mbps": bw}])
        batch = next(pipe)
        kinds = strat.step_schedule(local_since, H)
        metrics = {}
        for kind in kinds:
            state, m = trainer.step(state, batch, plan, kind)
            metrics.update(m)
            comm_bytes += N_EDGE_AGG * strat.wire_bytes(sched, plan, kind,
                                                        n_pods=2)
        if SYNC_KINDS & set(kinds):
            local_since = 0
        else:
            local_since += 1
        losses.append(float(metrics["loss"]))

    params = jax.tree.map(lambda x: x[0], state["params"])
    eval_loss = float(np.mean([float(eval_fn(params, b))
                               for b in eval_set]))
    final = np.mean(losses[-max(3, steps // 20):])
    conv_step = next((i for i, l in enumerate(losses)
                      if l <= final * 1.01), steps)
    return {"strategy": strategy, "losses": losses,
            "eval_loss": eval_loss, "ppl": math.exp(min(eval_loss, 20)),
            "comm_bytes": comm_bytes, "conv_step": conv_step}


def main(steps: int = 120):
    print("paper Table 1 (reported):")
    for name, row in PAPER_TABLE1.items():
        print(f"  {name:24s} top1={row['top1']} ppl={row['ppl']} "
              f"comm={row['comm_gb']}GB epochs={row['epochs']}")
    results = {}
    for strat, label in STRATS:
        r = run_strategy(strat, steps)
        results[strat] = r
        print(f"{label:24s} eval_loss={r['eval_loss']:.4f} "
              f"ppl={r['ppl']:.2f} comm={r['comm_bytes']/1e6:.1f}MB "
              f"conv_step={r['conv_step']}", flush=True)
    full = results["fullsync"]["comm_bytes"]
    ace = results["acesync"]["comm_bytes"]
    red = 100 * (1 - ace / max(full, 1))
    paper_red = 100 * (1 - 44.7 / 112.5)
    print(f"comm reduction ACE-Sync vs FullSync: {red:.1f}% "
          f"(paper: {paper_red:.1f}%)")
    loss_gap = results["acesync"]["eval_loss"] - results["fullsync"]["eval_loss"]
    print(f"quality gap (eval loss ACE - Full): {loss_gap:+.4f} "
          f"(paper: -0.3pt top-1)")
    res_dir = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                           "results")
    os.makedirs(res_dir, exist_ok=True)
    out = os.path.join(res_dir, "table1.json")
    json.dump({k: {kk: vv for kk, vv in v.items() if kk != "losses"}
               for k, v in results.items()}, open(out, "w"), indent=1)
    # fig2 CSV: convergence curves
    fig2 = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "results", "fig2_curves.csv")
    with open(fig2, "w") as f:
        f.write("step," + ",".join(s for s, _ in STRATS) + "\n")
        for i in range(steps):
            f.write(f"{i}," + ",".join(
                f"{results[s]['losses'][i]:.4f}" for s, _ in STRATS) + "\n")
    print(f"wrote {out} and {fig2}")
    return results


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    a = ap.parse_args()
    main(a.steps)
