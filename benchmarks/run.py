"""Benchmark harness. One function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows (assignment contract)."""
from __future__ import annotations

import json
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                ".."))

# --multipod / --hierarchy simulate pod meshes with 8 virtual host devices
# (--faults needs 12: its elastic soak shrinks a (3, 2, 2) fleet); XLA
# locks the device count at first use, so this must precede the jax
# import (same trick as tests/test_multipod.py, in-process).
if ("--multipod" in sys.argv or "--hierarchy" in sys.argv
        or "--faults" in sys.argv or "--audit" in sys.argv) \
        and "xla_force_host_platform_device_count" \
        not in os.environ.get("XLA_FLAGS", ""):
    _n_sim = 12 if "--faults" in sys.argv else 8
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={_n_sim}").strip()

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402


def _time(fn, *args, iters=5, warmup=2):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6  # us


def row(name, us, derived=""):
    print(f"{name},{us:.1f},{derived}", flush=True)


# ---------------------------------------------------------------------------
# micro: compression operators (the paper's hot loop)
# ---------------------------------------------------------------------------


def bench_compression():
    from repro.core import compression as C
    n = 1 << 20  # 1M gradient entries
    g = jnp.asarray(np.random.RandomState(0).randn(n).astype(np.float32))
    e = jnp.zeros_like(g)
    om = jnp.ones((1,), jnp.float32)
    for name, keep, bits in [("FULL", 1.0, 16), ("INT8", 1.0, 8),
                             ("TOPK10_INT8", 0.10, 8),
                             ("TOPK1_INT8", 0.01, 8)]:
        level = C.Level(name, keep, bits)
        fn = jax.jit(lambda g, e, c=level.codec: c.ef_sync(
            g, e, om, om[0], gamma=1.0, n_pods=1, block=1024,
            use_pallas=False))
        us = _time(fn, g, e)
        mbps = n * 4 / (us / 1e6) / 1e6
        wire = level.wire_bytes(n, 2)
        row(f"sync_leaf_{name}_1M", us,
            f"{mbps:.0f}MBps;wire={wire/1e3:.0f}KB")


def bench_codecs(out_path=None):
    """Per-codec microbenchmark: analytic wire bytes + wall time per size,
    written to benchmarks/results/BENCH_codecs.json so the perf trajectory
    accumulates in CI.  Sizes include the total gradient volume of the
    paper-350m SMOKE config (the reduced-width variant CI can afford —
    ~1e5 grads, not the full 350M model)."""
    from repro.codecs import build_codec, list_codecs
    from repro.configs import SMOKE_ARCHS
    from repro.core import sync as S
    from repro.kernels import ops as kops
    from repro.models.registry import build_model

    model = build_model(SMOKE_ARCHS["paper-350m"])
    model_total = int(sum(m.size for m in
                          S.group_metas(model.param_specs())))
    sizes = [1 << 18, 1 << 20, model_total]
    om = jnp.ones((1,), jnp.float32)
    records = []
    for name in list_codecs():
        codec = build_codec(name)
        for n in sizes:
            g = jnp.asarray(np.random.RandomState(0)
                            .randn(n).astype(np.float32))
            e = jnp.zeros_like(g)

            def run(g, e, c=codec, up=False):
                return c.ef_sync(g, e, om, om[0], gamma=1.0, n_pods=1,
                                 block=1024, use_pallas=up)

            us = _time(jax.jit(run), g, e, iters=3, warmup=1)
            rec = {"codec": name, "n": n, "wall_us": round(us, 1),
                   "gb_per_s": round(n * 4 / (us / 1e6) / 1e9, 3),
                   "wire_bytes_2pods": codec.wire_bytes(n, 2),
                   "is_model_total": n == model_total}
            if kops.default_use_pallas():
                # compiled Pallas path (accelerators; interpret is not a
                # meaningful perf number on CPU)
                usp = _time(jax.jit(lambda g, e: run(g, e, up=True)),
                            g, e, iters=3, warmup=1)
                rec["wall_us_pallas"] = round(usp, 1)
            records.append(rec)
            row(f"codec_{name}_{n}", us,
                f"wire={rec['wire_bytes_2pods']/1e3:.0f}KB")
    out = out_path or os.path.join(
        os.path.dirname(os.path.abspath(__file__)), "results",
        "BENCH_codecs.json")
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        json.dump({"backend": jax.default_backend(),
                   "paper_350m_smoke_total_grads": model_total,
                   "records": records}, f, indent=1)
    print(f"wrote {out}", flush=True)


def bench_kernels():
    from repro.kernels import ops
    n = 1 << 18
    g = jnp.asarray(np.random.RandomState(1).randn(n).astype(np.float32))
    e = jnp.zeros_like(g)
    us = _time(lambda: ops.ef_topk(g, e, gamma=1.0, k=104)[0])
    row("kernel_ef_topk_interp_256k", us, "interpret-mode(correctness path)")
    us2 = _time(lambda: ops.quantize_int8(g)[0])
    row("kernel_quantize_int8_interp_256k", us2, "")


# ---------------------------------------------------------------------------
# table 1 + fig 2 (paper's comparison) — smoke scale
# ---------------------------------------------------------------------------


def bench_table1(steps=60):
    from benchmarks import table1
    t0 = time.perf_counter()
    res = table1.main(steps)
    us = (time.perf_counter() - t0) * 1e6
    full = res["fullsync"]["comm_bytes"]
    ace = res["acesync"]["comm_bytes"]
    red = 100 * (1 - ace / max(full, 1))
    row("table1_4strategies", us,
        f"comm_reduction={red:.1f}%;paper=60.3%")


# ---------------------------------------------------------------------------
# train/serve step timings (smoke configs)
# ---------------------------------------------------------------------------


def bench_train_step():
    import tempfile
    from repro.launch.session import TrainSession
    for arch in ("paper-350m", "qwen3-8b", "dbrx-132b", "falcon-mamba-7b",
                 "recurrentgemma-2b"):
        # empty per-run ckpt dir: always a fresh init, never a restore
        sess = TrainSession.from_config(arch, strategy="acesync",
                                        seq_len=128, batch=4, steps=100,
                                        ckpt_dir=tempfile.mkdtemp())
        tr = sess.trainer
        shape = sess.run_config.shape
        batch = sess.model.make_batch(jax.random.PRNGKey(1), shape)
        plan = tr.default_plan()
        kind = tr.strategy.representative_kind
        # the train state is donated through the step — chain it instead
        # of replaying the same (consumed) buffers
        state_box = [sess.init()]

        def step():
            state_box[0], m = tr.step(state_box[0], batch, plan, kind)
            return m["loss"]
        us = _time(step, iters=3, warmup=1)
        tok = shape.global_batch * shape.seq_len
        row(f"train_step_smoke_{arch}", us,
            f"{tok/(us/1e6):.0f}tok_s")


def bench_strategy_loop(steps=12):
    """One short hosted loop per registered strategy via the TrainSession
    facade — proves every registry entry trains end-to-end and prices its
    pod-tier traffic."""
    from repro.strategies import list_strategies
    from repro.launch.session import TrainSession
    for name in list_strategies():
        sess = TrainSession.from_config(
            "paper-350m", strategy=name, seq_len=64, batch=4, steps=steps,
            ckpt_every=0, ckpt_dir="/tmp/repro_bench_ckpt_" + name)
        t0 = time.perf_counter()
        sess.run(steps, log_every=0)
        us = (time.perf_counter() - t0) * 1e6 / steps
        row(f"strategy_loop_{name}", us,
            f"loss={sess.losses[-1]:.3f};comm={sess.comm_bytes/1e6:.2f}MB")


def _phase_breakdown(plan, mesh=None, iters=8):
    """Per-phase wall time of ONE sync round of ``plan``, micro-probed as
    separate jitted calls on the final plan's padded rung buffers:

      * ``encode``  — EF + compress (the producer side the
        backward-interleaved schedule hides behind the remaining grads);
      * ``exchange`` — the packed one-shot pod collective (0 on a 1-pod
        mesh: nothing crosses the DCN);
      * ``decode``  — the receiver-side fold, one dequant+accumulate per
        peer payload.

    Returns {phase: us_per_sync}; the caller amortises by the plan's
    sync interval.  SKIP rungs and empty buckets contribute nothing."""
    from repro import compat
    from repro.codecs.base import BLOCK, pack_payload
    from repro.kernels import ops as kops
    from jax.sharding import PartitionSpec as P

    use_pallas = kops.default_use_pallas()
    n_pods = int(mesh.shape["pod"]) if mesh is not None else 1
    phases = {"encode": 0.0, "exchange": 0.0, "decode": 0.0}
    r = np.random.RandomState(0)
    for rung, nb in enumerate(plan.bucket_sig or ()):
        lv = plan.levels[rung]
        if not nb or lv.is_skip:
            continue
        codec = lv.codec
        n = nb * BLOCK
        flat = jnp.asarray(r.randn(n).astype(np.float32))
        err = jnp.asarray(r.randn(n).astype(np.float32) * 0.1)

        def enc(f, e, c=codec):
            return c.ef_encode(f, e, gamma=0.9, use_pallas=use_pallas)
        phases["encode"] += _time(jax.jit(enc), flat, err, iters=iters)

        payload, _, _ = jax.jit(enc)(flat, err)

        if codec.supports_ring:  # per-peer payload fold codecs
            def dec(pl, c=codec, nb_=nb, n_=n):
                acc = c.accum_init(nb_)
                for _ in range(n_pods):
                    acc = c.decode_accumulate(acc, pl,
                                              jnp.float32(1.0 / 3),
                                              use_pallas=use_pallas)
                return c.accum_finalize(acc, n_, BLOCK)
            phases["decode"] += _time(jax.jit(dec), payload, iters=iters)

        if mesh is not None and n_pods > 1:
            if codec.supports_ring:
                wire, _ = pack_payload(payload)

                def exch(w):
                    return jax.lax.all_gather(w, "pod")
            else:  # FULL: the exchange IS the bf16 psum, decode-free
                wire = flat.astype(jnp.bfloat16)

                def exch(w):
                    return jax.lax.psum(w, "pod")
            smapped = compat.shard_map(
                exch, mesh, in_specs=P(), out_specs=P(),
                manual_axes=set(mesh.axis_names))
            phases["exchange"] += _time(jax.jit(smapped), wire,
                                        iters=iters)
    return phases


def bench_steptime(out_path=None, steps=24, warmup=6, multipod=False,
                   fail_on_recompile=False):
    """Perf trajectory of the retrace-free replan path and the chunked
    ring exchange: steps/sec for fullsync vs acesync (the new default —
    auto ring + rung-ordered apply — against a PR-3-equivalent
    one-shot/barrier variant and a forced-ring stress variant), the
    replan-to-apply latency of the async device replan, the train-step
    compile count (steady-state replans must add ZERO — CI gates on it
    with ``--fail-on-recompile``; AOT warm-ups are reported separately
    as ``warm_compiles``), the padded-vs-analytic wire-byte overhead of
    the per-rung size classes, the chosen classes / chunk grid, and the
    bidirectional-vs-unidirectional forced-ring pair.  ``--multipod`` runs on the simulated (2, 2, 2)
    pod mesh (8 virtual CPU devices).  Run WITHOUT
    ``REPRO_FORCE_INTERPRET`` — perf is measured on the production
    dispatch path (pure-jnp oracle on CPU, compiled Pallas kernels on
    accelerators); the forced Pallas INTERPRETER is a correctness
    harness whose per-grid-step op expansion taxes exactly the codec
    paths this bench compares (the kernel path's correctness is pinned
    by the test suite, not timed here).  Written to
    benchmarks/results/BENCH_step_time.json and mirrored at the repo root
    (the trajectory CI uploads)."""
    import tempfile
    from repro.configs.base import ACESyncConfig
    from repro.launch.session import TrainSession

    mesh = None
    if multipod:
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))

    variants = [
        ("fullsync", "fullsync", 0, {}),
        ("acesync", "acesync", 6, {}),
        ("acesync", "acesync", 18, {}),
        # the adaptive interval pinned to H=2 — twice the default sync
        # cadence, the (harsher) workload earlier trajectory points used
        ("acesync_h2", "acesync", 6, dict(sync_interval_init=2)),
        # the PR-3 exchange: one-shot all_gather per rung + whole-tree
        # optimizer barrier, no backward interleaving — the baseline the
        # ring/overlap/segment-streaming path replaces
        ("acesync_oneshot_pr3", "acesync", 6,
         dict(ring_chunks=-1, overlap_apply=False,
              overlap_backward=False)),
    ]
    if multipod:
        # forced 2-chunk ring on every ring-capable rung: exercises the
        # ppermute pipeline end-to-end even at smoke bucket sizes (the
        # roofline auto path one-shots buckets this small).  The
        # bidirectional (default) and unidirectional variants are both
        # recorded: on the CPU simulator they time within noise (no real
        # full-duplex links), but the pair pins the perf trajectory for
        # real multi-pod hardware where the half-ring split is ~2x.
        variants.append(("acesync_ring2_bidir", "acesync", 6,
                         dict(ring_chunks=2, ring_bidir=True)))
        variants.append(("acesync_ring2_unidir", "acesync", 6,
                         dict(ring_chunks=2, ring_bidir=False)))

    records = []
    for name, strategy, cadence, ace_kw in variants:
        ace = ACESyncConfig(replan_every=cadence if cadence else 10 ** 9,
                            **ace_kw)
        sess = TrainSession.from_config(
            "paper-350m", strategy=strategy, mesh=mesh, seq_len=64,
            batch=4, steps=200, warmup_steps=10, ckpt_every=0,
            ckpt_dir=tempfile.mkdtemp(), acesync=ace)
        sess.run(warmup, log_every=0)            # compile + first replans
        tr = sess.trainer
        # stabilise the signature cache: keep stepping until a full
        # replan cycle adds no compiled variants (bounded) — the timed
        # window then measures the steady state the zero-retrace
        # contract is about
        stabilise_rounds = 0
        for _ in range(6):
            before = tr.compile_count()
            sess.run(max(cadence, 6), log_every=0)
            if tr.compile_count() == before:
                break
            stabilise_rounds += 1
        compiles_before = tr.compile_count()
        # best-of-3 timed windows: the CPU-sim box is shared and a single
        # short window can eat a scheduler stall; the best window is the
        # least-perturbed estimate of the steady-state step time
        sess.loop.poll_replan(block=True)
        windows = []
        for _ in range(3):
            t0 = time.perf_counter()
            sess.run(steps, log_every=0)
            windows.append(time.perf_counter() - t0)
        dt = min(windows)
        # join any background AOT warm thread before the session is
        # dropped (a daemon thread killed mid-XLA-compile aborts the
        # interpreter at teardown)
        sess.loop.poll_replan(block=True)
        compiles_after = tr.compile_count()
        sched = tr.scheduler
        plan = sess.loop.plan
        padded = sched.plan_wire_bytes(plan)
        analytic = sched.plan_wire_bytes(plan, padded=False)
        lat = sess.loop.replan_latencies
        rec = {
            "name": name,
            "strategy": strategy,
            "replan_every": cadence,
            "multipod": multipod,
            "steps_per_sec": round(steps / dt, 3),
            "us_per_step": round(dt / steps * 1e6, 1),
            "window_secs": [round(w, 3) for w in windows],
            "compile_count_warm": compiles_before,
            "stabilise_rounds": stabilise_rounds,
            "new_compiles_during_timed_steps":
                compiles_after - compiles_before,
            "replans_applied": len(lat),
            "replan_to_apply_latency_steps":
                (sum(lat) / len(lat) if lat else None),
            # ring direction + the AOT compiles the speculative replan
            # warm-up kept off the foreground step
            "ring_bidir": ace.ring_bidir,
            "warm_compiles": tr.warm_compiles,
            "wire_bytes_padded": padded,
            "wire_bytes_analytic": analytic,
            "padding_overhead_frac":
                round(padded / analytic - 1.0, 4) if analytic else 0.0,
            # the chosen per-rung size classes + ring chunk grid of the
            # final plan (the ROADMAP pad-growth knob's telemetry)
            "bucket_sig": list(plan.bucket_sig or ()),
            "ring_chunks": list(plan.ring_chunks or ()),
            "final_loss": round(sess.losses[-1], 4),
        }
        # per-phase sync wall time, amortised to us/step by the sync
        # interval (fullsync syncs every step) — the breakdown behind
        # the "encode hides behind backward" headline
        si = max(1, int(getattr(plan, "sync_interval", 1) or 1))
        ph = _phase_breakdown(plan, mesh=mesh)
        rec["sync_interval"] = si
        rec["phase_us_per_step"] = {k: round(v / si, 1)
                                    for k, v in ph.items()}
        rec["overlap_backward"] = ace.overlap_backward
        records.append(rec)
        row(f"steptime_{name}_replan{cadence}", dt / steps * 1e6,
            f"{rec['steps_per_sec']}steps_s;"
            f"recompiles={rec['new_compiles_during_timed_steps']}")
    out = out_path or os.path.join(
        os.path.dirname(os.path.abspath(__file__)), "results",
        "BENCH_step_time.json")
    os.makedirs(os.path.dirname(out), exist_ok=True)
    payload = {"backend": jax.default_backend(), "multipod": multipod,
               "timed_steps": steps, "records": records}
    with open(out, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"wrote {out}", flush=True)
    root_out = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                            "..", "BENCH_step_time.json")
    with open(root_out, "w") as f:
        json.dump(payload, f, indent=1)
    bad = [r["name"] for r in records
           if r["new_compiles_during_timed_steps"] > 0]
    if bad:
        msg = f"steady-state recompiles in: {bad}"
        if fail_on_recompile:
            raise SystemExit(msg)
        print(f"WARNING: {msg}", flush=True)
    return records


def bench_hierarchy(out_path=None, steps=24, warmup=6,
                    fail_on_recompile=False):
    """Heterogeneous-fleet benchmark of the two-tier sync topology.

    Runs a simulated (2, 2, 2) ``("pod", "edge", "data")`` mesh — a fleet
    of 4 members in 2 clusters of 2 — under a 16-device flapping 5-200
    Mbps telemetry trace, three ways: dense ``fullsync``, flat ``acesync``
    (``hier_mode=-1`` pins every rung to the one-tier fleet exchange), and
    ``acesync_hier`` (live :class:`~repro.hierarchy.ClusterState`
    re-clustering on the replan cadence, bottleneck-cluster byte budget,
    roofline-picked intra-cluster aggregation feeding the compressed
    cross-tier ring).  Records cross-tier + intra-tier wire bytes,
    steps/s, cluster-assignment churn, replan-to-apply latencies, and the
    steady-state compile count — which must stay at ZERO new entries while
    telemetry-driven replans re-cluster mid-run (CI gates on it with
    ``--fail-on-recompile``).  Written to
    benchmarks/results/BENCH_hierarchy.json and mirrored at the repo
    root."""
    import tempfile
    from repro.configs.base import ACESyncConfig
    from repro.launch.mesh import make_mesh
    from repro.launch.session import TrainSession

    mesh = make_mesh((2, 2, 2), ("pod", "edge", "data"))
    variants = [
        ("fullsync", "fullsync", {}),
        ("acesync_flat", "acesync", dict(hier_mode=-1)),
        ("acesync_hier", "acesync_hier", {}),
    ]
    records = []
    for name, strategy, ace_kw in variants:
        ace = ACESyncConfig(replan_every=6, sync_interval_init=2, **ace_kw)
        sess = TrainSession.from_config(
            "paper-350m", strategy=strategy, mesh=mesh, seq_len=64,
            batch=4, steps=400, warmup_steps=10, ckpt_every=0,
            n_edge_devices=16, ckpt_dir=tempfile.mkdtemp(), acesync=ace)
        sess.run(warmup, log_every=0)            # compile + first replans
        tr = sess.trainer
        # stabilise the signature cache (same contract as bench_steptime):
        # steady-state replans — which keep re-clustering the fleet — must
        # add zero compiled variants before the timed window opens
        stabilise_rounds = 0
        for _ in range(6):
            before = tr.compile_count()
            sess.run(6, log_every=0)
            if tr.compile_count() == before:
                break
            stabilise_rounds += 1
        # land any in-flight replan + background AOT warm-up before the
        # timed window opens (a compile thread would steal the timed CPU)
        sess.loop.poll_replan(block=True)
        compiles_before = tr.compile_count()
        bytes_before = sess.comm_bytes
        t0 = time.perf_counter()
        sess.run(steps, log_every=0)
        dt = time.perf_counter() - t0
        # join any warm thread the timed window launched: a daemon thread
        # killed mid-XLA-compile aborts the interpreter at teardown
        sess.loop.poll_replan(block=True)
        sched = tr.scheduler
        plan = sess.loop.plan
        cs = sess.loop.clusters
        lat = sess.loop.replan_latencies
        rec = {
            "name": name,
            "strategy": strategy,
            "fleet": {"n_pods": tr.n_pods, "n_edge": tr.n_edge,
                      "n_cross": sched.n_cross,
                      "hier_enabled": sched.hier_enabled},
            "steps_per_sec": round(steps / dt, 3),
            "cross_wire_bytes_timed": sess.comm_bytes - bytes_before,
            "cross_wire_bytes_per_sync": sched.plan_wire_bytes(plan),
            "intra_wire_bytes_per_sync": sched.plan_intra_bytes(plan),
            "bucket_sig": list(plan.bucket_sig or ()),
            "hier_grid": list(plan.hier or ()),
            "cluster_updates": cs.updates,
            "cluster_churn": cs.churn,
            "cluster_reclusters": cs.reclusters,
            "replans_applied": len(lat),
            "replan_to_apply_latency_steps":
                (sum(lat) / len(lat) if lat else None),
            "compile_count_warm": compiles_before,
            "stabilise_rounds": stabilise_rounds,
            "new_compiles_during_timed_steps":
                tr.compile_count() - compiles_before,
            "warm_compiles": tr.warm_compiles,
            "final_loss": round(sess.losses[-1], 4),
        }
        records.append(rec)
        row(f"hierarchy_{name}", dt / steps * 1e6,
            f"{rec['steps_per_sec']}steps_s;"
            f"cross={rec['cross_wire_bytes_per_sync']/1e3:.0f}KB;"
            f"churn={rec['cluster_churn']};"
            f"recompiles={rec['new_compiles_during_timed_steps']}")
    by = {r["name"]: r for r in records}
    reduction = (1.0 - by["acesync_hier"]["cross_wire_bytes_per_sync"]
                 / max(by["acesync_flat"]["cross_wire_bytes_per_sync"], 1))
    payload = {"backend": jax.default_backend(),
               "timed_steps": steps,
               "cross_tier_reduction_vs_flat_acesync": round(reduction, 4),
               "records": records}
    out = out_path or os.path.join(
        os.path.dirname(os.path.abspath(__file__)), "results",
        "BENCH_hierarchy.json")
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"wrote {out}", flush=True)
    root_out = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                            "..", "BENCH_hierarchy.json")
    with open(root_out, "w") as f:
        json.dump(payload, f, indent=1)
    row("hierarchy_cross_tier_reduction", 0.0,
        f"hier_vs_flat={100 * reduction:.1f}%")
    bad = [r["name"] for r in records
           if r["new_compiles_during_timed_steps"] > 0]
    if bad:
        msg = f"steady-state recompiles in: {bad}"
        if fail_on_recompile:
            raise SystemExit(msg)
        print(f"WARNING: {msg}", flush=True)
    if reduction <= 0:
        msg = (f"two-tier topology did not cut cross-tier bytes "
               f"(reduction={reduction:.4f})")
        if fail_on_recompile:  # CI strict mode gates the headline claim too
            raise SystemExit(msg)
        print(f"WARNING: {msg}", flush=True)
    return records


def bench_faults(out_path=None, steps=16, fail_on_recompile=False):
    """Fault-injected elastic soak on a simulated (3, 2, 2) pod mesh (12
    virtual CPU devices): pod 2 preempted mid-run, its heartbeats delayed
    on return, a checkpoint bit-rotted on disk — against a fault-free
    baseline of the same config.  Records the foreground compile count
    delta (a membership change must add ZERO — the new-P step is AOT-
    warmed in the background; CI gates on it with ``--fail-on-recompile``),
    the membership events with their warm-cache provenance, checkpoint
    integrity triage (the corrupted step must fail deep verification and
    restore must anchor elsewhere), and wall time overhead.  Written to
    benchmarks/results/BENCH_faults.json and mirrored at the repo root."""
    import tempfile
    from repro.checkpoint.checkpointer import Checkpointer
    from repro.launch.mesh import make_mesh
    from repro.launch.session import TrainSession
    from repro.runtime.faults import (FaultEvent, FaultSchedule, KILL_POD,
                                      REJOIN_POD, CORRUPT_CKPT,
                                      DELAY_HEARTBEAT)

    def run_once(faults, ckpt_every=0):
        mesh = make_mesh((3, 2, 2), ("pod", "data", "model"))
        sess = TrainSession.from_config(
            "paper-350m", strategy="acesync", mesh=mesh, seq_len=64,
            batch=6, steps=steps, ckpt_every=ckpt_every,
            ckpt_dir=tempfile.mkdtemp(), fault_schedule=faults,
            blocking_replans=True)
        t0 = time.perf_counter()
        sess.run(steps, log_every=0)
        dt = time.perf_counter() - t0
        sess.finish()
        return sess, dt

    base, dt_base = run_once(None)
    schedule = FaultSchedule([
        FaultEvent(4, KILL_POD, 2),
        FaultEvent(6, DELAY_HEARTBEAT, 1, duration=2),
        FaultEvent(8, REJOIN_POD, 2),
        FaultEvent(12, CORRUPT_CKPT, 0),   # bit-rots the newest ckpt (10)
    ])
    sess, dt_fault = run_once(schedule, ckpt_every=5)
    loop = sess.loop
    new_foreground = loop.compile_count() - base.loop.compile_count()
    ck = Checkpointer(loop.ckpt.dir)
    deep_valid = ck.valid_steps(deep=True)
    rec = {
        "steps": steps,
        "baseline_steps_per_sec": round(steps / dt_base, 3),
        "faulted_steps_per_sec": round(steps / dt_fault, 3),
        "fault_overhead_frac": round(dt_fault / dt_base - 1.0, 4),
        "baseline_compile_count": base.loop.compile_count(),
        "faulted_compile_count": loop.compile_count(),
        "new_foreground_compiles_from_faults": new_foreground,
        "warm_compiles": loop.warm_compile_count(),
        "membership_events": loop.membership_events,
        "events_fired": [{"step": e.step, "kind": e.kind,
                          "target": e.target} for e in schedule.fired],
        "ckpt_steps_deep_valid": deep_valid,
        "ckpt_corrupted_step_detected": 10 not in deep_valid,
        "ckpt_restore_anchor": ck.latest_step(),
        "final_loss": round(sess.losses[-1], 4),
        "final_n_pods": loop.trainer.n_pods,
    }
    out = out_path or os.path.join(
        os.path.dirname(os.path.abspath(__file__)), "results",
        "BENCH_faults.json")
    os.makedirs(os.path.dirname(out), exist_ok=True)
    payload = {"backend": jax.default_backend(), "record": rec}
    with open(out, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"wrote {out}", flush=True)
    root_out = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                            "..", "BENCH_faults.json")
    with open(root_out, "w") as f:
        json.dump(payload, f, indent=1)
    row("faults_elastic_soak", dt_fault / steps * 1e6,
        f"overhead={100 * rec['fault_overhead_frac']:.1f}%;"
        f"recompiles={new_foreground};"
        f"warm={rec['warm_compiles']}")
    problems = []
    if new_foreground > 0:
        problems.append(f"membership change caused {new_foreground} "
                        f"foreground recompiles")
    if not all(e.get("served_from_warm_cache")
               for e in loop.membership_events):
        problems.append("a membership swap missed the warm AOT cache")
    if not rec["ckpt_corrupted_step_detected"]:
        problems.append("corrupted checkpoint passed deep verification")
    if rec["ckpt_restore_anchor"] == 10:
        problems.append("restore anchored on the corrupted checkpoint")
    if problems:
        msg = "; ".join(problems)
        if fail_on_recompile:
            raise SystemExit(msg)
        print(f"WARNING: {msg}", flush=True)
    return rec


def bench_decode_step():
    from repro.configs import SMOKE_ARCHS
    from repro.configs.base import ShapeConfig
    from repro.models.registry import build_model
    for arch in ("paper-350m", "falcon-mamba-7b"):
        model = build_model(SMOKE_ARCHS[arch])
        params = model.init(jax.random.PRNGKey(0))
        pf = ShapeConfig("p", 64, 2, "prefill")
        batch = model.make_batch(jax.random.PRNGKey(1), pf)
        _, cache = jax.jit(model.prefill)(params, batch)
        tok = jnp.zeros((2, 1), jnp.int32)
        dec = jax.jit(model.decode_step)

        def step(c):
            return dec(params, c, jnp.int32(63), tok)[0]
        us = _time(step, cache, iters=5, warmup=2)
        row(f"decode_step_smoke_{arch}", us,
            f"{2/(us/1e6):.0f}tok_s")


# ---------------------------------------------------------------------------
# roofline summary (from dry-run artifacts, if present)
# ---------------------------------------------------------------------------


def bench_roofline_summary():
    from benchmarks import roofline
    rows = roofline.table("16x16")
    if not rows:
        row("roofline_16x16", 0.0, "no dry-run artifacts")
        return
    t0 = time.perf_counter()
    best = max(rows, key=lambda r: r["roofline_frac"])
    worst = min(rows, key=lambda r: r["roofline_frac"])
    us = (time.perf_counter() - t0) * 1e6
    row("roofline_16x16_cells", us,
        f"n={len(rows)};best={best['arch']}/{best['shape']}"
        f"@{best['roofline_frac']:.2f};"
        f"worst={worst['arch']}/{worst['shape']}"
        f"@{worst['roofline_frac']:.3f}")


def bench_audit(out_path=None, fail_on_violation=False):
    """Graph auditor over the shipped strategies on the simulated (2,2,2)
    meshes (see ``repro.analysis``): collective schema vs the ExecPlan's
    analytic schedule, donation aliasing, host-sync lint, recompile
    hazards, Pallas BlockSpec sweep.  Writes AUDIT.json to
    benchmarks/results/ and mirrors it at the repo root."""
    from repro.analysis import run_audit

    t0 = time.perf_counter()
    report = run_audit()
    us = (time.perf_counter() - t0) * 1e6
    payload = report.to_dict()
    payload["backend"] = jax.default_backend()
    out = out_path or os.path.join(
        os.path.dirname(os.path.abspath(__file__)), "results",
        "AUDIT.json")
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"wrote {out}", flush=True)
    root_out = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                            "..", "AUDIT.json")
    with open(root_out, "w") as f:
        json.dump(payload, f, indent=1)
    row("graph_audit", us, report.summary().replace(",", ";"))
    if not report.ok and fail_on_violation:
        raise SystemExit(report.summary())
    return report


def main() -> None:
    print("name,us_per_call,derived")
    if "--audit" in sys.argv:
        bench_audit(
            fail_on_violation="--fail-on-violation" in sys.argv)
        return
    if "--codecs" in sys.argv:
        bench_codecs()
        return
    if "--steptime" in sys.argv:
        bench_steptime(multipod="--multipod" in sys.argv,
                       fail_on_recompile="--fail-on-recompile" in sys.argv)
        return
    if "--hierarchy" in sys.argv:
        bench_hierarchy(
            fail_on_recompile="--fail-on-recompile" in sys.argv)
        return
    if "--faults" in sys.argv:
        bench_faults(
            fail_on_recompile="--fail-on-recompile" in sys.argv)
        return
    bench_compression()
    bench_kernels()
    bench_codecs()
    bench_train_step()
    bench_strategy_loop()
    bench_steptime()
    bench_decode_step()
    bench_roofline_summary()
    bench_table1(steps=int(os.environ.get("TABLE1_STEPS", "60")))


if __name__ == "__main__":
    main()
