"""Roofline analysis from the dry-run artifacts (assignment §ROOFLINE).

Per (arch x shape x mesh) cell:
    compute term    = HLO_FLOPs_per_device / peak_FLOPs            [s]
    memory term     = HLO_bytes_per_device / HBM_bw                [s]
    collective term = ICI bytes / ICI_bw + pod (DCN) bytes / DCN_bw [s]

HLO_FLOPs / bytes / collective-bytes come from the trip-count-aware HLO
walker (benchmarks/hlo_cost.py) — NOT from raw cost_analysis(), which counts
scan bodies once (verified; see EXPERIMENTS.md).  The memory term from CPU
HLO is an UPPER bound (CPU fusion granularity < TPU); an analytic
lower bound (params + optimizer + activation streams) is reported alongside.
"""
from __future__ import annotations

import glob
import json
import os
import sys
from typing import Dict, List, Optional

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "..", "src"))

from repro.launch.mesh import PEAK_FLOPS_BF16, HBM_BW, ICI_BW, DCN_BW  # noqa
from repro.configs import ARCHS, SHAPES  # noqa
from repro.models.flops import model_flops  # noqa

RESULTS = os.path.join(os.path.dirname(os.path.abspath(__file__)), "results")


def analytic_memory_bytes(arch: str, shape_name: str, n_chips: int) -> float:
    """Per-device HBM-traffic lower bound for one step."""
    cfg = ARCHS[arch]
    shape = SHAPES[shape_name]
    n = cfg.param_count()
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        # fwd + bwd + remat-fwd stream the active params thrice (bf16),
        # optimizer reads/writes m, v, p, g in f32
        traffic = 3 * 2 * n_active + 12 * n + 8 * n
    elif shape.kind == "prefill":
        traffic = 2 * n_active
    else:  # decode: read active params + the KV cache
        if cfg.family == "ssm":
            cache = cfg.n_layers * cfg.d_inner * cfg.ssm_state * 4
        elif cfg.family == "hybrid":
            cache = cfg.n_layers * cfg.lru_width * 4
        else:
            W = min(shape.cache_len, 10 ** 9)
            cache = (cfg.n_layers * 2 * W * cfg.n_kv_heads
                     * cfg.head_dim * 2)
        traffic = 2 * n_active + cache * shape.global_batch
    return traffic / n_chips


def load_cells(mesh: Optional[str] = None,
               strategy: str = "acesync") -> List[Dict]:
    out = []
    for f in sorted(glob.glob(os.path.join(RESULTS, "*.json"))):
        rec = json.load(open(f))
        if not rec.get("ok"):
            continue
        if mesh and rec["mesh"] != mesh:
            continue
        if rec.get("strategy", "acesync") != strategy and \
                rec.get("mode") is None:
            continue
        out.append(rec)
    return out


def roofline_row(rec: Dict) -> Dict:
    w = rec["walker"]
    coll = w["collective_bytes_per_device"]
    ici = sum(v for k, v in coll.items() if k not in ("pod", "unknown"))
    pod = coll.get("pod", 0.0)
    compute_s = w["flops_per_device"] / PEAK_FLOPS_BF16
    mem_ub_s = w["bytes_per_device"] / HBM_BW
    mem_lb_s = analytic_memory_bytes(rec["arch"], rec["shape"],
                                     rec["n_chips"]) / HBM_BW
    coll_s = ici / ICI_BW + pod / DCN_BW
    mem_s = max(mem_lb_s, min(mem_ub_s, mem_lb_s * 4))  # bounded estimate
    terms = {"compute": compute_s, "memory": mem_s, "collective": coll_s}
    dom = max(terms, key=terms.get)
    bound = max(terms.values())
    frac = compute_s / bound if bound > 0 else 0.0
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "compute_s": compute_s, "memory_s": mem_s,
        "memory_ub_s": mem_ub_s, "memory_lb_s": mem_lb_s,
        "collective_s": coll_s, "pod_bytes": pod, "ici_bytes": ici,
        "dominant": dom, "roofline_frac": frac,
        "model_flops": rec["model_flops_global"],
        "hlo_flops": rec["hlo_flops_global"],
        "useful_ratio": rec.get("useful_ratio"),
        "mem_per_dev_gb": rec.get("bytes_per_device", 0) / 1e9,
        "hbm_gb": rec.get("memory", {}).get("temp_size_in_bytes", 0) / 1e9,
    }


def table(mesh="16x16") -> List[Dict]:
    return [roofline_row(r) for r in load_cells(mesh)]


def fmt_table(rows: List[Dict]) -> str:
    hdr = (f"{'arch':24s} {'shape':12s} {'compute_s':>10s} {'memory_s':>10s} "
           f"{'collect_s':>10s} {'dom':>10s} {'useful':>7s} {'frac':>6s}")
    lines = [hdr, "-" * len(hdr)]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        lines.append(
            f"{r['arch']:24s} {r['shape']:12s} {r['compute_s']:10.4f} "
            f"{r['memory_s']:10.4f} {r['collective_s']:10.4f} "
            f"{r['dominant']:>10s} "
            f"{(r['useful_ratio'] or 0):7.3f} {r['roofline_frac']:6.3f}")
    return "\n".join(lines)


def main():
    for mesh in ("16x16", "2x16x16"):
        rows = table(mesh)
        if rows:
            print(f"\n=== roofline {mesh} ({len(rows)} cells) ===")
            print(fmt_table(rows))
    # write machine-readable
    out = {m: table(m) for m in ("16x16", "2x16x16")}
    with open(os.path.join(RESULTS, "roofline.json"), "w") as f:
        json.dump(out, f, indent=1)
    print(f"\nwrote {os.path.join(RESULTS, 'roofline.json')}")


if __name__ == "__main__":
    main()
