"""The paper's cloud-edge experiment in miniature: 64 heterogeneous edge
devices (5-200 Mbps, 10-300 ms), 4 synchronization strategies, communication
+ quality comparison — the Table 1 / Figure 2 reproduction.

Run:  PYTHONPATH=src python examples/cloud_edge_sim.py [--steps 120]
"""
import argparse
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks import table1

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=120)
args = ap.parse_args()
table1.main(args.steps)
