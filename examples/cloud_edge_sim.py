"""The paper's cloud-edge experiment in miniature: heterogeneous edge
devices (5-200 Mbps, 10-300 ms), multiple synchronization strategies,
communication + quality comparison.

Two modes:

  * default — the Table 1 / Figure 2 reproduction (64 edge devices, 4
    strategies, STAR-topology comm accounting);
  * ``--hierarchy`` — the two-tier fleet: a simulated ("pod", "edge",
    "data") mesh where live telemetry clustering (ClusterState) maps 16
    edge devices onto 2 clusters of 2 fleet members, intra-cluster
    aggregation feeds the compressed cross-tier ring, and the report
    compares cross-tier wire bytes for flat vs hierarchical ACE-Sync
    (writes benchmarks/results/BENCH_hierarchy.json).

Run:  PYTHONPATH=src python examples/cloud_edge_sim.py [--steps 120]
      PYTHONPATH=src python examples/cloud_edge_sim.py --hierarchy
"""
import argparse
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=120)
ap.add_argument("--hierarchy", action="store_true",
                help="run the two-tier cluster fleet instead of Table 1")
args = ap.parse_args()

if args.hierarchy:
    # the simulated fleet needs 8 virtual host devices; XLA locks the
    # device count at first use, so set this before importing jax
    if "xla_force_host_platform_device_count" not in \
            os.environ.get("XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                                   + " --xla_force_host_platform_device_"
                                     "count=8").strip()
    from benchmarks import run as bench
    bench.bench_hierarchy(steps=max(args.steps // 5, 6))
else:
    from benchmarks import table1
    table1.main(args.steps)
