"""Batched serving example: prefill + iterative decode with ring KV caches.
Run:  PYTHONPATH=src python examples/serve_lm.py"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
import numpy as np
import jax, jax.numpy as jnp

from repro.configs import SMOKE_ARCHS
from repro.launch.serve import Server, Request
from repro.models.registry import build_model

model = build_model(SMOKE_ARCHS["recurrentgemma-2b"])  # hybrid: RG-LRU+attn
params = jax.tree.map(lambda x: x.astype(jnp.bfloat16),
                      model.init(jax.random.PRNGKey(0)))
server = Server(model, cache_len=96, batch=4)
rng = np.random.RandomState(0)
reqs = [Request(i, rng.randint(0, model.cfg.vocab_size, size=48)
                .astype(np.int32), max_new_tokens=12) for i in range(8)]
done = server.serve(params, reqs)
for r in done[:3]:
    print(f"req {r.rid}: {len(r.out_tokens)} tokens "
          f"in {r.t_done - r.t_submit:.2f}s -> {r.out_tokens[:6]}...")
print(f"total: {sum(len(r.out_tokens) for r in done)} tokens")
