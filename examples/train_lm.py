"""End-to-end driver: train a ~paper-350M-family model (reduced dims for
CPU) for a few hundred steps with the full ACE-Sync control loop —
telemetry, clustering, knapsack plans, divergence-adapted H, checkpoints.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]
      [--strategy acesync|fullsync|topk|fedavg|localsgd|bandwidth_tiered]
"""
import argparse
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs.base import ACESyncConfig
from repro.launch.session import TrainSession
from repro.strategies import list_strategies

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--strategy", default="acesync", choices=list_strategies())
ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
args = ap.parse_args()

sess = TrainSession.from_config(
    "paper-350m", strategy=args.strategy, steps=args.steps,
    n_edge_devices=64, warmup_steps=20, lr=2e-3, ckpt_every=100,
    ckpt_dir=args.ckpt_dir, acesync=ACESyncConfig(replan_every=50))
sess.run(log_every=20)
sess.finish()
losses = sess.losses
print(f"loss {losses[0]:.4f} -> {losses[-1]:.4f} over {len(losses)} steps "
      f"(comm {sess.comm_bytes / 1e6:.2f}MB)")
assert losses[-1] < losses[0]
