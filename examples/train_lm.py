"""End-to-end driver: train a ~paper-350M-family model (reduced dims for
CPU) for a few hundred steps with the full ACE-Sync control loop —
telemetry, clustering, knapsack plans, divergence-adapted H, checkpoints.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
import jax

from repro.configs import SMOKE_ARCHS
from repro.configs.base import ACESyncConfig, RunConfig, ShapeConfig
from repro.data.pipeline import TokenPipeline
from repro.launch.train import TrainLoop
from repro.models.registry import build_model

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--strategy", default="acesync")
ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
args = ap.parse_args()

cfg = SMOKE_ARCHS["paper-350m"]
shape = ShapeConfig("e2e", 256, 8, "train")
run = RunConfig(model=cfg, shape=shape, total_steps=args.steps,
                warmup_steps=20, lr=2e-3, ckpt_every=100,
                ckpt_dir=args.ckpt_dir,
                acesync=ACESyncConfig(replan_every=50))
model = build_model(cfg, run)
loop = TrainLoop(model, run, strategy=args.strategy, n_edge_devices=64)
pipe = TokenPipeline(model, shape, seed=0)
state = loop.restore_or_init(jax.random.PRNGKey(0), pipe)
state = loop.run_steps(state, pipe, args.steps, log_every=20)
loop.ckpt.wait()
losses = [h["loss"] for h in loop.history if "loss" in h]
print(f"loss {losses[0]:.4f} -> {losses[-1]:.4f} over {len(losses)} steps")
assert losses[-1] < losses[0]
