"""Quickstart: build a reduced model, train 40 ACE-Sync steps on CPU via
the TrainSession facade. Run:  PYTHONPATH=src python examples/quickstart.py"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.session import TrainSession

sess = TrainSession.from_config(
    "qwen3-8b",                        # reduced qwen3 family config
    strategy="acesync", seq_len=128, batch=4, steps=40,
    warmup_steps=4, lr=2e-3, ckpt_every=0,
    ckpt_dir="/tmp/repro_quickstart")
print("strategy:", sess.strategy.name)

sess.run(log_every=10)

# the plan the control loop actually executed (telemetry + importance ->
# eq-(5) budget -> knapsack)
trainer = sess.trainer
plan = sess.loop.plan
print("compression plan:",
      {g.name.split("/")[-1]: plan.level_of(i).name
       for i, g in enumerate(trainer.metas)})
print(f"loss {sess.losses[0]:.4f} -> {sess.losses[-1]:.4f}")
print("wire bytes/sync:", trainer.scheduler.plan_wire_bytes(plan),
      "vs fullsync:", trainer.scheduler.fullsync_wire_bytes())
