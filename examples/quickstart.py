"""Quickstart: build a reduced model, train 40 ACE-Sync steps on CPU, serve
a few tokens. Run:  PYTHONPATH=src python examples/quickstart.py"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
import jax

from repro.configs import SMOKE_ARCHS
from repro.configs.base import RunConfig, ShapeConfig
from repro.core.trainer import Trainer
from repro.data.pipeline import TokenPipeline
from repro.models.registry import build_model

cfg = SMOKE_ARCHS["qwen3-8b"]          # reduced qwen3 family config
shape = ShapeConfig("quick", 128, 4, "train")
run = RunConfig(model=cfg, shape=shape, total_steps=40, warmup_steps=4,
                lr=2e-3)
model = build_model(cfg, run)

trainer = Trainer(model, run, strategy="acesync")
state = trainer.init_state(jax.random.PRNGKey(0))
pipe = TokenPipeline(model, shape, seed=0)

plan = trainer.default_plan(bandwidth_mbps=40.0)   # eq (5) budget
print("compression plan:",
      {g.name.split("/")[-1]: plan.level_of(i).name
       for i, g in enumerate(trainer.metas)})
step = trainer.step_fn(plan, "grad_sync")
for i in range(run.total_steps):
    state, metrics = step(state, next(pipe))
    if i % 10 == 0:
        print(f"step {i:3d}  loss {float(metrics['loss']):.4f}  "
              f"imp_mse {float(metrics['imp_mse']):.5f}")
print("wire bytes/sync:", trainer.scheduler.plan_wire_bytes(plan),
      "vs fullsync:", trainer.scheduler.fullsync_wire_bytes())
