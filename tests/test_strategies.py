"""SyncStrategy API tests: registry round-trip, plan/schedule/anchor parity
of the four migrated paper strategies against the seed's string-dispatch
behavior, and an end-to-end smoke step for every registered name."""
import numpy as np
import pytest

from repro.configs.base import ACESyncConfig
from repro.core.scheduler import Scheduler
from repro.launch.session import TrainSession
from repro.strategies import (SyncStrategy, build_strategy,
                              get_strategy, list_strategies,
                              register_strategy, resolve_strategy)
from repro.strategies import base as strategies_base

PAPER_STRATEGIES = ["fullsync", "topk", "fedavg", "acesync"]
GROUP_SIZES = [4096, 65536, 1024, 262144, 512, 1 << 20]


def _scheduler(n_pods=2):
    cfg = ACESyncConfig()
    return cfg, Scheduler(cfg, GROUP_SIZES, n_pods)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


class TestRegistry:
    def test_builtins_registered(self):
        for name in PAPER_STRATEGIES + ["localsgd", "bandwidth_tiered"]:
            assert name in list_strategies()

    def test_build_and_resolve(self):
        for name in list_strategies():
            s = build_strategy(name)
            assert isinstance(s, SyncStrategy)
            assert s.name == name
            assert resolve_strategy(name).name == name
            assert resolve_strategy(s) is s
            assert resolve_strategy(type(s)).name == name

    def test_unknown_name_raises(self):
        with pytest.raises(KeyError, match="unknown strategy"):
            get_strategy("no-such-strategy")

    def test_register_rejects_empty_and_duplicate_names(self):
        with pytest.raises(ValueError, match="non-empty"):
            register_strategy(type("Anon", (SyncStrategy,), {}))
        with pytest.raises(ValueError, match="already registered"):
            register_strategy(
                type("Clash", (SyncStrategy,), {"name": "fullsync"}))

    def test_custom_strategy_is_a_one_file_change(self):
        @register_strategy
        class Custom(SyncStrategy):
            name = "test-custom"

            def make_plan(self, scheduler, *, importance=None,
                          telemetry=None, omega=None):
                return scheduler.uniform_topk_plan(0.25, omega)

        try:
            assert "test-custom" in list_strategies()
            _, sched = _scheduler()
            plan = build_strategy("test-custom").make_plan(sched)
            assert all(sched.levels[i].is_topk for i in plan.level_idx)
        finally:
            strategies_base._REGISTRY.pop("test-custom")


# ---------------------------------------------------------------------------
# parity with the seed's string dispatch
# ---------------------------------------------------------------------------


def _seed_plan(strategy, scheduler, importance=None, bandwidth_mbps=50.0,
               omega=None):
    """The seed's Trainer.default_plan / TrainLoop.refresh_plan dispatch,
    verbatim."""
    if strategy == "fullsync":
        return scheduler.full_plan(omega)
    if strategy == "topk":
        return scheduler.uniform_topk_plan(0.1, omega)
    if strategy == "fedavg":
        return scheduler.full_plan(omega)
    imp = (importance if importance is not None
           else [1.0] * len(scheduler.sizes))
    return scheduler.plan(imp, bandwidth_mbps, omega)


def _seed_kinds(strategy, steps_since_sync, H):
    """The seed's TrainLoop.run_steps step-kind selection, verbatim."""
    if H <= 1:
        return ("grad_sync",)
    if (steps_since_sync + 1) % H:
        return ("local",)
    return ("local",
            "delta_sync" if strategy == "acesync" else "param_avg")


class TestSeedParity:
    @pytest.mark.parametrize("name", PAPER_STRATEGIES)
    @pytest.mark.parametrize("bw", [5.0, 30.0, 50.0, 120.0])
    @pytest.mark.parametrize("omega", [None, (0.7, 0.3)])
    def test_plans_byte_identical(self, name, bw, omega):
        _, sched_new = _scheduler()
        _, sched_old = _scheduler()
        imp = ([0.9, 0.1, 0.5, 1.0, 0.2, 0.7]
               if name == "acesync" else None)
        plan_new = build_strategy(name).make_plan(
            sched_new, importance=imp,
            telemetry=[{"bandwidth_mbps": bw}], omega=omega)
        plan_old = _seed_plan(name, sched_old, importance=imp,
                              bandwidth_mbps=bw, omega=omega)
        assert plan_new.level_idx == plan_old.level_idx
        assert plan_new.omega == plan_old.omega
        assert plan_new.sync_interval == plan_old.sync_interval
        assert plan_new.levels == plan_old.levels

    @pytest.mark.parametrize("name", PAPER_STRATEGIES)
    def test_step_schedule_matches_seed(self, name):
        strat = build_strategy(name)
        cfg = ACESyncConfig()
        # seed: H windows only for acesync/fedavg, else always 1
        H_seed = (cfg.sync_interval_init if name in ("acesync", "fedavg")
                  else 1)
        assert strat.initial_interval(cfg) == H_seed
        for H in (1, 2, cfg.sync_interval_init):
            for s in range(2 * max(H, 1) + 1):
                if H > 1 and name in ("fullsync", "topk"):
                    continue  # unreachable in the seed
                assert strat.step_schedule(s, H) == _seed_kinds(name, s, H)

    def test_anchor_matches_seed(self):
        for name in PAPER_STRATEGIES + ["localsgd", "bandwidth_tiered"]:
            seed_needs = name in ("acesync", "fedavg")
            assert build_strategy(name).needs_anchor == seed_needs


# ---------------------------------------------------------------------------
# every registered strategy trains end-to-end
# ---------------------------------------------------------------------------


class TestRoundTripSmoke:
    @pytest.mark.parametrize("name", list_strategies())
    def test_smoke_steps(self, name, tmp_path):
        steps = 3
        sess = TrainSession.from_config(
            "paper-350m", strategy=name, seq_len=32, batch=2, steps=steps,
            ckpt_every=0, ckpt_dir=str(tmp_path))
        sess.run(steps, log_every=0)
        assert len(sess.losses) == steps
        assert np.isfinite(sess.losses).all()
        assert sess.comm_bytes >= 0.0
        # the state holds what the strategy asked for
        assert ("anchor" in sess.state) == sess.strategy.needs_anchor


# ---------------------------------------------------------------------------
# scheduler seam used by knapsack-free strategies
# ---------------------------------------------------------------------------


class TestPlanFromLevels:
    def test_builds_plan(self):
        _, sched = _scheduler()
        idx = [1] * len(GROUP_SIZES)
        plan = sched.plan_from_levels(idx, sync_interval=1)
        assert plan.level_idx == tuple(idx)
        assert plan.sync_interval == 1

    def test_rejects_wrong_length(self):
        _, sched = _scheduler()
        with pytest.raises(ValueError, match="level indices"):
            sched.plan_from_levels([0, 1])

    def test_bandwidth_tiered_reacts_to_bandwidth(self):
        strat = build_strategy("bandwidth_tiered")
        _, sched = _scheduler()
        fat = strat.make_plan(sched,
                              telemetry=[{"bandwidth_mbps": 200.0}])
        thin = strat.make_plan(sched,
                               telemetry=[{"bandwidth_mbps": 5.0}])
        assert sched.plan_wire_bytes(thin) < sched.plan_wire_bytes(fat)
        # fat link: everything dense (INT8); thin link: big groups top-k
        assert all(not sched.levels[i].is_topk for i in fat.level_idx)
        assert any(sched.levels[i].is_topk for i in thin.level_idx)
