"""Attention-based importance estimator tests (paper eqs. 3-4)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import importance as imp


def _state(G=8, hidden=16, seed=0):
    return imp.init_state(jax.random.PRNGKey(seed), G, hidden)


def _struct(G=8):
    metas = [{"depth": i / (G - 1), "size": 10 ** (3 + i % 4),
              "kind": ["embed", "attn", "mlp", "other"][i % 4]}
             for i in range(G)]
    return imp.structural_features(metas)


class TestImportance:
    def test_scores_in_unit_interval(self):
        st = _state()
        sf = _struct()
        st = imp.update_stats(st, jnp.ones(8), jnp.ones(8), jnp.ones(8))
        s = imp.scores(st.params, imp.temporal_features(st), sf, alpha=0.5)
        assert s.shape == (8,)
        assert float(s.min()) >= 0.0 and float(s.max()) <= 1.0

    def test_alpha_mixes_branches(self):
        """eq (3): alpha=1 -> pure temporal, alpha=0 -> pure structural."""
        st = _state()
        sf = _struct()
        st = imp.update_stats(st, jnp.arange(8.0), jnp.ones(8),
                              jnp.arange(8.0))
        tf = imp.temporal_features(st)
        s_t = imp.scores(st.params, tf, sf, alpha=1.0)
        s_s = imp.scores(st.params, tf, sf, alpha=0.0)
        s_m = imp.scores(st.params, tf, sf, alpha=0.5)
        np.testing.assert_allclose(np.asarray(s_m),
                                   0.5 * np.asarray(s_t)
                                   + 0.5 * np.asarray(s_s), rtol=1e-5)

    def test_online_training_reduces_mse(self):
        """The estimator learns a fixed target pattern (the paper's
        gradient-snapshot supervision)."""
        G = 8
        st = _state(G)
        sf = _struct(G)
        target = jnp.asarray(np.linspace(0.1, 0.9, G), jnp.float32)
        first = None
        rng = np.random.RandomState(0)
        for t in range(300):
            ma = target * 2 + 0.05 * rng.rand(G)
            st = imp.update_stats(st, jnp.asarray(ma, jnp.float32),
                                  jnp.asarray(ma ** 2, jnp.float32),
                                  jnp.asarray(ma * 3, jnp.float32))
            st, mse = imp.train_step(st, sf, target, alpha=0.5, lr=3e-3)
            if first is None:
                first = float(mse)
        assert float(mse) < first * 0.5, (first, float(mse))

    def test_stats_ema(self):
        st = _state()
        st1 = imp.update_stats(st, jnp.ones(8), jnp.zeros(8), jnp.ones(8),
                               decay=0.5)
        np.testing.assert_allclose(np.asarray(st1.feat_ema[:, 0]), 0.5)
        st2 = imp.update_stats(st1, jnp.ones(8), jnp.zeros(8), jnp.ones(8),
                               decay=0.5)
        np.testing.assert_allclose(np.asarray(st2.feat_ema[:, 0]), 0.75)
        assert int(st2.step) == 2
