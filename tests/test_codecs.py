"""Codec subsystem tests: registry round-trip, bit-exact payload parity of
the four migrated seed rungs, error-feedback recomposition for every
registered codec (oracle AND Pallas path), packed-wire-size == analytic
accounting, and Level -> codec resolution."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests skip, the rest of the module runs
    from hypothesis_stub import given, settings, st

from repro.codecs import (Codec, build_codec, get_codec,
                          list_codecs, pack_bits, pack_payload,
                          plan_wire_bytes, register_codec, unpack_bits,
                          unpack_payload)
from repro.core import compression as C
from repro.core.compression import Level
from repro.core.scheduler import SyncPlan

BUILTINS = ["full", "int4", "int8", "sign", "skip", "topk"]


def _rand(n, seed=0):
    return jnp.asarray(np.random.RandomState(seed).randn(n)
                       .astype(np.float32))


def _default(name):
    return build_codec(name)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


class TestRegistry:
    def test_builtins_registered(self):
        assert list_codecs() == BUILTINS

    def test_build_and_get(self):
        for name in list_codecs():
            c = build_codec(name)
            assert isinstance(c, Codec)
            assert c.name == name
            assert get_codec(name) is type(c)

    def test_unknown_name_raises(self):
        with pytest.raises(KeyError, match="unknown codec"):
            get_codec("no-such-codec")

    def test_register_rejects_empty_and_duplicate(self):
        with pytest.raises(ValueError, match="non-empty"):
            register_codec(type("Anon", (Codec,), {}))
        with pytest.raises(ValueError, match="already registered"):
            register_codec(type("Clash", (Codec,), {"name": "int8"}))

    def test_topk_requires_valid_ratio(self):
        with pytest.raises(ValueError, match="ratio"):
            build_codec("topk", ratio=1.5)


# ---------------------------------------------------------------------------
# Level -> codec resolution
# ---------------------------------------------------------------------------


class TestLevelResolution:
    @pytest.mark.parametrize("level,codec_name", [
        (Level("FULL", 1.0, 16), "full"),
        (Level("INT8", 1.0, 8), "int8"),
        (Level("INT4", 1.0, 4), "int4"),
        (Level("SIGN1", 1.0, 1), "sign"),
        (Level("TOPK10_INT8", 0.10, 8), "topk"),
        (Level("SKIP", 0.0, 0), "skip"),
    ])
    def test_semantics(self, level, codec_name):
        assert level.codec.name == codec_name

    def test_topk_carries_ratio(self):
        assert Level("T", 0.25, 8).codec.keep_ratio == 0.25
        assert Level("T", 0.25, 8).codec.block_k(1024) == 256

    def test_resolution_cached(self):
        assert Level("A", 0.1, 8).codec is Level("B", 0.1, 8).codec


# ---------------------------------------------------------------------------
# bit-exact payload parity vs the seed operators
# ---------------------------------------------------------------------------


def _seed_topk_compress(blocks, k):
    """The seed's compression.topk_compress, frozen verbatim."""
    mag = jnp.abs(blocks)
    _, idx = jax.lax.top_k(mag, k)
    vals = jnp.take_along_axis(blocks, idx, axis=1)
    scale = jnp.max(jnp.abs(vals), axis=1) / 127.0
    scale = jnp.maximum(scale, 1e-30)
    q = jnp.clip(jnp.round(vals / scale[:, None]), -127, 127).astype(jnp.int8)
    return q, idx.astype(jnp.uint16), scale.astype(jnp.float32)


def _seed_int8_compress(blocks):
    """The seed's compression.int8_compress, frozen verbatim."""
    scale = jnp.max(jnp.abs(blocks), axis=1) / 127.0
    scale = jnp.maximum(scale, 1e-30)
    q = jnp.clip(jnp.round(blocks / scale[:, None]), -127, 127
                 ).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


class TestSeedPayloadParity:
    """The four seed rungs must migrate payload-identically: same bytes on
    the wire for the same input, bit for bit."""

    @pytest.mark.parametrize("seed", [0, 1, 7])
    @pytest.mark.parametrize("ratio", [0.25, 0.10, 0.01])
    def test_topk_bit_exact(self, seed, ratio):
        blocks = C.pad_to_blocks(_rand(8192, seed))
        codec = build_codec("topk", ratio=ratio)
        pay = codec.encode(blocks)
        q, idx, scale = _seed_topk_compress(blocks, codec.block_k(1024))
        np.testing.assert_array_equal(np.asarray(pay["q"]), np.asarray(q))
        np.testing.assert_array_equal(np.asarray(pay["idx"]),
                                      np.asarray(idx))
        np.testing.assert_array_equal(np.asarray(pay["scale"]),
                                      np.asarray(scale))

    @pytest.mark.parametrize("seed", [0, 3])
    def test_int8_bit_exact(self, seed):
        blocks = C.pad_to_blocks(_rand(4096, seed) * 10)
        pay = build_codec("int8").encode(blocks)
        q, scale = _seed_int8_compress(blocks)
        np.testing.assert_array_equal(np.asarray(pay["q"]), np.asarray(q))
        np.testing.assert_array_equal(np.asarray(pay["scale"]),
                                      np.asarray(scale))

    def test_full_bit_exact(self):
        blocks = C.pad_to_blocks(_rand(2048, 5))
        pay = build_codec("full").encode(blocks)
        np.testing.assert_array_equal(
            np.asarray(pay["wire"]),
            np.asarray(blocks.astype(jnp.bfloat16)))

    def test_skip_empty(self):
        assert build_codec("skip").encode(
            C.pad_to_blocks(_rand(1024))) == {}

    def test_wire_bytes_parity_with_seed_formulas(self):
        """FULL (ring psum) and TOPK (all_gather) keep the seed's exact
        byte formulas; INT8 now prices the block-padded payload that is
        actually packed on the wire."""
        n, P, block = 1_000_000, 2, 1024
        nb = (n + block - 1) // block
        assert Level("FULL", 1.0, 16).wire_bytes(n, P) == \
            int(2 * (P - 1) / P * 2 * n)
        for ratio in (0.25, 0.10, 0.01):
            lvl = Level("T", ratio, 8)
            k = lvl.block_k(block)
            assert lvl.wire_bytes(n, P) == (nb * k * 3 + 4 * nb) * (P - 1)
        assert Level("INT8", 1.0, 8).wire_bytes(n, P) == \
            (nb * block + 4 * nb) * (P - 1)
        # every codec is free when there is nobody to talk to
        for name in list_codecs():
            assert _default(name).wire_bytes(n, 1) == 0


# ---------------------------------------------------------------------------
# roundtrip + error-feedback recomposition properties
# ---------------------------------------------------------------------------


def _roundtrip_tol(codec, blocks):
    """Per-codec bound on |decode(encode(x)) - x| for kept entries."""
    absmax = float(jnp.max(jnp.abs(blocks)))
    if codec.name == "full":
        return absmax * 2 ** -8  # bf16 mantissa
    if codec.name == "int8":
        return absmax / 127.0 * 0.51 + 1e-6
    if codec.name == "int4":
        return absmax / 7.0 * 0.51 + 1e-6
    return None  # topk/sign/skip: lossy beyond a pointwise bound


class TestRoundTrip:
    @pytest.mark.parametrize("name", ["full", "int8", "int4"])
    def test_dense_roundtrip_error_bounded(self, name):
        codec = _default(name)
        blocks = C.pad_to_blocks(_rand(4096, 11) * 3)
        back = codec.decode(codec.encode(blocks), 1024)
        tol = _roundtrip_tol(codec, blocks)
        np.testing.assert_allclose(np.asarray(back), np.asarray(blocks),
                                   atol=tol)

    def test_sign_roundtrip_magnitude(self):
        codec = _default("sign")
        blocks = C.pad_to_blocks(_rand(2048, 12))
        back = codec.decode(codec.encode(blocks), 1024)
        # every reconstructed entry is +-(block mean magnitude), signs match
        scale = np.asarray(jnp.mean(jnp.abs(blocks), axis=1))
        np.testing.assert_allclose(
            np.abs(np.asarray(back)),
            np.broadcast_to(scale[:, None], back.shape), rtol=1e-6)
        assert np.all((np.asarray(back) >= 0) == (np.asarray(blocks) >= 0))

    def test_int4_roundtrip_through_level(self):
        out = C.roundtrip(_rand(3000, 13), Level("INT4", 1.0, 4))
        assert out.shape == (3000,)
        err = np.abs(np.asarray(out) - np.asarray(_rand(3000, 13)))
        assert err.max() <= float(jnp.abs(_rand(3000, 13)).max()) / 7 * 0.51 \
            + 1e-6

    @given(st.integers(min_value=0, max_value=2 ** 31 - 1))
    @settings(max_examples=15, deadline=None)
    def test_ef_recomposition_every_codec(self, seed):
        """agg/omega + new_e == g + gamma*e for EVERY registered codec —
        the lossless transmit/residual split error feedback relies on."""
        g = _rand(2048 + seed % 7, seed % 1000)
        e = _rand(g.shape[0], (seed + 1) % 1000) * 0.1
        om = jnp.ones((1,), jnp.float32)
        gamma = 0.7
        ef = np.asarray(g) + gamma * np.asarray(e)
        for name in list_codecs():
            agg, new_e = _default(name).ef_sync(
                g, e, om, om[0], gamma=gamma, n_pods=1, block=1024)
            np.testing.assert_allclose(np.asarray(agg + new_e), ef,
                                       rtol=1e-5, atol=1e-5,
                                       err_msg=name)

    @pytest.mark.parametrize("name", ["topk", "int8", "int4", "sign"])
    def test_ef_recomposition_pallas_path(self, name):
        """Same invariant through the fused Pallas kernels (interpret on
        CPU) — the path grad_sync/delta_sync exercise on accelerators."""
        g = _rand(5000, 21)
        e = _rand(5000, 22) * 0.2
        om = jnp.ones((1,), jnp.float32)
        agg, new_e = _default(name).ef_sync(
            g, e, om, om[0], gamma=1.0, n_pods=1, block=1024,
            use_pallas=True)
        np.testing.assert_allclose(np.asarray(agg + new_e),
                                   np.asarray(g + e), rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("name", ["int8", "int4", "sign"])
    def test_pallas_payload_matches_oracle(self, name):
        """Dense codecs: fused-kernel payload == oracle payload bit-exact
        (top-k is excluded: its bisection select tolerates threshold
        ties, covered by tests/test_kernels.py)."""
        g = _rand(3000, 31)
        e = _rand(3000, 32) * 0.3
        codec = _default(name)
        pay_o, own_o, _ = codec.ef_encode(g, e, gamma=0.9, block=1024,
                                          use_pallas=False)
        pay_p, own_p, _ = codec.ef_encode(g, e, gamma=0.9, block=1024,
                                          use_pallas=True)
        assert sorted(pay_o) == sorted(pay_p)
        for k in pay_o:
            a, b = np.asarray(pay_o[k]), np.asarray(pay_p[k])
            if a.dtype == np.float32:
                # fma-order differences (kernel vs oracle) reach ~1 ulp
                np.testing.assert_allclose(a, b, rtol=1e-6,
                                           err_msg=f"{name}/{k}")
            else:
                # a 1-ulp scale wiggle may flip a value sitting exactly on
                # a rounding boundary; allow <=0.1% of entries
                assert (a != b).mean() <= 1e-3, f"{name}/{k}"
        np.testing.assert_allclose(np.asarray(own_o), np.asarray(own_p),
                                   rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# chunked ring pipeline: decode-accumulate parity with the one-shot path
# ---------------------------------------------------------------------------


RING_CODECS = ["int8", "int4", "sign", "topk"]


def _payloads(codec, n, n_pods=2, block=1024):
    """One payload per virtual pod (different gradients per peer)."""
    outs = []
    for p in range(n_pods):
        pay, _, _ = codec.ef_encode(_rand(n, 40 + p),
                                    _rand(n, 50 + p) * 0.1, gamma=0.8,
                                    block=block)
        outs.append(pay)
    return outs


def _one_shot_agg(codec, payloads, omega, n, block=1024):
    """The one-shot path's aggregation math (what pod_exchange computes
    per peer from the gathered buffer), independent of the ring code."""
    if codec.name == "sign":
        vote = mag = None
        for w, pl_ in zip(omega, payloads):
            signs = unpack_bits(pl_["q"], block).astype(jnp.float32) * 2 - 1
            contrib, scale_c = w * signs, w * pl_["scale"]
            vote = contrib if vote is None else vote + contrib
            mag = scale_c if mag is None else mag + scale_c
        return (jnp.sign(vote) * mag[:, None]).reshape(-1)[:n]
    agg = jnp.zeros((n,), jnp.float32)
    for w, pl_ in zip(omega, payloads):
        agg = agg + w * codec.decode(pl_, block).reshape(-1)[:n]
    return agg


def _ring_agg(codec, payloads, omega, n, n_chunks, block=1024):
    """The ring path's math: chunk slices folded through accum_init /
    decode_accumulate / accum_finalize in the same peer order."""
    nb = (n + block - 1) // block
    assert nb % n_chunks == 0
    cb = nb // n_chunks
    parts = []
    for i in range(n_chunks):
        acc = codec.accum_init(cb, block)
        for w, pl_ in zip(omega, payloads):
            acc = codec.decode_accumulate(
                acc, codec._chunk_payload(pl_, i, cb), w, block=block)
        parts.append(codec.accum_finalize(acc, cb * block, block))
    return jnp.concatenate(parts)[:n]


class TestRingParity:
    @pytest.mark.parametrize("name", RING_CODECS)
    @pytest.mark.parametrize("n_chunks", [1, 2, 4])
    def test_ring_accumulate_bit_exact(self, name, n_chunks):
        """Chunked decode-accumulate == the one-shot aggregation, bit for
        bit, for every ring-capable codec (the exchange-level pin runs in
        tests/test_collectives.py on a real pod mesh)."""
        codec = _default(name)
        n = 4 * 1024
        omega = (0.6, 0.4)
        payloads = _payloads(codec, n)
        one = _one_shot_agg(codec, payloads, omega, n)
        ring = _ring_agg(codec, payloads, omega, n, n_chunks)
        np.testing.assert_array_equal(np.asarray(one), np.asarray(ring),
                                      err_msg=name)

    @pytest.mark.parametrize("name", RING_CODECS)
    def test_decode_accumulate_pallas_matches_oracle(self, name):
        """The fused Pallas decode-accumulate kernels (interpret on CPU)
        == the oracle acc + w * decode path."""
        codec = _default(name)
        n = 3 * 1024  # odd block count: exercises the ROWS padding
        pay, _, _ = codec.ef_encode(_rand(n, 60), jnp.zeros((n,)),
                                    gamma=1.0, block=1024)
        nb = 3
        w = jnp.float32(0.37)
        acc0 = codec.accum_init(nb, 1024)
        if name == "sign":
            acc0 = {"vote": jnp.asarray(
                        np.random.RandomState(1).randn(nb, 1024)
                        .astype(np.float32)),
                    "mag": jnp.abs(jnp.asarray(
                        np.random.RandomState(2).randn(nb)
                        .astype(np.float32)))}
        else:
            acc0 = jnp.asarray(np.random.RandomState(1).randn(nb, 1024)
                               .astype(np.float32))
        o = codec.decode_accumulate(acc0, pay, w, block=1024,
                                    use_pallas=False)
        p = codec.decode_accumulate(acc0, pay, w, block=1024,
                                    use_pallas=True)
        for a, b in zip(jax.tree.leaves(o), jax.tree.leaves(p)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-6, atol=1e-7,
                                       err_msg=name)

    @pytest.mark.parametrize("name", ["int8", "int4", "sign"])
    @pytest.mark.parametrize("use_pallas", [False, True])
    def test_deterministic_fold_is_order_insensitive(self, name,
                                                     use_pallas):
        """The P >= 3 mode: fixed-point / integer-vote partial sums reach
        bit-identical aggregates in ANY fold order (the float fold does
        not — that is the cross-pod drift the mode removes), and the
        fused Pallas kernels match the oracle bit for bit."""
        codec = _default(name)
        n = 4 * 1024
        omega = jnp.asarray([0.5, 0.3, 0.2], jnp.float32)
        payloads = _payloads(codec, n, n_pods=3)
        nb = 4

        def fold(order, det, up):
            acc = codec.accum_init(nb, 1024, deterministic=det)
            for j in order:
                acc = codec.decode_accumulate(acc, payloads[j], omega[j],
                                              block=1024, use_pallas=up,
                                              deterministic=det)
            return np.asarray(codec.accum_finalize(acc, n, 1024,
                                                   deterministic=det))

        a = fold([0, 1, 2], True, use_pallas)
        for order in ([2, 0, 1], [1, 2, 0], [2, 1, 0]):
            np.testing.assert_array_equal(a, fold(order, True, use_pallas),
                                          err_msg=f"{name}/{order}")
        # the dequant-add codecs also stay within the 2^-16 fixed-point
        # quantisation of the float fold (sign is excluded: a vote that
        # TIES in exact arithmetic legitimately resolves to 0 where the
        # float fold's rounding noise picked a side)
        if name != "sign":
            f = fold([0, 1, 2], False, use_pallas)
            np.testing.assert_allclose(a, f, atol=4 * 2.0 ** -16,
                                       err_msg=name)

    @pytest.mark.parametrize("name", ["int8", "int4", "sign"])
    def test_deterministic_pallas_matches_oracle_bitwise(self, name):
        """Integer accumulation admits no ulp wiggle: the fused fp
        kernels and the jnp oracle must agree EXACTLY."""
        codec = _default(name)
        n = 3 * 1024
        pay, _, _ = codec.ef_encode(_rand(n, 60), jnp.zeros((n,)),
                                    gamma=1.0, block=1024)
        w = jnp.float32(0.37)
        acc = codec.accum_init(3, 1024, deterministic=True)
        o = codec.decode_accumulate(acc, pay, w, block=1024,
                                    use_pallas=False, deterministic=True)
        p = codec.decode_accumulate(acc, pay, w, block=1024,
                                    use_pallas=True, deterministic=True)
        for a, b in zip(jax.tree.leaves(o), jax.tree.leaves(p)):
            assert a.dtype == b.dtype and a.dtype in (jnp.int32,)
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=name)

    def test_deterministic_one_shot_matches_ring_fold(self):
        """pod_exchange's deterministic fold (canonical gather order) ==
        the ring's arrival-order fold: exact accumulation makes the order
        irrelevant, so ring <-> one-shot replans never move the bits."""
        for name in ["int8", "int4", "sign"]:
            codec = _default(name)
            n = 4 * 1024
            omega = jnp.asarray([0.2, 0.5, 0.3], jnp.float32)
            payloads = _payloads(codec, n, n_pods=3)
            nb = 4
            # one-shot: pods 0..P-1; ring at pod 1: own, then 0, then 2
            accs = []
            for order in ([0, 1, 2], [1, 0, 2]):
                acc = codec.accum_init(nb, 1024, deterministic=True)
                for j in order:
                    acc = codec.decode_accumulate(
                        acc, payloads[j], omega[j], block=1024,
                        deterministic=True)
                accs.append(np.asarray(codec.accum_finalize(
                    acc, n, 1024, deterministic=True)))
            np.testing.assert_array_equal(accs[0], accs[1], err_msg=name)

    def test_old_style_trio_signature_stays_compatible(self):
        """A codec subclassed against the PRE-deterministic trio
        signature (no deterministic/fixed_bits kwargs) keeps working on
        every float path: the base exchange forwards the new kwargs only
        when the deterministic mode engages (Codec._det_kwargs)."""
        from repro.codecs.builtin import Int8Codec

        class OldTrio(Int8Codec):
            name = ""  # not registered

            def accum_init(self, nb, block=1024):
                return jnp.zeros((nb, block), jnp.float32)

            def decode_accumulate(self, acc, payload, weight, *,
                                  block=1024, use_pallas=False):
                return acc + weight * self.decode(payload, block)

            def accum_finalize(self, acc, n, block=1024):
                return acc.reshape(-1)[:n]

        old = OldTrio()
        init_kw, fold_kw = old._det_kwargs(False, 16)
        assert init_kw == {} and fold_kw == {}
        pay, _, _ = old.ef_encode(_rand(2048, 5), jnp.zeros((2048,)),
                                  gamma=1.0, block=1024)
        acc = old.accum_init(2, 1024, **init_kw)
        acc = old.decode_accumulate(acc, pay, jnp.float32(0.5),
                                    block=1024, **fold_kw)
        out = old.accum_finalize(acc, 2048, 1024, **fold_kw)
        assert out.shape == (2048,)
        # ...while the deterministic mode demands the new contract
        init_kw, fold_kw = old._det_kwargs(True, 16)
        assert init_kw == {"deterministic": True}
        assert fold_kw == {"deterministic": True, "fixed_bits": 16}

    def test_legacy_float_ring_fold_is_loud_error_on_p3(self):
        """Satellite pin: the order-sensitive float fold is unreachable
        on P >= 3 — explicitly requesting it raises instead of silently
        drifting (the old forced-ring bypass)."""
        codec = _default("int8")
        g, e = _rand(2048, 80), jnp.zeros((2048,))
        om = jnp.full((3,), 1 / 3, jnp.float32)
        with pytest.raises(ValueError, match="deterministic"):
            codec.ef_sync_ring(g, e, om, om[0], gamma=1.0, n_pods=3,
                               n_chunks=2, block=1024,
                               deterministic=False)

    @pytest.mark.parametrize("name", BUILTINS)
    def test_ring_single_pod_equals_one_shot(self, name):
        """ef_sync_ring degenerates to ef_sync off-mesh (and for the
        non-ring codecs FULL/SKIP it IS ef_sync by definition)."""
        codec = _default(name)
        g, e = _rand(2500, 70), _rand(2500, 71) * 0.2
        om = jnp.ones((1,), jnp.float32)
        a1, e1 = codec.ef_sync(g, e, om, om[0], gamma=0.9, n_pods=1,
                               block=1024)
        a2, e2 = codec.ef_sync_ring(g, e, om, om[0], gamma=0.9, n_pods=1,
                                    n_chunks=3, block=1024)
        np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))
        np.testing.assert_array_equal(np.asarray(e1), np.asarray(e2))


# ---------------------------------------------------------------------------
# packed wire buffer == analytic accounting
# ---------------------------------------------------------------------------


class TestPackedBytes:
    @pytest.mark.parametrize("n", [1024, 3000, 8192, 100_000])
    @pytest.mark.parametrize("name", ["int8", "int4", "sign", "topk"])
    def test_packed_size_equals_payload_bytes(self, n, name):
        """What pack_payload puts on the all_gather wire must be exactly
        what wire_bytes prices (the analytic == traced contract)."""
        codec = _default(name)
        payload, _, _ = codec.ef_encode(_rand(n, 3), jnp.zeros((n,)),
                                        gamma=1.0, block=1024)
        wire, meta = pack_payload(payload)
        assert wire.size == codec.payload_bytes(n, 1024)
        back = unpack_payload(wire, meta)
        for k in payload:
            np.testing.assert_array_equal(np.asarray(payload[k]),
                                          np.asarray(back[k]))

    def test_bit_pack_roundtrip(self):
        r = np.random.RandomState(0)
        bools = jnp.asarray(r.rand(4, 1024) > 0.5)
        packed = pack_bits(bools)
        assert packed.shape == (4, 128) and packed.dtype == jnp.uint8
        bits = unpack_bits(packed, 1024)
        np.testing.assert_array_equal(np.asarray(bits),
                                      np.asarray(bools).astype(np.uint8))


# ---------------------------------------------------------------------------
# bucketed plan pricing
# ---------------------------------------------------------------------------


class TestPlanPricing:
    def _plan(self, idx, omega=(0.5, 0.5)):
        cfg_levels = (Level("FULL", 1.0, 16), Level("INT8", 1.0, 8),
                      Level("TOPK10", 0.10, 8), Level("SKIP", 0.0, 0))
        return SyncPlan(tuple(idx), cfg_levels, omega, 1)

    def test_same_level_groups_priced_block_aligned(self):
        """Two same-level groups share ONE buffer and one collective, but
        each leaf is block-aligned in the static layout (the price of the
        retrace-free gather/scatter exchange): the bucket is priced at the
        sum of per-leaf block counts, exactly what per-group pricing
        gives — the knapsack's per-group accounting is exact."""
        sizes = [1500, 1500]  # 2 blocks each -> a 4-block bucket
        plan = self._plan([2, 2])
        bucketed = plan_wire_bytes(plan, sizes, 2)
        separate = sum(plan.levels[2].wire_bytes(n, 2) for n in sizes)
        assert bucketed == separate
        assert bucketed == plan.levels[2].wire_bytes(4 * 1024, 2)

    def test_mixed_plan_sums_buckets(self):
        sizes = [2048, 1024, 4096, 512]
        plan = self._plan([0, 1, 2, 3])
        expect = (plan.levels[0].wire_bytes(2048, 2)
                  + plan.levels[1].wire_bytes(1024, 2)
                  + plan.levels[2].wire_bytes(4096, 2))
        assert plan_wire_bytes(plan, sizes, 2) == expect

    def test_single_pod_free(self):
        plan = self._plan([0, 1, 2, 3], omega=(1.0,))
        assert plan_wire_bytes(plan, [1024] * 4, 1) == 0


# ---------------------------------------------------------------------------
# knapsack ladder with the widened rungs
# ---------------------------------------------------------------------------


class TestWidenedLadder:
    def test_default_ladder_resolves(self):
        from repro.configs.base import ACESyncConfig
        from repro.core.scheduler import levels_from_config
        names = {l.codec.name for l in levels_from_config(ACESyncConfig())}
        assert names == {"full", "int8", "int4", "sign", "topk", "skip"}

    def test_knapsack_prunes_dominated_rungs(self):
        """INT4 is cheaper AND higher-value than TOPK25, so a budget that
        can afford INT4 must never pick TOPK25."""
        from repro.configs.base import ACESyncConfig
        from repro.core import knapsack
        from repro.core.scheduler import levels_from_config
        levels = levels_from_config(ACESyncConfig())
        sizes = [10 ** 6] * 4
        full = sum(levels[0].wire_bytes(n, 2) for n in sizes)
        for frac in (0.1, 0.3, 0.6, 1.0):
            choice = knapsack.solve([1.0] * 4, sizes, levels, full * frac, 2)
            assert not any(levels[c].name == "TOPK25_INT8" for c in choice)

    def test_knapsack_value_monotone_in_budget_widened(self):
        from repro.configs.base import ACESyncConfig
        from repro.core import knapsack
        from repro.core.scheduler import levels_from_config
        levels = levels_from_config(ACESyncConfig())
        sizes = [10 ** 6, 5 * 10 ** 5, 10 ** 5]
        imp = [0.9, 0.5, 0.2]
        full = sum(levels[0].wire_bytes(n, 2) for n in sizes)
        prev = -1.0
        for frac in (0.0, 0.05, 0.15, 0.4, 0.8, 1.0):
            choice = knapsack.solve(imp, sizes, levels, full * frac, 2)
            val = sum(knapsack.level_value(levels[c]) * imp[i]
                      for i, c in enumerate(choice))
            assert val >= prev - 1e-9
            prev = val


# ---------------------------------------------------------------------------
# backend dispatch caching (the hoisted _on_cpu satellite)
# ---------------------------------------------------------------------------


class TestDispatchCaching:
    def test_cached_and_env_override(self, monkeypatch):
        from repro.kernels import ops
        ops.interpret_mode.cache_clear()
        ops.default_use_pallas.cache_clear()
        try:
            monkeypatch.setenv(ops.FORCE_INTERPRET_ENV, "1")
            ops.interpret_mode.cache_clear()
            ops.default_use_pallas.cache_clear()
            assert ops.interpret_mode() is True
            assert ops.default_use_pallas() is True
            monkeypatch.setenv(ops.FORCE_INTERPRET_ENV, "0")
            ops.interpret_mode.cache_clear()
            ops.default_use_pallas.cache_clear()
            assert ops.interpret_mode() is False
            assert ops.default_use_pallas() is False
            # cached: flipping the env without a cache clear is invisible
            monkeypatch.setenv(ops.FORCE_INTERPRET_ENV, "1")
            assert ops.interpret_mode() is False
        finally:
            monkeypatch.delenv(ops.FORCE_INTERPRET_ENV, raising=False)
            ops.interpret_mode.cache_clear()
            ops.default_use_pallas.cache_clear()
