"""The trip-count-aware HLO cost walker vs known ground truth."""
import jax
import jax.numpy as jnp
import pytest

import os
import sys
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
from benchmarks import hlo_cost  # noqa: E402

D = 256


def _flops(fn, *specs):
    c = jax.jit(fn).lower(*specs).compile()
    rep = hlo_cost.analyze(c.as_text(), (1,), ("data",))
    return rep


class TestWalker:
    def test_unrolled_dot_flops_exact(self):
        def f(x, w):
            for _ in range(3):
                x = x @ w
            return x
        spec = jax.ShapeDtypeStruct((D, D), jnp.float32)
        rep = _flops(f, spec, spec)
        assert rep.op_flops["dot"] == pytest.approx(3 * 2 * D ** 3)

    def test_scan_trip_count_multiplied(self):
        """The whole point of the walker: scans count body x trip."""
        def f(x, ws):
            def body(x, w):
                return jnp.tanh(x @ w), None
            return jax.lax.scan(body, x, ws)[0]
        x = jax.ShapeDtypeStruct((D, D), jnp.float32)
        ws = jax.ShapeDtypeStruct((5, D, D), jnp.float32)
        rep = _flops(f, x, ws)
        assert rep.op_flops["dot"] == pytest.approx(5 * 2 * D ** 3)

    def test_nested_scan_multiplies(self):
        def f(x, ws):
            def outer(x, w):
                def inner(y, _):
                    return y @ w, None
                y, _ = jax.lax.scan(inner, x, None, length=3)
                return y, None
            return jax.lax.scan(outer, x, ws)[0]
        x = jax.ShapeDtypeStruct((D, D), jnp.float32)
        ws = jax.ShapeDtypeStruct((4, D, D), jnp.float32)
        rep = _flops(f, x, ws)
        assert rep.op_flops["dot"] == pytest.approx(4 * 3 * 2 * D ** 3)

    def test_shape_parse(self):
        shapes = hlo_cost.parse_shapes("(f32[2,3]{1,0}, bf16[4], pred[])")
        assert [s.bytes for s in shapes] == [24, 8, 1]

    def test_replica_group_classification(self):
        groups = [[0, 1], [2, 3], [4, 5], [6, 7]]
        axis, size = hlo_cost.classify_axes(groups, (2, 2, 2),
                                            ("pod", "data", "model"))
        assert axis == "model" and size == 2
        groups2 = [[0, 4], [1, 5], [2, 6], [3, 7]]
        axis2, _ = hlo_cost.classify_axes(groups2, (2, 2, 2),
                                          ("pod", "data", "model"))
        assert axis2 == "pod"

    def test_iota_replica_groups(self):
        g = hlo_cost._parse_replica_groups(
            "replica_groups=[4,2]<=[8], metadata=")
        assert g == [[0, 1], [2, 3], [4, 5], [6, 7]]
        g2 = hlo_cost._parse_replica_groups(
            "replica_groups=[2,4]<=[4,2]T(1,0), metadata=")
        assert g2 == [[0, 2, 4, 6], [1, 3, 5, 7]]
