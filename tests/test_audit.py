"""Graph auditor: each pass trips on its seeded violation and stays
silent on the shipped code.

Fast tests seed violations synthetically (handcrafted HLO, broken
ExecPlans, poisoned sources, off-by-one BlockSpecs); the slow test runs
the real CLI end-to-end on the simulated 8-device mesh, like
tests/test_collectives.py."""
import dataclasses
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from repro.analysis import (  # noqa: E402
    AuditReport, audit_collectives, audit_donation, audit_exec_plan,
    audit_host_sync, audit_kernels, audit_plan_pair, check_record,
    expected_schedule, extract_collectives, parse_input_output_aliases,
    permute_direction)
from repro.analysis import lint_rules  # noqa: E402
from repro.analysis.pallas_audit import (  # noqa: E402
    PallasCallRecord, capture_pallas_calls)
from repro.core.compression import Level  # noqa: E402
from repro.core.planexec import ExecPlan  # noqa: E402

MESH = ((2, 2, 2), ("pod", "data", "model"))


def _plan(levels, sig, block=2048, **kw):
    perms = tuple(jnp.zeros((max(s, 1),), jnp.int32) for s in sig)
    return ExecPlan(levels=tuple(levels), sig=tuple(sig), block=block,
                    total_blocks=sum(sig), perms=perms,
                    omega=jnp.ones((2,), jnp.float32), **kw)


def _hlo(body: str) -> str:
    return ("HloModule seeded\n\n"
            "ENTRY %main.1 (p0.1: f32[2048]) -> f32[2048] {\n"
            "  %p0.1 = f32[2048]{0} parameter(0)\n"
            "  %h = bf16[2048]{0} convert(f32[2048]{0} %p0.1)\n"
            + body +
            "  ROOT %r = f32[2048]{0} copy(f32[2048]{0} %p0.1)\n}\n")


# the pod axis on a (2,2,2) pod-major mesh: devices 4 apart
_POD_GROUPS = "replica_groups={{0,4},{1,5},{2,6},{3,7}}"


class TestCollectivePass:
    """Pass 1: traced schedule vs analytic ExecPlan accounting."""

    def test_matching_schedule_is_clean(self):
        # one FULL rung of 1 block: analytic = 2(P-1)/P * 2n = 2n bytes
        ep = _plan([Level("FULL", 1.0, 16)], [1])
        txt = _hlo("  %ar = bf16[2048]{0} all-reduce(bf16[2048]{0} %h), "
                   + _POD_GROUPS + ", to_apply=%add\n")
        rep = AuditReport()
        out = audit_collectives(txt, ep, *MESH, n_pods=2, n_edge=1,
                                report=rep)
        assert rep.ok, rep.summary()
        assert out["traced"]["slow_bytes"] == pytest.approx(
            out["expected"]["slow_bytes"])

    def test_byte_mismatch_trips(self):
        # traced moves an f32[2048] all-reduce (8192B wire) against an
        # analytic schedule of 4096B + 4096B promotion slack -> 8192 is
        # within slack, so double the traced payload to break it
        ep = _plan([Level("FULL", 1.0, 16)], [1])
        txt = _hlo("  %big = f32[4096]{0} concatenate(f32[2048]{0} %p0.1, "
                   "f32[2048]{0} %p0.1), dimensions={0}\n"
                   "  %ar = f32[4096]{0} all-reduce(f32[4096]{0} %big), "
                   + _POD_GROUPS + ", to_apply=%add\n")
        rep = AuditReport()
        audit_collectives(txt, ep, *MESH, n_pods=2, n_edge=1, report=rep)
        assert not rep.ok
        assert any("slow-tier" in v.message for v in rep.errors())

    def test_missing_ring_permutes_trip(self):
        # chunks=(2,) promises K*(P-1)=2 ppermutes; the traced module
        # all-reduces instead
        ep = _plan([Level("INT8", 1.0, 8)], [1], chunks=(2,))
        txt = _hlo("  %ar = bf16[2048]{0} all-reduce(bf16[2048]{0} %h), "
                   + _POD_GROUPS + ", to_apply=%add\n")
        rep = AuditReport()
        audit_collectives(txt, ep, *MESH, n_pods=2, n_edge=1, report=rep)
        assert any("ppermute count" in v.message for v in rep.errors())

    def test_metric_pmeans_excluded(self):
        # a scalar loss pmean must not count as sync traffic
        ep = _plan([Level("FULL", 1.0, 16)], [1])
        txt = _hlo("  %loss = f32[2]{0} slice(f32[2048]{0} %p0.1), "
                   "slice={[0:2]}\n"
                   "  %m = f32[2]{0} all-reduce(f32[2]{0} %loss), "
                   + _POD_GROUPS + ", to_apply=%add\n"
                   "  %ar = bf16[2048]{0} all-reduce(bf16[2048]{0} %h), "
                   + _POD_GROUPS + ", to_apply=%add\n")
        rep = AuditReport()
        out = audit_collectives(txt, ep, *MESH, n_pods=2, n_edge=1,
                                report=rep)
        assert rep.ok, rep.summary()
        assert out["traced"]["n_metric_collectives"] == 1

    def test_permute_direction_classification(self):
        fwd = [(0, 1), (1, 2), (2, 3), (3, 0)]
        bwd = [(1, 0), (2, 1), (3, 2), (0, 3)]
        stride2 = [(0, 2), (1, 3), (2, 0), (3, 1)]
        assert permute_direction(fwd, (4,)) == "fwd"
        assert permute_direction(bwd, (4,)) == "bwd"
        assert permute_direction(stride2, (4,)) == "other"

    def test_expected_schedule_hier_tiers(self):
        from repro.core import planexec
        ep = _plan([Level("INT8", 1.0, 8)], [1],
                   hier=(planexec.INTRA_INT8,))
        want = expected_schedule(ep, n_pods=4, n_edge=2)
        assert want["n_cross"] == 2
        assert want["intra_bytes"] > 0
        assert want["slow_bytes"] < expected_schedule(ep, 4, 1)["slow_bytes"]


class TestDonationPass:
    """Pass 2: donate_argnums buffers must alias in the executable."""

    def _compiled_text(self, donate):
        kw = {"donate_argnums": (0,)} if donate else {}

        def f(x, y):
            return x * 2.0 + y, (x[:1] * 0.0)

        spec = jax.ShapeDtypeStruct((4096,), jnp.float32)
        return jax.jit(f, **kw).lower(spec, spec).compile().as_text()

    def test_donated_buffer_aliases_clean(self):
        txt = self._compiled_text(donate=True)
        assert parse_input_output_aliases(txt) == {0}
        rep = AuditReport()
        out = audit_donation(txt, [("['x']", 4096 * 4)], rep)
        assert rep.ok, rep.summary()
        assert out["n_missing"] == 0

    def test_undonated_buffer_trips(self):
        txt = self._compiled_text(donate=False)
        rep = AuditReport()
        out = audit_donation(txt, [("['x']", 4096 * 4)], rep)
        assert not rep.ok
        assert out["n_missing"] == 1
        assert any("NOT aliased" in v.message for v in rep.errors())

    def test_scalar_leaves_exempt(self):
        txt = self._compiled_text(donate=False)
        rep = AuditReport()
        audit_donation(txt, [("['step']", 4)], rep)
        # below the floor: only the "no alias map" violation may fire
        assert all("NOT aliased" not in v.message for v in rep.errors())


_HOT_ITEM_SRC = '''
class Loop:
    def run_steps(self, state, n):
        for _ in range(n):
            state = self.step(state)
            self.report(state)
        return state

    def step(self, state):
        return state

    def report(self, state):
        loss = state["loss"].item()
        x = np.asarray(jax.device_get(state["x"]))
        return loss, x
'''

_GUARDED_SRC = '''
class Loop:
    def run_steps(self, state, n):
        for _ in range(n):
            self.poll(state)
        return state

    def poll(self, state):
        if not _device_ready(state["sig"]):
            return None
        return np.asarray(jax.device_get(state["sig"]))
'''


class TestHostSyncPass:
    """Pass 3: no implicit device->host blocking on the hot path."""

    def test_injected_item_trips(self):
        rep = AuditReport()
        audit_host_sync(_HOT_ITEM_SRC, rep)
        msgs = [v.message for v in rep.errors()]
        assert any(".item()" in m for m in msgs)
        assert any("jax.device_get" in m for m in msgs)

    def test_readiness_guard_exempts(self):
        rep = AuditReport()
        audit_host_sync(_GUARDED_SRC, rep)
        assert rep.ok, rep.summary()

    def test_shipped_train_loop_is_clean(self):
        from repro.launch.train import TrainLoop
        rep = AuditReport()
        info = audit_host_sync(TrainLoop, rep)
        assert rep.ok, rep.summary()
        # the allowlist is load-bearing: the documented blockers were seen
        assert "_flush_metrics" in info["allowlisted"]


class TestRecompilePass:
    """Pass 4: plan fields must not widen the compiled-step cache."""

    def _ep(self):
        return _plan([Level("FULL", 1.0, 16), Level("INT8", 1.0, 8)],
                     [1, 1])

    def test_shipped_plan_shape_is_clean(self):
        rep = AuditReport()
        info = audit_exec_plan(self._ep(), rep)
        assert rep.ok, rep.summary()
        assert info["static_key_hashable"] and info["aux_fields_in_key"]

    def test_unhashable_field_trips(self):
        ep = dataclasses.replace(self._ep(), sig=[1, 1])
        rep = AuditReport()
        audit_exec_plan(ep, rep)
        assert any("unhashable" in v.message for v in rep.errors())

    def test_python_scalar_child_trips(self):
        ep = dataclasses.replace(self._ep(), omega=(1.0, 1.0))
        rep = AuditReport()
        audit_exec_plan(ep, rep)
        assert any("trace constant" in v.message for v in rep.errors())

    def test_replan_keeps_static_key(self):
        ep = self._ep()
        rep = AuditReport()
        assert audit_plan_pair(ep, ep.with_omega(ep.omega * 0.5),
                               expect_same=True, report=rep)
        assert rep.ok
        ep2 = dataclasses.replace(ep, sig=(2, 0))
        assert not audit_plan_pair(ep, ep2, expect_same=True, report=rep)
        assert not rep.ok


class TestPallasPass:
    """Pass 5: BlockSpec tiling + index-map bounds per kernel."""

    def test_off_by_one_block_trips(self):
        from jax.experimental import pallas as pl
        rec = PallasCallRecord(
            kernel_name="bad_tile", grid=(4,),
            in_specs=[pl.BlockSpec((8, 1000), lambda i: (i, 0))],
            out_specs=[], in_shapes=[(32, 1024)], out_shapes=[])
        rep = AuditReport()
        check_record(rec, rep)
        assert any("does not divide" in v.message for v in rep.errors())

    def test_out_of_bounds_index_map_trips(self):
        from jax.experimental import pallas as pl
        rec = PallasCallRecord(
            kernel_name="oob_map", grid=(4,),
            in_specs=[pl.BlockSpec((8, 1024), lambda i: (i + 1, 0))],
            out_specs=[], in_shapes=[(32, 1024)], out_shapes=[])
        rep = AuditReport()
        check_record(rec, rep)
        assert any("out of bounds" in v.message for v in rep.errors())

    def test_capture_intercepts_without_running(self):
        from repro.kernels import quantize
        g = jnp.ones((32, 1024), jnp.float32)
        with capture_pallas_calls() as records:
            out = getattr(quantize.quantize_int8_fused, "__wrapped__")(
                g, interpret=True)
        assert records and records[0].grid == (4,)
        # the fake returns zeros: proof no kernel body executed
        assert all(float(jnp.sum(jnp.abs(o))) == 0.0
                   for o in jax.tree.leaves(out))

    def test_shipped_kernels_are_clean(self):
        rep = AuditReport()
        info = audit_kernels(rep)
        assert rep.ok, rep.summary()
        assert len(info["kernels_checked"]) >= 15
        assert not info["kernels_failed"]


class TestLintRules:
    """The AST convention pack."""

    def test_python_rng_in_device_code_trips(self):
        import ast
        tree = ast.parse("import numpy as np\n"
                         "def draw():\n"
                         "    return np.random.randn(4)\n")
        rep = AuditReport()
        lint_rules.check_python_rng("core/fake.py", tree, rep)
        assert any("Python RNG" in v.message for v in rep.errors())
        rep2 = AuditReport()  # host-side module: exempt
        lint_rules.check_python_rng("data/fake.py", tree, rep2)
        assert rep2.ok

    def test_unregistered_codec_trips(self):
        import ast
        tree = ast.parse("class MyCodec(Codec):\n"
                         "    name = 'mine'\n"
                         "class _Base(Codec):\n"
                         "    pass\n"
                         "class Sub(_Base):\n"
                         "    name = 'sub'\n")
        rep = AuditReport()
        lint_rules.check_registration("codecs/fake.py", tree, rep)
        bad = {v.details["class"] for v in rep.errors()}
        assert bad == {"MyCodec", "Sub"}  # transitive base tracked

    def test_device_plan_host_sync_trips(self):
        import ast
        tree = ast.parse(
            "def device_replan_fn(s, cfg):\n"
            "    def inner(x):\n"
            "        return helper(x)\n"
            "    return inner\n"
            "def helper(x):\n"
            "    return jax.device_get(x)\n")
        rep = AuditReport()
        lint_rules.check_device_plan_sync("core/fake.py", tree, rep)
        assert any("device control-plane" in v.message
                   for v in rep.errors())

    def test_shipped_tree_is_clean(self):
        import repro
        root = os.path.abspath(next(iter(repro.__path__)))
        rep = AuditReport()
        info = lint_rules.audit_conventions(root, rep)
        assert rep.ok, rep.summary()
        assert info["n_files"] > 40


class TestReportShape:
    def test_serialization_roundtrip(self):
        import json
        rep = AuditReport()
        rep.ran("collective_schema")
        rep.add("collective_schema", "step", "boom", details={"x": 1})
        rep.add("donation_alias", "step", "meh", severity="warning")
        d = json.loads(rep.to_json())
        assert d["ok"] is False
        assert d["n_errors"] == 1 and d["n_warnings"] == 1
        assert d["violations"][0]["pass_name"] == "collective_schema"


def test_extract_collectives_hier_axes():
    """Flat rungs on a hier mesh gather over pod+edge; the auditor must
    classify that as slow tier (regression guard for the tier split)."""
    txt = ("HloModule t\n\nENTRY %e (p: f32[1024]) -> f32[1024] {\n"
           "  %p = f32[1024]{0} parameter(0)\n"
           "  %ag = f32[4096]{0} all-gather(f32[1024]{0} %p), "
           "replica_groups={{0,2,4,6},{1,3,5,7}}, dimensions={0}\n"
           "  ROOT %r = f32[1024]{0} copy(f32[1024]{0} %p)\n}\n")
    recs = extract_collectives(txt, (2, 2, 2), ("pod", "edge", "data"))
    assert len(recs) == 1
    assert set(recs[0].axis.split("+")) == {"pod", "edge"}


@pytest.mark.slow
def test_audit_cli_end_to_end(tmp_path):
    """scripts/audit.py gates clean on the shipped fullsync strategy."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src") + os.pathsep + root
    env.pop("XLA_FLAGS", None)
    out = tmp_path / "AUDIT.json"
    r = subprocess.run(
        [sys.executable, os.path.join(root, "scripts", "audit.py"),
         "--strategy", "fullsync", "--fail-on-violation",
         "--out", str(out)],
        env=env, capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-3000:]
    import json
    payload = json.loads(out.read_text())
    assert payload["ok"] is True
    assert payload["info"]["fullsync"]["donation"]["n_missing"] == 0
    assert set(payload["passes"]) >= {"collective_schema",
                                      "donation_alias", "host_sync",
                                      "recompile_hazard",
                                      "pallas_blockspec", "lint_rules"}
