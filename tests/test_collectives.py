"""Traced-HLO contract of the bucketed codec sync (8 virtual devices).

Two acceptance properties of the codec refactor, pinned on the lowered
HLO of a multi-pod ``sync_tree``:

  1. at most ONE pod collective per DISTINCT codec level in the plan
     (same-level leaves bucket into one buffer; each codec packs its whole
     payload pytree into one uint8 wire buffer);
  2. the analytic accounting (``wire_bytes_of_plan`` — what the Scheduler,
     knapsack and Table 1 price) EQUALS the traced collective bytes on the
     pod axis, for every codec including the bf16 psum of FULL (the seed
     priced bf16 but psum'd f32 — the drift this refactor removed).

XLA locks the device count at first use, so this runs in a subprocess with
XLA_FLAGS set, like tests/test_multipod.py."""
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core import sync as S
from repro.core.compression import Level
from repro.core.scheduler import SyncPlan
from repro.launch.mesh import make_mesh
from benchmarks import hlo_cost

mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
levels = (Level("FULL", 1.0, 16), Level("INT8", 1.0, 8),
          Level("TOPK10", 0.10, 8), Level("SIGN1", 1.0, 1),
          Level("SKIP", 0.0, 0))
# 6 leaves, two sharing TOPK10 -> 4 distinct collective-bearing levels
level_names = ["FULL", "INT8", "TOPK10", "TOPK10", "SIGN1", "SKIP"]
names = [l.name for l in levels]
idx = tuple(names.index(n) for n in level_names)
sizes = [2048, 3000, 1500, 1500, 2300, 700]   # non-block-multiples too
plan = SyncPlan(idx, levels, (0.5, 0.5), 1)

r = np.random.RandomState(0)
tree = {f"p{i}": jnp.asarray(r.randn(n).astype(np.float32))
        for i, n in enumerate(sizes)}
errors = jax.tree.map(jnp.zeros_like, tree)


def inner(t, e):
    return S.sync_tree(t, e, plan, mesh=mesh, shardings=None, gamma=1.0,
                       inside_manual=True)


smapped = compat.shard_map(
    inner, mesh,
    in_specs=(jax.tree.map(lambda _: P(), tree),
              jax.tree.map(lambda _: P(), errors)),
    out_specs=(jax.tree.map(lambda _: P(), tree),
               jax.tree.map(lambda _: P(), errors)),
    manual_axes=set(mesh.axis_names))
fn = jax.jit(smapped)

# --- run it: EF invariant survives the real multi-pod exchange ----------
agg, new_e = fn(tree, errors)
for k in tree:
    a = np.asarray(jax.device_get(agg[k]))
    assert np.isfinite(a).all(), k
    if k != "p5":  # non-SKIP leaves: per-pod own+residual == ef, and with
        # identical per-pod inputs the aggregate equals own
        np.testing.assert_allclose(np.asarray(agg[k] + new_e[k]),
                                   np.asarray(tree[k]), rtol=1e-4,
                                   atol=1e-4)

# --- traced-HLO assertions ---------------------------------------------
txt = fn.lower(tree, errors).compile().as_text()
rep = hlo_cost.analyze(txt, (2, 2, 2), ("pod", "data", "model"))
n_distinct_wire_levels = 4  # FULL, INT8, TOPK10 (bucketed x2), SIGN1
pod_count = rep.collective_count.get("pod", 0)
assert 1 <= pod_count <= n_distinct_wire_levels, \
    f"pod collectives {pod_count} > {n_distinct_wire_levels}: " \
    f"{dict(rep.collective_count)}"

analytic = S.wire_bytes_of_plan(plan, sizes, n_pods=2)
traced = rep.collective_bytes.get("pod", 0.0)
# XLA's bf16 normalization pass promotes the FULL bucket's bf16
# all-reduce to f32 on backends without native bf16 reduction (this CPU
# container); on TPU it stays bf16.  Accept exactly those two totals —
# every all_gather codec must match to the byte either way.
full_part = levels[0].wire_bytes(sizes[0], 2)
assert traced in (float(analytic), float(analytic + full_part)), \
    f"analytic {analytic} (or promoted {analytic + full_part}) " \
    f"!= traced {traced}"
# no sync traffic may leak onto the fast axes
for ax, b in rep.collective_bytes.items():
    if "pod" not in ax:
        assert b == 0.0, (ax, b)
print("COLLECTIVES_OK", pod_count, int(analytic))
"""


@pytest.mark.slow
def test_bucketed_sync_collectives_subprocess():
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src") + os.pathsep + root
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "COLLECTIVES_OK" in r.stdout


RING_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core import sync as S
from repro.core.compression import Level
from repro.core.planexec import build_exec_plan, sig_wire_bytes
from repro.core.scheduler import SyncPlan
from repro.launch.mesh import make_mesh
from benchmarks import hlo_cost

mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
# every ring-capable codec rings; FULL/SKIP stay on their one-shot path
levels = (Level("INT8", 1.0, 8), Level("TOPK10", 0.10, 8),
          Level("SIGN1", 1.0, 1), Level("INT4", 1.0, 4),
          Level("FULL", 1.0, 16), Level("SKIP", 0.0, 0))
idx = tuple(range(6))
sizes = [6000, 8192, 4100, 6000, 2048, 700]
plan = SyncPlan(idx, levels, (0.6, 0.4), 1)

r = np.random.RandomState(7)
tree = {f"p{i}": jnp.asarray(r.randn(n).astype(np.float32))
        for i, n in enumerate(sizes)}
errors = jax.tree.map(lambda x: jnp.ones_like(x) * 0.03, tree)
K = 2
ep_ring = build_exec_plan(plan, sizes, n_pods=2, ring=K)
ep_one = build_exec_plan(plan, sizes, n_pods=2, ring=0)
assert ep_ring.chunks == (K, K, K, K, 0, 0), ep_ring.chunks
assert ep_one.chunks == (0,) * 6, ep_one.chunks
# chunk rounding only pads rungs whose class is not a K multiple
assert all(s % K == 0 for s, c in zip(ep_ring.sig, ep_ring.chunks) if c)


def run(ep):
    def inner(t, e):
        return S.sync_tree(t, e, ep, mesh=mesh, shardings=None,
                           gamma=0.9, inside_manual=True)
    smapped = compat.shard_map(
        inner, mesh,
        in_specs=(jax.tree.map(lambda _: P(), tree),
                  jax.tree.map(lambda _: P(), errors)),
        out_specs=(jax.tree.map(lambda _: P(), tree),
                   jax.tree.map(lambda _: P(), errors)),
        manual_axes=set(mesh.axis_names))
    return jax.jit(smapped)


fn_ring, fn_one = run(ep_ring), run(ep_one)

# --- exchange parity: ring == one-shot ----------------------------------
agg_r, err_r = fn_ring(tree, errors)
agg_o, err_o = fn_one(tree, errors)
for k in tree:
    # residuals are device-local (no exchange in the loop): bit-exact
    np.testing.assert_array_equal(np.asarray(jax.device_get(err_r[k])),
                                  np.asarray(jax.device_get(err_o[k])),
                                  err_msg=k)
    # aggregates: the same omega-weighted two-term sums; XLA fusion may
    # re-associate the dense FMA by 1 ulp
    np.testing.assert_allclose(np.asarray(jax.device_get(agg_r[k])),
                               np.asarray(jax.device_get(agg_o[k])),
                               rtol=3e-7, atol=3e-7, err_msg=k)

# --- traced-HLO: exactly K ppermutes per ringing rung, same pod bytes ---
import re
txt = fn_ring.lower(tree, errors).compile().as_text()
rep = hlo_cost.analyze(txt, (2, 2, 2), ("pod", "data", "model"))
n_ring_rungs = sum(1 for c in ep_ring.chunks if c)
expect_permutes = K * (2 - 1) * n_ring_rungs
got_permutes = len(re.findall(
    r"=\s+\S+\s+collective-permute(?:-start)?\(", txt))
assert got_permutes == expect_permutes, (got_permutes, expect_permutes)
# pod collectives overall: K ppermutes per ringing rung + 1 for FULL
assert rep.collective_count.get("pod", 0) == expect_permutes + 1, \
    dict(rep.collective_count)
for ax, b in rep.collective_bytes.items():
    if "pod" not in ax:
        assert b == 0.0, (ax, b)

analytic = sig_wire_bytes(ep_ring.sig, ep_ring.levels, 2)
traced = rep.collective_bytes.get("pod", 0.0)
# XLA promotes FULL's bf16 all-reduce to f32 on CPU (see SCRIPT above)
full_part = levels[4].wire_bytes(ep_ring.sig[4] * 1024, 2)
assert traced in (float(analytic), float(analytic + full_part)), \
    (analytic, traced)
# the ring moves exactly the one-shot all_gather receive volume; only the
# K-multiple rounding of the signature pads, and that is priced in sig
txt_o = fn_one.lower(tree, errors).compile().as_text()
rep_o = hlo_cost.analyze(txt_o, (2, 2, 2), ("pod", "data", "model"))
analytic_o = sig_wire_bytes(ep_one.sig, ep_one.levels, 2)
traced_o = rep_o.collective_bytes.get("pod", 0.0)
assert traced_o in (float(analytic_o), float(analytic_o + full_part)), \
    (analytic_o, traced_o)
ring_pad = analytic - analytic_o
assert 0 <= ring_pad <= sum(
    lv.wire_bytes((K - 1) * 1024, 2)
    for lv, c in zip(levels, ep_ring.chunks) if c), ring_pad
print("RING_OK", got_permutes, int(analytic))
"""


@pytest.mark.slow
def test_ring_exchange_collectives_subprocess():
    """The chunked ring pipeline: bit-parity with the one-shot exchange,
    exactly K ppermutes per ringing rung in the lowered HLO, and analytic
    == traced wire bytes (the ring moves the all_gather receive volume)."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src") + os.pathsep + root
    r = subprocess.run([sys.executable, "-c", RING_SCRIPT], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "RING_OK" in r.stdout


# The P >= 3 deterministic-accumulation contract, soaked on real pod
# meshes.  Parameterised via env vars (XLA locks the device count per
# process): REPRO_TEST_PODS, REPRO_TEST_MESH, REPRO_TEST_DEVS,
# REPRO_TEST_RING ("auto" or a forced K).
DET_SCRIPT = r"""
import os
P = int(os.environ["REPRO_TEST_PODS"])
MESH = tuple(int(x) for x in os.environ["REPRO_TEST_MESH"].split(","))
RING = os.environ.get("REPRO_TEST_RING", "auto")
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                           + os.environ["REPRO_TEST_DEVS"])
import re
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as Spec

from repro import compat
from repro.core import sync as S
from repro.core.compression import Level
from repro.core.planexec import build_exec_plan, ring_hops, sig_wire_bytes
from repro.core.scheduler import SyncPlan
from repro.launch.mesh import make_mesh
from benchmarks import hlo_cost

mesh = make_mesh(MESH, ("pod", "data", "model"))
levels = (Level("INT8", 1.0, 8), Level("TOPK10", 0.10, 8),
          Level("SIGN1", 1.0, 1), Level("INT4", 1.0, 4),
          Level("FULL", 1.0, 16), Level("SKIP", 0.0, 0))
idx = tuple(range(6))
# the INT8 rung is big enough to be DCN-bound (its decode time clears
# the ppermute launch overhead on BOTH the bidir and the longer unidir
# critical path), so the AUTO heuristic rings it even without a forced K
# (the acceptance pin)
sizes = [2048 * 1024 if RING == "auto" else 6144,
         8192, 4096, 6144, 2048, 700]
omega = tuple(np.arange(1, P + 1, dtype=np.float64) / (P * (P + 1) / 2))
plan = SyncPlan(idx, levels, omega, 1)
ring = None if RING == "auto" else int(RING)
ep_ring = build_exec_plan(plan, sizes, n_pods=P, ring=ring, bidir=True)
ep_uni = build_exec_plan(plan, sizes, n_pods=P, ring=ring, bidir=False)
ep_one = build_exec_plan(plan, sizes, n_pods=P, ring=0)
assert ep_ring.chunks[0] >= 2, (RING, ep_ring.chunks)
assert all(c == 0 for c in ep_ring.chunks[4:]), ep_ring.chunks
assert ep_uni.chunks == ep_ring.chunks  # per-hop wire time is P-free

r = np.random.RandomState(11)
tree = {f"p{i}": jnp.asarray(r.randn(P, n).astype(np.float32))
        for i, n in enumerate(sizes)}          # per-pod DISTINCT grads
errors0 = jax.tree.map(jnp.zeros_like, tree)


def runner(ep):
    def inner(t, e):
        t = jax.tree.map(lambda x: x.reshape(x.shape[1:]), t)
        e = jax.tree.map(lambda x: x.reshape(x.shape[1:]), e)
        a, ne = S.sync_tree(t, e, ep, mesh=mesh, shardings=None,
                            gamma=0.9, inside_manual=True)
        return (jax.tree.map(lambda x: x[None], a),
                jax.tree.map(lambda x: x[None], ne))
    pod = jax.tree.map(lambda _: Spec("pod"), tree)
    smapped = compat.shard_map(inner, mesh, in_specs=(pod, pod),
                               out_specs=(pod, pod),
                               manual_axes=set(mesh.axis_names))
    return jax.jit(smapped)


fn_ring, fn_uni, fn_one = runner(ep_ring), runner(ep_uni), runner(ep_one)

# --- multi-step soak: EF errors carried, params mirror accumulated -----
err_r, err_u, err_o = errors0, errors0, errors0
params = {k: np.zeros_like(np.asarray(tree[k])) for k in tree}
for t in range(3):
    g = jax.tree.map(lambda x: x * (1.0 + 0.25 * t), tree)
    agg_r, err_r = fn_ring(g, err_r)
    agg_u, err_u = fn_uni(g, err_u)
    agg_o, err_o = fn_one(g, err_o)
    for k in tree:
        ar = np.asarray(jax.device_get(agg_r[k]))
        au = np.asarray(jax.device_get(agg_u[k]))
        ao = np.asarray(jax.device_get(agg_o[k]))
        for p in range(1, P):
            assert (ar[0] == ar[p]).all(), (k, t, "ring cross-pod drift")
            assert (ao[0] == ao[p]).all(), (k, t, "one-shot cross-pod")
        # deterministic accumulation: ring == one-shot == either
        # direction, bit for bit (order cannot matter)
        assert (ar == ao).all(), (k, t, "ring != one-shot")
        assert (ar == au).all(), (k, t, "bidir != unidir")
        params[k] += ar
for k in tree:  # N steps of identical aggregates -> identical params
    for p in range(1, P):
        assert (params[k][0] == params[k][p]).all(), (k, "param drift")

# --- HLO pins: ppermute count, direction split, analytic == traced -----
n_ring = sum(1 for c in ep_ring.chunks if c)
txt = fn_ring.lower(tree, errors0).compile().as_text()
rep = hlo_cost.analyze(txt, MESH, ("pod", "data", "model"))
got = len(re.findall(r"=\s+\S+\s+collective-permute(?:-start)?\(", txt))
expect = sum(c * (P - 1) for c in ep_ring.chunks if c)
assert got == expect, (got, expect)
pairs = set(re.findall(r"source_target_pairs=\{[^}]*\}", txt))
assert len(pairs) == (2 if P >= 3 else 1), pairs  # both DCN directions
txt_u = fn_uni.lower(tree, errors0).compile().as_text()
pairs_u = set(re.findall(r"source_target_pairs=\{[^}]*\}", txt_u))
assert len(pairs_u) == 1, pairs_u                 # forward ring only
assert len(re.findall(r"=\s+\S+\s+collective-permute(?:-start)?\(",
                      txt_u)) == expect
# hops split: two half-rings of ceil((P-1)/2)
assert ring_hops(P, True) == -(-(P - 1) // 2)

analytic = sig_wire_bytes(ep_ring.sig, ep_ring.levels, P)
traced = rep.collective_bytes.get("pod", 0.0)
# XLA promotes FULL's bf16 all-reduce to f32 on backends without native
# bf16 reduction (this CPU container): accept the analytic total with
# the bf16 ring-all-reduce term swapped for its f32 version (float math
# mirrors hlo_cost; the (P-1)/P thirds are fractional at P = 3)
full_n = ep_ring.sig[4] * 1024
full_f32 = 2.0 * (P - 1) / P * 4 * full_n
full_bf16 = levels[4].wire_bytes(full_n, P)
assert (abs(traced - analytic) < 2.0
        or abs(traced - (analytic - full_bf16 + full_f32)) < 2.0), \
    (analytic, traced)
for ax, b in rep.collective_bytes.items():
    if "pod" not in ax:
        assert b == 0.0, (ax, b)
print("DET_OK", P, got, int(analytic))
"""


def _run_det(n_pods, mesh, devs, ring):
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src") + os.pathsep + root
    env["REPRO_TEST_PODS"] = str(n_pods)
    env["REPRO_TEST_MESH"] = mesh
    env["REPRO_TEST_DEVS"] = str(devs)
    env["REPRO_TEST_RING"] = ring
    r = subprocess.run([sys.executable, "-c", DET_SCRIPT], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "DET_OK" in r.stdout


@pytest.mark.slow
def test_p3_deterministic_ring_soak_auto_heuristic():
    """P = 3 pods: the AUTO roofline heuristic rings the DCN-bound rung
    (the 2-pod fence is gone), a multi-step EF soak keeps per-pod
    aggregates/params bit-identical for every codec, ring == one-shot ==
    unidirectional bit for bit, K*(P-1) ppermutes split over BOTH DCN
    directions, analytic == traced wire bytes."""
    _run_det(3, "3,2,2", 12, "auto")


@pytest.mark.slow
def test_p4_deterministic_ring_soak_forced():
    """P = 4 pods, forced 2-chunk ring (satellite pin: a forced ring on
    P >= 3 routes through the deterministic fold, not the legacy
    arrival-order float fold): same bit-determinism contract, asymmetric
    half-rings (2 forward + 1 backward hop)."""
    _run_det(4, "4,2,1", 8, "2")


# Backward-interleaved streaming: the structural pin.  A segment's
# collective must be issuable BEFORE the rest of the backward finishes —
# i.e. its transitive operand cone in the lowered HLO excludes the
# shallow layers' gradient ops.  We mark the shallowest layer with
# jnp.sin: reverse-mode emits `cosine` only in THAT layer's grad path,
# so "cone contains cosine" == "depends on the final backward segment".
CONE_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import re
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core import sync as S
from repro.core.compression import Level
from repro.core.planexec import build_exec_plan
from repro.core.scheduler import SyncPlan
from repro.launch.mesh import make_mesh

# pod-only 2-device mesh: every collective in the module is a pod
# collective, no axis bookkeeping needed
mesh = make_mesh((2, 1, 1), ("pod", "data", "model"))
# 6 chained (D, D) layers; the FIRST (shallowest) applies sin, so its
# backward — and ONLY its backward — emits a `cosine` op.  Reverse-mode
# produces the DEEP grads first, cos-free.
D = 32
levels = (Level("INT8", 1.0, 8), Level("INT4", 1.0, 4))
idx = (0, 1, 0, 1, 0, 1)
sizes = [D * D] * 6
plan = SyncPlan(idx, levels, (0.5, 0.5), 1)
ep_seg = build_exec_plan(plan, sizes, n_pods=2, segments=2)
ep_flat = build_exec_plan(plan, sizes, n_pods=2, segments=1)
assert ep_seg.segmented and not ep_flat.segmented

r = np.random.RandomState(3)
params = {f"p{i}": jnp.asarray(r.randn(D, D).astype(np.float32) / D)
          for i in range(6)}
errors = jax.tree.map(jnp.zeros_like, params)
x = jnp.asarray(r.randn(8, D).astype(np.float32))


def make_fn(ep):
    def inner(ps, es, xb):
        def loss(ps):
            h = jnp.sin(xb @ ps["p0"])
            for i in range(1, 6):
                h = h @ ps[f"p{i}"]
            return jnp.mean(h * h)
        grads = jax.grad(loss)(ps)
        return S.sync_tree(grads, es, ep, mesh=mesh, shardings=None,
                           gamma=1.0, inside_manual=True)
    pp = jax.tree.map(lambda _: P(), params)
    smapped = compat.shard_map(inner, mesh, in_specs=(pp, pp, P()),
                               out_specs=(pp, pp),
                               manual_axes=set(mesh.axis_names))
    return jax.jit(smapped)


COLL = re.compile(r"=\s+\S+\s+(all-gather|all-reduce|all-to-all|"
                  r"reduce-scatter|collective-permute)(-start)?\(")
TOK = re.compile(r"%[\w.\-]+")


def cone_report(txt):
    # Def-use graph over %name tokens, scoped per computation (names are
    # only unique within one); a reference to another computation
    # (calls=/to_apply=/...) pulls in everything defined inside it.
    # Returns, per collective, whether its transitive cone has a cosine.
    comp_names = set(m.group(1) for m in re.finditer(
        r"^(?:ENTRY\s+)?(%[\w.\-]+)\s*\(", txt, re.M))
    deps, is_cos, comp_defs, colls = {}, set(), {}, []
    comp = None
    for line in txt.splitlines():
        m = re.match(r"^(?:ENTRY\s+)?(%[\w.\-]+)\s*\(", line)
        if m and line.rstrip().endswith("{"):
            comp = m.group(1)
            comp_defs.setdefault(comp, [])
            continue
        if " = " not in line or comp is None:
            continue
        lhs, rhs = line.split(" = ", 1)
        dm = TOK.search(lhs)
        if not dm:
            continue
        node = (comp, dm.group(0))
        deps[node] = [("COMP", t) if t in comp_names else (comp, t)
                      for t in TOK.findall(rhs)]
        comp_defs[comp].append(node)
        if re.search(r"\bcosine\(", rhs):
            is_cos.add(node)
        if COLL.search(line):
            colls.append(node)
    for c, defs in comp_defs.items():
        deps[("COMP", c)] = defs
    memo = {}
    def has_cos(n):
        if n in memo:
            return memo[n]
        memo[n] = False  # cycle guard (while bodies)
        memo[n] = n in is_cos or any(has_cos(d) for d in deps.get(n, ()))
        return memo[n]
    assert is_cos, "no cosine in HLO -- marker layer missing?"
    assert colls, "no collectives found"
    return [has_cos(c) for c in colls]


fn_seg, fn_flat = make_fn(ep_seg), make_fn(ep_flat)

# streaming must be free: segment-streamed == barriered bit for bit
agg_s, err_s = fn_seg(params, errors, x)
agg_f, err_f = fn_flat(params, errors, x)
for k in params:
    assert (np.asarray(agg_s[k]) == np.asarray(agg_f[k])).all(), k
    assert (np.asarray(err_s[k]) == np.asarray(err_f[k])).all(), k

# ... and with NONZERO error buffers: zero errors vacuously mask the EF
# combine (gamma * e contributes nothing), so run the same parity check
# mid-soak, where the residual path carries live ulp-sensitive state.
errors_nz = jax.tree.map(
    lambda p: jnp.asarray(0.3 * r.randn(*p.shape).astype(np.float32)),
    params)
agg_s, err_s = fn_seg(params, errors_nz, x)
agg_f, err_f = fn_flat(params, errors_nz, x)
for k in params:
    assert (np.asarray(agg_s[k]) == np.asarray(agg_f[k])).all(), (k, "nz")
    assert (np.asarray(err_s[k]) == np.asarray(err_f[k])).all(), (k, "nz")

rep_seg = cone_report(fn_seg.lower(params, errors, x).compile().as_text())
rep_flat = cone_report(
    fn_flat.lower(params, errors, x).compile().as_text())

# Segmented: the deep segment's collectives issue from cos-free cones —
# XLA may start them while the shallow backward still runs.  (At least
# one cone DOES contain cosine: the shallow segment's own — the sanity
# check that the marker threads through at all.)  With the coalesced
# wire exchange each segment's payload rungs share ONE all_gather, so
# the counts are per segment, not per rung.
n_free = sum(1 for c in rep_seg if not c)
assert n_free >= 1, rep_seg
assert sum(rep_seg) >= 1, rep_seg
assert len(rep_seg) >= 2, rep_seg
# Barriered: the single packed buffer makes EVERY collective depend on
# the last gradient — the false dependence this scheduling removes.
assert all(rep_flat), rep_flat
assert len(rep_flat) >= 1, rep_flat
print("CONE_OK", len(rep_seg), n_free, len(rep_flat))
"""


@pytest.mark.slow
def test_backward_interleaved_collective_cones_subprocess():
    """Structural pin of the backward-interleaved schedule: with
    segments=2, at least one rung collective's HLO operand cone excludes
    the shallowest layer's gradient (marked via sin -> cosine), so it can
    issue before the backward finishes; the barriered plan's collectives
    all carry the false last-gradient dependence.  Also asserts
    segment-streamed == barriered bit-parity on the same inputs, with
    both zero and nonzero EF error buffers (zero errors mask the
    residual path)."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src") + os.pathsep + root
    r = subprocess.run([sys.executable, "-c", CONE_SCRIPT], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "CONE_OK" in r.stdout
