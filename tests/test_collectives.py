"""Traced-HLO contract of the bucketed codec sync (8 virtual devices).

Two acceptance properties of the codec refactor, pinned on the lowered
HLO of a multi-pod ``sync_tree``:

  1. at most ONE pod collective per DISTINCT codec level in the plan
     (same-level leaves bucket into one buffer; each codec packs its whole
     payload pytree into one uint8 wire buffer);
  2. the analytic accounting (``wire_bytes_of_plan`` — what the Scheduler,
     knapsack and Table 1 price) EQUALS the traced collective bytes on the
     pod axis, for every codec including the bf16 psum of FULL (the seed
     priced bf16 but psum'd f32 — the drift this refactor removed).

XLA locks the device count at first use, so this runs in a subprocess with
XLA_FLAGS set, like tests/test_multipod.py."""
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core import sync as S
from repro.core.compression import Level
from repro.core.scheduler import SyncPlan
from repro.launch.mesh import make_mesh
from benchmarks import hlo_cost

mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
levels = (Level("FULL", 1.0, 16), Level("INT8", 1.0, 8),
          Level("TOPK10", 0.10, 8), Level("SIGN1", 1.0, 1),
          Level("SKIP", 0.0, 0))
# 6 leaves, two sharing TOPK10 -> 4 distinct collective-bearing levels
level_names = ["FULL", "INT8", "TOPK10", "TOPK10", "SIGN1", "SKIP"]
names = [l.name for l in levels]
idx = tuple(names.index(n) for n in level_names)
sizes = [2048, 3000, 1500, 1500, 2300, 700]   # non-block-multiples too
plan = SyncPlan(idx, levels, (0.5, 0.5), 1)

r = np.random.RandomState(0)
tree = {f"p{i}": jnp.asarray(r.randn(n).astype(np.float32))
        for i, n in enumerate(sizes)}
errors = jax.tree.map(jnp.zeros_like, tree)


def inner(t, e):
    return S.sync_tree(t, e, plan, mesh=mesh, shardings=None, gamma=1.0,
                       inside_manual=True)


smapped = compat.shard_map(
    inner, mesh,
    in_specs=(jax.tree.map(lambda _: P(), tree),
              jax.tree.map(lambda _: P(), errors)),
    out_specs=(jax.tree.map(lambda _: P(), tree),
               jax.tree.map(lambda _: P(), errors)),
    manual_axes=set(mesh.axis_names))
fn = jax.jit(smapped)

# --- run it: EF invariant survives the real multi-pod exchange ----------
agg, new_e = fn(tree, errors)
for k in tree:
    a = np.asarray(jax.device_get(agg[k]))
    assert np.isfinite(a).all(), k
    if k != "p5":  # non-SKIP leaves: per-pod own+residual == ef, and with
        # identical per-pod inputs the aggregate equals own
        np.testing.assert_allclose(np.asarray(agg[k] + new_e[k]),
                                   np.asarray(tree[k]), rtol=1e-4,
                                   atol=1e-4)

# --- traced-HLO assertions ---------------------------------------------
txt = fn.lower(tree, errors).compile().as_text()
rep = hlo_cost.analyze(txt, (2, 2, 2), ("pod", "data", "model"))
n_distinct_wire_levels = 4  # FULL, INT8, TOPK10 (bucketed x2), SIGN1
pod_count = rep.collective_count.get("pod", 0)
assert 1 <= pod_count <= n_distinct_wire_levels, \
    f"pod collectives {pod_count} > {n_distinct_wire_levels}: " \
    f"{dict(rep.collective_count)}"

analytic = S.wire_bytes_of_plan(plan, sizes, n_pods=2)
traced = rep.collective_bytes.get("pod", 0.0)
# XLA's bf16 normalization pass promotes the FULL bucket's bf16
# all-reduce to f32 on backends without native bf16 reduction (this CPU
# container); on TPU it stays bf16.  Accept exactly those two totals —
# every all_gather codec must match to the byte either way.
full_part = levels[0].wire_bytes(sizes[0], 2)
assert traced in (float(analytic), float(analytic + full_part)), \
    f"analytic {analytic} (or promoted {analytic + full_part}) " \
    f"!= traced {traced}"
# no sync traffic may leak onto the fast axes
for ax, b in rep.collective_bytes.items():
    if "pod" not in ax:
        assert b == 0.0, (ax, b)
print("COLLECTIVES_OK", pod_count, int(analytic))
"""


@pytest.mark.slow
def test_bucketed_sync_collectives_subprocess():
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src") + os.pathsep + root
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "COLLECTIVES_OK" in r.stdout
