"""Per-kernel allclose sweeps: Pallas (interpret=True on CPU) vs the ref.py
pure-jnp oracles, over shapes and input distributions (assignment
requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests skip, the rest of the module runs
    from hypothesis_stub import given, settings, st

from repro.kernels import autotune, ops, ref
from repro.kernels.topk_compress import ef_topk_select, LANES
from repro.kernels.quantize import (quantize_int8_fused, dequantize_int8,
                                    ef_int4_fused, unpack_nibbles)
from repro.kernels.sign import ef_sign_fused

SHAPES = [(8, 1024), (16, 1024), (64, 1024)]
DISTS = ["normal", "uniform", "heavy", "sparse"]


def _data(shape, dist, seed=0):
    r = np.random.RandomState(seed)
    if dist == "normal":
        x = r.randn(*shape)
    elif dist == "uniform":
        x = r.uniform(-3, 3, shape)
    elif dist == "heavy":
        x = r.standard_cauchy(shape)
    else:
        x = r.randn(*shape) * (r.rand(*shape) > 0.9)
    return jnp.asarray(x.astype(np.float32))


class TestTopKKernel:
    @pytest.mark.parametrize("shape", SHAPES, ids=str)
    @pytest.mark.parametrize("dist", DISTS)
    def test_matches_oracle(self, shape, dist):
        g = _data(shape, dist, 1)
        e = _data(shape, dist, 2)
        for k in (8, 104, 256):
            sel, res = ef_topk_select(g, e, gamma=0.9, k=k, interpret=True)
            sel_r, res_r = ref.ef_topk_select_ref(g, e, gamma=0.9, k=k)
            # fma-order differences can flip selection at exact threshold
            # ties: allow <=0.01% flipped entries, everything else close
            sel_np, sel_rn = np.asarray(sel), np.asarray(sel_r)
            close = np.isclose(sel_np, sel_rn, rtol=1e-5, atol=1e-5)
            assert (~close).mean() <= 1e-4, (~close).sum()
            res_np, res_rn = np.asarray(res), np.asarray(res_r)
            closer = np.isclose(res_np, res_rn, rtol=1e-5, atol=1e-5)
            assert (~closer).mean() <= 1e-4
            # the EF invariant must hold EXACTLY elementwise on both paths
            np.testing.assert_allclose(
                np.asarray(sel + res), np.asarray(g + 0.9 * e),
                rtol=1e-5, atol=1e-5)

    def test_selection_count_near_k(self):
        g = _data((8, 1024), "normal", 3)
        e = jnp.zeros_like(g)
        k = 104
        sel, _ = ef_topk_select(g, e, gamma=1.0, k=k, interpret=True)
        counts = np.asarray((sel != 0).sum(axis=1))
        assert np.all(np.abs(counts - k) <= 8), counts  # bisection tolerance

    def test_selected_entries_dominate(self):
        """Every selected |value| >= every dropped |value| - epsilon."""
        g = _data((8, 1024), "heavy", 4)
        e = jnp.zeros_like(g)
        sel, res = ef_topk_select(g, e, gamma=1.0, k=64, interpret=True)
        sel_np, res_np = np.asarray(sel), np.asarray(res)
        for r in range(8):
            kept = np.abs(sel_np[r][sel_np[r] != 0])
            dropped = np.abs(res_np[r][sel_np[r] == 0])
            if len(kept) and len(dropped):
                assert kept.min() >= dropped.max() - 1e-5

    def test_ef_invariant(self):
        g = _data((16, 1024), "normal", 5)
        e = _data((16, 1024), "normal", 6)
        sel, res = ef_topk_select(g, e, gamma=0.5, k=100, interpret=True)
        np.testing.assert_allclose(np.asarray(sel + res),
                                   np.asarray(g + 0.5 * e), rtol=1e-5,
                                   atol=1e-5)


class TestQuantizeKernel:
    @pytest.mark.parametrize("shape", SHAPES, ids=str)
    @pytest.mark.parametrize("dist", DISTS)
    def test_matches_oracle(self, shape, dist):
        x = _data(shape, dist, 7)
        q, s, r = quantize_int8_fused(x, interpret=True)
        q_r, s_r, r_r = ref.quantize_int8_ref(x)
        np.testing.assert_array_equal(np.asarray(q), np.asarray(q_r))
        np.testing.assert_allclose(np.asarray(s), np.asarray(s_r),
                                   rtol=1e-6)
        # residual tolerance scales with the block absmax (heavy-tailed
        # inputs reach 1e3+; fma ordering differs interpret vs XLA)
        tol = float(np.asarray(s_r).max()) * 1e-3 + 1e-6
        np.testing.assert_allclose(np.asarray(r), np.asarray(r_r),
                                   rtol=1e-4, atol=tol)

    def test_dequant_roundtrip(self):
        x = _data((8, 1024), "uniform", 8)
        q, s, r = quantize_int8_fused(x, interpret=True)
        back = dequantize_int8(q, s, interpret=True)
        np.testing.assert_allclose(np.asarray(back + r), np.asarray(x),
                                   rtol=1e-5, atol=1e-5)
        # quantisation error bounded by scale/2
        assert np.all(np.abs(np.asarray(r)) <= np.asarray(s) * 0.5 + 1e-6)


class TestInt4Kernel:
    @pytest.mark.parametrize("shape", SHAPES, ids=str)
    @pytest.mark.parametrize("dist", DISTS)
    def test_matches_oracle(self, shape, dist):
        g = _data(shape, dist, 11)
        e = _data(shape, dist, 12)
        p, s, r = ef_int4_fused(g, e, gamma=0.8, interpret=True)
        p_r, s_r, r_r = ref.ef_int4_ref(g, e, gamma=0.8)
        np.testing.assert_allclose(np.asarray(s), np.asarray(s_r),
                                   rtol=1e-6)
        # a 1-ulp scale wiggle can flip a value on a rounding boundary
        assert (np.asarray(p) != np.asarray(p_r)).mean() <= 1e-4
        tol = float(np.asarray(s_r).max()) * 1e-3 + 1e-6
        np.testing.assert_allclose(np.asarray(r), np.asarray(r_r),
                                   rtol=1e-4, atol=tol)
        # EF invariant: dequant(packed) + residual == g + gamma*e
        dq = unpack_nibbles(p) * s
        np.testing.assert_allclose(np.asarray(dq + r),
                                   np.asarray(g + 0.8 * e),
                                   rtol=1e-4, atol=tol)

    def test_nibble_packing_range(self):
        g = _data((8, 1024), "heavy", 13)
        e = jnp.zeros_like(g)
        p, s, r = ef_int4_fused(g, e, gamma=1.0, interpret=True)
        q = np.asarray(unpack_nibbles(p))
        assert q.min() >= -7 and q.max() <= 7
        # quantisation error bounded by scale/2
        assert np.all(np.abs(np.asarray(r)) <= np.asarray(s) * 0.5 + 1e-5)


class TestSignKernel:
    @pytest.mark.parametrize("shape", SHAPES, ids=str)
    @pytest.mark.parametrize("dist", DISTS)
    def test_matches_oracle(self, shape, dist):
        g = _data(shape, dist, 14)
        e = _data(shape, dist, 15)
        sg, s, r = ef_sign_fused(g, e, gamma=0.6, interpret=True)
        sg_r, s_r, r_r = ref.ef_sign_ref(g, e, gamma=0.6)
        np.testing.assert_array_equal(np.asarray(sg), np.asarray(sg_r))
        np.testing.assert_allclose(np.asarray(s), np.asarray(s_r),
                                   rtol=1e-6)
        np.testing.assert_allclose(np.asarray(r), np.asarray(r_r),
                                   rtol=1e-5, atol=1e-5)

    def test_sign_and_scale_semantics(self):
        g = _data((8, 1024), "normal", 16)
        e = jnp.zeros_like(g)
        sg, s, r = ef_sign_fused(g, e, gamma=1.0, interpret=True)
        assert set(np.unique(np.asarray(sg))) <= {-1, 1}
        np.testing.assert_allclose(
            np.asarray(s)[:, 0], np.mean(np.abs(np.asarray(g)), axis=1),
            rtol=1e-6)
        # EF invariant holds exactly elementwise
        np.testing.assert_allclose(
            np.asarray(sg.astype(jnp.float32) * s + r), np.asarray(g),
            rtol=1e-5, atol=1e-5)


class TestOpsWrappers:
    @given(st.integers(min_value=1, max_value=40000))
    @settings(max_examples=15, deadline=None)
    def test_flat_padding_roundtrip(self, n):
        r = np.random.RandomState(n)
        g = jnp.asarray(r.randn(n).astype(np.float32))
        e = jnp.zeros_like(g)
        sel, res = ops.ef_topk(g, e, gamma=1.0, k=64)
        assert sel.shape == (n,) and res.shape == (n,)
        np.testing.assert_allclose(np.asarray(sel + res), np.asarray(g),
                                   rtol=1e-5, atol=1e-5)

    def test_quantize_flat(self):
        g = jnp.asarray(np.random.RandomState(0).randn(5000)
                        .astype(np.float32))
        q, s, r, n = ops.quantize_int8(g)
        back = ops.dequant_int8(q, s, n)
        np.testing.assert_allclose(np.asarray(back), np.asarray(g),
                                   atol=float(np.asarray(s).max()) * 0.51)


def _gather_case(nbp1, S, seed, special, rows):
    """Block buffers + padded perm for the producer-fused gather kernels.
    ``special`` seeds a denormal row and an all-zero row (absmax == 0:
    the scale guard must hold); the last row is the zero row the sync
    path pads with."""
    r = np.random.RandomState(seed)
    fb = r.randn(nbp1, LANES).astype(np.float32)
    eb = r.randn(nbp1, LANES).astype(np.float32)
    if special and nbp1 > 3:
        fb[0] *= 1e-41          # subnormal magnitudes
        eb[0] *= 1e-41
        fb[1] = 0.0             # absmax == 0 row
        eb[1] = 0.0
    fb[-1] = 0.0
    eb[-1] = 0.0
    perm = r.randint(0, nbp1, size=S).astype(np.int32)
    p2, _ = ops._pad_perm(jnp.asarray(perm), rows, nbp1 - 1)
    return jnp.asarray(fb), jnp.asarray(eb), p2


class TestGatherKernels:
    """Property-based bit-parity of the fused gather+encode kernels vs
    the ref.py gather oracles, across non-multiple-of-tile perm lengths
    and denormal/zero rows.  Both sides run UNDER JIT: in-kernel
    ``g + gamma * e`` and jitted jnp both FMA-contract on XLA, while the
    eager oracle does separate mul+add (1-ulp apart) — the jitted parity
    is the one the (always-jitted) sync path relies on."""

    @given(st.integers(2, 9), st.integers(1, 23),
           st.integers(0, 10 ** 6), st.booleans(),
           st.sampled_from((1, 2, 4, 8)))
    @settings(max_examples=12, deadline=None)
    def test_int8_gather_bit_parity(self, nbp1, S, seed, special, rows):
        from repro.kernels.quantize import quantize_int8_gather
        fb, eb, p2 = _gather_case(nbp1, S, seed, special, rows)
        q, s, r = quantize_int8_gather(fb, eb, p2, gamma=0.9, rows=rows,
                                       interpret=True)
        q_r, s_r, r_r = jax.jit(
            lambda f, e, p: ref.quantize_int8_gather_ref(f, e, p,
                                                         gamma=0.9)
        )(fb, eb, p2)
        np.testing.assert_array_equal(np.asarray(q), np.asarray(q_r))
        np.testing.assert_array_equal(np.asarray(s), np.asarray(s_r))
        np.testing.assert_array_equal(np.asarray(r), np.asarray(r_r))

    @given(st.integers(2, 9), st.integers(1, 23),
           st.integers(0, 10 ** 6), st.booleans(),
           st.sampled_from((1, 2, 4, 8)))
    @settings(max_examples=12, deadline=None)
    def test_int4_gather_bit_parity(self, nbp1, S, seed, special, rows):
        from repro.kernels.quantize import ef_int4_gather
        fb, eb, p2 = _gather_case(nbp1, S, seed, special, rows)
        p, s, r = ef_int4_gather(fb, eb, p2, gamma=0.7, rows=rows,
                                 interpret=True)
        p_r, s_r, r_r = jax.jit(
            lambda f, e, pm: ref.ef_int4_gather_ref(f, e, pm, gamma=0.7)
        )(fb, eb, p2)
        np.testing.assert_array_equal(np.asarray(p), np.asarray(p_r))
        np.testing.assert_array_equal(np.asarray(s), np.asarray(s_r))
        np.testing.assert_array_equal(np.asarray(r), np.asarray(r_r))

    @given(st.integers(2, 9), st.integers(1, 23),
           st.integers(0, 10 ** 6), st.booleans(),
           st.sampled_from((1, 2, 4, 8)))
    @settings(max_examples=12, deadline=None)
    def test_sign_gather_bit_parity(self, nbp1, S, seed, special, rows):
        from repro.kernels.sign import ef_sign_gather
        fb, eb, p2 = _gather_case(nbp1, S, seed, special, rows)
        sg, s, r = ef_sign_gather(fb, eb, p2, gamma=0.6, rows=rows,
                                  interpret=True)
        sg_r, s_r, r_r = jax.jit(
            lambda f, e, p: ref.ef_sign_gather_ref(f, e, p, gamma=0.6)
        )(fb, eb, p2)
        np.testing.assert_array_equal(np.asarray(sg), np.asarray(sg_r))
        np.testing.assert_array_equal(np.asarray(s), np.asarray(s_r))
        np.testing.assert_array_equal(np.asarray(r), np.asarray(r_r))

    @given(st.integers(2, 9), st.integers(1, 23),
           st.integers(0, 10 ** 6), st.booleans(),
           st.sampled_from((1, 2, 4, 8)))
    @settings(max_examples=12, deadline=None)
    def test_topk_gather_bit_parity(self, nbp1, S, seed, special, rows):
        from repro.kernels.topk_compress import ef_topk_gather
        fb, eb, p2 = _gather_case(nbp1, S, seed, special, rows)
        sel, res = ef_topk_gather(fb, eb, p2, gamma=1.0, k=104,
                                  rows=rows, interpret=True)
        sel_r, res_r = jax.jit(
            lambda f, e, p: ref.ef_topk_gather_ref(f, e, p, gamma=1.0,
                                                   k=104)
        )(fb, eb, p2)
        np.testing.assert_array_equal(np.asarray(sel), np.asarray(sel_r))
        np.testing.assert_array_equal(np.asarray(res), np.asarray(res_r))

    # Deterministic sweep over the same case space — runs even where
    # hypothesis is absent (the property tests then skip via the stub).
    @pytest.mark.parametrize("rows", [1, 2, 4, 8])
    @pytest.mark.parametrize("special", [False, True])
    def test_gather_bit_parity_grid(self, rows, special):
        from repro.kernels.quantize import (ef_int4_gather,
                                            quantize_int8_gather)
        from repro.kernels.sign import ef_sign_gather
        from repro.kernels.topk_compress import ef_topk_gather
        for nbp1, S, seed in [(2, 1, 0), (5, 7, 1), (9, 23, 2),
                              (6, 13, 3)]:
            fb, eb, p2 = _gather_case(nbp1, S, seed, special, rows)
            pairs = [
                (quantize_int8_gather(fb, eb, p2, gamma=0.9, rows=rows,
                                      interpret=True),
                 jax.jit(lambda f, e, p: ref.quantize_int8_gather_ref(
                     f, e, p, gamma=0.9))(fb, eb, p2)),
                (ef_int4_gather(fb, eb, p2, gamma=0.7, rows=rows,
                                interpret=True),
                 jax.jit(lambda f, e, p: ref.ef_int4_gather_ref(
                     f, e, p, gamma=0.7))(fb, eb, p2)),
                (ef_sign_gather(fb, eb, p2, gamma=0.6, rows=rows,
                                interpret=True),
                 jax.jit(lambda f, e, p: ref.ef_sign_gather_ref(
                     f, e, p, gamma=0.6))(fb, eb, p2)),
                (ef_topk_gather(fb, eb, p2, gamma=1.0, k=104, rows=rows,
                                interpret=True),
                 jax.jit(lambda f, e, p: ref.ef_topk_gather_ref(
                     f, e, p, gamma=1.0, k=104))(fb, eb, p2)),
            ]
            for got, want in pairs:
                for a, b in zip(got, want):
                    np.testing.assert_array_equal(np.asarray(a),
                                                  np.asarray(b))

    @given(st.integers(2, 9), st.integers(1, 23),
           st.integers(0, 10 ** 6))
    @settings(max_examples=10, deadline=None)
    def test_ops_wrapper_slices_to_perm_length(self, nbp1, S, seed):
        """The ops.gather_ef_* wrappers pad the perm to the autotuned
        tile height and slice back: outputs are (S, ...) and match the
        oracle on the ORIGINAL perm bit for bit."""
        fb, eb, p2 = _gather_case(nbp1, S, seed, False, 1)
        perm = p2[:S]
        q, s, r = ops.gather_ef_int8(fb, eb, perm, gamma=0.9,
                                     use_pallas=True)
        assert q.shape == (S, LANES) and r.shape == (S * LANES,)
        q_r, s_r, r_r = jax.jit(
            lambda f, e, p: ref.quantize_int8_gather_ref(f, e, p,
                                                         gamma=0.9)
        )(fb, eb, perm)
        np.testing.assert_array_equal(np.asarray(q), np.asarray(q_r))
        np.testing.assert_array_equal(np.asarray(s), np.asarray(s_r))
        np.testing.assert_array_equal(np.asarray(r),
                                      np.asarray(r_r).reshape(-1))


class TestAutotune:
    """The block-size autotuner's determinism contract
    (tests satellite: REPRO_FORCE_INTERPRET must force the deterministic
    default path and never touch the cache file)."""

    def _reset(self):
        ops.interpret_mode.cache_clear()
        ops.default_use_pallas.cache_clear()
        autotune.clear_memo()

    @pytest.fixture(autouse=True)
    def _isolate(self, tmp_path, monkeypatch):
        monkeypatch.setenv(autotune.CACHE_ENV,
                           str(tmp_path / "autotune.json"))
        self.cache = tmp_path / "autotune.json"
        self._reset()
        yield
        self._reset()

    def test_interpret_mode_default_rows_no_cache_write(self, monkeypatch):
        monkeypatch.setenv(ops.FORCE_INTERPRET_ENV, "1")
        self._reset()
        for codec in ("int8", "int4", "sign", "topk"):
            for n in (1, 5, 64, 1000):
                assert autotune.block_rows(codec, n) == \
                    autotune.DEFAULT_ROWS
        # drive the real producer-fused path end to end
        fb = jnp.asarray(np.random.RandomState(0)
                         .randn(4, LANES).astype(np.float32))
        eb = fb * 0.5
        perm = jnp.arange(3, dtype=jnp.int32)
        out = ops.gather_ef_int8(fb, eb, perm, gamma=1.0, use_pallas=True)
        jax.block_until_ready(out)
        assert not self.cache.exists(), \
            "interpret mode must never write the autotune cache"

    def test_measured_path_caches_to_disk(self, monkeypatch):
        monkeypatch.setenv(ops.FORCE_INTERPRET_ENV, "0")
        self._reset()
        calls = []

        def bench(rows):
            calls.append(rows)
            return 1.0 / rows  # taller tiles win
        assert autotune.block_rows("int8", 64, bench=bench) == 8
        assert calls == [1, 2, 4, 8]
        assert self.cache.exists()
        # memo hit: no re-measure
        calls.clear()
        assert autotune.block_rows("int8", 64, bench=bench) == 8
        assert calls == []
        # fresh process (memo cleared): served from disk, still no bench
        autotune.clear_memo()
        assert autotune.block_rows("int8", 64, bench=bench) == 8
        assert calls == []
        # same sig class shares the entry; a different class re-measures
        assert autotune.block_rows("int8", 50, bench=bench) == 8
        assert calls == []
        assert autotune.block_rows("int8", 3, bench=bench) == 2
        assert calls == [1, 2]  # candidates capped at n_rows

    def test_candidates_capped_and_failures_skipped(self, monkeypatch):
        monkeypatch.setenv(ops.FORCE_INTERPRET_ENV, "0")
        self._reset()

        def bench(rows):
            if rows > 2:
                raise RuntimeError("tile too tall for vmem")
            return float(rows)
        assert autotune.block_rows("sign", 64, bench=bench) == 1
        # no bench at all: deterministic default, nothing persisted
        autotune.clear_memo()
        self.cache.unlink()
        assert autotune.block_rows("topk", 64) == autotune.DEFAULT_ROWS
        assert not self.cache.exists()
