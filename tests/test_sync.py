"""Hierarchical sync semantics (single-device path; the multi-pod path is
covered by tests/test_multipod.py in a subprocess with 8 virtual devices)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ACESyncConfig
from repro.core import sync as S
from repro.core.compression import Level
from repro.core.scheduler import Scheduler, SyncPlan


def _tree(seed=0):
    r = np.random.RandomState(seed)
    return {"a": jnp.asarray(r.randn(64, 32).astype(np.float32)),
            "b": jnp.asarray(r.randn(2000).astype(np.float32))}


def _plan(levels_by_group, omega=(1.0,)):
    cfg = ACESyncConfig()
    sched_levels = [Level(*l) for l in cfg.levels]
    names = [l.name for l in sched_levels]
    idx = tuple(names.index(n) for n in levels_by_group)
    return SyncPlan(idx, tuple(sched_levels), omega, 1)


class TestSyncTree:
    def test_full_level_identity(self):
        tree = _tree()
        errors = jax.tree.map(jnp.zeros_like, tree)
        plan = _plan(["FULL", "FULL"])
        agg, new_e = S.sync_tree(tree, errors, plan, mesh=None,
                                 shardings=None, gamma=1.0)
        for k in tree:
            np.testing.assert_allclose(np.asarray(agg[k]),
                                       np.asarray(tree[k]), rtol=1e-2,
                                       atol=1e-2)  # bf16 wire
            # residual is only bf16 rounding
            assert float(jnp.abs(new_e[k]).max()) < 0.02

    def test_skip_buffers_into_error(self):
        tree = _tree()
        errors = jax.tree.map(jnp.zeros_like, tree)
        plan = _plan(["SKIP", "SKIP"])
        agg, new_e = S.sync_tree(tree, errors, plan, mesh=None,
                                 shardings=None, gamma=1.0)
        for k in tree:
            assert float(jnp.abs(agg[k]).max()) == 0.0
            np.testing.assert_allclose(np.asarray(new_e[k]),
                                       np.asarray(tree[k]), rtol=1e-6)

    def test_topk_residual_partition(self):
        """agg + residual == gamma-weighted EF input (lossless split)."""
        tree = _tree(1)
        errors = jax.tree.map(lambda x: jnp.ones_like(x) * 0.1, tree)
        gamma = 0.7
        plan = _plan(["TOPK10_INT8", "TOPK25_INT8"])
        agg, new_e = S.sync_tree(tree, errors, plan, mesh=None,
                                 shardings=None, gamma=gamma)
        for k in tree:
            ef = np.asarray(tree[k]) + gamma * np.asarray(errors[k])
            np.testing.assert_allclose(np.asarray(agg[k] + new_e[k]), ef,
                                       rtol=1e-4, atol=1e-4)

    def test_error_feedback_accumulates_over_steps(self):
        cfg = ACESyncConfig()
        tree = {"w": jnp.asarray(
            np.random.RandomState(3).randn(4096).astype(np.float32))}
        e = {"w": jnp.zeros(4096, jnp.float32)}
        plan = _plan(["TOPK10_INT8"])
        total = jnp.zeros(4096)
        for _ in range(120):
            agg, e = S.sync_tree(tree, e, plan, mesh=None, shardings=None,
                                 gamma=1.0)
            total = total + agg["w"]
        rel = float(jnp.linalg.norm(total / 120 - tree["w"])
                    / jnp.linalg.norm(tree["w"]))
        assert rel < 0.1, rel


class TestBucketedSync:
    """The codec-refactor behavior: same-level leaves share one fused
    buffer; every wire format in the widened ladder syncs a mixed tree."""

    def _mixed(self, seed=5):
        r = np.random.RandomState(seed)
        return {"a": jnp.asarray(r.randn(1000).astype(np.float32)),
                "b": jnp.asarray(r.randn(64, 32).astype(np.float32)),
                "c": jnp.asarray(r.randn(3, 7, 11).astype(np.float32)),
                "d": jnp.asarray(r.randn(2048).astype(np.float32)),
                "e": jnp.asarray(r.randn(500).astype(np.float32)),
                "f": jnp.asarray(r.randn(300).astype(np.float32))}

    def test_widened_ladder_mixed_plan(self):
        cfg = ACESyncConfig()
        tree = self._mixed()
        errors = jax.tree.map(lambda x: jnp.ones_like(x) * 0.05, tree)
        gamma = 0.9
        plan = _plan(["FULL", "INT8", "INT4", "SIGN1", "TOPK10_INT8",
                      "SKIP"])
        agg, new_e = S.sync_tree(tree, errors, plan, mesh=None,
                                 shardings=None, gamma=gamma)
        for k in tree:
            assert agg[k].shape == tree[k].shape
            assert agg[k].dtype == tree[k].dtype
            ef = np.asarray(tree[k]) + gamma * np.asarray(errors[k])
            if k == "f":  # SKIP: everything lands in the residual
                assert float(jnp.abs(agg[k]).max()) == 0.0
                np.testing.assert_allclose(np.asarray(new_e[k]), ef,
                                           rtol=1e-5, atol=1e-5)
            else:  # lossless transmit/residual split per leaf
                np.testing.assert_allclose(np.asarray(agg[k] + new_e[k]),
                                           ef, rtol=1e-4, atol=1e-4)

    def test_same_level_leaves_bucket_together(self):
        """Leaves sharing a level are compressed as one buffer: entries of
        leaf 'b' land in blocks spanning the a/b boundary, and the result
        still splits back exactly (invariant per leaf)."""
        tree = {"a": jnp.asarray(np.random.RandomState(0)
                                 .randn(1500).astype(np.float32)),
                "b": jnp.asarray(np.random.RandomState(1)
                                 .randn(1500).astype(np.float32))}
        errors = jax.tree.map(jnp.zeros_like, tree)
        plan = _plan(["TOPK10_INT8", "TOPK10_INT8"])
        agg, new_e = S.sync_tree(tree, errors, plan, mesh=None,
                                 shardings=None, gamma=1.0)
        for k in tree:
            np.testing.assert_allclose(np.asarray(agg[k] + new_e[k]),
                                       np.asarray(tree[k]), rtol=1e-5,
                                       atol=1e-5)

    def test_pallas_path_matches_oracle_path(self):
        """sync_tree(use_pallas=True) routes through the fused kernels
        (interpret on CPU) and stays equivalent to the oracle path up to
        documented bisection-tie tolerance."""
        tree = self._mixed(9)
        errors = jax.tree.map(jnp.zeros_like, tree)
        plan = _plan(["INT8", "INT4", "SIGN1", "TOPK10_INT8", "INT8",
                      "SKIP"])
        agg_o, e_o = S.sync_tree(tree, errors, plan, mesh=None,
                                 shardings=None, gamma=1.0,
                                 use_pallas=False)
        agg_p, e_p = S.sync_tree(tree, errors, plan, mesh=None,
                                 shardings=None, gamma=1.0,
                                 use_pallas=True)
        for k in tree:
            a, b = np.asarray(agg_o[k]), np.asarray(agg_p[k])
            close = np.isclose(a, b, rtol=1e-4, atol=1e-4)
            assert (~close).mean() <= 1e-3, k
            ef = np.asarray(tree[k])
            np.testing.assert_allclose(np.asarray(agg_p[k] + e_p[k]), ef,
                                       rtol=1e-4, atol=1e-4)

    def test_wire_bytes_of_plan_buckets(self):
        """Pricing matches the static-shape exchange: every leaf is
        block-aligned (1500 -> 2 blocks), same-level leaves share one
        buffer/collective, and the bucket is priced at its block total."""
        sizes = [1500, 1500, 2048]
        plan = _plan(["TOPK10_INT8", "TOPK10_INT8", "INT8"])
        got = S.wire_bytes_of_plan(plan, sizes, 2)
        lv = {l.name: l for l in plan.levels}
        expect = lv["TOPK10_INT8"].wire_bytes(4 * 1024, 2) \
            + lv["INT8"].wire_bytes(2048, 2)
        assert got == expect


class TestGroupMeta:
    def test_metas_cover_leaves(self):
        tree = {"embed": jnp.zeros((10, 4)),
                "blocks": {"attn": {"wq": jnp.zeros((2, 4, 4))},
                           "ffn": {"w_gate": jnp.zeros((2, 4, 8))}}}
        metas = S.group_metas(tree)
        assert len(metas) == len(jax.tree.leaves(tree))
        kinds = {m.name: m.kind for m in metas}
        assert kinds["embed"] == "embed"
        assert [m for m in metas if "wq" in m.name][0].kind == "attn"
        assert [m for m in metas if "w_gate" in m.name][0].kind == "mlp"

    def test_stats_shapes(self):
        tree = _tree()
        ma, var, nrm = S.grad_group_stats(tree)
        assert ma.shape == (2,) and var.shape == (2,) and nrm.shape == (2,)


class TestScheduler:
    def test_eq5_monotone_bandwidth(self):
        cfg = ACESyncConfig()
        sched = Scheduler(cfg, [10 ** 6] * 4, n_pods=2)
        from repro.core.scheduler import kept_fraction, compression_level
        fracs = [kept_fraction(cfg, bw) for bw in (5, 50, 200)]
        assert fracs[0] < fracs[1] < fracs[2]  # low bw -> keep less
        comps = [compression_level(cfg, bw) for bw in (5, 50, 200)]
        assert comps[0] > comps[1] > comps[2]  # eq (5) verbatim

    def test_plan_bytes_shrink_with_bandwidth(self):
        cfg = ACESyncConfig()
        sched = Scheduler(cfg, [10 ** 6] * 6, n_pods=2)
        imp = [0.5] * 6
        p_low, p_high = sched.plan(imp, 5.0), sched.plan(imp, 200.0)
        b_low = sched.plan_wire_bytes(p_low)
        b_high = sched.plan_wire_bytes(p_high)
        full = sched.fullsync_wire_bytes()
        assert b_low < b_high
        # the knapsack respects the eq-(5) budget on the analytic floor;
        # the executed (padded) volume exceeds it by at most the size-class
        # growth of the bucket ladder
        assert sched.plan_wire_bytes(p_high, padded=False) <= full
        growth = sched.pad_growth
        assert b_high <= sched.plan_wire_bytes(p_high, padded=False) \
            * growth + len(sched.levels) * 1024 * 4

    def test_adapt_interval_eq9(self):
        cfg = ACESyncConfig(sync_interval_init=4)
        sched = Scheduler(cfg, [10 ** 5], n_pods=2)
        h1 = sched.adapt_interval(divergence=1.0, div_ref=1.0)   # high -> /2
        assert h1 == 2
        h2 = sched.adapt_interval(divergence=0.0001, div_ref=1.0)  # low -> x2
        assert h2 == 4
