"""Hierarchical sync semantics (single-device path; the multi-pod path is
covered by tests/test_multipod.py in a subprocess with 8 virtual devices)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ACESyncConfig
from repro.core import sync as S
from repro.core.compression import Level
from repro.core.scheduler import Scheduler, SyncPlan


def _tree(seed=0):
    r = np.random.RandomState(seed)
    return {"a": jnp.asarray(r.randn(64, 32).astype(np.float32)),
            "b": jnp.asarray(r.randn(2000).astype(np.float32))}


def _plan(levels_by_group, omega=(1.0,)):
    cfg = ACESyncConfig()
    sched_levels = [Level(*l) for l in cfg.levels]
    names = [l.name for l in sched_levels]
    idx = tuple(names.index(n) for n in levels_by_group)
    return SyncPlan(idx, tuple(sched_levels), omega, 1)


class TestSyncTree:
    def test_full_level_identity(self):
        tree = _tree()
        errors = jax.tree.map(jnp.zeros_like, tree)
        plan = _plan(["FULL", "FULL"])
        agg, new_e = S.sync_tree(tree, errors, plan, mesh=None,
                                 shardings=None, gamma=1.0)
        for k in tree:
            np.testing.assert_allclose(np.asarray(agg[k]),
                                       np.asarray(tree[k]), rtol=1e-2,
                                       atol=1e-2)  # bf16 wire
            # residual is only bf16 rounding
            assert float(jnp.abs(new_e[k]).max()) < 0.02

    def test_skip_buffers_into_error(self):
        tree = _tree()
        errors = jax.tree.map(jnp.zeros_like, tree)
        plan = _plan(["SKIP", "SKIP"])
        agg, new_e = S.sync_tree(tree, errors, plan, mesh=None,
                                 shardings=None, gamma=1.0)
        for k in tree:
            assert float(jnp.abs(agg[k]).max()) == 0.0
            np.testing.assert_allclose(np.asarray(new_e[k]),
                                       np.asarray(tree[k]), rtol=1e-6)

    def test_topk_residual_partition(self):
        """agg + residual == gamma-weighted EF input (lossless split)."""
        tree = _tree(1)
        errors = jax.tree.map(lambda x: jnp.ones_like(x) * 0.1, tree)
        gamma = 0.7
        plan = _plan(["TOPK10_INT8", "TOPK25_INT8"])
        agg, new_e = S.sync_tree(tree, errors, plan, mesh=None,
                                 shardings=None, gamma=gamma)
        for k in tree:
            ef = np.asarray(tree[k]) + gamma * np.asarray(errors[k])
            np.testing.assert_allclose(np.asarray(agg[k] + new_e[k]), ef,
                                       rtol=1e-4, atol=1e-4)

    def test_error_feedback_accumulates_over_steps(self):
        cfg = ACESyncConfig()
        tree = {"w": jnp.asarray(
            np.random.RandomState(3).randn(4096).astype(np.float32))}
        e = {"w": jnp.zeros(4096, jnp.float32)}
        plan = _plan(["TOPK10_INT8"])
        total = jnp.zeros(4096)
        for _ in range(120):
            agg, e = S.sync_tree(tree, e, plan, mesh=None, shardings=None,
                                 gamma=1.0)
            total = total + agg["w"]
        rel = float(jnp.linalg.norm(total / 120 - tree["w"])
                    / jnp.linalg.norm(tree["w"]))
        assert rel < 0.1, rel


class TestGroupMeta:
    def test_metas_cover_leaves(self):
        tree = {"embed": jnp.zeros((10, 4)),
                "blocks": {"attn": {"wq": jnp.zeros((2, 4, 4))},
                           "ffn": {"w_gate": jnp.zeros((2, 4, 8))}}}
        metas = S.group_metas(tree)
        assert len(metas) == len(jax.tree.leaves(tree))
        kinds = {m.name: m.kind for m in metas}
        assert kinds["embed"] == "embed"
        assert [m for m in metas if "wq" in m.name][0].kind == "attn"
        assert [m for m in metas if "w_gate" in m.name][0].kind == "mlp"

    def test_stats_shapes(self):
        tree = _tree()
        ma, var, nrm = S.grad_group_stats(tree)
        assert ma.shape == (2,) and var.shape == (2,) and nrm.shape == (2,)


class TestScheduler:
    def test_eq5_monotone_bandwidth(self):
        cfg = ACESyncConfig()
        sched = Scheduler(cfg, [10 ** 6] * 4, n_pods=2)
        from repro.core.scheduler import kept_fraction, compression_level
        fracs = [kept_fraction(cfg, bw) for bw in (5, 50, 200)]
        assert fracs[0] < fracs[1] < fracs[2]  # low bw -> keep less
        comps = [compression_level(cfg, bw) for bw in (5, 50, 200)]
        assert comps[0] > comps[1] > comps[2]  # eq (5) verbatim

    def test_plan_bytes_shrink_with_bandwidth(self):
        cfg = ACESyncConfig()
        sched = Scheduler(cfg, [10 ** 6] * 6, n_pods=2)
        imp = [0.5] * 6
        b_low = sched.plan_wire_bytes(sched.plan(imp, 5.0))
        b_high = sched.plan_wire_bytes(sched.plan(imp, 200.0))
        full = sched.fullsync_wire_bytes()
        assert b_low < b_high <= full

    def test_adapt_interval_eq9(self):
        cfg = ACESyncConfig(sync_interval_init=4)
        sched = Scheduler(cfg, [10 ** 5], n_pods=2)
        h1 = sched.adapt_interval(divergence=1.0, div_ref=1.0)   # high -> /2
        assert h1 == 2
        h2 = sched.adapt_interval(divergence=0.0001, div_ref=1.0)  # low -> x2
        assert h2 == 4
