"""No-op stand-ins for hypothesis so property-test modules still collect
(and their non-property tests still run) when hypothesis is not installed.
The property tests themselves are skipped with an explanatory reason.

Usage in a test module::

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from hypothesis_stub import given, settings, st
"""
import pytest


def given(*_args, **_kwargs):
    def deco(fn):
        return pytest.mark.skip(
            reason="hypothesis not installed (property test)")(fn)
    return deco


def settings(*_args, **_kwargs):
    def deco(fn):
        return fn
    return deco


class _StrategyStub:
    """Absorbs any st.<name>(...) strategy-construction call chain."""

    def __call__(self, *_args, **_kwargs):
        return self

    def __getattr__(self, _name):
        return self


st = _StrategyStub()
