"""Retrace-free adaptive replanning (plan-as-data) regression tests.

Pins the three contracts of the planexec refactor:

  1. steady-state replans — distinct level assignments sharing a bucket
     signature — trigger ZERO new train-step compilations (the jit cache
     is keyed on the signature, the perms ride as device data);
  2. the plan vectors are live data: the same compiled step produces
     different (and correct) results under different assignments;
  3. plan-vector execution is output-identical to the legacy static-plan
     path on the seed configs (sync_tree accepts both forms).
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SMOKE_ARCHS
from repro.configs.base import ACESyncConfig, RunConfig, ShapeConfig
from repro.core import sync as S
from repro.core import planexec
from repro.core.compression import Level
from repro.core.planexec import (ExecPlan, bucket_signature,
                                 build_exec_plan, pad_block_class)
from repro.core.scheduler import Scheduler, SyncPlan
from repro.core.trainer import Trainer
from repro.data.pipeline import TokenPipeline
from repro.models.registry import build_model

SHAPE = ShapeConfig("replan", 32, 2, "train")


def _trainer(strategy="acesync"):
    cfg = SMOKE_ARCHS["paper-350m"]
    run = RunConfig(model=cfg, shape=SHAPE, total_steps=30, warmup_steps=2,
                    lr=1e-3)
    model = build_model(cfg, run)
    tr = Trainer(model, run, mesh=None, strategy=strategy)
    return tr, TokenPipeline(model, SHAPE, seed=0)


def _same_sig_variants(sched, base_plan, n=3):
    """Distinct assignments sharing ``base_plan``'s compiled-step
    signature — bucket_sig AND (for backward-segmented plans) the
    per-segment seg_sig — via level swaps between equal-block groups."""
    from repro.core.planexec import n_blocks
    idx = list(base_plan.level_idx)
    blocks = [n_blocks(s) for s in sched.sizes]
    variants, seen = [], {tuple(idx)}
    for i in range(len(idx)):
        for j in range(i + 1, len(idx)):
            if blocks[i] == blocks[j] and idx[i] != idx[j]:
                cand = list(idx)
                cand[i], cand[j] = cand[j], cand[i]
                if tuple(cand) in seen:
                    continue
                plan = sched.plan_from_levels(cand, sync_interval=1,
                                              adaptive=True)
                if (plan.bucket_sig == base_plan.bucket_sig
                        and plan.seg_sig == base_plan.seg_sig):
                    variants.append(plan)
                    seen.add(tuple(cand))
            if len(variants) >= n:
                return variants
    return variants


class TestRetraceFree:
    def test_distinct_replans_zero_recompiles(self):
        """>= 3 distinct replans through the compiled step add zero jit
        cache entries after warmup.

        Under the default backward-segmented lowering the compiled-step
        identity is (bucket_sig, seg_sig), so the base assignment mixes
        two rungs inside each segment to admit within-segment swaps;
        cross-segment moves are a NEW signature by design and go through
        the background warm path instead (TestSpeculativeWarm)."""
        tr, pipe = _trainer()
        state = tr.init_state(jax.random.PRNGKey(0))
        plan0 = tr.default_plan(bandwidth_mbps=30.0)
        assert plan0.adaptive and plan0.bucket_sig is not None
        assert plan0.seg_sig is not None, \
            "default lowering should be backward-segmented"
        names = [l.name for l in tr.scheduler.levels]
        a, b = names.index("INT8"), names.index("INT4")
        idx = [a if i % 2 == 0 else b
               for i in range(len(tr.scheduler.sizes))]
        plan = tr.scheduler.plan_from_levels(idx, sync_interval=1,
                                             adaptive=True)
        state, _ = tr.step(state, next(pipe), plan, "grad_sync")
        warm = tr.compile_count()
        assert warm >= 1

        variants = _same_sig_variants(tr.scheduler, plan, n=3)
        assert len(variants) >= 3, \
            "seed config must admit 3 same-signature assignment swaps"
        for p in variants:
            assert p.level_idx != plan.level_idx
            state, m = tr.step(state, next(pipe), p, "grad_sync")
            assert np.isfinite(float(m["loss"]))
        assert tr.compile_count() == warm, \
            f"replanning retraced: {warm} -> {tr.compile_count()}"

    def test_omega_is_data_too(self):
        """Changing aggregation weights never recompiles either."""
        tr, pipe = _trainer("fullsync")
        state = tr.init_state(jax.random.PRNGKey(0))
        p1 = tr.scheduler.full_plan(omega=None)
        state, _ = tr.step(state, next(pipe), p1, "grad_sync")
        warm = tr.compile_count()
        p2 = tr.scheduler.full_plan(omega=(1.0,))
        state, _ = tr.step(state, next(pipe), p2, "grad_sync")
        assert tr.compile_count() == warm

    def test_plan_vectors_are_live(self):
        """Same compiled step, different perms -> different sync results:
        the plan is data, not a baked constant."""
        r = np.random.RandomState(0)
        tree = {"a": jnp.asarray(r.randn(2048).astype(np.float32)),
                "b": jnp.asarray(r.randn(2048).astype(np.float32))}
        errors = jax.tree.map(jnp.zeros_like, tree)
        cfg = ACESyncConfig()
        levels = tuple(Level(*l) for l in cfg.levels)
        names = [l.name for l in levels]
        iF, iS = names.index("FULL"), names.index("SKIP")
        sizes = [2048, 2048]

        def run(ep):
            f = jax.jit(lambda t, e, p: S.sync_tree(
                t, e, p, mesh=None, shardings=None, gamma=1.0))
            return f(tree, errors, ep)

        p_ab = build_exec_plan(
            SyncPlan((iF, iS), levels, (1.0,), 1), sizes)
        p_ba = build_exec_plan(
            SyncPlan((iS, iF), levels, (1.0,), 1), sizes)
        assert p_ab.sig == p_ba.sig
        agg1, _ = run(p_ab)
        agg2, _ = run(p_ba)
        # FULL transmits (bf16), SKIP zeroes — and they swap with the perm
        assert float(jnp.abs(agg1["a"]).max()) > 0
        assert float(jnp.abs(agg1["b"]).max()) == 0
        assert float(jnp.abs(agg2["a"]).max()) == 0
        assert float(jnp.abs(agg2["b"]).max()) > 0


class TestSpeculativeWarm:
    def test_warm_compile_avoids_foreground_compile(self):
        """AOT-warming an unseen bucket signature lets the next step run
        it WITHOUT adding a jit-cache entry (the foreground compile the
        replan-time background warm removes)."""
        tr, pipe = _trainer("fullsync")
        state = tr.init_state(jax.random.PRNGKey(0))
        sched = tr.scheduler
        p_full = sched.full_plan()
        state, _ = tr.step(state, next(pipe), p_full, "grad_sync")
        warm = tr.compile_count()
        # a different signature: everything on the INT8 rung
        names = [l.name for l in sched.levels]
        p_int8 = sched.plan_from_levels(
            [names.index("INT8")] * len(sched.sizes))
        assert not tr.step_is_warm(p_int8)
        assert tr.warm_compile(p_int8)
        assert tr.step_is_warm(p_int8)
        assert tr.warm_compiles >= 1
        state, m = tr.step(state, next(pipe), p_int8, "grad_sync")
        assert np.isfinite(float(m["loss"]))
        assert tr.compile_count() == warm, \
            "warmed signature still compiled in the foreground"

    def test_warm_compile_without_specs_is_noop(self):
        tr, _ = _trainer("fullsync")
        plan = tr.scheduler.full_plan()
        # nothing stepped yet: no argument specs to lower against
        assert tr.warm_compile(plan, kinds=("grad_sync",)) is False

    def test_loop_defers_swap_until_warm(self):
        """poll_replan on a cold signature keeps the old plan, launches
        the background warm, and swaps on a later poll — the hosted-loop
        form of the satellite."""
        from repro.launch.train import TrainLoop
        cfg = SMOKE_ARCHS["paper-350m"]
        run = RunConfig(model=cfg, shape=SHAPE, total_steps=16,
                        warmup_steps=2, lr=1e-3, ckpt_every=0,
                        acesync=ACESyncConfig(replan_every=3,
                                              sync_interval_init=2))
        model = build_model(cfg, run)
        loop = TrainLoop(model, run, mesh=None, strategy="acesync")
        pipe = TokenPipeline(model, SHAPE, seed=0)
        state = loop.restore_or_init(jax.random.PRNGKey(0), pipe)
        state = loop.run_steps(state, pipe, 8, log_every=0)
        plan0 = loop.plan
        # hand-roll a pending replan onto a signature the cache has not
        # seen (force every group onto SIGN1)
        sched = loop.trainer.scheduler
        names = [l.name for l in sched.levels]
        assign = jnp.asarray([names.index("SIGN1")] * len(sched.sizes),
                             jnp.int32)
        loop._pending_replan = (assign, None, loop._host_step)
        swapped = loop.poll_replan()
        if not swapped:                     # cold signature: deferred
            assert loop.plan is plan0 and loop._warming is not None
            assert loop.poll_replan(block=True)
        assert loop.plan is not plan0
        assert all(i == names.index("SIGN1") for i in loop.plan.level_idx)
        # every step kind the loop has actually scheduled is warm, and
        # stepping them under the new plan adds no foreground compiles
        kinds = tuple(loop.trainer._arg_specs)
        assert kinds and loop.trainer.step_is_warm(loop.plan, kinds)
        warm = loop.trainer.compile_count()
        for kind in kinds:
            state, _ = loop.trainer.step(state, next(pipe), loop.plan,
                                         kind)
        assert loop.trainer.compile_count() == warm


class TestAsyncReplanLoop:
    def test_device_replan_applies_in_loop(self, tmp_path):
        """The host loop's non-blocking replan path end-to-end: the device
        knapsack runs, the assignment vector lands asynchronously, the
        plan swaps, and training stays finite."""
        from repro.launch.train import TrainLoop
        cfg = SMOKE_ARCHS["paper-350m"]
        run = RunConfig(model=cfg, shape=SHAPE, total_steps=16,
                        warmup_steps=2, lr=1e-3, ckpt_every=0,
                        ckpt_dir=str(tmp_path),
                        acesync=ACESyncConfig(replan_every=3,
                                              sync_interval_init=2))
        model = build_model(cfg, run)
        loop = TrainLoop(model, run, mesh=None, strategy="acesync")
        pipe = TokenPipeline(model, SHAPE, seed=0)
        state = loop.restore_or_init(jax.random.PRNGKey(0), pipe)
        state = loop.run_steps(state, pipe, 14, log_every=0)
        assert len(loop.replan_latencies) >= 2, \
            "async device replans should have been applied"
        assert all(lat >= 0 for lat in loop.replan_latencies)
        assert loop.plan is not None and loop.plan.adaptive
        losses = [h["loss"] for h in loop.history if "loss" in h]
        assert len(losses) == 14 and np.isfinite(losses).all()


class TestPlanVectorParity:
    def test_exec_plan_matches_static_plan(self):
        """Plan-vector execution (padded, perms as data) is output-
        identical to the legacy static-plan trace on the seed ladder."""
        cfg = ACESyncConfig()
        levels = tuple(Level(*l) for l in cfg.levels)
        names = [l.name for l in levels]
        r = np.random.RandomState(3)
        tree = {k: jnp.asarray(r.randn(n).astype(np.float32))
                for k, n in [("a", 1000), ("b", 2048), ("c", 231),
                             ("d", 4096), ("e", 500), ("f", 300)]}
        errors = jax.tree.map(lambda x: jnp.ones_like(x) * 0.05, tree)
        idx = tuple(names.index(n) for n in
                    ["FULL", "INT8", "INT4", "SIGN1", "TOPK10_INT8",
                     "SKIP"])
        plan = SyncPlan(idx, levels, (1.0,), 1)
        sizes = [int(np.prod(v.shape)) for v in tree.values()]

        agg_s, err_s = S.sync_tree(tree, errors, plan, mesh=None,
                                   shardings=None, gamma=0.9)
        ep = build_exec_plan(plan, sizes, growth=1.125)
        agg_d, err_d = S.sync_tree(tree, errors, ep, mesh=None,
                                   shardings=None, gamma=0.9)
        for k in tree:
            np.testing.assert_allclose(np.asarray(agg_s[k]),
                                       np.asarray(agg_d[k]),
                                       rtol=1e-6, atol=1e-6)
            np.testing.assert_allclose(np.asarray(err_s[k]),
                                       np.asarray(err_d[k]),
                                       rtol=1e-6, atol=1e-6)

    def test_overlap_apply_matches_barrier_apply(self):
        """The rung-ordered apply (AdamW on each rung's bucket rows via
        sync_tree's apply_fn path, the new default) must match the
        whole-tree _optimize barrier path: same grads, same plan, same
        state -> same params / moments / EF residuals.  Guards the
        pack/gather/scatter invariants (intra-block tail padding and the
        shared zero row at index NB stay inert across rungs)."""
        cfg = SMOKE_ARCHS["paper-350m"]

        def run(overlap):
            run_cfg = RunConfig(model=cfg, shape=SHAPE, total_steps=30,
                                warmup_steps=2, lr=1e-3,
                                acesync=ACESyncConfig(
                                    overlap_apply=overlap))
            model = build_model(cfg, run_cfg)
            tr = Trainer(model, run_cfg, mesh=None, strategy="acesync")
            pipe = TokenPipeline(model, SHAPE, seed=0)
            state = tr.init_state(jax.random.PRNGKey(0))
            plan = tr.default_plan(bandwidth_mbps=30.0)
            for _ in range(3):
                state, m = tr.step(state, next(pipe), plan, "grad_sync")
            return state, m

        s_overlap, m_overlap = run(True)
        s_barrier, m_barrier = run(False)
        assert float(m_overlap["loss"]) == float(m_barrier["loss"])
        for key in ("params", "m", "v"):
            for a, b in zip(jax.tree.leaves(s_overlap[key]),
                            jax.tree.leaves(s_barrier[key])):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           rtol=1e-6, atol=1e-6,
                                           err_msg=key)
        for a, b in zip(jax.tree.leaves(s_overlap["ace"].errors),
                        jax.tree.leaves(s_barrier["ace"].errors)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-6, atol=1e-6)

    def test_delta_sync_overlap_matches_barrier_apply(self):
        """delta_sync's anchor update rung-ordered (sync_tree apply_fn
        path, the new default) must match the whole-tree barrier path:
        same state -> same params / anchor / EF residuals (the ROADMAP
        'anchor path still barriers' item)."""
        cfg = SMOKE_ARCHS["paper-350m"]

        def run(overlap):
            run_cfg = RunConfig(model=cfg, shape=SHAPE, total_steps=30,
                                warmup_steps=2, lr=1e-3,
                                acesync=ACESyncConfig(
                                    overlap_apply=overlap))
            model = build_model(cfg, run_cfg)
            tr = Trainer(model, run_cfg, mesh=None, strategy="fedavg")
            pipe = TokenPipeline(model, SHAPE, seed=0)
            state = tr.init_state(jax.random.PRNGKey(0))
            plan = tr.default_plan(bandwidth_mbps=30.0)
            for kind in ("local", "delta_sync", "local", "delta_sync"):
                state, m = tr.step(state, next(pipe), plan, kind)
            return state, m

        s_o, m_o = run(True)
        s_b, m_b = run(False)
        assert float(m_o["divergence"]) == float(m_b["divergence"])
        for key in ("params", "anchor"):
            for a, b in zip(jax.tree.leaves(s_o[key]),
                            jax.tree.leaves(s_b[key])):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           rtol=1e-6, atol=1e-6,
                                           err_msg=key)
        for a, b in zip(jax.tree.leaves(s_o["ace"].errors),
                        jax.tree.leaves(s_b["ace"].errors)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-6, atol=1e-6)

    def test_trainer_step_parity_across_plan_forms(self):
        """trainer.step under a SyncPlan equals stepping its ExecPlan."""
        tr, pipe = _trainer()
        batch = next(pipe)
        plan = tr.default_plan(bandwidth_mbps=30.0)
        s1 = tr.init_state(jax.random.PRNGKey(0))
        s2 = tr.init_state(jax.random.PRNGKey(0))
        out1, m1 = tr.step(s1, batch, plan, "grad_sync")
        out2, m2 = tr.step(s2, batch, tr.exec_plan(plan), "grad_sync")
        assert float(m1["loss"]) == float(m2["loss"])
        l1 = jax.tree.leaves(out1["params"])[0]
        l2 = jax.tree.leaves(out2["params"])[0]
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2))


class TestBucketSignature:
    def test_pad_class_properties(self):
        for growth in (1.125, 1.5, 2.0):
            prev = 0
            for nb in range(0, 2000, 7):
                c = pad_block_class(nb, growth)
                assert c >= nb
                assert c >= prev or nb == 0
                if nb > 0:
                    assert c <= max(int(np.ceil(nb * growth)), nb + 1)
                prev = c
        assert pad_block_class(0, 2.0) == 0
        assert pad_block_class(5, 1.0) == 5      # growth 1.0: exact sizes
        assert pad_block_class(5, 2.0) == 8      # power-of-two classes

    def test_signature_absorbs_jitter(self):
        """Small bucket-size jitter between replans stays in class."""
        sig1 = bucket_signature([0, 0, 1], [100 * 1024, 80 * 1024, 1024],
                                2, growth=1.125)
        sig2 = bucket_signature([0, 0, 1], [101 * 1024, 79 * 1024, 1024],
                                2, growth=1.125)
        assert sig1 == sig2

    def test_exec_plan_pads_with_zero_block(self):
        levels = (Level("INT8", 1.0, 8), Level("SKIP", 0.0, 0))
        plan = SyncPlan((0,), levels, (1.0,), 1)
        ep = build_exec_plan(plan, [3000], growth=2.0)
        assert ep.sig == (4, 0)                  # 3 blocks -> class 4
        assert ep.total_blocks == 3
        perm = np.asarray(ep.perms[0])
        assert perm.shape == (4,)
        assert list(perm[:3]) == [0, 1, 2]
        assert perm[3] == ep.total_blocks        # pad -> the zero block


class TestChunkGrid:
    LEVELS = (Level("INT8", 1.0, 8), Level("FULL", 1.0, 16),
              Level("SKIP", 0.0, 0))

    def test_chunks_in_static_key_and_pytree_aux(self):
        plan = SyncPlan((0,), (self.LEVELS[0], self.LEVELS[2]), (0.5, 0.5),
                        1)
        ep = build_exec_plan(plan, [8 * 1024], n_pods=2, ring=4)
        assert ep.chunks == (4, 0)
        assert ep.chunks in (ep.static_key()[2],) \
            and ep.static_key()[2] == ep.chunks
        # aux data: a tree-map does not touch the chunk grid
        ep2 = jax.tree.map(lambda x: x, ep)
        assert ep2.chunks == ep.chunks and ep2.sig == ep.sig

    def test_forced_ring_rounds_sig_to_chunk_multiple(self):
        plan = SyncPlan((0,), (self.LEVELS[0], self.LEVELS[2]), (0.5, 0.5),
                        1)
        ep = build_exec_plan(plan, [3 * 1024], n_pods=2, ring=2)
        assert ep.chunks[0] == 2
        assert ep.sig[0] == 4                   # 3 blocks -> 2-chunk pad
        perm = np.asarray(ep.perms[0])
        assert perm[3] == ep.total_blocks       # pad -> the zero block

    def test_heuristic_small_buckets_stay_one_shot(self):
        from repro.core.planexec import ring_chunk_count
        lvl = self.LEVELS[0]
        assert ring_chunk_count(lvl, 4, 2) == 0          # ~4KB payload
        assert ring_chunk_count(lvl, 0, 2) == 0
        assert ring_chunk_count(lvl, 10 ** 4, 1) == 0    # single pod
        # the deterministic accumulation unlocked auto rings on EVERY pod
        # count (P >= 3 folds in exact fixed-point / canonical order, so
        # cross-pod bit-determinism holds) — a DCN-bound rung rings on
        # the 3- and 4-pod meshes too
        assert ring_chunk_count(lvl, 10 ** 4, 3) >= 2
        assert ring_chunk_count(lvl, 10 ** 4, 4) >= 2
        assert ring_chunk_count(lvl, 10 ** 4, 4, ring=2) == 2  # forced ok

    def test_ring_hops_bidirectional_split(self):
        """The bidirectional ring's critical path is two half-rings of
        ceil((P-1)/2) hops; unidirectional keeps P-1."""
        from repro.core.planexec import ring_hops
        for P in range(2, 9):
            assert ring_hops(P, bidir=False) == P - 1
            assert ring_hops(P, bidir=True) == -(-(P - 1) // 2)
        assert ring_hops(1) == 0
        # per-hop wire time is P-independent, so the chosen K matches
        # across directions once a rung rings in both
        from repro.core.planexec import ring_chunk_count
        lvl = self.LEVELS[0]
        k_bi = ring_chunk_count(lvl, 64 * 1024, 4, bidir=True)
        k_uni = ring_chunk_count(lvl, 64 * 1024, 4, bidir=False)
        assert k_bi == k_uni >= 2

    def test_bidir_in_static_key(self):
        """Flipping the ring direction changes the lowered ppermute
        pattern, so it must key the compiled step."""
        plan = SyncPlan((0,), (self.LEVELS[0], self.LEVELS[2]), (0.5, 0.5),
                        1)
        ep_b = build_exec_plan(plan, [8 * 1024], n_pods=2, ring=4,
                               bidir=True)
        ep_u = build_exec_plan(plan, [8 * 1024], n_pods=2, ring=4,
                               bidir=False)
        assert ep_b.bidir and not ep_u.bidir
        assert ep_b.static_key() != ep_u.static_key()
        # aux data: a tree-map round-trips the flag
        ep2 = jax.tree.map(lambda x: x, ep_u)
        assert ep2.bidir == ep_u.bidir

    def test_heuristic_rings_dcn_bound_buckets(self):
        from repro.core.planexec import RING_MAX_CHUNKS, ring_chunk_count
        lvl = self.LEVELS[0]
        # a 64 MB int8 bucket is >> the DCN latency floor
        k = ring_chunk_count(lvl, 64 * 1024, 2)
        assert 2 <= k <= RING_MAX_CHUNKS
        assert k & (k - 1) == 0                  # power-of-two class
        # deterministic in the padded signature: same inputs, same grid
        assert k == ring_chunk_count(lvl, 64 * 1024, 2)

    def test_psum_and_skip_never_ring(self):
        from repro.core.planexec import ring_chunk_count
        assert ring_chunk_count(self.LEVELS[1], 10 ** 5, 2) == 0
        assert ring_chunk_count(self.LEVELS[2], 10 ** 5, 2) == 0
        # even forced
        assert ring_chunk_count(self.LEVELS[1], 10 ** 5, 2, ring=4) == 0

    def test_exec_grid_shared_with_scheduler_pricing(self):
        """Scheduler._finalize and build_exec_plan derive the signature
        from the same exec_grid, chunk rounding included — analytic bytes
        track the executed collectives."""
        cfg = ACESyncConfig(ring_chunks=2)
        sched = Scheduler(cfg, [3 * 1024, 2048], n_pods=2)
        plan = sched.full_plan()
        ep = build_exec_plan(plan, sched.sizes, n_pods=2, ring=2,
                             growth=None)
        assert plan.bucket_sig == ep.sig
        assert plan.ring_chunks == ep.chunks


class TestRungGrowthSchedule:
    def test_large_rungs_get_finer_classes(self):
        from repro.core.planexec import (MIN_RUNG_GROWTH, pad_block_class,
                                         rung_growth,
                                         scheduled_block_class)
        base = 1.125
        # expected (mean over sizes) padding of big rungs: the scheduled
        # ladder's ~3.1% classes beat the flat 12.5% ones (pointwise a
        # flat ladder value can land luckily close, so compare in
        # expectation)
        sizes = range(900, 1150)
        sched = np.mean([(scheduled_block_class(nb, base) - nb) / nb
                         for nb in sizes])
        flat = np.mean([(pad_block_class(nb, base) - nb) / nb
                        for nb in sizes])
        assert sched < flat / 2, (sched, flat)
        # floor regime: padding bounded by ~2x MIN_RUNG_GROWTH's excess
        assert all((scheduled_block_class(nb, base) - nb) / nb
                   <= 2 * (MIN_RUNG_GROWTH - 1.0) for nb in sizes)
        # ...but never finer than the floor: classes must stay wide
        # enough to absorb replan jitter (no per-replan retraces)
        assert rung_growth(10 ** 5, base) == MIN_RUNG_GROWTH

    def test_tiny_rungs_get_coarser_classes(self):
        from repro.core.planexec import RUNG_GROWTH_KNEE, rung_growth
        assert rung_growth(3, 1.125) == 2.0
        assert rung_growth(10, 1.125) == 1.125
        # the whole sub-knee band keeps the flat base: padding bytes are
        # negligible there and narrower classes would only add retraces
        assert rung_growth(RUNG_GROWTH_KNEE, 1.125) == 1.125
        assert rung_growth(1.0, None) is None

    def test_class_map_is_monotone_partition(self):
        """The scheduled class function is a single-ladder partition:
        monotone, idempotent, with above-knee ladder gaps wide enough
        that +-1-block replan jitter cannot force a recompile per replan
        (exhaustive over every nb — the earlier per-nb-growth scheme had
        width-1 and non-monotone classes the strided test missed)."""
        from repro.core.planexec import (MIN_RUNG_GROWTH, RUNG_GROWTH_KNEE,
                                         scheduled_block_class)
        base = 1.125
        prev = 0
        for nb in range(1, 4096):
            cls = scheduled_block_class(nb, base)
            assert cls >= nb
            assert cls >= prev, nb                        # monotone
            assert scheduled_block_class(cls, base) == cls  # idempotent
            prev = cls
        # ladder gaps above the knee: >= ~(base-1)*KNEE blocks, growing
        # to ~3.1% of the class in the floor regime
        c = scheduled_block_class(RUNG_GROWTH_KNEE + 1, base)
        while c < 4096:
            nxt = scheduled_block_class(c + 1, base)
            assert nxt - c >= (base - 1.0) * RUNG_GROWTH_KNEE - 1, (c, nxt)
            if c >= 256:
                assert nxt - c >= 0.5 * (MIN_RUNG_GROWTH - 1) * c, (c, nxt)
            c = nxt

    def test_schedule_classes_bounded(self):
        """The byte-weighted padding bound shrinks with rung size down to
        the MIN_RUNG_GROWTH floor; no class more than doubles its rung."""
        from repro.core.planexec import rung_growth, scheduled_block_class
        for nb in (3, 9, 30, 100, 400, 1500):
            cls = scheduled_block_class(nb, 1.125)
            assert nb <= cls <= 2 * nb, nb
            if nb > 64:  # past the knee: padding well under the flat 12.5%
                assert cls <= np.ceil(nb * 1.07), nb
        assert rung_growth(1500, 1.125) <= rung_growth(100, 1.125) \
            < rung_growth(30, 1.125) < rung_growth(3, 1.125)


class TestSchedulerPlanSig:
    def test_scheduler_attaches_signature(self):
        cfg = ACESyncConfig()
        sched = Scheduler(cfg, [4096, 8192, 1024], n_pods=2)
        full = sched.full_plan()
        assert full.bucket_sig is not None and not full.adaptive
        ada = sched.plan([1.0, 0.5, 0.2], 30.0)
        assert ada.adaptive
        # adaptive signature is padded: never below the exact one
        exact = bucket_signature(ada.level_idx, sched.sizes,
                                 len(sched.levels))
        assert all(p >= e for p, e in zip(ada.bucket_sig, exact))

    def test_padded_pricing_at_least_analytic(self):
        cfg = ACESyncConfig()
        sched = Scheduler(cfg, [10 ** 5] * 5, n_pods=2)
        plan = sched.plan([0.5] * 5, 25.0)
        assert sched.plan_wire_bytes(plan) >= \
            sched.plan_wire_bytes(plan, padded=False)

    def test_sig_not_priced_under_foreign_block(self):
        """A signature counted in the scheduler's block size must not be
        priced at a different block size — pricing falls back to the
        caller's sizes instead."""
        from repro.codecs import plan_wire_bytes
        cfg = ACESyncConfig(topk_block=512)
        sched = Scheduler(cfg, [4096, 2048], n_pods=2)
        plan = sched.full_plan()
        assert plan.bucket_block == 512
        # priced with the default 1024-block: rebuilt from sizes, equal to
        # the exact per-leaf block-aligned total (sizes are multiples)
        got = plan_wire_bytes(plan, sched.sizes, 2)
        assert got == plan.levels[plan.level_idx[0]].wire_bytes(6144, 2)
