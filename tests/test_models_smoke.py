"""Per-architecture smoke tests: REDUCED config of the same family runs one
forward/train step on CPU, asserting output shapes and no NaNs (assignment
requirement), plus prefill/decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SMOKE_ARCHS
from repro.configs.base import ShapeConfig
from repro.models.registry import build_model
from repro.optim import adamw

TRAIN = ShapeConfig("t", 64, 2, "train")
PREFILL = ShapeConfig("p", 64, 2, "prefill")

ARCH_IDS = sorted(SMOKE_ARCHS)


@pytest.fixture(scope="module")
def built():
    cache = {}

    def get(name):
        if name not in cache:
            model = build_model(SMOKE_ARCHS[name])
            params = model.init(jax.random.PRNGKey(0))
            cache[name] = (model, params)
        return cache[name]

    return get


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch, built):
    model, params = built(arch)
    batch = model.make_batch(jax.random.PRNGKey(1), TRAIN)
    x = jax.jit(model.forward)(params, batch)
    assert x.shape[0] == 2 and x.shape[1] == 64
    assert x.shape[-1] == model.cfg.d_model
    assert bool(jnp.all(jnp.isfinite(x.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_train_step_no_nans(arch, built):
    model, params = built(arch)
    batch = model.make_batch(jax.random.PRNGKey(2), TRAIN)

    @jax.jit
    def step(p):
        loss, grads = jax.value_and_grad(model.loss)(p, batch)
        opt = adamw.init_opt_state(p)
        newp, _ = adamw.adamw_update(p, grads, opt, jnp.int32(0), lr=1e-3)
        return loss, newp

    loss, newp = step(params)
    assert bool(jnp.isfinite(loss)), arch
    for leaf in jax.tree.leaves(newp):
        assert bool(jnp.all(jnp.isfinite(leaf))), arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_then_decode_finite(arch, built):
    model, params = built(arch)
    batch = model.make_batch(jax.random.PRNGKey(3), PREFILL)
    logits, cache = jax.jit(model.prefill)(params, batch)
    assert logits.shape[0] == 2 and logits.shape[1] == 1
    tok = jnp.zeros((2, 1), jnp.int32)
    logits2, cache2 = jax.jit(model.decode_step)(params, cache,
                                                 jnp.int32(64), tok)
    assert bool(jnp.all(jnp.isfinite(logits2.astype(jnp.float32))))


def test_decode_matches_forward_dense():
    """Teacher-forced decode reproduces the forward logits (paper-350m
    smoke): validates cache writes, ring positions and RoPE offsets."""
    model, params = (build_model(SMOKE_ARCHS["paper-350m"]),
                     build_model(SMOKE_ARCHS["paper-350m"]).init(
                         jax.random.PRNGKey(0)))
    toks = jax.random.randint(jax.random.PRNGKey(5), (1, 16), 0, 256)
    x = model.forward(params, {"tokens": toks})
    from repro.models import layers as L
    full_logits = L.lm_logits(x, params["embed"], model.cfg)

    # prefill on the first 8, then decode tokens 8..15 one by one
    logits_p, cache = model.prefill(params, {"tokens": toks[:, :8]},
                                    cache_len=16)
    np.testing.assert_allclose(np.asarray(logits_p[0, -1]),
                               np.asarray(full_logits[0, 7]),
                               rtol=0.15, atol=0.15)
    for t in range(8, 16):
        logits_d, cache = model.decode_step(params, cache, jnp.int32(t),
                                            toks[:, t:t + 1])
        np.testing.assert_allclose(np.asarray(logits_d[0, 0]),
                                   np.asarray(full_logits[0, t]),
                                   rtol=0.15, atol=0.15)


def test_sliding_window_ring_cache_consistency():
    """gemma2 smoke: decode beyond the window allocation stays finite and
    matches a fresh prefill on the same suffix."""
    model = build_model(SMOKE_ARCHS["gemma2-9b"])
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(7), (1, 48), 0, 256)
    _, cache = model.prefill(params, {"tokens": toks[:, :40]}, cache_len=64)
    for t in range(40, 48):
        logits, cache = model.decode_step(params, cache, jnp.int32(t),
                                          toks[:, t:t + 1])
        assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


def test_param_counts_match_analytic_order():
    """Reduced configs' true param count within 2x of the analytic formula
    (catches gross config/shape mistakes)."""
    for arch in ("paper-350m", "qwen3-8b", "minitron-8b", "starcoder2-3b"):
        model = build_model(SMOKE_ARCHS[arch])
        params = model.init(jax.random.PRNGKey(0))
        true = sum(x.size for x in jax.tree.leaves(params))
        analytic = SMOKE_ARCHS[arch].param_count()
        assert 0.4 < true / analytic < 2.5, (arch, true, analytic)
