"""Multi-pod trainer integration (8 virtual devices, (2,2,2) mesh).

XLA locks the device count at first use, so these run in a subprocess with
XLA_FLAGS set; the child script asserts and prints MULTIPOD_OK."""
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
import numpy as np
from repro.configs import SMOKE_ARCHS
from repro.configs.base import RunConfig, ShapeConfig
from repro.models.registry import build_model
from repro.core.trainer import Trainer
from repro.launch.mesh import make_mesh

mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
shape = ShapeConfig("t", 64, 8, "train")
cfg = SMOKE_ARCHS["qwen3-8b"]
run = RunConfig(model=cfg, shape=shape, total_steps=20, warmup_steps=2,
                lr=1e-3)
model = build_model(cfg, run)
tr = Trainer(model, run, mesh=mesh, strategy="acesync")
state = jax.device_put(tr.init_state(jax.random.PRNGKey(0)),
                       tr.state_shardings())
batch = jax.device_put(model.make_batch(jax.random.PRNGKey(1), shape),
                       tr.batch_shardings(shape))
plan = tr.default_plan(bandwidth_mbps=30.0)
fn = tr.step_fn(plan, "grad_sync")
losses = []
for _ in range(8):
    state, metrics = fn(state, batch)
    losses.append(float(metrics["loss"]))
assert losses[-1] < losses[0], losses
# grad-sync keeps pods aligned
p0 = np.asarray(jax.device_get(jax.tree.leaves(state["params"])[0]))
assert np.allclose(p0[0], p0[1], atol=1e-5), "pods diverged under grad_sync"

# local steps diverge pods, delta_sync realigns them
fn_local = tr.step_fn(plan, "local")
batch2 = jax.device_put(model.make_batch(jax.random.PRNGKey(2), shape),
                        tr.batch_shardings(shape))
state, _ = fn_local(state, batch2)  # different per-pod data -> divergence
p1 = np.asarray(jax.device_get(jax.tree.leaves(state["params"])[0]))
assert not np.allclose(p1[0], p1[1], atol=1e-7), "pods should diverge"
fn_delta = tr.step_fn(plan, "delta_sync")
state, m = fn_delta(state, batch2)
p2 = np.asarray(jax.device_get(jax.tree.leaves(state["params"])[0]))
assert np.allclose(p2[0], p2[1], atol=1e-5), "delta_sync must realign"
assert m["divergence"] >= 0.0

# fullsync == acesync-with-FULL-plan agreement on first step
tr2 = Trainer(model, run, mesh=mesh, strategy="fullsync")
state2 = jax.device_put(tr2.init_state(jax.random.PRNGKey(0)),
                        tr2.state_shardings())
fn2 = tr2.step_fn(tr2.default_plan(), "grad_sync")
state2, m2 = fn2(state2, batch)
assert abs(m2["loss"] - losses[0]) < 1e-3
print("MULTIPOD_OK")
"""


@pytest.mark.slow
def test_multipod_trainer_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "MULTIPOD_OK" in r.stdout


P3_SOAK_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=12"
import jax, jax.numpy as jnp
import numpy as np
from repro.configs import SMOKE_ARCHS
from repro.configs.base import ACESyncConfig, RunConfig, ShapeConfig
from repro.models.registry import build_model
from repro.core.trainer import Trainer
from repro.launch.mesh import make_mesh

mesh = make_mesh((3, 2, 2), ("pod", "data", "model"))
shape = ShapeConfig("t", 64, 6, "train")
cfg = SMOKE_ARCHS["paper-350m"]
# forced 2-chunk ring on every ring-capable rung: on a 3-pod mesh every
# exchange (ring AND one-shot) folds deterministically, so pods fed
# DIFFERENT data must stay BIT-identical under grad_sync — the drift the
# old arrival-order float fold allowed
run = RunConfig(model=cfg, shape=shape, total_steps=20, warmup_steps=2,
                lr=1e-3, acesync=ACESyncConfig(ring_chunks=2))
model = build_model(cfg, run)
tr = Trainer(model, run, mesh=mesh, strategy="acesync")
state = jax.device_put(tr.init_state(jax.random.PRNGKey(0)),
                       tr.state_shardings())
plan = tr.default_plan(bandwidth_mbps=30.0)
assert any(c >= 2 for c in tr.exec_plan(plan).chunks), \
    tr.exec_plan(plan).chunks
fn = tr.step_fn(plan, "grad_sync")
for s in range(4):
    batch = jax.device_put(
        model.make_batch(jax.random.PRNGKey(s + 1), shape),
        tr.batch_shardings(shape))
    state, metrics = fn(state, batch)
    assert np.isfinite(float(metrics["loss"]))
# per-pod parameter hashes: every leaf bit-identical across the 3 pods
for path, leaf in jax.tree_util.tree_flatten_with_path(
        state["params"])[0]:
    a = np.asarray(jax.device_get(leaf))
    for p in (1, 2):
        assert (a[0] == a[p]).all(), (path, "pods drifted")
print("P3_SOAK_OK")
"""


# Backward-interleaved streaming at the trainer level: a multi-step EF
# soak with overlap_backward on vs off must land BIT-identical params on
# every pod (the segment split is numerics-neutral by blockwise codec
# math; anything else is a streaming bug).  The contract is pinned on
# the kernel path (REPRO_FORCE_INTERPRET=1, matching CI): on the pure-
# jnp oracle path XLA:CPU fuses the whole step program and its FMA
# contraction follows the program shape, so the differently-segmented
# on/off programs pick up ulp-level noise OUTSIDE the sync region —
# sync_tree itself is bit-exact seg-vs-flat even with nonzero error
# buffers (pinned in tests/test_collectives.py).  Parameterised via env
# vars like tests/test_collectives.py's DET_SCRIPT (XLA locks the device
# count per process).  The companion retrace contract — zero steady-state
# recompiles across replans that change the rung schedule, including
# segmented ones — is pinned in tests/test_replan.py.
OVERLAP_SOAK_SCRIPT = r"""
import os
MESH = tuple(int(x) for x in os.environ["REPRO_TEST_MESH"].split(","))
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                           + os.environ["REPRO_TEST_DEVS"])
import jax
import numpy as np
from repro.configs import SMOKE_ARCHS
from repro.configs.base import ACESyncConfig, RunConfig, ShapeConfig
from repro.models.registry import build_model
from repro.core.trainer import Trainer
from repro.launch.mesh import make_mesh

mesh = make_mesh(MESH, ("pod", "data", "model"))
shape = ShapeConfig("t", 64, 6, "train")
cfg = SMOKE_ARCHS["paper-350m"]


def soak(overlap):
    run = RunConfig(model=cfg, shape=shape, total_steps=20,
                    warmup_steps=2, lr=1e-3,
                    acesync=ACESyncConfig(overlap_backward=overlap))
    model = build_model(cfg, run)
    tr = Trainer(model, run, mesh=mesh, strategy="acesync")
    plan = tr.default_plan(bandwidth_mbps=30.0)
    assert tr.exec_plan(plan).segmented == overlap, overlap
    state = jax.device_put(tr.init_state(jax.random.PRNGKey(0)),
                           tr.state_shardings())
    fn = tr.step_fn(plan, "grad_sync")
    for s in range(4):
        batch = jax.device_put(
            model.make_batch(jax.random.PRNGKey(s + 1), shape),
            tr.batch_shardings(shape))
        state, metrics = fn(state, batch)
        assert np.isfinite(float(metrics["loss"])), (overlap, s)
    return state


st_on, st_off = soak(True), soak(False)
n = 0
for (path, a), (_, b) in zip(
        jax.tree_util.tree_flatten_with_path(st_on["params"])[0],
        jax.tree_util.tree_flatten_with_path(st_off["params"])[0]):
    aa = np.asarray(jax.device_get(a))
    bb = np.asarray(jax.device_get(b))
    assert (aa == bb).all(), (path, "overlap changed the math")
    for p in range(1, MESH[0]):
        assert (aa[0] == aa[p]).all(), (path, "pods drifted")
    n += 1
assert n > 0
print("OVERLAP_SOAK_OK", n)
"""


def _run_overlap_soak(mesh, devs):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    env["REPRO_TEST_MESH"] = mesh
    env["REPRO_TEST_DEVS"] = str(devs)
    # Pin the kernel path: the parity contract is on the production
    # encode kernels, not the oracle path's whole-program XLA:CPU fusion
    # (see the comment above OVERLAP_SOAK_SCRIPT).
    env["REPRO_FORCE_INTERPRET"] = "1"
    r = subprocess.run([sys.executable, "-c", OVERLAP_SOAK_SCRIPT],
                       env=env, capture_output=True, text=True,
                       timeout=900)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OVERLAP_SOAK_OK" in r.stdout


@pytest.mark.slow
def test_overlap_backward_bit_parity_p2():
    """4-step EF soak on (2,2,2): params with overlap_backward on == off,
    bit for bit, and bit-identical across pods."""
    _run_overlap_soak("2,2,2", 8)


@pytest.mark.slow
def test_overlap_backward_bit_parity_p3():
    """Same contract on a 3-pod mesh, where every exchange folds through
    the deterministic fixed-point path."""
    _run_overlap_soak("3,2,2", 12)


@pytest.mark.slow
def test_p3_trainer_grad_sync_param_hash_soak():
    """Multi-step grad_sync on a simulated 3-pod mesh with a forced ring:
    per-pod parameters stay BIT-identical (the deterministic P >= 3
    accumulation contract at the trainer level)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    r = subprocess.run([sys.executable, "-c", P3_SOAK_SCRIPT], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "P3_SOAK_OK" in r.stdout


# Preemption soak: kill the driver at step k (checkpoints at 5/10, the
# newest one bit-rotted on disk + a crashed writer's .tmp left behind), a
# FRESH loop restores from the newest checkpoint that VERIFIES and
# continues — landing params, EF residuals and plan state BIT-identical
# to the uninterrupted run on the same mesh.  ``blocking_replans`` pins
# replan application to fixed steps so the plan/H trajectory is a pure
# function of the state trajectory.
PREEMPT_SOAK_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import tempfile
import jax, numpy as np
from repro.configs.base import ACESyncConfig
from repro.launch.mesh import make_mesh
from repro.launch.session import TrainSession
import repro.runtime.faults as F

STEPS = 14
mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))


def mk(d):
    return TrainSession.from_config(
        "paper-350m", strategy="acesync", mesh=mesh, steps=STEPS,
        seq_len=32, batch=4, ckpt_dir=d, ckpt_every=5,
        blocking_replans=True, acesync=ACESyncConfig(replan_every=4))


def host(tree):
    return [np.asarray(jax.device_get(l)) for l in jax.tree.leaves(tree)]


# run A: uninterrupted
dA = tempfile.mkdtemp()
a = mk(dA); a.run(STEPS, log_every=100); a.finish()

# run B: preempted after step 11 (checkpoints landed at 5 and 10)
dB = tempfile.mkdtemp()
b1 = mk(dB); b1.run(11, log_every=100); b1.finish()
# the preemption tore a write and bit-rotted the newest checkpoint:
os.makedirs(os.path.join(dB, "step_00000099.tmp"))
d10 = os.path.join(dB, "step_00000010")
biggest = max((n for n in os.listdir(d10) if n.startswith("leaf_")),
              key=lambda n: os.path.getsize(os.path.join(d10, n)))
idx = int(biggest.split("_")[1].split(".")[0])
assert F.corrupt_checkpoint_leaf(dB, idx, step=10)

# fresh process-equivalent: new session over the same ckpt dir
b2 = mk(dB)
b2.init()
restored = int(jax.device_get(
    jax.tree.leaves(b2.state["step"])[0].reshape(-1)[0]))
assert restored == 5, f"should fall back to step 5, got {restored}"
assert 10 in b2.loop.ckpt.corrupt_steps
b2.run(STEPS - restored, log_every=100)
b2.finish()

for la, lb in zip(host(a.state["params"]), host(b2.state["params"])):
    assert (la == lb).all(), "params diverged after restart-replay"
for la, lb in zip(host(a.state["ace"].errors),
                  host(b2.state["ace"].errors)):
    assert (la == lb).all(), "EF residuals diverged after restart-replay"
assert a.loop._plan.level_idx == b2.loop._plan.level_idx
assert a.loop._plan.sync_interval == b2.loop._plan.sync_interval
assert a.loop._steps_since_sync == b2.loop._steps_since_sync
assert (a.loop.trainer.scheduler.sync_interval
        == b2.loop.trainer.scheduler.sync_interval)
print("PREEMPT_SOAK_OK")
"""


@pytest.mark.slow
def test_preemption_restart_replay_bit_identical():
    """Kill at step k, restore (with fallback past a corrupt newest
    checkpoint), continue: bit-identical params + EF residuals + plan
    state vs the uninterrupted run."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    r = subprocess.run([sys.executable, "-c", PREEMPT_SOAK_SCRIPT], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "PREEMPT_SOAK_OK" in r.stdout


# Elastic soak: P=3 -> pod 2 preempted at step 4 -> P=2 -> rejoin at
# step 8 -> P=3.  Each transition re-derives the mesh/ring through a
# per-pod-count trainer whose step is AOT-warmed in the background, so
# the membership change adds ZERO foreground recompiles over the
# fault-free baseline (compile_count stays flat; the new-P signature is
# served from the warm AOT cache).
ELASTIC_SOAK_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=12"
import tempfile
import jax, numpy as np
from repro.launch.mesh import make_mesh
from repro.launch.session import TrainSession
from repro.runtime.faults import FaultSchedule

STEPS = 14


def run(faults):
    mesh = make_mesh((3, 2, 2), ("pod", "data", "model"))
    sess = TrainSession.from_config(
        "paper-350m", strategy="acesync", mesh=mesh, steps=STEPS,
        seq_len=32, batch=6, ckpt_dir=tempfile.mkdtemp(), ckpt_every=0,
        fault_schedule=faults, blocking_replans=True)
    sess.run(STEPS, log_every=100)
    sess.finish()
    return sess


base = run(None)
base_compiles = base.loop.compile_count()
assert base.loop.membership_events == []

faults = FaultSchedule.preempt_and_rejoin(pod=2, kill_step=4,
                                          rejoin_step=8)
sess = run(faults)
loop = sess.loop
ev = loop.membership_events
assert [e["n_pods"] for e in ev] == [2, 3], ev
assert all(e["served_from_warm_cache"] for e in ev), ev
# the P-change added ZERO foreground recompiles over the baseline
assert loop.compile_count() == base_compiles, \
    (loop.compile_count(), base_compiles)
assert loop.warm_compile_count() >= 2
# mesh / ring hops / scheduler re-derived for the shrunken fleet
tr2 = loop._trainers[2]
assert tr2.n_pods == 2 and tr2.mesh.shape["pod"] == 2
assert tr2.scheduler.n_pods == 2
# batch re-balanced with membership (rows-per-slice constant), and back
assert loop.trainer.n_pods == 3
assert loop._pipeline.shape.global_batch == 6
assert jax.tree.leaves(sess.state["params"])[0].shape[0] == 3
assert all(np.isfinite(l) for l in sess.losses), sess.losses
assert len(loop.faults.peek()) == 0
# dead pod dropped out of the heartbeat feed while preempted
assert 2 in loop.monitor.alive_pods()
print("ELASTIC_SOAK_OK")
"""


@pytest.mark.slow
def test_elastic_membership_zero_foreground_recompiles():
    """P=3 -> P=2 -> P=3 under an injected preempt/rejoin: compile_count
    stays flat vs the fault-free baseline, membership swaps are served
    from the background-warmed AOT cache, ring/mesh re-derived."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    r = subprocess.run([sys.executable, "-c", ELASTIC_SOAK_SCRIPT], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "ELASTIC_SOAK_OK" in r.stdout
