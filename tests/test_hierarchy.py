"""Hierarchical cloud-edge coordination: clustering, telemetry, omega.

Fast host-side contracts of the ``repro/hierarchy`` subsystem — k-means
determinism + empty-cluster handling, reliability-weight sanity, the
counter-hashed telemetry replay (pinned golden values), ClusterState
hysteresis — plus the slow subprocess pins of the two-tier exchange: the
analytic ``plan_wire_bytes`` / ``plan_intra_bytes`` accounting equals the
traced HLO collective bytes on BOTH tiers of a simulated heterogeneous
mesh, per-fleet-member aggregates stay bit-identical across cluster
re-assignments, and telemetry-driven replans that re-cluster mid-run add
zero steady-state recompiles."""
import math
import os
import subprocess
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "..", "src"))

from repro.core.clustering import (cluster_devices, kmeans,
                                   normalise_profiles, reliability_weights)
from repro.data.telemetry import (bandwidth_at, latency_at, make_profiles,
                                  snapshot, transfer_seconds)
from repro.hierarchy import ClusterState


def _partition(assignments):
    """Cluster labels -> frozenset of frozensets of member indices."""
    by = {}
    for i, a in enumerate(assignments):
        by.setdefault(a, set()).add(i)
    return frozenset(frozenset(v) for v in by.values())


# ---------------------------------------------------------------------------
# k-means
# ---------------------------------------------------------------------------


class TestKMeans:
    def test_converges_on_separated_blobs(self):
        r = np.random.RandomState(0)
        centers = np.array([[0.0, 0.0], [10.0, 0.0], [0.0, 10.0]])
        x = np.concatenate([c + 0.1 * r.randn(20, 2) for c in centers])
        assign, cent = kmeans(x, 3)
        # each blob lands in exactly one cluster
        for b in range(3):
            blob = assign[b * 20:(b + 1) * 20]
            assert len(set(blob.tolist())) == 1, blob
        # and the three blobs get three distinct clusters
        assert len(set(assign.tolist())) == 3
        assert np.isfinite(cent).all()

    def test_empty_cluster_reseeded_from_farthest_point(self):
        # 9 identical points + 1 far outlier with k=3: naive Lloyd's leaves
        # a cluster empty forever; the re-seed must give the outlier (the
        # worst-served point) its own centroid
        x = np.zeros((10, 2))
        x[-1] = [100.0, 100.0]
        assign, cent = kmeans(x, 3)
        assert assign[-1] != assign[0]
        assert np.isfinite(cent).all()
        # the outlier's centroid sits on the outlier
        np.testing.assert_allclose(cent[assign[-1]], x[-1])

    def test_permutation_determinism(self):
        profiles = snapshot(make_profiles(12, seed=5), step=3)
        base = cluster_devices(profiles, 3)
        perm = [7, 0, 11, 4, 2, 9, 1, 10, 5, 8, 3, 6]
        permuted = cluster_devices([profiles[i] for i in perm], 3)
        # device profiles[perm[j]] sits at position j of the permuted run:
        # the induced partition over ORIGINAL indices must be identical
        unpermuted = [None] * len(base)
        for j, i in enumerate(perm):
            unpermuted[i] = permuted[j]
        assert _partition(unpermuted) == _partition(base)

    def test_warm_start_keeps_stable_partition(self):
        x = normalise_profiles(snapshot(make_profiles(10, seed=2), 0))
        a1, c1 = kmeans(x, 3)
        a2, c2 = kmeans(x, 3, init=c1)
        assert _partition(a1.tolist()) == _partition(a2.tolist())


# ---------------------------------------------------------------------------
# reliability weights (paper eq. 8)
# ---------------------------------------------------------------------------


class TestReliabilityWeights:
    def test_softmax_normalised_and_cluster_shared(self):
        telem = snapshot(make_profiles(8, seed=1), 0)
        assign = cluster_devices(telem, 3)
        w = reliability_weights(telem, assign)
        assert all(v > 0 for v in w)
        assert math.isclose(sum(w), 1.0, rel_tol=1e-9)
        # weights are shared within a cluster
        by = {}
        for wi, a in zip(w, assign):
            by.setdefault(a, set()).add(round(wi, 12))
        assert all(len(v) == 1 for v in by.values())

    def test_single_cluster_is_uniform(self):
        telem = snapshot(make_profiles(5, seed=3), 0)
        w = reliability_weights(telem, [0] * 5)
        np.testing.assert_allclose(w, [0.2] * 5, rtol=1e-12)

    def test_zero_bandwidth_device_is_downweighted_not_nan(self):
        telem = [dict(bandwidth_mbps=100.0, latency_ms=50.0, straggle=1.0)
                 for _ in range(3)]
        telem.append(dict(bandwidth_mbps=0.0, latency_ms=50.0, straggle=1.0))
        w = reliability_weights(telem, [0, 0, 0, 1])
        assert all(math.isfinite(v) and v >= 0 for v in w)
        assert math.isclose(sum(w), 1.0, rel_tol=1e-9)
        assert w[3] < w[0] * 1e-3  # effectively muted, never NaN


# ---------------------------------------------------------------------------
# telemetry replay (counter-hashed, deterministic)
# ---------------------------------------------------------------------------


class TestTelemetry:
    def test_pure_function_of_args(self):
        profiles = make_profiles(4, seed=7)
        for p in profiles:
            for step in (0, 1, 123, 10_000):
                assert bandwidth_at(p, step, 7) == bandwidth_at(p, step, 7)
                assert latency_at(p, step, 7) == latency_at(p, step, 7)
        # interleaved call ORDER must not matter (the seed bug this
        # replaces: a shared np.random.RandomState made every call
        # order-dependent)
        a = [bandwidth_at(profiles[0], s, 7) for s in range(8)]
        b = list(reversed([bandwidth_at(profiles[0], s, 7)
                           for s in reversed(range(8))]))
        assert a == b

    def test_golden_values(self):
        profiles = make_profiles(4, seed=7)
        golden = [
            (0, 0, bandwidth_at, 6.028853474056805),
            (0, 123, bandwidth_at, 6.836170237407475),
            (0, 0, latency_at, 271.6884870714287),
            (0, 123, latency_at, 261.45078419990637),
            (1, 0, bandwidth_at, 169.5390496402137),
            (1, 123, bandwidth_at, 177.53702353965846),
            (1, 0, latency_at, 178.7867003927135),
            (1, 123, latency_at, 198.63491350657725),
        ]
        for dev, step, fn, want in golden:
            got = fn(profiles[dev], step, 7)
            assert got == pytest.approx(want, rel=1e-12), (dev, step, fn)

    def test_bounds_and_snapshot_keys(self):
        profiles = make_profiles(16, seed=0)
        for step in (0, 50, 500):
            for t in snapshot(profiles, step):
                assert 5.0 <= t["bandwidth_mbps"] <= 200.0
                assert 10.0 <= t["latency_ms"] <= 300.0
                assert t["straggle"] >= 1.0

    def test_transfer_seconds_pricing(self):
        # 1 MB at 100 Mbps + 20 ms propagation = 80 ms wire + 20 ms
        assert transfer_seconds(1_000_000, 100.0, 20.0) == \
            pytest.approx(0.1, rel=1e-12)
        assert transfer_seconds(0, 100.0, 20.0) == \
            pytest.approx(0.02, rel=1e-12)


# ---------------------------------------------------------------------------
# ClusterState: hysteresis + fleet mapping
# ---------------------------------------------------------------------------


class TestClusterState:
    def test_no_flap_under_jitter_only_telemetry(self):
        # well-separated bandwidth tiers + per-step jitter: re-clustering
        # every step must never move a device once assigned
        profiles = make_profiles(12, seed=4)
        cs = ClusterState(12, k=3, hysteresis=0.15)
        for step in range(0, 120, 5):
            cs.update(snapshot(profiles, step))
        assert cs.updates == 24
        assert cs.churn == 0
        assert cs.reclusters == 0

    def test_zero_hysteresis_tracks_plain_kmeans_moves(self):
        # hysteresis=0 accepts every proposed move: the filter, not the
        # proposal machinery, is what suppresses flapping
        profiles = make_profiles(12, seed=4)
        strict = ClusterState(12, k=3, hysteresis=0.0)
        for step in range(0, 120, 5):
            strict.update(snapshot(profiles, step))
        assert strict.updates == 24  # runs fine; churn may or may not be 0

    def test_drift_eventually_reclusters(self):
        # a device whose profile jumps decisively must cross the
        # hysteresis band and move
        telem = [dict(bandwidth_mbps=200.0, latency_ms=20.0, jitter=0.1,
                      straggle=1.0) for _ in range(4)]
        telem += [dict(bandwidth_mbps=6.0, latency_ms=280.0, jitter=0.1,
                       straggle=1.5) for _ in range(4)]
        cs = ClusterState(8, k=2, hysteresis=0.15)
        cs.update(telem)
        before = list(cs.assignments)
        moved = dict(telem[0])            # device 7 becomes a fast device
        telem2 = telem[:7] + [moved]
        cs.update(telem2)
        assert cs.assignments[7] == before[0]
        assert cs.churn >= 1 and cs.reclusters >= 1

    def test_fleet_slots_round_robin(self):
        cs = ClusterState(8, k=2)
        cs.assignments = [0, 0, 0, 0, 1, 1, 1, 1]
        slots = cs.fleet_slots(n_cross=2, n_edge=2)
        assert slots == [0, 1, 0, 1, 2, 3, 2, 3]

    def test_fleet_omega_normalised_and_fills_empty_slots(self):
        telem = snapshot(make_profiles(8, seed=6), 0)
        cs = ClusterState(8, k=2)
        cs.update(telem)
        om = cs.fleet_omega(telem, 2, 2)
        assert len(om) == 4
        assert math.isclose(sum(om), 1.0, rel_tol=1e-9)
        assert all(v > 0 for v in om)
        # 3 devices onto a 2x4 fleet: the 5+ empty slots get positive fill
        cs3 = ClusterState(3, k=2)
        cs3.update(telem[:3])
        om_wide = cs3.fleet_omega(telem[:3], 2, 4)
        assert len(om_wide) == 8
        assert math.isclose(sum(om_wide), 1.0, rel_tol=1e-9)
        assert all(v > 0 for v in om_wide)

    def test_policies_and_bottleneck(self):
        from repro.configs.base import ACESyncConfig
        telem = snapshot(make_profiles(10, seed=8), 0)
        cs = ClusterState(10, k=3)
        cs.update(telem)
        pols = cs.policies(telem, ACESyncConfig())
        assert sum(len(p.members) for p in pols) == 10
        assert math.isclose(sum(p.omega for p in pols), 1.0, rel_tol=1e-9)
        assert all(0.0 < p.kept_fraction <= 1.0 for p in pols)
        assert cs.bottleneck_bandwidth(telem) == \
            min(p.bandwidth_mbps for p in pols)
        mean_bw = sum(t["bandwidth_mbps"] for t in telem) / len(telem)
        assert cs.bottleneck_bandwidth(telem) <= mean_bw

    def test_update_before_query_raises(self):
        cs = ClusterState(4, k=2)
        with pytest.raises(RuntimeError):
            cs.fleet_omega([], 2, 2)


# ---------------------------------------------------------------------------
# scheduler guard (satellite: loud failure on degenerate omega)
# ---------------------------------------------------------------------------


def test_scheduler_rejects_nonpositive_omega_sum():
    from repro.configs.base import ACESyncConfig
    from repro.core.scheduler import Scheduler
    sched = Scheduler(ACESyncConfig(), [1024, 2048], n_pods=2)
    with pytest.raises(ValueError, match="positive finite sum"):
        sched.full_plan((0.0, 0.0))
    with pytest.raises(ValueError, match="positive finite sum"):
        sched.full_plan((1.0, float("nan")))
    # a valid omega still normalises
    plan = sched.full_plan((1.0, 3.0))
    assert plan.omega == pytest.approx((0.25, 0.75))


def test_scheduler_hier_pricing_cuts_cross_tier_bytes():
    """A hierarchical scheduler prices hier-capable rungs at the cluster
    count: cross-tier bytes drop vs the flat fleet, and the intra tier
    picks up the (cheap, fast-link) difference."""
    from repro.configs.base import ACESyncConfig
    from repro.core.scheduler import Scheduler
    sizes = [4096, 8192, 2048]
    flat = Scheduler(ACESyncConfig(), sizes, n_pods=4)
    hier = Scheduler(ACESyncConfig(), sizes, n_pods=4, n_edge=2)
    assert not flat.hier_enabled
    assert hier.hier_enabled and hier.n_cross == 2
    imp = [1.0, 2.0, 0.5]
    pf = flat.plan(imp, 50.0)
    ph = hier.plan(imp, 50.0)
    assert ph.hier is not None and any(ph.hier)
    assert not any(pf.hier or ())
    # same signature -> strictly fewer cross-tier bytes, non-zero intra
    if pf.bucket_sig == ph.bucket_sig and pf.level_idx == ph.level_idx:
        assert hier.plan_wire_bytes(ph) < flat.plan_wire_bytes(pf)
    assert hier.plan_intra_bytes(ph) > 0
    assert flat.plan_intra_bytes(pf) == 0
    # forcing flat (hier_mode=-1) restores single-tier pricing
    forced = Scheduler(ACESyncConfig(hier_mode=-1), sizes, n_pods=4,
                       n_edge=2)
    assert not forced.hier_enabled
    pfo = forced.plan(imp, 50.0)
    assert not any(pfo.hier or ())


# ---------------------------------------------------------------------------
# two-tier exchange: traced-HLO pin on a simulated heterogeneous mesh
# ---------------------------------------------------------------------------

HIER_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core import planexec
from repro.core import sync as S
from repro.core.compression import Level
from repro.core.scheduler import SyncPlan
from repro.launch.mesh import make_mesh
from benchmarks import hlo_cost

MESH_SHAPE, MESH_AXES = (2, 2, 2), ("pod", "edge", "data")
mesh = make_mesh(MESH_SHAPE, MESH_AXES)
FLEET, N_CROSS, N_EDGE = 4, 2, 2

# dense-quantiser ladder: every rung supports the two-tier path
levels = (Level("INT8", 1.0, 8), Level("INT4", 1.0, 4))
sizes = [2048, 3000, 1500]
idx = (0, 1, 0)
omega_a = (0.1, 0.2, 0.3, 0.4)

r = np.random.RandomState(0)
tree = {f"p{i}": jnp.asarray(r.randn(n).astype(np.float32))
        for i, n in enumerate(sizes)}
errors = jax.tree.map(jnp.zeros_like, tree)

# force INTRA_INT8 so the intra tier is an all_gather with exact byte
# accounting (FULL's bf16 psum gets f32-promoted by XLA on CPU)
ep = planexec.build_exec_plan(
    SyncPlan(idx, levels, omega_a, 1), [int(x.size) for x in tree.values()],
    n_pods=FLEET, n_edge=N_EDGE, hier=planexec.hier_override(2))
assert ep.hier and all(h == planexec.INTRA_INT8 for h in ep.hier
                       if h), ep.hier
assert any(h for h in ep.hier), "no two-tier rung chosen"


def inner(t, e, p):
    return S.sync_tree(t, e, p, mesh=mesh, shardings=None, gamma=1.0,
                       inside_manual=True)


pspec = jax.tree.map(lambda _: P(), tree)
smapped = compat.shard_map(
    inner, mesh,
    in_specs=(pspec, pspec, jax.tree.map(lambda _: P(), ep)),
    out_specs=(pspec, pspec),
    manual_axes=set(mesh.axis_names))
fn = jax.jit(smapped)

agg_a, err_a = fn(tree, errors, ep)

# --- per-fleet-member bit-identity (pod-uniformity of the aggregate) ----
for k in tree:
    a = np.asarray(jax.device_get(agg_a[k]))
    assert np.isfinite(a).all(), k

# the aggregate is replicated across the fleet: re-run under a CHANGED
# cluster assignment (different omega slotting) — same compiled fn (omega
# is device data), still finite, and deterministically different
omega_b = (0.4, 0.3, 0.2, 0.1)
agg_b, _ = fn(tree, errors, ep.with_omega(jnp.asarray(omega_b,
                                                      jnp.float32)))
agg_b2, _ = fn(tree, errors, ep.with_omega(jnp.asarray(omega_b,
                                                       jnp.float32)))
for k in tree:
    b1 = np.asarray(jax.device_get(agg_b[k]))
    b2 = np.asarray(jax.device_get(agg_b2[k]))
    assert (b1 == b2).all(), f"{k}: nondeterministic across identical runs"
    assert not (b1 == np.asarray(jax.device_get(agg_a[k]))).all(), \
        f"{k}: omega change had no effect"
assert fn._cache_size() == 1, \
    f"re-clustering retraced the step: {fn._cache_size()} traces"

# --- traced-HLO pin: analytic == traced on BOTH tiers -------------------
txt = fn.lower(tree, errors, ep).compile().as_text()
rep = hlo_cost.analyze(txt, MESH_SHAPE, MESH_AXES)
# price the EXECUTED grid: sig/hier of the lowered plan, cross tier at
# the cluster count, intra tier at the edge-group width
cross_analytic = planexec.sig_wire_bytes(ep.sig, ep.levels, FLEET,
                                         hier=ep.hier, n_cross=N_CROSS)
intra_analytic = planexec.sig_intra_bytes(ep.sig, ep.levels, N_EDGE,
                                          hier=ep.hier)
cross_traced = rep.collective_bytes.get("pod", 0.0)
intra_traced = rep.collective_bytes.get("edge", 0.0)
assert cross_traced == float(cross_analytic), \
    f"cross tier: analytic {cross_analytic} != traced {cross_traced}"
assert intra_traced == float(intra_analytic), \
    f"intra tier: analytic {intra_analytic} != traced {intra_traced}"
# no sync traffic on the data axis or the combined flat fleet axis
for ax, b in rep.collective_bytes.items():
    if ax not in ("pod", "edge"):
        assert b == 0.0, (ax, b)
print("HIER_PIN_OK", int(cross_analytic), int(intra_analytic))
"""


RECLUSTER_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax
from repro.configs.base import ACESyncConfig
from repro.launch.mesh import make_mesh
from repro.launch.session import TrainSession

mesh = make_mesh((2, 2, 2), ("pod", "edge", "data"))
ace = ACESyncConfig(replan_every=3, sync_interval_init=2)
sess = TrainSession.from_config(
    "paper-350m", strategy="acesync_hier", mesh=mesh, seq_len=64,
    batch=4, steps=400, warmup_steps=10, ckpt_every=0, n_edge_devices=16,
    ckpt_dir="/tmp/repro_recluster_ckpt", acesync=ace)
sess.run(8, log_every=0)
tr = sess.trainer
assert tr.n_pods == 4 and tr.n_edge == 2
assert tr.scheduler.hier_enabled
# stabilise, then land any in-flight replan/AOT warm-up
for _ in range(6):
    before = tr.compile_count()
    sess.run(6, log_every=0)
    if tr.compile_count() == before:
        break
sess.loop.poll_replan(block=True)
compiles = tr.compile_count()
updates_before = sess.loop.clusters.updates
sess.run(18, log_every=0)          # 6 replans, each re-clustering
sess.loop.poll_replan(block=True)
assert sess.loop.clusters.updates > updates_before, "no re-cluster ran"
assert tr.compile_count() == compiles, (
    f"steady-state replans recompiled: {tr.compile_count()} != {compiles}")
# fleet members hold bit-identical params after compressed two-tier syncs
params = jax.device_get(sess.state["params"])
for path, leaf in jax.tree_util.tree_leaves_with_path(params):
    arr = np.asarray(leaf)
    for m in range(1, arr.shape[0]):
        assert (arr[m] == arr[0]).all(), jax.tree_util.keystr(path)
assert all(np.isfinite(l) for l in sess.losses)
print("RECLUSTER_OK", sess.loop.clusters.updates, tr.compile_count())
"""


def _run_sub(script):
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src") + os.pathsep + root
    env.setdefault("REPRO_FORCE_INTERPRET", "1")
    return subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=900)


@pytest.mark.slow
def test_two_tier_hlo_pin_subprocess():
    r = _run_sub(HIER_SCRIPT)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "HIER_PIN_OK" in r.stdout


@pytest.mark.slow
def test_recluster_replans_zero_recompiles_subprocess():
    r = _run_sub(RECLUSTER_SCRIPT)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "RECLUSTER_OK" in r.stdout
