"""Checkpointing, data pipeline, telemetry, clustering, fault tolerance."""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.core.clustering import cluster_devices, kmeans, \
    reliability_weights
from repro.data.pipeline import TokenPipeline
from repro.data.telemetry import (bandwidth_at, make_profiles, snapshot,
                                  transfer_seconds, BW_MIN, BW_MAX)
from repro.runtime.fault_tolerance import (ElasticPlanner, HeartbeatMonitor,
                                           MeshPlan, StragglerDetector)
from repro.configs import SMOKE_ARCHS
from repro.configs.base import ShapeConfig
from repro.models.registry import build_model


class TestCheckpointer:
    def test_roundtrip(self, tmp_path):
        ck = Checkpointer(str(tmp_path))
        state = {"a": jnp.arange(10, dtype=jnp.float32),
                 "b": {"c": jnp.ones((3, 4))}}
        ck.save(5, state, extras={"pipe": {"seed": 1, "step": 7}},
                blocking=True)
        assert ck.latest_step() == 5
        tmpl = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
        restored, extras = ck.restore(tmpl)
        np.testing.assert_array_equal(np.asarray(restored["a"]),
                                      np.arange(10, dtype=np.float32))
        assert extras["pipe"]["step"] == 7

    def test_latest_pointer_and_prune(self, tmp_path):
        ck = Checkpointer(str(tmp_path))
        state = {"a": jnp.zeros(4)}
        for s in (1, 2, 3, 4):
            ck.save(s, state, blocking=True)
        assert ck.latest_step() == 4
        ck.prune(keep=2)
        steps = sorted(n for n in os.listdir(tmp_path)
                       if n.startswith("step_"))
        assert len(steps) == 2

    def test_elastic_pod_dim_reshape(self, tmp_path):
        """2-pod checkpoint restores onto a 1-pod state (and vice versa)."""
        ck = Checkpointer(str(tmp_path))
        two = {"p": jnp.stack([jnp.ones(4), jnp.ones(4) * 2])}
        ck.save(1, two, blocking=True)
        one_tmpl = {"p": jax.ShapeDtypeStruct((1, 4), jnp.float32)}
        restored, _ = ck.restore(one_tmpl)
        assert restored["p"].shape == (1, 4)
        four_tmpl = {"p": jax.ShapeDtypeStruct((4, 4), jnp.float32)}
        restored4, _ = ck.restore(four_tmpl)
        assert restored4["p"].shape == (4, 4)


class TestPipeline:
    def test_deterministic_and_restartable(self):
        model = build_model(SMOKE_ARCHS["paper-350m"])
        shape = ShapeConfig("t", 32, 2, "train")
        p1 = TokenPipeline(model, shape, seed=3)
        b1 = [next(p1) for _ in range(3)]
        snap = None
        p2 = TokenPipeline(model, shape, seed=3)
        next(p2)
        snap = p2.snapshot()
        p3 = TokenPipeline(model, shape, seed=3)
        p3.restore(snap)
        b3 = next(p3)
        np.testing.assert_array_equal(np.asarray(b1[1]["tokens"]),
                                      np.asarray(b3["tokens"]))

    def test_labels_are_shifted_tokens(self):
        model = build_model(SMOKE_ARCHS["paper-350m"])
        shape = ShapeConfig("t", 32, 2, "train")
        b = next(TokenPipeline(model, shape, seed=0))
        assert b["tokens"].shape == (2, 32)
        assert b["labels"].shape == (2, 32)

    def test_vlm_batch_has_patches(self):
        model = build_model(SMOKE_ARCHS["llava-next-mistral-7b"])
        shape = ShapeConfig("t", 32, 2, "train")
        b = next(TokenPipeline(model, shape, seed=0))
        assert "patch_embs" in b
        assert b["tokens"].shape[1] == 32 - model.cfg.n_patches


class TestTelemetry:
    def test_bandwidth_in_paper_range(self):
        profiles = make_profiles(16, seed=1)
        for p in profiles:
            for step in (0, 10, 500):
                bw = bandwidth_at(p, step, 1)
                assert BW_MIN <= bw <= BW_MAX

    def test_snapshot_keys(self):
        telem = snapshot(make_profiles(4), step=3)
        assert all({"bandwidth_mbps", "latency_ms", "jitter",
                    "straggle"} <= set(t) for t in telem)

    def test_transfer_seconds(self):
        assert transfer_seconds(1e6, 8.0, 0.0) == pytest.approx(1.0)


class TestClustering:
    def test_kmeans_separates(self):
        x = np.concatenate([np.zeros((10, 2)), np.ones((10, 2)) * 9])
        assign, cent = kmeans(x, 2)
        assert len(set(assign[:10])) == 1 and len(set(assign[10:])) == 1
        assert assign[0] != assign[-1]

    def test_reliability_weights_sum_one(self):
        telem = snapshot(make_profiles(8), step=0)
        assign = cluster_devices(telem, 3)
        w = reliability_weights(telem, assign)
        assert abs(sum(w) - 1.0) < 1e-6
        fast = dict(telem[0], bandwidth_mbps=200.0, straggle=1.0)
        slow = dict(telem[0], bandwidth_mbps=5.0, straggle=3.0)
        w2 = reliability_weights([fast, slow], [0, 1])
        assert w2[0] > w2[1]


class TestFaultTolerance:
    def test_heartbeat_marks_dead(self):
        mon = HeartbeatMonitor(3, timeout_s=10)
        now = time.time()
        mon.beat(0, 1.0, now + 5)
        mon.beat(1, 1.0, now + 5)
        # pod 2 silent since construction
        dead = mon.check(now + 11)
        assert dead == [2]
        assert mon.alive_pods() == [0, 1]

    def test_straggler_detection(self):
        mon = HeartbeatMonitor(4, timeout_s=1e9)
        for _ in range(20):
            for pod in range(4):
                mon.beat(pod, 10.0 if pod == 3 else 1.0)
        det = StragglerDetector(threshold=3.0)
        assert det.stragglers(mon) == [3]
        f = det.straggle_factors(mon)
        assert f[3] > 5 * f[0]

    def test_elastic_replan(self):
        pl = ElasticPlanner(MeshPlan(2, 16, 16))
        new = pl.on_pod_failure([1])
        assert new.shape == (16, 16)
        assert pl.rebalanced_batch(512) == 512 // 2 * 2 // 1 or True
        assert pl.rebalanced_batch(512) % (16 * 16) == 0
