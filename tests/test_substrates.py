"""Checkpointing, data pipeline, telemetry, clustering, fault tolerance."""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.core.clustering import cluster_devices, kmeans, \
    reliability_weights
from repro.data.pipeline import TokenPipeline
from repro.data.telemetry import (bandwidth_at, make_profiles, snapshot,
                                  transfer_seconds, BW_MIN, BW_MAX)
from repro.runtime.fault_tolerance import (ElasticPlanner, HeartbeatMonitor,
                                           MeshPlan, StragglerDetector)
from repro.configs import SMOKE_ARCHS
from repro.configs.base import ShapeConfig
from repro.models.registry import build_model


class TestCheckpointer:
    def test_roundtrip(self, tmp_path):
        ck = Checkpointer(str(tmp_path))
        state = {"a": jnp.arange(10, dtype=jnp.float32),
                 "b": {"c": jnp.ones((3, 4))}}
        ck.save(5, state, extras={"pipe": {"seed": 1, "step": 7}},
                blocking=True)
        assert ck.latest_step() == 5
        tmpl = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
        restored, extras = ck.restore(tmpl)
        np.testing.assert_array_equal(np.asarray(restored["a"]),
                                      np.arange(10, dtype=np.float32))
        assert extras["pipe"]["step"] == 7

    def test_latest_pointer_and_prune(self, tmp_path):
        ck = Checkpointer(str(tmp_path))
        state = {"a": jnp.zeros(4)}
        for s in (1, 2, 3, 4):
            ck.save(s, state, blocking=True)
        assert ck.latest_step() == 4
        ck.prune(keep=2)
        steps = sorted(n for n in os.listdir(tmp_path)
                       if n.startswith("step_"))
        assert len(steps) == 2

    def test_elastic_pod_dim_reshape(self, tmp_path):
        """2-pod checkpoint restores onto a 1-pod state (and vice versa)."""
        ck = Checkpointer(str(tmp_path))
        two = {"p": jnp.stack([jnp.ones(4), jnp.ones(4) * 2])}
        ck.save(1, two, blocking=True)
        one_tmpl = {"p": jax.ShapeDtypeStruct((1, 4), jnp.float32)}
        restored, _ = ck.restore(one_tmpl)
        assert restored["p"].shape == (1, 4)
        four_tmpl = {"p": jax.ShapeDtypeStruct((4, 4), jnp.float32)}
        restored4, _ = ck.restore(four_tmpl)
        assert restored4["p"].shape == (4, 4)


class TestPipeline:
    def test_deterministic_and_restartable(self):
        model = build_model(SMOKE_ARCHS["paper-350m"])
        shape = ShapeConfig("t", 32, 2, "train")
        p1 = TokenPipeline(model, shape, seed=3)
        b1 = [next(p1) for _ in range(3)]
        snap = None
        p2 = TokenPipeline(model, shape, seed=3)
        next(p2)
        snap = p2.snapshot()
        p3 = TokenPipeline(model, shape, seed=3)
        p3.restore(snap)
        b3 = next(p3)
        np.testing.assert_array_equal(np.asarray(b1[1]["tokens"]),
                                      np.asarray(b3["tokens"]))

    def test_labels_are_shifted_tokens(self):
        model = build_model(SMOKE_ARCHS["paper-350m"])
        shape = ShapeConfig("t", 32, 2, "train")
        b = next(TokenPipeline(model, shape, seed=0))
        assert b["tokens"].shape == (2, 32)
        assert b["labels"].shape == (2, 32)

    def test_vlm_batch_has_patches(self):
        model = build_model(SMOKE_ARCHS["llava-next-mistral-7b"])
        shape = ShapeConfig("t", 32, 2, "train")
        b = next(TokenPipeline(model, shape, seed=0))
        assert "patch_embs" in b
        assert b["tokens"].shape[1] == 32 - model.cfg.n_patches


class TestTelemetry:
    def test_bandwidth_in_paper_range(self):
        profiles = make_profiles(16, seed=1)
        for p in profiles:
            for step in (0, 10, 500):
                bw = bandwidth_at(p, step, 1)
                assert BW_MIN <= bw <= BW_MAX

    def test_snapshot_keys(self):
        telem = snapshot(make_profiles(4), step=3)
        assert all({"bandwidth_mbps", "latency_ms", "jitter",
                    "straggle"} <= set(t) for t in telem)

    def test_transfer_seconds(self):
        assert transfer_seconds(1e6, 8.0, 0.0) == pytest.approx(1.0)


class TestClustering:
    def test_kmeans_separates(self):
        x = np.concatenate([np.zeros((10, 2)), np.ones((10, 2)) * 9])
        assign, cent = kmeans(x, 2)
        assert len(set(assign[:10])) == 1 and len(set(assign[10:])) == 1
        assert assign[0] != assign[-1]

    def test_reliability_weights_sum_one(self):
        telem = snapshot(make_profiles(8), step=0)
        assign = cluster_devices(telem, 3)
        w = reliability_weights(telem, assign)
        assert abs(sum(w) - 1.0) < 1e-6
        fast = dict(telem[0], bandwidth_mbps=200.0, straggle=1.0)
        slow = dict(telem[0], bandwidth_mbps=5.0, straggle=3.0)
        w2 = reliability_weights([fast, slow], [0, 1])
        assert w2[0] > w2[1]


class TestFaultTolerance:
    def test_heartbeat_marks_dead(self):
        mon = HeartbeatMonitor(3, timeout_s=10)
        now = time.time()
        mon.beat(0, 1.0, now + 5)
        mon.beat(1, 1.0, now + 5)
        # pod 2 silent since construction
        dead = mon.check(now + 11)
        assert dead == [2]
        assert mon.alive_pods() == [0, 1]

    def test_straggler_detection(self):
        mon = HeartbeatMonitor(4, timeout_s=1e9)
        for _ in range(20):
            for pod in range(4):
                mon.beat(pod, 10.0 if pod == 3 else 1.0)
        det = StragglerDetector(threshold=3.0)
        assert det.stragglers(mon) == [3]
        f = det.straggle_factors(mon)
        assert f[3] > 5 * f[0]

    def test_elastic_replan(self):
        pl = ElasticPlanner(MeshPlan(2, 16, 16))
        new = pl.on_pod_failure([1])
        assert new.shape == (16, 16)
        assert pl.rebalanced_batch(512) == 512 // 2 * 2 // 1 or True
        assert pl.rebalanced_batch(512) % (16 * 16) == 0


class TestCheckpointIntegrity:
    """Corruption detection, fallback and crash-mid-save behaviour."""

    def _save_two(self, tmp_path):
        ck = Checkpointer(str(tmp_path))
        state = {"a": jnp.arange(512, dtype=jnp.float32),
                 "b": jnp.ones((64, 8))}
        ck.save(5, state, extras={"tag": 5}, blocking=True)
        ck.save(10, state, extras={"tag": 10}, blocking=True)
        tmpl = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
        return ck, state, tmpl

    def test_crc_detects_bitflips_and_falls_back(self, tmp_path):
        from repro.runtime.faults import corrupt_checkpoint_leaf
        ck, state, tmpl = self._save_two(tmp_path)
        path = corrupt_checkpoint_leaf(str(tmp_path), leaf=0, step=10)
        assert path and path.endswith("leaf_0.npy")
        # shallow verify still passes (file parses); deep catches it
        assert ck.verify(10, deep=False)
        assert not ck.verify(10, deep=True)
        restored, extras = ck.restore(tmpl)
        assert extras["tag"] == 5          # fell back to the older step
        assert 10 in ck.corrupt_steps
        np.testing.assert_array_equal(np.asarray(restored["a"]),
                                      np.arange(512, dtype=np.float32))

    def test_truncated_leaf_falls_back(self, tmp_path):
        from repro.runtime.faults import truncate_checkpoint_leaf
        ck, state, tmpl = self._save_two(tmp_path)
        assert truncate_checkpoint_leaf(str(tmp_path), leaf=1, step=10)
        _, extras = ck.restore(tmpl)
        assert extras["tag"] == 5

    def test_explicit_step_raises_on_corruption(self, tmp_path):
        from repro.checkpoint.checkpointer import CheckpointCorruptError
        from repro.runtime.faults import corrupt_checkpoint_leaf
        ck, state, tmpl = self._save_two(tmp_path)
        corrupt_checkpoint_leaf(str(tmp_path), leaf=0, step=10)
        with pytest.raises(CheckpointCorruptError):
            ck.restore(tmpl, step=10)

    def test_crash_mid_save_tmp_ignored_and_cleaned(self, tmp_path):
        ck, state, tmpl = self._save_two(tmp_path)
        # a killed writer leaves step_<N>.tmp behind
        junk = tmp_path / "step_00000015.tmp"
        junk.mkdir()
        (junk / "leaf_0.npy").write_bytes(b"partial")
        assert ck.latest_step() == 10      # .tmp never visible to readers
        _, extras = ck.restore(tmpl)
        assert extras["tag"] == 10
        ck.prune(keep=2)
        assert not junk.exists()           # prune cleans crashed writers

    def test_latest_pointer_lost_falls_back_to_scan(self, tmp_path):
        ck, state, tmpl = self._save_two(tmp_path)
        os.remove(tmp_path / "LATEST")
        assert ck.latest_step() == 10
        (tmp_path / "LATEST").write_text("step_garbage")
        assert ck.latest_step() == 10

    def test_prune_never_removes_latest_target(self, tmp_path):
        ck = Checkpointer(str(tmp_path))
        state = {"a": jnp.zeros(4)}
        for s in (1, 2, 3, 4):
            ck.save(s, state, blocking=True)
        # LATEST pinned at an older step (e.g. newer saves raced a crash)
        (tmp_path / "LATEST").write_text("step_00000002")
        ck.prune(keep=1)
        left = sorted(n for n in os.listdir(tmp_path)
                      if n.startswith("step_"))
        assert "step_00000002" in left     # restore's anchor survives
        assert "step_00000004" in left     # newest kept by keep=1

    def test_background_write_failure_is_loud(self, tmp_path):
        ck = Checkpointer(str(tmp_path / "ck"))
        blocker = tmp_path / "blocker"
        blocker.write_text("not a directory")
        ck.dir = str(blocker / "ck")       # every write attempt must fail
        ck.BACKOFF_S = 0.001
        ck.save(1, {"a": jnp.zeros(4)})
        with pytest.raises(RuntimeError, match="failed in the background"):
            ck.wait()
        # error is surfaced once, then cleared
        ck.wait()

    def test_write_retries_transient_failure(self, tmp_path):
        ck = Checkpointer(str(tmp_path))
        ck.BACKOFF_S = 0.001
        real_write, calls = ck._write, []

        def flaky(step, leaves, payload):
            calls.append(step)
            if len(calls) < 3:
                raise OSError("transient NFS blip")
            return real_write(step, leaves, payload)

        ck._write = flaky
        ck.save(7, {"a": jnp.arange(4.0)}, blocking=True)  # must not raise
        assert len(calls) == 3
        assert ck.latest_step() == 7

    def test_treedef_mismatch_raises(self, tmp_path):
        ck = Checkpointer(str(tmp_path))
        ck.save(1, {"a": jnp.zeros(4), "b": jnp.ones(4)}, blocking=True)
        tmpl = {"x": jax.ShapeDtypeStruct((4,), jnp.float32),
                "y": jax.ShapeDtypeStruct((4,), jnp.float32)}
        with pytest.raises(ValueError, match="different tree structure"):
            ck.restore(tmpl)


class TestFaultSchedule:
    def test_unknown_kind_rejected(self):
        from repro.runtime.faults import FaultEvent
        with pytest.raises(ValueError, match="unknown fault kind"):
            FaultEvent(3, "meteor_strike")

    def test_due_delivers_once_in_order(self):
        from repro.runtime.faults import (FaultEvent, FaultSchedule,
                                          KILL_POD, REJOIN_POD)
        fs = FaultSchedule([FaultEvent(8, REJOIN_POD, 1),
                            FaultEvent(3, KILL_POD, 1)])
        assert fs.due(2) == []
        ev = fs.due(5)
        assert [e.step for e in ev] == [3]
        assert fs.due(5) == []             # at most once
        assert [e.step for e in fs.due(100)] == [8]
        assert len(fs) == 0 and len(fs.fired) == 2

    def test_random_schedule_deterministic_and_paired(self):
        from repro.runtime.faults import (FaultSchedule, KILL_POD,
                                          REJOIN_POD)
        a = FaultSchedule.random(seed=7, n_steps=40, n_pods=4, n_kills=2,
                                 n_corruptions=1, n_delays=1)
        b = FaultSchedule.random(seed=7, n_steps=40, n_pods=4, n_kills=2,
                                 n_corruptions=1, n_delays=1)
        assert a.peek() == b.peek()
        kills = [e for e in a if e.kind == KILL_POD]
        joins = [e for e in a if e.kind == REJOIN_POD]
        assert len(kills) == len(joins) == 2
        for k, j in zip(kills, joins):
            assert j.step > k.step         # rejoin always after the kill
            assert k.target != 0           # coordinator pod never killed

    def test_preempt_and_rejoin_validates_order(self):
        from repro.runtime.faults import FaultSchedule
        with pytest.raises(ValueError):
            FaultSchedule.preempt_and_rejoin(pod=1, kill_step=9,
                                             rejoin_step=4)


class TestFaultToleranceElastic:
    def test_beat_unknown_pod_registers_instead_of_keyerror(self):
        mon = HeartbeatMonitor(2, timeout_s=10)
        mon.beat(5, 1.0)                   # pod id never seen: must not raise
        assert 5 in mon.alive_pods()

    def test_rejoin_clears_stale_step_times(self):
        mon = HeartbeatMonitor(2, timeout_s=10)
        for _ in range(5):
            mon.beat(1, 9.0)
        mon.mark_dead(1)
        assert 1 not in mon.alive_pods()
        mon.beat(1, 1.0)                   # rejoin via beat
        assert 1 in mon.alive_pods()
        # pre-preemption timings dropped: only the fresh beat remains
        assert mon.pods[1].step_times == [1.0]

    def test_mad_floor_suppresses_jitter_stragglers(self):
        mon = HeartbeatMonitor(4, timeout_s=1e9)
        for i in range(32):
            for pod in range(4):
                # statistically identical, ulp-level jitter only
                mon.beat(pod, 1.0 + 1e-12 * ((i + pod) % 3))
        det = StragglerDetector(threshold=3.0)
        assert det.stragglers(mon) == []

    def test_join_grows_capped_at_max(self):
        pl = ElasticPlanner(MeshPlan(3, 2, 2))
        assert pl.on_pod_failure([2]).n_pods == 2
        assert pl.on_pod_join(1).n_pods == 3
        assert pl.on_pod_join(5).n_pods == 3   # capped at the inventory

    def test_rebalanced_rows_keeps_rows_per_slice(self):
        pl = ElasticPlanner(MeshPlan(3, 2, 1))
        pl.on_pod_failure([2])             # 3 -> 2 pods
        assert pl.rebalanced_rows(6, old_n_pods=3) == 4
        pl.on_pod_join(1)                  # back to 3
        assert pl.rebalanced_rows(4, old_n_pods=2) == 6
