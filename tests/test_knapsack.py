"""Knapsack bandwidth allocator tests (paper's knapsack optimisation)."""
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests skip, the rest of the module runs
    from hypothesis_stub import given, settings, st

from repro.core import knapsack
from repro.core.compression import Level

LEVELS = [Level("FULL", 1.0, 16), Level("INT8", 1.0, 8),
          Level("TOPK25", 0.25, 8), Level("TOPK10", 0.10, 8),
          Level("TOPK1", 0.01, 8), Level("SKIP", 0.0, 0)]


def _bytes(choice, sizes):
    return knapsack.plan_bytes(choice, sizes, LEVELS, 2)


class TestKnapsack:
    def test_budget_respected(self):
        sizes = [10 ** 6] * 8
        imp = [1.0] * 8
        full = sum(LEVELS[0].wire_bytes(n, 2) for n in sizes)
        for frac in (0.05, 0.2, 0.5):
            choice = knapsack.solve(imp, sizes, LEVELS, full * frac, 2)
            assert _bytes(choice, sizes) <= full * frac + 1

    def test_unlimited_budget_goes_full(self):
        sizes = [10 ** 5] * 4
        choice = knapsack.solve([1.0] * 4, sizes, LEVELS, 10 ** 18, 2)
        assert all(LEVELS[c].is_full for c in choice)

    def test_zero_budget_all_skip(self):
        sizes = [10 ** 5] * 4
        choice = knapsack.solve([1.0] * 4, sizes, LEVELS, 0, 2)
        assert all(LEVELS[c].is_skip for c in choice)

    def test_important_groups_get_better_levels(self):
        sizes = [10 ** 6] * 4
        imp = [0.01, 0.01, 1.0, 1.0]
        full = sum(LEVELS[0].wire_bytes(n, 2) for n in sizes)
        choice = knapsack.solve(imp, sizes, LEVELS, full * 0.3, 2)
        vals = [knapsack.level_value(LEVELS[c]) for c in choice]
        assert vals[2] >= vals[0] and vals[3] >= vals[1]

    @given(st.lists(st.floats(min_value=0.0, max_value=1.0),
                    min_size=2, max_size=12),
           st.floats(min_value=0.0, max_value=1.0))
    @settings(max_examples=40, deadline=None)
    def test_budget_never_exceeded_property(self, imp, frac):
        sizes = [10 ** 5 * (i + 1) for i in range(len(imp))]
        full = sum(LEVELS[0].wire_bytes(n, 2) for n in sizes)
        budget = full * frac
        choice = knapsack.solve(imp, sizes, LEVELS, budget, 2)
        assert _bytes(choice, sizes) <= budget + 1

    def test_monotone_in_budget(self):
        """More budget never decreases total preserved value."""
        sizes = [10 ** 6, 5 * 10 ** 5, 10 ** 5]
        imp = [0.9, 0.5, 0.2]
        full = sum(LEVELS[0].wire_bytes(n, 2) for n in sizes)
        prev = -1.0
        for frac in (0.0, 0.1, 0.3, 0.6, 1.0):
            choice = knapsack.solve(imp, sizes, LEVELS, full * frac, 2)
            val = sum(knapsack.level_value(LEVELS[c]) * imp[i]
                      for i, c in enumerate(choice))
            assert val >= prev - 1e-9
            prev = val


class TestDeviceSolver:
    """The vectorized (jittable) knapsack the device-resident replan
    runs: convex-hull greedy, conservative but never over budget."""

    def _solver(self, sizes):
        return knapsack.make_device_solver(sizes, LEVELS, 2)

    def test_budget_respected(self):
        import jax.numpy as jnp
        sizes = [10 ** 6] * 8
        solver = self._solver(sizes)
        full = sum(LEVELS[0].wire_bytes(n, 2) for n in sizes)
        for frac in (0.0, 0.05, 0.2, 0.5, 0.8, 1.0):
            choice = np.asarray(solver(jnp.ones((8,), jnp.float32),
                                       jnp.float32(full * frac))).tolist()
            assert _bytes(choice, sizes) <= full * frac + 1

    def test_budget_extremes(self):
        import jax.numpy as jnp
        sizes = [10 ** 5] * 4
        solver = self._solver(sizes)
        lo = np.asarray(solver(jnp.ones((4,), jnp.float32),
                               jnp.float32(0.0)))
        assert all(LEVELS[c].is_skip for c in lo)
        hi = np.asarray(solver(jnp.ones((4,), jnp.float32),
                               jnp.float32(1e18)))
        assert all(LEVELS[c].is_full for c in hi)

    def test_important_groups_get_better_levels(self):
        import jax.numpy as jnp
        sizes = [10 ** 6] * 4
        solver = self._solver(sizes)
        full = sum(LEVELS[0].wire_bytes(n, 2) for n in sizes)
        choice = np.asarray(solver(
            jnp.asarray([0.01, 0.01, 1.0, 1.0], jnp.float32),
            jnp.float32(full * 0.3)))
        vals = [knapsack.level_value(LEVELS[c]) for c in choice]
        assert vals[2] >= vals[0] and vals[3] >= vals[1]

    def test_jit_once_budget_is_data(self):
        """Budget and importance are traced data: sweeping them reuses
        one compiled solver (the replan path never retraces)."""
        import jax
        import jax.numpy as jnp
        sizes = [10 ** 5] * 6
        solver = jax.jit(self._solver(sizes))
        full = sum(LEVELS[0].wire_bytes(n, 2) for n in sizes)
        for frac in (0.1, 0.4, 0.9):
            np.asarray(solver(jnp.ones((6,), jnp.float32),
                              jnp.float32(full * frac)))
        assert solver._cache_size() == 1

    def test_hull_is_importance_invariant(self):
        """Scaling all importances leaves the plan unchanged (the hull —
        and hence the density ORDER — is importance-scale-invariant)."""
        import jax.numpy as jnp
        sizes = [10 ** 6, 2 * 10 ** 5, 4 * 10 ** 5]
        solver = self._solver(sizes)
        full = sum(LEVELS[0].wire_bytes(n, 2) for n in sizes)
        imp = jnp.asarray([0.9, 0.2, 0.5], jnp.float32)
        a = np.asarray(solver(imp, jnp.float32(full * 0.4)))
        b = np.asarray(solver(imp * 0.1, jnp.float32(full * 0.4)))
        assert (a == b).all()
