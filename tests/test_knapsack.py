"""Knapsack bandwidth allocator tests (paper's knapsack optimisation)."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests skip, the rest of the module runs
    from hypothesis_stub import given, settings, st

from repro.core import knapsack
from repro.core.compression import Level

LEVELS = [Level("FULL", 1.0, 16), Level("INT8", 1.0, 8),
          Level("TOPK25", 0.25, 8), Level("TOPK10", 0.10, 8),
          Level("TOPK1", 0.01, 8), Level("SKIP", 0.0, 0)]


def _bytes(choice, sizes):
    return knapsack.plan_bytes(choice, sizes, LEVELS, 2)


class TestKnapsack:
    def test_budget_respected(self):
        sizes = [10 ** 6] * 8
        imp = [1.0] * 8
        full = sum(LEVELS[0].wire_bytes(n, 2) for n in sizes)
        for frac in (0.05, 0.2, 0.5):
            choice = knapsack.solve(imp, sizes, LEVELS, full * frac, 2)
            assert _bytes(choice, sizes) <= full * frac + 1

    def test_unlimited_budget_goes_full(self):
        sizes = [10 ** 5] * 4
        choice = knapsack.solve([1.0] * 4, sizes, LEVELS, 10 ** 18, 2)
        assert all(LEVELS[c].is_full for c in choice)

    def test_zero_budget_all_skip(self):
        sizes = [10 ** 5] * 4
        choice = knapsack.solve([1.0] * 4, sizes, LEVELS, 0, 2)
        assert all(LEVELS[c].is_skip for c in choice)

    def test_important_groups_get_better_levels(self):
        sizes = [10 ** 6] * 4
        imp = [0.01, 0.01, 1.0, 1.0]
        full = sum(LEVELS[0].wire_bytes(n, 2) for n in sizes)
        choice = knapsack.solve(imp, sizes, LEVELS, full * 0.3, 2)
        vals = [knapsack.level_value(LEVELS[c]) for c in choice]
        assert vals[2] >= vals[0] and vals[3] >= vals[1]

    @given(st.lists(st.floats(min_value=0.0, max_value=1.0),
                    min_size=2, max_size=12),
           st.floats(min_value=0.0, max_value=1.0))
    @settings(max_examples=40, deadline=None)
    def test_budget_never_exceeded_property(self, imp, frac):
        sizes = [10 ** 5 * (i + 1) for i in range(len(imp))]
        full = sum(LEVELS[0].wire_bytes(n, 2) for n in sizes)
        budget = full * frac
        choice = knapsack.solve(imp, sizes, LEVELS, budget, 2)
        assert _bytes(choice, sizes) <= budget + 1

    def test_monotone_in_budget(self):
        """More budget never decreases total preserved value."""
        sizes = [10 ** 6, 5 * 10 ** 5, 10 ** 5]
        imp = [0.9, 0.5, 0.2]
        full = sum(LEVELS[0].wire_bytes(n, 2) for n in sizes)
        prev = -1.0
        for frac in (0.0, 0.1, 0.3, 0.6, 1.0):
            choice = knapsack.solve(imp, sizes, LEVELS, full * frac, 2)
            val = sum(knapsack.level_value(LEVELS[c]) * imp[i]
                      for i, c in enumerate(choice))
            assert val >= prev - 1e-9
            prev = val
