"""End-to-end behaviour tests for the paper's system: ACE-Sync training
converges, baselines behave per Table 1's ordering, checkpoint/restart is
exact, divergence control reacts."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SMOKE_ARCHS
from repro.configs.base import ACESyncConfig, RunConfig, ShapeConfig
from repro.core.trainer import Trainer
from repro.core import sync as S
from repro.data.pipeline import TokenPipeline
from repro.launch.train import TrainLoop
from repro.models.registry import build_model

SHAPE = ShapeConfig("sys", 64, 4, "train")


def _run(strategy, steps=25, seed=0, **ace_kw):
    cfg = SMOKE_ARCHS["paper-350m"]
    run = RunConfig(model=cfg, shape=SHAPE, total_steps=steps,
                    warmup_steps=2, lr=1e-3,
                    acesync=ACESyncConfig(**ace_kw) if ace_kw
                    else ACESyncConfig())
    model = build_model(cfg, run)
    trainer = Trainer(model, run, mesh=None, strategy=strategy)
    state = trainer.init_state(jax.random.PRNGKey(seed))
    pipe = TokenPipeline(model, SHAPE, seed=seed)
    plan = trainer.default_plan(bandwidth_mbps=30.0)
    fn = trainer.step_fn(plan, "grad_sync")
    losses = []
    for _ in range(steps):
        batch = next(pipe)
        state, metrics = fn(state, batch)
        losses.append(float(metrics["loss"]))
    return losses, state, trainer


class TestTraining:
    def test_acesync_loss_decreases(self):
        losses, _, _ = _run("acesync", steps=30)
        assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.05, losses[:3]

    def test_fullsync_loss_decreases(self):
        losses, _, _ = _run("fullsync", steps=30)
        assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.05

    def test_acesync_tracks_fullsync(self):
        """Table-1 claim at smoke scale: compressed training stays close to
        the full-precision baseline."""
        l_full, _, _ = _run("fullsync", steps=30)
        l_ace, _, _ = _run("acesync", steps=30)
        assert abs(np.mean(l_ace[-5:]) - np.mean(l_full[-5:])) < 0.25

    def test_topk_baseline_runs(self):
        losses, _, _ = _run("topk", steps=15)
        assert np.isfinite(losses).all()

    def test_acesync_comm_cheaper_than_fullsync(self):
        _, _, tr_ace = _run("acesync", steps=2)
        plan_ace = tr_ace.default_plan(bandwidth_mbps=20.0)
        sched = tr_ace.scheduler
        assert sched.plan_wire_bytes(plan_ace) < sched.fullsync_wire_bytes()


class TestCheckpointRestart:
    def test_restart_is_exact(self, tmp_path):
        cfg = SMOKE_ARCHS["paper-350m"]
        run = RunConfig(model=cfg, shape=SHAPE, total_steps=30,
                        warmup_steps=2, ckpt_every=5,
                        ckpt_dir=str(tmp_path))
        model = build_model(cfg, run)

        loop = TrainLoop(model, run, mesh=None, strategy="fullsync")
        pipe = TokenPipeline(model, SHAPE, seed=0)
        state = loop.restore_or_init(jax.random.PRNGKey(0), pipe)
        state = loop.run_steps(state, pipe, 7, log_every=0)
        loop.ckpt.wait()
        state = loop.run_steps(state, pipe, 2, log_every=0)
        ref_loss = loop.history[-1]["loss"]
        ref_step = loop.history[-1]["step"]

        # crash-restart: fresh loop restores the checkpoint and replays the
        # pipeline to the same step -> identical loss
        loop2 = TrainLoop(model, run, mesh=None, strategy="fullsync")
        pipe2 = TokenPipeline(model, SHAPE, seed=0)
        state2 = loop2.restore_or_init(jax.random.PRNGKey(1), pipe2)
        step2 = int(jax.tree.leaves(state2["step"])[0].reshape(-1)[0])
        assert step2 == 5
        state2 = loop2.run_steps(state2, pipe2, ref_step - step2 + 1,
                                 log_every=0)
        loss2 = loop2.history[-1]["loss"]
        assert loop2.history[-1]["step"] == ref_step
        assert abs(loss2 - ref_loss) < 5e-3, (loss2, ref_loss)


class TestDivergenceControl:
    def test_identical_pods_zero_divergence(self):
        from repro.core import divergence as D
        params = {"w": jnp.ones((32, 32))}
        d = D.pod_divergence(params, mesh=None)
        assert float(d) == 0.0

    def test_projection_scales_with_param_change(self):
        from repro.core import divergence as D
        p1 = {"w": jnp.ones((64, 64))}
        p2 = {"w": jnp.ones((64, 64)) * 2}
        n1 = D.params_norm_estimate(p1)
        n2 = D.params_norm_estimate(p2)
        assert abs(float(n2) / max(float(n1), 1e-9) - 2.0) < 0.05
