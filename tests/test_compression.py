"""Unit + property tests for the compression operators (paper eq. 6-7)."""
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests skip, the rest of the module runs
    from hypothesis_stub import given, settings, st

from repro.core import compression as C


LEVELS = [C.Level("FULL", 1.0, 16), C.Level("INT8", 1.0, 8),
          C.Level("TOPK25", 0.25, 8), C.Level("TOPK10", 0.10, 8),
          C.Level("TOPK1", 0.01, 8), C.Level("SKIP", 0.0, 0)]


def _rand(n, seed=0):
    return jnp.asarray(np.random.RandomState(seed).randn(n).astype(np.float32))


class TestTopK:
    def test_topk_keeps_largest(self):
        blocks = _rand(2048).reshape(2, 1024)
        q, idx, scale = C.topk_compress(blocks, 16)
        dense = C.topk_decompress(q, idx, scale)
        for r in range(2):
            mag = np.abs(np.asarray(blocks[r]))
            kept = np.nonzero(np.asarray(dense[r]))[0]
            thresh = np.sort(mag)[-16]
            assert np.all(mag[kept] >= thresh * 0.5)

    def test_topk_roundtrip_error_bounded(self):
        blocks = _rand(4096).reshape(4, 1024)
        q, idx, scale = C.topk_compress(blocks, 128)
        dense = C.topk_decompress(q, idx, scale)
        # kept values quantised to int8: relative error <= scale/2 per entry
        mask = np.asarray(dense) != 0
        err = np.abs(np.asarray(dense) - np.asarray(blocks))[mask]
        assert err.max() <= np.asarray(scale).max() * 0.51

    def test_int8_roundtrip(self):
        blocks = _rand(2048, 3).reshape(2, 1024) * 10
        q, scale = C.int8_compress(blocks)
        back = C.int8_decompress(q, scale)
        np.testing.assert_allclose(np.asarray(back), np.asarray(blocks),
                                   atol=float(scale.max()) * 0.51)

    @pytest.mark.parametrize("level", LEVELS, ids=lambda l: l.name)
    def test_roundtrip_shapes(self, level):
        flat = _rand(3000, 7)  # non-multiple of block
        out = C.roundtrip(flat, level)
        assert out.shape == flat.shape
        assert out.dtype == flat.dtype
        if level.is_skip:
            assert float(jnp.abs(out).max()) == 0.0
        if level.is_full:
            np.testing.assert_allclose(np.asarray(out), np.asarray(flat),
                                       rtol=1e-2, atol=1e-2)


class TestWireBytes:
    def test_monotone_ladder(self):
        n, P = 1_000_000, 2
        byts = [l.wire_bytes(n, P) for l in LEVELS]
        assert byts[-1] == 0            # SKIP free
        assert byts[0] > byts[2] > byts[3] > byts[4]  # FULL > topk ladder

    def test_single_pod_free(self):
        assert C.Level("FULL", 1.0, 16).wire_bytes(10 ** 6, 1) == 0

    @given(st.integers(min_value=1, max_value=10 ** 7),
           st.sampled_from([0.25, 0.10, 0.01]))
    @settings(max_examples=30, deadline=None)
    def test_topk_cheaper_than_full(self, n, ratio):
        full = C.Level("FULL", 1.0, 16).wire_bytes(n, 2)
        topk = C.Level("T", ratio, 8).wire_bytes(n, 2)
        if n >= C.BLOCK:  # tiny tensors have per-block overhead
            assert topk < full


class TestErrorFeedbackProperty:
    @given(st.integers(min_value=0, max_value=2 ** 31 - 1))
    @settings(max_examples=20, deadline=None)
    def test_residual_plus_transmitted_is_exact(self, seed):
        """decompress(compress(ef)) + residual == ef, for every level."""
        flat = _rand(2048, seed % 1000)
        for level in LEVELS:
            sent = C.roundtrip(flat, level)
            resid = flat - sent
            np.testing.assert_allclose(np.asarray(sent + resid),
                                       np.asarray(flat), rtol=1e-5,
                                       atol=1e-5)

    def test_error_feedback_transmits_everything_eventually(self):
        """With EF, the cumulative transmitted signal approaches the
        cumulative gradient (Stich et al. 2018 memory property)."""
        level = C.Level("TOPK10", 0.10, 8)
        g = _rand(1024, 42)
        e = jnp.zeros_like(g)
        sent_total = jnp.zeros_like(g)
        rels = []
        for t in range(150):
            ef = g + e
            sent = C.roundtrip(ef, level)
            e = ef - sent
            sent_total = sent_total + sent
            avg_sent = sent_total / (t + 1)
            rels.append(float(jnp.linalg.norm(avg_sent - g)
                              / jnp.linalg.norm(g)))
        assert rels[-1] < 0.05, rels[-1]
        assert rels[-1] < rels[10]  # steadily improving
