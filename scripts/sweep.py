#!/usr/bin/env python
"""Crash-isolated dry-run sweep: one subprocess per cell (an XLA CHECK
abort then costs one cell, not the sweep). Resumable: cells with an OK
JSON in the results dir are skipped."""
import json
import os
import subprocess
import sys
import time

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT = os.path.join(ROOT, "benchmarks", "results")
os.makedirs(OUT, exist_ok=True)
sys.path.insert(0, os.path.join(ROOT, "src"))

from repro.configs import cells  # noqa: E402
from repro.strategies import list_strategies  # noqa: E402


def done_ok(mesh, arch, shape, strategy):
    f = os.path.join(OUT, f"{mesh}_{arch}_{shape}_{strategy}.json")
    if not os.path.exists(f):
        return False
    try:
        return json.load(open(f)).get("ok", False)
    except Exception:
        return False


def run(arch, shape, multi_pod, strategy="acesync", timeout=900):
    mesh = "2x16x16" if multi_pod else "16x16"
    if done_ok(mesh, arch, shape, strategy):
        print(f"skip {mesh} {arch} {shape} {strategy} (done)", flush=True)
        return True
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--strategy", strategy, "--out", OUT]
    if multi_pod:
        cmd.append("--multi-pod")
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    t0 = time.time()
    try:
        r = subprocess.run(cmd, cwd=ROOT, env=env, timeout=timeout,
                           capture_output=True, text=True)
        tail = (r.stdout or "").strip().splitlines()
        print("\n".join(tail[-2:]) if tail else f"rc={r.returncode}",
              flush=True)
        if r.returncode != 0:
            f = os.path.join(OUT, f"{mesh}_{arch}_{shape}_{strategy}.json")
            if not os.path.exists(f):
                json.dump({"arch": arch, "shape": shape, "mesh": mesh,
                           "strategy": strategy, "ok": False,
                           "error": f"subprocess rc={r.returncode}",
                           "stderr_tail": (r.stderr or "")[-2000:]},
                          open(f, "w"), indent=1)
        return r.returncode == 0
    except subprocess.TimeoutExpired:
        print(f"TIMEOUT {mesh} {arch} {shape}", flush=True)
        return False


def main():
    todo = []
    for arch, shape in cells():
        todo.append((arch, shape, True, "acesync"))
    for arch, shape in cells():
        todo.append((arch, shape, False, "acesync"))
    # strategy comparison (HLO-level Table 1 evidence): every registered
    # strategy on the paper arch, the paper's four on qwen3-8b
    for s in list_strategies():
        if s != "acesync":
            todo.append(("paper-350m", "train_4k", True, s))
    for s in ("fullsync", "topk", "fedavg"):
        todo.append(("qwen3-8b", "train_4k", True, s))
    todo.append(("paper-350m", "train_4k", True, "acesync"))
    todo.append(("paper-350m", "train_4k", False, "acesync"))

    t0 = time.time()
    fails = 0
    for i, (arch, shape, mp, strat) in enumerate(todo):
        print(f"--- [{i+1}/{len(todo)}] {arch} {shape} "
              f"{'multi' if mp else 'single'} {strat} "
              f"(t={time.time()-t0:.0f}s)", flush=True)
        if not run(arch, shape, mp, strat):
            fails += 1
    print(f"SWEEP DONE fails={fails} t={time.time()-t0:.0f}s", flush=True)


if __name__ == "__main__":
    main()
