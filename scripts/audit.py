#!/usr/bin/env python
"""Graph auditor CLI: prove the hot path's comm/donation/recompile
invariants on the simulated (2,2,2) meshes.

    PYTHONPATH=src python scripts/audit.py                # all strategies
    PYTHONPATH=src python scripts/audit.py --strategy acesync --out AUDIT.json
    PYTHONPATH=src python scripts/audit.py --fail-on-violation   # CI gate

MUST set the host-device override before ANY import touches jax."""
import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")
os.environ.setdefault("REPRO_FORCE_INTERPRET", "1")

import argparse
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--strategy", action="append", default=None,
                    help="strategy to audit (repeatable; default: all "
                         "shipped strategies)")
    ap.add_argument("--out", default="AUDIT.json",
                    help="report path (default: AUDIT.json)")
    ap.add_argument("--fail-on-violation", action="store_true",
                    help="exit 1 when any pass reports an error")
    ap.add_argument("--no-compile", action="store_true",
                    help="source-level passes only (no step lowering)")
    args = ap.parse_args(argv)

    sys.path.insert(0, os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "src"))
    from repro.analysis import run_audit

    report = run_audit(strategies=args.strategy,
                       skip_compile=args.no_compile)
    with open(args.out, "w") as fh:
        fh.write(report.to_json())
    print(report.summary())
    print(f"wrote {args.out}")
    if args.fail_on_violation and not report.ok:
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
